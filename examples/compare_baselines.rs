//! Baseline bake-off on one model (a single Table-2-style column): run
//! every PTQ method at 4/3/2-bit weights and print the accuracy cliff.
//! Demonstrates the `Method` registry of the experiment layer as a library
//! API (the `exp table2` subcommand drives the full grid).

use anyhow::Result;

use brecq::coordinator::experiments::{quantize_with, ExpOpts, Method};
use brecq::coordinator::Env;
use brecq::eval::{accuracy, EvalParams};
use brecq::recon::BitConfig;

fn main() -> Result<()> {
    let env = Env::bootstrap(None)?;
    let mname = std::env::args().nth(1)
        .unwrap_or_else(|| "resnet_s".into());
    let model = env.model(&mname);
    let train = env.train_set()?;
    let test = env.test_set()?;
    let o = ExpOpts { iters: 150, calib_n: 256, ..ExpOpts::default() };
    let calib = env.calib(&train, o.calib_n, o.seed);

    println!("{mname}: FP {:.2}%", model.fp_acc * 100.0);
    println!("{:<22} {:>6} {:>6} {:>6}", "method", "W4", "W3", "W2");
    for method in [Method::BiasCorr, Method::Omse, Method::AdaRoundLayer,
                   Method::AdaQuantLike, Method::Brecq] {
        let mut row = format!("{:<22}", method.name());
        for wbits in [4usize, 3, 2] {
            let bits = BitConfig::uniform(model, wbits, None, true);
            let qm = quantize_with(&env, &mname, method, &calib, &bits, &o)?;
            let acc = accuracy(&env.rt, model,
                               &EvalParams::quantized(&qm), &test)?;
            row.push_str(&format!(" {:>6.2}", acc * 100.0));
        }
        println!("{row}");
    }
    Ok(())
}
