//! Baseline bake-off on one model (a single Table-2-style column): run
//! every PTQ method at 4/3/2-bit weights as one batch and print the
//! accuracy cliff.
//!
//! Demonstrates `Session::run_many`: the 15 jobs execute concurrently on
//! the worker pool and share one artifact cache, so FP weights and the
//! calibration subset are loaded once instead of 15 times — check the
//! cache stats printed at the end.

use anyhow::Result;

use brecq::coordinator::Env;
use brecq::pipeline::{JobSpec, Method, Session};

fn main() -> Result<()> {
    let session = Session::new(Env::bootstrap(None)?);
    let mname = std::env::args().nth(1)
        .unwrap_or_else(|| "resnet_s".into());
    println!("{mname}: FP {:.2}%",
             session.model(&mname)?.fp_acc * 100.0);

    let methods = [Method::BiasCorr, Method::Omse, Method::AdaRoundLayer,
                   Method::AdaQuantLike, Method::Brecq];
    let wbit_grid = [4usize, 3, 2];
    let mut specs = Vec::new();
    for &method in &methods {
        for &wbits in &wbit_grid {
            specs.push(JobSpec {
                model: mname.clone(),
                method,
                wbits,
                abits: None,
                iters: 80,
                calib_n: 256,
                ..JobSpec::default()
            });
        }
    }
    let results = session.run_many(&specs);

    println!("{:<22} {:>6} {:>6} {:>6}", "method", "W4", "W3", "W2");
    let mut i = 0;
    for method in methods {
        let mut row = format!("{:<22}", method.name());
        for _ in wbit_grid {
            match &results[i] {
                Ok(out) => row.push_str(&format!(
                    " {:>6.2}",
                    out.accuracy.unwrap_or(0.0) * 100.0
                )),
                Err(e) => {
                    row.push_str(" err   ");
                    eprintln!("job {i} failed: {e}");
                }
            }
            i += 1;
        }
        println!("{row}");
    }
    let (hits, misses) = session.cache().stats();
    println!("(artifact cache: {hits} hits / {misses} misses — FP weights \
              and the calib subset were computed once for all 15 jobs)");
    Ok(())
}
