//! Zero-shot PTQ (paper §B.2 / Table 4's "w/ Distilled Data" row): no real
//! training data is available — synthesize calibration images from the FP
//! model's BatchNorm statistics (ZeroQ-style distillation), then run BRECQ
//! on the distilled set and compare against calibration on real data.

use anyhow::Result;

use brecq::coordinator::Env;
use brecq::distill::{distill, DistillConfig};
use brecq::eval::{accuracy, EvalParams};
use brecq::recon::{BitConfig, Calibrator, ReconConfig};

fn main() -> Result<()> {
    let env = Env::bootstrap(None)?;
    let model = env.model("resnet_s");
    let test = env.test_set()?;
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let bits = BitConfig::uniform(model, 4, Some(4), true);
    let cfg = ReconConfig { iters: 150, ..ReconConfig::default() };

    // distilled calibration set — zero real images used
    let dcal = distill(&env.rt, &env.mf, model, &DistillConfig {
        total: 256,
        verbose: true,
        ..DistillConfig::default()
    })?;
    println!("distilled {} images (labels = FP model predictions)",
             dcal.len());
    let qm = cal.calibrate(&dcal, &bits, &cfg)?;
    let acc_d = accuracy(&env.rt, model, &EvalParams::quantized(&qm), &test)?;

    // real-data reference
    let train = env.train_set()?;
    let rcal = env.calib(&train, 256, 0);
    let qm = cal.calibrate(&rcal, &bits, &cfg)?;
    let acc_r = accuracy(&env.rt, model, &EvalParams::quantized(&qm), &test)?;

    println!("W4A4 with distilled data: {:.2}%", acc_d * 100.0);
    println!("W4A4 with real data:      {:.2}%", acc_r * 100.0);
    println!("(paper: distilled ~= real at 4-bit, gap opens at 2-bit)");
    Ok(())
}
