//! Zero-shot PTQ (paper §B.2 / Table 4's "w/ Distilled Data" row): no real
//! training data is available — synthesize calibration images from the FP
//! model's BatchNorm statistics (ZeroQ-style distillation), then run BRECQ
//! on the distilled set and compare against calibration on real data.
//!
//! The data source is just a typed `JobSpec` field: the same pipeline runs
//! `source: Distilled` and `source: Train` as one batch. Distillation
//! needs the model's distill executable — absent in the generated
//! synthetic environment, in which case only the real-data reference runs.

use anyhow::Result;

use brecq::coordinator::Env;
use brecq::pipeline::{DataSource, JobSpec, Session};

fn main() -> Result<()> {
    let session = Session::new(Env::bootstrap(None)?);
    let model = session.model("resnet_s")?;

    let real = JobSpec {
        model: "resnet_s".into(),
        wbits: 4,
        abits: Some(4),
        iters: 150,
        calib_n: 256,
        source: DataSource::Train,
        ..JobSpec::default()
    };

    if model.distill_exe.is_none() {
        println!("resnet_s exports no distill executable in this \
                  environment (the synthetic env has none) — running the \
                  real-data reference only");
        let out = session.run(&real)?;
        println!("W4A4 with real data: {:.2}%",
                 out.accuracy.unwrap_or(0.0) * 100.0);
        return Ok(());
    }

    let distilled = JobSpec { source: DataSource::Distilled, ..real.clone() };
    let mut results = session.run_many(&[distilled, real]);
    let out_r = results.pop().unwrap()?;
    let out_d = results.pop().unwrap()?;

    println!("W4A4 with distilled data: {:.2}%",
             out_d.accuracy.unwrap_or(0.0) * 100.0);
    println!("W4A4 with real data:      {:.2}%",
             out_r.accuracy.unwrap_or(0.0) * 100.0);
    println!("(paper: distilled ~= real at 4-bit, gap opens at 2-bit)");
    Ok(())
}
