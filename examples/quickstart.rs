//! Quickstart: post-training-quantize a pretrained model to 4-bit weights /
//! 4-bit activations with BRECQ block reconstruction, then evaluate it.
//!
//!     make artifacts                       # once: trains + AOT-lowers
//!     cargo run --release --example quickstart
//!
//! This is the full public-API surface a downstream user touches: bootstrap
//! an `Env` from the artifacts, pick a `BitConfig`, run the `Calibrator`,
//! evaluate the `QuantizedModel`.

use anyhow::Result;

use brecq::coordinator::Env;
use brecq::eval::{accuracy, EvalParams};
use brecq::recon::{BitConfig, Calibrator, ReconConfig};

fn main() -> Result<()> {
    // 1. load artifacts (manifest + PJRT runtime + datasets)
    let env = Env::bootstrap(None)?;
    let model = env.model("resnet_s");
    println!("model {} — FP reference accuracy {:.2}%",
             model.name, model.fp_acc * 100.0);

    // 2. the paper's calibration protocol: 1024 images from the train set
    let train = env.train_set()?;
    let calib = env.calib(&train, 256, /*seed=*/ 0);

    // 3. W4A4, first & last layer kept at 8-bit (paper §4.2 policy)
    let bits = BitConfig::uniform(model, 4, Some(4), true);

    // 4. BRECQ block reconstruction (Algorithm 1)
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let cfg = ReconConfig { iters: 150, verbose: true,
                            ..ReconConfig::default() };
    let qm = cal.calibrate(&calib, &bits, &cfg)?;
    println!("calibrated in {:.1}s", qm.calib_seconds);

    // 5. evaluate the quantized model on the held-out test set
    let test = env.test_set()?;
    let acc = accuracy(&env.rt, model, &EvalParams::quantized(&qm), &test)?;
    println!("W4A4 top-1: {:.2}%  (FP {:.2}%)", acc * 100.0,
             model.fp_acc * 100.0);
    Ok(())
}
