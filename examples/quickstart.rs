//! Quickstart: post-training-quantize a pretrained model to 4-bit weights /
//! 4-bit activations with BRECQ block reconstruction, then evaluate it.
//!
//!     cargo run --release --example quickstart
//!
//! Works out of the box on the generated synthetic environment; point
//! `BRECQ_ARTIFACTS` at a `make artifacts` export for the full models.
//!
//! This is the whole public API surface a downstream user touches: build a
//! `Session` over an `Env`, describe the job as a typed `JobSpec`, and
//! `run` it — the session compiles the spec into its stage DAG
//! (fp-weights -> calib -> reconstruct -> eval) and caches every shared
//! intermediate for later jobs.

use anyhow::Result;

use brecq::coordinator::Env;
use brecq::pipeline::{JobSpec, Method, Session};

fn main() -> Result<()> {
    // 1. one session per environment; jobs share its artifact cache
    let session = Session::new(Env::bootstrap(None)?);
    let model = session.model("resnet_s")?;
    println!("model {} — FP reference accuracy {:.2}%",
             model.name, model.fp_acc * 100.0);

    // 2. W4A4 BRECQ at block granularity, first & last layer kept at
    //    8-bit (paper §4.2 policy) — all JobSpec defaults except the knobs
    //    we care about
    let spec = JobSpec {
        model: "resnet_s".into(),
        method: Method::Brecq,
        wbits: 4,
        abits: Some(4),
        iters: 150,
        calib_n: 256,
        verbose: true,
        ..JobSpec::default()
    };
    println!("stages: {}", spec.describe_stages());

    // 3. run the job (Algorithm 1 + held-out evaluation)
    let out = session.run(&spec)?;
    println!("calibrated in {:.1}s", out.calib_seconds());
    for r in out.reports() {
        println!("  unit {:<14} loss {:.3e} -> {:.3e}",
                 r.name, r.initial_loss, r.final_loss);
    }
    println!("W4A4 top-1: {:.2}%  (FP {:.2}%)",
             out.accuracy.unwrap_or(0.0) * 100.0, out.fp_acc * 100.0);
    Ok(())
}
