//! Mixed-precision deployment scenario (paper §3.4 / Fig. 2): a model must
//! fit a hardware latency budget on the precision-scalable accelerator.
//!
//! One `JobSpec` with `search` set runs the whole pipeline —
//! fp-weights -> calib -> sensitivity -> mp-search -> reconstruct ->
//! eval -> hw-report — and a second spec calibrates the unified-precision
//! alternative at the same budget. Both run as one batch; the sensitivity
//! LUT and calibration artifacts are computed once and shared.

use anyhow::Result;

use brecq::pipeline::{Hardware, HwBudget, JobSpec, Method, Session};
use brecq::coordinator::Env;

fn main() -> Result<()> {
    let session = Session::new(Env::bootstrap(None)?);
    let model = session.model("resnet_s")?;
    let nl = model.layers.len();

    // budget: 60% of the way from all-8-bit down to all-2-bit latency
    let fpga = Hardware::Fpga.measurer();
    let t8 = fpga.measure(model, &vec![8; nl], 8);
    let t2 = fpga.measure(model, &vec![2; nl], 8);
    let budget = t2 + (t8 - t2) * 0.4;
    println!("systolic latency: all-8 {t8:.2}ms, all-2 {t2:.2}ms, \
              budget {budget:.2}ms");

    let mixed = JobSpec {
        model: "resnet_s".into(),
        method: Method::Brecq,
        abits: Some(8),
        iters: 150,
        calib_n: 256,
        search: Some(HwBudget {
            hw: Hardware::Fpga,
            budget,
            relative: false,
        }),
        hw_report: true,
        ..JobSpec::default()
    };
    let unified = JobSpec { search: None, wbits: 2, ..mixed.clone() };
    let mut results = session.run_many(&[mixed, unified]);
    let uni = results.pop().unwrap()?;
    let mix = results.pop().unwrap()?;

    let res = mix.search.as_ref().expect("search job carries GA result");
    println!("GA ({} configs, {:.2}s): H(c) = {:.2}ms", res.evaluated,
             res.seconds, res.hw_cost);
    for (l, layer) in model.layers.iter().enumerate() {
        println!("  {:<16} {}-bit", layer.name, mix.wbits[l]);
    }
    println!("mixed-precision model: {:.2}% top-1 at {:.2}ms",
             mix.accuracy.unwrap_or(0.0) * 100.0, res.hw_cost);
    let hw = mix.hw.as_ref().expect("hw_report requested");
    println!("  deploy: {:.3} MB, FPGA {:.2}ms", hw.size_mb, hw.fpga_ms);

    let uhw = uni.hw.as_ref().expect("hw_report requested");
    println!("unified 2-bit at {:.2}ms: {:.2}% top-1  (mixed wins: {})",
             uhw.fpga_ms, uni.accuracy.unwrap_or(0.0) * 100.0,
             mix.accuracy > uni.accuracy);
    Ok(())
}
