//! Mixed-precision deployment scenario (paper §3.4 / Fig. 2): a model must
//! fit a hardware latency budget on the precision-scalable accelerator.
//!
//! Pipeline: sensitivity profiling (diagonal + intra-block off-diagonal)
//! -> genetic bitwidth search under the systolic simulator's H(c)
//! -> BRECQ calibration of the winning configuration -> evaluation,
//! compared against the unified-precision alternative at the same budget.

use anyhow::Result;

use brecq::coordinator::Env;
use brecq::eval::{accuracy, EvalParams};
use brecq::hwsim::{HwMeasure, Systolic};
use brecq::mp::{GaConfig, GeneticSearch};
use brecq::recon::{BitConfig, Calibrator, ReconConfig};
use brecq::sensitivity::Profiler;

fn main() -> Result<()> {
    let env = Env::bootstrap(None)?;
    let model = env.model("resnet_s");
    let train = env.train_set()?;
    let test = env.test_set()?;
    let calib = env.calib(&train, 256, 0);
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights()?;

    let sim = Systolic::default();
    let t8 = sim.measure(model, &vec![8; model.layers.len()], 8);
    let t2 = sim.measure(model, &vec![2; model.layers.len()], 8);
    // budget: 60% of the way from all-8-bit down to all-2-bit latency
    let budget = t2 + (t8 - t2) * 0.4;
    println!("systolic latency: all-8 {t8:.2}ms, all-2 {t2:.2}ms, \
              budget {budget:.2}ms");

    // sensitivity LUT with the paper's intra-block 2-bit pair terms
    let prof = Profiler { rt: &env.rt, mf: &env.mf, model };
    let table = prof.measure(&calib, &ws, &bs, true)?;

    let ga = GeneticSearch { model, table: &table, hw: &sim, abits: 8,
                             budget };
    let res = ga.run(&GaConfig::default())?;
    println!("GA ({} configs, {:.2}s): H(c) = {:.2}ms", res.evaluated,
             res.seconds, res.hw_cost);
    for (l, layer) in model.layers.iter().enumerate() {
        println!("  {:<16} {}-bit", layer.name, res.wbits[l]);
    }

    // calibrate + evaluate the mixed configuration
    let bits = BitConfig::mixed(res.wbits.clone(), 8, true);
    let cfg = ReconConfig { iters: 150, ..ReconConfig::default() };
    let qm = cal.calibrate(&calib, &bits, &cfg)?;
    let acc = accuracy(&env.rt, model, &EvalParams::quantized(&qm), &test)?;
    println!("mixed-precision model: {:.2}% top-1 at {:.2}ms", acc * 100.0,
             res.hw_cost);

    // unified-precision point that fits the same budget (w=2 everywhere)
    let ubits = BitConfig::uniform(model, 2, Some(8), true);
    let qm2 = cal.calibrate(&calib, &ubits, &cfg)?;
    let acc2 = accuracy(&env.rt, model, &EvalParams::quantized(&qm2), &test)?;
    println!("unified 2-bit at {:.2}ms: {:.2}% top-1  (mixed wins: {})",
             sim.measure(model, &ubits.wbits, 8), acc2 * 100.0, acc > acc2);
    Ok(())
}
