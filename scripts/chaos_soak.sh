#!/usr/bin/env bash
# Chaos soak (CI: chaos-smoke job). Hardened-serving acceptance run:
#   * two daemons share one --store under an armed $BRECQ_FAULTS plan
#     (probabilistic transient IO faults at every store site); their
#     concurrent clients must get fingerprints bitwise-equal to a
#     fault-free in-process reference, and between them compute each
#     unique artifact exactly once (the retry layer absorbs the faults);
#   * a warm re-submit under the same fault plan reports computes == 0;
#   * a daemon SIGKILLed mid-batch leaves a write-ahead journal; its
#     client sees a typed EOF error; a restarted daemon recovers the
#     journal before binding, after which the batch replays warm
#     (computes == 0) and still matches the fault-free reference;
#   * a daemon SIGKILLed mid-RECONSTRUCTION leaves per-unit checkpoints
#     in the store's pinned ckpt/ namespace; the restarted daemon's
#     journal recovery resumes exactly those units (units_resumed == the
#     checkpoint count at kill time, ckpt_corrupt == 0), the finished
#     result is bitwise-equal to the fault-free reference, and the
#     checkpoints are cleared once the final artifact publishes;
#   * at the end, no daemon ever served a corrupt artifact
#     (store_corrupt == 0 everywhere).
#
# usage: scripts/chaos_soak.sh [--quick]
#        --quick runs one kill/restart cycle instead of two (PR CI).
set -euo pipefail

cycles=2
if [ "${1:-}" = "--quick" ]; then
    cycles=1
fi

root=$(cd "$(dirname "$0")/.." && pwd)
bin="$root/rust/target/release/brecq"
if [ ! -x "$bin" ]; then
    (cd "$root/rust" && cargo build --release)
fi

# CHAOS_SOAK_TMP pins the scratch dir and keeps it after exit (CI
# uploads the daemon/client logs from it on failure).
tmp=${CHAOS_SOAK_TMP:-$(mktemp -d)}
mkdir -p "$tmp"
pid_a=""
pid_b=""
pid_c=""
cleanup() {
    for pid in "$pid_a" "$pid_b" "$pid_c"; do
        if [ -n "$pid" ] && kill -0 "$pid" 2>/dev/null; then
            kill -9 "$pid" 2>/dev/null || true
            wait "$pid" 2>/dev/null || true
        fi
    done
    if [ -z "${CHAOS_SOAK_TMP:-}" ]; then
        rm -rf "$tmp"
    fi
}
trap cleanup EXIT

sock_a="$tmp/a.sock"
sock_b="$tmp/b.sock"
store="$tmp/store"
jobs="$root/examples/jobs.json"

die() {
    echo "chaos_soak: FAIL — $1" >&2
    for log in "$tmp"/*.log; do
        [ -e "$log" ] || continue
        echo "--- $log ---" >&2
        cat "$log" >&2
    done
    exit 1
}

wait_sock() {
    for _ in $(seq 1 300); do
        if "$bin" ctl ping --sock "$1" >/dev/null 2>&1; then
            return 0
        fi
        sleep 0.2
    done
    die "daemon socket never came up at $1"
}

# start_daemon <name> <sock> [<store>]: sets $daemon_pid. Runs in the
# current shell (no subshell) so the daemon stays wait-able/kill-able.
start_daemon() {
    "$bin" serve --sock "$2" --store "${3:-$store}" \
        >>"$tmp/daemon-$1.log" 2>&1 &
    daemon_pid=$!
}

stop_daemon() { # <sock> <pid>
    "$bin" ctl shutdown --sock "$1" >/dev/null
    if ! wait "$2"; then
        die "daemon on $1 exited non-zero after ctl shutdown"
    fi
}

# check <ref.json> <client.json> <want_computes|-|sum> [<other.json>]
# Fingerprints must match the fault-free in-process reference job for
# job; computes is pinned to a number, or to "sum" across two clients
# equalling the reference (exactly-once across daemons).
check() {
    python3 - "$@" <<'PY'
import json, sys

ref = json.load(open(sys.argv[1]))
got = json.load(open(sys.argv[2]))
want = sys.argv[3]
rf = [j.get("fingerprint") for j in ref["jobs"]]
gf = [j.get("fingerprint") for j in got["jobs"]]
if not (all(rf) and all(gf)):
    print("a job is missing its fingerprint (errored?)")
    print(" ref:", rf)
    print(" got:", gf)
    sys.exit(1)
if rf != gf:
    print("fingerprint mismatch vs fault-free in-process run:")
    print(" ref:", rf)
    print(" got:", gf)
    sys.exit(1)
msg = f"{sys.argv[2]}: {len(gf)} fingerprints match the reference"
if want == "-":
    pass
elif want == "sum":
    other = json.load(open(sys.argv[4]))
    total = int(got["done"]["computes"]) + \
        int(other["done"]["computes"])
    if total != int(ref["computes"]):
        print(f"clients computed {total} artifacts under faults; the "
              f"fault-free run computed {ref['computes']} — "
              "compute-exactly-once is broken")
        sys.exit(1)
    msg += f", computes sum == {total} (exactly once)"
else:
    c = int(got["done"]["computes"])
    if c != int(want):
        print(f"expected computes == {want}, got {c}")
        sys.exit(1)
    msg += f", computes == {c}"
print("chaos_soak:", msg)
PY
}

# stats_clean <sock>: the daemon must never have served a corrupt entry.
stats_clean() {
    "$bin" ctl stats --sock "$1" | python3 - <<'PY'
import json, sys

st = json.loads(sys.stdin.read())
corrupt = int(st.get("store_corrupt", 0))
if corrupt != 0:
    print(f"daemon served-side store saw {corrupt} corrupt entries")
    sys.exit(1)
print("chaos_soak: store_corrupt == 0, retried ==",
      int(st.get("store_retried", 0)),
      "recovered ==", int(st.get("journal_recovered", 0)))
PY
}

# ---------------------------------------------------------------------
# Fault-free references (BRECQ_FAULTS must NOT be set yet)
# ---------------------------------------------------------------------
echo "chaos_soak: fault-free in-process reference run"
"$bin" run "$jobs" --stats --json "$tmp/ref.json" \
    >"$tmp/ref.log" 2>&1 || die "reference brecq run failed"

for i in $(seq 1 "$cycles"); do
    python3 - "$jobs" "$tmp/jobs$i.json" "$i" <<'PY'
import json, sys

jobs = json.load(open(sys.argv[1]))
for j in jobs:
    j["seed"] = int(sys.argv[3])
json.dump(jobs, open(sys.argv[2], "w"))
PY
    echo "chaos_soak: reference run for kill cycle $i (seed $i)"
    "$bin" run "$tmp/jobs$i.json" --stats --json "$tmp/ref$i.json" \
        >"$tmp/ref$i.log" 2>&1 || die "reference run $i failed"
done

# ---------------------------------------------------------------------
# Phase 1: two daemons, one store, armed fault plan
# ---------------------------------------------------------------------
export BRECQ_FAULTS="store.publish:io@0.15;store.index:io@0.1;store.load:io@0.1;store.lock:io@0.1"
export BRECQ_FAULTS_SEED=7
echo "chaos_soak: starting two daemons over one store, faults armed"
echo "chaos_soak:   BRECQ_FAULTS=$BRECQ_FAULTS"
start_daemon a "$sock_a"
pid_a=$daemon_pid
start_daemon b "$sock_b"
pid_b=$daemon_pid
wait_sock "$sock_a"
wait_sock "$sock_b"

echo "chaos_soak: concurrent cold submits against both daemons"
"$bin" submit "$jobs" --sock "$sock_a" --quiet --timeout 600 \
    --json "$tmp/a.json" >"$tmp/client-a.log" 2>&1 &
ca=$!
"$bin" submit "$jobs" --sock "$sock_b" --quiet --timeout 600 \
    --json "$tmp/b.json" >"$tmp/client-b.log" 2>&1 &
cb=$!
ok=0
wait "$ca" || ok=1
wait "$cb" || ok=1
[ "$ok" -eq 0 ] || die "a submit client exited non-zero under io faults"
check "$tmp/ref.json" "$tmp/a.json" sum "$tmp/b.json"
check "$tmp/ref.json" "$tmp/b.json" -

echo "chaos_soak: warm re-submit under the same fault plan"
"$bin" submit "$jobs" --sock "$sock_b" --quiet --timeout 600 \
    --json "$tmp/warm.json" >"$tmp/client-warm.log" 2>&1 \
    || die "warm submit failed under io faults"
check "$tmp/ref.json" "$tmp/warm.json" 0

# ---------------------------------------------------------------------
# Phase 2: kill -9 mid-batch, restart, journal recovery
# ---------------------------------------------------------------------
for i in $(seq 1 "$cycles"); do
    echo "chaos_soak: kill cycle $i — submitting cold batch to daemon A"
    "$bin" submit "$tmp/jobs$i.json" --sock "$sock_a" --timeout 600 \
        --json "$tmp/kill$i.json" >"$tmp/client-kill$i.log" 2>&1 &
    ck=$!
    # wait for the batch to actually start running, then SIGKILL
    started=0
    for _ in $(seq 1 200); do
        if grep -q '"event":"stage"' "$tmp/client-kill$i.log" \
            2>/dev/null; then
            started=1
            break
        fi
        sleep 0.05
    done
    [ "$started" -eq 1 ] || die "kill cycle $i: batch never started"
    echo "chaos_soak: kill cycle $i — SIGKILL daemon A (pid $pid_a)"
    kill -9 "$pid_a"
    wait "$pid_a" 2>/dev/null || true
    pid_a=""
    if wait "$ck"; then
        die "kill cycle $i: client exited 0 despite daemon death"
    fi
    grep -q "EOF" "$tmp/client-kill$i.log" \
        || die "kill cycle $i: client did not report the EOF error"
    compgen -G "$store/journal/*.json" >/dev/null \
        || die "kill cycle $i: no in-flight journal left behind"

    echo "chaos_soak: kill cycle $i — restarting daemon A (recovery)"
    start_daemon a "$sock_a"
    pid_a=$daemon_pid
    wait_sock "$sock_a"
    grep -q "\[recover\] claimed" "$tmp/daemon-a.log" \
        || die "kill cycle $i: restarted daemon did not recover the journal"
    if compgen -G "$store/journal/*.json" >/dev/null; then
        die "kill cycle $i: journal not consumed by recovery"
    fi

    echo "chaos_soak: kill cycle $i — warm resubmit after recovery"
    "$bin" submit "$tmp/jobs$i.json" --sock "$sock_a" --quiet \
        --timeout 600 --json "$tmp/recovered$i.json" \
        >"$tmp/client-recovered$i.log" 2>&1 \
        || die "kill cycle $i: post-recovery submit failed"
    check "$tmp/ref$i.json" "$tmp/recovered$i.json" 0
done

# ---------------------------------------------------------------------
# Phase 3: kill -9 mid-RECONSTRUCTION, restart, checkpoint resume
# ---------------------------------------------------------------------
# Fault-free phase over a fresh store: the property under test is the
# checkpoint/resume path itself, pinned deterministically.
unset BRECQ_FAULTS BRECQ_FAULTS_SEED
store_c="$tmp/store_c"
sock_c="$tmp/c.sock"

# one slow single-job batch so the SIGKILL lands between recon units
python3 - "$tmp/jobs-resume.json" <<'PY'
import json, sys

json.dump([{"model": "resnet_s", "method": "brecq", "gran": "block",
            "wbits": 4, "abits": 8, "iters": 200, "calib_n": 64,
            "seed": 33}], open(sys.argv[1], "w"))
PY
echo "chaos_soak: fault-free reference for the resume batch"
"$bin" run "$tmp/jobs-resume.json" --stats --json "$tmp/ref-resume.json" \
    >"$tmp/ref-resume.log" 2>&1 || die "resume reference run failed"

echo "chaos_soak: resume cycle — submitting slow batch to daemon C"
start_daemon c "$sock_c" "$store_c"
pid_c=$daemon_pid
wait_sock "$sock_c"
"$bin" submit "$tmp/jobs-resume.json" --sock "$sock_c" --timeout 600 \
    >"$tmp/client-resume.log" 2>&1 &
cr=$!
# ckpt_count: pinned-namespace index files; the directory may
# legitimately not exist yet, so guard against set -e/pipefail.
ckpt_count() {
    local n
    n=$(find "$store_c/ckpt" -maxdepth 1 -name '*.json' 2>/dev/null \
        | wc -l) || n=0
    echo "$n"
}

# wait for the first committed unit checkpoint, then SIGKILL
ckpts=0
for _ in $(seq 1 600); do
    ckpts=$(ckpt_count)
    [ "$ckpts" -ge 1 ] && break
    sleep 0.05
done
[ "$ckpts" -ge 1 ] || die "resume cycle: no unit checkpoint appeared"
echo "chaos_soak: resume cycle — SIGKILL daemon C (pid $pid_c)"
kill -9 "$pid_c"
wait "$pid_c" 2>/dev/null || true
pid_c=""
if wait "$cr"; then
    die "resume cycle: client exited 0 despite daemon death"
fi
grep -q "EOF" "$tmp/client-resume.log" \
    || die "resume cycle: client did not report the EOF error"
# index files commit by atomic rename: every one on disk at kill time
# is a complete checkpoint and must be resumed, not recomputed
k=$(ckpt_count)
[ "$k" -ge 1 ] || die "resume cycle: checkpoints vanished after kill"

echo "chaos_soak: resume cycle — restarting daemon C (recovery, k=$k)"
start_daemon c "$sock_c" "$store_c"
pid_c=$daemon_pid
wait_sock "$sock_c"
"$bin" ctl stats --sock "$sock_c" | python3 - "$k" <<'PY' \
    || die "resume cycle: recovery stats are wrong"
import json, sys

st = json.loads(sys.stdin.read())
k = int(sys.argv[1])
resumed = int(st.get("units_resumed", 0))
corrupt = int(st.get("ckpt_corrupt", 0))
if resumed != k:
    print(f"expected units_resumed == {k}, got {resumed}")
    sys.exit(1)
if corrupt != 0:
    print(f"expected ckpt_corrupt == 0, got {corrupt}")
    sys.exit(1)
print(f"chaos_soak: recovery resumed {resumed} checkpointed units, "
      "ckpt_corrupt == 0")
PY
if [ "$(ckpt_count)" -ne 0 ]; then
    die "resume cycle: checkpoints not cleared after the final publish"
fi

echo "chaos_soak: resume cycle — warm resubmit after recovery"
"$bin" submit "$tmp/jobs-resume.json" --sock "$sock_c" --quiet \
    --timeout 600 --json "$tmp/resumed.json" \
    >"$tmp/client-resumed.log" 2>&1 \
    || die "resume cycle: post-recovery submit failed"
check "$tmp/ref-resume.json" "$tmp/resumed.json" 0

# ---------------------------------------------------------------------
# Final accounting: nothing corrupt was ever served
# ---------------------------------------------------------------------
stats_clean "$sock_a" || die "daemon A served corrupt artifacts"
stats_clean "$sock_b" || die "daemon B served corrupt artifacts"
stats_clean "$sock_c" || die "daemon C served corrupt artifacts"

echo "chaos_soak: clean shutdown"
stop_daemon "$sock_a" "$pid_a"
pid_a=""
stop_daemon "$sock_b" "$pid_b"
pid_b=""
stop_daemon "$sock_c" "$pid_c"
pid_c=""

echo "chaos_soak: all checks passed ($cycles kill cycles + resume cycle)"
