#!/usr/bin/env bash
# Fixture tests for scripts/check_bench.sh — the perf gate itself needs
# a regression test, or a refactor can silently disarm it. Each case
# builds a small NEW/BASELINE JSON pair (including the multi-node
# `recon plan step [stage:...|net:...|pack:...]` rows bench_recon now
# emits) and asserts the gate's exit code and key output lines.
#
# usage: scripts/test_check_bench.sh   (exit 0 = all cases pass)
set -uo pipefail

here=$(cd "$(dirname "$0")" && pwd)
gate="$here/check_bench.sh"
tmp=$(mktemp -d)
trap 'rm -rf "$tmp"' EXIT

fails=0

# run <name> <expected_exit> <grep_pattern> <new.json> <base.json> [env...]
run_case() {
    local name=$1 want=$2 pat=$3 new=$4 base=$5
    shift 5
    local out rc
    out=$(env "$@" bash "$gate" "$new" "$base" 2>&1)
    rc=$?
    if [ "$rc" -ne "$want" ]; then
        echo "FAIL  $name: exit $rc (wanted $want)"
        echo "$out" | sed 's/^/      | /'
        fails=$((fails + 1))
        return
    fi
    if ! grep -q "$pat" <<<"$out"; then
        echo "FAIL  $name: output missing /$pat/"
        echo "$out" | sed 's/^/      | /'
        fails=$((fails + 1))
        return
    fi
    echo "ok    $name"
}

# Fixture builder: results as name=min_ms pairs, notes as key=value.
# mk <path> <calibrated> <result>... -- <note>...
mk() {
    local path=$1 calibrated=$2
    shift 2
    python3 - "$path" "$calibrated" "$@" <<'PY'
import json, sys
path, calibrated = sys.argv[1], sys.argv[2] == "true"
args = sys.argv[3:]
split = args.index("--") if "--" in args else len(args)
results = []
for spec in args[:split]:
    name, ms = spec.rsplit("=", 1)
    results.append({"name": name, "min_ms": float(ms)})
notes = {}
for spec in args[split + 1:]:
    key, val = spec.rsplit("=", 1)
    notes[key] = float(val)
doc = {"host_threads": 8, "results": results, "notes": notes}
if not calibrated:
    doc["calibrated"] = False
with open(path, "w") as f:
    json.dump(doc, f)
PY
}

rows_ok=(
    "calibrate 20it/unit gran=block=900"
    "recon plan step [b1]=4.0"
    "recon plan step [stage:stage1]=9.0"
    "recon plan step [net:net]=9.5"
    "recon plan step [pack:p0]=8.0"
)
notes_ok=(
    "recon_speedup_4t_over_1t=2.1"
    "recon_iters_per_sec=250.0"
    "plan_fallback_steps_total=0"
)

mk "$tmp/base.json" true "${rows_ok[@]}" -- "${notes_ok[@]}"

# 1. identical run passes
mk "$tmp/new_same.json" true "${rows_ok[@]}" -- "${notes_ok[@]}"
run_case "pass: identical run" 0 "bench gate: PASS (calibrated)" \
    "$tmp/new_same.json" "$tmp/base.json"

# 2. >25% min_ms regression on a multi-node plan row fails
rows_slow=("${rows_ok[@]}")
rows_slow[2]="recon plan step [stage:stage1]=12.0"
mk "$tmp/new_slow.json" true "${rows_slow[@]}" -- "${notes_ok[@]}"
run_case "fail: stage plan row regression" 1 "25% regression" \
    "$tmp/new_slow.json" "$tmp/base.json"

# 3. a baseline row missing from the new run fails (rename guard)
mk "$tmp/new_missing.json" true "${rows_ok[@]:0:4}" -- "${notes_ok[@]}"
run_case "fail: pack plan row disappeared" 1 "missing from" \
    "$tmp/new_missing.json" "$tmp/base.json"

# 4. rows the baseline doesn't know yet pass with a notice (how the
#    stage/net/pack rows land before the baseline is rebased)
mk "$tmp/base_old.json" true "${rows_ok[@]:0:2}" -- "${notes_ok[@]}"
run_case "pass: new plan rows, old baseline" 0 "^new   recon plan step" \
    "$tmp/new_same.json" "$tmp/base_old.json"

# 5. recon_iters_per_sec throughput drop fails
mk "$tmp/new_slow_ips.json" true "${rows_ok[@]}" -- \
    "recon_speedup_4t_over_1t=2.1" "recon_iters_per_sec=100.0" \
    "plan_fallback_steps_total=0"
run_case "fail: iters/sec throughput drop" 1 "throughput regression" \
    "$tmp/new_slow_ips.json" "$tmp/base.json"

# 6. speedup below the floor fails
mk "$tmp/new_slow_sp.json" true "${rows_ok[@]}" -- \
    "recon_speedup_4t_over_1t=1.1" "recon_iters_per_sec=250.0" \
    "plan_fallback_steps_total=0"
run_case "fail: speedup under floor" 1 "floor" \
    "$tmp/new_slow_sp.json" "$tmp/base.json"

# 7. uncalibrated baseline: bootstrap pass off-main ...
mk "$tmp/base_boot.json" false "${rows_ok[@]}" -- "${notes_ok[@]}"
run_case "pass: bootstrap mode (loud)" 0 "BOOTSTRAP MODE" \
    "$tmp/new_same.json" "$tmp/base_boot.json"

# 8. ... and a hard failure when CI demands calibration (main)
run_case "fail: bootstrap forbidden on main" 2 "BOOTSTRAP FORBIDDEN" \
    "$tmp/new_same.json" "$tmp/base_boot.json" \
    BENCH_REQUIRE_CALIBRATED=1

# 9. missing baseline file is also bootstrap
run_case "pass: no baseline file" 0 "no baseline file" \
    "$tmp/new_same.json" "$tmp/nonexistent.json"

# --- fold mode (bench-calibrate on main folds `new` rows into the
#     committed baseline so they stop drifting ungated) ---

# run_fold <name> <expected_exit> <grep_pattern> <new.json> <base.json>
run_fold() {
    local name=$1 want=$2 pat=$3 new=$4 base=$5
    local out rc
    out=$(bash "$gate" --fold "$new" "$base" 2>&1)
    rc=$?
    if [ "$rc" -ne "$want" ]; then
        echo "FAIL  $name: exit $rc (wanted $want)"
        echo "$out" | sed 's/^/      | /'
        fails=$((fails + 1))
        return
    fi
    if ! grep -q "$pat" <<<"$out"; then
        echo "FAIL  $name: output missing /$pat/"
        echo "$out" | sed 's/^/      | /'
        fails=$((fails + 1))
        return
    fi
    echo "ok    $name"
}

# 10. fold appends rows/notes the baseline lacks; the folded baseline
#     then gates the same new run cleanly (no more `new` notices)
mk "$tmp/base_fold.json" true "${rows_ok[@]:0:2}" -- \
    "recon_speedup_4t_over_1t=2.1" "recon_iters_per_sec=250.0"
run_fold "fold: appends missing rows + notes" 0 "fold: added result" \
    "$tmp/new_same.json" "$tmp/base_fold.json"
run_case "pass: gate clean after fold" 0 "bench gate: PASS (calibrated)" \
    "$tmp/new_same.json" "$tmp/base_fold.json"
if grep -q "^new   " <(bash "$gate" "$tmp/new_same.json" \
        "$tmp/base_fold.json" 2>&1); then
    echo "FAIL  fold: 'new' notices survived the fold"
    fails=$((fails + 1))
else
    echo "ok    fold: no 'new' notices after fold"
fi

# 11. fold never overwrites an existing baseline number (loosening the
#     gate takes an explicit recalibration): fold a slower run over the
#     full baseline, then confirm the gate still flags the regression
cp "$tmp/base.json" "$tmp/base_keep.json"
run_fold "fold: nothing to add is a no-op" 0 "already covers" \
    "$tmp/new_slow.json" "$tmp/base_keep.json"
run_case "fail: fold kept the old stage number" 1 "25% regression" \
    "$tmp/new_slow.json" "$tmp/base_keep.json"

# 12. fold refuses to own an uncalibrated baseline (self-calibrate path
#     does) and leaves the file byte-identical
cp "$tmp/base_boot.json" "$tmp/base_boot_keep.json"
run_fold "fold: uncalibrated baseline is a no-op" 0 "uncalibrated" \
    "$tmp/new_same.json" "$tmp/base_boot_keep.json"
if cmp -s "$tmp/base_boot.json" "$tmp/base_boot_keep.json"; then
    echo "ok    fold: uncalibrated baseline untouched"
else
    echo "FAIL  fold: uncalibrated baseline was modified"
    fails=$((fails + 1))
fi

# 13. missing baseline file: fold no-ops instead of creating one
run_fold "fold: missing baseline is a no-op" 0 "nothing to fold" \
    "$tmp/new_same.json" "$tmp/fold_nonexistent.json"
if [ -e "$tmp/fold_nonexistent.json" ]; then
    echo "FAIL  fold: created a baseline out of thin air"
    fails=$((fails + 1))
else
    echo "ok    fold: no baseline file created"
fi

# --- merge mode (bench-smoke combines bench_recon + bench_store JSONs
#     into the single NEW document the gate compares) ---

# run_merge <name> <expected_exit> <grep_pattern> <out.json> <in...>
run_merge() {
    local name=$1 want=$2 pat=$3
    shift 3
    local out rc
    out=$(bash "$gate" --merge "$@" 2>&1)
    rc=$?
    if [ "$rc" -ne "$want" ]; then
        echo "FAIL  $name: exit $rc (wanted $want)"
        echo "$out" | sed 's/^/      | /'
        fails=$((fails + 1))
        return
    fi
    if ! grep -q "$pat" <<<"$out"; then
        echo "FAIL  $name: output missing /$pat/"
        echo "$out" | sed 's/^/      | /'
        fails=$((fails + 1))
        return
    fi
    echo "ok    $name"
}

# 14. merging two bench files yields one doc the gate accepts against a
#     baseline that spans both benches' rows (which a single input could
#     never satisfy — the rename guard would fire)
mk "$tmp/in_recon.json" true "${rows_ok[@]}" -- "${notes_ok[@]}" \
    "scratch_allocs_total=5"
mk "$tmp/in_store.json" true "store.publish fp-weights=3.0" \
    "store.load+decode fp-weights=1.0" -- "store_warm_job_s=0.4" \
    "scratch_allocs_total=7"
run_merge "merge: two bench files combine" 0 "merge: wrote" \
    "$tmp/merged.json" "$tmp/in_recon.json" "$tmp/in_store.json"
mk "$tmp/base_both.json" true "${rows_ok[@]}" \
    "store.publish fp-weights=3.0" \
    "store.load+decode fp-weights=1.0" -- "${notes_ok[@]}" \
    "store_warm_job_s=0.4"
run_case "pass: merged doc spans both benches" 0 \
    "bench gate: PASS (calibrated)" \
    "$tmp/merged.json" "$tmp/base_both.json"
if python3 -c "
import json, sys
d = json.load(open('$tmp/merged.json'))
sys.exit(0 if d['notes'].get('scratch_allocs_total') == 12 else 1)
"; then
    echo "ok    merge: scratch counters summed"
else
    echo "FAIL  merge: scratch counters not summed"
    fails=$((fails + 1))
fi

# 15. a result row appearing in two inputs is an error, not a silent
#     last-one-wins
run_merge "fail: duplicate row across inputs" 1 "duplicate result row" \
    "$tmp/merged_dup.json" "$tmp/in_recon.json" "$tmp/in_recon.json"

# 16. conflicting non-scratch notes are an error
mk "$tmp/in_conflict.json" true "other row=1.0" -- \
    "recon_iters_per_sec=99.0"
run_merge "fail: conflicting note across inputs" 1 "conflicting note" \
    "$tmp/merged_conflict.json" "$tmp/in_recon.json" \
    "$tmp/in_conflict.json"

if [ "$fails" -ne 0 ]; then
    echo "check_bench fixture tests: $fails FAILED"
    exit 1
fi
echo "check_bench fixture tests: all passed"
