#!/usr/bin/env bash
# Perf-regression gate over the bench harness's JSON output.
#
# usage: scripts/check_bench.sh [--fold] NEW.json [BASELINE.json]
#        scripts/check_bench.sh --merge OUT.json IN1.json [IN2.json ...]
#   BASELINE.json defaults to BENCH_native.json at the repo root.
#   --fold appends baseline-missing rows/notes instead of gating (below).
#   --merge combines several bench binaries' JSONs into one document so
#   the gate (which insists every baseline row appears in NEW) can cover
#   rows from more than one bench binary. Result names must be unique
#   across inputs; notes must agree, except the per-process
#   `scratch_*_total` counters, which are summed. `calibrated` is the
#   AND of the inputs; `host_threads` comes from the first input.
#
# Fails (exit 1) when (all checks arm only once a calibrated baseline
# is committed):
#   * any benchmark's min_ms regresses more than 25% vs the baseline, or
#   * a baseline entry has no matching result (bench renamed/deleted), or
#   * the 4-thread reconstruction speedup falls below $BENCH_MIN_SPEEDUP
#     (default 1.5x; speedup checks need >= 4 host hw threads), or
#   * the speedup drops below 75% of the baseline's recorded speedup, or
#   * the plan-engine iteration throughput note `recon_iters_per_sec`
#     falls below 75% of the baseline's (the min_ms rule, inverted for a
#     higher-is-better metric).
#
# Bootstrap mode: a missing baseline, or one marked `"calibrated": false`,
# passes with a LOUD warning and a distinct exit message so an
# uncalibrated baseline cannot silently persist. Set
# BENCH_REQUIRE_CALIBRATED=1 (CI does on main) to turn bootstrap mode
# into a hard failure (exit 2) — commit the bench-smoke artifact as
# BENCH_native.json to calibrate:
#   cd rust && cargo bench --bench bench_recon -- --quick --json ../BENCH_native.json
#
# Fold mode: check_bench.sh --fold NEW.json [BASELINE.json] rewrites the
# baseline in place, appending any result rows and notes NEW has that
# the baseline lacks (benches added since the last calibration). It
# NEVER overwrites an existing baseline number — loosening the gate
# still takes an explicit recalibration — and it no-ops (exit 0) on a
# missing or uncalibrated baseline, where the self-calibrate path owns
# the file. CI's main-only bench-calibrate job runs this so `new` rows
# stop drifting ungated.
set -euo pipefail

if [ "${1:-}" = "--merge" ]; then
    shift
    out=${1:?usage: check_bench.sh --merge OUT.json IN1.json [IN2.json ...]}
    shift
    if [ "$#" -lt 1 ]; then
        echo "usage: check_bench.sh --merge OUT.json IN1.json [IN2.json ...]" >&2
        exit 1
    fi
    python3 - "$out" "$@" <<'PY'
import json, sys

out_path, in_paths = sys.argv[1], sys.argv[2:]
docs = []
for p in in_paths:
    with open(p) as f:
        docs.append((p, json.load(f)))

results, names = [], set()
notes = {}
# per-process scratch-arena counters appear in every bench JSON with
# different values; summing keeps the zero-alloc signal meaningful
SUMMED = ("scratch_allocs_total", "scratch_reuses_total")
for p, d in docs:
    for r in d.get("results", []):
        if r["name"] in names:
            print(f"merge: duplicate result row '{r['name']}' in {p}")
            sys.exit(1)
        names.add(r["name"])
        results.append(r)
    for k, v in (d.get("notes") or {}).items():
        if k in SUMMED:
            notes[k] = notes.get(k, 0) + v
        elif k in notes and notes[k] != v:
            print(f"merge: conflicting note '{k}' in {p} "
                  f"({notes[k]} vs {v})")
            sys.exit(1)
        else:
            notes[k] = v

first = docs[0][1]
merged = {
    "schema": first.get("schema", 1),
    "bench": "+".join(d.get("bench", "?") for _, d in docs),
    "calibrated": all(d.get("calibrated", True) for _, d in docs),
    "quick": any(d.get("quick", False) for _, d in docs),
    "threads": first.get("threads", 0),
    "host_threads": first.get("host_threads", 0),
    "results": results,
    "notes": notes,
}
with open(out_path, "w") as f:
    json.dump(merged, f, indent=1, sort_keys=True)
    f.write("\n")
print(f"merge: wrote {out_path} — {len(results)} rows, {len(notes)} "
      f"notes from {len(in_paths)} inputs")
PY
    exit $?
fi

if [ "${1:-}" = "--fold" ]; then
    shift
    new=${1:?usage: check_bench.sh --fold NEW.json [BASELINE.json]}
    base=${2:-BENCH_native.json}
    python3 - "$new" "$base" <<'PY'
import json, sys

new_path, base_path = sys.argv[1], sys.argv[2]
with open(new_path) as f:
    new = json.load(f)
try:
    with open(base_path) as f:
        base = json.load(f)
except FileNotFoundError:
    print(f"fold: no baseline at {base_path} — nothing to fold "
          "(calibrate first)")
    sys.exit(0)
if not base.get("calibrated", True):
    print(f"fold: baseline {base_path} is uncalibrated — nothing to "
          "fold (the self-calibrate path owns it)")
    sys.exit(0)

have = {r["name"] for r in base.get("results", [])}
added = [r for r in new.get("results", []) if r["name"] not in have]
base_notes = base.get("notes") or {}
new_notes = new.get("notes") or {}
added_notes = {k: v for k, v in new_notes.items() if k not in base_notes}
if not added and not added_notes:
    print("fold: baseline already covers every result row and note")
    sys.exit(0)
base["results"] = base.get("results", []) + added
base_notes.update(added_notes)
base["notes"] = base_notes
with open(base_path, "w") as f:
    json.dump(base, f, indent=1, sort_keys=True)
    f.write("\n")
for r in added:
    print(f"fold: added result '{r['name']}' ({r['min_ms']:.1f}ms)")
for k in sorted(added_notes):
    print(f"fold: added note '{k}' ({added_notes[k]})")
print(f"fold: {base_path} updated — commit it to arm the gate for the "
      "new rows")
PY
    exit $?
fi

new=${1:?usage: check_bench.sh NEW.json [BASELINE.json]}
base=${2:-BENCH_native.json}

python3 - "$new" "$base" <<'PY'
import json, os, sys

new_path, base_path = sys.argv[1], sys.argv[2]
with open(new_path) as f:
    new = json.load(f)
host = int(new.get("host_threads", 0))
notes = new.get("notes", {}) or {}
min_speedup = float(os.environ.get("BENCH_MIN_SPEEDUP", "1.5"))
require_calibrated = os.environ.get("BENCH_REQUIRE_CALIBRATED", "0") == "1"
failures = []

speedup = notes.get("recon_speedup_4t_over_1t")
if speedup is not None:
    print(f"measured 4-thread recon speedup: {speedup:.2f}x "
          f"(host has {host} hw threads)")

base = None
bootstrap_reason = None
try:
    with open(base_path) as f:
        base = json.load(f)
except FileNotFoundError:
    bootstrap_reason = f"no baseline file at {base_path}"
if base is not None and not base.get("calibrated", True):
    bootstrap_reason = (f"baseline {base_path} is marked "
                        f'"calibrated": false (placeholder)')
    base = None

if bootstrap_reason is not None:
    banner = "!" * 70
    print(banner)
    print("!!  BOOTSTRAP MODE — PERF GATE IS UNARMED")
    print(f"!!  {bootstrap_reason}")
    print("!!  Nothing was compared. To arm the gate, commit the")
    print(f"!!  bench-smoke JSON artifact as {base_path}:")
    print("!!    cd rust && cargo bench --bench bench_recon -- "
          "--quick --json ../BENCH_native.json")
    print(banner)
    if require_calibrated:
        print("bench gate: FAIL (BOOTSTRAP FORBIDDEN — "
              "BENCH_REQUIRE_CALIBRATED=1 and the committed baseline "
              "is not calibrated)")
        sys.exit(2)
    print("bench gate: PASS (BOOTSTRAP MODE — uncalibrated baseline, "
          "no regression checks ran)")
    sys.exit(0)

old = {r["name"]: r for r in base.get("results", [])}
seen = set()
for r in new.get("results", []):
    seen.add(r["name"])
    o = old.get(r["name"])
    if o is None:
        print(f"new   {r['name']}: {r['min_ms']:.1f}ms (no baseline; "
              f"rebase {base_path} to start tracking it)")
        continue
    if r["min_ms"] > o["min_ms"] * 1.25:
        failures.append(
            f"{r['name']}: min {r['min_ms']:.1f}ms vs baseline "
            f"{o['min_ms']:.1f}ms (> 25% regression)")
    else:
        print(f"ok    {r['name']}: {r['min_ms']:.1f}ms "
              f"(baseline {o['min_ms']:.1f}ms)")
# a baseline entry with no matching result means a bench was renamed
# or deleted — fail loudly instead of silently disarming the gate
for name in old:
    if name not in seen:
        failures.append(
            f"baseline entry '{name}' missing from {new_path} "
            f"(bench renamed/removed? rebase {base_path})")
# speedup checks run only on hosts with enough hardware threads to make
# 4-thread numbers meaningful
if speedup is not None and host >= 4:
    if speedup < min_speedup:
        failures.append(
            f"4-thread recon speedup {speedup:.2f}x "
            f"< {min_speedup}x floor")
    base_speedup = \
        (base.get("notes") or {}).get("recon_speedup_4t_over_1t")
    if base_speedup and speedup < 0.75 * base_speedup:
        failures.append(
            f"speedup {speedup:.2f}x < 75% of baseline "
            f"{base_speedup:.2f}x")
elif speedup is not None:
    print("host has < 4 hw threads: skipping the speedup checks")

# reconstruction-plan iteration throughput: gated like min_ms, inverted
# (higher is better; >25% drop fails once the baseline records it). Like
# the bench-row rule above, a baseline note with no matching result
# means the metric was renamed/removed — fail loudly rather than
# silently disarming the gate.
ips = notes.get("recon_iters_per_sec")
base_ips = (base.get("notes") or {}).get("recon_iters_per_sec")
if base_ips is not None and ips is None:
    failures.append(
        f"baseline records recon_iters_per_sec but {new_path} does not "
        f"(bench note renamed/removed? rebase {base_path})")
elif ips is not None and base_ips is not None and base_ips > 0:
    if ips < 0.75 * base_ips:
        failures.append(
            f"recon_iters_per_sec {ips:.1f}/s vs baseline "
            f"{base_ips:.1f}/s (> 25% throughput regression)")
    else:
        print(f"ok    recon_iters_per_sec: {ips:.1f}/s "
              f"(baseline {base_ips:.1f}/s)")
elif ips is not None:
    print(f"new   recon_iters_per_sec: {ips:.1f}/s (no baseline note; "
          f"rebase {base_path} to start gating it)")

if failures:
    print("PERF REGRESSION:")
    for f in failures:
        print(f"  - {f}")
    sys.exit(1)
print("bench gate: PASS (calibrated)")
PY
