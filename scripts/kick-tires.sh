#!/usr/bin/env bash
# Artifact-evaluation harness ("kick the tires"): build the release
# binary, regenerate every paper table and figure with `brecq exp all`
# into a versioned output directory, and verify the produced report
# files against the committed completeness manifest
# (scripts/kick-tires-manifest.txt).
#
# usage: scripts/kick-tires.sh [--quick] [--out DIR] [--bin PATH]
#
#   --quick    minutes-not-hours mode: reduced reconstruction iteration
#              counts and calibration-set sizes, and a shortened QAT
#              baseline. CI runs this on every PR. The resulting numbers
#              are NOT paper-grade — run without --quick for artifact
#              evaluation proper.
#   --out DIR  place outputs under DIR instead of the default
#              artifacts/out/<git-sha>/
#   --bin P    use an existing brecq binary instead of building one
#              (skips `cargo build --release`)
#
# Outputs under the out directory:
#   reports/<id>.md + reports/<id>.json   one pair per table/figure
#   exp-all.log                           full runner transcript
#   MANIFEST.txt                          sorted listing of reports/
#
# Exit codes: 0 = every table ran and the manifest matches; non-zero on
# any table failure (`brecq exp all` reports per-table verdicts and
# fails at the end) or on any manifest mismatch (missing OR unexpected
# files — the committed manifest is the source of truth).
set -euo pipefail

here=$(cd "$(dirname "$0")" && pwd)
root=$(cd "$here/.." && pwd)
manifest="$here/kick-tires-manifest.txt"

quick=0
out=""
bin=""
while [ $# -gt 0 ]; do
    case "$1" in
        --quick) quick=1 ;;
        --out) out=${2:?--out needs a directory}; shift ;;
        --bin) bin=${2:?--bin needs a path}; shift ;;
        *) echo "kick-tires: unknown flag '$1' (see header comment)" >&2
           exit 2 ;;
    esac
    shift
done

sha=$(git -C "$root" rev-parse --short HEAD 2>/dev/null || echo nogit)
out=${out:-$root/artifacts/out/$sha}
mkdir -p "$out"

if [ -z "$bin" ]; then
    echo "[kick-tires] building release binary"
    (cd "$root/rust" && cargo build --release)
    bin="$root/rust/target/release/brecq"
fi
[ -x "$bin" ] || { echo "kick-tires: no brecq binary at $bin" >&2; exit 2; }

# --quick trades fidelity for wall-clock: fewer Algorithm-1 iterations,
# a smaller calibration set, fewer LSQ steps for the table4 QAT column.
flags=()
if [ "$quick" -eq 1 ]; then
    flags+=(--iters 40 --calib 128 --qat-steps 120 --seeds 1)
    echo "[kick-tires] QUICK mode: ${flags[*]} (numbers are not paper-grade)"
fi

echo "[kick-tires] regenerating all tables into $out"
rc=0
# ${flags[@]+...}: expand-if-set, so an empty array survives `set -u`
# on bash < 4.4
"$bin" exp all --out "$out" ${flags[@]+"${flags[@]}"} 2>&1 \
    | tee "$out/exp-all.log" || rc=$?

# Completeness check runs even when a table failed: the diff shows
# exactly which outputs the failure cost us.
(cd "$out" && find reports -type f | LC_ALL=C sort) > "$out/MANIFEST.txt"
if ! diff -u "$manifest" "$out/MANIFEST.txt"; then
    echo "[kick-tires] FAIL: produced files do not match" \
         "scripts/kick-tires-manifest.txt (see diff above;" \
         "'-' = expected but missing, '+' = unexpected extra)" >&2
    exit 1
fi
n=$(wc -l < "$out/MANIFEST.txt")
if [ "$rc" -ne 0 ]; then
    echo "[kick-tires] FAIL: brecq exp all exited $rc" \
         "(see $out/exp-all.log)" >&2
    exit "$rc"
fi
echo "[kick-tires] PASS: all $n expected report files present under $out"
