#!/usr/bin/env bash
# Serve-mode smoke test (CI: serve-smoke job). Proves the daemon is a
# faithful remote front-end for the pipeline:
#   * two CONCURRENT `brecq submit` clients get job-for-job fingerprints
#     bitwise-equal to a sequential in-process `brecq run`, and between
#     them compute each unique artifact exactly once;
#   * a warm re-submit against the live daemon reports computes == 0;
#   * `brecq ctl shutdown` exits the daemon cleanly and removes the
#     socket;
#   * a RESTARTED daemon over the same --store replays the whole batch
#     from disk: computes == 0 and fingerprints still match the
#     in-process reference.
#
# usage: scripts/serve_smoke.sh   (builds rust/target/release/brecq if
#                                  missing; exit 0 = all checks pass)
set -euo pipefail

root=$(cd "$(dirname "$0")/.." && pwd)
bin="$root/rust/target/release/brecq"
if [ ! -x "$bin" ]; then
    (cd "$root/rust" && cargo build --release)
fi

tmp=$(mktemp -d)
daemon_pid=""
cleanup() {
    if [ -n "$daemon_pid" ] && kill -0 "$daemon_pid" 2>/dev/null; then
        kill "$daemon_pid" 2>/dev/null || true
        wait "$daemon_pid" 2>/dev/null || true
    fi
    rm -rf "$tmp"
}
trap cleanup EXIT

sock="$tmp/brecq.sock"
store="$tmp/store"
jobs="$root/examples/jobs.json"

die() {
    echo "serve_smoke: FAIL — $1" >&2
    for log in "$tmp"/*.log; do
        [ -e "$log" ] || continue
        echo "--- $log ---" >&2
        cat "$log" >&2
    done
    exit 1
}

wait_sock() {
    for _ in $(seq 1 100); do
        if "$bin" ctl ping --sock "$sock" >/dev/null 2>&1; then
            return 0
        fi
        sleep 0.2
    done
    die "daemon socket never came up at $sock"
}

start_daemon() {
    "$bin" serve --sock "$sock" --store "$store" \
        >>"$tmp/daemon.log" 2>&1 &
    daemon_pid=$!
    wait_sock
}

stop_daemon() {
    "$bin" ctl shutdown --sock "$sock" >/dev/null
    if ! wait "$daemon_pid"; then
        die "daemon exited non-zero after ctl shutdown"
    fi
    daemon_pid=""
    if [ -e "$sock" ]; then
        die "daemon left its socket behind at $sock"
    fi
}

# check <client.json> <want_computes|-> [<want_computes_sum_with>]
# Compares the client's per-job fingerprints against the in-process
# reference; optionally pins the batch's `done.computes`, or checks that
# two batches' computes sum to the reference's (each unique artifact
# computed exactly once across the concurrent clients).
check() {
    python3 - "$tmp/ref.json" "$@" <<'PY'
import json, sys

ref = json.load(open(sys.argv[1]))
got = json.load(open(sys.argv[2]))
want = sys.argv[3]
rf = [j.get("fingerprint") for j in ref["jobs"]]
gf = [j.get("fingerprint") for j in got["jobs"]]
if not (all(rf) and all(gf)):
    print("a job is missing its fingerprint (errored?)")
    print(" ref:", rf)
    print(" got:", gf)
    sys.exit(1)
if rf != gf:
    print("fingerprint mismatch vs in-process run:")
    print(" ref:", rf)
    print(" got:", gf)
    sys.exit(1)
msg = f"{sys.argv[2]}: {len(gf)} fingerprints match the reference"
if want == "-":
    pass
elif want == "sum":
    other = json.load(open(sys.argv[4]))
    total = int(got["done"]["computes"]) + \
        int(other["done"]["computes"])
    if total != int(ref["computes"]):
        print(f"concurrent clients computed {total} artifacts; the "
              f"in-process run computed {ref['computes']} — dedup "
              "across batches is broken")
        sys.exit(1)
    msg += f", computes sum == {total}"
else:
    c = int(got["done"]["computes"])
    if c != int(want):
        print(f"expected computes == {want}, got {c}")
        sys.exit(1)
    msg += f", computes == {c}"
print("serve_smoke:", msg)
PY
}

echo "serve_smoke: in-process reference run"
"$bin" run "$jobs" --stats --json "$tmp/ref.json" \
    >"$tmp/ref.log" 2>&1 || die "reference brecq run failed"

echo "serve_smoke: starting daemon (store at $store)"
start_daemon

echo "serve_smoke: two concurrent submit clients"
"$bin" submit "$jobs" --sock "$sock" --quiet \
    --json "$tmp/a.json" >"$tmp/a.log" 2>&1 &
pa=$!
"$bin" submit "$jobs" --sock "$sock" --quiet --priority 1 \
    --json "$tmp/b.json" >"$tmp/b.log" 2>&1 &
pb=$!
ok=0
wait "$pa" || ok=1
wait "$pb" || ok=1
[ "$ok" -eq 0 ] || die "a submit client exited non-zero"
check "$tmp/a.json" sum "$tmp/b.json"
check "$tmp/b.json" -

echo "serve_smoke: warm re-submit against the live daemon"
"$bin" submit "$jobs" --sock "$sock" --quiet \
    --json "$tmp/warm.json" >"$tmp/warm.log" 2>&1 \
    || die "warm submit failed"
check "$tmp/warm.json" 0

echo "serve_smoke: clean shutdown"
stop_daemon

echo "serve_smoke: restarting daemon over the same store"
start_daemon
"$bin" submit "$jobs" --sock "$sock" --quiet \
    --json "$tmp/restart.json" >"$tmp/restart.log" 2>&1 \
    || die "post-restart submit failed"
check "$tmp/restart.json" 0
stop_daemon

echo "serve_smoke: all checks passed"
