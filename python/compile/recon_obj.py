"""Builders for every AOT executable (L2 compute graphs).

Each builder returns `(fn, in_specs, out_specs)` where specs are ordered
`(role_name, shape)` lists — the positional ABI recorded in the manifest and
consumed by the Rust runtime. Roles are index-based (`w0`, `v0`, `astep1`,
...) rather than layer-name based so that structurally identical units share
one executable (AOT dedup).

Executables (all f32, shapes static, bitwidths are *runtime* scalars):

  unit_fwd    — run one reconstruction unit, FP or fake-quant activations.
                Used by the dual-stream collector and final stitched eval.
  unit_recon  — one optimization step of Eq. 10 + rounding regularizer:
                forward + gradients wrt AdaRound v and activation steps.
                The Rust coordinator owns the Adam state and β schedule.
  eval_fwd    — whole-model logits (eval batch) with optional act quant.
  fim         — ∂L/∂z at every unit output of a granularity (eps-injection
                trick: grad wrt zero perturbations added at unit outputs).
  qat_step    — LSQ QAT loss + grads wrt (w, b, w_step, a_step) (Table 4).
  distill     — ZeroQ BN-statistics matching loss + grad wrt the input
                images (distilled-data generation, Fig. 3 / Table 4).

Passing bit bounds (wn/wp/aqmin/aqmax) and flags as (1,)-shaped runtime
inputs is what lets a single executable serve 2/4/8-bit, mixed precision and
the FP stream — no per-bitwidth recompilation.
"""

from typing import List, Tuple

import jax
import jax.numpy as jnp

from . import nets
from .kernels import fake_quant, fim_loss, lsq, ref


class Sig:
    """Ordered (name, shape) argument list."""

    def __init__(self):
        self.items: List[Tuple[str, tuple]] = []

    def add(self, name, shape):
        self.items.append((name, tuple(int(d) for d in shape)))

    def index(self):
        return {n: i for i, (n, _) in enumerate(self.items)}


def unit_io_shapes(model: nets.Model, gran: str, batch: int):
    """Walk the unit stream with abstract values; returns per-unit
    (in_shape, skip_shape|None, out_shape)."""
    units = model.units(gran)
    outs = []

    def tap(i, u, z):
        outs.append(tuple(z.shape))
        return z

    params_spec = {}
    for l in model.layers:
        params_spec[l.name + '.w'] = jax.ShapeDtypeStruct(l.wshape(),
                                                          jnp.float32)
        params_spec[l.name + '.b'] = jax.ShapeDtypeStruct((l.cout,),
                                                          jnp.float32)
    x_spec = jax.ShapeDtypeStruct((batch, 3, model.input_hw, model.input_hw),
                                  jnp.float32)
    jax.eval_shape(lambda x, p: model.run_units(nets.Ctx(p), x, gran, tap),
                   x_spec, params_spec)

    shapes, main, pending = [], tuple(x_spec.shape), None
    for u, out in zip(units, outs):
        if u.save_skip:
            pending = main
        skip = pending if u.uses_skip else None
        shapes.append((main, skip, out))
        main = out
        if u.uses_skip:
            pending = None
    return shapes


def _mk_qa(d, idx, name2i, flag_name='aq_flag'):
    """Activation hook: LSQ fake-quant gated by the aq_flag input."""
    def qa(name, x):
        i = name2i[name]
        xq = lsq.lsq_quant(x, d[idx[f'astep{i}']], d[idx[f'aqmin{i}']],
                           d[idx[f'aqmax{i}']])
        return jnp.where(d[idx[flag_name]][0] > 0, xq, x)
    return qa


# --------------------------------------------------------------------------
# unit_fwd
# --------------------------------------------------------------------------

def build_unit_fwd(unit: nets.Unit, in_shape, skip_shape, out_shape):
    sig = Sig()
    sig.add('x', in_shape)
    if unit.uses_skip:
        sig.add('skip', skip_shape)
    for i, l in enumerate(unit.layers):
        sig.add(f'w{i}', l.wshape())
        sig.add(f'b{i}', (l.cout,))
    for i, _ in enumerate(unit.layers):
        sig.add(f'astep{i}', (1,))
        sig.add(f'aqmin{i}', (1,))
        sig.add(f'aqmax{i}', (1,))
    sig.add('aq_flag', (1,))
    idx = sig.index()
    name2i = {l.name: i for i, l in enumerate(unit.layers)}

    def fn(*d):
        params = {}
        for i, l in enumerate(unit.layers):
            params[l.name + '.w'] = d[idx[f'w{i}']]
            params[l.name + '.b'] = d[idx[f'b{i}']]
        ctx = nets.Ctx(params, qa=_mk_qa(d, idx, name2i))
        if unit.uses_skip:
            z = unit.fn(ctx, d[idx['x']], d[idx['skip']])
        else:
            z = unit.fn(ctx, d[idx['x']])
        return (z,)

    return fn, sig.items, [('z', tuple(out_shape))]


# --------------------------------------------------------------------------
# unit_recon
# --------------------------------------------------------------------------

def build_unit_recon(unit: nets.Unit, in_shape, skip_shape, out_shape):
    sig = Sig()
    sig.add('x', in_shape)
    if unit.uses_skip:
        sig.add('skip', skip_shape)
    sig.add('z_fp', out_shape)
    sig.add('fim', out_shape)
    for i, l in enumerate(unit.layers):
        sig.add(f'w{i}', l.wshape())
        sig.add(f'b{i}', (l.cout,))
        sig.add(f'wstep{i}', (l.cout,))
        sig.add(f'v{i}', l.wshape())
        sig.add(f'wn{i}', (1,))
        sig.add(f'wp{i}', (1,))
    for i, _ in enumerate(unit.layers):
        sig.add(f'astep{i}', (1,))
        sig.add(f'aqmin{i}', (1,))
        sig.add(f'aqmax{i}', (1,))
    sig.add('beta', (1,))
    sig.add('lam', (1,))
    sig.add('aq_flag', (1,))
    idx = sig.index()
    name2i = {l.name: i for i, l in enumerate(unit.layers)}
    nl = len(unit.layers)

    def fn(*d):
        params = {}
        for i, l in enumerate(unit.layers):
            params[l.name + '.w'] = d[idx[f'w{i}']]
            params[l.name + '.b'] = d[idx[f'b{i}']]

        def loss_fn(vs, asteps):
            def qw(name, w):
                i = name2i[name]
                return fake_quant.adaround(w, d[idx[f'wstep{i}']], vs[i],
                                           d[idx[f'wn{i}']], d[idx[f'wp{i}']])

            def qa(name, x):
                i = name2i[name]
                xq = lsq.lsq_quant(x, asteps[i], d[idx[f'aqmin{i}']],
                                   d[idx[f'aqmax{i}']])
                return jnp.where(d[idx['aq_flag']][0] > 0, xq, x)

            ctx = nets.Ctx(params, qw=qw, qa=qa)
            if unit.uses_skip:
                zq = unit.fn(ctx, d[idx['x']], d[idx['skip']])
            else:
                zq = unit.fn(ctx, d[idx['x']])
            rec = fim_loss.fim_loss(d[idx['z_fp']], zq, d[idx['fim']])
            beta = d[idx['beta']][0]
            rl = jnp.float32(0.0)
            for v in vs:
                h = ref.rect_sigmoid(v)
                rl = rl + jnp.sum(1.0 - jnp.abs(2.0 * h - 1.0) ** beta)
            return rec + d[idx['lam']][0] * rl, (rec, rl)

        vs = tuple(d[idx[f'v{i}']] for i in range(nl))
        asteps = tuple(d[idx[f'astep{i}']] for i in range(nl))
        (loss, (rec, rl)), (gv, gs) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(vs, asteps)
        return (loss.reshape(1), rec.reshape(1), rl.reshape(1), *gv, *gs)

    outs = [('loss', (1,)), ('rec_loss', (1,)), ('round_loss', (1,))]
    for i, l in enumerate(unit.layers):
        outs.append((f'gv{i}', l.wshape()))
    for i in range(nl):
        outs.append((f'gastep{i}', (1,)))
    return fn, sig.items, outs


# --------------------------------------------------------------------------
# eval_fwd
# --------------------------------------------------------------------------

def build_eval_fwd(model: nets.Model, batch: int):
    layers = model.layers
    sig = Sig()
    sig.add('images', (batch, 3, model.input_hw, model.input_hw))
    for i, l in enumerate(layers):
        sig.add(f'w{i}', l.wshape())
        sig.add(f'b{i}', (l.cout,))
    for i, _ in enumerate(layers):
        sig.add(f'astep{i}', (1,))
        sig.add(f'aqmin{i}', (1,))
        sig.add(f'aqmax{i}', (1,))
    sig.add('aq_flag', (1,))
    idx = sig.index()
    name2i = {l.name: i for i, l in enumerate(layers)}

    def fn(*d):
        params = {}
        for i, l in enumerate(layers):
            params[l.name + '.w'] = d[idx[f'w{i}']]
            params[l.name + '.b'] = d[idx[f'b{i}']]
        ctx = nets.Ctx(params, qa=_mk_qa(d, idx, name2i))
        return (model.apply(ctx, d[idx['images']]),)

    return fn, sig.items, [('logits', (batch, model.num_classes))]


# --------------------------------------------------------------------------
# fim
# --------------------------------------------------------------------------

def build_fim(model: nets.Model, gran: str, batch: int):
    layers = model.layers
    shapes = unit_io_shapes(model, gran, batch)
    units = model.units(gran)
    sig = Sig()
    sig.add('images', (batch, 3, model.input_hw, model.input_hw))
    sig.add('onehot', (batch, model.num_classes))
    for i, l in enumerate(layers):
        sig.add(f'w{i}', l.wshape())
        sig.add(f'b{i}', (l.cout,))
    idx = sig.index()

    def fn(*d):
        params = {}
        for i, l in enumerate(layers):
            params[l.name + '.w'] = d[idx[f'w{i}']]
            params[l.name + '.b'] = d[idx[f'b{i}']]
        ctx = nets.Ctx(params)

        def loss_of(eps):
            def tap(i, u, z):
                return z + eps[i]
            logits = model.run_units(ctx, d[idx['images']], gran, tap)
            return nets.cross_entropy(logits, d[idx['onehot']])

        eps0 = tuple(jnp.zeros(s[2], jnp.float32) for s in shapes)
        return jax.grad(loss_of)(eps0)

    outs = [(f'g{j}', shapes[j][2]) for j in range(len(units))]
    return fn, sig.items, outs


# --------------------------------------------------------------------------
# qat_step (LSQ QAT baseline, Table 4)
# --------------------------------------------------------------------------

def build_qat_step(model: nets.Model, batch: int):
    layers = model.layers
    sig = Sig()
    sig.add('images', (batch, 3, model.input_hw, model.input_hw))
    sig.add('onehot', (batch, model.num_classes))
    for i, l in enumerate(layers):
        sig.add(f'w{i}', l.wshape())
        sig.add(f'b{i}', (l.cout,))
    for i, _ in enumerate(layers):
        sig.add(f'wstep{i}', (1,))
        sig.add(f'astep{i}', (1,))
        sig.add(f'aqmin{i}', (1,))
        sig.add(f'aqmax{i}', (1,))
    sig.add('wqmin', (1,))
    sig.add('wqmax', (1,))
    idx = sig.index()
    name2i = {l.name: i for i, l in enumerate(layers)}

    def fn(*d):
        def loss_fn(ws, bs, wsteps, asteps):
            params = {}
            for i, l in enumerate(layers):
                params[l.name + '.w'] = ws[i]
                params[l.name + '.b'] = bs[i]

            def qw(name, w):
                i = name2i[name]
                return lsq.lsq_quant(w, wsteps[i], d[idx['wqmin']],
                                     d[idx['wqmax']])

            def qa(name, x):
                i = name2i[name]
                return lsq.lsq_quant(x, asteps[i], d[idx[f'aqmin{i}']],
                                     d[idx[f'aqmax{i}']])

            ctx = nets.Ctx(params, qw=qw, qa=qa)
            logits = model.apply(ctx, d[idx['images']])
            return nets.cross_entropy(logits, d[idx['onehot']])

        ws = tuple(d[idx[f'w{i}']] for i in range(len(layers)))
        bs = tuple(d[idx[f'b{i}']] for i in range(len(layers)))
        wsteps = tuple(d[idx[f'wstep{i}']] for i in range(len(layers)))
        asteps = tuple(d[idx[f'astep{i}']] for i in range(len(layers)))
        loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2, 3))(
            ws, bs, wsteps, asteps)
        gw, gb, gws, gas = grads
        return (loss.reshape(1), *gw, *gb, *gws, *gas)

    outs = [('loss', (1,))]
    for i, l in enumerate(layers):
        outs.append((f'gw{i}', l.wshape()))
    for i, l in enumerate(layers):
        outs.append((f'gb{i}', (l.cout,)))
    for i in range(len(layers)):
        outs.append((f'gwstep{i}', (1,)))
    for i in range(len(layers)):
        outs.append((f'gastep{i}', (1,)))
    return fn, sig.items, outs


# --------------------------------------------------------------------------
# distill (ZeroQ data distillation)
# --------------------------------------------------------------------------

def build_distill(model: nets.Model, batch: int):
    """BN-statistics matching: loss(x) + grad wrt x. Raw (unfolded) params."""
    convs = [l for l in model.layers if l.kind == 'conv']
    fc = [l for l in model.layers if l.kind == 'fc']
    sig = Sig()
    sig.add('x', (batch, 3, model.input_hw, model.input_hw))
    for i, l in enumerate(convs):
        sig.add(f'w{i}', l.wshape())
        sig.add(f'gamma{i}', (l.cout,))
        sig.add(f'beta{i}', (l.cout,))
        sig.add(f'mu{i}', (l.cout,))       # target running stats
        sig.add(f'var{i}', (l.cout,))
    for j, l in enumerate(fc):
        sig.add(f'fcw{j}', l.wshape())
        sig.add(f'fcb{j}', (l.cout,))
    idx = sig.index()

    def fn(*d):
        def loss_fn(x):
            params = {}
            for i, l in enumerate(convs):
                params[l.name + '.w'] = d[idx[f'w{i}']]
                params[l.name + '.gamma'] = d[idx[f'gamma{i}']]
                params[l.name + '.beta'] = d[idx[f'beta{i}']]
            for j, l in enumerate(fc):
                params[l.name + '.w'] = d[idx[f'fcw{j}']]
                params[l.name + '.b'] = d[idx[f'fcb{j}']]
            ctx = nets.TrainCtx(params, use_batch_stats=True)
            logits = model.apply(ctx, x)
            # zero-weighted logits term: keeps the fc params in the
            # lowered signature (jax.jit would DCE-prune them otherwise)
            loss = jnp.float32(0.0) + 0.0 * jnp.sum(logits)
            for i, l in enumerate(convs):
                mu_b, var_b = ctx.stats[l.name]
                loss = loss + jnp.mean((mu_b - d[idx[f'mu{i}']]) ** 2)
                loss = loss + jnp.mean((var_b - d[idx[f'var{i}']]) ** 2)
            # input prior: standardized images have zero mean / unit variance
            loss = loss + jnp.mean(jnp.mean(x, axis=(0, 2, 3)) ** 2)
            loss = loss + jnp.mean((jnp.var(x, axis=(0, 2, 3)) - 1.0) ** 2)
            return loss

        loss, gx = jax.value_and_grad(loss_fn)(d[idx['x']])
        return (loss.reshape(1), gx)

    outs = [('loss', (1,)),
            ('gx', (batch, 3, model.input_hw, model.input_hw))]
    return fn, sig.items, outs


# --------------------------------------------------------------------------
# act_obs (activation-site statistics for LSQ step init)
# --------------------------------------------------------------------------

def build_act_obs(model: nets.Model, batch: int):
    """Per-layer [max|x|, mean|x|] of every layer's input activation —
    the Rust coordinator initializes LSQ steps as 2*E|x|/sqrt(qmax)."""
    layers = model.layers
    sig = Sig()
    sig.add('images', (batch, 3, model.input_hw, model.input_hw))
    for i, l in enumerate(layers):
        sig.add(f'w{i}', l.wshape())
        sig.add(f'b{i}', (l.cout,))
    idx = sig.index()

    def fn(*d):
        params = {}
        for i, l in enumerate(layers):
            params[l.name + '.w'] = d[idx[f'w{i}']]
            params[l.name + '.b'] = d[idx[f'b{i}']]
        stats = {}

        def qa(name, x):
            stats[name] = jnp.stack(
                [jnp.max(jnp.abs(x)), jnp.mean(jnp.abs(x))])
            return x

        ctx = nets.Ctx(params, qa=qa)
        logits = model.apply(ctx, d[idx['images']])
        # anchor: jax.jit DCE-prunes unused params at lowering time, which
        # would desync the executable signature from the manifest — the
        # final layer's w/b don't affect any site statistic, so thread a
        # zero-weighted dependency on the logits through the last output.
        out = [stats[l.name] for l in layers]
        out[-1] = out[-1] + 0.0 * jnp.sum(logits)
        return tuple(out)

    outs = [(f'obs{i}', (2,)) for i in range(len(layers))]
    return fn, sig.items, outs
