"""AOT export: lower every executable to HLO *text* + write the manifest.

HLO text (not serialized HloModuleProto) is the interchange format: jax>=0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (the
version the published `xla` rust crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. Recipe follows
/opt/xla-example/gen_hlo.py.

Run as:  cd python && python -m compile.aot --out ../artifacts

The export is idempotent and cached at three levels:
  * dataset files are only generated when missing,
  * FP training only runs when a model's weight store is missing,
  * all HLO lowering is deduplicated by structural signature (units with
    equal topology/shapes/layer-configs share one executable).
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset, nets, recon_obj, store, train

CALIB_B = 32
EVAL_B = 200
QAT_B = 64
DISTILL_B = 32
TRAIN_EPOCHS = int(os.environ.get('BRECQ_TRAIN_EPOCHS', '3'))

# which granularities to export per model (ablation models get all four)
GRANS = {
    'resnet_s': ['layer', 'block', 'stage', 'net'],
    'mobilenetv2_s': ['layer', 'block', 'stage', 'net'],
    'regnet_s': ['layer', 'block'],
    # mnasnet_s is in the zoo but outside the default export: its large
    # depthwise-k5 blocks train too slowly on the single-core CI substrate.
    # Export with --models mnasnet_s when budget allows.
    # 'mnasnet_s': ['layer', 'block'],
}
QAT_MODELS = ['resnet_s', 'mobilenetv2_s']
DISTILL_MODELS = ['resnet_s']


def to_hlo_text(fn, in_specs) -> str:
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in in_specs]
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir('stablehlo')
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


class Exporter:
    def __init__(self, outdir):
        self.outdir = outdir
        self.registry = {}      # exe_name -> {file, inputs, outputs}
        self.dedup = {}         # structural key -> exe_name
        self.counter = 0
        self.lowered_s = 0.0

    def lower(self, key, fn, in_specs, out_specs):
        """Lower (or reuse) an executable; returns its name."""
        if key in self.dedup:
            return self.dedup[key]
        name = f'exe_{self.counter:03d}'
        self.counter += 1
        t0 = time.time()
        text = to_hlo_text(fn, in_specs)
        self.lowered_s += time.time() - t0
        fname = f'{name}.hlo.txt'
        with open(os.path.join(self.outdir, fname), 'w') as f:
            f.write(text)
        self.registry[name] = {
            'file': fname,
            'inputs': [{'name': n, 'shape': list(s)} for n, s in in_specs],
            'outputs': [{'name': n, 'shape': list(s)} for n, s in out_specs],
        }
        self.dedup[key] = name
        return name


def layer_struct(l: nets.Layer):
    return (l.kind, l.cin, l.cout, l.k, l.stride, l.groups, l.relu)


def export_model(ex: Exporter, model: nets.Model, fp_acc: float):
    entry = {
        'fp_acc': fp_acc,
        'weights': f'weights_{model.name}',
        'layers': model.layer_geometry(),
        'grans': {},
    }
    # attach weight shapes to the geometry
    for geo, l in zip(entry['layers'], model.layers):
        geo['wshape'] = list(l.wshape())

    # whole-model eval forward
    fn, isig, osig = recon_obj.build_eval_fwd(model, EVAL_B)
    entry['fwd_exe'] = ex.lower(
        ('eval_fwd', EVAL_B, tuple(layer_struct(l) for l in model.layers)),
        fn, isig, osig)
    entry['eval_batch'] = EVAL_B

    # per-layer activation statistics (LSQ step init on the Rust side)
    fn, isig, osig = recon_obj.build_act_obs(model, CALIB_B)
    entry['act_obs_exe'] = ex.lower(
        ('act_obs', CALIB_B, tuple(layer_struct(l) for l in model.layers)),
        fn, isig, osig)

    for gran in GRANS[model.name]:
        units = model.units(gran)
        shapes = recon_obj.unit_io_shapes(model, gran, CALIB_B)
        gentry = {'units': []}
        # FIM executable for this granularity
        fn, isig, osig = recon_obj.build_fim(model, gran, CALIB_B)
        gentry['fim_exe'] = ex.lower(
            ('fim', gran, CALIB_B, model.name), fn, isig, osig)
        for u, (ins, sk, out) in zip(units, shapes):
            ukey = (u.topo, u.uses_skip, ins, sk, out,
                    tuple(layer_struct(l) for l in u.layers))
            fn, isig, osig = recon_obj.build_unit_fwd(u, ins, sk, out)
            fwd = ex.lower(('unit_fwd',) + ukey, fn, isig, osig)
            fn, isig, osig = recon_obj.build_unit_recon(u, ins, sk, out)
            rec = ex.lower(('unit_recon',) + ukey, fn, isig, osig)
            gentry['units'].append({
                'name': u.name,
                'topo': u.topo,
                'layers': [l.name for l in u.layers],
                'uses_skip': u.uses_skip,
                'save_skip': u.save_skip,
                'in_shape': list(ins),
                'skip_shape': list(sk) if sk else None,
                'out_shape': list(out),
                'fwd_exe': fwd,
                'recon_exe': rec,
            })
        entry['grans'][gran] = gentry

    if model.name in QAT_MODELS:
        fn, isig, osig = recon_obj.build_qat_step(model, QAT_B)
        entry['qat_exe'] = ex.lower(('qat', model.name, QAT_B),
                                    fn, isig, osig)
        entry['qat_batch'] = QAT_B
    if model.name in DISTILL_MODELS:
        fn, isig, osig = recon_obj.build_distill(model, DISTILL_B)
        entry['distill_exe'] = ex.lower(('distill', model.name, DISTILL_B),
                                        fn, isig, osig)
        entry['distill_batch'] = DISTILL_B
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--out', default='../artifacts')
    ap.add_argument('--models', default=','.join(GRANS.keys()))  # resnet_s,mobilenetv2_s,regnet_s
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    t0 = time.time()
    data_dir = os.path.join(outdir, 'data')
    data, mean, std = dataset.load(data_dir)
    print(f'[aot] dataset ready ({time.time() - t0:.0f}s)')

    model_names = args.models.split(',')
    fp_accs = {}
    for name in model_names:
        prefix = os.path.join(outdir, f'weights_{name}')
        if not (os.path.exists(prefix + '.json')
                and os.path.exists(prefix + '.bin')):
            print(f'[aot] training {name} (epochs={TRAIN_EPOCHS})...')
            train.train_and_store(name, outdir, data, mean, std,
                                  epochs=TRAIN_EPOCHS)
        tensors = store.read_store(prefix)
        fp_accs[name] = float(tensors['meta.fp_acc'][0])
        print(f'[aot] {name}: fp_acc={fp_accs[name] * 100:.2f}%')

    ex = Exporter(outdir)
    manifest = {
        'calib_batch': CALIB_B,
        'dataset': {
            'dir': 'data',
            'img': dataset.IMG,
            'classes': dataset.NUM_CLASSES,
            'train_n': dataset.TRAIN_N,
            'test_n': dataset.TEST_N,
            'mean': [float(v) for v in mean],
            'std': [float(v) for v in std],
        },
        'models': {},
    }
    for name in model_names:
        t1 = time.time()
        model = nets.get_model(name)
        manifest['models'][name] = export_model(ex, model, fp_accs[name])
        print(f'[aot] {name}: exported ({time.time() - t1:.0f}s, '
              f'{ex.counter} executables total)')
    manifest['executables'] = ex.registry

    with open(os.path.join(outdir, 'manifest.json'), 'w') as f:
        json.dump(manifest, f, indent=1)
    print(f'[aot] done: {ex.counter} executables, '
          f'lowering {ex.lowered_s:.0f}s, total {time.time() - t0:.0f}s')


if __name__ == '__main__':
    main()
