"""Functional CNN model zoo + BRECQ reconstruction-unit partitioner.

Models are CIFAR-scale stand-ins for the paper's ImageNet nets, keeping the
block taxonomy BRECQ's analysis keys on:

  resnet_s       — ResNet-style basic blocks (normal conv, residual)
  mobilenetv2_s  — inverted residual blocks (depthwise separable, linear
                   bottleneck → signed activation sites)
  regnet_s       — RegNetX-style X-blocks (group conv)
  mnasnet_s      — NAS-searched-style MB blocks (mixed kernel size / expand)

A model is: stem (layer unit) + body blocks + head (layer units), exactly the
decomposition of Fig. 1a. `Model.units(gran)` partitions the body at one of
the paper's four granularities (layer / block / stage / net); stem and head
always use naive layer reconstruction (§B.4.4).

Everything is pure-functional: parameters are flat dicts keyed by layer name
("s1.b0.conv1.w", ...). The same block-apply code serves FP training (BN,
batch stats via `TrainCtx`), deployment/eval and the reconstruction
objective (`Ctx` with pluggable weight/activation fake-quant hooks).

Stream semantics for unit-by-unit advance (used by the Rust coordinator and
mirrored here for FIM/AOT): the calibration activation stream is a pair
(main, skip). For each unit in order:
    if unit.save_skip: skip := main            # captured at unit input
    main := unit.fn(ctx, main, skip if unit.uses_skip else None)
    if unit.uses_skip: skip := None            # consumed
This makes every unit a single-output subgraph even when residual adds are
split at layer granularity.
"""

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

EPS_BN = 1e-5


# --------------------------------------------------------------------------
# Layers
# --------------------------------------------------------------------------

@dataclass
class Layer:
    """One weighted op in deploy form (BN already folded into w, b)."""
    name: str
    kind: str                  # 'conv' | 'fc'
    cin: int
    cout: int
    k: int = 1
    stride: int = 1
    groups: int = 1
    relu: bool = True          # ReLU applied inside the layer
    site_signed: bool = False  # can this layer's *input* be negative?

    def wshape(self):
        if self.kind == 'fc':
            return (self.cout, self.cin)
        return (self.cout, self.cin // self.groups, self.k, self.k)

    def nparams(self):
        s = self.wshape()
        n = 1
        for d in s:
            n *= d
        return n + self.cout

    def macs(self, hw_in: Tuple[int, int]):
        """MACs for one sample at the given input spatial size."""
        if self.kind == 'fc':
            return self.cin * self.cout
        h = hw_in[0] // self.stride
        w = hw_in[1] // self.stride
        return h * w * self.cout * (self.cin // self.groups) * self.k * self.k


def conv2d(x, w, stride, groups):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), 'SAME',
        feature_group_count=groups,
        dimension_numbers=('NCHW', 'OIHW', 'NCHW'))


class Ctx:
    """Deploy-mode execution context with fake-quant hooks.

    qw(name, w) -> w'   weight hook (AdaRound soft-quant during recon;
                         identity for FP / pre-quantized weights)
    qa(name, x) -> x'   activation hook at the layer's input site
    """

    def __init__(self, params, qw=None, qa=None):
        self.params = params
        self.qw = qw or (lambda n, w: w)
        self.qa = qa or (lambda n, x: x)

    def layer(self, l: Layer, x):
        w = self.qw(l.name, self.params[l.name + '.w'])
        b = self.params[l.name + '.b']
        x = self.qa(l.name, x)
        if l.kind == 'fc':
            z = x @ w.T + b
        else:
            z = conv2d(x, w, l.stride, l.groups) + b.reshape(1, -1, 1, 1)
        return jax.nn.relu(z) if l.relu else z


class TrainCtx:
    """Training-mode context: conv (no bias) -> BatchNorm -> ReLU.

    Collects the batch statistics of every BN into `self.stats` so the
    training loop can maintain running estimates (and `train.py` can do the
    exact post-training stat recalibration pass before folding).
    """

    def __init__(self, params, running=None, use_batch_stats=True):
        self.params = params
        self.running = running or {}
        self.use_batch_stats = use_batch_stats
        self.stats = {}

    def layer(self, l: Layer, x):
        w = self.params[l.name + '.w']
        if l.kind == 'fc':
            z = x @ w.T + self.params[l.name + '.b']
            return jax.nn.relu(z) if l.relu else z
        z = conv2d(x, w, l.stride, l.groups)
        if self.use_batch_stats:
            mu = jnp.mean(z, axis=(0, 2, 3))
            var = jnp.var(z, axis=(0, 2, 3))
        else:
            mu = self.running[l.name + '.mu']
            var = self.running[l.name + '.var']
        self.stats[l.name] = (mu, var)
        zn = (z - mu.reshape(1, -1, 1, 1)) / jnp.sqrt(
            var.reshape(1, -1, 1, 1) + EPS_BN)
        z = (self.params[l.name + '.gamma'].reshape(1, -1, 1, 1) * zn
             + self.params[l.name + '.beta'].reshape(1, -1, 1, 1))
        return jax.nn.relu(z) if l.relu else z


# --------------------------------------------------------------------------
# Units
# --------------------------------------------------------------------------

@dataclass
class Unit:
    """Single-output reconstruction subgraph (see module docstring)."""
    name: str
    layers: List[Layer]                       # weights owned / reconstructed
    fn: Callable                              # fn(ctx, x, skip=None) -> z
    uses_skip: bool = False
    save_skip: bool = False
    topo: str = ''   # structural tag: units with equal (topo, shapes, layer
                     # configs) lower to identical HLO -> AOT dedup key


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

class Block:
    """Interface: .layers (list), .apply(ctx, x), .layer_units(),
    .block_unit(), .out_signed, .stride"""

    def block_unit(self) -> Unit:
        return Unit(self.name, list(self.layers),
                    lambda ctx, x, skip=None: self.apply(ctx, x),
                    topo=self.topo())

    def topo(self) -> str:
        raise NotImplementedError

    def layer_units(self) -> List[Unit]:
        raise NotImplementedError


class BasicBlock(Block):
    """ResNet basic block: relu(conv2(relu(conv1(x))) + down(x))."""

    def __init__(self, name, cin, cout, stride, in_signed=False):
        self.name, self.stride = name, stride
        self.conv1 = Layer(f'{name}.conv1', 'conv', cin, cout, 3, stride,
                           relu=True, site_signed=in_signed)
        self.conv2 = Layer(f'{name}.conv2', 'conv', cout, cout, 3, 1,
                           relu=False, site_signed=False)
        self.down = None
        if stride != 1 or cin != cout:
            self.down = Layer(f'{name}.down', 'conv', cin, cout, 1, stride,
                              relu=False, site_signed=in_signed)
        self.layers = [l for l in (self.conv1, self.conv2, self.down) if l]
        self.out_signed = False

    def topo(self):
        return f'basic(down={self.down is not None})'

    def apply(self, ctx, x):
        h = ctx.layer(self.conv2, ctx.layer(self.conv1, x))
        sc = ctx.layer(self.down, x) if self.down else x
        return jax.nn.relu(h + sc)

    def layer_units(self):
        u1 = Unit(self.conv1.name, [self.conv1],
                  lambda ctx, x, skip=None: ctx.layer(self.conv1, x),
                  save_skip=True, topo='conv')

        def f2(ctx, x, skip=None):
            h = ctx.layer(self.conv2, x)
            sc = ctx.layer(self.down, skip) if self.down else skip
            return jax.nn.relu(h + sc)
        owned = [self.conv2] + ([self.down] if self.down else [])
        u2 = Unit(self.conv2.name, owned, f2, uses_skip=True,
                  topo=f'basic_l2(down={self.down is not None})')
        return [u1, u2]


class InvertedResidual(Block):
    """MobileNetV2 block: project(dw(expand(x))) [+ x]. Linear bottleneck —
    the block output is signed."""

    def __init__(self, name, cin, cout, stride, t=4, k=3, in_signed=True):
        self.name, self.stride = name, stride
        mid = cin * t
        self.expand = Layer(f'{name}.expand', 'conv', cin, mid, 1, 1,
                            relu=True, site_signed=in_signed)
        self.dw = Layer(f'{name}.dw', 'conv', mid, mid, k, stride,
                        groups=mid, relu=True, site_signed=False)
        self.project = Layer(f'{name}.project', 'conv', mid, cout, 1, 1,
                             relu=False, site_signed=False)
        self.residual = (stride == 1 and cin == cout)
        self.layers = [self.expand, self.dw, self.project]
        self.out_signed = True

    def topo(self):
        return f'ir(res={self.residual})'

    def apply(self, ctx, x):
        h = ctx.layer(self.project,
                      ctx.layer(self.dw, ctx.layer(self.expand, x)))
        return h + x if self.residual else h

    def layer_units(self):
        u1 = Unit(self.expand.name, [self.expand],
                  lambda ctx, x, skip=None: ctx.layer(self.expand, x),
                  save_skip=self.residual, topo='conv')
        u2 = Unit(self.dw.name, [self.dw],
                  lambda ctx, x, skip=None: ctx.layer(self.dw, x),
                  topo='conv')
        if self.residual:
            u3 = Unit(self.project.name, [self.project],
                      lambda ctx, x, skip=None:
                          ctx.layer(self.project, x) + skip,
                      uses_skip=True, topo='ir_l3(res)')
        else:
            u3 = Unit(self.project.name, [self.project],
                      lambda ctx, x, skip=None: ctx.layer(self.project, x),
                      topo='conv')
        return [u1, u2, u3]


class XBlock(Block):
    """RegNetX block: relu(conv3(conv2g(conv1(x))) + down(x)), group conv."""

    def __init__(self, name, cin, cout, stride, group_w=8, in_signed=False):
        self.name, self.stride = name, stride
        g = max(1, cout // group_w)
        self.conv1 = Layer(f'{name}.conv1', 'conv', cin, cout, 1, 1,
                           relu=True, site_signed=in_signed)
        self.conv2 = Layer(f'{name}.conv2', 'conv', cout, cout, 3, stride,
                           groups=g, relu=True, site_signed=False)
        self.conv3 = Layer(f'{name}.conv3', 'conv', cout, cout, 1, 1,
                           relu=False, site_signed=False)
        self.down = None
        if stride != 1 or cin != cout:
            self.down = Layer(f'{name}.down', 'conv', cin, cout, 1, stride,
                              relu=False, site_signed=in_signed)
        self.layers = [l for l in
                       (self.conv1, self.conv2, self.conv3, self.down) if l]
        self.out_signed = False

    def topo(self):
        return f'xblock(down={self.down is not None})'

    def apply(self, ctx, x):
        h = ctx.layer(self.conv3,
                      ctx.layer(self.conv2, ctx.layer(self.conv1, x)))
        sc = ctx.layer(self.down, x) if self.down else x
        return jax.nn.relu(h + sc)

    def layer_units(self):
        u1 = Unit(self.conv1.name, [self.conv1],
                  lambda ctx, x, skip=None: ctx.layer(self.conv1, x),
                  save_skip=True, topo='conv')
        u2 = Unit(self.conv2.name, [self.conv2],
                  lambda ctx, x, skip=None: ctx.layer(self.conv2, x),
                  topo='conv')

        def f3(ctx, x, skip=None):
            h = ctx.layer(self.conv3, x)
            sc = ctx.layer(self.down, skip) if self.down else skip
            return jax.nn.relu(h + sc)
        owned = [self.conv3] + ([self.down] if self.down else [])
        u3 = Unit(self.conv3.name, owned, f3, uses_skip=True,
                  topo=f'xblock_l3(down={self.down is not None})')
        return [u1, u2, u3]


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------

GRANULARITIES = ('layer', 'block', 'stage', 'net')


@dataclass(eq=False)  # identity hash: Model instances are jit static args
class Model:
    name: str
    stem: Layer
    blocks: List[Block]
    stages: List[Tuple[int, int]]          # [start, end) block indices
    head_convs: List[Layer]                # e.g. mbv2 final 1x1 conv
    fc: Layer
    num_classes: int = 10
    input_hw: int = 32

    @property
    def layers(self) -> List[Layer]:
        out = [self.stem]
        for b in self.blocks:
            out.extend(b.layers)
        out.extend(self.head_convs)
        out.append(self.fc)
        return out

    # -- whole-net apply (any ctx) ----------------------------------------
    def apply(self, ctx, x):
        x = ctx.layer(self.stem, x)
        for b in self.blocks:
            x = b.apply(ctx, x)
        for hc in self.head_convs:
            x = ctx.layer(hc, x)
        x = jnp.mean(x, axis=(2, 3))
        return ctx.layer(self.fc, x)

    # -- unit partition -----------------------------------------------------
    def units(self, gran: str) -> List[Unit]:
        assert gran in GRANULARITIES, gran
        units = [Unit('stem', [self.stem],
                      lambda ctx, x, skip=None: ctx.layer(self.stem, x),
                      topo='conv')]
        if gran == 'layer':
            for b in self.blocks:
                units.extend(b.layer_units())
        elif gran == 'block':
            for b in self.blocks:
                units.append(b.block_unit())
        elif gran == 'stage':
            for si, (s, e) in enumerate(self.stages):
                blks = self.blocks[s:e]
                layers = [l for b in blks for l in b.layers]

                def mk(blks):
                    def fn(ctx, x, skip=None):
                        for b in blks:
                            x = b.apply(ctx, x)
                        return x
                    return fn
                units.append(Unit(f'stage{si + 1}', layers, mk(blks),
                                  topo='seq(' + ','.join(
                                      b.topo() for b in blks) + ')'))
        else:  # net
            layers = [l for b in self.blocks for l in b.layers]

            def fn(ctx, x, skip=None):
                for b in self.blocks:
                    x = b.apply(ctx, x)
                return x
            units.append(Unit('net', layers, fn,
                              topo='seq(' + ','.join(
                                  b.topo() for b in self.blocks) + ')'))
        for hc in self.head_convs:
            def mkh(hc):
                return lambda ctx, x, skip=None: ctx.layer(hc, x)
            units.append(Unit(hc.name, [hc], mkh(hc), topo='conv'))

        def fhead(ctx, x, skip=None):
            return ctx.layer(self.fc, jnp.mean(x, axis=(2, 3)))
        units.append(Unit('head', [self.fc], fhead, topo='gap_fc'))
        return units

    # -- unit stream runner (shared semantics with the Rust coordinator) --
    def run_units(self, ctx, x, gran: str, tap=None):
        """Run the whole net unit-by-unit; `tap(i, unit, z)` may transform
        each unit output (used for FIM eps-injection). Returns logits."""
        main, skip = x, None
        for i, u in enumerate(self.units(gran)):
            if u.save_skip:
                skip = main
            z = u.fn(ctx, main, skip) if u.uses_skip else u.fn(ctx, main)
            if tap is not None:
                z = tap(i, u, z)
            main = z
            if u.uses_skip:
                skip = None
        return main

    # -- hardware metadata (consumed by the Rust hwsim via the manifest) --
    def layer_geometry(self):
        """Per-layer (name, cin, cout, k, stride, groups, h_in, w_in, macs,
        nparams) walking the real spatial sizes."""
        out = []
        hw = self.input_hw
        # stem
        out.append(self._geo(self.stem, hw))
        hw //= self.stem.stride
        for b in self.blocks:
            for l in b.layers:
                out.append(self._geo(l, hw))
            hw //= b.stride
        for hc in self.head_convs:
            out.append(self._geo(hc, hw))
        fcg = self._geo(self.fc, 1)
        out.append(fcg)
        return out

    def _geo(self, l: Layer, hw: int):
        return dict(name=l.name, kind=l.kind, cin=l.cin, cout=l.cout,
                    k=l.k, stride=l.stride, groups=l.groups, relu=l.relu,
                    site_signed=l.site_signed, h_in=hw, w_in=hw,
                    macs=l.macs((hw, hw)), nparams=l.nparams())


# --------------------------------------------------------------------------
# Model zoo
# --------------------------------------------------------------------------

def _stage_ranges(blocks_per_stage):
    out, s = [], 0
    for n in blocks_per_stage:
        out.append((s, s + n))
        s += n
    return out


def resnet_s() -> Model:
    stem = Layer('stem', 'conv', 3, 16, 3, 1, relu=True, site_signed=True)
    widths, strides = [16, 32, 64], [1, 2, 2]
    blocks, cin = [], 16
    for si, (w, st) in enumerate(zip(widths, strides)):
        for bi in range(2):
            blocks.append(BasicBlock(f's{si + 1}.b{bi}', cin, w,
                                     st if bi == 0 else 1))
            cin = w
    fc = Layer('head.fc', 'fc', 64, 10, relu=False, site_signed=False)
    return Model('resnet_s', stem, blocks, _stage_ranges([2, 2, 2]), [], fc)


def mobilenetv2_s() -> Model:
    stem = Layer('stem', 'conv', 3, 16, 3, 1, relu=True, site_signed=True)
    cfg = [  # (cout, stride, t)
        (24, 1, 4), (24, 1, 4),
        (32, 2, 4), (32, 1, 4),
        (64, 2, 4), (64, 1, 4),
    ]
    blocks, cin, sig = [], 16, False   # stem output is post-ReLU
    for i, (cout, st, t) in enumerate(cfg):
        blocks.append(InvertedResidual(f's{i // 2 + 1}.b{i % 2}', cin, cout,
                                       st, t=t, in_signed=sig))
        cin, sig = cout, True          # linear bottleneck output: signed
    head = Layer('head.conv', 'conv', 64, 128, 1, 1, relu=True,
                 site_signed=True)
    fc = Layer('head.fc', 'fc', 128, 10, relu=False, site_signed=False)
    return Model('mobilenetv2_s', stem, blocks, _stage_ranges([2, 2, 2]),
                 [head], fc)


def regnet_s() -> Model:
    stem = Layer('stem', 'conv', 3, 24, 3, 1, relu=True, site_signed=True)
    widths, strides = [32, 64, 96], [1, 2, 2]
    blocks, cin = [], 24
    for si, (w, st) in enumerate(zip(widths, strides)):
        for bi in range(2):
            blocks.append(XBlock(f's{si + 1}.b{bi}', cin, w,
                                 st if bi == 0 else 1))
            cin = w
    fc = Layer('head.fc', 'fc', 96, 10, relu=False, site_signed=False)
    return Model('regnet_s', stem, blocks, _stage_ranges([2, 2, 2]), [], fc)


def mnasnet_s() -> Model:
    """NAS-searched-style: MB blocks with per-stage kernel size / expansion
    (the MnasNet signature)."""
    stem = Layer('stem', 'conv', 3, 16, 3, 1, relu=True, site_signed=True)
    cfg = [  # (cout, stride, t, k)
        (24, 1, 3, 3), (24, 1, 3, 3),
        (48, 2, 3, 5), (48, 1, 3, 5),
        (80, 2, 6, 3), (80, 1, 6, 3),
    ]
    blocks, cin, sig = [], 16, False
    for i, (cout, st, t, k) in enumerate(cfg):
        blocks.append(InvertedResidual(f's{i // 2 + 1}.b{i % 2}', cin, cout,
                                       st, t=t, k=k, in_signed=sig))
        cin, sig = cout, True
    head = Layer('head.conv', 'conv', 80, 128, 1, 1, relu=True,
                 site_signed=True)
    fc = Layer('head.fc', 'fc', 128, 10, relu=False, site_signed=False)
    return Model('mnasnet_s', stem, blocks, _stage_ranges([2, 2, 2]),
                 [head], fc)


ZOO = {
    'resnet_s': resnet_s,
    'mobilenetv2_s': mobilenetv2_s,
    'regnet_s': regnet_s,
    'mnasnet_s': mnasnet_s,
}


def get_model(name: str) -> Model:
    return ZOO[name]()


# --------------------------------------------------------------------------
# Parameter initialization (training mode) and BN folding
# --------------------------------------------------------------------------

def init_train_params(model: Model, seed: int = 0):
    """He-init conv weights + BN affine params (train mode), plus fc."""
    key = jax.random.PRNGKey(seed)
    params, running = {}, {}
    for l in model.layers:
        key, k1 = jax.random.split(key)
        fan_in = (l.cin // l.groups) * l.k * l.k if l.kind == 'conv' else l.cin
        w = jax.random.normal(k1, l.wshape()) * jnp.sqrt(2.0 / fan_in)
        params[l.name + '.w'] = w.astype(jnp.float32)
        if l.kind == 'conv':
            params[l.name + '.gamma'] = jnp.ones((l.cout,), jnp.float32)
            params[l.name + '.beta'] = jnp.zeros((l.cout,), jnp.float32)
            running[l.name + '.mu'] = jnp.zeros((l.cout,), jnp.float32)
            running[l.name + '.var'] = jnp.ones((l.cout,), jnp.float32)
        else:
            params[l.name + '.b'] = jnp.zeros((l.cout,), jnp.float32)
    return params, running


def fold_bn(model: Model, params, running):
    """Fold BN into conv weights: deploy params {name.w, name.b}."""
    out = {}
    for l in model.layers:
        w = params[l.name + '.w']
        if l.kind == 'conv':
            gamma = params[l.name + '.gamma']
            beta = params[l.name + '.beta']
            mu = running[l.name + '.mu']
            var = running[l.name + '.var']
            scale = gamma / jnp.sqrt(var + EPS_BN)
            out[l.name + '.w'] = w * scale.reshape(-1, 1, 1, 1)
            out[l.name + '.b'] = beta - mu * scale
        else:
            out[l.name + '.w'] = w
            out[l.name + '.b'] = params[l.name + '.b']
    return out


def cross_entropy(logits, onehot):
    return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))
