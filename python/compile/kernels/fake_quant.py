"""AdaRound soft weight fake-quantization as a Pallas kernel (Eq. 16).

    w_hat = s * clip( floor(w/s) + h(v), n, p ),  h = rectified sigmoid

The kernel is differentiable wrt the rounding variable `v` through a custom
VJP whose backward pass is itself a Pallas kernel. `w` and `step` are frozen
during BRECQ reconstruction, so their cotangents are zero.

Tiling (§Hardware-Adaptation): weights are viewed as (C, K) = (out-channels,
everything else), padded to (8k, 128m) tiles; the per-channel step rides
along as a (C, 1) column broadcast across lanes; the clip bounds n/p are
(1, 1) scalars broadcast to every grid step. The whole schedule reads each
operand exactly once — the kernel is bandwidth-bound.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common as cm
from .ref import ZETA, GAMMA


def _fwd_kernel(w_ref, s_ref, v_ref, n_ref, p_ref, o_ref):
    w = w_ref[...]
    s = s_ref[...]          # (BC, 1) broadcasts across lanes
    v = v_ref[...]
    n = n_ref[0, 0]
    p = p_ref[0, 0]
    h = jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)
    g = jnp.floor(w / s) + h
    o_ref[...] = s * jnp.clip(g, n, p)


def _bwd_kernel(w_ref, s_ref, v_ref, n_ref, p_ref, g_ref, o_ref):
    w = w_ref[...]
    s = s_ref[...]
    v = v_ref[...]
    n = n_ref[0, 0]
    p = p_ref[0, 0]
    gout = g_ref[...]
    sig = jax.nn.sigmoid(v)
    h = sig * (ZETA - GAMMA) + GAMMA
    hgrad = jnp.where(jnp.logical_and(h > 0.0, h < 1.0),
                      sig * (1.0 - sig) * (ZETA - GAMMA), 0.0)
    g = jnp.floor(w / s) + jnp.clip(h, 0.0, 1.0)
    inside = jnp.logical_and(g > n, g < p)
    o_ref[...] = gout * s * jnp.where(inside, hgrad, 0.0)


def _tile(w, step, v):
    """(C, K) view padded to tiles; step padded with ones (avoids div-by-0
    in dead rows; results there are sliced away)."""
    c = w.shape[0]
    w2 = w.reshape(c, -1)
    v2 = v.reshape(c, -1)
    k = w2.shape[1]
    cp = cm.ceil_to(c, cm.SUBLANES)
    kp = cm.ceil_to(k, cm.LANES)
    w2 = cm.pad2d(w2, cp, kp)
    v2 = cm.pad2d(v2, cp, kp)
    s2 = jnp.pad(step.reshape(c, 1), ((0, cp - c), (0, 0)), constant_values=1.0)
    return w2, s2, v2, c, k, cp, kp


def _grid_specs(cp, kp):
    if cm.SINGLE_BLOCK:
        grid = (1,)
        wspec = pl.BlockSpec((cp, kp), lambda i: (0, 0))
        sspec = pl.BlockSpec((cp, 1), lambda i: (0, 0))
    else:
        grid = (cp // cm.SUBLANES,)
        wspec = pl.BlockSpec((cm.SUBLANES, kp), lambda i: (i, 0))
        sspec = pl.BlockSpec((cm.SUBLANES, 1), lambda i: (i, 0))
    nspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return grid, wspec, sspec, nspec


@jax.custom_vjp
def adaround(w, step, v, n, p):
    """Soft fake-quantized weights; step shape (C,), n/p shape (1,)."""
    w2, s2, v2, c, k, cp, kp = _tile(w, step, v)
    grid, wspec, sspec, nspec = _grid_specs(cp, kp)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[wspec, sspec, wspec, nspec, nspec],
        out_specs=wspec,
        out_shape=jax.ShapeDtypeStruct((cp, kp), w.dtype),
        interpret=cm.INTERPRET,
    )(w2, s2, v2, n.reshape(1, 1), p.reshape(1, 1))
    return out[:c, :k].reshape(w.shape)


def _fwd(w, step, v, n, p):
    return adaround(w, step, v, n, p), (w, step, v, n, p)


def _bwd(res, gout):
    w, step, v, n, p = res
    w2, s2, v2, c, k, cp, kp = _tile(w, step, v)
    g2 = cm.pad2d(gout.reshape(c, -1), cp, kp)
    grid, wspec, sspec, nspec = _grid_specs(cp, kp)
    gv = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[wspec, sspec, wspec, nspec, nspec, wspec],
        out_specs=wspec,
        out_shape=jax.ShapeDtypeStruct((cp, kp), w.dtype),
        interpret=cm.INTERPRET,
    )(w2, s2, v2, n.reshape(1, 1), p.reshape(1, 1), g2)
    gv = gv[:c, :k].reshape(w.shape)
    return (jnp.zeros_like(w), jnp.zeros_like(step), gv,
            jnp.zeros_like(n), jnp.zeros_like(p))


adaround.defvjp(_fwd, _bwd)
