"""LSQ fake-quantization with a learned step size as a Pallas kernel.

Forward:  x_hat = s * clip( round(x/s), qmin, qmax )
Backward (Eq. 18 of the paper / Esser et al. 2020, no gradient scale):
  wrt x: straight-through inside the clip range,
  wrt s: qmin / qmax outside, (round(x/s) - x/s) inside.

Used for activation quantization during block reconstruction (Algorithm 1's
"update the activation quantization step size") and, with signed bounds, as
the weight quantizer of the LSQ-QAT baseline (Table 4).

Tiling: the activation is streamed as (8, 128) VPU tiles; the step and the
clip bounds are (1,1) scalars broadcast to every grid step; the backward
pass emits per-tile partial sums for d/ds which are reduced outside the
kernel (one extra (G,1) vector — avoids a second HBM pass over x).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common as cm


def _fwd_kernel(x_ref, s_ref, qn_ref, qp_ref, o_ref):
    x = x_ref[...]
    s = s_ref[0, 0]
    qn = qn_ref[0, 0]
    qp = qp_ref[0, 0]
    o_ref[...] = s * jnp.clip(jnp.round(x / s), qn, qp)


def _bwd_kernel(x_ref, s_ref, qn_ref, qp_ref, g_ref, gx_ref, gs_ref):
    x = x_ref[...]
    s = s_ref[0, 0]
    qn = qn_ref[0, 0]
    qp = qp_ref[0, 0]
    g = g_ref[...]
    xs = x / s
    below = xs <= qn
    above = xs >= qp
    inside = jnp.logical_not(jnp.logical_or(below, above))
    gx_ref[...] = g * inside.astype(x.dtype)
    ds = jnp.where(below, qn, jnp.where(above, qp, jnp.round(xs) - xs))
    gs_ref[0, 0] = jnp.sum(g * ds)


@jax.custom_vjp
def lsq_quant(x, step, qmin, qmax):
    """Fake-quantize `x` (any shape); step/qmin/qmax are (1,)-shaped."""
    x2, n = cm.as_rows128(x)
    rows = x2.shape[0]
    grid = (cm.grid_steps(rows, cm.SUBLANES),)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[cm.row_spec(rows), cm.scalar_spec(), cm.scalar_spec(),
                  cm.scalar_spec()],
        out_specs=cm.row_spec(rows),
        out_shape=jax.ShapeDtypeStruct((rows, cm.LANES), x.dtype),
        interpret=cm.INTERPRET,
    )(x2, step.reshape(1, 1), qmin.reshape(1, 1), qmax.reshape(1, 1))
    return cm.from_rows128(out, n, x.shape)


def _fwd(x, step, qmin, qmax):
    return lsq_quant(x, step, qmin, qmax), (x, step, qmin, qmax)


def _bwd(res, gout):
    x, step, qmin, qmax = res
    x2, n = cm.as_rows128(x)
    g2, _ = cm.as_rows128(gout)        # zero-padded: dead lanes contribute 0
    rows = x2.shape[0]
    gsteps = cm.grid_steps(rows, cm.SUBLANES)
    grid = (gsteps,)
    gx2, gs_part = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[cm.row_spec(rows), cm.scalar_spec(), cm.scalar_spec(),
                  cm.scalar_spec(), cm.row_spec(rows)],
        out_specs=[cm.row_spec(rows),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, cm.LANES), x.dtype),
                   jax.ShapeDtypeStruct((gsteps, 1), x.dtype)],
        interpret=cm.INTERPRET,
    )(x2, step.reshape(1, 1), qmin.reshape(1, 1), qmax.reshape(1, 1), g2)
    gx = cm.from_rows128(gx2, n, x.shape)
    gs = jnp.sum(gs_part).reshape((1,))
    return gx, gs, jnp.zeros_like(qmin), jnp.zeros_like(qmax)


lsq_quant.defvjp(_fwd, _bwd)
