"""FIM-weighted squared reconstruction error as a fused Pallas kernel (Eq. 10).

    L = sum( fim * (z - z_hat)^2 ) / B

where `fim` is the element-wise squared gradient of the task loss at the
unit's FP output (the diagonal pre-activation Fisher), cached per calibration
sample. The kernel fuses subtract/square/scale/reduce into one pass over the
three operands (arith intensity < 1 FLOP/B: pure bandwidth), emitting
per-tile partial sums reduced outside.

Differentiable wrt z_hat only (z and fim are frozen calibration caches).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common as cm


def _fwd_kernel(z_ref, q_ref, f_ref, o_ref):
    d = z_ref[...] - q_ref[...]
    o_ref[0, 0] = jnp.sum(f_ref[...] * d * d)


def _bwd_kernel(z_ref, q_ref, f_ref, c_ref, o_ref):
    # d/d z_hat [ f*(z-z_hat)^2 ] = -2 f (z - z_hat), times the scalar
    # upstream cotangent (already divided by B).
    c = c_ref[0, 0]
    o_ref[...] = -2.0 * c * f_ref[...] * (z_ref[...] - q_ref[...])


@jax.custom_vjp
def fim_loss(z, zq, fim):
    """Scalar FIM-weighted loss; batch dim = z.shape[0]."""
    z2, _ = cm.as_rows128(z)
    q2, _ = cm.as_rows128(zq)
    f2, _ = cm.as_rows128(fim)     # zero-padded: dead lanes contribute 0
    rows = z2.shape[0]
    gsteps = cm.grid_steps(rows, cm.SUBLANES)
    part = pl.pallas_call(
        _fwd_kernel,
        grid=(gsteps,),
        in_specs=[cm.row_spec(rows), cm.row_spec(rows), cm.row_spec(rows)],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gsteps, 1), z.dtype),
        interpret=cm.INTERPRET,
    )(z2, q2, f2)
    return jnp.sum(part) / z.shape[0]


def _fwd(z, zq, fim):
    return fim_loss(z, zq, fim), (z, zq, fim)


def _bwd(res, gout):
    z, zq, fim = res
    z2, n = cm.as_rows128(z)
    q2, _ = cm.as_rows128(zq)
    f2, _ = cm.as_rows128(fim)
    rows = z2.shape[0]
    c = (gout / z.shape[0]).reshape(1, 1)
    gq2 = pl.pallas_call(
        _bwd_kernel,
        grid=(cm.grid_steps(rows, cm.SUBLANES),),
        in_specs=[cm.row_spec(rows), cm.row_spec(rows), cm.row_spec(rows),
                  cm.scalar_spec()],
        out_specs=cm.row_spec(rows),
        out_shape=jax.ShapeDtypeStruct((rows, cm.LANES), z.dtype),
        interpret=cm.INTERPRET,
    )(z2, q2, f2, c)
    gq = cm.from_rows128(gq2, n, z.shape)
    return jnp.zeros_like(z), gq, jnp.zeros_like(fim)


fim_loss.defvjp(_fwd, _bwd)
