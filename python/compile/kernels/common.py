"""Shared tiling helpers for the Pallas kernels.

All kernels view their operands as 2D (rows x 128-lane) tiles, the natural
TPU VPU layout (see DESIGN.md §Hardware-Adaptation). Inputs of arbitrary
shape are padded up to tile multiples in the surrounding jit graph (XLA fuses
the pad/slice with neighbours, so this costs one pass at most) and sliced
back afterwards.

Kernels run with interpret=True: the CPU PJRT client cannot execute Mosaic
custom-calls, so the Pallas body is lowered to plain HLO. The BlockSpec
schedule is still the real one a TPU build would use.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128      # last-dim tile (TPU vector lanes)
SUBLANES = 8     # row tile (f32 sublanes)

INTERPRET = True

# Interpret-mode pallas lowers every grid step into one iteration of an HLO
# while-loop with dynamic-slice bookkeeping; on the CPU PJRT backend a
# many-step grid dominates the executable's runtime. We therefore lower with
# a single grid step whose block covers the whole (padded) operand — the
# numerics and the kernel body are identical; the multi-step BlockSpec
# schedule a real TPU build would use is what row_spec/grid_steps describe
# when SINGLE_BLOCK is off (see DESIGN.md §Hardware-Adaptation and the
# EXPERIMENTS.md §Perf entry for the before/after).
SINGLE_BLOCK = True


def grid_steps(rows: int, block_rows: int) -> int:
    return 1 if SINGLE_BLOCK else rows // block_rows


def ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pad2d(a, rows, cols, value=0.0):
    """Pad a 2D array up to (rows, cols) with a constant."""
    r, c = a.shape
    if r == rows and c == cols:
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)), constant_values=value)


def as_rows128(a, value=0.0):
    """Flatten to 1D, pad, reshape to (R, LANES) with R a SUBLANES multiple.

    Returns (tiled, original_size).
    """
    flat = a.reshape(-1)
    n = flat.shape[0]
    ncols = LANES
    nrows = ceil_to(max(1, (n + ncols - 1) // ncols), SUBLANES)
    padded = jnp.pad(flat, (0, nrows * ncols - n), constant_values=value)
    return padded.reshape(nrows, ncols), n


def from_rows128(tiled, n, shape):
    """Inverse of as_rows128."""
    return tiled.reshape(-1)[:n].reshape(shape)


def scalar_spec():
    """BlockSpec for a (1,1) scalar operand broadcast to every grid step."""
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


def row_spec(rows, cols=LANES):
    """BlockSpec marching down the row dimension of an (R, cols) operand.
    With SINGLE_BLOCK the block covers all rows in one grid step."""
    if SINGLE_BLOCK:
        return pl.BlockSpec((rows, cols), lambda i: (0, 0))
    return pl.BlockSpec((SUBLANES, cols), lambda i: (i, 0))
