"""Pure-jnp oracles for every Pallas kernel (forward + analytic VJPs).

These are the correctness ground truth: pytest checks each Pallas kernel
(interpret=True) against these, for values and for gradients. They are also
used directly by the unit tests of the reconstruction objective.

Notation follows the paper:
  AdaRound (Eq. 16):  w_hat = s * clip( floor(w/s) + h(v), n, p )
      h(v) = clip( sigmoid(v) * (zeta - gamma) + gamma, 0, 1 ),
      zeta=1.1, gamma=-0.1 (rectified sigmoid of Nagel et al. 2020).
  LSQ (Eq. 18):       x_hat = s * clip( round(x/s), qmin, qmax )
      d x_hat / d s  = qmin                    if x/s <= qmin
                     = qmax                    if x/s >= qmax
                     = round(x/s) - x/s        otherwise
      d x_hat / d x  = 1 inside the clip range, 0 outside (STE).
  FIM loss (Eq. 10):  L = sum( fim * (z - z_hat)^2 ) / B
      (fim = squared per-sample gradient dL/dz of the FP network).
"""

import jax
import jax.numpy as jnp

ZETA = 1.1
GAMMA = -0.1


def rect_sigmoid(v):
    """Rectified sigmoid h(v) from AdaRound."""
    return jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def rect_sigmoid_grad(v):
    """dh/dv (zero in the rectified/clipped region)."""
    s = jax.nn.sigmoid(v)
    h = s * (ZETA - GAMMA) + GAMMA
    inside = jnp.logical_and(h > 0.0, h < 1.0)
    return jnp.where(inside, s * (1.0 - s) * (ZETA - GAMMA), 0.0)


def adaround_ref(w, step, v, n, p):
    """AdaRound soft fake-quant. `step` broadcasts against `w`
    (per-channel: shape (C,1,..)), `n`/`p` are (1,)-shaped clip bounds."""
    g = jnp.floor(w / step) + rect_sigmoid(v)
    return step * jnp.clip(g, n.reshape(()), p.reshape(()))


def adaround_grad_v_ref(w, step, v, n, p, gout):
    """VJP wrt v: gout * step * 1{n < floor(w/s)+h(v) < p} * h'(v)."""
    g = jnp.floor(w / step) + rect_sigmoid(v)
    nn, pp = n.reshape(()), p.reshape(())
    inside = jnp.logical_and(g > nn, g < pp)
    return gout * step * jnp.where(inside, rect_sigmoid_grad(v), 0.0)


def adaround_hard_ref(w, step, v, n, p):
    """Hard-rounding commit: h(v) binarized at 0.5 (used after calibration)."""
    g = jnp.floor(w / step) + (rect_sigmoid(v) >= 0.5).astype(w.dtype)
    return step * jnp.clip(g, n.reshape(()), p.reshape(()))


def lsq_ref(x, step, qmin, qmax):
    """LSQ fake-quant with a (1,)-shaped scalar step and clip bounds."""
    s = step.reshape(())
    r = jnp.clip(jnp.round(x / s), qmin.reshape(()), qmax.reshape(()))
    return s * r


def lsq_grads_ref(x, step, qmin, qmax, gout):
    """VJP wrt (x, step) per Eq. 18. Returns (gx, gstep) with gstep (1,)."""
    s = step.reshape(())
    qn, qp = qmin.reshape(()), qmax.reshape(())
    xs = x / s
    below = xs <= qn
    above = xs >= qp
    inside = jnp.logical_not(jnp.logical_or(below, above))
    gx = gout * inside.astype(x.dtype)
    ds = jnp.where(below, qn, jnp.where(above, qp, jnp.round(xs) - xs))
    gstep = jnp.sum(gout * ds).reshape((1,))
    return gx, gstep


def fim_loss_ref(z, zq, fim):
    """FIM-weighted squared error, averaged over the leading batch dim."""
    b = z.shape[0]
    return jnp.sum(fim * (z - zq) ** 2) / b


def fim_loss_grad_zq_ref(z, zq, fim, gout):
    """VJP wrt zq: -2/B * fim * (z - zq) * gout."""
    b = z.shape[0]
    return -2.0 / b * fim * (z - zq) * gout


def round_ste_ref(w, step, n, p):
    """Plain nearest-rounding fake quant (baselines: OMSE, bias correction)."""
    r = jnp.clip(jnp.round(w / step), n.reshape(()), p.reshape(()))
    return step * r
