"""Flat tensor store: `<name>.bin` (little-endian f32) + `<name>.json` index.

The ABI shared with the Rust side (`rust/src/store.rs`): the JSON maps
tensor name -> {shape, offset, size} with offsets in f32 elements.
"""

import json

import numpy as np


def write_store(path_prefix: str, tensors: dict):
    """tensors: name -> np.ndarray (written as f32)."""
    index, offset = {}, 0
    with open(path_prefix + '.bin', 'wb') as f:
        for name in sorted(tensors):
            a = np.asarray(tensors[name], dtype=np.float32)
            f.write(a.tobytes())
            index[name] = {'shape': list(a.shape), 'offset': offset,
                           'size': int(a.size)}
            offset += int(a.size)
    with open(path_prefix + '.json', 'w') as f:
        json.dump({'tensors': index}, f)


def read_store(path_prefix: str) -> dict:
    with open(path_prefix + '.json') as f:
        index = json.load(f)['tensors']
    buf = np.fromfile(path_prefix + '.bin', dtype='<f4')
    out = {}
    for name, meta in index.items():
        a = buf[meta['offset']:meta['offset'] + meta['size']]
        out[name] = a.reshape(meta['shape']).copy()
    return out
