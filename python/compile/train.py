"""FP pre-training of the model zoo (build path only).

Trains each model with Adam + BN on `synth10`, then:
  1. runs an exact BN-statistics recalibration pass over the train set
     (aggregated mean/var, not EMA — the PTQ literature assumes converged
     BN stats before folding),
  2. folds BN into conv weights (deploy params),
  3. writes both deploy and raw(+BN-stat) tensors to the artifact store
     (raw params feed the ZeroQ distilled-data executable).

Invoked by aot.py when the weight store is missing; `make artifacts` is a
no-op when everything is already on disk.
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset, nets, store


def _onehot(y, n=10):
    return jax.nn.one_hot(y, n, dtype=jnp.float32)


def make_train_step(model):
    def loss_fn(params, running, x, y1h):
        ctx = nets.TrainCtx(params, running, use_batch_stats=True)
        logits = model.apply(ctx, x)
        loss = nets.cross_entropy(logits, y1h)
        wd = sum(jnp.sum(params[l.name + '.w'] ** 2) for l in model.layers)
        return loss + 5e-4 * wd, ctx.stats

    @jax.jit
    def step(params, running, opt_m, opt_v, t, x, y1h, lr):
        (loss, stats), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, running, x, y1h)
        new_p, new_m, new_v = {}, {}, {}
        b1, b2, eps = 0.9, 0.999, 1e-8
        for k in params:
            g = grads[k]
            m = b1 * opt_m[k] + (1 - b1) * g
            v = b2 * opt_v[k] + (1 - b2) * g * g
            mh = m / (1 - b1 ** t)
            vh = v / (1 - b2 ** t)
            new_p[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
            new_m[k], new_v[k] = m, v
        new_run = dict(running)
        for name, (mu, var) in stats.items():
            new_run[name + '.mu'] = 0.9 * running[name + '.mu'] + 0.1 * mu
            new_run[name + '.var'] = 0.9 * running[name + '.var'] + 0.1 * var
        return new_p, new_run, new_m, new_v, loss

    return step


@functools.partial(jax.jit, static_argnums=(0,))
def _eval_logits(model, params, running, x):
    ctx = nets.TrainCtx(params, running, use_batch_stats=False)
    return model.apply(ctx, x)


@functools.partial(jax.jit, static_argnums=(0,))
def _batch_stats(model, params, x):
    ctx = nets.TrainCtx(params, {}, use_batch_stats=True)
    model.apply(ctx, x)
    return ctx.stats


@functools.partial(jax.jit, static_argnums=(0,))
def _deploy_logits(model, dparams, x):
    return model.apply(nets.Ctx(dparams), x)


def evaluate(model, params, running, x, y, bs=500):
    correct = 0
    for i in range(0, x.shape[0], bs):
        logits = _eval_logits(model, params, running, x[i:i + bs])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i:i + bs]))
    return correct / x.shape[0]


def evaluate_deploy(model, dparams, x, y, bs=500):
    correct = 0
    for i in range(0, x.shape[0], bs):
        logits = _deploy_logits(model, dparams, x[i:i + bs])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i:i + bs]))
    return correct / x.shape[0]


def recalibrate_bn(model, params, xtr, bs=256, nbatches=24):
    """Exact aggregated BN statistics over `nbatches` training batches."""
    sums, sqs, count = {}, {}, 0
    for i in range(nbatches):
        x = xtr[i * bs:(i + 1) * bs]
        if x.shape[0] < bs:
            break
        stats = _batch_stats(model, params, x)
        for name, (mu, var) in stats.items():
            # E[z], E[z^2] aggregation (var = E[z^2] - E[z]^2 at the end)
            sums[name] = sums.get(name, 0) + mu
            sqs[name] = sqs.get(name, 0) + (var + mu * mu)
        count += 1
    running = {}
    for name in sums:
        mu = sums[name] / count
        running[name + '.mu'] = mu
        running[name + '.var'] = sqs[name] / count - mu * mu
    return running


def train_model(model, data, mean, std, epochs=8, bs=128, lr=2e-3, seed=0):
    (xtr_u8, ytr, xte_u8, yte) = data
    xtr = dataset.to_nchw_f32(xtr_u8, mean, std)
    xte = dataset.to_nchw_f32(xte_u8, mean, std)
    xtr_j, ytr_j = jnp.asarray(xtr), jnp.asarray(ytr.astype(np.int32))
    params, running = nets.init_train_params(model, seed)
    opt_m = {k: jnp.zeros_like(v) for k, v in params.items()}
    opt_v = {k: jnp.zeros_like(v) for k, v in params.items()}
    step = make_train_step(model)
    n = xtr.shape[0]
    rng = np.random.default_rng(seed)
    t0, t = time.time(), 0
    steps_total = epochs * (n // bs)
    for ep in range(epochs):
        perm = rng.permutation(n)
        for i in range(n // bs):
            idx = perm[i * bs:(i + 1) * bs]
            t += 1
            cur_lr = lr * 0.5 * (1 + np.cos(np.pi * t / steps_total))
            params, running, opt_m, opt_v, loss = step(
                params, running, opt_m, opt_v, t,
                xtr_j[idx], _onehot(ytr_j[idx]), cur_lr)
        acc = evaluate(model, params, running, jnp.asarray(xte),
                       jnp.asarray(yte.astype(np.int32)))
        print(f'  [{model.name}] epoch {ep + 1}/{epochs} '
              f'loss={float(loss):.3f} test_acc={acc * 100:.2f}% '
              f'({time.time() - t0:.0f}s)')
    running = recalibrate_bn(model, params, xtr_j)
    dparams = nets.fold_bn(model, params, running)
    acc = evaluate_deploy(model, dparams, jnp.asarray(xte),
                          jnp.asarray(yte.astype(np.int32)))
    print(f'  [{model.name}] folded deploy test_acc={acc * 100:.2f}%')
    return params, running, dparams, acc


def train_and_store(model_name: str, artifacts_dir: str, data, mean, std,
                    epochs=8):
    model = nets.get_model(model_name)
    params, running, dparams, acc = train_model(model, data, mean, std,
                                                epochs=epochs)
    tensors = {}
    for k, v in dparams.items():
        tensors[k] = np.asarray(v)
    for k, v in params.items():
        tensors['raw.' + k] = np.asarray(v)
    for k, v in running.items():
        tensors['bnstat.' + k] = np.asarray(v)
    tensors['meta.fp_acc'] = np.array([acc], dtype=np.float32)
    store.write_store(f'{artifacts_dir}/weights_{model_name}', tensors)
    return acc
