"""Deterministic synthetic datasets (ImageNet / MS-COCO stand-ins).

`synth10`: 10-class 32x32x3 procedural texture classification. Each class is
a distinct combination of oriented stripes (class frequency + orientation),
a class-conditioned colour prior and a positioned radial blob, under per-
sample jitter and pixel noise — separably learnable to >90% top-1 by the
small FP models, yet non-trivial (classes share colour/orientation margins).

Images are stored as u8 HWC rasters; both Python (training) and Rust
(calibration/eval) standardize with the per-channel mean/std recorded in
the manifest. Everything is seeded: the datasets are bit-reproducible.
"""

import os

import numpy as np

NUM_CLASSES = 10
IMG = 32
TRAIN_N = 10000
TEST_N = 2000

_PALETTE = np.array([
    [0.9, 0.2, 0.2], [0.2, 0.9, 0.2], [0.2, 0.3, 0.9], [0.9, 0.8, 0.2],
    [0.8, 0.2, 0.9], [0.2, 0.9, 0.9], [0.9, 0.5, 0.1], [0.5, 0.9, 0.5],
    [0.6, 0.4, 0.9], [0.9, 0.9, 0.9],
], dtype=np.float32)


def _images_for(labels: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    n = labels.shape[0]
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32) / IMG
    imgs = np.empty((n, IMG, IMG, 3), dtype=np.uint8)
    for i, c in enumerate(labels):
        # classes overlap in every single cue (orientation jitter comparable
        # to class spacing, shared colours, jittered blob positions) so the
        # FP models land in the mid-90s rather than saturating — quantization
        # degradation then has somewhere to show.
        theta = np.pi * c / NUM_CLASSES + rng.normal(0, 0.16)
        freq = 2.5 + (c % 5) + rng.normal(0, 0.45)
        phase = rng.uniform(0, 2 * np.pi)
        stripes = np.sin(2 * np.pi * freq *
                         (xx * np.cos(theta) + yy * np.sin(theta)) + phase)
        cx = 0.3 + 0.4 * ((c % 3) / 2.0) + rng.normal(0, 0.13)
        cy = 0.3 + 0.4 * ((c // 3 % 3) / 2.0) + rng.normal(0, 0.13)
        blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / 0.02))
        col = 0.55 * _PALETTE[c] + 0.45 * _PALETTE[(c + 1) % NUM_CLASSES]
        col2 = 0.55 * _PALETTE[(c + 3) % NUM_CLASSES] \
            + 0.45 * _PALETTE[(c + 4) % NUM_CLASSES]
        # class-independent distractor blob
        dx, dy = rng.uniform(0.15, 0.85, size=2)
        distract = np.exp(-(((xx - dx) ** 2 + (yy - dy) ** 2) / 0.015))
        img = (0.45 + 0.15 * stripes[..., None] * col
               + 0.22 * blob[..., None] * col2
               + 0.18 * distract[..., None] * _PALETTE[rng.integers(10)]
               + 0.16 * rng.normal(size=(IMG, IMG, 3)))
        imgs[i] = np.clip(img * 255.0, 0, 255).astype(np.uint8)
    return imgs


def generate(seed: int = 1234):
    """Returns (train_x u8 NHWC, train_y u8, test_x, test_y)."""
    rng = np.random.default_rng(seed)
    ytr = rng.integers(0, NUM_CLASSES, size=TRAIN_N).astype(np.uint8)
    yte = rng.integers(0, NUM_CLASSES, size=TEST_N).astype(np.uint8)
    xtr = _images_for(ytr, rng)
    xte = _images_for(yte, rng)
    return xtr, ytr, xte, yte


def standardize_stats(xtr_u8: np.ndarray):
    """Per-channel mean/std in [0,1] units."""
    x = xtr_u8.astype(np.float32) / 255.0
    return x.mean(axis=(0, 1, 2)), x.std(axis=(0, 1, 2))


def to_nchw_f32(x_u8: np.ndarray, mean, std) -> np.ndarray:
    x = x_u8.astype(np.float32) / 255.0
    x = (x - mean) / std
    return np.transpose(x, (0, 3, 1, 2)).copy()


def ensure_on_disk(outdir: str, seed: int = 1234):
    """Write train/test rasters + labels; no-op when files already exist.
    Returns (paths dict, mean, std)."""
    os.makedirs(outdir, exist_ok=True)
    paths = {k: os.path.join(outdir, f'{k}.bin')
             for k in ('train_x', 'train_y', 'test_x', 'test_y')}
    stats_path = os.path.join(outdir, 'stats.npy')
    if not all(os.path.exists(p) for p in paths.values()) \
            or not os.path.exists(stats_path):
        xtr, ytr, xte, yte = generate(seed)
        mean, std = standardize_stats(xtr)
        xtr.tofile(paths['train_x'])
        ytr.tofile(paths['train_y'])
        xte.tofile(paths['test_x'])
        yte.tofile(paths['test_y'])
        np.save(stats_path, np.stack([mean, std]))
    stats = np.load(stats_path)
    return paths, stats[0], stats[1]


def load(outdir: str):
    paths, mean, std = ensure_on_disk(outdir)
    xtr = np.fromfile(paths['train_x'], dtype=np.uint8).reshape(
        TRAIN_N, IMG, IMG, 3)
    ytr = np.fromfile(paths['train_y'], dtype=np.uint8)
    xte = np.fromfile(paths['test_x'], dtype=np.uint8).reshape(
        TEST_N, IMG, IMG, 3)
    yte = np.fromfile(paths['test_y'], dtype=np.uint8)
    return (xtr, ytr, xte, yte), mean, std
