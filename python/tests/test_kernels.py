"""Pallas kernels vs pure-jnp oracle: values and gradients.

Hypothesis sweeps shapes/values; every kernel is checked in interpret mode
against ref.py for both the forward pass and the custom-VJP backward pass.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fake_quant, fim_loss, lsq, ref

jax.config.update('jax_platform_name', 'cpu')


def rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# --------------------------------------------------------------------------
# AdaRound fake-quant
# --------------------------------------------------------------------------

SHAPES_W = [(4, 3, 3, 3), (16, 8, 1, 1), (7, 5, 3, 3), (10, 64), (1, 1, 1, 1),
            (33, 2, 5, 5)]


@pytest.mark.parametrize('shape', SHAPES_W)
@pytest.mark.parametrize('bits', [2, 4, 8])
def test_adaround_fwd_matches_ref(shape, bits):
    rng = np.random.default_rng(hash((shape, bits)) % 2 ** 31)
    w = rand(rng, shape)
    c = shape[0]
    step = jnp.asarray(np.abs(rng.normal(size=(c,))).astype(np.float32)
                       * 0.1 + 0.01)
    v = rand(rng, shape, 2.0)
    n = jnp.array([-2.0 ** (bits - 1)], jnp.float32)
    p = jnp.array([2.0 ** (bits - 1) - 1], jnp.float32)
    got = fake_quant.adaround(w, step, v, n, p)
    sb = step.reshape((c,) + (1,) * (len(shape) - 1))
    want = ref.adaround_ref(w, sb, v, n, p)
    np.testing.assert_allclose(got, want, atol=1e-6)


@pytest.mark.parametrize('shape', SHAPES_W)
def test_adaround_grad_v_matches_ref(shape):
    rng = np.random.default_rng(hash(shape) % 2 ** 31)
    w = rand(rng, shape)
    c = shape[0]
    step = jnp.asarray(np.abs(rng.normal(size=(c,))).astype(np.float32)
                       * 0.1 + 0.01)
    v = rand(rng, shape, 2.0)
    n, p = jnp.array([-8.0]), jnp.array([7.0])
    g = rand(rng, shape)
    _, vjp = jax.vjp(lambda vv: fake_quant.adaround(w, step, vv, n, p), v)
    got = vjp(g)[0]
    sb = step.reshape((c,) + (1,) * (len(shape) - 1))
    want = ref.adaround_grad_v_ref(w, sb, v, n, p, g)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_adaround_extreme_v_is_floor_or_ceil():
    """h(v) saturates: v >> 0 gives ceil, v << 0 gives floor."""
    rng = np.random.default_rng(0)
    w = rand(rng, (6, 4))
    step = jnp.full((6,), 0.07, jnp.float32)
    n, p = jnp.array([-128.0]), jnp.array([127.0])
    hi = fake_quant.adaround(w, step, jnp.full(w.shape, 20.0), n, p)
    lo = fake_quant.adaround(w, step, jnp.full(w.shape, -20.0), n, p)
    sb = step.reshape(6, 1)
    np.testing.assert_allclose(hi, sb * (jnp.floor(w / sb) + 1), atol=1e-6)
    np.testing.assert_allclose(lo, sb * jnp.floor(w / sb), atol=1e-6)


def test_adaround_output_on_grid():
    """With saturated v, quantized weights live on the step grid in [n,p]."""
    rng = np.random.default_rng(1)
    w = rand(rng, (8, 8))
    step = jnp.full((8,), 0.05, jnp.float32)
    n, p = jnp.array([-2.0]), jnp.array([1.0])
    v = jnp.where(rand(rng, w.shape) > 0, 20.0, -20.0)
    q = np.asarray(fake_quant.adaround(w, step, v, n, p)) / 0.05
    assert np.all(q >= -2.0 - 1e-5) and np.all(q <= 1.0 + 1e-5)
    np.testing.assert_allclose(q, np.round(q), atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(c=st.integers(1, 24), k=st.integers(1, 40), seed=st.integers(0, 999))
def test_adaround_hypothesis_sweep(c, k, seed):
    rng = np.random.default_rng(seed)
    w = rand(rng, (c, k))
    step = jnp.asarray(np.abs(rng.normal(size=(c,))).astype(np.float32)
                       * 0.2 + 0.005)
    v = rand(rng, (c, k), 3.0)
    n, p = jnp.array([-8.0]), jnp.array([7.0])
    got = fake_quant.adaround(w, step, v, n, p)
    want = ref.adaround_ref(w, step.reshape(c, 1), v, n, p)
    np.testing.assert_allclose(got, want, atol=1e-6)


# --------------------------------------------------------------------------
# LSQ activation fake-quant
# --------------------------------------------------------------------------

SHAPES_X = [(2, 3, 8, 8), (32,), (5, 7), (1, 130), (3, 3, 3, 3, 2)]


@pytest.mark.parametrize('shape', SHAPES_X)
@pytest.mark.parametrize('signed', [False, True])
def test_lsq_fwd_matches_ref(shape, signed):
    rng = np.random.default_rng(hash((shape, signed)) % 2 ** 31)
    x = rand(rng, shape, 2.0)
    s = jnp.array([0.09], jnp.float32)
    qn = jnp.array([-8.0 if signed else 0.0], jnp.float32)
    qp = jnp.array([7.0 if signed else 15.0], jnp.float32)
    got = lsq.lsq_quant(x, s, qn, qp)
    want = ref.lsq_ref(x, s, qn, qp)
    np.testing.assert_allclose(got, want, atol=1e-6)


@pytest.mark.parametrize('shape', SHAPES_X)
def test_lsq_grads_match_ref(shape):
    rng = np.random.default_rng(hash(shape) % 2 ** 31)
    x = rand(rng, shape, 2.0)
    s = jnp.array([0.13], jnp.float32)
    qn, qp = jnp.array([0.0]), jnp.array([15.0])
    g = rand(rng, shape)
    _, vjp = jax.vjp(lambda xx, ss: lsq.lsq_quant(xx, ss, qn, qp), x, s)
    gx, gs = vjp(g)
    gxr, gsr = ref.lsq_grads_ref(x, s, qn, qp, g)
    np.testing.assert_allclose(gx, gxr, atol=1e-6)
    np.testing.assert_allclose(gs, gsr, rtol=2e-4, atol=1e-5)


def test_lsq_idempotent():
    """Quantizing an already-quantized tensor is the identity."""
    rng = np.random.default_rng(3)
    x = rand(rng, (4, 16), 2.0)
    s = jnp.array([0.11])
    qn, qp = jnp.array([-8.0]), jnp.array([7.0])
    q1 = lsq.lsq_quant(x, s, qn, qp)
    q2 = lsq.lsq_quant(q1, s, qn, qp)
    np.testing.assert_allclose(q1, q2, atol=1e-6)


def test_lsq_step_gradient_signs():
    """Saturated-low elements pull the step with weight qmin; saturated-high
    with qmax (Eq. 18 boundary behaviour)."""
    x = jnp.array([-100.0, 100.0], jnp.float32)
    s = jnp.array([0.1])
    qn, qp = jnp.array([-8.0]), jnp.array([7.0])
    _, vjp = jax.vjp(lambda ss: lsq.lsq_quant(x, ss, qn, qp), s)
    g_low = vjp(jnp.array([1.0, 0.0], jnp.float32))[0]
    g_high = vjp(jnp.array([0.0, 1.0], jnp.float32))[0]
    np.testing.assert_allclose(g_low, [-8.0], atol=1e-6)
    np.testing.assert_allclose(g_high, [7.0], atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 600), seed=st.integers(0, 999),
       bits=st.sampled_from([2, 4, 8]))
def test_lsq_hypothesis_sweep(n, seed, bits):
    rng = np.random.default_rng(seed)
    x = rand(rng, (n,), 3.0)
    s = jnp.array([float(np.abs(rng.normal()) * 0.3 + 0.01)], jnp.float32)
    qn = jnp.array([0.0])
    qp = jnp.array([2.0 ** bits - 1])
    np.testing.assert_allclose(lsq.lsq_quant(x, s, qn, qp),
                               ref.lsq_ref(x, s, qn, qp), atol=1e-6)


# --------------------------------------------------------------------------
# FIM-weighted loss
# --------------------------------------------------------------------------

SHAPES_Z = [(8, 4, 4, 4), (2, 10), (32, 3), (1, 1, 1, 1)]


@pytest.mark.parametrize('shape', SHAPES_Z)
def test_fim_loss_matches_ref(shape):
    rng = np.random.default_rng(hash(shape) % 2 ** 31)
    z = rand(rng, shape)
    zq = z + rand(rng, shape, 0.1)
    fim = jnp.asarray((rng.normal(size=shape) ** 2).astype(np.float32))
    got = fim_loss.fim_loss(z, zq, fim)
    want = ref.fim_loss_ref(z, zq, fim)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize('shape', SHAPES_Z)
def test_fim_loss_grad_matches_ref(shape):
    rng = np.random.default_rng(hash(shape) % 2 ** 31)
    z = rand(rng, shape)
    zq = z + rand(rng, shape, 0.1)
    fim = jnp.asarray((rng.normal(size=shape) ** 2).astype(np.float32))
    _, vjp = jax.vjp(lambda q: fim_loss.fim_loss(z, q, fim), zq)
    got = vjp(jnp.float32(1.0))[0]
    want = ref.fim_loss_grad_zq_ref(z, zq, fim, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_fim_loss_zero_at_equal():
    rng = np.random.default_rng(5)
    z = rand(rng, (4, 8))
    fim = jnp.ones_like(z)
    assert float(fim_loss.fim_loss(z, z, fim)) == 0.0


def test_fim_loss_reduces_to_mse_with_unit_fim():
    """fim == 1 recovers the plain layer-wise MSE objective (AdaRound)."""
    rng = np.random.default_rng(6)
    z = rand(rng, (8, 6))
    zq = z + rand(rng, (8, 6), 0.2)
    got = fim_loss.fim_loss(z, zq, jnp.ones_like(z))
    want = jnp.sum((z - zq) ** 2) / 8
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_fim_loss_weighting_order():
    """Elements with larger FIM weight dominate the loss (Eq. 10 intent)."""
    z = jnp.zeros((1, 2), jnp.float32)
    zq = jnp.ones((1, 2), jnp.float32)
    hi = fim_loss.fim_loss(z, zq, jnp.array([[10.0, 0.1]], jnp.float32))
    lo = fim_loss.fim_loss(z, zq, jnp.array([[0.1, 0.1]], jnp.float32))
    assert float(hi) > float(lo)


# --------------------------------------------------------------------------
# Hard-rounding commit (ref only — the Rust side mirrors this math)
# --------------------------------------------------------------------------

def test_hard_round_consistent_with_saturated_soft():
    rng = np.random.default_rng(7)
    w = rand(rng, (5, 9))
    step = jnp.asarray(np.abs(rng.normal(size=(5,))).astype(np.float32)
                       * 0.1 + 0.02).reshape(5, 1)
    n, p = jnp.array([-8.0]), jnp.array([7.0])
    v = rand(rng, w.shape, 4.0)
    hard = ref.adaround_hard_ref(w, step, v, n, p)
    soft_sat = ref.adaround_ref(w, step, jnp.where(
        ref.rect_sigmoid(v) >= 0.5, 20.0, -20.0), n, p)
    np.testing.assert_allclose(hard, soft_sat, atol=1e-6)
