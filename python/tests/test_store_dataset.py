"""Store roundtrip + dataset determinism/shape checks."""

import os
import tempfile

import numpy as np

from compile import dataset, store


def test_store_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        prefix = os.path.join(d, 's')
        tensors = {
            'a.w': np.random.default_rng(0).normal(size=(3, 4, 2)),
            'b': np.array([1.5], np.float32),
            'z.scalar': np.array(2.0, np.float32),
        }
        store.write_store(prefix, tensors)
        back = store.read_store(prefix)
        assert set(back) == set(tensors)
        np.testing.assert_allclose(back['a.w'],
                                   tensors['a.w'].astype(np.float32))
        np.testing.assert_allclose(back['b'], [1.5])


def test_dataset_deterministic():
    x1, y1, _, _ = dataset.generate(seed=99)
    x2, y2, _, _ = dataset.generate(seed=99)
    assert np.array_equal(x1[:50], x2[:50])
    assert np.array_equal(y1, y2)


def test_dataset_shapes_and_classes():
    xtr, ytr, xte, yte = dataset.generate(seed=7)
    assert xtr.shape == (dataset.TRAIN_N, 32, 32, 3)
    assert xte.shape == (dataset.TEST_N, 32, 32, 3)
    assert xtr.dtype == np.uint8
    assert set(np.unique(ytr)) <= set(range(10))
    # every class present
    assert len(np.unique(ytr)) == 10


def test_standardization():
    xtr, _, _, _ = dataset.generate(seed=7)
    mean, std = dataset.standardize_stats(xtr)
    z = dataset.to_nchw_f32(xtr[:256], mean, std)
    assert z.shape == (256, 3, 32, 32)
    assert abs(float(z.mean())) < 0.1
    assert 0.7 < float(z.std()) < 1.3


def test_classes_are_distinguishable():
    """Mean images of different classes differ substantially — the dataset
    carries class signal (the FP models reach >95%, this is the cheap
    invariant guarding the generator)."""
    xtr, ytr, _, _ = dataset.generate(seed=7)
    means = np.stack([xtr[ytr == c].mean(axis=0) for c in range(10)])
    d01 = np.abs(means[0] - means[1]).mean()
    assert d01 > 2.0, d01
