"""Model zoo / unit-partition invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import nets

jax.config.update('jax_platform_name', 'cpu')


@pytest.fixture(scope='module', params=list(nets.ZOO))
def model_and_params(request):
    m = nets.get_model(request.param)
    params, running = nets.init_train_params(m, seed=3)
    dparams = nets.fold_bn(m, params, running)
    return m, params, running, dparams


def test_forward_shapes(model_and_params):
    m, _, _, d = model_and_params
    x = jnp.zeros((2, 3, 32, 32))
    logits = m.apply(nets.Ctx(d), x)
    assert logits.shape == (2, 10)


@pytest.mark.parametrize('gran', nets.GRANULARITIES)
def test_unit_stream_equals_direct_apply(model_and_params, gran):
    m, _, _, d = model_and_params
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 3, 32, 32)).astype(np.float32))
    direct = m.apply(nets.Ctx(d), x)
    streamed = m.run_units(nets.Ctx(d), x, gran)
    np.testing.assert_allclose(streamed, direct, atol=1e-4)


def test_units_cover_all_layers_exactly_once(model_and_params):
    m, _, _, _ = model_and_params
    all_names = [l.name for l in m.layers]
    for gran in nets.GRANULARITIES:
        owned = [l.name for u in m.units(gran) for l in u.layers]
        assert sorted(owned) == sorted(all_names), (m.name, gran)


def test_geometry_matches_param_shapes(model_and_params):
    m, _, _, d = model_and_params
    for geo, l in zip(m.layer_geometry(), m.layers):
        assert tuple(d[l.name + '.w'].shape) == l.wshape()
        assert geo['nparams'] == int(np.prod(l.wshape())) + l.cout
        assert geo['macs'] > 0


def test_bn_fold_preserves_inference(model_and_params):
    """Deploy-mode (folded) forward == train-mode forward with running
    stats — the PTQ substrate's starting point must be exact."""
    m, params, running, d = model_and_params
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 3, 32, 32)).astype(np.float32))
    train_logits = m.apply(
        nets.TrainCtx(params, running, use_batch_stats=False), x)
    deploy_logits = m.apply(nets.Ctx(d), x)
    np.testing.assert_allclose(deploy_logits, train_logits,
                               rtol=1e-3, atol=1e-4)


def test_skip_units_structure(model_and_params):
    """save_skip precedes uses_skip, and both are cleared in order."""
    m, _, _, _ = model_and_params
    for gran in nets.GRANULARITIES:
        pending = False
        for u in m.units(gran):
            if u.save_skip:
                pending = True
            if u.uses_skip:
                assert pending, (m.name, gran, u.name)
                pending = False
        assert not pending, (m.name, gran)


def test_mbv2_signed_sites():
    """Linear bottleneck outputs feed signed activation sites."""
    m = nets.get_model('mobilenetv2_s')
    # the expand conv of every non-first block sees a signed input
    signed = [l.site_signed for l in m.layers if l.name.endswith('expand')]
    assert signed[1:] == [True] * (len(signed) - 1)
    # stem sees the (standardized, signed) image
    assert m.stem.site_signed


def test_depthwise_and_group_conv_configs():
    mb = nets.get_model('mobilenetv2_s')
    dw = [l for l in mb.layers if l.groups > 1]
    assert dw and all(l.groups == l.cin for l in dw)
    rg = nets.get_model('regnet_s')
    gc = [l for l in rg.layers if l.groups > 1]
    assert gc and all(l.cin % l.groups == 0 and l.groups < l.cin
                      for l in gc)
