"""Manifest / artifact validation (skips until `make artifacts` has run).

This is the ABI contract test between the Python build path and the Rust
runtime: every executable referenced by a unit must exist on disk with a
signature whose role layout matches what rust/src/recon.rs assembles.
"""

import json
import os

import pytest

ART = os.environ.get(
    'BRECQ_ARTIFACTS',
    os.path.join(os.path.dirname(__file__), '..', '..', 'artifacts'))
MANIFEST = os.path.join(ART, 'manifest.json')

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST),
    reason='artifacts not built (run `make artifacts`)')


@pytest.fixture(scope='module')
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_all_executable_files_exist(manifest):
    for name, e in manifest['executables'].items():
        path = os.path.join(ART, e['file'])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 100, name


def test_unit_exe_signatures_match_role_layout(manifest):
    for mname, m in manifest['models'].items():
        for gran, g in m['grans'].items():
            for u in g['units']:
                exe = manifest['executables'][u['recon_exe']]
                names = [i['name'] for i in exe['inputs']]
                nl = len(u['layers'])
                want = ['x'] + (['skip'] if u['uses_skip'] else [])
                want += ['z_fp', 'fim']
                for i in range(nl):
                    want += [f'w{i}', f'b{i}', f'wstep{i}', f'v{i}',
                             f'wn{i}', f'wp{i}']
                for i in range(nl):
                    want += [f'astep{i}', f'aqmin{i}', f'aqmax{i}']
                want += ['beta', 'lam', 'aq_flag']
                assert names == want, (mname, gran, u['name'])
                onames = [o['name'] for o in exe['outputs']]
                wout = ['loss', 'rec_loss', 'round_loss']
                wout += [f'gv{i}' for i in range(nl)]
                wout += [f'gastep{i}' for i in range(nl)]
                assert onames == wout, (mname, gran, u['name'])


def test_unit_shapes_chain(manifest):
    """Within a granularity, unit in_shape equals previous out_shape."""
    for m in manifest['models'].values():
        for g in m['grans'].values():
            prev = None
            for u in g['units']:
                if prev is not None:
                    assert u['in_shape'] == prev, u['name']
                prev = u['out_shape']


def test_weight_store_exists(manifest):
    for m in manifest['models'].values():
        for ext in ('.json', '.bin'):
            assert os.path.exists(os.path.join(ART, m['weights'] + ext))


def test_dedup_happened(manifest):
    """Structurally identical units must share executables."""
    total_units = sum(len(g['units'])
                      for m in manifest['models'].values()
                      for g in m['grans'].values())
    distinct_exes = len(manifest['executables'])
    assert distinct_exes < 2 * total_units + 10 * len(manifest['models'])
