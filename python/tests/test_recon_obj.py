"""Reconstruction-objective builders: ABI, numerics and gradients.

These are the contracts the Rust coordinator relies on; every builder is
checked against an independently-constructed reference computation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import nets, recon_obj
from compile.kernels import ref

jax.config.update('jax_platform_name', 'cpu')

B = 4


def mk_args(isig, rng, overrides=None):
    args = []
    for name, shape in isig:
        if name.startswith(('wstep', 'astep')):
            a = np.abs(rng.normal(size=shape)).astype(np.float32) * 0.05 + 0.02
        elif name.startswith('wn') or name == 'wqmin':
            a = np.array([-8.0], np.float32)
        elif name.startswith('wp') or name == 'wqmax':
            a = np.array([7.0], np.float32)
        elif name.startswith('aqmin'):
            a = np.array([0.0], np.float32)
        elif name.startswith(('aqmax',)):
            a = np.array([15.0], np.float32)
        elif name == 'beta':
            a = np.array([8.0], np.float32)
        elif name == 'lam':
            a = np.array([0.01], np.float32)
        elif name == 'aq_flag':
            a = np.array([0.0], np.float32)
        elif name == 'onehot':
            a = np.zeros(shape, np.float32)
            a[np.arange(shape[0]), rng.integers(0, shape[1], shape[0])] = 1
        else:
            a = rng.normal(size=shape).astype(np.float32) * 0.5
        if overrides and name in overrides:
            a = overrides[name]
        args.append(jnp.asarray(a))
    return args


@pytest.fixture(scope='module')
def resnet():
    m = nets.get_model('resnet_s')
    params, running = nets.init_train_params(m, seed=5)
    d = nets.fold_bn(m, params, running)
    return m, d


def test_unit_fwd_matches_direct(resnet):
    m, d = resnet
    shapes = recon_obj.unit_io_shapes(m, 'block', B)
    units = m.units('block')
    u, (ins, sk, out) = units[3], shapes[3]
    fn, isig, osig = recon_obj.build_unit_fwd(u, ins, sk, out)
    rng = np.random.default_rng(2)
    # bind the real folded weights so we can compare with unit.fn directly
    over = {}
    for i, l in enumerate(u.layers):
        over[f'w{i}'] = np.asarray(d[l.name + '.w'])
        over[f'b{i}'] = np.asarray(d[l.name + '.b'])
    args = mk_args(isig, rng, over)
    (z,) = jax.jit(fn)(*args)
    x = args[0]
    want = u.fn(nets.Ctx(d), x)
    np.testing.assert_allclose(z, want, rtol=1e-4, atol=1e-5)


def test_unit_fwd_aq_flag_gates_quantization(resnet):
    m, d = resnet
    shapes = recon_obj.unit_io_shapes(m, 'block', B)
    u, (ins, sk, out) = m.units('block')[1], shapes[1]
    fn, isig, _ = recon_obj.build_unit_fwd(u, ins, sk, out)
    rng = np.random.default_rng(3)
    args = mk_args(isig, rng)
    idx = {n: i for i, (n, _) in enumerate(isig)}
    args[idx['aq_flag']] = jnp.array([0.0])
    (z_off,) = jax.jit(fn)(*args)
    args[idx['aq_flag']] = jnp.array([1.0])
    (z_on,) = jax.jit(fn)(*args)
    # quantization must change the output (and only when the flag is on)
    assert not np.allclose(z_off, z_on)


def test_unit_recon_grads_match_ref_objective(resnet):
    """The AOT unit_recon gradient wrt v must equal jax.grad of an
    independently assembled (pure-ref, no pallas) objective."""
    m, d = resnet
    shapes = recon_obj.unit_io_shapes(m, 'block', B)
    u, (ins, sk, out) = m.units('block')[1], shapes[1]
    fn, isig, osig = recon_obj.build_unit_recon(u, ins, sk, out)
    rng = np.random.default_rng(4)
    args = mk_args(isig, rng)
    outs = jax.jit(fn)(*args)
    idx = {n: i for i, (n, _) in enumerate(isig)}
    names = [n for n, _ in osig]

    def ref_loss(vs):
        params = {}
        for i, l in enumerate(u.layers):
            params[l.name + '.w'] = args[idx[f'w{i}']]
            params[l.name + '.b'] = args[idx[f'b{i}']]

        def qw(name, w):
            i = [l.name for l in u.layers].index(name)
            step = args[idx[f'wstep{i}']]
            sb = step.reshape((step.shape[0],) + (1,) * (w.ndim - 1))
            return ref.adaround_ref(w, sb, vs[i], args[idx[f'wn{i}']],
                                    args[idx[f'wp{i}']])

        ctx = nets.Ctx(params, qw=qw)  # aq_flag=0: no act quant
        zq = u.fn(ctx, args[idx['x']])
        rec = ref.fim_loss_ref(args[idx['z_fp']], zq, args[idx['fim']])
        beta = args[idx['beta']][0]
        rl = sum(jnp.sum(1.0 - jnp.abs(2 * ref.rect_sigmoid(v) - 1) ** beta)
                 for v in vs)
        return rec + args[idx['lam']][0] * rl

    vs = tuple(args[idx[f'v{i}']] for i in range(len(u.layers)))
    want_loss = ref_loss(vs)
    gv_ref = jax.grad(lambda vv: ref_loss(vv))(vs)
    np.testing.assert_allclose(outs[0][0], want_loss, rtol=1e-4)
    for i in range(len(u.layers)):
        got = outs[names.index(f'gv{i}')]
        np.testing.assert_allclose(got, gv_ref[i], rtol=1e-3, atol=1e-6)


def test_fim_outputs_match_unit_count(resnet):
    m, d = resnet
    for gran in ('block', 'layer'):
        fn, isig, osig = recon_obj.build_fim(m, gran, B)
        assert len(osig) == len(m.units(gran))
        rng = np.random.default_rng(6)
        over = {}
        li = 0
        for l in m.layers:
            over[f'w{li}'] = np.asarray(d[l.name + '.w'])
            over[f'b{li}'] = np.asarray(d[l.name + '.b'])
            li += 1
        args = mk_args(isig, rng, over)
        outs = jax.jit(fn)(*args)
        shapes = recon_obj.unit_io_shapes(m, gran, B)
        for t, (_, _, out_shape) in zip(outs, shapes):
            assert tuple(t.shape) == tuple(out_shape)
        # gradients at the last unit (logits) are nonzero for a CE loss
        assert float(jnp.abs(outs[-1]).max()) > 0


def test_eval_fwd_matches_apply(resnet):
    m, d = resnet
    fn, isig, _ = recon_obj.build_eval_fwd(m, B)
    rng = np.random.default_rng(7)
    over = {}
    for i, l in enumerate(m.layers):
        over[f'w{i}'] = np.asarray(d[l.name + '.w'])
        over[f'b{i}'] = np.asarray(d[l.name + '.b'])
    args = mk_args(isig, rng, over)
    (logits,) = jax.jit(fn)(*args)
    want = m.apply(nets.Ctx(d), args[0])
    np.testing.assert_allclose(logits, want, rtol=1e-4, atol=1e-5)


def test_act_obs_reports_input_stats(resnet):
    m, d = resnet
    fn, isig, osig = recon_obj.build_act_obs(m, B)
    rng = np.random.default_rng(8)
    args = mk_args(isig, rng)
    outs = jax.jit(fn)(*args)
    assert len(outs) == len(m.layers)
    for t in outs:
        maxabs, meanabs = float(t[0]), float(t[1])
        assert maxabs >= meanabs >= 0


def test_qat_step_outputs(resnet):
    m, _ = resnet
    fn, isig, osig = recon_obj.build_qat_step(m, B)
    rng = np.random.default_rng(9)
    args = mk_args(isig, rng)
    outs = jax.jit(fn)(*args)
    nl = len(m.layers)
    assert len(outs) == 1 + 4 * nl
    assert outs[0].shape == (1,)
    # weight gradients flow through the LSQ STE
    assert any(float(jnp.abs(outs[1 + i]).max()) > 0 for i in range(nl))


def test_distill_grad_decreases_loss(resnet):
    m, _ = resnet
    params, running = nets.init_train_params(m, seed=10)
    fn, isig, _ = recon_obj.build_distill(m, B)
    rng = np.random.default_rng(11)
    over = {}
    convs = [l for l in m.layers if l.kind == 'conv']
    for i, l in enumerate(convs):
        over[f'w{i}'] = np.asarray(params[l.name + '.w'])
        over[f'gamma{i}'] = np.asarray(params[l.name + '.gamma'])
        over[f'beta{i}'] = np.asarray(params[l.name + '.beta'])
        over[f'mu{i}'] = rng.normal(size=(l.cout,)).astype(np.float32) * 0.1
        over[f'var{i}'] = np.abs(
            rng.normal(size=(l.cout,))).astype(np.float32) + 0.5
    args = mk_args(isig, rng, over)
    f = jax.jit(fn)
    loss0, gx = f(*args)
    x = args[0] - 0.5 * gx  # one crude gradient step
    loss1, _ = f(x, *args[1:])
    assert float(loss1[0]) < float(loss0[0])
