//! Bench: backend dispatch hot path. The reconstruction loop issues one
//! `unit_recon` dispatch per Adam step; its latency bounds the whole
//! calibration wall-clock (paper: 20 min for ResNet-18 on a 1080TI).
//! Also measures the fwd/eval paths, the literal marshalling overhead,
//! and — so speedups are attributable per kernel rather than only
//! end-to-end — each distinct conv/fc geometry of both synthetic models
//! through the GEMM-backed kernels (fwd and bwd), plus the raw GEMM
//! micro-kernel and its panel-packing cost.

mod harness;

use std::collections::HashSet;

use brecq::eval::{forward, EvalParams};
use brecq::quant::mse_steps_per_channel;
use brecq::recon::{BitConfig, Calibrator};
use brecq::runtime::gemm;
use brecq::runtime::native::{conv2d, conv2d_bwd, fc_bwd, fc_fwd};
use brecq::tensor::Tensor;
use harness::Harness;

fn main() {
    let mut h = Harness::from_args("bench_runtime");
    let env = harness::bench_env();
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 64, 0);

    // eval forward (batch = eval_batch)
    let p = EvalParams::fp(model, &ws, &bs);
    let eval_imgs = {
        // tile the 64-image calib set up to the eval batch
        let mut parts = Vec::new();
        let b = model.eval_batch;
        while parts.iter().map(|t: &Tensor| t.shape[0]).sum::<usize>() < b {
            parts.push(calib.images.clone());
        }
        Tensor::stack0(&parts).slice0(0, b)
    };
    let iters = h.iters(10);
    h.run("eval_fwd batch=eval", iters, || {
        let out = forward(&env.rt, model, &p, &eval_imgs).unwrap();
        std::hint::black_box(out.data[0]);
    });

    // unit_fwd advance of one block over 64 samples
    let unit = &model.gran("block").units[3];
    let bits = BitConfig::uniform(model, 4, None, true);
    let adv_imgs = images_for(unit, &calib.images);
    let iters = h.iters(10);
    h.run("unit_fwd s2.b0 batch=32 x2", iters, || {
        let z = cal
            .advance(unit, &adv_imgs, None, &ws, &bs, &vec![1.0; ws.len()],
                     &bits, false)
            .unwrap();
        std::hint::black_box(z.data[0]);
    });

    // FIM pass over 64 samples (2 batches)
    let iters = h.iters(3);
    h.run("fim_pass block 64 imgs", iters, || {
        let f = cal.fim_pass("block", &calib, &ws, &bs).unwrap();
        std::hint::black_box(f.len());
    });

    // literal marshalling: weight steps init (pure rust, no dispatch)
    let iters = h.iters(10);
    h.run("mse_steps_per_channel all layers", iters, || {
        for w in &ws {
            std::hint::black_box(mse_steps_per_channel(w, 4));
        }
    });

    // ---- per-kernel micro benches -----------------------------------
    // Every distinct conv/fc geometry of both synthetic models at the
    // calibration batch size, forward and backward, so a regression (or
    // win) is attributable to one kernel shape.
    const KB: usize = 32; // calibration batch
    let mut seen: HashSet<(String, usize, usize, usize, usize, usize, usize)> =
        HashSet::new();
    for mname in ["resnet_s", "mobilenetv2_s"] {
        if !env.has_model(mname) {
            continue;
        }
        for l in &env.model(mname).layers {
            let key = (
                l.kind.clone(),
                l.cin,
                l.cout,
                l.k,
                l.stride,
                l.groups,
                l.h_in,
            );
            if !seen.insert(key) {
                continue;
            }
            let iters = h.iters(30);
            if l.kind == "fc" {
                let x = Tensor::full(vec![KB, l.cin], 0.5);
                let w = Tensor::full(vec![l.cout, l.cin], 0.1);
                let g = Tensor::full(vec![KB, l.cout], 0.3);
                h.run(
                    &format!("fc_fwd {}x{} b{KB}", l.cin, l.cout),
                    iters,
                    || {
                        std::hint::black_box(fc_fwd(&x, &w));
                    },
                );
                h.run(
                    &format!("fc_bwd {}x{} b{KB}", l.cin, l.cout),
                    iters,
                    || {
                        std::hint::black_box(fc_bwd(&x, &w, &g));
                    },
                );
            } else {
                let x = Tensor::full(vec![KB, l.cin, l.h_in, l.w_in], 0.5);
                let w = Tensor::full(
                    vec![l.cout, l.cin / l.groups, l.k, l.k],
                    0.1,
                );
                let gout = {
                    let probe = conv2d(&x, &w, l.stride, l.groups);
                    Tensor::full(probe.shape.clone(), 0.3)
                };
                let tag = format!(
                    "{}-{}c k{} s{} g{} {}px b{KB}",
                    l.cin, l.cout, l.k, l.stride, l.groups, l.h_in
                );
                h.run(&format!("conv_fwd {tag}"), iters, || {
                    std::hint::black_box(conv2d(&x, &w, l.stride, l.groups));
                });
                h.run(&format!("conv_bwd {tag}"), iters, || {
                    std::hint::black_box(conv2d_bwd(
                        &x, &w, l.stride, l.groups, &gout,
                    ));
                });
            }
        }
    }

    // raw micro-kernel + packing, at a shape representative of the
    // per-sample conv GEMMs (M=cout, K=cin*k*k, N=out pixels)
    {
        let (m, n, k) = (64usize, 256usize, 576usize);
        let a = vec![0.25f32; m * k];
        let b = vec![0.5f32; k * n];
        let mut c = vec![0f32; m * n];
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        let iters = h.iters(50);
        h.run(&format!("gemm {m}x{n}x{k}"), iters, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            gemm::gemm(
                m, n, k, &a, k, 1, &b, n, 1, &mut c, n, &mut pa, &mut pb,
            );
            std::hint::black_box(c[0]);
        });
        let kc = k.min(gemm::KC);
        let mut packed_b =
            vec![0f32; n.min(gemm::NC).div_ceil(gemm::NR) * gemm::NR * kc];
        let mut packed_a =
            vec![0f32; m.min(gemm::MC).div_ceil(gemm::MR) * gemm::MR * kc];
        let iters = h.iters(50);
        h.run(&format!("gemm pack_b {k}x{n} panel"), iters, || {
            gemm::pack_b(&b, n, 1, 0, kc, 0, n.min(gemm::NC), &mut packed_b);
            std::hint::black_box(packed_b[0]);
        });
        h.run(&format!("gemm pack_a {m}x{k} panel"), iters, || {
            gemm::pack_a(&a, k, 1, 0, m.min(gemm::MC), 0, kc, &mut packed_a);
            std::hint::black_box(packed_a[0]);
        });
    }

    // scratch-arena health (allocs/reuses) is appended to the JSON notes
    // by Harness::finish for every bench binary.
    h.finish();
}

/// The stream advance needs a main-activation tensor whose trailing shape
/// matches the unit input; to keep the bench self-contained we synthesize
/// a correctly-shaped activation (values don't matter for timing).
fn images_for(unit: &brecq::model::UnitInfo,
              images: &brecq::tensor::Tensor) -> brecq::tensor::Tensor {
    let mut shape = unit.in_shape.clone();
    shape[0] = images.shape[0];
    brecq::tensor::Tensor::zeros(shape)
}
