//! Bench: backend dispatch hot path. The reconstruction loop issues one
//! `unit_recon` dispatch per Adam step; its latency bounds the whole
//! calibration wall-clock (paper: 20 min for ResNet-18 on a 1080TI).
//! Also measures the fwd/eval paths and the literal marshalling overhead.

mod harness;

use brecq::eval::{forward, EvalParams};
use brecq::quant::mse_steps_per_channel;
use brecq::recon::{BitConfig, Calibrator};
use brecq::tensor::Tensor;
use harness::Harness;

fn main() {
    let mut h = Harness::from_args("bench_runtime");
    let env = harness::bench_env();
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 64, 0);

    // eval forward (batch = eval_batch)
    let p = EvalParams::fp(model, &ws, &bs);
    let eval_imgs = {
        // tile the 64-image calib set up to the eval batch
        let mut parts = Vec::new();
        let b = model.eval_batch;
        while parts.iter().map(|t: &Tensor| t.shape[0]).sum::<usize>() < b {
            parts.push(calib.images.clone());
        }
        Tensor::stack0(&parts).slice0(0, b)
    };
    let iters = h.iters(10);
    h.run("eval_fwd batch=eval", iters, || {
        let out = forward(&env.rt, model, &p, &eval_imgs).unwrap();
        std::hint::black_box(out.data[0]);
    });

    // unit_fwd advance of one block over 64 samples
    let unit = &model.gran("block").units[3];
    let bits = BitConfig::uniform(model, 4, None, true);
    let adv_imgs = images_for(unit, &calib.images);
    let iters = h.iters(10);
    h.run("unit_fwd s2.b0 batch=32 x2", iters, || {
        let z = cal
            .advance(unit, &adv_imgs, None, &ws, &bs, &vec![1.0; ws.len()],
                     &bits, false)
            .unwrap();
        std::hint::black_box(z.data[0]);
    });

    // FIM pass over 64 samples (2 batches)
    let iters = h.iters(3);
    h.run("fim_pass block 64 imgs", iters, || {
        let f = cal.fim_pass("block", &calib, &ws, &bs).unwrap();
        std::hint::black_box(f.len());
    });

    // literal marshalling: weight steps init (pure rust, no dispatch)
    let iters = h.iters(10);
    h.run("mse_steps_per_channel all layers", iters, || {
        for w in &ws {
            std::hint::black_box(mse_steps_per_channel(w, 4));
        }
    });

    h.finish();
}

/// The stream advance needs a main-activation tensor whose trailing shape
/// matches the unit input; to keep the bench self-contained we synthesize
/// a correctly-shaped activation (values don't matter for timing).
fn images_for(unit: &brecq::model::UnitInfo,
              images: &brecq::tensor::Tensor) -> brecq::tensor::Tensor {
    let mut shape = unit.in_shape.clone();
    shape[0] = images.shape[0];
    brecq::tensor::Tensor::zeros(shape)
}
