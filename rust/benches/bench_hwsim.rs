//! Bench: hardware-simulator throughput. The GA evaluates H(c) inside its
//! fitness loop (paper: whole search finishes in ~3s), so a single model
//! measurement must stay in the microsecond range.

mod harness;

use brecq::coordinator::Env;
use brecq::hwsim::{ArmCpu, HwMeasure, ModelSize, Systolic};
use harness::Bench;

fn main() {
    if !harness::artifacts_ready() {
        return;
    }
    let env = Env::bootstrap(None).unwrap();
    let model = env.model("resnet_s");
    let wbits = vec![4usize; model.layers.len()];

    let sim = Systolic::default();
    Bench::new("systolic.model_ms x1000").iters(20).run(|| {
        let mut acc = 0.0;
        for _ in 0..1000 {
            acc += sim.measure(model, &wbits, 8);
        }
        std::hint::black_box(acc);
    });

    let arm = ArmCpu::default();
    if ArmCpu::supports(model) {
        Bench::new("armcpu.model_ms x1000").iters(20).run(|| {
            let mut acc = 0.0;
            for _ in 0..1000 {
                acc += arm.measure(model, &wbits, 8);
            }
            std::hint::black_box(acc);
        });
    }

    let size = ModelSize;
    Bench::new("model_size x1000").iters(20).run(|| {
        let mut acc = 0.0;
        for _ in 0..1000 {
            acc += size.measure(model, &wbits, 8);
        }
        std::hint::black_box(acc);
    });
}
