//! Bench: hardware-simulator throughput. The GA evaluates H(c) inside its
//! fitness loop (paper: whole search finishes in ~3s), so a single model
//! measurement must stay in the microsecond range.

mod harness;

use brecq::hwsim::{ArmCpu, HwMeasure, ModelSize, Systolic};
use harness::Harness;

fn main() {
    let mut h = Harness::from_args("bench_hwsim");
    let env = harness::bench_env();
    let model = env.model("resnet_s");
    let wbits = vec![4usize; model.layers.len()];

    let sim = Systolic::default();
    let iters = h.iters(20);
    h.run("systolic.model_ms x1000", iters, || {
        let mut acc = 0.0;
        for _ in 0..1000 {
            acc += sim.measure(model, &wbits, 8);
        }
        std::hint::black_box(acc);
    });

    let arm = ArmCpu::default();
    if ArmCpu::supports(model) {
        let iters = h.iters(20);
        h.run("armcpu.model_ms x1000", iters, || {
            let mut acc = 0.0;
            for _ in 0..1000 {
                acc += arm.measure(model, &wbits, 8);
            }
            std::hint::black_box(acc);
        });
    }

    let size = ModelSize;
    let iters = h.iters(20);
    h.run("model_size x1000", iters, || {
        let mut acc = 0.0;
        for _ in 0..1000 {
            acc += size.measure(model, &wbits, 8);
        }
        std::hint::black_box(acc);
    });

    h.finish();
}
