//! Bench: the reconstruction step and a full mini-calibration — the paper's
//! headline production-cost claim (Table 4: ResNet-18 calibrated in 0.4 GPU
//! hours vs 100 for QAT; §3.3: "a quantized ResNet-18 within 20 minutes").
//! This regenerates the cost side of Table 4 on our substrate: calibration
//! wall-clock per model/config.

mod harness;

use brecq::coordinator::Env;
use brecq::recon::{BitConfig, Calibrator, ReconConfig};
use harness::Bench;

fn main() {
    if !harness::artifacts_ready() {
        return;
    }
    let env = Env::bootstrap(None).unwrap();
    let model = env.model("resnet_s");
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 64, 0);
    let cal = Calibrator::new(&env.rt, &env.mf, model);

    // end-to-end mini-calibration (8 units x 20 iters, 64 calib images)
    for (name, gran) in [("block", "block"), ("layer", "layer")] {
        let bits = BitConfig::uniform(model, 4, None, true);
        let cfg = ReconConfig {
            gran: gran.into(),
            iters: 20,
            ..ReconConfig::default()
        };
        Bench::new(&format!("calibrate 20it/unit gran={name}"))
            .iters(2)
            .run(|| {
                let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();
                std::hint::black_box(qm.weights.len());
            });
    }

    // per-dispatch cost of the hottest executable (largest recon unit)
    let units = &model.gran("block").units;
    for u in units.iter().take(3) {
        let sig = env.rt.signature(&u.recon_exe).unwrap().clone();
        // build a correctly-shaped argument set once; reuse across iters
        let args: Vec<brecq::tensor::Tensor> = sig
            .inputs
            .iter()
            .map(|(name, shape)| {
                if name.starts_with("wstep") || name.starts_with("astep") {
                    brecq::tensor::Tensor::full(shape.clone(), 0.05)
                } else if name.starts_with("wp") || name.starts_with("aqmax")
                {
                    brecq::tensor::Tensor::full(shape.clone(), 7.0)
                } else if name.starts_with("wn") {
                    brecq::tensor::Tensor::full(shape.clone(), -8.0)
                } else if name == "beta" {
                    brecq::tensor::Tensor::full(shape.clone(), 10.0)
                } else {
                    brecq::tensor::Tensor::zeros(shape.clone())
                }
            })
            .collect();
        let refs: Vec<&brecq::tensor::Tensor> = args.iter().collect();
        Bench::new(&format!("unit_recon dispatch [{}]", u.name))
            .iters(10)
            .run(|| {
                let out = env.rt.run(&u.recon_exe, &refs).unwrap();
                std::hint::black_box(out[0].data[0]);
            });
    }
}
