//! Bench: the reconstruction step and a full mini-calibration — the paper's
//! headline production-cost claim (Table 4: ResNet-18 calibrated in 0.4 GPU
//! hours vs 100 for QAT; §3.3: "a quantized ResNet-18 within 20 minutes").
//! This regenerates the cost side of Table 4 on our substrate, and also
//! measures the worker-pool speedup (1 vs 4 threads) on the same
//! end-to-end unit reconstruction — losses must be bit-identical (the
//! pool's determinism contract) and the speedup is what CI gates through
//! `scripts/check_bench.sh`.

//! Since the reconstruction-plan engine, the headline tentpole metric is
//! `recon_iters_per_sec`: fused `plan.step` throughput on the heaviest
//! block unit at 4 threads, gated by `scripts/check_bench.sh` (higher is
//! better, >25% regression fails). The per-dispatch rows are retained
//! for contrast — they measure the fallback parity path.

mod harness;

use brecq::calib::CalibSet;
use brecq::quant::{
    act_bounds, mse_steps_per_channel, weight_bounds, AdaRoundState,
};
use brecq::recon::{BitConfig, Calibrator, ReconConfig};
use brecq::runtime::plan;
use brecq::tensor::Tensor;
use brecq::util::pool;
use brecq::util::rng::Rng;
use harness::Harness;

fn main() {
    let mut h = Harness::from_args("bench_recon");
    let env = harness::bench_env();
    let model = env.model("resnet_s");
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 64, 0);
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    // plan counters are cumulative process-global atomics; snapshot now
    // and report end-of-run deltas so the notes attribute to this bench
    // alone regardless of what else the process ran
    let plan_c0 = plan::snapshot();

    // end-to-end mini-calibration (20 iters/unit, 64 calib images)
    for gran in ["block", "layer"] {
        let bits = BitConfig::uniform(model, 4, None, true);
        let cfg = ReconConfig {
            gran: gran.to_string(),
            iters: 20,
            ..ReconConfig::default()
        };
        let iters = h.iters(2);
        h.run(&format!("calibrate 20it/unit gran={gran}"), iters, || {
            let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();
            std::hint::black_box(qm.weights.len());
        });
    }

    // per-dispatch cost of the hottest executable (largest recon units)
    let units = &model.gran("block").units;
    for u in units.iter().take(3) {
        let sig = env.rt.signature(&u.recon_exe).unwrap().clone();
        // build a correctly-shaped argument set once; reuse across iters
        let args: Vec<brecq::tensor::Tensor> = sig
            .inputs
            .iter()
            .map(|(name, shape)| {
                if name.starts_with("wstep") || name.starts_with("astep") {
                    brecq::tensor::Tensor::full(shape.clone(), 0.05)
                } else if name.starts_with("wp") || name.starts_with("aqmax")
                {
                    brecq::tensor::Tensor::full(shape.clone(), 7.0)
                } else if name.starts_with("wn") {
                    brecq::tensor::Tensor::full(shape.clone(), -8.0)
                } else if name == "beta" {
                    brecq::tensor::Tensor::full(shape.clone(), 10.0)
                } else {
                    brecq::tensor::Tensor::zeros(shape.clone())
                }
            })
            .collect();
        let refs: Vec<&brecq::tensor::Tensor> = args.iter().collect();
        let iters = h.iters(10);
        h.run(&format!("unit_recon dispatch [{}]", u.name), iters, || {
            let out = env.rt.run(&u.recon_exe, &refs).unwrap();
            std::hint::black_box(out[0].data[0]);
        });
    }

    // plan-step throughput at 4 threads: the reconstruction-plan
    // engine's fused iteration (gather + soft-quant + fwd/bwd + gv
    // chain in one zero-alloc call), on the heaviest unit of every
    // plan-compiled granularity — the single-node block unit (whose
    // derived `recon_iters_per_sec` note is the gated tentpole metric)
    // plus the multi-node stage/net/pack seq programs.
    {
        pool::set_threads(4);
        let (ws, bs_all) = cal.fp_weights().unwrap();
        let heaviest = |gran: &str| {
            model
                .gran(gran)
                .units
                .iter()
                .max_by_key(|u| {
                    u.layer_ids
                        .iter()
                        .map(|&l| model.layers[l].macs)
                        .sum::<u64>()
                })
                .unwrap()
        };
        for gran in ["block", "stage", "net", "pack"] {
            let unit = heaviest(gran);
            let k = 64usize;
            let bsz = 32usize;
            let mut rng = Rng::new(42);
            let mut synth = |shape: &[usize]| -> Tensor {
                let mut shape = shape.to_vec();
                shape[0] = k;
                let n: usize = shape.iter().product();
                Tensor::new(
                    shape,
                    (0..n).map(|_| rng.gauss() as f32).collect(),
                )
            };
            let x = synth(&unit.in_shape);
            let z_fp = synth(&unit.out_shape);
            let mut fim_shape = unit.out_shape.clone();
            fim_shape[0] = k;
            let fim = Tensor::full(fim_shape, 1.0);
            let states: Vec<AdaRoundState> = unit
                .layer_ids
                .iter()
                .map(|&l| {
                    let steps = mse_steps_per_channel(&ws[l], 4);
                    AdaRoundState::init(&ws[l], &steps, 4)
                })
                .collect();
            let wsteps: Vec<Tensor> =
                states.iter().map(|s| s.steps_tensor()).collect();
            let vs: Vec<Tensor> =
                states.iter().map(|s| s.v.clone()).collect();
            let asteps: Vec<Tensor> = unit
                .layer_ids
                .iter()
                .map(|_| Tensor::scalar1(0.05))
                .collect();
            let inputs = plan::PlanInputs {
                x: &x,
                skip: None,
                z_fp: &z_fp,
                fim: Some(&fim),
                ws: unit.layer_ids.iter().map(|&l| &ws[l]).collect(),
                bs: unit.layer_ids.iter().map(|&l| &bs_all[l]).collect(),
                wsteps: wsteps.iter().collect(),
                wbounds: unit
                    .layer_ids
                    .iter()
                    .map(|_| weight_bounds(4))
                    .collect(),
                abounds: unit
                    .layer_ids
                    .iter()
                    .map(|&l| act_bounds(8, model.layers[l].site_signed))
                    .collect(),
                aq: false,
                batch: bsz,
            };
            let mut uplan = env
                .rt
                .prepare_recon(&unit.recon_exe, inputs)
                .unwrap()
                .expect("exported units compile to reconstruction plans");
            let mut srng = Rng::new(7);
            let iters = h.iters(if gran == "block" { 200 } else { 100 });
            // the block row keeps its historical name (the calibrated
            // baseline tracks it); multi-node rows carry their gran
            let label = if gran == "block" {
                format!("recon plan step [{}]", unit.name)
            } else {
                format!("recon plan step [{gran}:{}]", unit.name)
            };
            let ms = h.run(&label, iters, || {
                let rows = CalibSet::gather_rows_idx(k, bsz, &mut srng);
                let out =
                    uplan.step(&rows, &vs, &asteps, 10.0, 0.01).unwrap();
                std::hint::black_box(out.loss);
            });
            if gran == "block" {
                let min_ms =
                    ms.iter().cloned().fold(f64::INFINITY, f64::min);
                h.note("recon_iters_per_sec", 1e3 / min_ms);
            }
        }
    }

    // worker-pool speedup: identical end-to-end reconstruction at 1 vs 4
    // threads. Bit-identical losses are asserted, wall-clocks recorded.
    let bits = BitConfig::uniform(model, 4, None, true);
    let cfg = ReconConfig {
        iters: if h.quick { 10 } else { 20 },
        ..ReconConfig::default()
    };
    let runs = if h.quick { 2 } else { 3 };
    let time_at = |nt: usize| -> (f64, Vec<u64>) {
        pool::set_threads(nt);
        let mut best = f64::INFINITY;
        let mut losses = Vec::new();
        for _ in 0..runs {
            let t0 = std::time::Instant::now();
            let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();
            best = best.min(t0.elapsed().as_secs_f64());
            losses = qm
                .reports
                .iter()
                .map(|r| r.final_loss.to_bits())
                .collect();
        }
        (best, losses)
    };
    let (t1, l1) = time_at(1);
    let (t4, l4) = time_at(4);
    // zero-alloc steady state, end to end: with the arenas warm from the
    // timed runs, one more full calibration must serve every scratch
    // request from recycled buffers (tests/parallel.rs asserts the same
    // property per kernel; this reports it for Algorithm 1 whole).
    let (a0, _) = pool::scratch_counters();
    let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();
    std::hint::black_box(qm.weights.len());
    let (a1, _) = pool::scratch_counters();
    pool::set_threads(0);
    assert_eq!(l1, l4, "thread count changed reconstruction losses");
    h.note("recon_wall_s_1t", t1);
    h.note("recon_wall_s_4t", t4);
    h.note("recon_speedup_4t_over_1t", t1 / t4);
    h.note("steady_state_scratch_allocs", (a1 - a0) as f64);
    // plan-engine accounting: how much of this bench went through
    // compiled plans vs the per-dispatch fallback (delta since the
    // start-of-run snapshot — never the polluted process totals)
    let pd = plan::snapshot().since(&plan_c0);
    h.note("plan_builds_total", pd.builds as f64);
    h.note("plan_steps_total", pd.steps as f64);
    h.note("plan_fallback_steps_total", pd.fallback_steps as f64);
    h.finish();
}
