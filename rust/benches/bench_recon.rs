//! Bench: the reconstruction step and a full mini-calibration — the paper's
//! headline production-cost claim (Table 4: ResNet-18 calibrated in 0.4 GPU
//! hours vs 100 for QAT; §3.3: "a quantized ResNet-18 within 20 minutes").
//! This regenerates the cost side of Table 4 on our substrate, and also
//! measures the worker-pool speedup (1 vs 4 threads) on the same
//! end-to-end unit reconstruction — losses must be bit-identical (the
//! pool's determinism contract) and the speedup is what CI gates through
//! `scripts/check_bench.sh`.

mod harness;

use brecq::recon::{BitConfig, Calibrator, ReconConfig};
use brecq::util::pool;
use harness::Harness;

fn main() {
    let mut h = Harness::from_args("bench_recon");
    let env = harness::bench_env();
    let model = env.model("resnet_s");
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 64, 0);
    let cal = Calibrator::new(&env.rt, &env.mf, model);

    // end-to-end mini-calibration (20 iters/unit, 64 calib images)
    for gran in ["block", "layer"] {
        let bits = BitConfig::uniform(model, 4, None, true);
        let cfg = ReconConfig {
            gran: gran.to_string(),
            iters: 20,
            ..ReconConfig::default()
        };
        let iters = h.iters(2);
        h.run(&format!("calibrate 20it/unit gran={gran}"), iters, || {
            let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();
            std::hint::black_box(qm.weights.len());
        });
    }

    // per-dispatch cost of the hottest executable (largest recon units)
    let units = &model.gran("block").units;
    for u in units.iter().take(3) {
        let sig = env.rt.signature(&u.recon_exe).unwrap().clone();
        // build a correctly-shaped argument set once; reuse across iters
        let args: Vec<brecq::tensor::Tensor> = sig
            .inputs
            .iter()
            .map(|(name, shape)| {
                if name.starts_with("wstep") || name.starts_with("astep") {
                    brecq::tensor::Tensor::full(shape.clone(), 0.05)
                } else if name.starts_with("wp") || name.starts_with("aqmax")
                {
                    brecq::tensor::Tensor::full(shape.clone(), 7.0)
                } else if name.starts_with("wn") {
                    brecq::tensor::Tensor::full(shape.clone(), -8.0)
                } else if name == "beta" {
                    brecq::tensor::Tensor::full(shape.clone(), 10.0)
                } else {
                    brecq::tensor::Tensor::zeros(shape.clone())
                }
            })
            .collect();
        let refs: Vec<&brecq::tensor::Tensor> = args.iter().collect();
        let iters = h.iters(10);
        h.run(&format!("unit_recon dispatch [{}]", u.name), iters, || {
            let out = env.rt.run(&u.recon_exe, &refs).unwrap();
            std::hint::black_box(out[0].data[0]);
        });
    }

    // worker-pool speedup: identical end-to-end reconstruction at 1 vs 4
    // threads. Bit-identical losses are asserted, wall-clocks recorded.
    let bits = BitConfig::uniform(model, 4, None, true);
    let cfg = ReconConfig {
        iters: if h.quick { 10 } else { 20 },
        ..ReconConfig::default()
    };
    let runs = if h.quick { 2 } else { 3 };
    let time_at = |nt: usize| -> (f64, Vec<u64>) {
        pool::set_threads(nt);
        let mut best = f64::INFINITY;
        let mut losses = Vec::new();
        for _ in 0..runs {
            let t0 = std::time::Instant::now();
            let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();
            best = best.min(t0.elapsed().as_secs_f64());
            losses = qm
                .reports
                .iter()
                .map(|r| r.final_loss.to_bits())
                .collect();
        }
        (best, losses)
    };
    let (t1, l1) = time_at(1);
    let (t4, l4) = time_at(4);
    // zero-alloc steady state, end to end: with the arenas warm from the
    // timed runs, one more full calibration must serve every scratch
    // request from recycled buffers (tests/parallel.rs asserts the same
    // property per kernel; this reports it for Algorithm 1 whole).
    let (a0, _) = pool::scratch_counters();
    let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();
    std::hint::black_box(qm.weights.len());
    let (a1, _) = pool::scratch_counters();
    pool::set_threads(0);
    assert_eq!(l1, l4, "thread count changed reconstruction losses");
    h.note("recon_wall_s_1t", t1);
    h.note("recon_wall_s_4t", t4);
    h.note("recon_speedup_4t_over_1t", t1 / t4);
    h.note("steady_state_scratch_allocs", (a1 - a0) as f64);
    h.finish();
}
