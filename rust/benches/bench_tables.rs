//! Bench: end-to-end experiment-driver costs — one timed entry per paper
//! table/figure pipeline (reduced budgets; the full-budget runs live in
//! EXPERIMENTS.md). Regenerating a table is itself the workload here: these
//! timings are the "production cycle" the paper's PTQ-vs-QAT argument is
//! about.

mod harness;

use brecq::coordinator::experiments::{quantize_with, ExpOpts, Method};
use brecq::eval::{accuracy, EvalParams};
use brecq::recon::BitConfig;
use brecq::recon::Calibrator;
use brecq::sensitivity::Profiler;
use harness::Harness;

fn main() {
    let mut h = Harness::from_args("bench_tables");
    let env = harness::bench_env();
    let train = env.train_set().unwrap();
    let test = env.test_set().unwrap();
    let o = ExpOpts { iters: 30, calib_n: 64, ..ExpOpts::default() };
    let calib = env.calib(&train, o.calib_n, 0);

    // Table 1 cell: one granularity run (block, W2)
    let model = env.model("resnet_s");
    let iters = h.iters(3);
    h.run("table1-cell brecq block W2", iters, || {
        let bits = BitConfig::uniform(model, 2, None, true);
        let qm = quantize_with(&env, "resnet_s", Method::Brecq, &calib,
                               &bits, &o)
            .unwrap();
        let acc = accuracy(&env.rt, model, &EvalParams::quantized(&qm),
                           &test)
            .unwrap();
        std::hint::black_box(acc);
    });

    // Table 2 cell: one baseline run (OMSE W4 — data-free, fast path)
    let iters = h.iters(3);
    h.run("table2-cell omse W4", iters, || {
        let bits = BitConfig::uniform(model, 4, None, true);
        let qm = quantize_with(&env, "resnet_s", Method::Omse, &calib,
                               &bits, &o)
            .unwrap();
        std::hint::black_box(qm.weights.len());
    });

    // Table 3 cell: fully quantized run (W4A4)
    let iters = h.iters(3);
    h.run("table3-cell brecq W4A4", iters, || {
        let bits = BitConfig::uniform(model, 4, Some(4), true);
        let qm = quantize_with(&env, "resnet_s", Method::Brecq, &calib,
                               &bits, &o)
            .unwrap();
        std::hint::black_box(qm.act_steps[1]);
    });

    // Fig 2 pipeline stage: sensitivity LUT (diag only here; pairs in the
    // full run)
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let iters = h.iters(3);
    h.run("fig2-stage sensitivity diag", iters, || {
        let prof = Profiler { rt: &env.rt, mf: &env.mf, model };
        let t = prof.measure(&calib, &ws, &bs, false).unwrap();
        std::hint::black_box(t.base_loss);
    });

    h.finish();
}
