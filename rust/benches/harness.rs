//! Minimal bench harness (criterion is unavailable offline): warmup +
//! timed iterations, reporting mean/p50/p95/min via util::stats. Used by
//! every `[[bench]]` target with `harness = false`.

use std::time::Instant;

use brecq::util::stats;

pub struct Bench {
    pub name: String,
    pub warmup: usize,
    pub iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench { name: name.to_string(), warmup: 2, iters: 10 }
    }

    pub fn iters(mut self, n: usize) -> Bench {
        self.iters = n;
        self
    }

    /// Times `f` and prints a summary line; returns per-iter seconds.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Vec<f64> {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3); // ms
        }
        println!("bench {:<40} {} ms", self.name, stats::summary(&samples));
        samples
    }
}

/// Skip (but report) when artifacts are missing — benches must not fail the
/// build on a fresh checkout.
pub fn artifacts_ready() -> bool {
    let dir = std::env::var("BRECQ_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let ok = std::path::Path::new(&dir).join("manifest.json").exists();
    if !ok {
        println!("bench SKIPPED: no artifacts at {dir}/ (run `make artifacts`)");
    }
    ok
}
