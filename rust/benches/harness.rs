//! Minimal bench harness (criterion is unavailable offline): warmup +
//! timed iterations reporting mean/p50/p95/min via util::stats, a
//! `--quick` mode for CI smoke runs, and machine-readable JSON output
//! (`--json out.json`) consumed by the perf-regression gate
//! (`scripts/check_bench.sh` against the committed `BENCH_native.json`).
//!
//! Benches never skip: [`bench_env`] uses real artifacts when present
//! (`./artifacts` or `$BRECQ_ARTIFACTS`) and otherwise falls back to the
//! same hermetic synthetic environment the test suite runs on. A minimal
//! example of the artifact manifest format lives at
//! `rust/tests/fixtures/manifest.json`.

// Shared by every `[[bench]]` binary via `mod harness;` — not every
// binary uses every helper.
#![allow(dead_code)]

use std::time::Instant;

use brecq::coordinator::Env;
use brecq::util::json::{arr, num, obj, s, Json};
use brecq::util::{pool, stats};

pub struct Harness {
    bench: String,
    pub quick: bool,
    json_path: Option<String>,
    /// (name, iters, per-iter milliseconds)
    results: Vec<(String, usize, Vec<f64>)>,
    notes: Vec<(String, f64)>,
}

impl Harness {
    /// Parse bench argv: `--quick`, `--json PATH` and `--threads N`
    /// (pin the worker pool for ad-hoc runs; benches that measure
    /// specific thread counts — e.g. bench_recon's speedup section —
    /// still override it with `pool::set_threads`). Everything else
    /// (e.g. the `--bench` flag cargo forwards) is ignored.
    pub fn from_args(bench: &str) -> Harness {
        let mut quick = false;
        let mut json_path = None;
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            match a.as_str() {
                "--quick" => quick = true,
                "--json" => json_path = args.next(),
                "--threads" => {
                    let n = args
                        .next()
                        .and_then(|v| v.parse::<usize>().ok())
                        .unwrap_or(0);
                    pool::set_threads(n);
                }
                _ => {}
            }
        }
        Harness {
            bench: bench.to_string(),
            quick,
            json_path,
            results: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Iteration count for one bench: `full` normally, reduced in --quick.
    pub fn iters(&self, full: usize) -> usize {
        if self.quick {
            (full / 3).max(1)
        } else {
            full
        }
    }

    /// Time `f` over `iters` iterations (plus warmup); prints a summary
    /// line, records the samples for the JSON report, and returns the
    /// per-iter milliseconds.
    pub fn run<F: FnMut()>(
        &mut self,
        name: &str,
        iters: usize,
        mut f: F,
    ) -> Vec<f64> {
        let warmup = if self.quick { 1 } else { 2 };
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3); // ms
        }
        println!("bench {:<44} {} ms", name, stats::summary(&samples));
        self.results.push((name.to_string(), iters, samples.clone()));
        samples
    }

    /// Record a named scalar (speedups, wall-clock seconds) for the JSON
    /// report.
    pub fn note(&mut self, key: &str, v: f64) {
        println!("note  {key:<44} {v:.4}");
        self.notes.push((key.to_string(), v));
    }

    /// Write the JSON report if `--json` was given. Scratch-arena
    /// counters are appended to the notes automatically so every bench
    /// binary reports whether the kernels ran zero-alloc.
    pub fn finish(mut self) {
        let (allocs, reuses) = pool::scratch_counters();
        self.notes
            .push(("scratch_allocs_total".to_string(), allocs as f64));
        self.notes
            .push(("scratch_reuses_total".to_string(), reuses as f64));
        let Some(path) = self.json_path else { return };
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|(name, iters, ms)| {
                let min = ms.iter().cloned().fold(f64::INFINITY, f64::min);
                obj(vec![
                    ("name", s(name)),
                    ("iters", num(*iters as f64)),
                    ("mean_ms", num(stats::mean(ms))),
                    ("p50_ms", num(stats::percentile(ms, 50.0))),
                    ("min_ms", num(min)),
                ])
            })
            .collect();
        let notes: Vec<(&str, Json)> = self
            .notes
            .iter()
            .map(|(k, v)| (k.as_str(), num(*v)))
            .collect();
        let doc = obj(vec![
            ("schema", num(1.0)),
            ("bench", s(&self.bench)),
            ("calibrated", Json::Bool(true)),
            ("quick", Json::Bool(self.quick)),
            ("threads", num(pool::threads() as f64)),
            ("host_threads", num(host_threads() as f64)),
            ("results", arr(results)),
            ("notes", obj(notes)),
        ]);
        std::fs::write(&path, doc.to_string()).expect("write bench json");
        println!("bench json -> {path}");
    }
}

/// Hardware threads on this host (recorded so the perf gate can skip
/// speedup checks on under-provisioned machines).
pub fn host_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Bench environment: real artifacts when present, otherwise the hermetic
/// synthetic environment — benches always run on a fresh checkout.
pub fn bench_env() -> Env {
    Env::bootstrap(None).expect("bench environment")
}
