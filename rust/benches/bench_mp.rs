//! Bench: genetic mixed-precision search (Algorithm 2). Paper B.4.4: "the
//! genetic algorithm usually completes the evolution in only about 3
//! seconds" — this bench checks we're in that class (with the LUT already
//! measured, as in the paper's protocol).

mod harness;

use std::collections::HashMap;

use brecq::hwsim::{HwMeasure, ModelSize, Systolic};
use brecq::mp::{GaConfig, GeneticSearch};
use brecq::sensitivity::{intra_block_pairs, SensitivityTable};
use harness::Harness;

fn main() {
    let mut h = Harness::from_args("bench_mp");
    let env = harness::bench_env();
    let model = env.model("resnet_s");

    // synthetic-but-shaped LUT (measuring the real one needs calibration
    // dispatches; GA cost is independent of where the numbers came from)
    let diag = (0..model.layers.len())
        .map(|l| {
            let mut m = HashMap::new();
            m.insert(2usize, 0.1 + 0.01 * l as f64);
            m.insert(4usize, 0.01 + 0.001 * l as f64);
            m
        })
        .collect();
    let mut offdiag = HashMap::new();
    for (a, b) in intra_block_pairs(model) {
        offdiag.insert((a, b), 0.02);
    }
    let table = SensitivityTable { diag, offdiag, base_loss: 0.5 };

    let size = ModelSize;
    let full = size.measure(model, &vec![8; model.layers.len()], 8);
    let ga = GeneticSearch { model, table: &table, hw: &size, abits: 8,
                             budget: full * 0.5 };
    let iters = h.iters(5);
    h.run("ga.search pop=50 iters=100", iters, || {
        let r = ga.run(&GaConfig::default()).unwrap();
        std::hint::black_box(r.predicted_loss);
    });

    let sim = Systolic::default();
    let t8 = sim.measure(model, &vec![8; model.layers.len()], 8);
    let ga2 = GeneticSearch { model, table: &table, hw: &sim, abits: 8,
                              budget: t8 * 0.6 };
    let iters = h.iters(5);
    h.run("ga.search fpga-constrained", iters, || {
        let r = ga2.run(&GaConfig::default()).unwrap();
        std::hint::black_box(r.predicted_loss);
    });

    let iters = h.iters(5);
    h.run("pareto_greedy", iters, || {
        let r = ga.pareto_greedy().unwrap();
        std::hint::black_box(r.predicted_loss);
    });

    h.finish();
}
