//! Bench: artifact-store throughput — blob publish (atomic write-rename)
//! and warm load+decode at an FpWeights-sized payload, the store-hit
//! `get_or_build` path a warm session takes per stage, and the
//! end-to-end cold-vs-warm wall clock of one small BRECQ job (the number
//! the store exists to shrink). Warm replay is asserted compute-free so
//! the bench can't silently measure a recompute.

mod harness;

use std::sync::Arc;
use std::time::Instant;

use brecq::pipeline::{Artifact, ArtifactCache, ArtifactStore, FpWeights,
                      JobSpec, Session};
use brecq::tensor::Tensor;
use harness::Harness;

fn main() {
    let mut h = Harness::from_args("bench_store");
    let dir = std::env::temp_dir()
        .join(format!("brecq_bench_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // an FpWeights-shaped payload: 16 small conv layers (~150 KB)
    let ws: Vec<Tensor> = (0..16)
        .map(|i| {
            Tensor::new(
                vec![16, 16, 3, 3],
                vec![0.5 + i as f32 * 0.01; 16 * 16 * 3 * 3],
            )
        })
        .collect();
    let bs: Vec<Tensor> =
        (0..16).map(|_| Tensor::new(vec![16], vec![0.25; 16])).collect();
    let blob = FpWeights { ws, bs }.encode();
    h.note("store_entry_bytes", blob.payload_len() as f64);

    let store = ArtifactStore::open(&dir).unwrap();
    let mut k = 0usize;
    let iters = h.iters(30);
    h.run("store.publish fp-weights", iters, || {
        k += 1;
        store.publish(&format!("bench/pub/{k}"), &blob).unwrap();
    });

    store.publish("bench/warm", &blob).unwrap();
    let iters = h.iters(30);
    h.run("store.load+decode fp-weights", iters, || {
        let b = store.load("bench/warm").expect("warm entry present");
        let v = FpWeights::decode(&b).unwrap();
        std::hint::black_box(v.ws.len());
    });

    // the per-stage warm path: fresh cache (cold memory), warm disk —
    // lock, load, verify, decode
    let shared = Arc::new(ArtifactStore::open(&dir).unwrap());
    let iters = h.iters(30);
    h.run("cache.get_or_build store-hit", iters, || {
        let c = ArtifactCache::with_store(shared.clone());
        let v: Arc<FpWeights> = c
            .get_or_build("bench/warm", || unreachable!("warm key"))
            .unwrap();
        std::hint::black_box(v.ws.len());
    });

    // end-to-end: one small BRECQ job, cold store vs warm replay
    let job_dir = dir.join("jobs");
    let spec = JobSpec {
        wbits: 4,
        abits: Some(8),
        iters: 12,
        calib_n: 32,
        ..JobSpec::default()
    };
    let cold = Session::with_store(
        harness::bench_env(),
        Arc::new(ArtifactStore::open(&job_dir).unwrap()),
    );
    let t0 = Instant::now();
    cold.run(&spec).expect("cold job");
    h.note("store_cold_job_s", t0.elapsed().as_secs_f64());

    let warm = Session::with_store(
        harness::bench_env(),
        Arc::new(ArtifactStore::open(&job_dir).unwrap()),
    );
    let t0 = Instant::now();
    warm.run(&spec).expect("warm job");
    h.note("store_warm_job_s", t0.elapsed().as_secs_f64());
    assert_eq!(
        warm.cache().computes(),
        0,
        "warm replay recomputed — the bench would be measuring a lie"
    );

    let _ = std::fs::remove_dir_all(&dir);
    h.finish();
}
