//! Hardware performance models H(c) for mixed-precision search
//! (paper Appendix B.4.3).
//!
//! Three measurement functions over a per-layer bit assignment:
//!
//!  * `ModelSize` — weight bytes at the assigned precision (+f32 biases),
//!  * `Systolic`  — tile-level cycle simulation of the paper's self-built
//!    precision-scalable systolic accelerator: 16x16 MAC array whose peak
//!    throughput scales linearly as precision decreases (256 GMAC/s at
//!    8x8-bit up to 4 TMAC/s at 2x2-bit, via scalable function units à la
//!    BitFusion), a double-buffered on-chip buffer with bounded DRAM
//!    bandwidth, and the parallelism penalty for depthwise/group conv the
//!    appendix calls out ("the parallelism of the specific layer ... is
//!    limited"),
//!  * `ArmCpu`    — the redesigned low-bit GEMM latency model of Han et al.
//!    2020: no sub-8-bit ALUs on ARM, so compute does not speed up, but
//!    bit-packing cuts data movement, and lower bit-widths allow more
//!    accumulations into an 8-bit register before a 16-bit widening move.
//!    Like the paper's implementation it only supports normal convolution
//!    (depthwise/group layers are rejected), which is why Fig. 4 only shows
//!    ResNets.
//!
//! All simulators are deterministic functions of the manifest geometry —
//! they run inside the GA fitness loop, so they must be microsecond-fast.

use crate::model::{LayerInfo, ModelInfo};

/// A hardware measurement function H(c) (Eq. 11). `Sync` because the GA
/// evaluates populations concurrently on the worker pool; all simulators
/// are stateless geometry functions, so this is free.
pub trait HwMeasure: Sync {
    /// Cost of the model under per-layer weight bits `wbits` and uniform
    /// activation bits `abits`. Units: bytes (size) or milliseconds.
    fn measure(&self, model: &ModelInfo, wbits: &[usize], abits: usize)
        -> f64;
    fn unit(&self) -> &'static str;
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------
// Model size
// ---------------------------------------------------------------------

pub struct ModelSize;

impl HwMeasure for ModelSize {
    fn measure(&self, model: &ModelInfo, wbits: &[usize], _abits: usize)
        -> f64 {
        let mut bits: u64 = 0;
        for (l, layer) in model.layers.iter().enumerate() {
            let n: u64 = layer.wshape.iter().product::<usize>() as u64;
            bits += n * wbits[l] as u64;
            bits += layer.cout as u64 * 32; // biases kept f32
        }
        bits as f64 / 8.0
    }

    fn unit(&self) -> &'static str {
        "bytes"
    }

    fn name(&self) -> &'static str {
        "model-size"
    }
}

pub fn size_mb(model: &ModelInfo, wbits: &[usize]) -> f64 {
    ModelSize.measure(model, wbits, 8) / (1024.0 * 1024.0)
}

// ---------------------------------------------------------------------
// Precision-scalable systolic accelerator (FPGA)
// ---------------------------------------------------------------------

pub struct Systolic {
    /// MAC array geometry (rows = input-channel lanes, cols = out channels)
    pub rows: usize,
    pub cols: usize,
    /// clock in MHz -> 16x16 @ 1000 MHz = 256 GMAC/s at 8x8
    pub clock_mhz: f64,
    /// DRAM <-> on-chip buffer bandwidth, bytes/cycle
    pub dram_bpc: f64,
    /// fixed per-layer launch overhead, cycles
    pub launch_cycles: f64,
    /// spatial tile (output pixels per pass)
    pub tile_px: usize,
}

impl Default for Systolic {
    fn default() -> Self {
        Systolic {
            rows: 16,
            cols: 16,
            clock_mhz: 1000.0,
            dram_bpc: 8.0,
            launch_cycles: 2000.0,
            tile_px: 64,
        }
    }
}

impl Systolic {
    /// Precision-scaled MACs/cycle of the full array: peak 256 at 8x8,
    /// x2 per halved operand width (scalable function units).
    fn macs_per_cycle(&self, wbit: usize, abit: usize) -> f64 {
        (self.rows * self.cols) as f64 * (8.0 / wbit as f64)
            * (8.0 / abit as f64)
    }

    /// Cycle count for one layer (tile-level simulation).
    pub fn layer_cycles(&self, l: &LayerInfo, wbit: usize, abit: usize)
        -> f64 {
        let h_out = (l.h_in / l.stride).max(1);
        let w_out = (l.w_in / l.stride).max(1);
        let out_px = (h_out * w_out).max(1);
        let cin_g = (l.cin / l.groups).max(1);

        // array utilization: rows carry input-channel lanes (depthwise has
        // 1), cols carry output channels
        let row_util = (cin_g.min(self.rows)) as f64 / self.rows as f64;
        let col_util = (l.cout.min(self.cols)) as f64 / self.cols as f64;
        let util = (row_util * col_util).max(1e-3);

        let peak = self.macs_per_cycle(wbit, abit);
        let weight_bytes =
            l.wshape.iter().product::<usize>() as f64 * wbit as f64 / 8.0;

        // tiles over output pixels; weights stream once (double-buffered),
        // activations stream per tile
        let ntiles = (out_px + self.tile_px - 1) / self.tile_px;
        let macs_per_tile = l.macs as f64 / out_px as f64
            * self.tile_px.min(out_px) as f64;
        let act_in_bytes_tile = (self.tile_px.min(out_px)
            * l.stride
            * l.stride) as f64
            * l.cin as f64
            * abit as f64
            / 8.0;
        let act_out_bytes_tile =
            self.tile_px.min(out_px) as f64 * l.cout as f64 * abit as f64
                / 8.0;

        let mut cycles = self.launch_cycles;
        // weight fill overlaps the first tile only partially
        cycles += weight_bytes / self.dram_bpc;
        for _ in 0..ntiles {
            let compute = macs_per_tile / (peak * util);
            let mem =
                (act_in_bytes_tile + act_out_bytes_tile) / self.dram_bpc;
            cycles += compute.max(mem); // double buffering: overlap
        }
        cycles
    }

    pub fn model_ms(&self, model: &ModelInfo, wbits: &[usize], abits: usize)
        -> f64 {
        let total: f64 = model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| self.layer_cycles(l, wbits[i], abits))
            .sum();
        total / (self.clock_mhz * 1e3) // cycles @ MHz -> ms
    }
}

impl HwMeasure for Systolic {
    fn measure(&self, model: &ModelInfo, wbits: &[usize], abits: usize)
        -> f64 {
        self.model_ms(model, wbits, abits)
    }

    fn unit(&self) -> &'static str {
        "ms"
    }

    fn name(&self) -> &'static str {
        "systolic-fpga"
    }
}

// ---------------------------------------------------------------------
// ARM mobile CPU low-bit GEMM (Han et al. 2020 model)
// ---------------------------------------------------------------------

pub struct ArmCpu {
    /// effective MAC throughput of the NEON kernel, MMAC/s
    pub mmacs: f64,
    /// memory streaming bandwidth, MB/s
    pub mem_mbs: f64,
    /// per-layer call overhead, ms
    pub overhead_ms: f64,
}

impl Default for ArmCpu {
    fn default() -> Self {
        // Raspberry Pi 3B-class: quad A53 @1.2GHz
        ArmCpu { mmacs: 3200.0, mem_mbs: 1800.0, overhead_ms: 0.12 }
    }
}

impl ArmCpu {
    /// How many low-bit products fit an 8-bit accumulator before widening:
    /// products of w-bit x a-bit values need (w + a) bits headroom; the
    /// remaining 16-(w+a) bits allow 2^(16-w-a) accumulations per 16-bit
    /// lane vs 1 for 8x8 — modelled as a widening-traffic divisor.
    fn widen_divisor(wbit: usize, abit: usize) -> f64 {
        let head = 16i32 - (wbit + abit) as i32;
        2f64.powi(head.clamp(0, 6)) // 8x8 -> 1, 4x8 -> 16x fewer widens
    }

    pub fn layer_ms(&self, l: &LayerInfo, wbit: usize, abit: usize) -> f64 {
        assert!(
            l.groups == 1 || l.kind == "fc",
            "ArmCpu GEMM model supports normal convolution only (paper B.4.3)"
        );
        let weight_mb = l.wshape.iter().product::<usize>() as f64
            * wbit as f64
            / 8.0
            / 1e6;
        let h_out = (l.h_in / l.stride).max(1);
        let w_out = (l.w_in / l.stride).max(1);
        // im2col activation traffic (packed at abit)
        let act_mb = (h_out * w_out * l.cin * l.k * l.k) as f64
            * abit as f64
            / 8.0
            / 1e6
            + (h_out * w_out * l.cout) as f64 * abit as f64 / 8.0 / 1e6;
        // widening moves: one 8->16 transfer per `widen_divisor` MACs
        let widen_mb = l.macs as f64 * 2.0
            / Self::widen_divisor(wbit, abit)
            / 1e6;
        let mem_ms = (weight_mb + act_mb + widen_mb) / self.mem_mbs * 1e3;
        let compute_ms = l.macs as f64 / (self.mmacs * 1e6) * 1e3;
        self.overhead_ms + compute_ms.max(mem_ms)
    }

    pub fn model_ms(&self, model: &ModelInfo, wbits: &[usize], abits: usize)
        -> f64 {
        model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| self.layer_ms(l, wbits[i], abits))
            .sum()
    }

    /// The model only covers normal conv; callers must check first.
    pub fn supports(model: &ModelInfo) -> bool {
        model
            .layers
            .iter()
            .all(|l| l.groups == 1 || l.kind == "fc")
    }
}

impl HwMeasure for ArmCpu {
    fn measure(&self, model: &ModelInfo, wbits: &[usize], abits: usize)
        -> f64 {
        self.model_ms(model, wbits, abits)
    }

    fn unit(&self) -> &'static str {
        "ms"
    }

    fn name(&self) -> &'static str {
        "arm-cpu"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(name: &str, cin: usize, cout: usize, k: usize, stride: usize,
            groups: usize, hw: usize) -> LayerInfo {
        let macs = (hw / stride) * (hw / stride) * cout * (cin / groups)
            * k * k;
        LayerInfo {
            name: name.into(),
            kind: "conv".into(),
            cin,
            cout,
            k,
            stride,
            groups,
            relu: true,
            site_signed: false,
            h_in: hw,
            w_in: hw,
            macs: macs as u64,
            nparams: (cout * (cin / groups) * k * k + cout) as u64,
            wshape: vec![cout, cin / groups, k, k],
        }
    }

    fn toy_model() -> ModelInfo {
        ModelInfo {
            name: "toy".into(),
            fp_acc: 1.0,
            weights_prefix: String::new(),
            layers: vec![
                conv("a", 3, 16, 3, 1, 1, 32),
                conv("b", 16, 32, 3, 2, 1, 32),
                conv("c", 32, 32, 3, 1, 32, 16), // depthwise
            ],
            fwd_exe: String::new(),
            act_obs_exe: String::new(),
            eval_batch: 1,
            grans: Default::default(),
            qat_exe: None,
            qat_batch: 0,
            distill_exe: None,
            distill_batch: 0,
            task: crate::model::Task::Classify,
            dataset: None,
            det: None,
        }
    }

    #[test]
    fn size_scales_with_bits() {
        let m = toy_model();
        let s8 = ModelSize.measure(&m, &[8, 8, 8], 8);
        let s2 = ModelSize.measure(&m, &[2, 2, 2], 8);
        assert!(s2 < s8);
        // weight bits scale 4x; biases stay f32 so ratio is < 4
        let wbytes8: f64 = m
            .layers
            .iter()
            .map(|l| l.wshape.iter().product::<usize>() as f64)
            .sum();
        assert!((s8 - s2) * 8.0 / 6.0 - wbytes8 < 1.0);
    }

    #[test]
    fn systolic_lower_bits_faster() {
        let m = toy_model();
        let sim = Systolic::default();
        let t8 = sim.model_ms(&m, &[8, 8, 8], 8);
        let t4 = sim.model_ms(&m, &[4, 4, 4], 8);
        let t2 = sim.model_ms(&m, &[2, 2, 2], 4);
        assert!(t4 < t8, "{t4} vs {t8}");
        assert!(t2 < t4, "{t2} vs {t4}");
    }

    #[test]
    fn systolic_sublinear_scaling() {
        // memory/launch bounds prevent perfectly linear 4x speedup
        let m = toy_model();
        let sim = Systolic::default();
        let t8 = sim.model_ms(&m, &[8, 8, 8], 8);
        let t2 = sim.model_ms(&m, &[2, 2, 2], 8);
        assert!(t8 / t2 < 4.0, "speedup {}", t8 / t2);
        assert!(t8 / t2 > 1.2, "speedup {}", t8 / t2);
    }

    #[test]
    fn systolic_depthwise_penalty() {
        // depthwise layer has ~1/16 row utilization: cycles/MAC far higher
        let m = toy_model();
        let sim = Systolic::default();
        let dense = sim.layer_cycles(&m.layers[1], 8, 8)
            / m.layers[1].macs as f64;
        let dw =
            sim.layer_cycles(&m.layers[2], 8, 8) / m.layers[2].macs as f64;
        assert!(dw > dense * 2.0, "dw {dw} dense {dense}");
    }

    #[test]
    fn arm_lower_bits_faster_but_saturating() {
        let l = conv("x", 64, 64, 3, 1, 1, 16);
        let sim = ArmCpu::default();
        let t8 = sim.layer_ms(&l, 8, 8);
        let t4 = sim.layer_ms(&l, 4, 8);
        let t2 = sim.layer_ms(&l, 2, 8);
        assert!(t4 <= t8);
        assert!(t2 <= t4);
        // compute floor: gains stay below the 4x raw bit reduction
        assert!(t8 / t2 < 4.0, "{}", t8 / t2);
        assert!(t8 / t2 > 1.05, "{}", t8 / t2);
    }

    #[test]
    #[should_panic]
    fn arm_rejects_group_conv() {
        let l = conv("g", 32, 32, 3, 1, 4, 16);
        ArmCpu::default().layer_ms(&l, 8, 8);
    }

    #[test]
    fn arm_supports_check() {
        assert!(!ArmCpu::supports(&toy_model())); // has depthwise
    }

    #[test]
    fn mixed_between_uniform() {
        let m = toy_model();
        let sim = Systolic::default();
        let t8 = sim.model_ms(&m, &[8, 8, 8], 8);
        let t2 = sim.model_ms(&m, &[2, 2, 2], 8);
        let tm = sim.model_ms(&m, &[8, 2, 2], 8);
        assert!(tm < t8 && tm > t2);
    }
}
