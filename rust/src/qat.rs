//! LSQ quantization-aware training baseline (Table 4's cost comparison).
//!
//! Full-dataset QAT through the AOT `qat_step` executable: weights and
//! activations fake-quantized by LSQ with learnable per-tensor steps,
//! straight-through gradients (the Pallas lsq kernel's custom VJP), all
//! parameters updated by host-side Adam. This is deliberately the
//! *expensive* path — the point of Table 4 is that BRECQ reaches comparable
//! accuracy at a tiny fraction of this cost, so wall-clock is recorded.

use anyhow::Result;

use crate::calib::DataSet;
use crate::model::{Manifest, ModelInfo};
use crate::optim::Adam;
use crate::quant::{act_bounds, mse_step_tensor, weight_bounds};
use crate::recon::{BitConfig, Calibrator, QuantizedModel};
use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct QatConfig {
    pub steps: usize,
    pub lr_w: f32,
    pub lr_s: f32,
    pub wbits: usize,
    pub abits: usize,
    pub seed: u64,
    pub verbose: bool,
}

impl Default for QatConfig {
    fn default() -> Self {
        QatConfig {
            steps: 600,
            lr_w: 5e-4,
            lr_s: 1e-3,
            wbits: 4,
            abits: 4,
            seed: 0,
            verbose: false,
        }
    }
}

pub struct QatResult {
    pub model: QuantizedModel,
    pub train_seconds: f64,
    pub steps: usize,
    pub images_seen: usize,
}

/// Run LSQ QAT on the full training set; returns deployable quantized
/// weights (hard LSQ rounding of the trained FP weights).
pub fn train(
    rt: &dyn Backend,
    mf: &Manifest,
    model: &ModelInfo,
    trainset: &DataSet,
    cfg: &QatConfig,
) -> Result<QatResult> {
    let exe = model
        .qat_exe
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("{}: no qat executable", model.name))?;
    let b = model.qat_batch;
    let nl = model.layers.len();
    let classes = mf.dataset.classes;
    let t0 = std::time::Instant::now();

    let cal = Calibrator::new(rt, mf, model);
    let (mut ws, mut bs) = cal.fp_weights()?;

    // per-tensor weight steps (LSQ init) + activation steps from stats
    let mut wsteps: Vec<Tensor> = ws
        .iter()
        .map(|w| {
            let (n, p) = weight_bounds(cfg.wbits);
            Tensor::scalar1(mse_step_tensor(&w.data, n, p))
        })
        .collect();
    let bits = BitConfig::uniform(model, cfg.wbits, Some(cfg.abits), true);
    let calib_like = trainset_as_calib(trainset, 512);
    let mut asteps_f = cal.init_act_steps(&calib_like, &ws, &bs, &bits, 4)?;
    let mut asteps: Vec<Tensor> =
        asteps_f.iter().map(|&s| Tensor::scalar1(s)).collect();

    let (wqmin, wqmax) = weight_bounds(cfg.wbits);
    let wqmin_t = Tensor::scalar1(wqmin);
    let wqmax_t = Tensor::scalar1(wqmax);
    let abounds: Vec<(Tensor, Tensor)> = model
        .layers
        .iter()
        .map(|l| {
            let (lo, hi) = act_bounds(cfg.abits, l.site_signed);
            (Tensor::scalar1(lo), Tensor::scalar1(hi))
        })
        .collect();

    let sizes: Vec<usize> = ws
        .iter()
        .map(|w| w.numel())
        .chain(bs.iter().map(|x| x.numel()))
        .collect();
    let mut opt_w = Adam::new(cfg.lr_w, &sizes);
    let mut opt_s = Adam::new(cfg.lr_s, &vec![1usize; 2 * nl]);

    let mut rng = Rng::new(cfg.seed);
    let n = trainset.len();
    let mut images_seen = 0;
    for t in 0..cfg.steps {
        let rows = rng.sample_indices(n, b);
        let images = gather_images(trainset, &rows);
        let onehot = onehot_rows(trainset, &rows, classes);
        let mut args: Vec<&Tensor> = vec![&images, &onehot];
        for l in 0..nl {
            args.push(&ws[l]);
            args.push(&bs[l]);
        }
        for l in 0..nl {
            args.push(&wsteps[l]);
            args.push(&asteps[l]);
            args.push(&abounds[l].0);
            args.push(&abounds[l].1);
        }
        args.push(&wqmin_t);
        args.push(&wqmax_t);
        let out = rt.run(exe, &args)?;
        // outputs: loss, gw*nl, gb*nl, gwstep*nl, gastep*nl
        let loss = out[0].data[0];
        let gw = &out[1..1 + nl];
        let gb = &out[1 + nl..1 + 2 * nl];
        let gws = &out[1 + 2 * nl..1 + 3 * nl];
        let gas = &out[1 + 3 * nl..1 + 4 * nl];
        {
            let mut params: Vec<&mut Tensor> = ws
                .iter_mut()
                .chain(bs.iter_mut())
                .collect();
            let grads: Vec<&Tensor> = gw.iter().chain(gb.iter()).collect();
            opt_w.step(&mut params, &grads);
        }
        {
            let mut params: Vec<&mut Tensor> = wsteps
                .iter_mut()
                .chain(asteps.iter_mut())
                .collect();
            let grads: Vec<&Tensor> = gws.iter().chain(gas.iter()).collect();
            opt_s.step(&mut params, &grads);
            for p in wsteps.iter_mut().chain(asteps.iter_mut()) {
                p.data[0] = p.data[0].max(1e-6);
            }
        }
        images_seen += b;
        if cfg.verbose && t % 100 == 0 {
            eprintln!("  [qat {}] step {t} loss {loss:.4}", model.name);
        }
    }

    // deploy: hard LSQ rounding of the trained weights
    let weights: Vec<Tensor> = ws
        .iter()
        .enumerate()
        .map(|(l, w)| {
            let s = wsteps[l].data[0];
            w.map(|x| s * (x / s).round().clamp(wqmin, wqmax))
        })
        .collect();
    for l in 0..nl {
        asteps_f[l] = asteps[l].data[0];
    }
    Ok(QatResult {
        model: QuantizedModel {
            weights,
            biases: bs,
            act_steps: asteps_f,
            bits,
            reports: vec![],
            calib_seconds: t0.elapsed().as_secs_f64(),
        },
        train_seconds: t0.elapsed().as_secs_f64(),
        steps: cfg.steps,
        images_seen,
    })
}

fn gather_images(ds: &DataSet, rows: &[usize]) -> Tensor {
    let inner = ds.images.inner();
    let mut data = Vec::with_capacity(rows.len() * inner);
    for &r in rows {
        data.extend_from_slice(&ds.images.data[r * inner..(r + 1) * inner]);
    }
    let mut shape = ds.images.shape.clone();
    shape[0] = rows.len();
    Tensor::new(shape, data)
}

fn onehot_rows(ds: &DataSet, rows: &[usize], classes: usize) -> Tensor {
    let mut data = vec![0f32; rows.len() * classes];
    for (i, &r) in rows.iter().enumerate() {
        data[i * classes + ds.labels[r]] = 1.0;
    }
    Tensor::new(vec![rows.len(), classes], data)
}

fn trainset_as_calib(ds: &DataSet, k: usize) -> crate::calib::CalibSet {
    crate::calib::CalibSet {
        images: ds.images.slice0(0, k.min(ds.len())),
        labels: ds.labels[..k.min(ds.len())].to_vec(),
    }
}
