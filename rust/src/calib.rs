//! Calibration data pipeline: raster loading, standardization, batching.
//!
//! The datasets are u8 NHWC rasters (see python/compile/dataset.py); this
//! module converts them to the standardized NCHW f32 layout the executables
//! expect, holds the calibration subset (the paper uses 1024 train images)
//! and the test set, and serves deterministic batch views.

use std::path::Path;

use anyhow::{bail, Result};

use crate::model::DatasetInfo;
use crate::store::load_u8;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct DataSet {
    pub images: Tensor, // (N, 3, H, W) standardized
    pub labels: Vec<usize>,
}

impl DataSet {
    /// `which` is "train" or "test".
    pub fn load(info: &DatasetInfo, which: &str) -> Result<DataSet> {
        let n = match which {
            "train" => info.train_n,
            "test" => info.test_n,
            _ => bail!("unknown split {which}"),
        };
        let img = info.img;
        let x = load_u8(&Path::new(&info.dir).join(format!("{which}_x.bin")))?;
        let y = load_u8(&Path::new(&info.dir).join(format!("{which}_y.bin")))?;
        if x.len() != n * img * img * 3 || y.len() != n {
            bail!(
                "dataset size mismatch: {} vs {} / {} vs {}",
                x.len(),
                n * img * img * 3,
                y.len(),
                n
            );
        }
        // u8 HWC -> standardized f32 CHW
        let mut images = vec![0f32; n * 3 * img * img];
        for i in 0..n {
            for h in 0..img {
                for w in 0..img {
                    for c in 0..3 {
                        let v = x[((i * img + h) * img + w) * 3 + c] as f32
                            / 255.0;
                        let v = (v - info.mean[c]) / info.std[c];
                        images[((i * 3 + c) * img + h) * img + w] = v;
                    }
                }
            }
        }
        Ok(DataSet {
            images: Tensor::new(vec![n, 3, img, img], images),
            labels: y.iter().map(|&v| v as usize).collect(),
        })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Contiguous batch view (copies — executables need owned literals).
    pub fn batch(&self, start: usize, len: usize) -> Tensor {
        self.images.slice0(start, len)
    }

    /// The calibration subset: `k` images sampled without replacement.
    pub fn calib_subset(&self, k: usize, rng: &mut Rng) -> CalibSet {
        let idx = rng.sample_indices(self.len(), k);
        let inner = self.images.inner();
        let mut data = Vec::with_capacity(k * inner);
        let mut labels = Vec::with_capacity(k);
        for &i in &idx {
            data.extend_from_slice(
                &self.images.data[i * inner..(i + 1) * inner],
            );
            labels.push(self.labels[i]);
        }
        let mut shape = self.images.shape.clone();
        shape[0] = k;
        CalibSet {
            images: Tensor::new(shape, data),
            labels,
        }
    }
}

/// The calibration working set (paper: 1024 images). Also constructible
/// directly from distilled data (ZeroQ path).
pub struct CalibSet {
    pub images: Tensor, // (K, 3, H, W)
    pub labels: Vec<usize>,
}

impl CalibSet {
    pub fn len(&self) -> usize {
        self.images.shape[0]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn batch(&self, start: usize, len: usize) -> Tensor {
        self.images.slice0(start, len)
    }

    /// One-hot labels for a batch (classes from the logits width).
    pub fn onehot(&self, start: usize, len: usize, classes: usize) -> Tensor {
        let mut data = vec![0f32; len * classes];
        for (r, &lab) in self.labels[start..start + len].iter().enumerate() {
            data[r * classes + lab] = 1.0;
        }
        Tensor::new(vec![len, classes], data)
    }

    /// Random batch of `len` sample indices (with replacement across calls,
    /// without within a batch) — the reconstruction loop's sampler.
    pub fn random_batch_rows(&self, len: usize, rng: &mut Rng) -> Vec<usize> {
        rng.sample_indices(self.len(), len)
    }

    /// Gather rows of a cached activation tensor into a batch (the
    /// allocating sibling of [`Tensor::gather_rows_into`]; no zero-fill
    /// — every element is appended exactly once).
    pub fn gather_rows(src: &Tensor, rows: &[usize]) -> Tensor {
        let inner = src.inner();
        let mut data = Vec::with_capacity(rows.len() * inner);
        for &r in rows {
            data.extend_from_slice(&src.data[r * inner..(r + 1) * inner]);
        }
        let mut shape = src.shape.clone();
        shape[0] = rows.len();
        Tensor::new(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_rows_picks_rows() {
        let src = Tensor::new(vec![4, 2], vec![0., 1., 2., 3., 4., 5., 6., 7.]);
        let g = CalibSet::gather_rows(&src, &[3, 0]);
        assert_eq!(g.shape, vec![2, 2]);
        assert_eq!(g.data, vec![6., 7., 0., 1.]);
    }

    #[test]
    fn onehot_layout() {
        let cs = CalibSet {
            images: Tensor::zeros(vec![3, 1, 1, 1]),
            labels: vec![2, 0, 1],
        };
        let oh = cs.onehot(0, 3, 4);
        assert_eq!(oh.shape, vec![3, 4]);
        assert_eq!(
            oh.data,
            vec![0., 0., 1., 0., 1., 0., 0., 0., 0., 1., 0., 0.]
        );
    }
}
