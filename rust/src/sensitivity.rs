//! Sensitivity profiling for mixed precision (paper §3.4).
//!
//! The paper's loss model has a diagonal part (each layer's own sensitivity,
//! as in HAWQ/ZeroQ) plus an *intra-block off-diagonal* part — the
//! cross-layer terms the block-diagonal Hessian keeps. We measure both
//! empirically on the calibration set:
//!
//!   s_l(b)    = L(layer l at b-bit, rest 8-bit) - L(all 8-bit)
//!   o_{l,m}   = L(l & m at 2-bit) - L0 - s_l(2) - s_m(2)   (same block)
//!
//! and store them in a lookup table the GA fitness consults (the paper:
//! "the sensitivity ... will be stored in a lookup table. When calculating
//! the fitness value ... we will check the lookup table"). 2-bit-only pair
//! terms, as in the paper ("we only take 2-bit permutations into
//! consideration").
//!
//! Measuring the LUT is the most expensive stage of a mixed-precision job
//! (2·L diagonal + intra-block pair probes over the calibration set), so
//! [`crate::pipeline::Session`] caches it content-keyed — every search job
//! in a session that agrees on (model, data source, calib size, seed)
//! shares one measurement.

use std::collections::HashMap;

use anyhow::Result;

use crate::calib::CalibSet;
use crate::eval::{calib_loss, EvalParams};
use crate::model::{Manifest, ModelInfo};
use crate::quant::{mse_steps_per_channel, quantize_nearest};
use crate::recon::BitConfig;
use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::pool;

#[derive(Debug, Clone)]
pub struct SensitivityTable {
    /// s[layer][bit] -> loss increase (bits 2 and 4 measured; 8 = 0)
    pub diag: Vec<HashMap<usize, f64>>,
    /// intra-block 2-bit interaction terms keyed by (layer_lo, layer_hi)
    pub offdiag: HashMap<(usize, usize), f64>,
    pub base_loss: f64,
}

impl SensitivityTable {
    /// Predicted calibration loss of a per-layer bit assignment (Eq. 11
    /// fitness): base + Σ diag + Σ intra-block 2-bit pair terms.
    pub fn predict(&self, wbits: &[usize]) -> f64 {
        let mut loss = self.base_loss;
        for (l, &b) in wbits.iter().enumerate() {
            if b < 8 {
                loss += self.diag[l].get(&b).copied().unwrap_or(0.0);
            }
        }
        for (&(a, b), &o) in &self.offdiag {
            if wbits[a] == 2 && wbits[b] == 2 {
                loss += o;
            }
        }
        loss
    }
}

/// Pack-PTQ grouping (PAPERS.md): partition `nb` adjacent blocks into
/// packs by greedy adjacent merge. `diag[i]` is block i's own 2-bit
/// sensitivity, `coupling[i]` the measured interaction between blocks i
/// and i+1 (`err({i,i+1}) - diag[i] - diag[i+1]`, the FIM/Hessian
/// off-block term BRECQ's block-diagonal assumption drops). Blocks i
/// and i+1 fall into the same pack when the interaction is at least
/// `tau` of the smaller diagonal term; `max_len` caps pack length so a
/// coupling chain cannot degenerate into whole-net reconstruction.
/// Returns contiguous, ordered, covering ranges — a valid partition by
/// construction.
pub fn group_packs(
    diag: &[f64],
    coupling: &[f64],
    tau: f64,
    max_len: usize,
) -> Vec<std::ops::Range<usize>> {
    let nb = diag.len();
    assert!(
        nb == 0 || coupling.len() == nb - 1,
        "group_packs: {} blocks need {} coupling terms, got {}",
        nb,
        nb.saturating_sub(1),
        coupling.len()
    );
    assert!(max_len >= 1, "group_packs: max_len must be >= 1");
    let mut packs = Vec::new();
    let mut start = 0usize;
    for i in 0..nb {
        let len = i + 1 - start;
        let merge_next = i + 1 < nb && len < max_len && {
            let floor = diag[i].min(diag[i + 1]).max(f64::MIN_POSITIVE);
            coupling[i] > tau * floor
        };
        if !merge_next {
            packs.push(start..i + 1);
            start = i + 1;
        }
    }
    packs
}

/// Layer pairs that share a reconstruction block (block granularity units).
pub fn intra_block_pairs(model: &ModelInfo) -> Vec<(usize, usize)> {
    let mut pairs = Vec::new();
    if let Some(g) = model.grans.get("block") {
        for u in &g.units {
            for i in 0..u.layer_ids.len() {
                for j in i + 1..u.layer_ids.len() {
                    let (a, b) = (u.layer_ids[i], u.layer_ids[j]);
                    pairs.push((a.min(b), a.max(b)));
                }
            }
        }
    }
    pairs
}

pub struct Profiler<'a> {
    pub rt: &'a dyn Backend,
    pub mf: &'a Manifest,
    pub model: &'a ModelInfo,
}

impl<'a> Profiler<'a> {
    /// Measure the table. `ws`/`bs` are FP deploy weights; quantization in
    /// the probes is nearest-rounding with per-channel MSE steps (the
    /// paper measures sensitivity on the calibrated quantizers; nearest
    /// rounding is the data-free proxy and preserves the ordering).
    pub fn measure(
        &self,
        calib: &CalibSet,
        ws: &[Tensor],
        bs: &[Tensor],
        with_offdiag: bool,
    ) -> Result<SensitivityTable> {
        let nl = self.model.layers.len();
        // pre-quantize every layer at 2/4/8
        let mut q: Vec<HashMap<usize, Tensor>> = Vec::with_capacity(nl);
        for l in 0..nl {
            let mut m = HashMap::new();
            for bits in [2usize, 4, 8] {
                let steps = mse_steps_per_channel(&ws[l], bits);
                m.insert(bits, quantize_nearest(&ws[l], &steps, bits));
            }
            q.push(m);
        }
        let loss_with = |assign: &dyn Fn(usize) -> usize| -> Result<f64> {
            let weights: Vec<Tensor> = (0..nl)
                .map(|l| q[l][&assign(l)].clone())
                .collect();
            let p = EvalParams {
                weights: &weights,
                biases: bs,
                act_steps: vec![1.0; nl],
                bits: BitConfig::uniform(self.model, 8, None, false),
                aq: false,
            };
            calib_loss(self.rt, self.mf, self.model, &p, calib)
        };

        let base_loss = loss_with(&|_| 8)?;
        let mut diag: Vec<HashMap<usize, f64>> =
            (0..nl).map(|_| HashMap::new()).collect();
        // every probe is an independent eval stream over the frozen
        // pre-quantized weights — dispatch them concurrently on the pool
        // and fold results in probe order (deterministic LUT)
        let macs: u64 = self.model.layers.iter().map(|l| l.macs).sum();
        let probe_work = (macs as usize).saturating_mul(calib.len());
        let probes: Vec<(usize, usize)> =
            (0..nl).flat_map(|l| [(l, 2usize), (l, 4)]).collect();
        let work = probe_work.saturating_mul(probes.len());
        let per = pool::par_fill(probes.len(), 1, work, |i| {
            let (l, bits) = probes[i];
            loss_with(&|j| if j == l { bits } else { 8 })
        });
        for ((l, bits), r) in probes.iter().zip(per) {
            diag[*l].insert(*bits, (r? - base_loss).max(0.0));
        }

        let mut offdiag = HashMap::new();
        if with_offdiag {
            let pairs = intra_block_pairs(self.model);
            let work = probe_work.saturating_mul(pairs.len());
            let per = pool::par_fill(pairs.len(), 1, work, |i| {
                let (a, b) = pairs[i];
                loss_with(&|j| if j == a || j == b { 2 } else { 8 })
            });
            for ((a, b), r) in pairs.iter().zip(per) {
                let o = r? - base_loss - diag[*a][&2] - diag[*b][&2];
                offdiag.insert((*a, *b), o);
            }
        }

        Ok(SensitivityTable { diag, offdiag, base_loss })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SensitivityTable {
        let mut d0 = HashMap::new();
        d0.insert(2, 1.0);
        d0.insert(4, 0.1);
        let mut d1 = HashMap::new();
        d1.insert(2, 0.5);
        d1.insert(4, 0.05);
        let mut off = HashMap::new();
        off.insert((0, 1), 0.25);
        SensitivityTable { diag: vec![d0, d1], offdiag: off, base_loss: 2.0 }
    }

    #[test]
    fn predict_diag_only() {
        let t = table();
        assert!((t.predict(&[8, 8]) - 2.0).abs() < 1e-12);
        assert!((t.predict(&[4, 8]) - 2.1).abs() < 1e-12);
        assert!((t.predict(&[2, 8]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn predict_includes_pair_term_only_when_both_2bit() {
        let t = table();
        assert!((t.predict(&[2, 2]) - (2.0 + 1.0 + 0.5 + 0.25)).abs()
            < 1e-12);
        assert!((t.predict(&[2, 4]) - (2.0 + 1.0 + 0.05)).abs() < 1e-12);
    }

    #[test]
    fn group_packs_merges_only_coupled_neighbors() {
        // strong coupling between 0-1, none between 1-2 or 2-3
        let diag = [1.0, 1.0, 1.0, 1.0];
        let coupling = [0.5, 0.0, -0.1];
        let p = group_packs(&diag, &coupling, 0.05, 4);
        assert_eq!(p, vec![0..2, 2..3, 3..4]);
    }

    #[test]
    fn group_packs_uncoupled_is_identity_partition() {
        let diag = [1.0, 2.0, 3.0];
        let coupling = [0.0, 0.0];
        let p = group_packs(&diag, &coupling, 0.05, 4);
        assert_eq!(p, vec![0..1, 1..2, 2..3]);
    }

    #[test]
    fn group_packs_respects_max_len() {
        // everything coupled, but packs cap at 2
        let diag = [1.0; 5];
        let coupling = [10.0; 4];
        let p = group_packs(&diag, &coupling, 0.05, 2);
        assert_eq!(p, vec![0..2, 2..4, 4..5]);
    }

    #[test]
    fn group_packs_covers_and_orders() {
        let diag = [0.3, 0.1, 0.9, 0.2, 0.4, 0.6];
        let coupling = [0.02, 0.5, -0.3, 0.011, 0.0];
        for tau in [0.0, 0.05, 0.5, 10.0] {
            let p = group_packs(&diag, &coupling, tau, 3);
            let mut next = 0usize;
            for r in &p {
                assert_eq!(r.start, next, "contiguous at tau={tau}");
                assert!(r.end > r.start);
                next = r.end;
            }
            assert_eq!(next, diag.len(), "covering at tau={tau}");
        }
    }

    #[test]
    fn group_packs_degenerate_sizes() {
        assert_eq!(group_packs(&[], &[], 0.05, 4), Vec::<_>::new());
        assert_eq!(group_packs(&[1.0], &[], 0.05, 4), vec![0..1]);
        // max_len 1 forces singletons regardless of coupling
        let p = group_packs(&[1.0, 1.0], &[100.0], 0.05, 1);
        assert_eq!(p, vec![0..1, 1..2]);
    }
}
