//! The BRECQ calibration engine (paper Algorithm 1) — the L3 system core.
//!
//! Orchestrates, unit by unit at the chosen reconstruction granularity:
//!
//!   1. a FIM pass over the calibration set (squared per-sample gradients
//!      of the task loss at every unit output — the diagonal pre-activation
//!      Fisher of Eq. 9/10),
//!   2. a dual activation stream: the FP stream provides reconstruction
//!      targets z_fp; the quantized stream provides unit inputs x (the
//!      asymmetric-reconstruction choice of the reference implementation),
//!   3. per-unit optimization: T Adam steps on the AdaRound rounding
//!      variables and LSQ activation steps, with β-annealed rounding
//!      regularization — driven by a compiled reconstruction plan
//!      ([`crate::runtime::plan`]: the unit lowered once, zero-alloc
//!      fused steps) when the backend offers one, and by per-iteration
//!      `unit_recon` dispatches (the retained bit-parity reference)
//!      otherwise,
//!   4. hard-rounding commit, then stream advance through `unit_fwd`.
//!
//! Per-layer bitwidths are runtime inputs to the executables, so the same
//! artifacts serve unified 2/4/8-bit, first/last-8-bit policies and every
//! mixed-precision configuration the GA proposes.
//!
//! The whole-calibration-set passes — the dual activation streams
//! (`advance`), the FIM pass and the act-obs step init — dispatch their
//! independent calibration batches concurrently on [`crate::util::pool`]
//! (`Backend` is `Sync`). Batch results are stitched in index order, so
//! calibration is bit-identical at any `BRECQ_THREADS` value.
//!
//! This module is the engine; the typed front door is
//! [`crate::pipeline`] — the CLI and examples never construct a
//! [`Calibrator`] directly, they submit a `JobSpec` to a `Session`, which
//! drives this engine and caches the shared inputs (FP weights,
//! calibration sets) across jobs.

use anyhow::Result;

use crate::calib::CalibSet;
use crate::model::{Manifest, ModelInfo, UnitInfo};
use crate::optim::{Adam, BetaSchedule};
use crate::quant::{
    act_bounds, mse_steps_per_channel, weight_bounds, AdaRoundState,
};
use crate::runtime::{plan, Backend};
use crate::tensor::Tensor;
use crate::util::cancel::CancelToken;
use crate::util::faults;
use crate::util::pool;
use crate::util::rng::Rng;

/// Per-layer bit assignment (weights + activation sites).
#[derive(Debug, Clone)]
pub struct BitConfig {
    pub wbits: Vec<usize>,
    pub abits: Vec<usize>,
    pub aq: bool, // activation quantization enabled
}

impl BitConfig {
    /// Uniform precision, optionally keeping first & last layer at 8-bit
    /// (the paper's default policy, §4.2).
    pub fn uniform(
        model: &ModelInfo,
        wbits: usize,
        abits: Option<usize>,
        first_last_8: bool,
    ) -> BitConfig {
        let n = model.layers.len();
        let mut w = vec![wbits; n];
        let mut a = vec![abits.unwrap_or(8); n];
        if first_last_8 {
            w[model.first_layer()] = 8;
            w[model.last_layer()] = 8;
            a[model.first_layer()] = 8;
            a[model.last_layer()] = 8;
        }
        BitConfig { wbits: w, abits: a, aq: abits.is_some() }
    }

    /// Mixed precision: explicit per-layer weight bits.
    pub fn mixed(wbits: Vec<usize>, abits: usize, aq: bool) -> BitConfig {
        let n = wbits.len();
        BitConfig { wbits, abits: vec![abits; n], aq }
    }
}

/// One completed unit of Algorithm 1, frozen for resume: the committed
/// hard-rounded weights and learned activation steps for the unit's
/// layers (unit order), the unit report (its losses feed
/// `JobOutput::fingerprint()`, so it must round-trip bitwise), and the
/// post-unit RNG snapshot ([`Rng::state`]) so the next unit draws the
/// exact calibration rows it would have drawn uninterrupted.
///
/// Activation streams are deliberately *not* stored: on resume they are
/// recomputed by advancing `unit_fwd` with the restored weights — a
/// deterministic, thread-invariant function of the checkpointed state —
/// which keeps checkpoints small (weights, not K-sample activations).
#[derive(Debug, Clone)]
pub struct UnitCheckpoint {
    pub qweights: Vec<Tensor>,
    pub act_steps: Vec<f32>,
    pub report: UnitReport,
    pub rng: [u64; 6],
}

/// Per-unit checkpoint sink/source for resumable reconstruction. The
/// engine stays storage-agnostic: [`crate::pipeline`] installs a
/// store-backed implementation keyed under the recon cache key; with no
/// hook installed (benches, direct `Calibrator` use) the cost is one
/// `Option` branch per unit.
pub trait UnitCheckpointer: Send + Sync {
    /// Checkpoint for unit `ui`, or `None` on miss/corruption. `unit`
    /// and `layers` let the implementation reject an entry that does
    /// not match the unit it claims to be (counted as corrupt, never
    /// applied). Invalid entries are discarded so only that unit is
    /// recomputed.
    fn load(
        &self,
        ui: usize,
        unit: &str,
        layers: usize,
    ) -> Option<UnitCheckpoint>;
    /// Publish the checkpoint for unit `ui`. Best-effort: failures are
    /// logged by the implementation and never fail the calibration.
    fn save(&self, ui: usize, ckpt: &UnitCheckpoint);
}

/// Optional checkpointer slot on [`ReconConfig`] — a newtype so the
/// config keeps deriving `Debug`/`Clone` around the trait object.
#[derive(Clone, Default)]
pub struct CkptHook(pub Option<std::sync::Arc<dyn UnitCheckpointer>>);

impl std::fmt::Debug for CkptHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.0.is_some() {
            "CkptHook(installed)"
        } else {
            "CkptHook(none)"
        })
    }
}

#[derive(Debug, Clone)]
pub struct ReconConfig {
    pub gran: String,
    pub iters: usize,
    pub batch: usize,
    pub lr_v: f32,
    pub lr_s: f32,
    pub lam: f32,
    /// FIM weighting (BRECQ). false => plain MSE (AdaRound/AdaQuant proxies)
    pub use_fim: bool,
    /// rounding regularizer on (AdaRound-style). false => AdaQuant-like
    /// continuous optimization committed by thresholding.
    pub round_reg: bool,
    /// Drive the inner loop through a compiled reconstruction plan
    /// ([`crate::runtime::plan`]) when the backend offers one. false
    /// forces the per-iteration dispatch path — the bit-parity reference
    /// (`tests/plan.rs` compares the two). Results are identical either
    /// way.
    pub plan: bool,
    pub seed: u64,
    pub verbose: bool,
    /// Cooperative cancellation scope, checked at unit and iteration
    /// boundaries. The default inert token costs one branch per check.
    pub cancel: CancelToken,
    /// Per-unit checkpoint hook for resumable reconstruction (default
    /// none — checkpointing off).
    pub ckpt: CkptHook,
}

impl Default for ReconConfig {
    fn default() -> Self {
        ReconConfig {
            gran: "block".into(),
            iters: 800,
            batch: 32,
            lr_v: 3e-3,
            lr_s: 1e-3,
            lam: 0.01,
            use_fim: true,
            round_reg: true,
            plan: true,
            seed: 0,
            verbose: false,
            cancel: CancelToken::none(),
            ckpt: CkptHook(None),
        }
    }
}

#[derive(Debug, Clone)]
pub struct UnitReport {
    pub name: String,
    pub initial_loss: f64,
    pub final_loss: f64,
    pub soft_fraction_before_commit: f64,
    pub iters: usize,
    pub seconds: f64,
}

/// A calibrated model: hard-quantized weights + learned activation steps.
#[derive(Debug, Clone)]
pub struct QuantizedModel {
    pub weights: Vec<Tensor>, // per layer, model order
    pub biases: Vec<Tensor>,
    pub act_steps: Vec<f32>,
    pub bits: BitConfig,
    pub reports: Vec<UnitReport>,
    pub calib_seconds: f64,
}

pub struct Calibrator<'a> {
    pub rt: &'a dyn Backend,
    pub mf: &'a Manifest,
    pub model: &'a ModelInfo,
}

impl<'a> Calibrator<'a> {
    pub fn new(
        rt: &'a dyn Backend,
        mf: &'a Manifest,
        model: &'a ModelInfo,
    ) -> Calibrator<'a> {
        Calibrator { rt, mf, model }
    }

    /// Load FP deploy weights in model-layer order.
    pub fn fp_weights(&self) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let store = self.mf.load_weights(self.model)?;
        let mut ws = Vec::new();
        let mut bs = Vec::new();
        for l in &self.model.layers {
            ws.push(store.get(&format!("{}.w", l.name)).clone());
            bs.push(store.get(&format!("{}.b", l.name)).clone());
        }
        Ok((ws, bs))
    }

    /// Activation-step init via the `act_obs` executable: LSQ-style
    /// s = 2 E|x| / sqrt(qmax), observed on a few calibration batches
    /// (dispatched concurrently; per-batch stats fold in batch order).
    pub fn init_act_steps(
        &self,
        calib: &CalibSet,
        ws: &[Tensor],
        bs: &[Tensor],
        bits: &BitConfig,
        nbatches: usize,
    ) -> Result<Vec<f32>> {
        let b = self.mf.calib_batch;
        let nb = nbatches.min(calib.len() / b).max(1);
        let nl = self.model.layers.len();
        let mut meanabs = vec![0f64; nl];
        let exe = &self.model.act_obs_exe;
        let work = self.model_work(nb * b);
        let per_batch =
            pool::par_fill(nb, 1, work, |i| -> Result<Vec<f64>> {
                let images = calib.batch(i * b, b);
                let mut args: Vec<&Tensor> = vec![&images];
                for l in 0..nl {
                    args.push(&ws[l]);
                    args.push(&bs[l]);
                }
                let out = self.rt.run(exe, &args)?;
                // [maxabs, meanabs] per layer
                Ok(out.iter().map(|t| t.data[1] as f64).collect())
            });
        for r in per_batch {
            let batch_means: Vec<f64> = r?;
            for (l, m) in batch_means.into_iter().enumerate() {
                meanabs[l] += m;
            }
        }
        let mut steps = Vec::with_capacity(nl);
        for (l, layer) in self.model.layers.iter().enumerate() {
            let (_, qmax) = act_bounds(bits.abits[l], layer.site_signed);
            let m = (meanabs[l] / nb as f64) as f32;
            steps.push((2.0 * m / qmax.max(1.0).sqrt()).max(1e-5));
        }
        Ok(steps)
    }

    /// FIM pass: squared per-sample task-loss gradients at every unit
    /// output of the granularity (Eq. 10 weights). Returns one (K, ...)
    /// cache per unit. Calibration batches are independent, so they
    /// dispatch concurrently and stitch in batch order.
    pub fn fim_pass(
        &self,
        gran: &str,
        calib: &CalibSet,
        ws: &[Tensor],
        bs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        let g = self.model.try_gran(gran)?;
        let b = self.mf.calib_batch;
        let k = calib.len();
        assert!(k % b == 0, "calib size must be a multiple of {b}");
        let classes = self.mf.dataset_for(self.model).classes;
        let mut parts: Vec<Vec<Tensor>> =
            (0..g.units.len()).map(|_| Vec::new()).collect();
        let work = self.model_work(k).saturating_mul(3);
        let per_batch =
            pool::par_fill(k / b, 1, work, |i| -> Result<Vec<Tensor>> {
                let images = calib.batch(i * b, b);
                // detection models feed regression-target rows through
                // the onehot slot (the seed becomes (logits - target),
                // see runtime::native::fim_walk)
                let onehot = match &self.model.det {
                    Some(det) => {
                        det.target_rows(&calib.labels[i * b..(i + 1) * b])
                    }
                    None => calib.onehot(i * b, b, classes),
                };
                let mut args: Vec<&Tensor> = vec![&images, &onehot];
                for l in 0..self.model.layers.len() {
                    args.push(&ws[l]);
                    args.push(&bs[l]);
                }
                let grads = self.rt.run(&g.fim_exe, &args)?;
                // diagonal FIM: elementwise squared gradients
                Ok(grads.into_iter().map(|gt| gt.map(|x| x * x)).collect())
            });
        for r in per_batch {
            for (u, gt) in r?.into_iter().enumerate() {
                parts[u].push(gt);
            }
        }
        // Normalize each unit's FIM to mean 1 and bound the weights.
        // Only the *relative* weighting matters in Eq. 10, and raw squared
        // batch-mean gradients are O(1/B^2) small — unnormalized they sink
        // below Adam's epsilon and reconstruction degenerates to nearest
        // rounding. The clamp is a substrate adaptation (documented in
        // DESIGN.md §Substrate adaptations, repo root): our FP models sit
        // near 100% train accuracy, so
        // per-sample CE gradients are extremely heavy-tailed — a handful of
        // boundary samples would dominate Eq. 10 and collapse the effective
        // calibration-set size (measured: W2 resnet_s 30% unclamped vs 94%
        // MSE). Bounded weights keep the Fisher ordering while every sample
        // still contributes.
        Ok(parts
            .iter()
            .map(|p| {
                let t = Tensor::stack0(p);
                let mean = (t.data.iter().map(|&x| x as f64).sum::<f64>()
                    / t.numel() as f64)
                    .max(1e-30) as f32;
                t.map(|x| (x / mean).clamp(0.25, 4.0))
            })
            .collect())
    }

    /// Full BRECQ calibration (Algorithm 1).
    pub fn calibrate(
        &self,
        calib: &CalibSet,
        bits: &BitConfig,
        cfg: &ReconConfig,
    ) -> Result<QuantizedModel> {
        let t_start = std::time::Instant::now();
        if let Some(reason) = cfg.cancel.cancelled() {
            anyhow::bail!("cancelled before calibration: {reason}");
        }
        let (ws, bs) = self.fp_weights()?;
        let nl = self.model.layers.len();
        let b = self.mf.calib_batch;
        let k = calib.len();
        assert!(k % b == 0, "calib size {k} must be a multiple of {b}");
        let nbatch = k / b;
        let mut rng = Rng::new(cfg.seed);

        // weight quantizer init (per-channel MSE steps + AdaRound v)
        let mut states: Vec<AdaRoundState> = (0..nl)
            .map(|l| {
                let steps = mse_steps_per_channel(&ws[l], bits.wbits[l]);
                AdaRoundState::init(&ws[l], &steps, bits.wbits[l])
            })
            .collect();

        // activation steps (learned during recon when aq is on)
        let mut act_steps = if bits.aq {
            self.init_act_steps(calib, &ws, &bs, bits, 4)?
        } else {
            vec![1.0; nl]
        };

        // FIM caches (or unit MSE weights); the granularity string is
        // validated here — an unknown/undeclared one is a typed error,
        // never a silent fallback
        let gran = self.model.try_gran(&cfg.gran)?;
        if let Some(reason) = cfg.cancel.cancelled() {
            anyhow::bail!("cancelled before FIM pass: {reason}");
        }
        let fim = if cfg.use_fim {
            Some(self.fim_pass(&cfg.gran, calib, &ws, &bs)?)
        } else {
            None
        };

        // dual activation streams over the whole calibration set
        let mut fp_main = calib.images.clone();
        let mut q_main = calib.images.clone();
        let mut fp_skip: Option<Tensor> = None;
        let mut q_skip: Option<Tensor> = None;

        let mut qweights: Vec<Tensor> = ws.clone(); // committed as we go
        let mut reports = Vec::new();

        for (ui, unit) in gran.units.iter().enumerate() {
            if let Some(reason) = cfg.cancel.cancelled() {
                anyhow::bail!(
                    "cancelled at unit '{}': {reason}",
                    unit.name
                );
            }
            // Resume probe: a valid checkpoint replays this unit's
            // committed result instead of reconstructing it. A miss,
            // a checksum failure or a mismatched entry (all handled
            // inside the hook) falls through to the live path, so a
            // corrupt checkpoint costs exactly one recomputed unit.
            let restored = cfg
                .ckpt
                .0
                .as_deref()
                .and_then(|h| h.load(ui, &unit.name, unit.layer_ids.len()));
            if restored.is_none() {
                // Fault-injection site: lets the chaos harness fail or
                // panic mid-reconstruction, between committed units.
                match faults::check("job.recon") {
                    Some(faults::Kind::Panic) => panic!(
                        "injected panic at job.recon (unit '{}')",
                        unit.name
                    ),
                    Some(k) => anyhow::bail!(
                        "injected {} fault at job.recon (unit '{}')",
                        k.as_str(),
                        unit.name
                    ),
                    None => {}
                }
            }
            if unit.save_skip {
                fp_skip = Some(fp_main.clone());
                q_skip = Some(q_main.clone());
            }
            // FP targets for this unit. On the resume path this runs
            // before the checkpointed act steps are applied — the same
            // pre-reconstruction ordering as the live path, so the FP
            // stream is bit-identical either way.
            let z_fp = self.advance(
                unit, &fp_main, fp_skip.as_ref(), &ws, &bs, &act_steps,
                bits, false,
            )?;

            if let Some(c) = restored {
                // Apply the committed result and the post-unit RNG
                // snapshot; the quantized stream is recomputed below by
                // advancing with the restored weights (deterministic,
                // thread-invariant — see UnitCheckpoint docs).
                for (i, &l) in unit.layer_ids.iter().enumerate() {
                    qweights[l] = c.qweights[i].clone();
                    act_steps[l] = c.act_steps[i];
                }
                rng = Rng::from_state(c.rng);
                reports.push(c.report);
            } else {
                // no FIM clone: the reconstruction borrows the per-unit
                // cache; None means unit weight (plain MSE) inside the
                // loss
                let unit_fim: Option<&Tensor> =
                    fim.as_ref().map(|f| &f[ui]);

                let report = self.reconstruct_unit(
                    unit, &q_main, q_skip.as_ref(), &z_fp, unit_fim, &ws,
                    &bs, &mut states, &mut act_steps, bits, cfg, &mut rng,
                    nbatch,
                )?;

                // commit hard-rounded weights for this unit's layers
                for &l in &unit.layer_ids {
                    qweights[l] = states[l].commit(&ws[l]);
                }
                // checkpoint the committed unit (best-effort) before
                // the streams advance: everything after this point is
                // recomputable from the checkpoint alone
                if let Some(h) = cfg.ckpt.0.as_deref() {
                    h.save(
                        ui,
                        &UnitCheckpoint {
                            qweights: unit
                                .layer_ids
                                .iter()
                                .map(|&l| qweights[l].clone())
                                .collect(),
                            act_steps: unit
                                .layer_ids
                                .iter()
                                .map(|&l| act_steps[l])
                                .collect(),
                            report: report.clone(),
                            rng: rng.state(),
                        },
                    );
                }
                reports.push(report);
            }
            // advance the quantized stream with the committed weights
            let q_next = self.advance(
                unit, &q_main, q_skip.as_ref(), &qweights, &bs, &act_steps,
                bits, bits.aq,
            )?;
            fp_main = z_fp;
            q_main = q_next;
            if unit.uses_skip {
                fp_skip = None;
                q_skip = None;
            }
            if cfg.verbose {
                let r = reports.last().unwrap();
                eprintln!(
                    "  [{}] unit {:<12} loss {:.3e} -> {:.3e}  ({:.1}s)",
                    self.model.name, r.name, r.initial_loss, r.final_loss,
                    r.seconds
                );
            }
        }

        Ok(QuantizedModel {
            weights: qweights,
            biases: bs,
            act_steps,
            bits: bits.clone(),
            reports,
            calib_seconds: t_start.elapsed().as_secs_f64(),
        })
    }

    /// Run `unit_fwd` over the whole K-sample stream in calib batches.
    /// Batches are independent, so they dispatch concurrently on the
    /// worker pool and stitch in batch order — bit-identical to the
    /// sequential walk.
    #[allow(clippy::too_many_arguments)]
    pub fn advance(
        &self,
        unit: &UnitInfo,
        main: &Tensor,
        skip: Option<&Tensor>,
        ws: &[Tensor],
        bs: &[Tensor],
        act_steps: &[f32],
        bits: &BitConfig,
        aq: bool,
    ) -> Result<Tensor> {
        let b = self.mf.calib_batch;
        let k = main.shape[0];
        let flag = Tensor::scalar1(if aq { 1.0 } else { 0.0 });
        // per-site scalars
        let scalars = self.site_scalars(unit, act_steps, bits);
        let work = self.unit_work(unit, k);
        let per_batch =
            pool::par_fill(k / b, 1, work, |i| -> Result<Tensor> {
                let xb = main.slice0(i * b, b);
                let skb = skip.map(|s| s.slice0(i * b, b));
                let mut args: Vec<&Tensor> = vec![&xb];
                if unit.uses_skip {
                    args.push(skb.as_ref().unwrap());
                }
                for &l in &unit.layer_ids {
                    args.push(&ws[l]);
                    args.push(&bs[l]);
                }
                for (st, lo, hi) in &scalars {
                    args.push(st);
                    args.push(lo);
                    args.push(hi);
                }
                args.push(&flag);
                let mut out = self.rt.run(&unit.fwd_exe, &args)?;
                Ok(out.remove(0))
            });
        let mut outs = Vec::with_capacity(k / b);
        for r in per_batch {
            outs.push(r?);
        }
        Ok(Tensor::stack0(&outs))
    }

    /// Scalar-work estimate for streaming `samples` images through the
    /// whole model (pool fan-out heuristic).
    fn model_work(&self, samples: usize) -> usize {
        let macs: u64 = self.model.layers.iter().map(|l| l.macs).sum();
        (macs as usize).saturating_mul(samples)
    }

    /// Scalar-work estimate for one unit over `samples` images.
    fn unit_work(&self, unit: &UnitInfo, samples: usize) -> usize {
        let macs: u64 = unit
            .layer_ids
            .iter()
            .map(|&l| self.model.layers[l].macs)
            .sum();
        (macs as usize).saturating_mul(samples)
    }

    fn site_scalars(
        &self,
        unit: &UnitInfo,
        act_steps: &[f32],
        bits: &BitConfig,
    ) -> Vec<(Tensor, Tensor, Tensor)> {
        unit.layer_ids
            .iter()
            .map(|&l| {
                let layer = &self.model.layers[l];
                let (lo, hi) = act_bounds(bits.abits[l], layer.site_signed);
                (
                    Tensor::scalar1(act_steps[l]),
                    Tensor::scalar1(lo),
                    Tensor::scalar1(hi),
                )
            })
            .collect()
    }

    /// T Adam iterations on one unit (step 3 of the pipeline).
    ///
    /// The loop runs on a compiled reconstruction plan
    /// ([`crate::runtime::plan`]) when the backend offers one
    /// (`cfg.plan`, the default): the unit is lowered once and each
    /// iteration is a single `plan.step(rows, vs, asteps, beta, lam)`
    /// call with zero steady-state allocation. Otherwise — `cfg.plan`
    /// off, plan-less backends, or units the backend declines (seq
    /// units) — every iteration dispatches the `unit_recon` executable
    /// with the full ~10·nl argument binding: the retained path, and
    /// the bit-parity reference the plan must reproduce exactly.
    #[allow(clippy::too_many_arguments)]
    fn reconstruct_unit(
        &self,
        unit: &UnitInfo,
        x_cache: &Tensor,
        skip_cache: Option<&Tensor>,
        z_fp: &Tensor,
        fim: Option<&Tensor>,
        ws: &[Tensor],
        bs: &[Tensor],
        states: &mut [AdaRoundState],
        act_steps: &mut [f32],
        bits: &BitConfig,
        cfg: &ReconConfig,
        rng: &mut Rng,
        _nbatch: usize,
    ) -> Result<UnitReport> {
        let t0 = std::time::Instant::now();
        let bsz = cfg.batch.min(x_cache.shape[0]);
        let nl = unit.layer_ids.len();
        let sched = BetaSchedule::brecq_default(cfg.iters);

        // trainable: v per layer, act step per site
        let mut vs: Vec<Tensor> = unit
            .layer_ids
            .iter()
            .map(|&l| states[l].v.clone())
            .collect();
        let mut asteps: Vec<Tensor> = unit
            .layer_ids
            .iter()
            .map(|&l| Tensor::scalar1(act_steps[l]))
            .collect();
        let mut opt_v = Adam::for_params(
            cfg.lr_v,
            &vs.iter().collect::<Vec<_>>(),
        );
        let mut opt_s = Adam::for_params(
            cfg.lr_s,
            &asteps.iter().collect::<Vec<_>>(),
        );

        // frozen per-layer inputs
        let wsteps: Vec<Tensor> = unit
            .layer_ids
            .iter()
            .map(|&l| states[l].steps_tensor())
            .collect();
        let wbounds: Vec<(Tensor, Tensor)> = unit
            .layer_ids
            .iter()
            .map(|&l| {
                let (n, p) = weight_bounds(bits.wbits[l]);
                (Tensor::scalar1(n), Tensor::scalar1(p))
            })
            .collect();
        let abounds: Vec<(Tensor, Tensor)> = unit
            .layer_ids
            .iter()
            .map(|&l| {
                let layer = &self.model.layers[l];
                let (lo, hi) = act_bounds(bits.abits[l], layer.site_signed);
                (Tensor::scalar1(lo), Tensor::scalar1(hi))
            })
            .collect();
        let aq_flag = Tensor::scalar1(if bits.aq { 1.0 } else { 0.0 });

        // compile the unit once (plan path). The plan borrows the frozen
        // caches and per-layer constants for the whole loop.
        let mut plan_box = if cfg.plan {
            let inputs = plan::PlanInputs {
                x: x_cache,
                skip: skip_cache,
                z_fp,
                fim,
                ws: unit.layer_ids.iter().map(|&l| &ws[l]).collect(),
                bs: unit.layer_ids.iter().map(|&l| &bs[l]).collect(),
                wsteps: wsteps.iter().collect(),
                wbounds: unit
                    .layer_ids
                    .iter()
                    .map(|&l| weight_bounds(bits.wbits[l]))
                    .collect(),
                abounds: unit
                    .layer_ids
                    .iter()
                    .map(|&l| {
                        let layer = &self.model.layers[l];
                        act_bounds(bits.abits[l], layer.site_signed)
                    })
                    .collect(),
                aq: bits.aq,
                batch: bsz,
            };
            self.rt.prepare_recon(&unit.recon_exe, inputs)?
        } else {
            None
        };

        // dispatch fallback without a FIM cache: one bsz-sized all-ones
        // tensor satisfies the executable ABI for every iteration
        // (gathering all-ones rows is the identity), replacing the old
        // K-sized materialization; multiplying by 1.0 is exact, so the
        // losses match the plan's implicit unit weight bitwise.
        let ones_fb = if plan_box.is_none() && fim.is_none() {
            let mut shape = z_fp.shape.clone();
            shape[0] = bsz;
            Some(Tensor::full(shape, 1.0))
        } else {
            None
        };

        let mut initial_loss = 0.0;
        let mut final_loss = 0.0;
        for t in 0..cfg.iters {
            if let Some(reason) = cfg.cancel.cancelled() {
                anyhow::bail!(
                    "cancelled at unit '{}' iteration {t}: {reason}",
                    unit.name
                );
            }
            let rows = CalibSet::gather_rows_idx(x_cache.shape[0], bsz, rng);
            let (beta, reg_on) = sched.at(t);
            let lam = if cfg.round_reg && reg_on { cfg.lam } else { 0.0 };
            let rec_loss: f64;

            if let Some(p) = plan_box.as_deref_mut() {
                // fused iteration: gather + soft-quant + fwd/bwd + gv
                // chain in one call, zero steady-state allocation
                let s = p.step(&rows, &vs, &asteps, beta, lam)?;
                rec_loss = s.rec as f64;
                {
                    let mut prefs: Vec<&mut Tensor> =
                        vs.iter_mut().collect();
                    let grefs: Vec<&Tensor> = p.gv().iter().collect();
                    opt_v.step(&mut prefs, &grefs);
                }
                if bits.aq {
                    let mut prefs: Vec<&mut Tensor> =
                        asteps.iter_mut().collect();
                    let grefs: Vec<&Tensor> = p.gsteps().iter().collect();
                    opt_s.step(&mut prefs, &grefs);
                    for st in asteps.iter_mut() {
                        st.data[0] = st.data[0].max(1e-6);
                    }
                }
            } else {
                plan::note_fallback_step();
                let xb = CalibSet::gather_rows(x_cache, &rows);
                let skb =
                    skip_cache.map(|s| CalibSet::gather_rows(s, &rows));
                let zb = CalibSet::gather_rows(z_fp, &rows);
                let fb_gathered =
                    fim.map(|f| CalibSet::gather_rows(f, &rows));
                let fb: &Tensor = fb_gathered
                    .as_ref()
                    .unwrap_or_else(|| {
                        ones_fb.as_ref().expect("MSE fallback ones")
                    });
                let beta_t = Tensor::scalar1(beta);
                let lam_t = Tensor::scalar1(lam);

                let mut args: Vec<&Tensor> = vec![&xb];
                if unit.uses_skip {
                    args.push(skb.as_ref().unwrap());
                }
                args.push(&zb);
                args.push(fb);
                for (i, &l) in unit.layer_ids.iter().enumerate() {
                    args.push(&ws[l]);
                    args.push(&bs[l]);
                    args.push(&wsteps[i]);
                    args.push(&vs[i]);
                    args.push(&wbounds[i].0);
                    args.push(&wbounds[i].1);
                }
                for (i, _) in unit.layer_ids.iter().enumerate() {
                    args.push(&asteps[i]);
                    args.push(&abounds[i].0);
                    args.push(&abounds[i].1);
                }
                args.push(&beta_t);
                args.push(&lam_t);
                args.push(&aq_flag);

                let out = self.rt.run(&unit.recon_exe, &args)?;
                // outputs: loss, rec_loss, round_loss, gv*nl, gastep*nl
                rec_loss = out[1].data[0] as f64;
                let gv = &out[3..3 + nl];
                let gs = &out[3 + nl..3 + 2 * nl];
                {
                    let mut prefs: Vec<&mut Tensor> =
                        vs.iter_mut().collect();
                    let grefs: Vec<&Tensor> = gv.iter().collect();
                    opt_v.step(&mut prefs, &grefs);
                }
                if bits.aq {
                    let mut prefs: Vec<&mut Tensor> =
                        asteps.iter_mut().collect();
                    let grefs: Vec<&Tensor> = gs.iter().collect();
                    opt_s.step(&mut prefs, &grefs);
                    for st in asteps.iter_mut() {
                        st.data[0] = st.data[0].max(1e-6); // keep positive
                    }
                }
            }
            if t == 0 {
                initial_loss = rec_loss;
            }
            final_loss = rec_loss;
        }

        // write back learned state
        let mut soft = 0.0;
        for (i, &l) in unit.layer_ids.iter().enumerate() {
            states[l].v = vs[i].clone();
            soft += states[l].soft_fraction();
            act_steps[l] = asteps[i].data[0];
        }
        Ok(UnitReport {
            name: unit.name.clone(),
            initial_loss,
            final_loss,
            soft_fraction_before_commit: soft / nl.max(1) as f64,
            iters: cfg.iters,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

impl CalibSet {
    /// `len` distinct row indices in [0, n) — recon batch sampler.
    pub fn gather_rows_idx(n: usize, len: usize, rng: &mut Rng) -> Vec<usize> {
        rng.sample_indices(n, len)
    }
}
