//! # brecq — BRECQ post-training quantization (ICLR 2021), reproduced
//!
//! This crate is the entire runtime: it drives the paper's algorithms —
//! block reconstruction (Algorithm 1), FIM-weighted objectives (Eq. 10),
//! sensitivity profiling, genetic mixed-precision search (Algorithm 2),
//! the precision-scalable accelerator latency simulator and the full
//! experiment suite — over a pluggable executable backend
//! ([`runtime::Backend`]):
//!
//! * **native** ([`runtime::native`], default) — a pure-Rust interpreter
//!   for every executable family the manifest names (`unit_fwd`,
//!   `unit_recon`, `eval_fwd`, `act_obs`, `fim`), ported from the
//!   pure-jnp oracles in `python/compile/kernels/ref.py`, plus a compiled
//!   reconstruction-plan engine ([`runtime::plan`]) that runs the
//!   Algorithm-1 inner loop with cached im2col slabs and zero
//!   steady-state allocation, bit-identical to per-iteration dispatch.
//!   Paired with the deterministic synthetic environment
//!   ([`model::synthetic`]) this makes the whole pipeline — and the
//!   integration test suite — run hermetically on a fresh checkout: no
//!   Python, no XLA, no artifacts.
//! * **pjrt** ([`runtime::pjrt`], cargo feature `pjrt`) — the original
//!   three-layer path: Python authors and AOT-lowers the compute (models,
//!   Pallas fake-quant kernels, reconstruction objectives) to HLO text once
//!   at build time (`make artifacts`), and this backend compiles/executes
//!   it via the `xla` crate.
//!
//! The public front door is [`pipeline`]: a typed, cache-aware session API
//! (`Session` + `JobSpec`) that compiles each quantization job into an
//! explicit stage DAG and shares expensive intermediates (FP weights,
//! calibration subsets, sensitivity LUTs) across jobs — and, through
//! [`pipeline::artifact_store`], across *processes*: sessions opened on
//! the same store directory replay cached stages bit-identically with
//! zero backend work. The `brecq serve` daemon ([`pipeline::serve`])
//! exposes that as a local job service. The CLI (`src/main.rs`) and every
//! example are thin views over it. ([`store`] is unrelated to the
//! artifact store: it reads the build-time python-ABI tensor files.)
//!
//! See DESIGN.md (repo root) for the system inventory and EXPERIMENTS.md
//! for the paper-vs-measured results.

pub mod util {
    pub mod cancel;
    pub mod cli;
    pub mod faults;
    pub mod json;
    pub mod pool;
    pub mod rng;
    pub mod stats;
}

pub mod tensor;
pub mod store;
pub mod runtime;
pub mod model;
pub mod calib;
pub mod quant;
pub mod optim;
pub mod recon;
pub mod eval;
pub mod sensitivity;
pub mod mp;
pub mod hwsim;
pub mod baselines;
pub mod qat;
pub mod distill;
pub mod coordinator;
pub mod pipeline;
