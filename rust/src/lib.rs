//! # brecq — BRECQ post-training quantization (ICLR 2021), reproduced
//!
//! A three-layer Rust + JAX + Pallas system: Python authors and AOT-lowers
//! the compute (models, Pallas fake-quant kernels, reconstruction
//! objectives) to HLO text once at build time; this crate is the entire
//! runtime — it loads the artifacts via PJRT and drives the paper's
//! algorithms: block reconstruction (Algorithm 1), FIM-weighted objectives
//! (Eq. 10), sensitivity profiling, genetic mixed-precision search
//! (Algorithm 2), the precision-scalable accelerator latency simulator and
//! the full experiment suite.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod util {
    pub mod cli;
    pub mod json;
    pub mod rng;
    pub mod stats;
}

pub mod tensor;
pub mod store;
pub mod runtime;
pub mod model;
pub mod calib;
pub mod quant;
pub mod optim;
pub mod recon;
pub mod eval;
pub mod sensitivity;
pub mod mp;
pub mod hwsim;
pub mod baselines;
pub mod qat;
pub mod distill;
pub mod coordinator;
