//! **Build-artifact reader** for the compile-time tensor ABI shared with
//! `python/compile/store.py`: `<prefix>.json` index (name -> shape/offset/
//! size in f32 elements) over a flat little-endian f32 `<prefix>.bin`.
//! Read-only, flat f32, produced by the model build — the environment's
//! *inputs*.
//!
//! Not to be confused with [`crate::pipeline::artifact_store`], the
//! read/write content-addressed store for *computed* pipeline artifacts
//! (typed multi-section payloads, checksums, cross-process locking).
//! This module never writes anything.

use std::collections::HashMap;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;

pub struct Store {
    pub tensors: HashMap<String, Tensor>,
}

impl Store {
    pub fn load(prefix: &Path) -> Result<Store> {
        let idx_path = prefix.with_extension("json");
        let bin_path = prefix.with_extension("bin");
        let idx_text = fs::read_to_string(&idx_path)
            .with_context(|| format!("reading {idx_path:?}"))?;
        let idx = Json::parse(&idx_text)
            .map_err(|e| anyhow::anyhow!("parsing {idx_path:?}: {e}"))?;
        let raw = fs::read(&bin_path)
            .with_context(|| format!("reading {bin_path:?}"))?;
        if raw.len() % 4 != 0 {
            bail!("{bin_path:?}: length not a multiple of 4");
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();

        let mut tensors = HashMap::new();
        let entries = idx
            .req("tensors")
            .as_obj()
            .context("store index: 'tensors' not an object")?;
        for (name, meta) in entries {
            let shape = meta.req("shape").usize_vec();
            let offset = meta.req("offset").as_usize().unwrap();
            let size = meta.req("size").as_usize().unwrap();
            if offset + size > floats.len() {
                bail!("tensor {name} out of range in {bin_path:?}");
            }
            tensors.insert(
                name.clone(),
                Tensor::new(
                    if shape.is_empty() { vec![1] } else { shape },
                    floats[offset..offset + size].to_vec(),
                ),
            );
        }
        Ok(Store { tensors })
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("store: missing tensor '{name}'"))
    }

    pub fn try_get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }
}

/// Raw u8 raster file loader (datasets are stored as u8 NHWC + labels).
pub fn load_u8(path: &Path) -> Result<Vec<u8>> {
    fs::read(path).with_context(|| format!("reading {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn reads_python_format() {
        let dir = std::env::temp_dir().join("brecq_store_test");
        fs::create_dir_all(&dir).unwrap();
        let prefix = dir.join("s");
        // two tensors: a (2,2) at offset 0, b (3,) at offset 4
        let vals: Vec<f32> = vec![1., 2., 3., 4., 9., 8., 7.];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        fs::File::create(prefix.with_extension("bin"))
            .unwrap()
            .write_all(&bytes)
            .unwrap();
        fs::write(
            prefix.with_extension("json"),
            r#"{"tensors":{"a":{"shape":[2,2],"offset":0,"size":4},
                           "b":{"shape":[3],"offset":4,"size":3}}}"#,
        )
        .unwrap();
        let s = Store::load(&prefix).unwrap();
        assert_eq!(s.get("a").shape, vec![2, 2]);
        assert_eq!(s.get("a").data, vec![1., 2., 3., 4.]);
        assert_eq!(s.get("b").data, vec![9., 8., 7.]);
        assert!(s.try_get("missing").is_none());
    }

    #[test]
    fn rejects_out_of_range() {
        let dir = std::env::temp_dir().join("brecq_store_test2");
        fs::create_dir_all(&dir).unwrap();
        let prefix = dir.join("s");
        fs::write(prefix.with_extension("bin"), [0u8; 8]).unwrap();
        fs::write(
            prefix.with_extension("json"),
            r#"{"tensors":{"a":{"shape":[4],"offset":0,"size":4}}}"#,
        )
        .unwrap();
        assert!(Store::load(&prefix).is_err());
    }
}
