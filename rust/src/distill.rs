//! ZeroQ-style data distillation (paper §B.2, Fig. 3, Table 4's
//! zero-shot row): synthesize calibration images by matching the stored
//! (pre-fold) BatchNorm statistics of the FP model, via the AOT
//! `distill_grad` executable (BN-matching loss + ∂loss/∂images) and
//! host-side Adam on the pixels.
//!
//! Labels for the distilled set (needed by the FIM pass) are the FP
//! model's own predictions — the distilled data has no ground truth.

use anyhow::Result;

use crate::calib::CalibSet;
use crate::eval::{forward, EvalParams};
use crate::model::{Manifest, ModelInfo};
use crate::optim::Adam;
use crate::recon::Calibrator;
use crate::runtime::Backend;
use crate::store::Store;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct DistillConfig {
    pub total: usize, // number of distilled images (multiple of batch)
    pub iters: usize, // Adam steps per batch
    pub lr: f32,
    pub seed: u64,
    pub verbose: bool,
}

impl Default for DistillConfig {
    fn default() -> Self {
        DistillConfig { total: 1024, iters: 160, lr: 0.1, seed: 0,
                        verbose: false }
    }
}

/// Generate a distilled calibration set.
pub fn distill(
    rt: &dyn Backend,
    mf: &Manifest,
    model: &ModelInfo,
    cfg: &DistillConfig,
) -> Result<CalibSet> {
    let exe = model.distill_exe.as_ref().ok_or_else(|| {
        anyhow::anyhow!("{}: no distill executable", model.name)
    })?;
    let b = model.distill_batch;
    assert!(cfg.total % b == 0);
    let store = mf.load_weights(model)?;

    // raw (unfolded) conv params + BN stats, in model conv order, then fc
    let convs: Vec<&crate::model::LayerInfo> = model
        .layers
        .iter()
        .filter(|l| l.kind == "conv")
        .collect();
    let fcs: Vec<&crate::model::LayerInfo> = model
        .layers
        .iter()
        .filter(|l| l.kind == "fc")
        .collect();
    let mut fixed: Vec<Tensor> = Vec::new();
    for l in &convs {
        fixed.push(get(&store, &format!("raw.{}.w", l.name)));
        fixed.push(get(&store, &format!("raw.{}.gamma", l.name)));
        fixed.push(get(&store, &format!("raw.{}.beta", l.name)));
        fixed.push(get(&store, &format!("bnstat.{}.mu", l.name)));
        fixed.push(get(&store, &format!("bnstat.{}.var", l.name)));
    }
    for l in &fcs {
        fixed.push(get(&store, &format!("raw.{}.w", l.name)));
        fixed.push(get(&store, &format!("raw.{}.b", l.name)));
    }

    let hw = mf.dataset.img;
    let mut rng = Rng::new(cfg.seed);
    let mut batches = Vec::new();
    for bi in 0..cfg.total / b {
        let mut x = Tensor::new(
            vec![b, 3, hw, hw],
            (0..b * 3 * hw * hw)
                .map(|_| rng.gauss() as f32)
                .collect(),
        );
        let mut opt = Adam::new(cfg.lr, &[x.numel()]);
        let mut last = f32::INFINITY;
        for _ in 0..cfg.iters {
            let mut args: Vec<&Tensor> = vec![&x];
            for t in &fixed {
                args.push(t);
            }
            let out = rt.run(exe, &args)?;
            last = out[0].data[0];
            opt.step(&mut [&mut x], &[&out[1]]);
        }
        if cfg.verbose {
            eprintln!("  [distill {}] batch {bi} loss {last:.4}",
                      model.name);
        }
        batches.push(x);
    }
    let images = Tensor::stack0(&batches);

    // pseudo-labels from the FP model
    let cal = Calibrator::new(rt, mf, model);
    let (ws, bs) = cal.fp_weights()?;
    let p = EvalParams::fp(model, &ws, &bs);
    let eb = model.eval_batch;
    let total = cfg.total;
    let mut labels = Vec::with_capacity(total);
    let mut start = 0;
    while start < total {
        let take = eb.min(total - start);
        let imgs = if take == eb {
            images.slice0(start, eb)
        } else {
            Tensor::stack0(&[
                images.slice0(start, take),
                images.slice0(0, eb - take),
            ])
        };
        let logits = forward(rt, model, &p, &imgs)?;
        let preds = logits.argmax_rows();
        labels.extend_from_slice(&preds[..take]);
        start += take;
    }
    Ok(CalibSet { images, labels })
}

fn get(store: &Store, name: &str) -> Tensor {
    store.get(name).clone()
}
