//! Deterministic synthetic environment generator: a tiny two-model
//! manifest + weight stores + calibration dataset, written in the normal
//! on-disk artifact format and executable by the native backend — no
//! Python, no JAX, no network.
//!
//! The task is constructed, not trained, and is quantization-robust *by
//! design* (a fully random trunk does not survive W2 weight noise — its
//! quantized self is effectively a different random projection, and no
//! fixed classifier head survives that):
//!
//! * **Prototypes** carry a per-channel density signature (each class's
//!   3-bit id selects a high/low pixel-on probability per color channel),
//!   so class identity lives in channel means and survives pooling,
//!   passthrough and quantization.
//! * **Trunks** are near-identity: every conv is a center-tap channel
//!   passthrough plus Gaussian noise taps. Nearest rounding preserves the
//!   dominant tap at 2 bits, while the noise taps give AdaRound/LSQ real
//!   reconstruction work.
//! * **Heads** are cosine classifiers: fc row c is the model's own
//!   normalized trunk feature of prototype c (no bias), which maps
//!   prototype c to class c by construction and is invariant to the
//!   uniform gain shifts quantization introduces.
//!
//! Samples are prototypes plus pixel noise, labels are the generating
//! cluster ids, and `fp_acc` is measured (1.0 on accepted tasks). A
//! deterministic retry loop additionally *verifies* the headroom — FP
//! accuracy 1.0, minimum test logit margin, nearest-rounding-W2 accuracy —
//! for both models before a seed is accepted, so low-bit accuracy floors
//! in the hermetic suite sit far from the noise floor.
//!
//! Three models are emitted, miniatures of the paper's families:
//!  * `resnet_s` — stem + basic block (identity skip) + strided basic block
//!    (1x1 down projection), exported at layer/block/stage/net/pack
//!    granularity,
//!  * `mobilenetv2_s` — stem + inverted residual (expand/depthwise/project,
//!    linear bottleneck) + head conv, exported at layer/block/pack
//!    granularity,
//!  * `det_s` — the detection family (paper Table 5): resnet_s's exact
//!    trunk geometry feeding a box-regression + objectness head over a
//!    quadrant anchor grid, evaluated by mAP on its own "scene" raster
//!    dataset (`data_det/`). The head is *solved*, not trained: a
//!    minimum-norm linear map sending each scene prototype's trunk
//!    feature exactly to its anchor-relative regression target, the
//!    detection analogue of the cosine classifier below. Its own
//!    acceptance loop verifies FP mAP, objectness margin and
//!    nearest-W2 mAP on a separate rng stream, so the classification
//!    candidates are bit-identical to a build without it.
//!
//! The `pack` granularity is Pack-PTQ (see PAPERS.md): the generator
//! measures a FIM-interaction proxy between adjacent blocks — the
//! excess logit MSE of quantizing two neighbors together over the sum
//! of quantizing each alone — and
//! [`crate::sensitivity::group_packs`] greedily merges strongly-coupled
//! neighbors into packs reconstructed jointly. The partition is
//! concrete at export time, so packs get their own `fim` executable and
//! stream like any other granularity.

use std::collections::BTreeMap;
use std::fs;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::eval::det_map;
use crate::model::{DetInfo, DetObj};
use crate::quant::{mse_steps_per_channel, quantize_nearest};
use crate::runtime::native::{add_bias, conv2d, fc_fwd, gap_fwd, relu_inplace};
use crate::sensitivity::group_packs;
use crate::tensor::Tensor;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::Rng;

pub const MEAN: [f32; 3] = [0.5, 0.5, 0.5];
pub const STD: [f32; 3] = [0.25, 0.25, 0.25];

/// Passthrough conv tap strength and relative noise level of the
/// structured trunk init (see module docs).
const TAP: f32 = 1.5;
const TAP_NOISE: f32 = 0.25;

#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub seed: u64,
    pub img: usize,
    pub classes: usize,
    pub train_n: usize,
    pub test_n: usize,
    pub calib_batch: usize,
    pub eval_batch: usize,
    /// pixel noise (u8 scale) around the class prototypes
    pub sigma: f32,
    /// prototype candidates scanned by the farthest-point selector
    pub candidates: usize,
    /// deterministic retry budget for the task-quality acceptance loop
    pub max_tries: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            seed: 0,
            img: 8,
            classes: 4,
            train_n: 256,
            test_n: 64,
            // matches ReconConfig::default().batch — unit executables are
            // declared (and ABI-checked) at this batch size
            calib_batch: 32,
            eval_batch: 32,
            sigma: 8.0,
            candidates: 16,
            max_tries: 32,
        }
    }
}

// ------------------------------------------------------------------
// Structural description of the two synthetic models
// ------------------------------------------------------------------

#[derive(Clone)]
struct SLayer {
    name: String,
    kind: &'static str, // "conv" | "fc"
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    groups: usize,
    relu: bool,
    site_signed: bool,
    h_in: usize,
}

impl SLayer {
    fn wshape(&self) -> Vec<usize> {
        if self.kind == "fc" {
            vec![self.cout, self.cin]
        } else {
            vec![self.cout, self.cin / self.groups, self.k, self.k]
        }
    }

    fn macs(&self) -> u64 {
        if self.kind == "fc" {
            (self.cin * self.cout) as u64
        } else {
            let o = (self.h_in + self.stride - 1) / self.stride;
            (o * o * self.cout * (self.cin / self.groups) * self.k * self.k)
                as u64
        }
    }

    fn nparams(&self) -> u64 {
        self.wshape().iter().product::<usize>() as u64 + self.cout as u64
    }
}

#[derive(Clone)]
enum SBlock {
    /// relu(conv2(conv1(x)) + [down](x)) — layer indices into SModel::layers
    Basic { c1: usize, c2: usize, down: Option<usize> },
    /// project(dw(expand(x))) [+ x]
    Ir { e: usize, d: usize, p: usize, res: bool },
}

struct SModel {
    name: &'static str,
    layers: Vec<SLayer>,
    blocks: Vec<SBlock>,
    head_convs: Vec<usize>,
    fc: usize,
    grans: Vec<&'static str>,
    /// Final-layer output width: the class count for classification
    /// models, `DetInfo::head_dim()` for the detection family.
    out_dim: usize,
}

fn conv_layer(
    name: String,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    groups: usize,
    relu: bool,
    site_signed: bool,
    h_in: usize,
) -> SLayer {
    SLayer {
        name,
        kind: "conv",
        cin,
        cout,
        k,
        stride,
        groups,
        relu,
        site_signed,
        h_in,
    }
}

fn resnet_desc(cfg: &SynthConfig) -> SModel {
    let mut layers = Vec::new();
    let mut hw = cfg.img;
    layers.push(conv_layer("stem".into(), 3, 8, 3, 1, 1, true, true, hw));
    // s1.b0: 8 -> 8, stride 1, identity skip
    layers.push(conv_layer(
        "s1.b0.conv1".into(), 8, 8, 3, 1, 1, true, false, hw,
    ));
    layers.push(conv_layer(
        "s1.b0.conv2".into(), 8, 8, 3, 1, 1, false, false, hw,
    ));
    let b0 = SBlock::Basic { c1: 1, c2: 2, down: None };
    // s2.b0: 8 -> 12, stride 2, 1x1 down projection
    layers.push(conv_layer(
        "s2.b0.conv1".into(), 8, 12, 3, 2, 1, true, false, hw,
    ));
    layers.push(conv_layer(
        "s2.b0.conv2".into(), 12, 12, 3, 1, 1, false, false, hw / 2,
    ));
    layers.push(conv_layer(
        "s2.b0.down".into(), 8, 12, 1, 2, 1, false, false, hw,
    ));
    let b1 = SBlock::Basic { c1: 3, c2: 4, down: Some(5) };
    hw /= 2;
    let _ = hw;
    layers.push(SLayer {
        name: "head.fc".into(),
        kind: "fc",
        cin: 12,
        cout: cfg.classes,
        k: 1,
        stride: 1,
        groups: 1,
        relu: false,
        site_signed: false,
        h_in: 1,
    });
    SModel {
        name: "resnet_s",
        layers,
        blocks: vec![b0, b1],
        head_convs: vec![],
        fc: 6,
        grans: vec!["layer", "block", "stage", "net", "pack"],
        out_dim: cfg.classes,
    }
}

/// The detection family: resnet_s's exact trunk geometry — every node
/// topology the plan compiler already covers, so its units compile with
/// zero fallback by construction — with the classifier replaced by a
/// `det.head_dim()`-wide box-regression + objectness head.
fn det_desc(cfg: &SynthConfig, det: &DetInfo) -> SModel {
    let mut m = resnet_desc(cfg);
    m.name = "det_s";
    m.out_dim = det.head_dim();
    m.layers[m.fc].cout = det.head_dim();
    m
}

fn mbv2_desc(cfg: &SynthConfig) -> SModel {
    let mut layers = Vec::new();
    let hw = cfg.img;
    layers.push(conv_layer("stem".into(), 3, 8, 3, 1, 1, true, true, hw));
    // s1.b0: inverted residual 8 -> 8, t=2 (mid 16), stride 1, residual
    layers.push(conv_layer(
        "s1.b0.expand".into(), 8, 16, 1, 1, 1, true, false, hw,
    ));
    layers.push(conv_layer(
        "s1.b0.dw".into(), 16, 16, 3, 1, 16, true, false, hw,
    ));
    layers.push(conv_layer(
        "s1.b0.project".into(), 16, 8, 1, 1, 1, false, false, hw,
    ));
    let b0 = SBlock::Ir { e: 1, d: 2, p: 3, res: true };
    // linear-bottleneck output is signed -> head conv sees a signed site
    layers.push(conv_layer(
        "head.conv".into(), 8, 16, 1, 1, 1, true, true, hw,
    ));
    layers.push(SLayer {
        name: "head.fc".into(),
        kind: "fc",
        cin: 16,
        cout: cfg.classes,
        k: 1,
        stride: 1,
        groups: 1,
        relu: false,
        site_signed: false,
        h_in: 1,
    });
    SModel {
        name: "mobilenetv2_s",
        layers,
        blocks: vec![b0],
        head_convs: vec![4],
        fc: 5,
        grans: vec!["layer", "block", "pack"],
        out_dim: cfg.classes,
    }
}

// ------------------------------------------------------------------
// Forward (generator-side; mirrors runtime::native node semantics)
// ------------------------------------------------------------------

fn apply_layer(l: &SLayer, x: &Tensor, w: &Tensor, b: &Tensor) -> Tensor {
    let mut z = if l.kind == "fc" {
        fc_fwd(x, w)
    } else {
        conv2d(x, w, l.stride, l.groups)
    };
    add_bias(&mut z, b);
    if l.relu {
        relu_inplace(&mut z);
    }
    z
}

fn add_t(a: &Tensor, b: &Tensor) -> Tensor {
    let data = a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect();
    Tensor::new(a.shape.clone(), data)
}

/// Trunk features: everything up to (and including) global average pool.
fn trunk(m: &SModel, ws: &[Tensor], bs: &[Tensor], x: &Tensor) -> Tensor {
    let mut h = apply_layer(&m.layers[0], x, &ws[0], &bs[0]);
    for blk in &m.blocks {
        h = match *blk {
            SBlock::Basic { c1, c2, down } => {
                let h1 = apply_layer(&m.layers[c1], &h, &ws[c1], &bs[c1]);
                let h2 = apply_layer(&m.layers[c2], &h1, &ws[c2], &bs[c2]);
                let sc = match down {
                    Some(d) => apply_layer(&m.layers[d], &h, &ws[d], &bs[d]),
                    None => h.clone(),
                };
                let mut out = add_t(&h2, &sc);
                relu_inplace(&mut out);
                out
            }
            SBlock::Ir { e, d, p, res } => {
                let he = apply_layer(&m.layers[e], &h, &ws[e], &bs[e]);
                let hd = apply_layer(&m.layers[d], &he, &ws[d], &bs[d]);
                let hp = apply_layer(&m.layers[p], &hd, &ws[p], &bs[p]);
                if res {
                    add_t(&hp, &h)
                } else {
                    hp
                }
            }
        };
    }
    for &hc in &m.head_convs {
        h = apply_layer(&m.layers[hc], &h, &ws[hc], &bs[hc]);
    }
    gap_fwd(&h)
}

fn logits(m: &SModel, ws: &[Tensor], bs: &[Tensor], x: &Tensor) -> Tensor {
    let f = trunk(m, ws, bs, x);
    apply_layer(&m.layers[m.fc], &f, &ws[m.fc], &bs[m.fc])
}

// ------------------------------------------------------------------
// Weights, data, task selection
// ------------------------------------------------------------------

/// Structured trunk init: center-tap channel passthrough + noise taps.
/// The fc head is left at zero and set from prototype features later.
fn structured_init(m: &SModel, rng: &mut Rng) -> (Vec<Tensor>, Vec<Tensor>) {
    let mut ws = Vec::new();
    let mut bs = Vec::new();
    for l in &m.layers {
        let shape = l.wshape();
        let n: usize = shape.iter().product();
        let w = if l.kind == "fc" {
            Tensor::zeros(shape)
        } else {
            let cpg_in = l.cin / l.groups;
            let fan_in = cpg_in * l.k * l.k;
            let sigma = TAP * TAP_NOISE / (fan_in as f32).sqrt();
            let mut w = Tensor::new(
                shape,
                (0..n).map(|_| rng.gauss() as f32 * sigma).collect(),
            );
            let cc = l.k / 2;
            let inner = cpg_in * l.k * l.k;
            for oc in 0..l.cout {
                let ic = oc % cpg_in;
                w.data[oc * inner + (ic * l.k + cc) * l.k + cc] += TAP;
            }
            w
        };
        ws.push(w);
        bs.push(Tensor::zeros(vec![l.cout]));
    }
    (ws, bs)
}

/// u8 NHWC raster -> standardized f32 NCHW (exactly DataSet::load's math).
fn standardize(raw: &[u8], n: usize, img: usize) -> Tensor {
    let mut images = vec![0f32; n * 3 * img * img];
    for i in 0..n {
        for h in 0..img {
            for w in 0..img {
                for c in 0..3 {
                    let v = raw[((i * img + h) * img + w) * 3 + c] as f32
                        / 255.0;
                    let v = (v - MEAN[c]) / STD[c];
                    images[((i * 3 + c) * img + h) * img + w] = v;
                }
            }
        }
    }
    Tensor::new(vec![n, 3, img, img], images)
}

/// Noisy samples around `protos` (u8 NHWC) with labels = cluster ids.
fn make_split(
    protos: &[Vec<u8>],
    n: usize,
    img: usize,
    sigma: f32,
    rng: &mut Rng,
) -> (Vec<u8>, Vec<u8>) {
    let classes = protos.len();
    let px = img * img * 3;
    let mut raw = Vec::with_capacity(n * px);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % classes;
        for j in 0..px {
            let v = protos[c][j] as f32 + rng.gauss() as f32 * sigma;
            raw.push(v.clamp(0.0, 255.0) as u8);
        }
        labels.push(c as u8);
    }
    (raw, labels)
}

/// L2-normalize each row (cosine-classifier directions).
fn normalize_rows(rows: &[Vec<f32>]) -> Vec<Vec<f32>> {
    rows.iter()
        .map(|r| {
            let nrm = r.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-8);
            r.iter().map(|x| x / nrm).collect()
        })
        .collect()
}

/// Greedy farthest-point selection of `k` rows (start at row 0).
fn farthest_points(rows: &[Vec<f32>], k: usize) -> Vec<usize> {
    let dist = |a: &[f32], b: &[f32]| -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
    };
    let mut chosen = vec![0usize];
    while chosen.len() < k {
        let mut best = (f32::NEG_INFINITY, 0usize);
        for (i, r) in rows.iter().enumerate() {
            if chosen.contains(&i) {
                continue;
            }
            let d = chosen
                .iter()
                .map(|&c| dist(r, &rows[c]))
                .fold(f32::INFINITY, f32::min);
            if d > best.0 {
                best = (d, i);
            }
        }
        chosen.push(best.1);
    }
    chosen
}

fn tensor_rows(t: &Tensor) -> Vec<Vec<f32>> {
    let c = t.shape[1];
    t.data.chunks(c).map(|r| r.to_vec()).collect()
}

struct Candidate {
    models: Vec<(SModel, Vec<Tensor>, Vec<Tensor>)>, // (desc, ws, bs)
    train_raw: Vec<u8>,
    train_y: Vec<u8>,
    test_raw: Vec<u8>,
    test_y: Vec<u8>,
    fp_accs: Vec<f64>,
    score: f64,
    accepted: bool,
}

fn accuracy_of(lg: &Tensor, labels: &[u8]) -> f64 {
    let preds = lg.argmax_rows();
    let hit = preds
        .iter()
        .zip(labels)
        .filter(|(p, l)| **p == **l as usize)
        .count();
    hit as f64 / labels.len().max(1) as f64
}

fn min_margin(lg: &Tensor) -> f64 {
    let c = lg.shape[1];
    let mut m = f64::INFINITY;
    for row in lg.data.chunks(c) {
        let mut v: Vec<f32> = row.to_vec();
        v.sort_by(|a, b| b.partial_cmp(a).unwrap());
        m = m.min((v[0] - v[1]) as f64);
    }
    m
}

fn build_candidate(cfg: &SynthConfig, try_seed: u64) -> Candidate {
    let mut rng = Rng::new(
        cfg.seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(try_seed),
    );
    let px = cfg.img * cfg.img * 3;

    // prototype candidates (u8 NHWC): each carries a per-channel density
    // signature — a random 3-bit id selects the pixel-on probability per
    // color channel — so class identity survives pooling and quantization
    let cands: Vec<Vec<u8>> = (0..cfg.candidates)
        .map(|_| {
            let bits = rng.below(8);
            let mut c = Vec::with_capacity(px);
            for j in 0..px {
                let ch = j % 3;
                let p = if (bits >> ch) & 1 == 1 { 0.85 } else { 0.15 };
                c.push(if rng.f64() < p { 255u8 } else { 0u8 });
            }
            c
        })
        .collect();

    // structured passthrough trunks
    let descs = vec![resnet_desc(cfg), mbv2_desc(cfg)];
    let mut models: Vec<(SModel, Vec<Tensor>, Vec<Tensor>)> = descs
        .into_iter()
        .map(|m| {
            let (ws, bs) = structured_init(&m, &mut rng);
            (m, ws, bs)
        })
        .collect();

    // candidate features under each trunk
    let mut cand_raw = Vec::with_capacity(cfg.candidates * px);
    for c in &cands {
        cand_raw.extend_from_slice(c);
    }
    let cand_x = standardize(&cand_raw, cfg.candidates, cfg.img);
    let feats: Vec<Vec<Vec<f32>>> = models
        .iter()
        .map(|(m, ws, bs)| tensor_rows(&trunk(m, ws, bs, &cand_x)))
        .collect();

    // prototype selection on the first model's cosine feature geometry
    let chosen = farthest_points(&normalize_rows(&feats[0]), cfg.classes);
    let protos: Vec<Vec<u8>> =
        chosen.iter().map(|&i| cands[i].clone()).collect();

    // cosine classifier head per model: fc row c = the model's own
    // normalized feature of prototype c -> prototype c argmaxes class c
    for (mi, (m, ws, _)) in models.iter_mut().enumerate() {
        let class_feats: Vec<Vec<f32>> =
            chosen.iter().map(|&i| feats[mi][i].clone()).collect();
        let wrows = normalize_rows(&class_feats);
        let d = wrows[0].len();
        let mut data = Vec::with_capacity(cfg.classes * d);
        for r in &wrows {
            data.extend_from_slice(r);
        }
        ws[m.fc] = Tensor::new(vec![cfg.classes, d], data);
    }

    // dataset
    let (train_raw, train_y) =
        make_split(&protos, cfg.train_n, cfg.img, cfg.sigma, &mut rng);
    let (test_raw, test_y) =
        make_split(&protos, cfg.test_n, cfg.img, cfg.sigma, &mut rng);
    let test_x = standardize(&test_raw, cfg.test_n, cfg.img);

    // diagnostics per model: FP accuracy, min margin, nearest-W2 accuracy
    let mut fp_accs = Vec::new();
    let mut score = f64::INFINITY;
    let mut accepted = true;
    for (m, ws, bs) in &models {
        let lg = logits(m, ws, bs, &test_x);
        let fp_acc = accuracy_of(&lg, &test_y);
        let margin = min_margin(&lg);
        let nl = m.layers.len();
        let wq: Vec<Tensor> = ws
            .iter()
            .enumerate()
            .map(|(l, w)| {
                let bits = if l == 0 || l == nl - 1 { 8 } else { 2 };
                let steps = mse_steps_per_channel(w, bits);
                quantize_nearest(w, &steps, bits)
            })
            .collect();
        let lq = logits(m, &wq, bs, &test_x);
        let near2 = accuracy_of(&lq, &test_y);
        fp_accs.push(fp_acc);
        accepted &= fp_acc >= 1.0 && margin >= 0.5 && near2 >= 0.95;
        score = score.min(fp_acc + near2 + margin.min(2.0));
    }

    Candidate {
        models,
        train_raw,
        train_y,
        test_raw,
        test_y,
        fp_accs,
        score,
        accepted,
    }
}

// ------------------------------------------------------------------
// Detection family (paper Table 5): geometry, scenes, head solve
// ------------------------------------------------------------------

/// The fixed synthetic detection geometry: a 2x2 quadrant anchor grid
/// and four scene classes occupying 1–3 anchors each. Ground-truth
/// boxes are deterministically jittered off their anchors (shifted
/// centers, scaled extents) so every regression target is nonzero —
/// the head must actually regress, not emit constants.
fn det_info() -> DetInfo {
    let anchors: Vec<[f64; 4]> = vec![
        [0.25, 0.25, 0.5, 0.5],
        [0.75, 0.25, 0.5, 0.5],
        [0.25, 0.75, 0.5, 0.5],
        [0.75, 0.75, 0.5, 0.5],
    ];
    let classes = anchors.len();
    let scenes = (0..classes)
        .map(|k| {
            let n_obj = 1 + k % 3;
            (0..n_obj)
                .map(|j| {
                    let a = (k + j) % classes;
                    let [acx, acy, aw, ah] = anchors[a];
                    let sx = if a % 2 == 0 { 1.0 } else { -1.0 };
                    let sy = if a < 2 { 1.0 } else { -1.0 };
                    let fw = 0.85 + 0.10 * ((k + a) % 3) as f64;
                    let fh = 0.85 + 0.10 * ((k + 2 * a) % 3) as f64;
                    DetObj {
                        anchor: a,
                        bbox: [
                            acx + 0.04 * sx,
                            acy + 0.04 * sy,
                            aw * fw,
                            ah * fh,
                        ],
                    }
                })
                .collect()
        })
        .collect();
    DetInfo { anchors, scenes }
}

/// Paint one scene class's ground-truth boxes onto a dim background
/// (u8 NHWC): each object's pixels go bright in the channel keyed by
/// its anchor, so scene identity lives in channel/occupancy statistics
/// and survives pooling and quantization like the classification
/// prototypes' density signatures.
fn render_scene(det: &DetInfo, scene: usize, img: usize) -> Vec<u8> {
    let mut raw = vec![30u8; img * img * 3];
    for o in &det.scenes[scene] {
        let [cx, cy, w, h] = o.bbox;
        let hot = o.anchor % 3;
        for py in 0..img {
            let yc = (py as f64 + 0.5) / img as f64;
            if (yc - cy).abs() > h / 2.0 {
                continue;
            }
            for px in 0..img {
                let xc = (px as f64 + 0.5) / img as f64;
                if (xc - cx).abs() > w / 2.0 {
                    continue;
                }
                for ch in 0..3 {
                    raw[(py * img + px) * 3 + ch] =
                        if ch == hot { 235 } else { 110 };
                }
            }
        }
    }
    raw
}

/// Exact (minimum-norm) linear head: W with `W·φ_k = t_k` for every
/// scene prototype trunk feature φ_k — `W = Tᵀ G⁻¹ Φ` with the K×K
/// Gram `G = Φ Φᵀ` inverted in f64 by Gauss-Jordan with partial
/// pivoting. The detection analogue of the cosine-classifier trick:
/// prototypes map to their targets *by construction*, and the map is
/// linear so noisy samples degrade gracefully. Returns None when the
/// prototype features are (near-)linearly dependent — the candidate is
/// rejected and the acceptance loop retries with fresh trunk noise.
fn solve_head(
    phi: &[Vec<f32>],
    targets: &[Vec<f32>],
) -> Option<Vec<Vec<f32>>> {
    let k = phi.len();
    let d = phi[0].len();
    let od = targets[0].len();
    let mut g: Vec<Vec<f64>> = (0..k)
        .map(|i| {
            (0..k)
                .map(|j| {
                    phi[i]
                        .iter()
                        .zip(&phi[j])
                        .map(|(&a, &b)| a as f64 * b as f64)
                        .sum()
                })
                .collect()
        })
        .collect();
    let max_diag = (0..k).fold(0f64, |m, i| m.max(g[i][i]));
    if max_diag <= 0.0 {
        return None;
    }
    let tiny = max_diag * 1e-10;
    let mut inv: Vec<Vec<f64>> = (0..k)
        .map(|i| (0..k).map(|j| f64::from(u8::from(i == j))).collect())
        .collect();
    for col in 0..k {
        let piv = (col..k).max_by(|&a, &b| {
            g[a][col].abs().partial_cmp(&g[b][col].abs()).unwrap()
        })?;
        if g[piv][col].abs() < tiny {
            return None;
        }
        g.swap(col, piv);
        inv.swap(col, piv);
        let p = g[col][col];
        for j in 0..k {
            g[col][j] /= p;
            inv[col][j] /= p;
        }
        for r in 0..k {
            if r == col {
                continue;
            }
            let f = g[r][col];
            if f == 0.0 {
                continue;
            }
            for j in 0..k {
                g[r][j] -= f * g[col][j];
                inv[r][j] -= f * inv[col][j];
            }
        }
    }
    // A = Tᵀ G⁻¹ (od×k), W = A Φ (od×d)
    let mut wrows = vec![vec![0f32; d]; od];
    for (o, row) in wrows.iter_mut().enumerate() {
        let a: Vec<f64> = (0..k)
            .map(|j| {
                (0..k).map(|i| targets[i][o] as f64 * inv[i][j]).sum()
            })
            .collect();
        for (c, w) in row.iter_mut().enumerate() {
            *w = (0..k)
                .map(|j| a[j] * phi[j][c] as f64)
                .sum::<f64>() as f32;
        }
    }
    Some(wrows)
}

/// Which anchors a scene class occupies.
fn det_occupancy(det: &DetInfo, scene: usize) -> Vec<bool> {
    let mut occ = vec![false; det.anchors.len()];
    for o in &det.scenes[scene] {
        occ[o.anchor] = true;
    }
    occ
}

/// Minimum signed objectness margin over every (sample, anchor):
/// occupied anchors score their obj logit, empty anchors its negation
/// — the detection analogue of `min_margin`.
fn det_obj_margin(det: &DetInfo, lg: &Tensor, labels: &[usize]) -> f64 {
    let d = det.head_dim();
    let mut m = f64::INFINITY;
    for (row, &l) in lg.data.chunks(d).zip(labels) {
        let occ = det_occupancy(det, l);
        for (a, &on) in occ.iter().enumerate() {
            let o = row[a * 5 + 4] as f64;
            m = m.min(if on { o } else { -o });
        }
    }
    m
}

struct DetCandidate {
    model: SModel,
    ws: Vec<Tensor>,
    bs: Vec<Tensor>,
    train_raw: Vec<u8>,
    train_y: Vec<u8>,
    test_raw: Vec<u8>,
    test_y: Vec<u8>,
    fp_map: f64,
    score: f64,
    accepted: bool,
}

/// One detection-environment candidate on its own rng stream (the
/// classification candidates consume theirs untouched). None when the
/// head solve hits a degenerate prototype Gram.
fn build_det_candidate(
    cfg: &SynthConfig,
    det: &DetInfo,
    try_seed: u64,
) -> Option<DetCandidate> {
    let mut rng = Rng::new(
        (cfg.seed ^ 0xde7ec7)
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(try_seed),
    );
    let m = det_desc(cfg, det);
    let (mut ws, bs) = structured_init(&m, &mut rng);

    // scene prototypes -> trunk features -> exact head solve
    let classes = det.scenes.len();
    let protos: Vec<Vec<u8>> =
        (0..classes).map(|k| render_scene(det, k, cfg.img)).collect();
    let mut proto_raw = Vec::new();
    for p in &protos {
        proto_raw.extend_from_slice(p);
    }
    let proto_x = standardize(&proto_raw, classes, cfg.img);
    let phi = tensor_rows(&trunk(&m, &ws, &bs, &proto_x));
    let targets: Vec<Vec<f32>> =
        (0..classes).map(|k| det.target_row(k)).collect();
    let wrows = solve_head(&phi, &targets)?;
    let d = phi[0].len();
    let mut data = Vec::with_capacity(m.out_dim * d);
    for r in &wrows {
        data.extend_from_slice(r);
    }
    ws[m.fc] = Tensor::new(vec![m.out_dim, d], data);

    // scene dataset (noisy rasters around the prototypes)
    let (train_raw, train_y) =
        make_split(&protos, cfg.train_n, cfg.img, cfg.sigma, &mut rng);
    let (test_raw, test_y) =
        make_split(&protos, cfg.test_n, cfg.img, cfg.sigma, &mut rng);
    let test_x = standardize(&test_raw, cfg.test_n, cfg.img);
    let test_labels: Vec<usize> =
        test_y.iter().map(|&v| v as usize).collect();

    // diagnostics: FP mAP, objectness margin, nearest-W2 mAP
    let lg = logits(&m, &ws, &bs, &test_x);
    let fp_map = det_map(det, &lg, &test_labels);
    let margin = det_obj_margin(det, &lg, &test_labels);
    let nl = m.layers.len();
    let wq: Vec<Tensor> = ws
        .iter()
        .enumerate()
        .map(|(l, w)| {
            let bits = if l == 0 || l == nl - 1 { 8 } else { 2 };
            let steps = mse_steps_per_channel(w, bits);
            quantize_nearest(w, &steps, bits)
        })
        .collect();
    let lq = logits(&m, &wq, &bs, &test_x);
    let near2 = det_map(det, &lq, &test_labels);
    let accepted = fp_map >= 0.999 && margin >= 0.5 && near2 >= 0.75;
    let score = fp_map + near2 + margin.min(2.0);

    Some(DetCandidate {
        model: m,
        ws,
        bs,
        train_raw,
        train_y,
        test_raw,
        test_y,
        fp_map,
        score,
        accepted,
    })
}

// ------------------------------------------------------------------
// Manifest assembly + on-disk stores
// ------------------------------------------------------------------

fn shape_json(v: &[usize]) -> Json {
    arr(v.iter().map(|&d| num(d as f64)).collect())
}

fn io_json(items: &[(String, Vec<usize>)]) -> Json {
    arr(items
        .iter()
        .map(|(n, sh)| obj(vec![("name", s(n)), ("shape", shape_json(sh))]))
        .collect())
}

struct SUnit {
    name: String,
    topo: String,
    layer_ids: Vec<usize>,
    uses_skip: bool,
    save_skip: bool,
    in_shape: Vec<usize>,
    skip_shape: Option<Vec<usize>>,
    out_shape: Vec<usize>,
}

fn conv_out_shape(l: &SLayer, inp: &[usize]) -> Vec<usize> {
    let h = (inp[2] + l.stride - 1) / l.stride;
    let w = (inp[3] + l.stride - 1) / l.stride;
    vec![inp[0], l.cout, h, w]
}

/// Unit partition at one granularity, with stream IO shapes (batch `b`).
/// `packs` is the model's Pack-PTQ block partition (consumed only by the
/// `"pack"` arm). Granularity strings are matched exhaustively: an
/// unknown one is a generator bug and panics — it must never silently
/// fall through to another partition (the runtime guards user input
/// separately via `ModelInfo::try_gran`).
fn units_of(
    m: &SModel,
    gran: &str,
    b: usize,
    cfg: &SynthConfig,
    packs: &[Range<usize>],
) -> Vec<SUnit> {
    let mut units: Vec<SUnit> = Vec::new();
    let mut cur = vec![b, 3, cfg.img, cfg.img];
    let mut pending_skip: Option<Vec<usize>> = None;

    let push = |units: &mut Vec<SUnit>,
                pending_skip: &mut Option<Vec<usize>>,
                cur: &mut Vec<usize>,
                name: String,
                topo: String,
                layer_ids: Vec<usize>,
                uses_skip: bool,
                save_skip: bool,
                out: Vec<usize>| {
        if save_skip {
            *pending_skip = Some(cur.clone());
        }
        let skip_shape = if uses_skip { pending_skip.clone() } else { None };
        units.push(SUnit {
            name,
            topo,
            layer_ids,
            uses_skip,
            save_skip,
            in_shape: cur.clone(),
            skip_shape,
            out_shape: out.clone(),
        });
        if uses_skip {
            *pending_skip = None;
        }
        *cur = out;
    };

    // stem
    let stem_out = conv_out_shape(&m.layers[0], &cur);
    push(
        &mut units,
        &mut pending_skip,
        &mut cur,
        "stem".into(),
        "conv".into(),
        vec![0],
        false,
        false,
        stem_out,
    );

    match gran {
        "layer" => {
            for blk in &m.blocks {
                match *blk {
                    SBlock::Basic { c1, c2, down } => {
                        let o1 = conv_out_shape(&m.layers[c1], &cur);
                        push(
                            &mut units,
                            &mut pending_skip,
                            &mut cur,
                            m.layers[c1].name.clone(),
                            "conv".into(),
                            vec![c1],
                            false,
                            true,
                            o1,
                        );
                        let o2 = conv_out_shape(&m.layers[c2], &cur);
                        let mut ids = vec![c2];
                        if let Some(d) = down {
                            ids.push(d);
                        }
                        push(
                            &mut units,
                            &mut pending_skip,
                            &mut cur,
                            m.layers[c2].name.clone(),
                            format!("basic_l2(down={})", down.is_some()),
                            ids,
                            true,
                            false,
                            o2,
                        );
                    }
                    SBlock::Ir { e, d, p, res } => {
                        let oe = conv_out_shape(&m.layers[e], &cur);
                        push(
                            &mut units,
                            &mut pending_skip,
                            &mut cur,
                            m.layers[e].name.clone(),
                            "conv".into(),
                            vec![e],
                            false,
                            res,
                            oe,
                        );
                        let od = conv_out_shape(&m.layers[d], &cur);
                        push(
                            &mut units,
                            &mut pending_skip,
                            &mut cur,
                            m.layers[d].name.clone(),
                            "conv".into(),
                            vec![d],
                            false,
                            false,
                            od,
                        );
                        let op = conv_out_shape(&m.layers[p], &cur);
                        push(
                            &mut units,
                            &mut pending_skip,
                            &mut cur,
                            m.layers[p].name.clone(),
                            if res { "ir_l3(res)" } else { "conv" }.into(),
                            vec![p],
                            res,
                            false,
                            op,
                        );
                    }
                }
            }
        }
        "block" => {
            for (bi, blk) in m.blocks.iter().enumerate() {
                let (name, topo, ids, out) = block_unit(m, blk, bi, &cur);
                push(
                    &mut units,
                    &mut pending_skip,
                    &mut cur,
                    name,
                    topo,
                    ids,
                    false,
                    false,
                    out,
                );
            }
        }
        "stage" | "net" => {
            // all body blocks fused into one seq unit (the synthetic
            // trunks have a single stage, so the partitions coincide)
            let mut ids = Vec::new();
            let mut topos = Vec::new();
            let mut out = cur.clone();
            for (bi, blk) in m.blocks.iter().enumerate() {
                let (_, topo, bids, o) = block_unit(m, blk, bi, &out);
                ids.extend(bids);
                topos.push(topo);
                out = o;
            }
            let name =
                if gran == "net" { "net".to_string() } else { "stage1".into() };
            push(
                &mut units,
                &mut pending_skip,
                &mut cur,
                name,
                format!("seq({})", topos.join(",")),
                ids,
                false,
                false,
                out,
            );
        }
        "pack" => {
            // Pack-PTQ: FIM-coupled adjacent blocks reconstruct jointly.
            // A singleton pack is exactly its block unit; a longer pack
            // is a seq over its blocks, named p{j}.
            assert_eq!(
                packs.iter().map(|r| r.len()).sum::<usize>(),
                m.blocks.len(),
                "pack partition must cover every block of {}",
                m.name
            );
            for (j, r) in packs.iter().enumerate() {
                if r.len() == 1 {
                    let (name, topo, ids, out) =
                        block_unit(m, &m.blocks[r.start], r.start, &cur);
                    push(
                        &mut units,
                        &mut pending_skip,
                        &mut cur,
                        name,
                        topo,
                        ids,
                        false,
                        false,
                        out,
                    );
                } else {
                    let mut ids = Vec::new();
                    let mut topos = Vec::new();
                    let mut out = cur.clone();
                    for bi in r.clone() {
                        let (_, topo, bids, o) =
                            block_unit(m, &m.blocks[bi], bi, &out);
                        ids.extend(bids);
                        topos.push(topo);
                        out = o;
                    }
                    push(
                        &mut units,
                        &mut pending_skip,
                        &mut cur,
                        format!("p{j}"),
                        format!("seq({})", topos.join(",")),
                        ids,
                        false,
                        false,
                        out,
                    );
                }
            }
        }
        other => panic!(
            "units_of: unknown granularity '{other}' for model {} — \
             every declared granularity needs an explicit arm here",
            m.name
        ),
    }

    for &hc in &m.head_convs {
        let o = conv_out_shape(&m.layers[hc], &cur);
        push(
            &mut units,
            &mut pending_skip,
            &mut cur,
            m.layers[hc].name.clone(),
            "conv".into(),
            vec![hc],
            false,
            false,
            o,
        );
    }
    let out = vec![b, m.out_dim];
    push(
        &mut units,
        &mut pending_skip,
        &mut cur,
        "head".into(),
        "gap_fc".into(),
        vec![m.fc],
        false,
        false,
        out,
    );
    units
}

/// (name, topo, layer ids, out shape) of one whole-block unit.
fn block_unit(
    m: &SModel,
    blk: &SBlock,
    bi: usize,
    inp: &[usize],
) -> (String, String, Vec<usize>, Vec<usize>) {
    match *blk {
        SBlock::Basic { c1, c2, down } => {
            let o1 = conv_out_shape(&m.layers[c1], inp);
            let o2 = conv_out_shape(&m.layers[c2], &o1);
            let mut ids = vec![c1, c2];
            if let Some(d) = down {
                ids.push(d);
            }
            (
                format!("s{}.b0", bi + 1),
                format!("basic(down={})", down.is_some()),
                ids,
                o2,
            )
        }
        SBlock::Ir { e, d, p, res } => {
            let oe = conv_out_shape(&m.layers[e], inp);
            let od = conv_out_shape(&m.layers[d], &oe);
            let op = conv_out_shape(&m.layers[p], &od);
            (
                format!("s{}.b0", bi + 1),
                format!("ir(res={res})"),
                vec![e, d, p],
                op,
            )
        }
    }
}

fn block_layer_ids(blk: &SBlock) -> Vec<usize> {
    match *blk {
        SBlock::Basic { c1, c2, down } => {
            let mut v = vec![c1, c2];
            if let Some(d) = down {
                v.push(d);
            }
            v
        }
        SBlock::Ir { e, d, p, .. } => vec![e, d, p],
    }
}

/// Pack-PTQ grouping threshold: adjacent blocks merge into one pack
/// when their measured interaction term is at least this fraction of
/// the smaller block's own 2-bit sensitivity. A design parameter, not a
/// fit: large enough to ignore measurement noise around zero, small
/// enough that genuinely coupled neighbors (a residual stream feeding a
/// strided consumer) clear it.
const PACK_TAU: f64 = 0.05;
/// Upper bound on blocks per pack — keeps a pathological coupling chain
/// from degenerating into whole-net reconstruction (Pack-PTQ's failure
/// mode at low calibration sizes).
const PACK_MAX_LEN: usize = 4;

/// Measure the Pack-PTQ block partition for one model on the held-out
/// split. `err(S)` is the mean squared logit deviation from FP with
/// every layer of the blocks in `S` at 2-bit nearest rounding — the
/// same data-driven FIM proxy as [`crate::sensitivity`]'s off-diagonal
/// probes, lifted from layer pairs to block pairs:
///
///   s_i       = err({i})
///   o_{i,i+1} = err({i, i+1}) - s_i - s_{i+1}
///
/// A positive `o` means the neighbors' quantization errors interact
/// (the block-diagonal Hessian term BRECQ drops between blocks is not
/// actually negligible there), so the pair reconstructs jointly.
fn pack_partition(
    m: &SModel,
    ws: &[Tensor],
    bs: &[Tensor],
    x: &Tensor,
) -> Vec<Range<usize>> {
    let nb = m.blocks.len();
    if nb <= 1 {
        return (0..nb).map(|i| i..i + 1).collect();
    }
    let lg_fp = logits(m, ws, bs, x);
    let err = |blocks: &[usize]| -> f64 {
        let mut wq: Vec<Tensor> = ws.to_vec();
        for &bi in blocks {
            for &l in &block_layer_ids(&m.blocks[bi]) {
                let steps = mse_steps_per_channel(&ws[l], 2);
                wq[l] = quantize_nearest(&ws[l], &steps, 2);
            }
        }
        let lq = logits(m, &wq, bs, x);
        lq.data
            .iter()
            .zip(&lg_fp.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            / lg_fp.data.len() as f64
    };
    let diag: Vec<f64> = (0..nb).map(|i| err(&[i])).collect();
    let coupling: Vec<f64> =
        (0..nb - 1).map(|i| err(&[i, i + 1]) - diag[i] - diag[i + 1]).collect();
    group_packs(&diag, &coupling, PACK_TAU, PACK_MAX_LEN)
}

fn unit_fwd_sig(
    u: &SUnit,
    layers: &[SLayer],
) -> (Vec<(String, Vec<usize>)>, Vec<(String, Vec<usize>)>) {
    let mut inputs = vec![("x".to_string(), u.in_shape.clone())];
    if u.uses_skip {
        inputs.push(("skip".into(), u.skip_shape.clone().unwrap()));
    }
    for (i, &l) in u.layer_ids.iter().enumerate() {
        inputs.push((format!("w{i}"), layers[l].wshape()));
        inputs.push((format!("b{i}"), vec![layers[l].cout]));
    }
    for i in 0..u.layer_ids.len() {
        inputs.push((format!("astep{i}"), vec![1]));
        inputs.push((format!("aqmin{i}"), vec![1]));
        inputs.push((format!("aqmax{i}"), vec![1]));
    }
    inputs.push(("aq_flag".into(), vec![1]));
    (inputs, vec![("z".into(), u.out_shape.clone())])
}

fn unit_recon_sig(
    u: &SUnit,
    layers: &[SLayer],
) -> (Vec<(String, Vec<usize>)>, Vec<(String, Vec<usize>)>) {
    let mut inputs = vec![("x".to_string(), u.in_shape.clone())];
    if u.uses_skip {
        inputs.push(("skip".into(), u.skip_shape.clone().unwrap()));
    }
    inputs.push(("z_fp".into(), u.out_shape.clone()));
    inputs.push(("fim".into(), u.out_shape.clone()));
    for (i, &l) in u.layer_ids.iter().enumerate() {
        inputs.push((format!("w{i}"), layers[l].wshape()));
        inputs.push((format!("b{i}"), vec![layers[l].cout]));
        inputs.push((format!("wstep{i}"), vec![layers[l].cout]));
        inputs.push((format!("v{i}"), layers[l].wshape()));
        inputs.push((format!("wn{i}"), vec![1]));
        inputs.push((format!("wp{i}"), vec![1]));
    }
    for i in 0..u.layer_ids.len() {
        inputs.push((format!("astep{i}"), vec![1]));
        inputs.push((format!("aqmin{i}"), vec![1]));
        inputs.push((format!("aqmax{i}"), vec![1]));
    }
    inputs.push(("beta".into(), vec![1]));
    inputs.push(("lam".into(), vec![1]));
    inputs.push(("aq_flag".into(), vec![1]));

    let mut outputs = vec![
        ("loss".to_string(), vec![1]),
        ("rec_loss".into(), vec![1]),
        ("round_loss".into(), vec![1]),
    ];
    for (i, &l) in u.layer_ids.iter().enumerate() {
        outputs.push((format!("gv{i}"), layers[l].wshape()));
    }
    for i in 0..u.layer_ids.len() {
        outputs.push((format!("gastep{i}"), vec![1]));
    }
    (inputs, outputs)
}

fn write_store(prefix: &Path, tensors: &[(String, &Tensor)]) -> Result<()> {
    let mut bin: Vec<u8> = Vec::new();
    let mut index = BTreeMap::new();
    let mut offset = 0usize;
    for (name, t) in tensors {
        for v in &t.data {
            bin.extend_from_slice(&v.to_le_bytes());
        }
        index.insert(
            name.clone(),
            obj(vec![
                ("shape", shape_json(&t.shape)),
                ("offset", num(offset as f64)),
                ("size", num(t.numel() as f64)),
            ]),
        );
        offset += t.numel();
    }
    fs::write(prefix.with_extension("bin"), &bin)?;
    let idx = Json::Obj(
        [("tensors".to_string(), Json::Obj(index))].into_iter().collect(),
    );
    fs::write(prefix.with_extension("json"), idx.to_string())?;
    Ok(())
}

fn add_exe(
    exes: &mut BTreeMap<String, Json>,
    name: &str,
    io: (Vec<(String, Vec<usize>)>, Vec<(String, Vec<usize>)>),
) {
    exes.insert(
        name.to_string(),
        obj(vec![
            ("file", s("native")),
            ("inputs", io_json(&io.0)),
            ("outputs", io_json(&io.1)),
        ]),
    );
}

fn det_json(det: &DetInfo) -> Json {
    let anchors = arr(det
        .anchors
        .iter()
        .map(|a| arr(a.iter().map(|&v| num(v)).collect()))
        .collect());
    let scenes = arr(det
        .scenes
        .iter()
        .map(|objs| {
            arr(objs
                .iter()
                .map(|o| {
                    obj(vec![
                        ("anchor", num(o.anchor as f64)),
                        (
                            "box",
                            arr(o.bbox.iter().map(|&v| num(v)).collect()),
                        ),
                    ])
                })
                .collect())
        })
        .collect());
    obj(vec![("anchors", anchors), ("scenes", scenes)])
}

/// Write one model's weight store and assemble its manifest entry,
/// registering every executable it references. `pack_x` is the
/// standardized held-out split the Pack-PTQ coupling probes run on;
/// `extra` appends model-level keys (the detection family's
/// task/dataset/det).
#[allow(clippy::too_many_arguments)]
fn emit_model(
    dir: &Path,
    cfg: &SynthConfig,
    m: &SModel,
    ws: &[Tensor],
    bs: &[Tensor],
    fp_acc: f64,
    pack_x: &Tensor,
    exes: &mut BTreeMap<String, Json>,
    extra: Vec<(&str, Json)>,
) -> Result<Json> {
    // weight store
    let mut tensors: Vec<(String, &Tensor)> = Vec::new();
    for (l, layer) in m.layers.iter().enumerate() {
        tensors.push((format!("{}.w", layer.name), &ws[l]));
        tensors.push((format!("{}.b", layer.name), &bs[l]));
    }
    write_store(&dir.join(format!("weights_{}", m.name)), &tensors)?;

    // layer geometry
    let layers_json = arr(m
        .layers
        .iter()
        .map(|l| {
            obj(vec![
                ("name", s(&l.name)),
                ("kind", s(l.kind)),
                ("cin", num(l.cin as f64)),
                ("cout", num(l.cout as f64)),
                ("k", num(l.k as f64)),
                ("stride", num(l.stride as f64)),
                ("groups", num(l.groups as f64)),
                ("relu", Json::Bool(l.relu)),
                ("site_signed", Json::Bool(l.site_signed)),
                ("h_in", num(l.h_in as f64)),
                ("w_in", num(l.h_in as f64)),
                ("macs", num(l.macs() as f64)),
                ("nparams", num(l.nparams() as f64)),
                ("wshape", shape_json(&l.wshape())),
            ])
        })
        .collect());

    // model-level executables
    let nl = m.layers.len();
    let img_sh = |b: usize| vec![b, 3, cfg.img, cfg.img];
    let fwd_exe = format!("{}.eval_fwd", m.name);
    let mut inputs = vec![("images".to_string(), img_sh(cfg.eval_batch))];
    for (i, l) in m.layers.iter().enumerate() {
        inputs.push((format!("w{i}"), l.wshape()));
        inputs.push((format!("b{i}"), vec![l.cout]));
    }
    for i in 0..nl {
        inputs.push((format!("astep{i}"), vec![1]));
        inputs.push((format!("aqmin{i}"), vec![1]));
        inputs.push((format!("aqmax{i}"), vec![1]));
    }
    inputs.push(("aq_flag".into(), vec![1]));
    add_exe(
        exes,
        &fwd_exe,
        (
            inputs,
            vec![("logits".to_string(), vec![cfg.eval_batch, m.out_dim])],
        ),
    );

    let act_obs_exe = format!("{}.act_obs", m.name);
    let mut inputs = vec![("images".to_string(), img_sh(cfg.calib_batch))];
    for (i, l) in m.layers.iter().enumerate() {
        inputs.push((format!("w{i}"), l.wshape()));
        inputs.push((format!("b{i}"), vec![l.cout]));
    }
    let outputs =
        (0..nl).map(|i| (format!("obs{i}"), vec![2])).collect::<Vec<_>>();
    add_exe(exes, &act_obs_exe, (inputs, outputs));

    // granularities (pack partition measured once per model)
    let packs = pack_partition(m, ws, bs, pack_x);
    let mut grans_json: BTreeMap<String, Json> = BTreeMap::new();
    for gran in &m.grans {
        let units = units_of(m, gran, cfg.calib_batch, cfg, &packs);
        let fim_exe = format!("{}.{}.fim", m.name, gran);
        let mut inputs = vec![("images".to_string(), img_sh(cfg.calib_batch))];
        // detection models feed per-sample regression-target rows
        // through the same slot (see `recon::fim_pass`)
        inputs.push(("onehot".into(), vec![cfg.calib_batch, m.out_dim]));
        for (i, l) in m.layers.iter().enumerate() {
            inputs.push((format!("w{i}"), l.wshape()));
            inputs.push((format!("b{i}"), vec![l.cout]));
        }
        let outputs = units
            .iter()
            .enumerate()
            .map(|(j, u)| (format!("g{j}"), u.out_shape.clone()))
            .collect::<Vec<_>>();
        add_exe(exes, &fim_exe, (inputs, outputs));

        let mut units_json = Vec::new();
        for (ui, u) in units.iter().enumerate() {
            let fwd = format!("{}.{}.u{}.fwd", m.name, gran, ui);
            let rec = format!("{}.{}.u{}.recon", m.name, gran, ui);
            add_exe(exes, &fwd, unit_fwd_sig(u, &m.layers));
            add_exe(exes, &rec, unit_recon_sig(u, &m.layers));
            units_json.push(obj(vec![
                ("name", s(&u.name)),
                ("topo", s(&u.topo)),
                (
                    "layers",
                    arr(u
                        .layer_ids
                        .iter()
                        .map(|&l| s(&m.layers[l].name))
                        .collect()),
                ),
                ("uses_skip", Json::Bool(u.uses_skip)),
                ("save_skip", Json::Bool(u.save_skip)),
                ("in_shape", shape_json(&u.in_shape)),
                (
                    "skip_shape",
                    match &u.skip_shape {
                        Some(sh) => shape_json(sh),
                        None => Json::Null,
                    },
                ),
                ("out_shape", shape_json(&u.out_shape)),
                ("fwd_exe", s(&fwd)),
                ("recon_exe", s(&rec)),
            ]));
        }
        grans_json.insert(
            gran.to_string(),
            obj(vec![("fim_exe", s(&fim_exe)), ("units", arr(units_json))]),
        );
    }

    let mut pairs = vec![
        ("fp_acc", num(fp_acc)),
        ("weights", s(&format!("weights_{}", m.name))),
        ("layers", layers_json),
        ("fwd_exe", s(&fwd_exe)),
        ("act_obs_exe", s(&act_obs_exe)),
        ("eval_batch", num(cfg.eval_batch as f64)),
        ("grans", Json::Obj(grans_json)),
    ];
    pairs.extend(extra);
    Ok(obj(pairs))
}

/// Generate the synthetic environment into `dir` (created if missing):
/// manifest.json, per-model weight stores and the u8 raster dataset.
pub fn generate(dir: &Path, cfg: &SynthConfig) -> Result<()> {
    fs::create_dir_all(dir.join("data"))
        .with_context(|| format!("creating {dir:?}"))?;

    // deterministic task-quality retry loop
    let mut best: Option<Candidate> = None;
    for t in 0..cfg.max_tries {
        let cand = build_candidate(cfg, t);
        if cand.accepted {
            best = Some(cand);
            break;
        }
        let take = match &best {
            Some(b) => cand.score > b.score,
            None => true,
        };
        if take {
            best = Some(cand);
        }
    }
    let cand = best.context("synthetic generation produced no candidate")?;

    // dataset files (u8 NHWC rasters + u8 labels)
    let data = dir.join("data");
    fs::write(data.join("train_x.bin"), &cand.train_raw)?;
    fs::write(data.join("train_y.bin"), &cand.train_y)?;
    fs::write(data.join("test_x.bin"), &cand.test_raw)?;
    fs::write(data.join("test_y.bin"), &cand.test_y)?;

    let mut exes: BTreeMap<String, Json> = BTreeMap::new();

    // Pack-PTQ coupling probes run on the held-out split (the same
    // reference the acceptance loop scores against)
    let test_x = standardize(&cand.test_raw, cfg.test_n, cfg.img);

    let mut models_json: BTreeMap<String, Json> = BTreeMap::new();
    for ((m, ws, bs), fp_acc) in cand.models.iter().zip(&cand.fp_accs) {
        let mj = emit_model(
            dir, cfg, m, ws, bs, *fp_acc, &test_x, &mut exes, vec![],
        )?;
        models_json.insert(m.name.to_string(), mj);
    }

    // detection family: own acceptance loop (separate rng stream), own
    // scene dataset, extra manifest keys (task/dataset/det)
    let det = det_info();
    let mut dbest: Option<DetCandidate> = None;
    for t in 0..cfg.max_tries {
        if let Some(c) = build_det_candidate(cfg, &det, t) {
            if c.accepted {
                dbest = Some(c);
                break;
            }
            let take = dbest.as_ref().map_or(true, |b| c.score > b.score);
            if take {
                dbest = Some(c);
            }
        }
    }
    let dc =
        dbest.context("synthetic detection generation produced no candidate")?;
    let ddata = dir.join("data_det");
    fs::create_dir_all(&ddata)?;
    fs::write(ddata.join("train_x.bin"), &dc.train_raw)?;
    fs::write(ddata.join("train_y.bin"), &dc.train_y)?;
    fs::write(ddata.join("test_x.bin"), &dc.test_raw)?;
    fs::write(ddata.join("test_y.bin"), &dc.test_y)?;
    let det_x = standardize(&dc.test_raw, cfg.test_n, cfg.img);
    let dmj = emit_model(
        dir,
        cfg,
        &dc.model,
        &dc.ws,
        &dc.bs,
        dc.fp_map,
        &det_x,
        &mut exes,
        vec![
            ("task", s("detect")),
            (
                "dataset",
                obj(vec![
                    ("dir", s("data_det")),
                    ("img", num(cfg.img as f64)),
                    ("classes", num(det.scenes.len() as f64)),
                    ("train_n", num(cfg.train_n as f64)),
                    ("test_n", num(cfg.test_n as f64)),
                    (
                        "mean",
                        arr(MEAN.iter().map(|&v| num(v as f64)).collect()),
                    ),
                    (
                        "std",
                        arr(STD.iter().map(|&v| num(v as f64)).collect()),
                    ),
                ]),
            ),
            ("det", det_json(&det)),
        ],
    )?;
    models_json.insert(dc.model.name.to_string(), dmj);

    let manifest = obj(vec![
        ("backend", s("native")),
        ("calib_batch", num(cfg.calib_batch as f64)),
        (
            "dataset",
            obj(vec![
                ("dir", s("data")),
                ("img", num(cfg.img as f64)),
                ("classes", num(cfg.classes as f64)),
                ("train_n", num(cfg.train_n as f64)),
                ("test_n", num(cfg.test_n as f64)),
                ("mean", arr(MEAN.iter().map(|&v| num(v as f64)).collect())),
                ("std", arr(STD.iter().map(|&v| num(v as f64)).collect())),
            ]),
        ),
        ("models", Json::Obj(models_json)),
        ("executables", Json::Obj(exes)),
    ]);
    fs::write(dir.join("manifest.json"), manifest.to_string())?;
    Ok(())
}

static DEFAULT_DIR: Mutex<Option<PathBuf>> = Mutex::new(None);

/// Generate (once per process) the default synthetic environment in a
/// temp directory and return its path. Subsequent calls reuse it.
pub fn ensure_default() -> Result<PathBuf> {
    let mut guard = DEFAULT_DIR.lock().unwrap();
    if let Some(p) = guard.as_ref() {
        return Ok(p.clone());
    }
    let dir = std::env::temp_dir()
        .join(format!("brecq-synth-{}", std::process::id()));
    generate(&dir, &SynthConfig::default())?;
    *guard = Some(dir.clone());
    Ok(dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn farthest_points_spreads() {
        let rows = vec![
            vec![0.0, 0.0],
            vec![0.1, 0.0],
            vec![10.0, 0.0],
            vec![0.0, 10.0],
            vec![10.0, 10.0],
        ];
        let chosen = farthest_points(&rows, 4);
        assert_eq!(chosen.len(), 4);
        // the near-duplicate of row 0 must be the one left out
        assert!(!chosen.contains(&1), "{chosen:?}");
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let rows = vec![vec![1.0, 2.0, 3.0], vec![3.0, 2.0, 1.0]];
        let cn = normalize_rows(&rows);
        for r in &cn {
            let n: f32 = r.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn structured_init_has_dominant_center_taps() {
        let cfg = SynthConfig::default();
        let m = resnet_desc(&cfg);
        let mut rng = Rng::new(7);
        let (ws, bs) = structured_init(&m, &mut rng);
        // stem: conv 3->8 k3 — center tap of the mapped input channel
        // must dominate the noise taps
        let stem = &ws[0];
        let inner = 3 * 3 * 3;
        for oc in 0..8 {
            let ic = oc % 3;
            let tap = stem.data[oc * inner + (ic * 3 + 1) * 3 + 1];
            assert!(tap > TAP * 0.5, "oc {oc}: tap {tap}");
        }
        assert!(bs.iter().all(|b| b.data.iter().all(|&v| v == 0.0)));
        // fc left zeroed for the classifier construction
        assert!(ws[m.fc].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn standardize_matches_dataset_loader_layout() {
        // one pixel, NHWC c order -> NCHW planes
        let raw: Vec<u8> = vec![255, 0, 127];
        let t = standardize(&raw, 1, 1);
        assert_eq!(t.shape, vec![1, 3, 1, 1]);
        assert!((t.data[0] - 2.0).abs() < 1e-6); // (1.0-0.5)/0.25
        assert!((t.data[1] + 2.0).abs() < 1e-6); // (0.0-0.5)/0.25
    }
}
