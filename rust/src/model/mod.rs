//! Model graph as seen by the coordinator: parsed from manifest.json.
//!
//! The manifest is produced by `python/compile/aot.py` and is the single
//! source of truth for layer geometry (shapes, MACs, act-site signedness),
//! the unit partition of every exported granularity, and the executable
//! signatures each unit binds to. Nothing here re-derives network structure
//! — the Rust side is deliberately architecture-agnostic.

pub mod synthetic;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::store::Store;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// What a model's final-layer outputs mean: softmax-classification
/// logits (the default) or the detection family's per-anchor
/// box-regression + objectness rows. Dispatch on this happens at the
/// API boundary (eval stage, FIM seeding) — the reconstruction engine
/// itself is task-agnostic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    Classify,
    Detect,
}

/// Objectness logit magnitude of the synthetic detection targets:
/// occupied anchors regress to `+DET_OBJ_LOGIT`, empty ones to
/// `-DET_OBJ_LOGIT`. Shared by the generator's head solve, the FIM
/// target rows and the mAP decode so they can never drift apart.
pub const DET_OBJ_LOGIT: f32 = 2.5;

/// One ground-truth object: which anchor slot owns it and its box in
/// normalized `[cx, cy, w, h]` image coordinates.
#[derive(Debug, Clone)]
pub struct DetObj {
    pub anchor: usize,
    pub bbox: [f64; 4],
}

/// Detection-head geometry from the manifest: the anchor grid (each
/// `[cx, cy, w, h]`, normalized) and, per scene class, the ground-truth
/// objects the mAP eval matches against. The head emits 5 channels per
/// anchor — `[dx, dy, dw, dh, obj]` with the standard anchor-relative
/// encoding (`dx = (cx - acx)/aw`, `dw = ln(w/aw)`).
#[derive(Debug, Clone)]
pub struct DetInfo {
    pub anchors: Vec<[f64; 4]>,
    pub scenes: Vec<Vec<DetObj>>,
}

impl DetInfo {
    /// Width of the head's output row: 5 channels per anchor.
    pub fn head_dim(&self) -> usize {
        self.anchors.len() * 5
    }

    /// The exact regression target row for one scene class. Empty
    /// anchors target zero deltas and `-DET_OBJ_LOGIT` objectness.
    pub fn target_row(&self, scene: usize) -> Vec<f32> {
        let mut t = vec![0f32; self.head_dim()];
        for a in 0..self.anchors.len() {
            t[a * 5 + 4] = -DET_OBJ_LOGIT;
        }
        for o in &self.scenes[scene] {
            let [acx, acy, aw, ah] = self.anchors[o.anchor];
            let [cx, cy, w, h] = o.bbox;
            let base = o.anchor * 5;
            t[base] = ((cx - acx) / aw) as f32;
            t[base + 1] = ((cy - acy) / ah) as f32;
            t[base + 2] = ((w / aw).ln()) as f32;
            t[base + 3] = ((h / ah).ln()) as f32;
            t[base + 4] = DET_OBJ_LOGIT;
        }
        t
    }

    /// Stacked target rows for a batch of scene labels — the detection
    /// counterpart of `CalibSet::onehot`, fed to the FIM executables
    /// through the same argument slot.
    pub fn target_rows(&self, labels: &[usize]) -> Tensor {
        let d = self.head_dim();
        let mut data = Vec::with_capacity(labels.len() * d);
        for &l in labels {
            data.extend_from_slice(&self.target_row(l));
        }
        Tensor::new(vec![labels.len(), d], data)
    }

    /// Decode one anchor's prediction from a logits row back to a box.
    pub fn decode(&self, row: &[f32], a: usize) -> [f64; 4] {
        let [acx, acy, aw, ah] = self.anchors[a];
        let base = a * 5;
        [
            acx + row[base] as f64 * aw,
            acy + row[base + 1] as f64 * ah,
            aw * (row[base + 2] as f64).exp(),
            ah * (row[base + 3] as f64).exp(),
        ]
    }
}

#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub name: String,
    pub kind: String, // "conv" | "fc"
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub groups: usize,
    pub relu: bool,
    pub site_signed: bool,
    pub h_in: usize,
    pub w_in: usize,
    pub macs: u64,
    pub nparams: u64,
    pub wshape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct UnitInfo {
    pub name: String,
    pub topo: String,
    /// indices into ModelInfo::layers, in executable binding order
    pub layer_ids: Vec<usize>,
    pub uses_skip: bool,
    pub save_skip: bool,
    pub in_shape: Vec<usize>,
    pub skip_shape: Option<Vec<usize>>,
    pub out_shape: Vec<usize>,
    pub fwd_exe: String,
    pub recon_exe: String,
}

#[derive(Debug, Clone)]
pub struct GranInfo {
    pub fim_exe: String,
    pub units: Vec<UnitInfo>,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub fp_acc: f64,
    pub weights_prefix: String,
    pub layers: Vec<LayerInfo>,
    pub fwd_exe: String,
    pub act_obs_exe: String,
    pub eval_batch: usize,
    pub grans: HashMap<String, GranInfo>,
    pub qat_exe: Option<String>,
    pub qat_batch: usize,
    pub distill_exe: Option<String>,
    pub distill_batch: usize,
    /// What the logits mean (default: classification).
    pub task: Task,
    /// Dataset override for models that do not consume the manifest's
    /// root dataset (the detection family's scene rasters). Resolve
    /// through `Manifest::dataset_for`, never read directly.
    pub dataset: Option<DatasetInfo>,
    /// Detection-head geometry; present iff `task == Task::Detect`.
    pub det: Option<DetInfo>,
}

impl ModelInfo {
    pub fn layer_index(&self, name: &str) -> usize {
        self.layers
            .iter()
            .position(|l| l.name == name)
            .unwrap_or_else(|| panic!("unknown layer '{name}'"))
    }

    /// First (stem) and last (classifier) layer indices — the layers the
    /// paper keeps at 8-bit by default (§4.2 / Table 6).
    pub fn first_layer(&self) -> usize {
        0
    }

    pub fn last_layer(&self) -> usize {
        self.layers.len() - 1
    }

    pub fn gran(&self, g: &str) -> &GranInfo {
        self.try_gran(g)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Validated granularity lookup: a typed error (instead of a panic
    /// or — worse — a silent fallback) for granularity strings the model
    /// does not export. Every user-facing entry point that accepts a
    /// granularity string routes through this, so a typo like `"blcok"`
    /// or requesting `net` from a model that only exports `layer`/
    /// `block` fails loudly with the declared choices.
    pub fn try_gran(&self, g: &str) -> anyhow::Result<&GranInfo> {
        self.grans.get(g).ok_or_else(|| {
            let mut have: Vec<&str> =
                self.grans.keys().map(|k| k.as_str()).collect();
            have.sort_unstable();
            anyhow::anyhow!(
                "{}: granularity '{g}' is not exported (available: {})",
                self.name,
                have.join("|")
            )
        })
    }

    /// Total weight parameters (excluding biases, like the paper's size
    /// accounting which stores biases at high precision anyway).
    pub fn total_weight_params(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.wshape.iter().product::<usize>() as u64)
            .sum()
    }
}

#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub dir: PathBuf,
    pub img: usize,
    pub classes: usize,
    pub train_n: usize,
    pub test_n: usize,
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

pub struct Manifest {
    pub json: Json,
    pub dir: PathBuf,
    pub calib_batch: usize,
    pub dataset: DatasetInfo,
    pub models: HashMap<String, ModelInfo>,
}

fn parse_dataset(root: &Path, d: &Json) -> DatasetInfo {
    DatasetInfo {
        dir: root.join(d.req("dir").as_str().unwrap()),
        img: d.req("img").as_usize().unwrap(),
        classes: d.req("classes").as_usize().unwrap(),
        train_n: d.req("train_n").as_usize().unwrap(),
        test_n: d.req("test_n").as_usize().unwrap(),
        mean: d.req("mean").f32_vec(),
        std: d.req("std").f32_vec(),
    }
}

fn parse_box(j: &Json) -> [f64; 4] {
    let v: Vec<f64> = j
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    [v[0], v[1], v[2], v[3]]
}

fn parse_det(j: &Json) -> DetInfo {
    DetInfo {
        anchors: j
            .req("anchors")
            .as_arr()
            .unwrap()
            .iter()
            .map(parse_box)
            .collect(),
        scenes: j
            .req("scenes")
            .as_arr()
            .unwrap()
            .iter()
            .map(|sc| {
                sc.as_arr()
                    .unwrap()
                    .iter()
                    .map(|o| DetObj {
                        anchor: o.req("anchor").as_usize().unwrap(),
                        bbox: parse_box(o.req("box")),
                    })
                    .collect()
            })
            .collect(),
    }
}

fn parse_layer(j: &Json) -> LayerInfo {
    LayerInfo {
        name: j.req("name").as_str().unwrap().to_string(),
        kind: j.req("kind").as_str().unwrap().to_string(),
        cin: j.req("cin").as_usize().unwrap(),
        cout: j.req("cout").as_usize().unwrap(),
        k: j.req("k").as_usize().unwrap(),
        stride: j.req("stride").as_usize().unwrap(),
        groups: j.req("groups").as_usize().unwrap(),
        relu: j.req("relu").as_bool().unwrap(),
        site_signed: j.req("site_signed").as_bool().unwrap(),
        h_in: j.req("h_in").as_usize().unwrap(),
        w_in: j.req("w_in").as_usize().unwrap(),
        macs: j.req("macs").as_f64().unwrap() as u64,
        nparams: j.req("nparams").as_f64().unwrap() as u64,
        wshape: j.req("wshape").usize_vec(),
    }
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}"))?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

        let dataset = parse_dataset(dir, json.req("dataset"));

        let mut models = HashMap::new();
        for (name, m) in json.req("models").as_obj().unwrap() {
            let layers: Vec<LayerInfo> = m
                .req("layers")
                .as_arr()
                .unwrap()
                .iter()
                .map(parse_layer)
                .collect();
            let layer_idx: HashMap<&str, usize> = layers
                .iter()
                .enumerate()
                .map(|(i, l)| (l.name.as_str(), i))
                .collect();

            let mut grans = HashMap::new();
            for (g, ge) in m.req("grans").as_obj().unwrap() {
                let units = ge
                    .req("units")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|u| UnitInfo {
                        name: u.req("name").as_str().unwrap().to_string(),
                        topo: u.req("topo").as_str().unwrap().to_string(),
                        layer_ids: u
                            .req("layers")
                            .as_arr()
                            .unwrap()
                            .iter()
                            .map(|l| layer_idx[l.as_str().unwrap()])
                            .collect(),
                        uses_skip: u.req("uses_skip").as_bool().unwrap(),
                        save_skip: u.req("save_skip").as_bool().unwrap(),
                        in_shape: u.req("in_shape").usize_vec(),
                        skip_shape: match u.req("skip_shape") {
                            Json::Null => None,
                            v => Some(v.usize_vec()),
                        },
                        out_shape: u.req("out_shape").usize_vec(),
                        fwd_exe: u.req("fwd_exe").as_str().unwrap().into(),
                        recon_exe: u.req("recon_exe").as_str().unwrap().into(),
                    })
                    .collect();
                grans.insert(
                    g.clone(),
                    GranInfo {
                        fim_exe: ge.req("fim_exe").as_str().unwrap().into(),
                        units,
                    },
                );
            }

            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    fp_acc: m.req("fp_acc").as_f64().unwrap(),
                    weights_prefix: m.req("weights").as_str().unwrap().into(),
                    layers,
                    fwd_exe: m.req("fwd_exe").as_str().unwrap().into(),
                    act_obs_exe: m.req("act_obs_exe").as_str().unwrap().into(),
                    eval_batch: m.req("eval_batch").as_usize().unwrap(),
                    grans,
                    qat_exe: m
                        .get("qat_exe")
                        .and_then(|v| v.as_str())
                        .map(String::from),
                    qat_batch: m
                        .get("qat_batch")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(0),
                    distill_exe: m
                        .get("distill_exe")
                        .and_then(|v| v.as_str())
                        .map(String::from),
                    distill_batch: m
                        .get("distill_batch")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(0),
                    task: match m.get("task").and_then(|v| v.as_str()) {
                        Some("detect") => Task::Detect,
                        _ => Task::Classify,
                    },
                    dataset: m
                        .get("dataset")
                        .filter(|v| !matches!(**v, Json::Null))
                        .map(|d| parse_dataset(dir, d)),
                    det: m
                        .get("det")
                        .filter(|v| !matches!(**v, Json::Null))
                        .map(parse_det),
                },
            );
        }

        Ok(Manifest {
            calib_batch: json.req("calib_batch").as_usize().unwrap(),
            dataset,
            models,
            json,
            dir: dir.to_path_buf(),
        })
    }

    pub fn model(&self, name: &str) -> &ModelInfo {
        self.models
            .get(name)
            .unwrap_or_else(|| panic!("model '{name}' not in manifest"))
    }

    /// The dataset a model trains/evaluates on: its own override when it
    /// declares one (the detection family's scene rasters), else the
    /// manifest's root dataset.
    pub fn dataset_for<'a>(&'a self, model: &'a ModelInfo) -> &'a DatasetInfo {
        model.dataset.as_ref().unwrap_or(&self.dataset)
    }

    /// Width of a model's final-layer output row: the detection head
    /// dimension for `Task::Detect`, else the dataset's class count.
    pub fn out_dim(&self, model: &ModelInfo) -> usize {
        match &model.det {
            Some(det) => det.head_dim(),
            None => self.dataset_for(model).classes,
        }
    }

    pub fn load_weights(&self, model: &ModelInfo) -> Result<Store> {
        Store::load(&self.dir.join(&model.weights_prefix))
    }
}
