//! brecq CLI — the leader entrypoint.
//!
//! Subcommands:
//!   calibrate  — run BRECQ (or a baseline) on one model and report accuracy
//!   eval       — FP accuracy of a model through the AOT eval path
//!   sensitivity— print the per-layer/-pair sensitivity LUT
//!   mp-search  — GA mixed-precision search under a hardware budget
//!   hwsim      — latency/size of a model at a uniform precision
//!   distill    — generate ZeroQ-style distilled calibration data
//!   exp        — regenerate a paper table/figure (table1..table6, fig2,
//!                fig3, fig4, all)

use anyhow::Result;

use brecq::baselines;
use brecq::coordinator::experiments::{self as exp, ExpOpts, Method};
use brecq::coordinator::report::Table;
use brecq::coordinator::Env;
use brecq::distill::DistillConfig;
use brecq::eval::{accuracy, EvalParams};
use brecq::hwsim::{size_mb, ArmCpu, HwMeasure, ModelSize, Systolic};
use brecq::mp::{GaConfig, GeneticSearch};
use brecq::recon::{BitConfig, Calibrator};
use brecq::sensitivity::Profiler;
use brecq::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn opts(a: &Args) -> ExpOpts {
    ExpOpts {
        iters: a.usize("iters", 250),
        calib_n: a.usize("calib", 1024),
        seed: a.u64("seed", 0),
        seeds: a.usize("seeds", 1),
        verbose: a.bool("verbose", false),
    }
}

fn run() -> Result<()> {
    let a = Args::from_env();
    let artifacts = a.opt_str("artifacts");
    // worker-pool size: --threads beats $BRECQ_THREADS beats autodetect;
    // results are identical at any setting (see util::pool)
    let threads = a.usize("threads", 0);
    if threads > 0 {
        brecq::util::pool::set_threads(threads);
    }
    match a.cmd.as_str() {
        "eval" => {
            let env = Env::bootstrap(artifacts)?;
            let mname = a.str("model", "resnet_s");
            let model = env.model(&mname);
            let cal = Calibrator::new(&env.rt, &env.mf, model);
            let (ws, bs) = cal.fp_weights()?;
            let test = env.test_set()?;
            let acc = accuracy(&env.rt, model,
                               &EvalParams::fp(model, &ws, &bs), &test)?;
            println!("{mname}: FP top-1 {:.2}% (train-time reference {:.2}%)",
                     acc * 100.0, model.fp_acc * 100.0);
        }
        "calibrate" => {
            let env = Env::bootstrap(artifacts)?;
            let o = opts(&a);
            let mname = a.str("model", "resnet_s");
            let wbits = a.usize("bits", 4);
            let abits = a.usize("act-bits", 0);
            let method = match a.str("method", "brecq").as_str() {
                "brecq" => Method::Brecq,
                "adaround" => Method::AdaRoundLayer,
                "adaquant" => Method::AdaQuantLike,
                "omse" => Method::Omse,
                "biascorr" => Method::BiasCorr,
                m => anyhow::bail!("unknown method {m}"),
            };
            let gran = a.str("gran", "block");
            let model = env.model(&mname);
            let bits = BitConfig::uniform(
                model, wbits,
                if abits == 0 { None } else { Some(abits) },
                !a.bool("quantize-first-last", false));
            let train = env.train_set()?;
            let calib = env.calib(&train, o.calib_n, o.seed);
            let qm = if method == Method::Brecq && gran != "block" {
                let cal = Calibrator::new(&env.rt, &env.mf, model);
                let cfg = baselines::brecq_cfg(
                    &brecq::recon::ReconConfig {
                        iters: o.iters, seed: o.seed, verbose: o.verbose,
                        ..Default::default()
                    }, &gran);
                cal.calibrate(&calib, &bits, &cfg)?
            } else {
                exp::quantize_with(&env, &mname, method, &calib, &bits, &o)?
            };
            let test = env.test_set()?;
            let acc = accuracy(&env.rt, model, &EvalParams::quantized(&qm),
                               &test)?;
            println!(
                "{mname} {} W{wbits}A{}: top-1 {:.2}% (FP {:.2}%), \
                 calibrated in {:.1}s",
                a.str("method", "brecq"),
                if abits == 0 { "FP".into() } else { abits.to_string() },
                acc * 100.0, model.fp_acc * 100.0, qm.calib_seconds);
            for r in &qm.reports {
                println!("  unit {:<14} loss {:.3e} -> {:.3e} ({} iters)",
                         r.name, r.initial_loss, r.final_loss, r.iters);
            }
        }
        "sensitivity" => {
            let env = Env::bootstrap(artifacts)?;
            let o = opts(&a);
            let mname = a.str("model", "resnet_s");
            let model = env.model(&mname);
            let train = env.train_set()?;
            let calib = env.calib(&train, o.calib_n, o.seed);
            let cal = Calibrator::new(&env.rt, &env.mf, model);
            let (ws, bs) = cal.fp_weights()?;
            let prof = Profiler { rt: &env.rt, mf: &env.mf, model };
            let t = prof.measure(&calib, &ws, &bs, true)?;
            println!("base calib loss: {:.4}", t.base_loss);
            let mut tab = Table::new(
                &format!("Sensitivity LUT — {mname}"),
                &["Layer", "s(4-bit)", "s(2-bit)"]);
            for (l, layer) in model.layers.iter().enumerate() {
                tab.row(vec![layer.name.clone(),
                             format!("{:.5}", t.diag[l][&4]),
                             format!("{:.5}", t.diag[l][&2])]);
            }
            tab.print();
            println!("intra-block off-diagonal (2-bit pairs):");
            for ((x, y), v) in &t.offdiag {
                println!("  {} x {}: {v:+.5}",
                         model.layers[*x].name, model.layers[*y].name);
            }
        }
        "mp-search" => {
            let env = Env::bootstrap(artifacts)?;
            let o = opts(&a);
            let mname = a.str("model", "resnet_s");
            let model = env.model(&mname);
            let hw_kind = a.str("hw", "size");
            let budget = a.f32("budget", 0.0) as f64;
            anyhow::ensure!(budget > 0.0, "--budget required");
            let train = env.train_set()?;
            let calib = env.calib(&train, o.calib_n, o.seed);
            let cal = Calibrator::new(&env.rt, &env.mf, model);
            let (ws, bs) = cal.fp_weights()?;
            let prof = Profiler { rt: &env.rt, mf: &env.mf, model };
            let table = prof.measure(&calib, &ws, &bs, true)?;
            let systolic = Systolic::default();
            let arm = ArmCpu::default();
            let size = ModelSize;
            let hw: &dyn HwMeasure = match hw_kind.as_str() {
                "size" => &size,
                "fpga" => &systolic,
                "arm" => &arm,
                _ => anyhow::bail!("--hw must be size|fpga|arm"),
            };
            let ga = GeneticSearch { model, table: &table, hw, abits: 8,
                                     budget };
            let res = ga.run(&GaConfig { seed: o.seed,
                                         ..Default::default() })?;
            println!("GA best ({} evals, {:.2}s): H(c)={:.4} {}",
                     res.evaluated, res.seconds, res.hw_cost, hw.unit());
            for (l, layer) in model.layers.iter().enumerate() {
                println!("  {:<16} {} bits", layer.name, res.wbits[l]);
            }
        }
        "hwsim" => {
            let env = Env::bootstrap(artifacts)?;
            let mname = a.str("model", "resnet_s");
            let model = env.model(&mname);
            let abits = a.usize("act-bits", 8);
            let mut tab = Table::new(
                &format!("hwsim — {mname} (A{abits})"),
                &["W-bits", "Size (MB)", "FPGA (ms)", "ARM (ms)"]);
            let systolic = Systolic::default();
            let arm_ok = ArmCpu::supports(model);
            let arm = ArmCpu::default();
            for wb in [8usize, 4, 2] {
                let wbits = vec![wb; model.layers.len()];
                tab.row(vec![
                    format!("{wb}"),
                    format!("{:.3}", size_mb(model, &wbits)),
                    format!("{:.2}", systolic.model_ms(model, &wbits,
                                                       abits)),
                    if arm_ok {
                        format!("{:.2}", arm.model_ms(model, &wbits, abits))
                    } else {
                        "n/a (group/dw conv)".into()
                    },
                ]);
            }
            tab.print();
        }
        "distill" => {
            let env = Env::bootstrap(artifacts)?;
            let o = opts(&a);
            let mname = a.str("model", "resnet_s");
            let model = env.model(&mname);
            let dcal = brecq::distill::distill(
                &env.rt, &env.mf, model,
                &DistillConfig {
                    total: a.usize("n", 256),
                    iters: a.usize("distill-iters", 160),
                    seed: o.seed,
                    verbose: o.verbose,
                    ..Default::default()
                })?;
            println!("distilled {} images; label histogram:", dcal.len());
            let mut hist = vec![0usize; env.mf.dataset.classes];
            for &l in &dcal.labels {
                hist[l] += 1;
            }
            println!("  {hist:?}");
        }
        "exp" => {
            let env = Env::bootstrap(artifacts)?;
            let o = opts(&a);
            let which = a.positional.first().cloned()
                .unwrap_or_else(|| "all".into());
            let models = a.list(
                "models", "resnet_s,mobilenetv2_s,regnet_s,mnasnet_s");
            run_exp(&env, &o, &which, &models, &a)?;
            for (name, calls, secs) in env.rt.hotspots(8) {
                eprintln!("[dispatch] {name}: {calls} calls {secs:.1}s");
            }
        }
        "" | "help" => {
            println!("{}", HELP);
        }
        other => {
            anyhow::bail!("unknown subcommand '{other}'\n{HELP}");
        }
    }
    Ok(())
}

fn run_exp(env: &Env, o: &ExpOpts, which: &str, models: &[String],
           a: &Args) -> Result<()> {
    let save = |t: Table, id: &str| -> Result<()> {
        t.print();
        t.save(&env.dir, id)?;
        Ok(())
    };
    match which {
        "table1" => save(exp::table1(env, o)?, "table1")?,
        "table2" => save(exp::table2(env, o, models)?, "table2")?,
        "table3" => save(exp::table3(env, o, models)?, "table3")?,
        "table4" => {
            let steps = a.usize("qat-steps", 600);
            save(exp::table4(env, o, steps)?, "table4")?
        }
        "table6" => save(exp::table6(env, o)?, "table6")?,
        "fig2" => {
            for m in ["resnet_s", "mobilenetv2_s", "regnet_s"] {
                if models.iter().any(|x| x == m)
                    && env.mf.models.contains_key(m) {
                    save(exp::mixed_precision(env, o, m, "size")?,
                         &format!("fig2_size_{m}"))?;
                    save(exp::mixed_precision(env, o, m, "fpga")?,
                         &format!("fig2_fpga_{m}"))?;
                }
            }
        }
        "fig3" => save(exp::fig3(env, o)?, "fig3")?,
        "fig4" => {
            save(exp::mixed_precision(env, o, "resnet_s", "arm")?,
                 "fig4_arm_resnet_s")?
        }
        "all" => {
            for w in ["table1", "table2", "table3", "table4", "table6",
                      "fig2", "fig3", "fig4"] {
                run_exp(env, o, w, models, a)?;
            }
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

const HELP: &str = "brecq — BRECQ post-training quantization (ICLR 2021)

USAGE: brecq <cmd> [--flags]

  eval        --model M
  calibrate   --model M --bits B [--act-bits A] [--method brecq|adaround|
              adaquant|omse|biascorr] [--gran layer|block|stage|net]
              [--iters N] [--calib K] [--seed S] [--verbose]
  sensitivity --model M
  mp-search   --model M --hw size|fpga|arm --budget X
  hwsim       --model M [--act-bits A]
  distill     --model M --n K
  exp         <table1|table2|table3|table4|table6|fig2|fig3|fig4|all>
              [--models a,b,c] [--iters N] [--seeds S] [--qat-steps N]

Global: --artifacts DIR (default ./artifacts or $BRECQ_ARTIFACTS)
        --threads N   worker-pool size (default $BRECQ_THREADS or auto);
                      results are bit-identical at any thread count";
