//! brecq CLI — every subcommand is a thin view over [`brecq::pipeline`].
//!
//! Subcommands:
//!   calibrate  — run BRECQ (or a baseline) on one model and report accuracy
//!   eval       — FP accuracy of a model through the AOT eval path
//!   sensitivity— print the per-layer/-pair sensitivity LUT
//!   mp-search  — GA mixed-precision search under a hardware budget
//!   hwsim      — latency/size of a model at a uniform precision
//!   distill    — generate ZeroQ-style distilled calibration data
//!   run        — execute a JSON batch of JobSpecs through one
//!                cache-aware session (see examples/jobs.json)
//!   serve      — job daemon: accept JobSpec batches over a unix socket,
//!                schedule them on the worker pool, stream progress events
//!   submit     — client for `serve`: send a jobs.json to a running daemon
//!   ctl        — one-shot daemon control (ping / stats / shutdown)
//!   exp        — regenerate a paper table/figure; `exp list` enumerates
//!                the available outputs
//!
//! The CLI owns flag parsing and printing only; method/granularity/
//! hardware dispatch, stage ordering and artifact reuse all live in the
//! typed pipeline (`Session` + `JobSpec`). `--store DIR` (or
//! `$BRECQ_STORE`) layers the persistent content-addressed artifact store
//! under the session cache so runs replay across processes.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use brecq::coordinator::experiments::{self as exp, ExpOpts};
use brecq::coordinator::report::Table;
use brecq::coordinator::Env;
use brecq::distill::DistillConfig;
use brecq::pipeline::{self, ArtifactStore, DataSource, Granularity,
                      Hardware, JobSpec, Method, Session};
use brecq::util::cli::Args;
use brecq::util::json;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn opts(a: &Args) -> ExpOpts {
    ExpOpts {
        iters: a.usize("iters", 250),
        calib_n: a.usize("calib", 1024),
        seed: a.u64("seed", 0),
        seeds: a.usize("seeds", 1),
        verbose: a.bool("verbose", false),
    }
}

fn session(
    artifacts: Option<String>,
    store: Option<&str>,
) -> Result<Session> {
    let env = Env::bootstrap(artifacts)?;
    Ok(match store {
        Some(dir) => {
            Session::with_store(env, Arc::new(ArtifactStore::open(dir)?))
        }
        None => Session::new(env),
    })
}

fn run() -> Result<()> {
    let a = Args::from_env();
    let artifacts = a.opt_str("artifacts");
    // persistent artifact store: --store beats $BRECQ_STORE beats none
    // (sessions without a store keep the in-memory cache only)
    let store = a
        .opt_str("store")
        .or_else(|| std::env::var("BRECQ_STORE").ok());
    let store = store.as_deref();
    // worker-pool size: --threads beats $BRECQ_THREADS beats autodetect;
    // results are identical at any setting (see util::pool)
    let threads = a.usize("threads", 0);
    if threads > 0 {
        brecq::util::pool::set_threads(threads);
    }
    match a.cmd.as_str() {
        "eval" => {
            let s = session(artifacts, store)?;
            let mname = a.str("model", "resnet_s");
            let spec = JobSpec {
                model: mname.clone(),
                method: Method::Fp,
                ..JobSpec::default()
            };
            let out = s.run(&spec)?;
            println!(
                "{mname}: FP top-1 {:.2}% (train-time reference {:.2}%)",
                out.accuracy.unwrap_or(0.0) * 100.0,
                out.fp_acc * 100.0
            );
        }
        "calibrate" => {
            let s = session(artifacts, store)?;
            let o = opts(&a);
            let abits = a.usize("act-bits", 0);
            let spec = JobSpec {
                model: a.str("model", "resnet_s"),
                method: Method::parse(&a.str("method", "brecq"))?,
                gran: Granularity::parse(&a.str("gran", "block"))?,
                wbits: a.usize("bits", 4),
                abits: if abits == 0 { None } else { Some(abits) },
                first_last_8: !a.bool("quantize-first-last", false),
                iters: o.iters,
                calib_n: o.calib_n,
                seed: o.seed,
                source: DataSource::parse(&a.str("data", "train"))?,
                verbose: o.verbose,
                ..JobSpec::default()
            };
            let out = s.run(&spec)?;
            println!(
                "{} {} {}: top-1 {:.2}% (FP {:.2}%), calibrated in {:.1}s",
                spec.model,
                spec.method.as_str(),
                out.bits_label(),
                out.accuracy.unwrap_or(0.0) * 100.0,
                out.fp_acc * 100.0,
                out.calib_seconds()
            );
            for r in out.reports() {
                println!("  unit {:<14} loss {:.3e} -> {:.3e} ({} iters)",
                         r.name, r.initial_loss, r.final_loss, r.iters);
            }
        }
        "sensitivity" => {
            let s = session(artifacts, store)?;
            let o = opts(&a);
            let mname = a.str("model", "resnet_s");
            let t = s.sensitivity(&mname, DataSource::Train, o.calib_n,
                                  o.seed)?;
            let model = s.model(&mname)?;
            println!("base calib loss: {:.4}", t.base_loss);
            let mut tab = Table::new(
                &format!("Sensitivity LUT — {mname}"),
                &["Layer", "s(4-bit)", "s(2-bit)"]);
            for (l, layer) in model.layers.iter().enumerate() {
                tab.row(vec![layer.name.clone(),
                             format!("{:.5}", t.diag[l][&4]),
                             format!("{:.5}", t.diag[l][&2])]);
            }
            tab.print();
            println!("intra-block off-diagonal (2-bit pairs):");
            for ((x, y), v) in &t.offdiag {
                println!("  {} x {}: {v:+.5}",
                         model.layers[*x].name, model.layers[*y].name);
            }
        }
        "mp-search" => {
            let s = session(artifacts, store)?;
            let o = opts(&a);
            let mname = a.str("model", "resnet_s");
            let hw = Hardware::parse(&a.str("hw", "size"))?;
            let budget = a.f32("budget", 0.0) as f64;
            let res = s.mp_search(&mname, hw, budget, o.calib_n, o.seed)?;
            let model = s.model(&mname)?;
            println!("GA best ({} evals, {:.2}s): H(c)={:.4} {}",
                     res.evaluated, res.seconds, res.hw_cost,
                     hw.measurer().unit());
            for (l, layer) in model.layers.iter().enumerate() {
                println!("  {:<16} {} bits", layer.name, res.wbits[l]);
            }
        }
        "hwsim" => {
            let s = session(artifacts, store)?;
            let mname = a.str("model", "resnet_s");
            let model = s.model(&mname)?;
            let abits = a.usize("act-bits", 8);
            let mut tab = Table::new(
                &format!("hwsim — {mname} (A{abits})"),
                &["W-bits", "Size (MB)", "FPGA (ms)", "ARM (ms)"]);
            for wb in [8usize, 4, 2] {
                let wbits = vec![wb; model.layers.len()];
                let r = pipeline::hw_report(model, &wbits, abits);
                tab.row(vec![
                    format!("{wb}"),
                    format!("{:.3}", r.size_mb),
                    format!("{:.2}", r.fpga_ms),
                    match r.arm_ms {
                        Some(ms) => format!("{ms:.2}"),
                        None => "n/a (group/dw conv)".into(),
                    },
                ]);
            }
            tab.print();
        }
        "distill" => {
            let s = session(artifacts, store)?;
            let o = opts(&a);
            let mname = a.str("model", "resnet_s");
            let dcal = s.distill(&mname, &DistillConfig {
                total: a.usize("n", 256),
                iters: a.usize("distill-iters", 160),
                seed: o.seed,
                verbose: o.verbose,
                ..Default::default()
            })?;
            println!("distilled {} images; label histogram:", dcal.len());
            let mut hist = vec![0usize; s.env().mf.dataset.classes];
            for &l in &dcal.labels {
                hist[l] += 1;
            }
            println!("  {hist:?}");
        }
        "run" => {
            let path = a.positional.first().cloned().ok_or_else(|| {
                anyhow::anyhow!("usage: brecq run <jobs.json>\n{HELP}")
            })?;
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            let specs = JobSpec::parse_jobs(&text)?;
            let s = session(artifacts, store)?;
            println!("[run] {} jobs from {path} (threads: {})",
                     specs.len(), brecq::util::pool::threads());
            let results = s.run_many(&specs);
            let mut tab = Table::new(
                &format!("brecq run — {path}"),
                &["#", "Model", "Method", "Bits", "Top-1 %", "H(c)",
                  "Seconds"]);
            let mut failed = 0usize;
            for (i, r) in results.iter().enumerate() {
                match r {
                    Ok(out) => tab.row(vec![
                        format!("{i}"),
                        out.spec.model.clone(),
                        out.spec.method.as_str().into(),
                        out.bits_label(),
                        match out.accuracy {
                            Some(acc) => format!("{:.2}", acc * 100.0),
                            None => "-".into(),
                        },
                        match &out.search {
                            Some(res) => format!("{:.4}", res.hw_cost),
                            None => "-".into(),
                        },
                        format!("{:.1}", out.seconds),
                    ]),
                    Err(e) => {
                        failed += 1;
                        tab.row(vec![
                            format!("{i}"),
                            specs[i].model.clone(),
                            specs[i].method.as_str().into(),
                            "-".into(),
                            format!("error: {e}"),
                            "-".into(),
                            "-".into(),
                        ])
                    }
                }
            }
            tab.print();
            let (hits, misses) = s.cache().stats();
            println!("artifact cache: {hits} hits / {misses} misses");
            if let Some(st) = s.cache().store() {
                let ss = st.stats();
                println!(
                    "artifact store: {} hits / {} misses / {} publishes \
                     / {} corrupt ({} entries at {})",
                    ss.hits, ss.misses, ss.publishes, ss.corrupt,
                    st.len(), st.dir().display()
                );
                println!(
                    "checkpoints: {} written / {} units resumed / {} \
                     corrupt",
                    s.cache().ckpt_written(),
                    s.cache().units_resumed(),
                    s.cache().ckpt_corrupt(),
                );
            }
            // --stats: per-slot outcome tallies — which cache keys were
            // served from memory, from the store, or computed fresh
            if a.bool("stats", false) {
                let mut st = Table::new(
                    "per-slot cache outcomes",
                    &["Key", "Hit", "Store hit", "Computed", "Loaded",
                      "Resumed"]);
                for (key, ss) in s.cache().per_key_stats() {
                    st.row(vec![
                        key,
                        ss.hits.to_string(),
                        ss.store_hits.to_string(),
                        ss.computes.to_string(),
                        ss.loads.to_string(),
                        ss.resumed.to_string(),
                    ]);
                }
                st.print();
            }
            // --json OUT: machine-readable results + counters (the serve
            // smoke test diffs these fingerprints against daemon runs)
            if let Some(out_path) = a.opt_str("json") {
                let jobs: Vec<json::Json> = results
                    .iter()
                    .enumerate()
                    .map(|(i, r)| match r {
                        Ok(out) => out.to_json(),
                        Err(e) => json::obj(vec![
                            ("model", json::s(&specs[i].model)),
                            ("error", json::s(&format!("{e}"))),
                        ]),
                    })
                    .collect();
                let mut top = vec![
                    ("jobs", json::arr(jobs)),
                    ("cache_hits", json::num(hits as f64)),
                    ("cache_misses", json::num(misses as f64)),
                    ("computes",
                     json::num(s.cache().computes() as f64)),
                    ("store_hits",
                     json::num(s.cache().store_hits() as f64)),
                    ("units_resumed",
                     json::num(s.cache().units_resumed() as f64)),
                    ("ckpt_written",
                     json::num(s.cache().ckpt_written() as f64)),
                    ("ckpt_corrupt",
                     json::num(s.cache().ckpt_corrupt() as f64)),
                ];
                if let Some(st) = s.cache().store() {
                    let ss = st.stats();
                    top.push(("store_publishes",
                              json::num(ss.publishes as f64)));
                    top.push(("store_corrupt",
                              json::num(ss.corrupt as f64)));
                }
                std::fs::write(&out_path, json::obj(top).to_string())
                    .map_err(|e| anyhow::anyhow!(
                        "writing {out_path}: {e}"))?;
                println!("[run] wrote {out_path}");
            }
            anyhow::ensure!(
                failed == 0,
                "{failed} of {} jobs failed",
                specs.len()
            );
        }
        #[cfg(unix)]
        "serve" => {
            let sock = PathBuf::from(a.str("sock", "brecq.sock"));
            let workers = a.usize("workers", 0);
            if brecq::util::faults::armed() {
                eprintln!(
                    "[serve] WARNING: fault injection armed \
                     (BRECQ_FAULTS is set) — chaos-testing mode"
                );
            }
            let s = session(artifacts, store)?;
            pipeline::serve::serve(s, &sock, workers)?;
        }
        #[cfg(unix)]
        "submit" => {
            let path = a.positional.first().cloned().ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: brecq submit <jobs.json> --sock PATH\n{HELP}"
                )
            })?;
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            let specs = JobSpec::parse_jobs(&text)?;
            let sock = PathBuf::from(a.str("sock", "brecq.sock"));
            let priority = a.f32("priority", 0.0) as i64;
            let quiet = a.bool("quiet", false);
            // 0 (the default) waits forever; otherwise a typed timeout
            let t = a.usize("timeout", 0);
            let timeout =
                (t > 0).then(|| std::time::Duration::from_secs(t as u64));
            let summary = pipeline::serve::submit(
                &sock, &specs, priority, timeout, |ev| {
                    if !quiet {
                        println!("{}", ev.to_string());
                    }
                })?;
            let failed = summary
                .results
                .iter()
                .filter(|r| r.is_err())
                .count();
            for (i, r) in summary.results.iter().enumerate() {
                match r {
                    Ok(out) => println!(
                        "[submit] job {i}: ok fingerprint={}",
                        out.get("fingerprint")
                            .and_then(|f| f.as_str())
                            .unwrap_or("?")
                    ),
                    Err(e) => println!("[submit] job {i}: error: {e}"),
                }
            }
            if let Some(out_path) = a.opt_str("json") {
                let jobs: Vec<json::Json> = summary
                    .results
                    .iter()
                    .map(|r| match r {
                        Ok(out) => out.clone(),
                        Err(e) => json::obj(vec![
                            ("error", json::s(e)),
                        ]),
                    })
                    .collect();
                let top = json::obj(vec![
                    ("jobs", json::arr(jobs)),
                    ("done", summary.done.clone()),
                ]);
                std::fs::write(&out_path, top.to_string()).map_err(
                    |e| anyhow::anyhow!("writing {out_path}: {e}"))?;
                println!("[submit] wrote {out_path}");
            }
            println!("[submit] done: {}", summary.done.to_string());
            anyhow::ensure!(
                failed == 0,
                "{failed} of {} jobs failed",
                summary.results.len()
            );
        }
        #[cfg(unix)]
        "ctl" => {
            let op = a.positional.first().cloned().ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: brecq ctl <ping|stats|shutdown|cancel BATCH> \
                     --sock PATH"
                )
            })?;
            let sock = PathBuf::from(a.str("sock", "brecq.sock"));
            let reply = if op == "cancel" {
                let id = a
                    .positional
                    .get(1)
                    .and_then(|s| s.parse::<f64>().ok())
                    .ok_or_else(|| anyhow::anyhow!(
                        "usage: brecq ctl cancel <batch-id> --sock PATH \
                         (the id from the submit 'accepted' event)"
                    ))?;
                pipeline::serve::control_fields(
                    &sock, "cancel", vec![("batch", json::num(id))])?
            } else {
                pipeline::serve::control(&sock, &op)?
            };
            println!("{}", reply.to_string());
        }
        "exp" => {
            let which = a.positional.first().cloned()
                .unwrap_or_else(|| "all".into());
            if which == "list" {
                print_exp_list();
                return Ok(());
            }
            let s = session(artifacts, store)?;
            let o = opts(&a);
            let models = a.list(
                "models", "resnet_s,mobilenetv2_s,regnet_s,mnasnet_s");
            // --out redirects the rendered reports (kick-tires.sh points
            // it at artifacts/out/<git-sha>); default keeps the
            // environment's own reports/ directory
            let out = a
                .opt_str("out")
                .map(PathBuf::from)
                .unwrap_or_else(|| s.env().dir.clone());
            run_exp(&s, &o, &which, &models, &a, &out)?;
            for (name, calls, secs) in s.env().rt.hotspots(8) {
                eprintln!("[dispatch] {name}: {calls} calls {secs:.1}s");
            }
        }
        "" | "help" => {
            println!("{}", HELP);
        }
        other => {
            anyhow::bail!("unknown subcommand '{other}'\n{HELP}");
        }
    }
    Ok(())
}

/// `exp all`'s table order (also what kick-tires.sh regenerates).
const ALL_EXPS: [&str; 9] = [
    "table1", "table2", "table3", "table4", "table5", "table6", "fig2",
    "fig3", "fig4",
];

/// `exp list`: every runnable output.
fn print_exp_list() {
    let mut tab = Table::new(
        "exp — available outputs (paper tables & figures)",
        &["Id", "Paper", "Regenerates"]);
    for (id, paper, what) in [
        ("table1", "Table 1",
         "granularity ablation at 2-bit weights \
          (layer/block/stage/net/pack)"),
        ("table2", "Table 2",
         "weight-only PTQ comparison, W4/W3/W2, activations FP"),
        ("table3", "Table 3",
         "fully quantized PTQ comparison, W4A4 and W2A4"),
        ("table4", "Table 4",
         "PTQ vs LSQ-QAT: accuracy, data need and wall-clock"),
        ("table5", "Table 5",
         "detection backbone PTQ (mAP) on the synthetic det_s workload, \
          W4A8 and W2A8"),
        ("table6", "Table 6 / B.1",
         "first/last-layer 8-bit policy ablation"),
        ("fig2", "Fig. 2",
         "mixed precision under model-size and FPGA latency budgets"),
        ("fig3", "Fig. 3 / B.2",
         "calibration-set size and real-vs-distilled data source"),
        ("fig4", "Fig. 4",
         "mixed precision under ARM CPU latency budgets (ResNet only)"),
        ("all", "—", "everything above, in order"),
    ] {
        tab.row(vec![id.into(), paper.into(), what.into()]);
    }
    tab.print();
    println!(
        "table5 runs the paper's detection benchmark on a synthetic \
         scene workload, not MS COCO — see EXPERIMENTS.md for the \
         fidelity caveats."
    );
}

fn run_exp(s: &Session, o: &ExpOpts, which: &str, models: &[String],
           a: &Args, out: &Path) -> Result<()> {
    // table1 runs through the session (persistent-store-aware); the other
    // drivers still take the raw Env until they migrate
    let env = s.env();
    let save = |t: Table, id: &str| -> Result<()> {
        t.print();
        t.save(out, id)?;
        Ok(())
    };
    match which {
        "table1" => save(exp::table1(s, o)?, "table1")?,
        "table2" => save(exp::table2(env, o, models)?, "table2")?,
        "table3" => save(exp::table3(env, o, models)?, "table3")?,
        "table4" => {
            let steps = a.usize("qat-steps", 600);
            save(exp::table4(env, o, steps)?, "table4")?
        }
        "table5" => save(exp::table5(env, o)?, "table5")?,
        "table6" => save(exp::table6(env, o)?, "table6")?,
        "fig2" => {
            for m in ["resnet_s", "mobilenetv2_s", "regnet_s"] {
                if models.iter().any(|x| x == m)
                    && env.mf.models.contains_key(m) {
                    save(exp::mixed_precision(env, o, m, Hardware::Size)?,
                         &format!("fig2_size_{m}"))?;
                    save(exp::mixed_precision(env, o, m, Hardware::Fpga)?,
                         &format!("fig2_fpga_{m}"))?;
                }
            }
        }
        "fig3" => save(exp::fig3(env, o)?, "fig3")?,
        "fig4" => {
            save(exp::mixed_precision(env, o, "resnet_s", Hardware::Arm)?,
                 "fig4_arm_resnet_s")?
        }
        "all" => {
            // every table runs even when an earlier one fails — a broken
            // runner must not hide the outputs after it (kick-tires.sh
            // depends on this for its completeness manifest) — and the
            // per-table verdicts land in one summary before the non-zero
            // exit
            let mut failed: Vec<String> = Vec::new();
            for w in ALL_EXPS {
                match run_exp(s, o, w, models, a, out) {
                    Ok(()) => println!("[exp] {w}: ok"),
                    Err(e) => {
                        println!("[exp] {w}: FAIL — {e:#}");
                        failed.push(w.to_string());
                    }
                }
            }
            anyhow::ensure!(
                failed.is_empty(),
                "exp all: {}/{} tables failed: {}",
                failed.len(),
                ALL_EXPS.len(),
                failed.join(", ")
            );
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (try `brecq exp list`)"
        ),
    }
    Ok(())
}

const HELP: &str = "brecq — BRECQ post-training quantization (ICLR 2021)

USAGE: brecq <cmd> [--flags]

  eval        --model M
  calibrate   --model M --bits B [--act-bits A] [--method fp|brecq|
              adaround|adaquant|omse|biascorr] [--gran layer|block|
              stage|net|pack] [--data train|distilled] [--iters N]
              [--calib K] [--seed S] [--verbose]
  sensitivity --model M
  mp-search   --model M --hw size|fpga|arm --budget X
  hwsim       --model M [--act-bits A]
  distill     --model M --n K
  run         <jobs.json> [--stats] [--json OUT]
              batch mode: a JSON array of job specs runs through one
              cache-aware pipeline session (shared FP weights, calib sets
              and sensitivity LUTs); see examples/jobs.json. --stats
              prints per-slot hit/store-hit/compute tallies; --json OUT
              writes results + counters machine-readably
  serve       --sock PATH [--workers N]   job daemon: accepts JobSpec
              batches over a unix socket, fair-shares them across client
              connections on the worker pool, streams NDJSON progress
              events; SIGINT/SIGTERM drain and exit cleanly. Pair with
              --store DIR so results persist across daemon restarts.
              Jobs run panic-isolated; with a store, in-flight batches
              are journalled and a restarted daemon finishes them —
              reconstruction resumes from per-unit checkpoints, bitwise
              identical to an uninterrupted run. $BRECQ_FAULTS arms
              deterministic fault injection (see DESIGN.md, chaos
              testing only)
  submit      <jobs.json> --sock PATH [--priority P] [--json OUT]
              [--quiet] [--timeout SECS]   send a batch to a running
              daemon and stream its events; exits non-zero if any job
              failed. --timeout bounds the whole wait (default: wait
              forever) and sends a best-effort 'ctl cancel' on expiry —
              finished units stay checkpointed, so resubmitting resumes;
              a daemon that dies mid-batch is reported as a connection
              EOF, distinct from per-job failures
  ctl         <ping|stats|shutdown|cancel BATCH> --sock PATH   one-shot
              daemon control; cancel stops a batch by the id from its
              'accepted' event (running jobs stop at the next stage or
              iteration boundary; finished units stay checkpointed for
              resume). stats reports cache/store counters plus
              units_resumed / ckpt_written / ckpt_corrupt
  exp         <list|table1|table2|table3|table4|table5|table6|fig2|fig3|
              fig4|all> [--models a,b,c] [--iters N] [--seeds S]
              [--qat-steps N] [--out DIR]
              `exp list` shows what each id regenerates; `exp all` runs
              every table, reports per-table pass/fail and exits non-zero
              if any failed. table5 is the paper's detection benchmark on
              the synthetic det_s workload (see EXPERIMENTS.md); --out
              redirects the rendered reports (scripts/kick-tires.sh uses
              artifacts/out/<git-sha>).

Global: --artifacts DIR (default ./artifacts or $BRECQ_ARTIFACTS)
        --store DIR   persistent content-addressed artifact store
                      (default $BRECQ_STORE or none): cached stages replay
                      bit-identically across processes with zero backend
                      work
        --threads N   worker-pool size (default $BRECQ_THREADS or auto);
                      results are bit-identical at any thread count";
