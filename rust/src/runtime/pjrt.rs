//! PJRT backend (cargo feature `pjrt`): loads AOT HLO-text artifacts and
//! executes them through the `xla` crate.
//!
//! This is the only module that touches `xla`. The pattern
//! (HLO text -> HloModuleProto -> XlaComputation -> compile -> execute)
//! follows /opt/xla-example/load_hlo.rs; text is the interchange format
//! because xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id protos.
//!
//! Executables are compiled lazily and cached per name — experiments touch
//! only the units they need, and repeated calibrations reuse the cache.
//! ABI validation and dispatch accounting live in the shared
//! [`Backend::run`](super::Backend::run) wrapper.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;

use super::{parse_sigs, Backend, Dispatches, ExeSig};

pub struct Executable {
    pub sig: ExeSig,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with positional tensors (already validated against the
    /// manifest signature by [`Backend::run`]).
    fn run_raw(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let mut literals = Vec::with_capacity(args.len());
        for (t, (name, _)) in args.iter().zip(&self.sig.inputs) {
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .with_context(|| format!("reshaping input {name}"))?,
            );
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // AOT lowering uses return_tuple=True: always a tuple literal.
        let parts = result.to_tuple()?;
        if parts.len() != self.sig.outputs.len() {
            bail!(
                "{}: got {} outputs, signature has {}",
                self.sig.name,
                parts.len(),
                self.sig.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, (name, shape)) in parts.iter().zip(&self.sig.outputs) {
            let data = lit
                .to_vec::<f32>()
                .with_context(|| format!("reading output {name}"))?;
            out.push(Tensor::new(shape.clone(), data));
        }
        Ok(out)
    }
}

pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    sigs: HashMap<String, ExeSig>,
    // Mutex/Arc (not RefCell/Rc): `Backend: Sync` since the worker pool
    // dispatches executables concurrently.
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    dispatches: Dispatches,
}

impl PjrtRuntime {
    /// `dir` is the artifacts directory containing manifest.json.
    pub fn new(dir: &Path, manifest: &Json) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()?;
        let sigs = parse_sigs(manifest)?;
        Ok(PjrtRuntime {
            client,
            dir: dir.to_path_buf(),
            sigs,
            cache: Mutex::new(HashMap::new()),
            dispatches: Dispatches::new(),
        })
    }

    /// Compile (or fetch from cache) an executable by manifest name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let sig = self
            .sigs
            .get(name)
            .with_context(|| format!("unknown executable '{name}'"))?
            .clone();
        let path = self.dir.join(&sig.file);
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parsing HLO {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let e = Arc::new(Executable { sig, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), e.clone());
        Ok(e)
    }
}

impl Backend for PjrtRuntime {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn signature(&self, name: &str) -> Option<&ExeSig> {
        self.sigs.get(name)
    }

    fn execute(&self, name: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?.run_raw(args)
    }

    fn dispatches(&self) -> &Dispatches {
        &self.dispatches
    }

    fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
