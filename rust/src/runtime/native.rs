//! Pure-Rust native backend: executes every executable family the manifest
//! names — `unit_fwd`, `unit_recon` (loss forward + AdaRound/LSQ analytic
//! gradients), model `fwd` (eval), `act_obs` and `fim` — with no XLA
//! toolchain or AOT artifacts.
//!
//! The quantizer math is a direct port of the pure-jnp oracles in
//! `python/compile/kernels/ref.py` (the kernels' correctness ground truth):
//! rectified-sigmoid AdaRound (Eq. 16), LSQ with STE gradients (Eq. 18) and
//! the FIM-weighted reconstruction loss (Eq. 10). Layer semantics are
//! NCHW/OIHW grouped convolution with TF-style SAME padding — matching
//! `jax.lax.conv_general_dilated(..., 'SAME')` in `python/compile/nets.py`
//! — plus fc, global-average-pool and softmax cross-entropy, each with a
//! hand-written backward pass.
//!
//! Unit graphs are reconstructed from the manifest alone: the `topo` tag of
//! every unit (`conv`, `basic(...)`, `basic_l2(...)`, `ir(...)`, `ir_l3(res)`,
//! `seq(...)`, `gap_fc`) is parsed into a node program over the unit's
//! layer list. Unsupported topologies (e.g. `xblock` from the full PJRT
//! export) fail loudly at backend construction — use the `pjrt` feature for
//! those artifacts.
//!
//! Layer compute is GEMM-ified: `conv2d` runs as per-sample im2col +
//! the shared blocked micro-kernel ([`super::gemm`]), `conv2d_bwd` as a
//! flipped-weight GEMM over gathered gradient columns (`gx`) plus an
//! ordered batch fold of `gout x im2col^T` GEMMs (`gw`), and `fc` both
//! ways through the same kernel. All scratch (im2col panels, packed
//! operands, the shared transposed-col slab) comes from the recycling
//! arenas in [`crate::util::pool`], so steady-state reconstruction steps
//! allocate nothing beyond their output tensors.
//!
//! The hot paths run on the [`crate::util::pool`] worker pool: conv2d
//! fans out per sample, its backward per sample (`gx`) and per
//! out-channel block (`gw`), and the model-level executables
//! (`eval_fwd`, `act_obs`, `fim`) split their batch into per-sample
//! chunks. Every parallel path is **bit-identical** to the retained
//! scalar reference loops at any `BRECQ_THREADS` value — work is
//! partitioned by ownership and each output element's reduction runs in
//! the scalar loop's order (see the im2col parity note below, the gemm
//! module's determinism contract and `tests/parallel.rs`).

// Kernel loops index several buffers with shared offset arithmetic; the
// iterator forms clippy suggests obscure the stencil math.
#![allow(clippy::needless_range_loop)]

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::model::{LayerInfo, Manifest, ModelInfo, Task, UnitInfo};
use crate::tensor::Tensor;
use crate::util::pool;

use super::{gemm, parse_sigs, Backend, Dispatches, ExeSig};

pub const ZETA: f32 = 1.1;
pub const GAMMA: f32 = -0.1;

// ------------------------------------------------------------------
// Kernel ports of python/compile/kernels/ref.py (scalar form)
// ------------------------------------------------------------------

/// Rectified sigmoid h(v) from AdaRound (Nagel et al. 2020).
pub fn rect_sigmoid(v: f32) -> f32 {
    let s = 1.0 / (1.0 + (-v).exp());
    (s * (ZETA - GAMMA) + GAMMA).clamp(0.0, 1.0)
}

/// dh/dv — zero in the rectified (clipped) region.
pub fn rect_sigmoid_grad(v: f32) -> f32 {
    let s = 1.0 / (1.0 + (-v).exp());
    let h = s * (ZETA - GAMMA) + GAMMA;
    if h > 0.0 && h < 1.0 {
        s * (1.0 - s) * (ZETA - GAMMA)
    } else {
        0.0
    }
}

/// AdaRound soft fake-quant (Eq. 16): s * clip(floor(w/s) + h(v), n, p).
pub fn adaround(w: f32, step: f32, v: f32, n: f32, p: f32) -> f32 {
    step * ((w / step).floor() + rect_sigmoid(v)).clamp(n, p)
}

/// VJP of [`adaround`] wrt v: gout * s * 1{n < floor(w/s)+h(v) < p} * h'(v).
pub fn adaround_grad_v(
    w: f32,
    step: f32,
    v: f32,
    n: f32,
    p: f32,
    gout: f32,
) -> f32 {
    let g = (w / step).floor() + rect_sigmoid(v);
    if g > n && g < p {
        gout * step * rect_sigmoid_grad(v)
    } else {
        0.0
    }
}

/// Hard-rounding commit: h(v) binarized at 0.5.
pub fn adaround_hard(w: f32, step: f32, v: f32, n: f32, p: f32) -> f32 {
    let up = if rect_sigmoid(v) >= 0.5 { 1.0 } else { 0.0 };
    step * ((w / step).floor() + up).clamp(n, p)
}

/// LSQ fake-quant (Eq. 18 forward): s * clip(round(x/s), qmin, qmax).
pub fn lsq(x: f32, step: f32, qmin: f32, qmax: f32) -> f32 {
    step * (x / step).round().clamp(qmin, qmax)
}

/// LSQ VJP wrt (x, step) per Eq. 18. Returns (gx, per-element step-grad
/// contribution); the caller sums the latter into the scalar step grad.
pub fn lsq_grads(
    x: f32,
    step: f32,
    qmin: f32,
    qmax: f32,
    gout: f32,
) -> (f32, f32) {
    let xs = x / step;
    if xs <= qmin {
        (0.0, gout * qmin)
    } else if xs >= qmax {
        (0.0, gout * qmax)
    } else {
        (gout, gout * (xs.round() - xs))
    }
}

/// Plain nearest-rounding fake quant (round-STE forward).
pub fn round_ste(w: f32, step: f32, n: f32, p: f32) -> f32 {
    step * (w / step).round().clamp(n, p)
}

/// One fused gv + rounding-regularizer element — the single definition
/// shared by `exec_unit_recon`'s sequential pass and the plan engine's
/// channel-parallel pass (`super::plan`), so the two paths cannot
/// drift. Evaluates the rectified sigmoid once and returns
/// (`1 - |2h(v)-1|^beta` as the f64 regularizer term,
/// `gout * s * 1{inside} * h'(v) + lam * d(reg)/dv` as the gv element) —
/// bit-identical to composing [`adaround_grad_v`] with the standalone
/// regularizer loop.
#[inline]
pub(crate) fn gv_reg_elem(
    w: f32,
    s: f32,
    ve: f32,
    wn: f32,
    wp: f32,
    gout: f32,
    beta: f32,
    lam: f32,
) -> (f64, f32) {
    let sig = 1.0 / (1.0 + (-ve).exp());
    let h = (sig * (ZETA - GAMMA) + GAMMA).clamp(0.0, 1.0);
    let t = 2.0 * h - 1.0;
    let term = 1.0 - (t.abs() as f64).powf(beta as f64);
    let hp = if h > 0.0 && h < 1.0 {
        sig * (1.0 - sig) * (ZETA - GAMMA)
    } else {
        0.0
    };
    let gt = (w / s).floor() + h;
    let mut g = if gt > wn && gt < wp { gout * s * hp } else { 0.0 };
    if lam > 0.0 {
        let dr =
            -(beta) * t.abs().powf(beta - 1.0) * t.signum() * 2.0 * hp;
        g += lam * dr;
    }
    (term, g)
}

/// FIM-weighted squared error (Eq. 10), averaged over the leading batch dim.
pub fn fim_loss(z: &Tensor, zq: &Tensor, fim: &Tensor) -> f64 {
    let b = z.shape[0] as f64;
    let mut acc = 0f64;
    for i in 0..z.data.len() {
        let d = (z.data[i] - zq.data[i]) as f64;
        acc += fim.data[i] as f64 * d * d;
    }
    acc / b
}

/// VJP of [`fim_loss`] wrt zq (gout = 1): -2/B * fim * (z - zq).
pub fn fim_loss_grad_zq(z: &Tensor, zq: &Tensor, fim: &Tensor) -> Tensor {
    let b = z.shape[0] as f32;
    let data = (0..z.data.len())
        .map(|i| -2.0 / b * fim.data[i] * (z.data[i] - zq.data[i]))
        .collect();
    Tensor::new(zq.shape.clone(), data)
}

// ------------------------------------------------------------------
// Dense layer primitives (forward + backward)
// ------------------------------------------------------------------

/// TF/XLA 'SAME' padding: (out_size, low_pad) for one spatial dim.
pub(crate) fn same_pads(h: usize, k: usize, s: usize) -> (usize, i64) {
    let out = (h + s - 1) / s;
    let total = ((out - 1) * s + k).saturating_sub(h);
    (out, (total / 2) as i64)
}

// ------------------------------------------------------------------
// im2col layouts feeding the shared GEMM micro-kernel (runtime::gemm)
//
// Bit-parity argument: the scalar reference loop accumulates each
// output element's taps with a single f32 accumulator in (ic, kh, kw)
// order, skipping out-of-image taps. The im2col buffers below order the
// GEMM reduction dimension identically and hold +0.0 at every padded
// tap; folding those zeros in order is bit-neutral because an f32
// `acc += p` chain starting from +0.0 can never produce a -0.0
// accumulator (x + (-x) rounds to +0.0), and IEEE addition of ±0.0 to a
// non-(-0.0) value is exact identity. `tests/parallel.rs` pins this —
// including inputs seeded with -0.0 and denormals — against the
// retained scalar loops.
// ------------------------------------------------------------------

/// Valid `ow` range `[lo, hi)` such that `iw = ow*stride - pad_w + kw`
/// lies in `[0, wd)`.
fn ow_range(
    wo: usize,
    wd: usize,
    stride: usize,
    pad_w: i64,
    kw: usize,
) -> (usize, usize) {
    let s = stride as i64;
    let off = pad_w - kw as i64; // iw = ow*stride - off
    let lo = if off > 0 { ((off + s - 1) / s) as usize } else { 0 };
    let hi_i = wd as i64 - 1 + off;
    let hi = if hi_i < 0 { 0 } else { (hi_i / s + 1) as usize };
    (lo.min(wo), hi.min(wo))
}

/// Scatter one `(cin, h, wd)` sample into im2col layout with a strided
/// output: element `(r, n)` — row `r = (ci, kh, kw)` (ascending, the
/// scalar loop's tap order), column `n = (oh, ow)` — lands at
/// `r*rs_out + n*cs_out`. `(rs_out, cs_out) = (ho*wo, 1)` gives the
/// forward GEMM's B operand; `(1, cin*k*k)` gives the transposed slab
/// the weight-gradient reduction reads. `out` must be pre-zeroed; padded
/// taps stay +0.0.
#[allow(clippy::too_many_arguments)]
pub(crate) fn im2col(
    x: &[f32],
    cin: usize,
    h: usize,
    wd: usize,
    k: usize,
    stride: usize,
    ho: usize,
    wo: usize,
    pad_h: i64,
    pad_w: i64,
    rs_out: usize,
    cs_out: usize,
    out: &mut [f32],
) {
    for ci in 0..cin {
        for kh in 0..k {
            for kw in 0..k {
                let r = (ci * k + kh) * k + kw;
                let (lo, hi) = ow_range(wo, wd, stride, pad_w, kw);
                for oh in 0..ho {
                    let ih = (oh * stride) as i64 - pad_h + kh as i64;
                    if ih < 0 || ih >= h as i64 {
                        continue;
                    }
                    let xrow = (ci * h + ih as usize) * wd;
                    let obase = r * rs_out + oh * wo * cs_out;
                    for ow in lo..hi {
                        let iw = (ow * stride) as i64 - pad_w + kw as i64;
                        out[obase + ow * cs_out] = x[xrow + iw as usize];
                    }
                }
            }
        }
    }
}

/// Gather one sample's output-gradient into the transposed-convolution
/// im2col layout: row `r = (oc, khf, kwf)` over the **flipped** kernel
/// index (`kh = k-1-khf`), column `n = (ih, iw)`. Flipping makes the
/// GEMM's ascending reduction order `(oc, khf, kwf)` coincide with the
/// fused scalar loop's `(oc, oh, ow)` order for every input-gradient
/// element (ascending `khf` is ascending `oh`). `cols` pre-zeroed;
/// stride-hole and out-of-range taps stay +0.0.
#[allow(clippy::too_many_arguments)]
fn gx_cols(
    g: &[f32],
    cout: usize,
    ho: usize,
    wo: usize,
    k: usize,
    stride: usize,
    h: usize,
    wd: usize,
    pad_h: i64,
    pad_w: i64,
    cols: &mut [f32],
) {
    let n_in = h * wd;
    for oc in 0..cout {
        for khf in 0..k {
            let kh = k - 1 - khf;
            for kwf in 0..k {
                let kw = k - 1 - kwf;
                let r = (oc * k + khf) * k + kwf;
                let (lo, hi) = ow_range(wo, wd, stride, pad_w, kw);
                for oh in 0..ho {
                    let ih = (oh * stride) as i64 - pad_h + kh as i64;
                    if ih < 0 || ih >= h as i64 {
                        continue;
                    }
                    let grow = (oc * ho + oh) * wo;
                    let crow = r * n_in + ih as usize * wd;
                    for ow in lo..hi {
                        let iw = (ow * stride) as i64 - pad_w + kw as i64;
                        cols[crow + iw as usize] = g[grow + ow];
                    }
                }
            }
        }
    }
}

/// Flip + transpose one group's weights into the input-gradient GEMM's
/// A operand: `out[ci][(ocl, khf, kwf)] = w[gbase+ocl][ci][k-1-khf][k-1-kwf]`.
/// Fully overwritten — no pre-zeroing needed.
fn pack_wflip(
    w: &[f32],
    gi: usize,
    cpg_out: usize,
    cpg_in: usize,
    k: usize,
    out: &mut [f32],
) {
    let kk = k * k;
    let krows = cpg_out * kk;
    for ci in 0..cpg_in {
        for ocl in 0..cpg_out {
            let wbase = ((gi * cpg_out + ocl) * cpg_in + ci) * kk;
            let obase = ci * krows + ocl * kk;
            for khf in 0..k {
                for kwf in 0..k {
                    out[obase + khf * k + kwf] =
                        w[wbase + (k - 1 - khf) * k + (k - 1 - kwf)];
                }
            }
        }
    }
}

/// Grouped NCHW x OIHW convolution with SAME padding (no bias), computed
/// as per-sample im2col + GEMM on the shared micro-kernel.
///
/// Parallelized over batch samples: every output element is produced by
/// exactly one pool job, and the GEMM accumulates its `(ic, kh, kw)` taps
/// in the scalar loop's order (see the im2col parity note above), so the
/// result is bit-identical to the scalar reference at any thread count.
/// 1x1 stride-1 convolutions skip im2col entirely — the sample already
/// is its own column matrix.
pub fn conv2d(x: &Tensor, w: &Tensor, stride: usize, groups: usize) -> Tensor {
    let (b, cout) = (x.shape[0], w.shape[0]);
    let (ho, _) = same_pads(x.shape[2], w.shape[2], stride);
    let (wo, _) = same_pads(x.shape[3], w.shape[2], stride);
    let mut out = vec![0f32; b * cout * ho * wo];
    conv2d_core(x, w, stride, groups, &mut out);
    Tensor::new(vec![b, cout, ho, wo], out)
}

/// [`conv2d`] into a caller-provided buffer (the reconstruction plan's
/// persistent activation scratch). Zeroes `out` first — the GEMM
/// accumulates — so the result is bit-identical to the allocating form.
pub(crate) fn conv2d_into(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    groups: usize,
    out: &mut [f32],
) {
    out.fill(0.0);
    conv2d_core(x, w, stride, groups, out);
}

fn conv2d_core(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    groups: usize,
    out: &mut [f32],
) {
    let (b, cin, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (cout, cpg_in, k) = (w.shape[0], w.shape[1], w.shape[2]);
    assert_eq!(cin / groups, cpg_in, "conv group mismatch");
    let cpg_out = cout / groups;
    let (ho, pad_h) = same_pads(h, k, stride);
    let (wo, pad_w) = same_pads(wd, k, stride);
    let n = ho * wo;
    let kw_g = cpg_in * k * k;
    assert_eq!(out.len(), b * cout * n, "conv2d: bad out len");
    let work = out.len().saturating_mul(kw_g);
    pool::par_chunks_mut(out, cout * n, work, |bi, orow| {
        pool::with_scratch(|s| {
            let xs = x.row0(bi);
            let built;
            let cols: &[f32] = if k == 1 && stride == 1 {
                xs // rows = ci, cols = (h, wd): x's own layout
            } else {
                built = pool::grab(&mut s.im2col, cin * k * k * n);
                im2col(
                    xs, cin, h, wd, k, stride, ho, wo, pad_h, pad_w, n, 1,
                    built,
                );
                built
            };
            for gi in 0..groups {
                gemm::gemm(
                    cpg_out,
                    n,
                    kw_g,
                    &w.data[gi * cpg_out * kw_g..],
                    kw_g,
                    1,
                    &cols[gi * kw_g * n..],
                    n,
                    1,
                    &mut orow[gi * cpg_out * n..],
                    n,
                    &mut s.pack_a,
                    &mut s.pack_b,
                );
            }
        });
    });
}

/// Geometry of one backward call, shared by the sequential and parallel
/// paths (and by the reconstruction plan's slab-backed weight-gradient
/// fold in [`super::plan`]).
#[derive(Clone, Copy)]
pub(crate) struct BwdGeom {
    pub(crate) b: usize,
    pub(crate) cin: usize,
    pub(crate) h: usize,
    pub(crate) wd: usize,
    pub(crate) cout: usize,
    pub(crate) cpg_in: usize,
    pub(crate) cpg_out: usize,
    pub(crate) k: usize,
    pub(crate) stride: usize,
    pub(crate) groups: usize,
    pub(crate) ho: usize,
    pub(crate) wo: usize,
    pub(crate) pad_h: i64,
    pub(crate) pad_w: i64,
}

impl BwdGeom {
    /// Geometry for a `(b, cin, h, wd)` input under `w`'s kernel.
    pub(crate) fn of(
        b: usize,
        cin: usize,
        h: usize,
        wd: usize,
        w: &Tensor,
        stride: usize,
        groups: usize,
    ) -> BwdGeom {
        let (cout, cpg_in, k) = (w.shape[0], w.shape[1], w.shape[2]);
        let (ho, pad_h) = same_pads(h, k, stride);
        let (wo, pad_w) = same_pads(wd, k, stride);
        BwdGeom {
            b,
            cin,
            h,
            wd,
            cout,
            cpg_in,
            cpg_out: cout / groups,
            k,
            stride,
            groups,
            ho,
            wo,
            pad_h,
            pad_w,
        }
    }
    pub(crate) fn n(&self) -> usize {
        self.ho * self.wo
    }
    pub(crate) fn hw_in(&self) -> usize {
        self.h * self.wd
    }
    pub(crate) fn kw_g(&self) -> usize {
        self.cpg_in * self.k * self.k
    }
    pub(crate) fn kw_all(&self) -> usize {
        self.cin * self.k * self.k
    }
    /// 1x1 stride-1 convs read their operands directly (no col buffers).
    pub(crate) fn direct(&self) -> bool {
        self.k == 1 && self.stride == 1
    }
}

/// Input gradient of one sample: flipped-weight GEMM over the gathered
/// output-gradient columns. `gxs` is the sample's pre-zeroed slice;
/// `wf_all` is the flipped/transposed weight operand for all groups,
/// packed **once per backward call** by the caller (empty — and unread —
/// for direct 1x1 convs, which use a strided view of `w` instead).
#[allow(clippy::too_many_arguments)]
fn gx_sample(
    gs: &[f32],
    w: &Tensor,
    wf_all: &[f32],
    g: BwdGeom,
    gxs: &mut [f32],
    gcols_buf: &mut Vec<f32>,
    pa: &mut Vec<f32>,
    pb: &mut Vec<f32>,
) {
    let kk = g.k * g.k;
    if g.direct() {
        // gcols degenerates to the gradient sample itself and the
        // flipped weights to a plain transposed view — zero packing.
        for gi in 0..g.groups {
            gemm::gemm(
                g.cpg_in,
                g.hw_in(),
                g.cpg_out,
                &w.data[gi * g.cpg_out * g.cpg_in..],
                1,
                g.cpg_in,
                &gs[gi * g.cpg_out * g.n()..],
                g.n(),
                1,
                &mut gxs[gi * g.cpg_in * g.hw_in()..],
                g.hw_in(),
                pa,
                pb,
            );
        }
        return;
    }
    let gcols = pool::grab(gcols_buf, g.cout * kk * g.hw_in());
    gx_cols(
        gs, g.cout, g.ho, g.wo, g.k, g.stride, g.h, g.wd, g.pad_h, g.pad_w,
        gcols,
    );
    let gsz = g.cpg_in * g.cpg_out * kk;
    for gi in 0..g.groups {
        gemm::gemm(
            g.cpg_in,
            g.hw_in(),
            g.cpg_out * kk,
            &wf_all[gi * gsz..],
            g.cpg_out * kk,
            1,
            &gcols[gi * g.cpg_out * kk * g.hw_in()..],
            g.hw_in(),
            1,
            &mut gxs[gi * g.cpg_in * g.hw_in()..],
            g.hw_in(),
            pa,
            pb,
        );
    }
}

/// One sample's weight-gradient contribution, accumulated into `gw` rows
/// `[oc0, oc0+m)` (all inside one group `gi`): GEMM with the reduction
/// over this sample's spatial positions, extending each element's chain.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gw_accum(
    gs_sample: &[f32],
    cols_t_or_x: &[f32],
    rs_b: usize,
    cs_b: usize,
    g: BwdGeom,
    oc0: usize,
    m: usize,
    gw_rows: &mut [f32],
    pa: &mut Vec<f32>,
    pb: &mut Vec<f32>,
) {
    gemm::gemm(
        m,
        g.kw_g(),
        g.n(),
        &gs_sample[oc0 * g.n()..],
        g.n(),
        1,
        cols_t_or_x,
        rs_b,
        cs_b,
        gw_rows,
        g.kw_g(),
        pa,
        pb,
    );
}

/// Backward of [`conv2d`]: gradients wrt input and weights, both via the
/// shared GEMM micro-kernel.
///
/// * `gx` — per sample: gather `gout` into flipped-kernel columns
///   ([`gx_cols`]) and multiply by the flipped/transposed weights. The
///   reduction order `(oc, khf, kwf)` equals the fused scalar loop's
///   `(oc, oh, ow)` accumulation order per element.
/// * `gw` — reduction over `(bi, oh, ow)` ascending: an ordered fold of
///   per-sample GEMMs over the transposed im2col slab ([`im2col`] with
///   a `(1, cin*k*k)` output stride),
///   exactly the fused loop's order per weight element.
///
/// The parallel form partitions `gx` per sample and `gw` per
/// out-channel block (ownership-partitioned, no shared accumulators);
/// the sub-threshold sequential form walks samples in order with the
/// same GEMMs. Both are bit-identical to the retained scalar reference
/// at any thread count. Neither form takes the scalar reference's
/// `g == 0.0` shortcut; that skip is bit-neutral (an `acc += w*g` chain
/// never holds -0.0, so adding the skipped ±0.0 products changes no
/// bits) and `tests/parallel.rs` pins the equivalence on gradients
/// containing exact zeros and -0.0.
pub fn conv2d_bwd(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    groups: usize,
    gout: &Tensor,
) -> (Tensor, Tensor) {
    let mut gx = vec![0f32; x.data.len()];
    let mut gw = vec![0f32; w.data.len()];
    conv2d_bwd_core(x, w, stride, groups, gout, Some(&mut gx), &mut gw);
    (
        Tensor::new(x.shape.clone(), gx),
        Tensor::new(w.shape.clone(), gw),
    )
}

/// [`conv2d_bwd`] into caller-provided buffers. `gx: None` skips the
/// input-gradient phase entirely (the reconstruction plan's frozen-input
/// layers only need `gw`); the weight-gradient fold is unaffected, so
/// `gw` stays bit-identical either way. Both buffers are zeroed here —
/// the GEMMs accumulate.
pub(crate) fn conv2d_bwd_into(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    groups: usize,
    gout: &Tensor,
    mut gx: Option<&mut [f32]>,
    gw: &mut [f32],
) {
    if let Some(g) = gx.as_deref_mut() {
        g.fill(0.0);
    }
    gw.fill(0.0);
    conv2d_bwd_core(x, w, stride, groups, gout, gx, gw);
}

fn conv2d_bwd_core(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    groups: usize,
    gout: &Tensor,
    mut gx: Option<&mut [f32]>,
    gw: &mut [f32],
) {
    let (b, cin, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (cout, cpg_in, k) = (w.shape[0], w.shape[1], w.shape[2]);
    let cpg_out = cout / groups;
    let (ho, pad_h) = same_pads(h, k, stride);
    let (wo, pad_w) = same_pads(wd, k, stride);
    let g = BwdGeom {
        b,
        cin,
        h,
        wd,
        cout,
        cpg_in,
        cpg_out,
        k,
        stride,
        groups,
        ho,
        wo,
        pad_h,
        pad_w,
    };
    let (n, hw_in, kw_g, kw_all) = (g.n(), g.hw_in(), g.kw_g(), g.kw_all());
    let kk = k * k;
    let gsz = cpg_in * cpg_out * kk;
    let work = gout.data.len().saturating_mul(kw_g);

    if !pool::active(work) {
        // sequential: same GEMMs, batch samples walked in order
        pool::with_scratch(|s| {
            let pool::Scratch {
                im2col: gcols_buf,
                cols_t,
                wpack,
                pack_a,
                pack_b,
            } = s;
            let wf_all: &[f32] = if g.direct() || gx.is_none() {
                &[]
            } else {
                let wf = pool::grab_dirty(wpack, w.data.len());
                for gi in 0..groups {
                    pack_wflip(
                        &w.data,
                        gi,
                        cpg_out,
                        cpg_in,
                        k,
                        &mut wf[gi * gsz..],
                    );
                }
                wf
            };
            for bi in 0..b {
                let gs = gout.row0(bi);
                let xs = x.row0(bi);
                if let Some(gx_all) = gx.as_deref_mut() {
                    gx_sample(
                        gs,
                        w,
                        wf_all,
                        g,
                        &mut gx_all[bi * cin * hw_in..],
                        gcols_buf,
                        pack_a,
                        pack_b,
                    );
                }
                if g.direct() {
                    for gi in 0..groups {
                        gw_accum(
                            gs,
                            &xs[gi * cpg_in * hw_in..],
                            1,
                            hw_in,
                            g,
                            gi * cpg_out,
                            cpg_out,
                            &mut gw[gi * cpg_out * kw_g..],
                            pack_a,
                            pack_b,
                        );
                    }
                } else {
                    let ct = pool::grab(cols_t, n * kw_all);
                    im2col(
                        xs, cin, h, wd, k, stride, ho, wo, pad_h, pad_w, 1,
                        kw_all, ct,
                    );
                    for gi in 0..groups {
                        gw_accum(
                            gs,
                            &ct[gi * kw_g..],
                            kw_all,
                            1,
                            g,
                            gi * cpg_out,
                            cpg_out,
                            &mut gw[gi * cpg_out * kw_g..],
                            pack_a,
                            pack_b,
                        );
                    }
                }
            }
        });
        return;
    }

    // Parallel form, in batch chunks so the shared transposed-im2col
    // slab is bounded (~SLAB_CAP f32s) regardless of batch size. Chunks
    // run in batch order and phase B accumulates into `gw` across them,
    // so every weight-gradient element still folds its samples strictly
    // ascending — the fused scalar loop's order, bit-for-bit.
    //
    // The flipped-weight operand is packed once, up front, and shared
    // read-only by every phase-A job.
    const SLAB_CAP: usize = 1 << 24; // f32 elements (~64 MB)
    let wf_all = if g.direct() || gx.is_none() {
        Vec::new()
    } else {
        let mut v = pool::take_shared(w.data.len());
        for gi in 0..groups {
            pack_wflip(&w.data, gi, cpg_out, cpg_in, k, &mut v[gi * gsz..]);
        }
        v
    };
    let bc = if g.direct() {
        b
    } else {
        (SLAB_CAP / (n * kw_all).max(1)).clamp(1, b)
    };
    // Zeroed once: the padded-tap positions of the slab are the same for
    // every sample, so later chunks only ever overwrite live entries.
    let mut cols_t = if g.direct() {
        Vec::new()
    } else {
        pool::take_shared(bc * n * kw_all)
    };
    for c0 in (0..b).step_by(bc) {
        let clen = bc.min(b - c0);
        // Phase A — per-sample jobs: gx GEMM, plus (when needed) this
        // sample's transposed-im2col slab slot for phase B. With gx
        // skipped (None), only the slab slots are built.
        match gx.as_deref_mut() {
            Some(gx_all) => {
                let gx_chunk =
                    &mut gx_all[c0 * cin * hw_in..(c0 + clen) * cin * hw_in];
                if g.direct() {
                    pool::par_chunks_mut(
                        gx_chunk,
                        cin * hw_in,
                        work,
                        |ci, gxs| {
                            pool::with_scratch(|s| {
                                let gs = gout.row0(c0 + ci);
                                gx_sample(
                                    gs,
                                    w,
                                    &wf_all,
                                    g,
                                    gxs,
                                    &mut s.im2col,
                                    &mut s.pack_a,
                                    &mut s.pack_b,
                                );
                            });
                        },
                    );
                } else {
                    pool::par_chunks2_mut(
                        gx_chunk,
                        cin * hw_in,
                        &mut cols_t[..clen * n * kw_all],
                        n * kw_all,
                        work,
                        |ci, gxs, ct| {
                            pool::with_scratch(|s| {
                                let gs = gout.row0(c0 + ci);
                                let xs = x.row0(c0 + ci);
                                gx_sample(
                                    gs,
                                    w,
                                    &wf_all,
                                    g,
                                    gxs,
                                    &mut s.im2col,
                                    &mut s.pack_a,
                                    &mut s.pack_b,
                                );
                                im2col(
                                    xs, cin, h, wd, k, stride, ho, wo,
                                    pad_h, pad_w, 1, kw_all, ct,
                                );
                            });
                        },
                    );
                }
            }
            None => {
                if !g.direct() {
                    pool::par_chunks_mut(
                        &mut cols_t[..clen * n * kw_all],
                        n * kw_all,
                        work,
                        |ci, ct| {
                            let xs = x.row0(c0 + ci);
                            im2col(
                                xs, cin, h, wd, k, stride, ho, wo, pad_h,
                                pad_w, 1, kw_all, ct,
                            );
                        },
                    );
                }
            }
        }

        // Phase B — gw in out-channel blocks: each job owns a row block
        // and folds this chunk's samples in ascending order (the scalar
        // order, continued across chunks).
        pool::par_chunks_mut(gw, gemm::MR * kw_g, work, |ci, gwr| {
            pool::with_scratch(|s| {
                let o0 = ci * gemm::MR;
                let mrows = gwr.len() / kw_g;
                let mut r = 0;
                while r < mrows {
                    let oc = o0 + r;
                    let gi = oc / cpg_out;
                    let m = ((gi + 1) * cpg_out - oc).min(mrows - r);
                    for bl in 0..clen {
                        let gs = gout.row0(c0 + bl);
                        if g.direct() {
                            let xs = x.row0(c0 + bl);
                            gw_accum(
                                gs,
                                &xs[gi * cpg_in * hw_in..],
                                1,
                                hw_in,
                                g,
                                oc,
                                m,
                                &mut gwr[r * kw_g..],
                                &mut s.pack_a,
                                &mut s.pack_b,
                            );
                        } else {
                            gw_accum(
                                gs,
                                &cols_t[bl * n * kw_all + gi * kw_g..],
                                kw_all,
                                1,
                                g,
                                oc,
                                m,
                                &mut gwr[r * kw_g..],
                                &mut s.pack_a,
                                &mut s.pack_b,
                            );
                        }
                    }
                    r += m;
                }
            });
        });
    }
    if !g.direct() {
        pool::give_shared(cols_t);
        if !wf_all.is_empty() {
            pool::give_shared(wf_all);
        }
    }
}

/// Per-job row count for partitioning a (B, ...) matrix across the pool:
/// about two chunks per thread.
fn row_grain(rows: usize) -> usize {
    rows.div_ceil(pool::threads().max(1) * 2).max(1)
}

/// x (B, Cin) @ w (Cout, Cin)^T — GEMM with `w` viewed transposed.
/// Reduction over `Cin` ascending: the scalar loop's order.
pub fn fc_fwd(x: &Tensor, w: &Tensor) -> Tensor {
    let (b, cout) = (x.shape[0], w.shape[0]);
    let mut out = vec![0f32; b * cout];
    fc_fwd_core(x, w, &mut out);
    Tensor::new(vec![b, cout], out)
}

/// [`fc_fwd`] into a caller-provided (pre-existing) buffer; zeroed here
/// because the GEMM accumulates.
pub(crate) fn fc_fwd_into(x: &Tensor, w: &Tensor, out: &mut [f32]) {
    out.fill(0.0);
    fc_fwd_core(x, w, out);
}

fn fc_fwd_core(x: &Tensor, w: &Tensor, out: &mut [f32]) {
    let (b, cin) = (x.shape[0], x.shape[1]);
    let cout = w.shape[0];
    assert_eq!(out.len(), b * cout, "fc_fwd: bad out len");
    let work = out.len().saturating_mul(cin);
    let rows = row_grain(b);
    pool::par_chunks_mut(out, rows * cout, work, |ci, orows| {
        pool::with_scratch(|s| {
            let r0 = ci * rows;
            let m = orows.len() / cout;
            gemm::gemm(
                m,
                cout,
                cin,
                &x.data[r0 * cin..],
                cin,
                1,
                &w.data,
                1,
                cin,
                orows,
                cout,
                &mut s.pack_a,
                &mut s.pack_b,
            );
        });
    });
}

/// Backward of [`fc_fwd`]: `gx = g @ w` (reduction over `Cout`
/// ascending) and `gw = g^T @ x` (reduction over the batch ascending) —
/// both exactly the fused scalar loop's per-element accumulation order,
/// partitioned over output rows.
pub fn fc_bwd(x: &Tensor, w: &Tensor, gout: &Tensor) -> (Tensor, Tensor) {
    let mut gx = vec![0f32; x.data.len()];
    let mut gw = vec![0f32; w.data.len()];
    fc_bwd_core(x, w, gout, Some(&mut gx), &mut gw);
    (
        Tensor::new(x.shape.clone(), gx),
        Tensor::new(w.shape.clone(), gw),
    )
}

/// [`fc_bwd`] into caller-provided buffers; `gx: None` skips the
/// input-gradient GEMM (frozen-input head layers only need `gw`). Both
/// buffers are zeroed here — the GEMMs accumulate.
pub(crate) fn fc_bwd_into(
    x: &Tensor,
    w: &Tensor,
    gout: &Tensor,
    mut gx: Option<&mut [f32]>,
    gw: &mut [f32],
) {
    if let Some(g) = gx.as_deref_mut() {
        g.fill(0.0);
    }
    gw.fill(0.0);
    fc_bwd_core(x, w, gout, gx, gw);
}

fn fc_bwd_core(
    x: &Tensor,
    w: &Tensor,
    gout: &Tensor,
    mut gx: Option<&mut [f32]>,
    gw: &mut [f32],
) {
    let (b, cin) = (x.shape[0], x.shape[1]);
    let cout = w.shape[0];
    let work = (b * cout).saturating_mul(cin);
    let rows = row_grain(b);
    if let Some(gx) = gx.as_deref_mut() {
        pool::par_chunks_mut(gx, rows * cin, work, |ci, gxr| {
            pool::with_scratch(|s| {
                let r0 = ci * rows;
                let m = gxr.len() / cin;
                gemm::gemm(
                    m,
                    cin,
                    cout,
                    &gout.data[r0 * cout..],
                    cout,
                    1,
                    &w.data,
                    cin,
                    1,
                    gxr,
                    cin,
                    &mut s.pack_a,
                    &mut s.pack_b,
                );
            });
        });
    }
    let orows = row_grain(cout);
    pool::par_chunks_mut(gw, orows * cin, work, |ci, gwr| {
        pool::with_scratch(|s| {
            let o0 = ci * orows;
            let m = gwr.len() / cin;
            gemm::gemm(
                m,
                cin,
                b,
                &gout.data[o0..],
                1,
                cout,
                &x.data,
                cin,
                1,
                gwr,
                cin,
                &mut s.pack_a,
                &mut s.pack_b,
            );
        });
    });
}

/// Global average pool (B, C, H, W) -> (B, C).
pub(crate) fn gap_fwd(x: &Tensor) -> Tensor {
    let (b, c) = (x.shape[0], x.shape[1]);
    let inner = x.shape[2] * x.shape[3];
    let mut out = vec![0f32; b * c];
    for bi in 0..b {
        for ci in 0..c {
            let base = (bi * c + ci) * inner;
            let s: f32 = x.data[base..base + inner].iter().sum();
            out[bi * c + ci] = s / inner as f32;
        }
    }
    Tensor::new(vec![b, c], out)
}

fn gap_bwd(g: &Tensor, in_shape: &[usize]) -> Tensor {
    let (b, c) = (in_shape[0], in_shape[1]);
    let inner = in_shape[2] * in_shape[3];
    let mut gx = vec![0f32; b * c * inner];
    for bi in 0..b {
        for ci in 0..c {
            let v = g.data[bi * c + ci] / inner as f32;
            let base = (bi * c + ci) * inner;
            for j in 0..inner {
                gx[base + j] = v;
            }
        }
    }
    Tensor::new(in_shape.to_vec(), gx)
}

pub(crate) fn add_bias(z: &mut Tensor, bias: &Tensor) {
    let c = z.shape[1];
    let inner: usize = z.shape[2..].iter().product::<usize>().max(1);
    let b = z.shape[0];
    for bi in 0..b {
        for ci in 0..c {
            let base = (bi * c + ci) * inner;
            let v = bias.data[ci];
            for j in 0..inner {
                z.data[base + j] += v;
            }
        }
    }
}

fn add(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape, b.shape);
    let data = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| x + y)
        .collect();
    Tensor::new(a.shape.clone(), data)
}

pub(crate) fn relu_inplace(z: &mut Tensor) {
    for v in z.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU backward: pass gradient where the stored (post-relu) output is > 0.
fn relu_mask(g: &Tensor, out: &Tensor) -> Tensor {
    let data = g
        .data
        .iter()
        .zip(&out.data)
        .map(|(gv, ov)| if *ov > 0.0 { *gv } else { 0.0 })
        .collect();
    Tensor::new(g.shape.clone(), data)
}

// ------------------------------------------------------------------
// Layer application with tape
// ------------------------------------------------------------------

/// Per-site activation fake-quant parameters (None = FP passthrough).
#[derive(Debug, Clone, Copy)]
pub struct AqParams {
    pub step: f32,
    pub lo: f32,
    pub hi: f32,
}

struct LayerTape {
    x: Tensor,   // raw input (pre act-quant) — LSQ backward needs it
    xq: Tensor,  // quantized input actually fed to the conv/fc
    out: Tensor, // layer output (post relu)
}

fn layer_fwd(
    l: &LayerInfo,
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    aq: Option<AqParams>,
) -> LayerTape {
    let xq = match aq {
        Some(p) => x.map(|v| lsq(v, p.step, p.lo, p.hi)),
        None => x.clone(),
    };
    let mut z = if l.kind == "fc" {
        fc_fwd(&xq, w)
    } else {
        conv2d(&xq, w, l.stride, l.groups)
    };
    add_bias(&mut z, b);
    if l.relu {
        relu_inplace(&mut z);
    }
    LayerTape { x: x.clone(), xq, out: z }
}

/// Backward through one layer: returns (grad wrt raw input, grad wrt the
/// weight as used, LSQ step-grad). `gout` is the grad at the layer output.
fn layer_bwd(
    l: &LayerInfo,
    tape: &LayerTape,
    w: &Tensor,
    aq: Option<AqParams>,
    gout: &Tensor,
) -> (Tensor, Tensor, f32) {
    let g = if l.relu {
        relu_mask(gout, &tape.out)
    } else {
        gout.clone()
    };
    let (gxq, gw) = if l.kind == "fc" {
        fc_bwd(&tape.xq, w, &g)
    } else {
        conv2d_bwd(&tape.xq, w, l.stride, l.groups, &g)
    };
    match aq {
        Some(p) => {
            let mut gstep = 0f32;
            let mut gx = vec![0f32; gxq.data.len()];
            for i in 0..gxq.data.len() {
                let (gi, ds) =
                    lsq_grads(tape.x.data[i], p.step, p.lo, p.hi, gxq.data[i]);
                gx[i] = gi;
                gstep += ds;
            }
            (Tensor::new(gxq.shape.clone(), gx), gw, gstep)
        }
        None => (gxq, gw, 0.0),
    }
}

// ------------------------------------------------------------------
// Unit node programs (parsed from manifest `topo` tags)
// ------------------------------------------------------------------

/// One structural node of a unit graph. Indices point into the unit's
/// layer list (manifest binding order). `pub(crate)`: the reconstruction
/// plan ([`super::plan`]) compiles the same node vocabulary.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Node {
    /// Plain chain-apply of one layer.
    Layer(usize),
    /// ResNet basic block: relu(conv2(conv1(x)) + [down](x)).
    Basic { c1: usize, c2: usize, down: Option<usize> },
    /// Layer-granularity tail of a basic block: relu(conv2(x) + [down](skip)).
    BasicL2 { c2: usize, down: Option<usize> },
    /// Inverted residual: project(dw(expand(x))) [+ x].
    Ir { e: usize, d: usize, p: usize, res: bool },
    /// Layer-granularity tail of a residual IR block: project(x) + skip.
    IrL3 { p: usize },
    /// Head: fc(global_average_pool(x)).
    GapFc { fc: usize },
}

fn topo_bool(s: &str) -> bool {
    s.contains("true") || s.contains("True")
}

/// Split `seq(a,b,c)` contents at top-level commas.
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for ch in s.chars() {
        match ch {
            '(' => {
                depth += 1;
                cur.push(ch);
            }
            ')' => {
                depth = depth.saturating_sub(1);
                cur.push(ch);
            }
            ',' if depth == 0 => {
                out.push(cur.trim().to_string());
                cur = String::new();
            }
            _ => cur.push(ch),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

/// Parse one (non-seq) topo tag into a node, consuming layer indices.
fn parse_one(topo: &str, next: &mut usize) -> Result<Node> {
    let mut take = || {
        let i = *next;
        *next += 1;
        i
    };
    if topo == "conv" {
        return Ok(Node::Layer(take()));
    }
    if topo == "gap_fc" {
        return Ok(Node::GapFc { fc: take() });
    }
    if let Some(rest) = topo.strip_prefix("basic_l2(") {
        let c2 = take();
        let down = if topo_bool(rest) { Some(take()) } else { None };
        return Ok(Node::BasicL2 { c2, down });
    }
    if let Some(rest) = topo.strip_prefix("basic(") {
        let c1 = take();
        let c2 = take();
        let down = if topo_bool(rest) { Some(take()) } else { None };
        return Ok(Node::Basic { c1, c2, down });
    }
    if topo.starts_with("ir_l3") {
        return Ok(Node::IrL3 { p: take() });
    }
    if let Some(rest) = topo.strip_prefix("ir(") {
        let e = take();
        let d = take();
        let p = take();
        return Ok(Node::Ir { e, d, p, res: topo_bool(rest) });
    }
    bail!(
        "native backend: unsupported unit topology '{topo}' \
         (rebuild with --features pjrt for full AOT artifacts)"
    );
}

fn parse_topo(topo: &str, nlayers: usize) -> Result<Vec<Node>> {
    let mut next = 0usize;
    let mut nodes = Vec::new();
    if let Some(rest) = topo.strip_prefix("seq(") {
        let inner = rest.strip_suffix(')').unwrap_or(rest);
        for sub in split_top_level(inner) {
            nodes.push(parse_one(&sub, &mut next)?);
        }
    } else {
        nodes.push(parse_one(topo, &mut next)?);
    }
    if next != nlayers {
        bail!(
            "topo '{topo}' consumes {next} layers but the unit binds {nlayers}"
        );
    }
    Ok(nodes)
}

/// A unit compiled against the manifest: node program + layer geometry.
#[derive(Clone)]
pub(crate) struct UnitProg {
    pub(crate) name: String,
    pub(crate) nodes: Vec<Node>,
    pub(crate) layers: Vec<LayerInfo>, // unit binding order
    pub(crate) model_ids: Vec<usize>,  // model-order index of each layer
    pub(crate) uses_skip: bool,
    pub(crate) save_skip: bool,
}

fn build_unit_prog(model: &ModelInfo, u: &UnitInfo) -> Result<UnitProg> {
    let layers: Vec<LayerInfo> = u
        .layer_ids
        .iter()
        .map(|&l| model.layers[l].clone())
        .collect();
    let nodes = parse_topo(&u.topo, layers.len())
        .with_context(|| format!("unit '{}'", u.name))?;
    Ok(UnitProg {
        name: u.name.clone(),
        nodes,
        layers,
        model_ids: u.layer_ids.clone(),
        uses_skip: u.uses_skip,
        save_skip: u.save_skip,
    })
}

enum NodeTape {
    Layer(LayerTape),
    Basic {
        t1: LayerTape,
        t2: LayerTape,
        td: Option<LayerTape>,
        out: Tensor,
    },
    BasicL2 {
        t2: LayerTape,
        td: Option<LayerTape>,
        out: Tensor,
    },
    Ir {
        te: LayerTape,
        td: LayerTape,
        tp: LayerTape,
    },
    IrL3 {
        tp: LayerTape,
    },
    GapFc {
        in_shape: Vec<usize>,
        t: LayerTape,
    },
}

/// Forward one node. `skip` is the unit's skip input (consumed only by
/// BasicL2 / IrL3 nodes).
fn node_fwd(
    prog: &UnitProg,
    node: &Node,
    x: &Tensor,
    skip: Option<&Tensor>,
    ws: &[&Tensor],
    bs: &[&Tensor],
    aq: &[Option<AqParams>],
) -> Result<(Tensor, NodeTape)> {
    let lf = |i: usize, inp: &Tensor| {
        layer_fwd(&prog.layers[i], inp, ws[i], bs[i], aq[i])
    };
    match *node {
        Node::Layer(i) => {
            let t = lf(i, x);
            Ok((t.out.clone(), NodeTape::Layer(t)))
        }
        Node::Basic { c1, c2, down } => {
            let t1 = lf(c1, x);
            let t2 = lf(c2, &t1.out);
            // the skip hop is borrowed, not cloned: the add reads it once
            let (td, mut out) = match down {
                Some(d) => {
                    let td = lf(d, x);
                    let o = add(&t2.out, &td.out);
                    (Some(td), o)
                }
                None => (None, add(&t2.out, x)),
            };
            relu_inplace(&mut out);
            Ok((out.clone(), NodeTape::Basic { t1, t2, td, out }))
        }
        Node::BasicL2 { c2, down } => {
            let sk = skip.context("basic_l2 unit needs a skip input")?;
            let t2 = lf(c2, x);
            let (td, mut out) = match down {
                Some(d) => {
                    let td = lf(d, sk);
                    let o = add(&t2.out, &td.out);
                    (Some(td), o)
                }
                None => (None, add(&t2.out, sk)),
            };
            relu_inplace(&mut out);
            Ok((out.clone(), NodeTape::BasicL2 { t2, td, out }))
        }
        Node::Ir { e, d, p, res } => {
            let te = lf(e, x);
            let td = lf(d, &te.out);
            let tp = lf(p, &td.out);
            let out = if res { add(&tp.out, x) } else { tp.out.clone() };
            Ok((out, NodeTape::Ir { te, td, tp }))
        }
        Node::IrL3 { p } => {
            let sk = skip.context("ir_l3 unit needs a skip input")?;
            let tp = lf(p, x);
            let out = add(&tp.out, sk);
            Ok((out, NodeTape::IrL3 { tp }))
        }
        Node::GapFc { fc } => {
            let g = gap_fwd(x);
            let t = lf(fc, &g);
            Ok((t.out.clone(), NodeTape::GapFc { in_shape: x.shape.clone(), t }))
        }
    }
}

/// Backward one node. Accumulates per-layer weight grads / LSQ step grads
/// into `gws` / `gsteps`; returns (grad wrt node input, grad wrt unit skip).
#[allow(clippy::too_many_arguments)]
fn node_bwd(
    prog: &UnitProg,
    node: &Node,
    tape: &NodeTape,
    ws: &[&Tensor],
    aq: &[Option<AqParams>],
    gout: &Tensor,
    gws: &mut [Tensor],
    gsteps: &mut [f32],
) -> Result<(Tensor, Option<Tensor>)> {
    match (node, tape) {
        (&Node::Layer(i), NodeTape::Layer(t)) => {
            let (gx, gw, gs) = layer_bwd(&prog.layers[i], t, ws[i], aq[i], gout);
            gws[i] = add(&gws[i], &gw);
            gsteps[i] += gs;
            Ok((gx, None))
        }
        (&Node::Basic { c1, c2, down }, NodeTape::Basic { t1, t2, td, out }) => {
            let g = relu_mask(gout, out);
            let (gh1, gw2, gs2) =
                layer_bwd(&prog.layers[c2], t2, ws[c2], aq[c2], &g);
            gws[c2] = add(&gws[c2], &gw2);
            gsteps[c2] += gs2;
            // identity skip: borrow the masked grad instead of cloning it
            let g_sc_store;
            let g_sc: &Tensor = match (down, td) {
                (Some(d), Some(tdd)) => {
                    let (gxd, gwd, gsd) =
                        layer_bwd(&prog.layers[d], tdd, ws[d], aq[d], &g);
                    gws[d] = add(&gws[d], &gwd);
                    gsteps[d] += gsd;
                    g_sc_store = gxd;
                    &g_sc_store
                }
                _ => &g,
            };
            let (gx1, gw1, gs1) =
                layer_bwd(&prog.layers[c1], t1, ws[c1], aq[c1], &gh1);
            gws[c1] = add(&gws[c1], &gw1);
            gsteps[c1] += gs1;
            Ok((add(&gx1, g_sc), None))
        }
        (&Node::BasicL2 { c2, down }, NodeTape::BasicL2 { t2, td, out }) => {
            let g = relu_mask(gout, out);
            let (gx, gw2, gs2) =
                layer_bwd(&prog.layers[c2], t2, ws[c2], aq[c2], &g);
            gws[c2] = add(&gws[c2], &gw2);
            gsteps[c2] += gs2;
            let g_skip = match (down, td) {
                (Some(d), Some(tdd)) => {
                    let (gxd, gwd, gsd) =
                        layer_bwd(&prog.layers[d], tdd, ws[d], aq[d], &g);
                    gws[d] = add(&gws[d], &gwd);
                    gsteps[d] += gsd;
                    gxd
                }
                _ => g,
            };
            Ok((gx, Some(g_skip)))
        }
        (&Node::Ir { e, d, p, res }, NodeTape::Ir { te, td, tp }) => {
            let (gd, gwp, gsp) =
                layer_bwd(&prog.layers[p], tp, ws[p], aq[p], gout);
            gws[p] = add(&gws[p], &gwp);
            gsteps[p] += gsp;
            let (ge, gwd, gsd) =
                layer_bwd(&prog.layers[d], td, ws[d], aq[d], &gd);
            gws[d] = add(&gws[d], &gwd);
            gsteps[d] += gsd;
            let (gx, gwe, gse) =
                layer_bwd(&prog.layers[e], te, ws[e], aq[e], &ge);
            gws[e] = add(&gws[e], &gwe);
            gsteps[e] += gse;
            let gx = if res { add(&gx, gout) } else { gx };
            Ok((gx, None))
        }
        (&Node::IrL3 { p }, NodeTape::IrL3 { tp }) => {
            let (gx, gwp, gsp) =
                layer_bwd(&prog.layers[p], tp, ws[p], aq[p], gout);
            gws[p] = add(&gws[p], &gwp);
            gsteps[p] += gsp;
            Ok((gx, Some(gout.clone())))
        }
        (&Node::GapFc { fc }, NodeTape::GapFc { in_shape, t }) => {
            let (gg, gwf, gsf) =
                layer_bwd(&prog.layers[fc], t, ws[fc], aq[fc], gout);
            gws[fc] = add(&gws[fc], &gwf);
            gsteps[fc] += gsf;
            Ok((gap_bwd(&gg, in_shape), None))
        }
        _ => bail!("node/tape mismatch in unit '{}'", prog.name),
    }
}

/// Run a unit forward; returns (output, tapes).
fn run_unit(
    prog: &UnitProg,
    x: &Tensor,
    skip: Option<&Tensor>,
    ws: &[&Tensor],
    bs: &[&Tensor],
    aq: &[Option<AqParams>],
) -> Result<(Tensor, Vec<NodeTape>)> {
    // the first hop borrows `x`; only node outputs are owned (a clone
    // happens solely in the degenerate empty-program case)
    let mut main: Option<Tensor> = None;
    let mut tapes = Vec::with_capacity(prog.nodes.len());
    for node in &prog.nodes {
        let inp = main.as_ref().unwrap_or(x);
        let (out, tape) = node_fwd(prog, node, inp, skip, ws, bs, aq)?;
        tapes.push(tape);
        main = Some(out);
    }
    Ok((main.unwrap_or_else(|| x.clone()), tapes))
}

/// Backward through a whole unit: returns (grad wrt unit input, grad wrt
/// unit skip input) and fills per-layer weight / act-step grads.
#[allow(clippy::too_many_arguments)]
fn run_unit_bwd(
    prog: &UnitProg,
    tapes: &[NodeTape],
    ws: &[&Tensor],
    aq: &[Option<AqParams>],
    gout: &Tensor,
    gws: &mut [Tensor],
    gsteps: &mut [f32],
) -> Result<(Tensor, Option<Tensor>)> {
    // the first (reverse) hop borrows `gout`; later hops own their grads
    let mut g: Option<Tensor> = None;
    let mut g_skip: Option<Tensor> = None;
    for (node, tape) in prog.nodes.iter().zip(tapes.iter()).rev() {
        let gref = g.as_ref().unwrap_or(gout);
        let (gx, gs) =
            node_bwd(prog, node, tape, ws, aq, gref, gws, gsteps)?;
        if let Some(gs) = gs {
            g_skip = Some(match g_skip {
                Some(acc) => add(&acc, &gs),
                None => gs,
            });
        }
        g = Some(gx);
    }
    Ok((g.unwrap_or_else(|| gout.clone()), g_skip))
}

/// Enumerate (unit-layer index, tape) pairs in layer binding order —
/// the act_obs statistics walk.
fn layer_tapes<'t>(
    nodes: &[Node],
    tapes: &'t [NodeTape],
) -> Vec<(usize, &'t LayerTape)> {
    let mut out = Vec::new();
    for (node, tape) in nodes.iter().zip(tapes.iter()) {
        match (node, tape) {
            (&Node::Layer(i), NodeTape::Layer(t)) => out.push((i, t)),
            (
                &Node::Basic { c1, c2, down },
                NodeTape::Basic { t1, t2, td, .. },
            ) => {
                out.push((c1, t1));
                out.push((c2, t2));
                if let (Some(d), Some(tdd)) = (down, td) {
                    out.push((d, tdd));
                }
            }
            (&Node::BasicL2 { c2, down }, NodeTape::BasicL2 { t2, td, .. }) => {
                out.push((c2, t2));
                if let (Some(d), Some(tdd)) = (down, td) {
                    out.push((d, tdd));
                }
            }
            (&Node::Ir { e, d, p, .. }, NodeTape::Ir { te, td, tp }) => {
                out.push((e, te));
                out.push((d, td));
                out.push((p, tp));
            }
            (&Node::IrL3 { p }, NodeTape::IrL3 { tp }) => out.push((p, tp)),
            (&Node::GapFc { fc }, NodeTape::GapFc { t, .. }) => {
                out.push((fc, t))
            }
            _ => {}
        }
    }
    out
}

// ------------------------------------------------------------------
// Executable programs
// ------------------------------------------------------------------

enum Prog {
    UnitFwd(UnitProg),
    UnitRecon(UnitProg),
    /// Whole-model logits over a granularity's unit stream.
    EvalFwd { units: Vec<UnitProg>, nl: usize },
    /// Per-layer [max|x|, mean|x|] input statistics, model layer order.
    ActObs { units: Vec<UnitProg>, nl: usize },
    /// d(loss)/d(unit output) at every unit of a granularity. The loss
    /// is batch-mean cross-entropy for classification models and
    /// batch-mean half-SSE against the regression-target rows for
    /// detection models (`det`).
    Fim { units: Vec<UnitProg>, nl: usize, det: bool },
}

pub struct NativeBackend {
    sigs: HashMap<String, ExeSig>,
    progs: HashMap<String, Prog>,
    dispatches: Dispatches,
}

/// Positional argument cursor over a validated arg slice.
struct Cursor<'a> {
    v: &'a [&'a Tensor],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn next(&mut self) -> &'a Tensor {
        let t = self.v[self.i];
        self.i += 1;
        t
    }

    fn scalar(&mut self) -> f32 {
        self.next().data[0]
    }
}

impl NativeBackend {
    /// Build the executable table from a manifest. Every exe name the
    /// manifest references resolves to an interpretable program; unknown
    /// topologies fail here, loudly.
    pub fn from_manifest(mf: &Manifest) -> Result<NativeBackend> {
        let sigs = parse_sigs(&mf.json)?;
        let mut progs: HashMap<String, Prog> = HashMap::new();
        for model in mf.models.values() {
            for g in model.grans.values() {
                let mut uprogs = Vec::new();
                for u in &g.units {
                    let up = build_unit_prog(model, u)?;
                    progs.insert(
                        u.fwd_exe.clone(),
                        Prog::UnitFwd(up.clone()),
                    );
                    progs.insert(
                        u.recon_exe.clone(),
                        Prog::UnitRecon(up.clone()),
                    );
                    uprogs.push(up);
                }
                progs.insert(
                    g.fim_exe.clone(),
                    Prog::Fim {
                        units: uprogs,
                        nl: model.layers.len(),
                        det: model.task == Task::Detect,
                    },
                );
            }
            // The model-level executables stream over the coarsest exported
            // granularity ("block" preferred; any works — stream semantics
            // are identical).
            let g = model
                .grans
                .get("block")
                .or_else(|| model.grans.values().next())
                .with_context(|| {
                    format!("{}: no granularities exported", model.name)
                })?;
            let units: Vec<UnitProg> = g
                .units
                .iter()
                .map(|u| build_unit_prog(model, u))
                .collect::<Result<Vec<_>>>()?;
            progs.insert(
                model.fwd_exe.clone(),
                Prog::EvalFwd {
                    units: units.clone(),
                    nl: model.layers.len(),
                },
            );
            progs.insert(
                model.act_obs_exe.clone(),
                Prog::ActObs { units, nl: model.layers.len() },
            );
        }
        Ok(NativeBackend { sigs, progs, dispatches: Dispatches::new() })
    }

    fn exec_unit_fwd(
        &self,
        u: &UnitProg,
        args: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let mut c = Cursor { v: args, i: 0 };
        let x = c.next();
        let skip = if u.uses_skip { Some(c.next()) } else { None };
        let nu = u.layers.len();
        let mut ws = Vec::with_capacity(nu);
        let mut bs = Vec::with_capacity(nu);
        for _ in 0..nu {
            ws.push(c.next());
            bs.push(c.next());
        }
        let mut sites = Vec::with_capacity(nu);
        for _ in 0..nu {
            let step = c.scalar();
            let lo = c.scalar();
            let hi = c.scalar();
            sites.push(AqParams { step, lo, hi });
        }
        let aq_on = c.scalar() > 0.0;
        let aq: Vec<Option<AqParams>> = sites
            .iter()
            .map(|p| if aq_on { Some(*p) } else { None })
            .collect();
        let (out, _) = run_unit(u, x, skip, &ws, &bs, &aq)?;
        Ok(vec![out])
    }

    fn exec_unit_recon(
        &self,
        u: &UnitProg,
        args: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let mut c = Cursor { v: args, i: 0 };
        let x = c.next();
        let skip = if u.uses_skip { Some(c.next()) } else { None };
        let z_fp = c.next();
        let fim = c.next();
        let nu = u.layers.len();
        let mut ws = Vec::with_capacity(nu);
        let mut bs = Vec::with_capacity(nu);
        let mut wsteps = Vec::with_capacity(nu);
        let mut vs = Vec::with_capacity(nu);
        let mut wns = Vec::with_capacity(nu);
        let mut wps = Vec::with_capacity(nu);
        for _ in 0..nu {
            ws.push(c.next());
            bs.push(c.next());
            wsteps.push(c.next());
            vs.push(c.next());
            wns.push(c.scalar());
            wps.push(c.scalar());
        }
        let mut sites = Vec::with_capacity(nu);
        for _ in 0..nu {
            let step = c.scalar();
            let lo = c.scalar();
            let hi = c.scalar();
            sites.push(AqParams { step, lo, hi });
        }
        let beta = c.scalar();
        let lam = c.scalar();
        let aq_on = c.scalar() > 0.0;
        let aq: Vec<Option<AqParams>> = sites
            .iter()
            .map(|p| if aq_on { Some(*p) } else { None })
            .collect();

        // soft-quantized weights (AdaRound, Eq. 16); per-channel steps
        // broadcast over the leading (out-channel) dim
        let what: Vec<Tensor> = (0..nu)
            .map(|i| {
                let w = ws[i];
                let inner = w.inner();
                let mut out = w.clone();
                for ch in 0..w.c0() {
                    let s = wsteps[i].data[ch];
                    for e in ch * inner..(ch + 1) * inner {
                        out.data[e] = adaround(
                            w.data[e],
                            s,
                            vs[i].data[e],
                            wns[i],
                            wps[i],
                        );
                    }
                }
                out
            })
            .collect();
        let wrefs: Vec<&Tensor> = what.iter().collect();

        let (zq, tapes) = run_unit(u, x, skip, &wrefs, &bs, &aq)?;
        let rec = fim_loss(z_fp, &zq, fim);

        // backward
        let g_zq = fim_loss_grad_zq(z_fp, &zq, fim);
        let mut gws: Vec<Tensor> =
            ws.iter().map(|w| Tensor::zeros(w.shape.clone())).collect();
        let mut gsteps = vec![0f32; nu];
        run_unit_bwd(u, &tapes, &wrefs, &aq, &g_zq, &mut gws, &mut gsteps)?;

        // One fused pass per rounding variable: the rounding regularizer
        // sum_i sum(1 - |2h(v)-1|^beta) and the chain to v
        // (gv = gw_hat * step * inside * h'(v) + lam * d(rl)/dv) both need
        // h(v) — [`gv_reg_elem`] evaluates the sigmoid once per element
        // and serves both. The rl chain accumulates in the same
        // layer-then-linear element order as the former standalone loop,
        // so the sum (and every gv element) is bit-identical to the
        // two-pass form.
        let mut rl = 0f64;
        let mut gvs = Vec::with_capacity(nu);
        for i in 0..nu {
            let w = ws[i];
            let inner = w.inner();
            let mut gv = Tensor::zeros(w.shape.clone());
            for ch in 0..w.c0() {
                let s = wsteps[i].data[ch];
                for e in ch * inner..(ch + 1) * inner {
                    let (term, g) = gv_reg_elem(
                        w.data[e],
                        s,
                        vs[i].data[e],
                        wns[i],
                        wps[i],
                        gws[i].data[e],
                        beta,
                        lam,
                    );
                    rl += term;
                    gv.data[e] = g;
                }
            }
            gvs.push(gv);
        }

        let mut out = vec![
            Tensor::scalar1((rec + lam as f64 * rl) as f32),
            Tensor::scalar1(rec as f32),
            Tensor::scalar1(rl as f32),
        ];
        out.extend(gvs);
        for gs in gsteps {
            out.push(Tensor::scalar1(if aq_on { gs } else { 0.0 }));
        }
        Ok(out)
    }

    /// Shared stream walk for the model-level executables. Returns the
    /// final output plus (unit outputs, tapes) when `keep` is set.
    #[allow(clippy::type_complexity)]
    fn stream(
        units: &[UnitProg],
        images: &Tensor,
        ws: &[&Tensor],
        bs: &[&Tensor],
        aq: &[Option<AqParams>],
        keep: bool,
    ) -> Result<(Tensor, Vec<(Tensor, Vec<NodeTape>)>)> {
        let mut main = images.clone();
        let mut skip: Option<Tensor> = None;
        let mut kept = Vec::new();
        for u in units {
            if u.save_skip {
                skip = Some(main.clone());
            }
            let uws: Vec<&Tensor> =
                u.model_ids.iter().map(|&m| ws[m]).collect();
            let ubs: Vec<&Tensor> =
                u.model_ids.iter().map(|&m| bs[m]).collect();
            let uaq: Vec<Option<AqParams>> =
                u.model_ids.iter().map(|&m| aq[m]).collect();
            let (out, tapes) =
                run_unit(u, &main, skip.as_ref(), &uws, &ubs, &uaq)?;
            if keep {
                kept.push((out.clone(), tapes));
            }
            main = out;
            if u.uses_skip {
                skip = None;
            }
        }
        Ok((main, kept))
    }

    /// Per-batch work estimate (scalar MACs) for one stream pass.
    fn stream_work(units: &[UnitProg], b: usize) -> usize {
        let macs: u64 = units
            .iter()
            .flat_map(|u| u.layers.iter())
            .map(|l| l.macs)
            .sum();
        (macs as usize).saturating_mul(b)
    }

    /// Contiguous sample ranges (start, len) covering `0..b`, sized for
    /// the worker pool (about two chunks per thread). Chunk boundaries
    /// never affect results: every layer family treats sample rows
    /// independently.
    fn sample_chunks(b: usize) -> Vec<(usize, usize)> {
        let grain = b.div_ceil(pool::threads().max(1) * 2).max(1);
        (0..b)
            .step_by(grain)
            .map(|s| (s, grain.min(b - s)))
            .collect()
    }

    /// Forward the unit stream, splitting the batch into sample chunks
    /// across the worker pool. The stitched logits are bit-identical to
    /// the single-batch walk.
    fn stream_fwd_par(
        units: &[UnitProg],
        images: &Tensor,
        ws: &[&Tensor],
        bs: &[&Tensor],
        aq: &[Option<AqParams>],
    ) -> Result<Tensor> {
        let b = images.shape[0];
        if b <= 1 || !pool::active(Self::stream_work(units, b)) {
            let (out, _) = Self::stream(units, images, ws, bs, aq, false)?;
            return Ok(out);
        }
        let chunks = Self::sample_chunks(b);
        let outs = pool::par_fill(chunks.len(), 1, usize::MAX, |ci| {
            let (start, len) = chunks[ci];
            let xb = images.slice0(start, len);
            Self::stream(units, &xb, ws, bs, aq, false).map(|(out, _)| out)
        });
        let mut parts = Vec::with_capacity(outs.len());
        for r in outs {
            parts.push(r?);
        }
        Ok(Tensor::stack0(&parts))
    }

    fn parse_model_args<'a>(
        c: &mut Cursor<'a>,
        nl: usize,
    ) -> (Vec<&'a Tensor>, Vec<&'a Tensor>) {
        let mut ws = Vec::with_capacity(nl);
        let mut bs = Vec::with_capacity(nl);
        for _ in 0..nl {
            ws.push(c.next());
            bs.push(c.next());
        }
        (ws, bs)
    }

    fn exec_eval_fwd(
        &self,
        units: &[UnitProg],
        nl: usize,
        args: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let mut c = Cursor { v: args, i: 0 };
        let images = c.next();
        let (ws, bs) = Self::parse_model_args(&mut c, nl);
        let mut sites = Vec::with_capacity(nl);
        for _ in 0..nl {
            let step = c.scalar();
            let lo = c.scalar();
            let hi = c.scalar();
            sites.push(AqParams { step, lo, hi });
        }
        let aq_on = c.scalar() > 0.0;
        let aq: Vec<Option<AqParams>> = sites
            .iter()
            .map(|p| if aq_on { Some(*p) } else { None })
            .collect();
        let logits = Self::stream_fwd_par(units, images, &ws, &bs, &aq)?;
        Ok(vec![logits])
    }

    fn exec_act_obs(
        &self,
        units: &[UnitProg],
        nl: usize,
        args: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let mut c = Cursor { v: args, i: 0 };
        let images = c.next();
        let (ws, bs) = Self::parse_model_args(&mut c, nl);
        let aq = vec![None; nl];
        let b = images.shape[0];
        // Forward tapes per sample chunk on the pool; the statistics walk
        // below runs on this thread in chunk order, so every per-layer
        // accumulator sees elements in exactly the batched linear order —
        // results are bit-identical at any thread count.
        let chunks = if b > 1 && pool::active(Self::stream_work(units, b)) {
            Self::sample_chunks(b)
        } else {
            vec![(0, b)]
        };
        let kept_chunks = pool::par_fill(chunks.len(), 1, usize::MAX, |ci| {
            let (start, len) = chunks[ci];
            let xb = images.slice0(start, len);
            Self::stream(units, &xb, &ws, &bs, &aq, true)
                .map(|(_, kept)| kept)
        });
        let mut maxabs = vec![0f32; nl];
        let mut sums = vec![0f64; nl];
        let mut counts = vec![0usize; nl];
        for kc in kept_chunks {
            let kept = kc?;
            for (u, (_, tapes)) in units.iter().zip(kept.iter()) {
                for (li, tape) in layer_tapes(&u.nodes, tapes) {
                    let m = u.model_ids[li];
                    counts[m] += tape.x.data.len();
                    for &v in &tape.x.data {
                        let a = v.abs();
                        maxabs[m] = maxabs[m].max(a);
                        sums[m] += a as f64;
                    }
                }
            }
        }
        Ok((0..nl)
            .map(|m| {
                let mean = (sums[m] / counts[m].max(1) as f64) as f32;
                Tensor::new(vec![2], vec![maxabs[m], mean])
            })
            .collect())
    }

    /// One FIM walk over `images`: forward the stream (keeping tapes),
    /// seed d(loss)/d(logits) with the batch-mean divisor `denom`, then
    /// reverse the stream recording the grad at every unit output. The
    /// seed is `(softmax - onehot)/denom` for classification and, with
    /// `det`, `(logits - target)/denom` — the gradient of batch-mean
    /// half-SSE against the regression-target rows fed through the
    /// onehot slot. Sample rows are independent end to end (the per-unit
    /// weight/step grads this computes on the side are discarded), so
    /// chunked calls stitched along dim 0 reproduce the single-batch walk
    /// bitwise.
    #[allow(clippy::too_many_arguments)]
    fn fim_walk(
        units: &[UnitProg],
        images: &Tensor,
        onehot: &Tensor,
        ws: &[&Tensor],
        bs: &[&Tensor],
        aq: &[Option<AqParams>],
        denom: f32,
        det: bool,
    ) -> Result<Vec<Tensor>> {
        let (logits, kept) = Self::stream(units, images, ws, bs, aq, true)?;

        let (b, classes) = (logits.shape[0], logits.shape[1]);
        let mut g = vec![0f32; b * classes];
        if det {
            // d(mean-batch half-SSE)/d(logits) = (logits - target)/denom
            for i in 0..b * classes {
                g[i] = (logits.data[i] - onehot.data[i]) / denom;
            }
        } else {
            // d(mean-batch cross-entropy)/d(logits)
            //   = (softmax - onehot)/denom
            for bi in 0..b {
                let row = &logits.data[bi * classes..(bi + 1) * classes];
                let m =
                    row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
                let exps: Vec<f32> =
                    row.iter().map(|&x| (x - m).exp()).collect();
                let z: f32 = exps.iter().sum();
                for ci in 0..classes {
                    g[bi * classes + ci] = (exps[ci] / z
                        - onehot.data[bi * classes + ci])
                        / denom;
                }
            }
        }
        let mut g_main = Tensor::new(vec![b, classes], g);

        // reverse stream: record the grad at every unit output; skip grads
        // re-join the main grad at the unit whose input was captured.
        let mut out_grads: Vec<Option<Tensor>> = vec![None; units.len()];
        let mut g_skip_pending: Option<Tensor> = None;
        for ui in (0..units.len()).rev() {
            let u = &units[ui];
            out_grads[ui] = Some(g_main.clone());
            let uws: Vec<&Tensor> =
                u.model_ids.iter().map(|&m| ws[m]).collect();
            let uaq: Vec<Option<AqParams>> =
                u.model_ids.iter().map(|&m| aq[m]).collect();
            let mut gws: Vec<Tensor> = uws
                .iter()
                .map(|w| Tensor::zeros(w.shape.clone()))
                .collect();
            let mut gsteps = vec![0f32; uws.len()];
            let (g_in, g_skip) = run_unit_bwd(
                u,
                &kept[ui].1,
                &uws,
                &uaq,
                &g_main,
                &mut gws,
                &mut gsteps,
            )?;
            if u.uses_skip {
                g_skip_pending = g_skip;
            }
            g_main = g_in;
            if u.save_skip {
                if let Some(gs) = g_skip_pending.take() {
                    g_main = add(&g_main, &gs);
                }
            }
        }
        Ok(out_grads.into_iter().map(|g| g.unwrap()).collect())
    }

    fn exec_fim(
        &self,
        units: &[UnitProg],
        nl: usize,
        det: bool,
        args: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let mut c = Cursor { v: args, i: 0 };
        let images = c.next();
        let onehot = c.next();
        let (ws, bs) = Self::parse_model_args(&mut c, nl);
        let aq = vec![None; nl];
        let b = images.shape[0];
        let denom = b as f32;
        // forward + backward: roughly 3x one forward pass
        let work = Self::stream_work(units, b).saturating_mul(3);
        if b <= 1 || !pool::active(work) {
            return Self::fim_walk(
                units, images, onehot, &ws, &bs, &aq, denom, det,
            );
        }
        let chunks = Self::sample_chunks(b);
        let per_chunk = pool::par_fill(chunks.len(), 1, usize::MAX, |ci| {
            let (start, len) = chunks[ci];
            let xb = images.slice0(start, len);
            let ob = onehot.slice0(start, len);
            Self::fim_walk(units, &xb, &ob, &ws, &bs, &aq, denom, det)
        });
        let mut per_unit: Vec<Vec<Tensor>> =
            (0..units.len()).map(|_| Vec::new()).collect();
        for r in per_chunk {
            for (u, g) in r?.into_iter().enumerate() {
                per_unit[u].push(g);
            }
        }
        Ok(per_unit.into_iter().map(|p| Tensor::stack0(&p)).collect())
    }
}

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn signature(&self, name: &str) -> Option<&ExeSig> {
        self.sigs.get(name)
    }

    fn execute(&self, name: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let prog = self
            .progs
            .get(name)
            .with_context(|| format!("native backend: no program '{name}'"))?;
        match prog {
            Prog::UnitFwd(u) => self.exec_unit_fwd(u, args),
            Prog::UnitRecon(u) => self.exec_unit_recon(u, args),
            Prog::EvalFwd { units, nl } => {
                self.exec_eval_fwd(units, *nl, args)
            }
            Prog::ActObs { units, nl } => self.exec_act_obs(units, *nl, args),
            Prog::Fim { units, nl, det } => {
                self.exec_fim(units, *nl, *det, args)
            }
        }
    }

    fn dispatches(&self) -> &Dispatches {
        &self.dispatches
    }

    fn compiled_count(&self) -> usize {
        self.progs.len()
    }

    /// Compile a stateful reconstruction plan for a `unit_recon`
    /// executable (see [`super::plan`]) — single- and multi-node (seq)
    /// unit programs alike. Only node shapes whose shared-gradient
    /// masking cannot be done in place return `None` and fall back to
    /// per-iteration dispatch — the retained parity path.
    fn prepare_recon<'p>(
        &'p self,
        name: &str,
        inputs: super::plan::PlanInputs<'p>,
    ) -> Result<Option<Box<dyn super::plan::ReconPlan + 'p>>> {
        let Some(Prog::UnitRecon(u)) = self.progs.get(name) else {
            return Ok(None);
        };
        let t0 = std::time::Instant::now();
        let plan = super::plan::build_native_plan(u, inputs)?;
        if plan.is_some() {
            self.dispatches.record(
                &format!("{name}#plan_build"),
                t0.elapsed().as_secs_f64(),
            );
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_pads_matches_tf_convention() {
        // k=3, s=1: symmetric pad 1
        assert_eq!(same_pads(8, 3, 1), (8, 1));
        // k=3, s=2, h=8: out 4, total pad 1, low pad 0 (pad-more-on-high)
        assert_eq!(same_pads(8, 3, 2), (4, 0));
        // k=1: no pad
        assert_eq!(same_pads(8, 1, 2), (4, 0));
        assert_eq!(same_pads(7, 5, 1), (7, 2));
    }

    #[test]
    fn conv_1x1_equals_channel_matmul() {
        // 1x1 conv == per-pixel matmul over channels
        let x = Tensor::new(
            vec![1, 2, 2, 2],
            vec![1., 2., 3., 4., 5., 6., 7., 8.],
        );
        let w = Tensor::new(vec![1, 2, 1, 1], vec![10.0, 0.5]);
        let out = conv2d(&x, &w, 1, 1);
        assert_eq!(out.shape, vec![1, 1, 2, 2]);
        // out[h,w] = 10*x0[h,w] + 0.5*x1[h,w]
        assert_eq!(out.data, vec![12.5, 23.0, 33.5, 44.0]);
    }

    #[test]
    fn depthwise_conv_scales_channels() {
        let x = Tensor::new(vec![1, 2, 1, 1], vec![3.0, 4.0]);
        let w = Tensor::new(vec![2, 1, 1, 1], vec![2.0, -1.0]);
        let out = conv2d(&x, &w, 1, 2);
        assert_eq!(out.data, vec![6.0, -4.0]);
    }

    #[test]
    fn conv_grads_match_finite_differences() {
        let mut rng = crate::util::rng::Rng::new(7);
        let x = Tensor::new(
            vec![2, 3, 5, 5],
            (0..2 * 3 * 5 * 5).map(|_| rng.gauss() as f32).collect(),
        );
        let w = Tensor::new(
            vec![4, 3, 3, 3],
            (0..4 * 3 * 3 * 3).map(|_| rng.gauss() as f32 * 0.3).collect(),
        );
        let gout = {
            let probe = conv2d(&x, &w, 2, 1);
            Tensor::new(
                probe.shape.clone(),
                (0..probe.numel()).map(|_| rng.gauss() as f32).collect(),
            )
        };
        let loss = |x: &Tensor, w: &Tensor| -> f64 {
            let z = conv2d(x, w, 2, 1);
            z.data
                .iter()
                .zip(&gout.data)
                .map(|(a, g)| (*a as f64) * (*g as f64))
                .sum()
        };
        let (gx, gw) = conv2d_bwd(&x, &w, 2, 1, &gout);
        let eps = 1e-2f32;
        for idx in [0usize, 17, 63, 149] {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let num = (loss(&xp, &w) - loss(&x, &w)) / eps as f64;
            assert!(
                (num - gx.data[idx] as f64).abs() < 2e-2,
                "gx[{idx}]: fd {num} vs {}",
                gx.data[idx]
            );
        }
        for idx in [0usize, 31, 80, 107] {
            let mut wp = w.clone();
            wp.data[idx] += eps;
            let num = (loss(&x, &wp) - loss(&x, &w)) / eps as f64;
            assert!(
                (num - gw.data[idx] as f64).abs() < 2e-2,
                "gw[{idx}]: fd {num} vs {}",
                gw.data[idx]
            );
        }
    }

    #[test]
    fn lsq_grad_piecewise() {
        // below, above, interior — per Eq. 18
        let (gx, gs) = lsq_grads(-10.0, 1.0, -8.0, 7.0, 2.0);
        assert_eq!((gx, gs), (0.0, -16.0));
        let (gx, gs) = lsq_grads(10.0, 1.0, -8.0, 7.0, 2.0);
        assert_eq!((gx, gs), (0.0, 14.0));
        let (gx, gs) = lsq_grads(1.3, 1.0, -8.0, 7.0, 2.0);
        assert_eq!(gx, 2.0);
        assert!((gs - 2.0 * (1.0 - 1.3)).abs() < 1e-6);
    }

    #[test]
    fn topo_parser_roundtrip() {
        assert_eq!(parse_topo("conv", 1).unwrap().len(), 1);
        assert_eq!(parse_topo("gap_fc", 1).unwrap().len(), 1);
        assert_eq!(parse_topo("basic(down=true)", 3).unwrap().len(), 1);
        assert_eq!(parse_topo("basic(down=false)", 2).unwrap().len(), 1);
        assert_eq!(parse_topo("basic_l2(down=True)", 2).unwrap().len(), 1);
        assert_eq!(parse_topo("ir(res=True)", 3).unwrap().len(), 1);
        assert_eq!(parse_topo("ir_l3(res)", 1).unwrap().len(), 1);
        let seq = parse_topo("seq(basic(down=false),basic(down=true))", 5)
            .unwrap();
        assert_eq!(seq.len(), 2);
        // wrong layer count
        assert!(parse_topo("basic(down=true)", 2).is_err());
        // unknown tag
        assert!(parse_topo("xblock(down=true)", 4).is_err());
    }

    #[test]
    fn split_top_level_respects_parens() {
        assert_eq!(
            split_top_level("basic(down=false),ir(res=true),conv"),
            vec!["basic(down=false)", "ir(res=true)", "conv"]
        );
    }
}
