//! Backend-agnostic executable runtime.
//!
//! The coordinator drives every compute graph — `unit_fwd`, `unit_recon`,
//! `eval_fwd`, `fim`, `act_obs`, ... — through the [`Backend`] trait:
//! named executables with manifest-declared positional signatures. Two
//! implementations exist:
//!
//! * [`native`] — a pure-Rust interpreter that executes the executable
//!   families directly (ports of the pure-jnp oracles in
//!   `python/compile/kernels/ref.py`). No external toolchain; this is the
//!   default and what the hermetic test suite runs on.
//! * [`pjrt`] (cargo feature `pjrt`) — compiles the AOT HLO-text artifacts
//!   produced by `python/compile/aot.py` via the `xla` crate and executes
//!   them on PJRT. Needs the XLA toolchain and `make artifacts`.
//!
//! Every dispatch goes through the provided [`Backend::run`], which checks
//! argument count/shape against the manifest signature (an ABI mismatch
//! fails loudly at dispatch, not as garbage numerics) and records
//! per-executable dispatch accounting for the perf report.

pub mod gemm;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod plan;

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;

/// Signature of one executable (from the manifest).
#[derive(Debug, Clone)]
pub struct ExeSig {
    pub name: String,
    pub file: String,
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<(String, Vec<usize>)>,
}

/// Parse the manifest's `executables` table into signatures.
pub fn parse_sigs(manifest: &Json) -> Result<HashMap<String, ExeSig>> {
    let mut sigs = HashMap::new();
    let exes = manifest
        .req("executables")
        .as_obj()
        .context("manifest: executables")?;
    for (name, e) in exes {
        let parse_io = |key: &str| -> Vec<(String, Vec<usize>)> {
            e.req(key)
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| {
                    (
                        x.req("name").as_str().unwrap().to_string(),
                        x.req("shape").usize_vec(),
                    )
                })
                .collect()
        };
        sigs.insert(
            name.clone(),
            ExeSig {
                name: name.clone(),
                file: e
                    .get("file")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
                inputs: parse_io("inputs"),
                outputs: parse_io("outputs"),
            },
        );
    }
    Ok(sigs)
}

fn check_inputs(sig: &ExeSig, args: &[&Tensor]) -> Result<()> {
    if args.len() != sig.inputs.len() {
        bail!(
            "{}: got {} args, signature has {}",
            sig.name,
            args.len(),
            sig.inputs.len()
        );
    }
    for (t, (name, shape)) in args.iter().zip(&sig.inputs) {
        if &t.shape != shape {
            bail!(
                "{}: input '{}' shape {:?} != expected {:?}",
                sig.name,
                name,
                t.shape,
                shape
            );
        }
    }
    Ok(())
}

fn check_outputs(sig: &ExeSig, out: &[Tensor]) -> Result<()> {
    if out.len() != sig.outputs.len() {
        bail!(
            "{}: got {} outputs, signature has {}",
            sig.name,
            out.len(),
            sig.outputs.len()
        );
    }
    for (t, (name, shape)) in out.iter().zip(&sig.outputs) {
        if &t.shape != shape {
            bail!(
                "{}: output '{}' shape {:?} != declared {:?}",
                sig.name,
                name,
                t.shape,
                shape
            );
        }
    }
    Ok(())
}

/// Per-executable dispatch accounting: (calls, total seconds). Interior
/// mutability so backends can record through `&self`; a `Mutex` (not
/// `RefCell`) because the worker pool dispatches executables concurrently
/// from `util::pool` threads.
#[derive(Default)]
pub struct Dispatches {
    inner: Mutex<HashMap<String, (u64, f64)>>,
}

impl Dispatches {
    pub fn new() -> Dispatches {
        Dispatches::default()
    }

    pub fn record(&self, name: &str, seconds: f64) {
        let mut d = self.inner.lock().unwrap();
        let ent = d.entry(name.to_string()).or_insert((0, 0.0));
        ent.0 += 1;
        ent.1 += seconds;
    }

    /// Top-k hot spots: (exe, calls, total seconds), hottest first.
    pub fn hotspots(&self, k: usize) -> Vec<(String, u64, f64)> {
        let d = self.inner.lock().unwrap();
        let mut v: Vec<(String, u64, f64)> =
            d.iter().map(|(n, (c, t))| (n.clone(), *c, *t)).collect();
        v.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        v.truncate(k);
        v
    }
}

/// An executable provider: compiles/interprets named executables against
/// their manifest signatures. All algorithm code takes `&dyn Backend`.
///
/// `Sync` is a supertrait: the calibration engine shares one backend
/// across `util::pool` workers (parallel stream advancement, sensitivity
/// probes), so implementations must be safe to dispatch concurrently.
pub trait Backend: Sync {
    /// Short backend tag ("native" | "pjrt") for logs and reports.
    fn kind(&self) -> &'static str;

    /// Signature of a manifest executable, if it exists.
    fn signature(&self, name: &str) -> Option<&ExeSig>;

    /// Raw execution — implementors only. Callers use [`Backend::run`],
    /// which validates the ABI and records dispatch accounting.
    fn execute(&self, name: &str, args: &[&Tensor]) -> Result<Vec<Tensor>>;

    /// Dispatch accounting storage (one per backend instance).
    fn dispatches(&self) -> &Dispatches;

    /// Number of distinct executables prepared (compiled / instantiated).
    fn compiled_count(&self) -> usize;

    /// Compile a stateful reconstruction plan for a `unit_recon`
    /// executable: the unit is lowered once, and `plan.step(...)` then
    /// runs Algorithm-1 iterations with zero steady-state allocation and
    /// no per-iteration re-lowering (see [`plan`]). Backends without plan
    /// support — and units a backend declines to plan — return
    /// `Ok(None)`; the caller falls back to per-iteration [`Backend::run`]
    /// dispatches, which are retained as the bit-parity reference path.
    fn prepare_recon<'p>(
        &'p self,
        name: &str,
        inputs: plan::PlanInputs<'p>,
    ) -> Result<Option<Box<dyn plan::ReconPlan + 'p>>> {
        let _ = (name, inputs);
        Ok(None)
    }

    /// Validated, accounted dispatch of one executable.
    fn run(&self, name: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let sig = self
            .signature(name)
            .with_context(|| format!("unknown executable '{name}'"))?;
        check_inputs(sig, args)?;
        let t0 = std::time::Instant::now();
        let out = self.execute(name, args)?;
        self.dispatches().record(name, t0.elapsed().as_secs_f64());
        check_outputs(sig, &out)?;
        Ok(out)
    }

    /// Top-k dispatch hot spots: (exe, calls, total seconds).
    fn hotspots(&self, k: usize) -> Vec<(String, u64, f64)> {
        self.dispatches().hotspots(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo {
        sigs: HashMap<String, ExeSig>,
        dispatches: Dispatches,
    }

    impl Backend for Echo {
        fn kind(&self) -> &'static str {
            "echo"
        }
        fn signature(&self, name: &str) -> Option<&ExeSig> {
            self.sigs.get(name)
        }
        fn execute(&self, _name: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
            Ok(vec![args[0].clone()])
        }
        fn dispatches(&self) -> &Dispatches {
            &self.dispatches
        }
        fn compiled_count(&self) -> usize {
            self.sigs.len()
        }
    }

    fn echo() -> Echo {
        let mut sigs = HashMap::new();
        sigs.insert(
            "id".to_string(),
            ExeSig {
                name: "id".into(),
                file: String::new(),
                inputs: vec![("x".into(), vec![2, 2])],
                outputs: vec![("y".into(), vec![2, 2])],
            },
        );
        Echo { sigs, dispatches: Dispatches::new() }
    }

    #[test]
    fn run_validates_and_accounts() {
        let b = echo();
        let x = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let out = b.run("id", &[&x]).unwrap();
        assert_eq!(out[0].data, x.data);
        // wrong arity
        assert!(b.run("id", &[&x, &x]).is_err());
        // wrong shape
        let bad = Tensor::zeros(vec![3]);
        assert!(b.run("id", &[&bad]).is_err());
        // unknown exe
        assert!(b.run("nope", &[&x]).is_err());
        let hot = b.hotspots(4);
        assert_eq!(hot.len(), 1);
        assert_eq!(hot[0].0, "id");
        assert_eq!(hot[0].1, 1); // only the valid dispatch counted
    }

    #[test]
    fn trait_object_dispatch() {
        let b = echo();
        let dynb: &dyn Backend = &b;
        let x = Tensor::new(vec![2, 2], vec![0.; 4]);
        assert!(dynb.run("id", &[&x]).is_ok());
        assert_eq!(dynb.kind(), "echo");
        assert_eq!(dynb.compiled_count(), 1);
    }
}
