//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! This is the only module that touches the `xla` crate. The pattern
//! (HLO text -> HloModuleProto -> XlaComputation -> compile -> execute)
//! follows /opt/xla-example/load_hlo.rs; text is the interchange format
//! because xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id protos.
//!
//! Executables are compiled lazily and cached per name — experiments touch
//! only the units they need, and repeated calibrations reuse the cache.
//! Every call checks argument count/shape against the manifest signature so
//! an ABI mismatch fails loudly at dispatch, not as garbage numerics.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;

/// Signature of one AOT executable (from the manifest).
#[derive(Debug, Clone)]
pub struct ExeSig {
    pub name: String,
    pub file: String,
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<(String, Vec<usize>)>,
}

pub struct Executable {
    pub sig: ExeSig,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with positional tensors matching the manifest signature.
    pub fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        if args.len() != self.sig.inputs.len() {
            bail!(
                "{}: got {} args, signature has {}",
                self.sig.name,
                args.len(),
                self.sig.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (t, (name, shape)) in args.iter().zip(&self.sig.inputs) {
            if &t.shape != shape {
                bail!(
                    "{}: input '{}' shape {:?} != expected {:?}",
                    self.sig.name,
                    name,
                    t.shape,
                    shape
                );
            }
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .with_context(|| format!("reshaping input {name}"))?,
            );
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // AOT lowering uses return_tuple=True: always a tuple literal.
        let parts = result.to_tuple()?;
        if parts.len() != self.sig.outputs.len() {
            bail!(
                "{}: got {} outputs, signature has {}",
                self.sig.name,
                parts.len(),
                self.sig.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, (name, shape)) in parts.iter().zip(&self.sig.outputs) {
            let data = lit
                .to_vec::<f32>()
                .with_context(|| format!("reading output {name}"))?;
            out.push(Tensor::new(shape.clone(), data));
        }
        Ok(out)
    }
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    sigs: HashMap<String, ExeSig>,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// per-executable dispatch counters (count, seconds) for the perf report
    pub dispatches: RefCell<HashMap<String, (u64, f64)>>,
}

impl Runtime {
    /// `dir` is the artifacts directory containing manifest.json.
    pub fn new(dir: &Path, manifest: &Json) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let mut sigs = HashMap::new();
        let exes = manifest
            .req("executables")
            .as_obj()
            .context("manifest: executables")?;
        for (name, e) in exes {
            let parse_io = |key: &str| -> Vec<(String, Vec<usize>)> {
                e.req(key)
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|x| {
                        (
                            x.req("name").as_str().unwrap().to_string(),
                            x.req("shape").usize_vec(),
                        )
                    })
                    .collect()
            };
            sigs.insert(
                name.clone(),
                ExeSig {
                    name: name.clone(),
                    file: e.req("file").as_str().unwrap().to_string(),
                    inputs: parse_io("inputs"),
                    outputs: parse_io("outputs"),
                },
            );
        }
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            sigs,
            cache: RefCell::new(HashMap::new()),
            dispatches: RefCell::new(HashMap::new()),
        })
    }

    pub fn signature(&self, name: &str) -> Option<&ExeSig> {
        self.sigs.get(name)
    }

    /// Compile (or fetch from cache) an executable by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let sig = self
            .sigs
            .get(name)
            .with_context(|| format!("unknown executable '{name}'"))?
            .clone();
        let path = self.dir.join(&sig.file);
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parsing HLO {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let e = Rc::new(Executable { sig, exe });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Convenience: load + run with dispatch accounting.
    pub fn run(&self, name: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.load(name)?;
        let t0 = std::time::Instant::now();
        let out = exe.run(args)?;
        let dt = t0.elapsed().as_secs_f64();
        let mut d = self.dispatches.borrow_mut();
        let ent = d.entry(name.to_string()).or_insert((0, 0.0));
        ent.0 += 1;
        ent.1 += dt;
        Ok(out)
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Top-k dispatch hot spots: (exe, calls, total seconds).
    pub fn hotspots(&self, k: usize) -> Vec<(String, u64, f64)> {
        let d = self.dispatches.borrow();
        let mut v: Vec<(String, u64, f64)> = d
            .iter()
            .map(|(n, (c, t))| (n.clone(), *c, *t))
            .collect();
        v.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        v.truncate(k);
        v
    }
}
