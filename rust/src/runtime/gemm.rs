//! Shared cache-blocked, register-tiled f32 matmul micro-kernel — the
//! single compute primitive behind the native backend's conv/fc forward
//! and backward paths (im2col + GEMM; see `runtime::native`).
//!
//! # Determinism contract
//!
//! Every output element `C[i,j]` is produced by **one** accumulator that
//! walks the reduction dimension `k` in strictly ascending order:
//!
//! * the micro-kernel keeps an `MR x NR` register tile and advances all
//!   of its accumulators one `k` step at a time (lane-parallel across the
//!   tile, sequential along `k` — no split accumulators, no `mul_add`
//!   contraction, so each lane performs exactly the two IEEE roundings
//!   of the scalar loop `acc += a*b`);
//! * `KC` blocking stores the tile back to `C` between `k` blocks and
//!   reloads it for the next, which extends the same sequential chain —
//!   association is unchanged;
//! * panel edges are zero-padded in the packed operands; the padded lanes
//!   compute `acc += 0.0 * x` into lanes that are never stored.
//!
//! Consequently a `gemm` call is bit-identical to the naive ordered
//! triple loop for any blocking parameters, and callers that partition
//! `C` across pool workers (ownership-partitioned rows) get bit-identical
//! results at any thread count. `tests/parallel.rs` pins this against the
//! retained scalar reference loops.
//!
//! Operands are described by (base slice, row stride, col stride) so the
//! packing routines absorb transposed and sub-matrix views; the packed
//! panels live in [`pool::Scratch`] buffers, so steady-state calls do no
//! heap allocation.

// Packing and micro-kernel loops index several buffers through shared
// offset arithmetic; iterator forms would obscure the panel math (same
// rationale as runtime::native).
#![allow(clippy::needless_range_loop)]

use crate::util::pool;

/// Micro-tile rows (register blocking in M).
pub const MR: usize = 4;
/// Micro-tile columns (register blocking in N; two 8-lane vectors).
pub const NR: usize = 16;
/// Reduction-dimension cache block (packed panels stay L1/L2 resident).
pub const KC: usize = 256;
/// Row cache block.
pub const MC: usize = 128;
/// Column cache block.
pub const NC: usize = 512;

/// Pack an `mc x kc` block of A (element `(i, k)` at `i*rs + k*cs` from
/// `base`) into MR-row panels: `out[(ip*kc + kk)*MR + i]`, zero-padding
/// the last panel's rows. Panel-major so the micro-kernel streams it.
#[allow(clippy::too_many_arguments)]
pub fn pack_a(
    a: &[f32],
    rs: usize,
    cs: usize,
    i0: usize,
    mc: usize,
    k0: usize,
    kc: usize,
    out: &mut [f32],
) {
    let npanels = mc.div_ceil(MR);
    for ip in 0..npanels {
        let ibase = i0 + ip * MR;
        let mr = MR.min(i0 + mc - ibase);
        for kk in 0..kc {
            let o = (ip * kc + kk) * MR;
            let col = (k0 + kk) * cs;
            for i in 0..mr {
                out[o + i] = a[(ibase + i) * rs + col];
            }
            for i in mr..MR {
                out[o + i] = 0.0;
            }
        }
    }
}

/// Pack a `kc x nc` block of B (element `(k, j)` at `k*rs + j*cs` from
/// `base`) into NR-column panels: `out[(jp*kc + kk)*NR + j]`, zero-padding
/// the last panel's columns.
#[allow(clippy::too_many_arguments)]
pub fn pack_b(
    b: &[f32],
    rs: usize,
    cs: usize,
    k0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
    out: &mut [f32],
) {
    let npanels = nc.div_ceil(NR);
    for jp in 0..npanels {
        let jbase = j0 + jp * NR;
        let nr = NR.min(j0 + nc - jbase);
        for kk in 0..kc {
            let o = (jp * kc + kk) * NR;
            let row = (k0 + kk) * rs;
            for j in 0..nr {
                out[o + j] = b[row + (jbase + j) * cs];
            }
            for j in nr..NR {
                out[o + j] = 0.0;
            }
        }
    }
}

/// MR x NR register-tile micro-kernel over one packed A panel and one
/// packed B panel: loads the live `mr x nr` sub-tile of C, advances every
/// accumulator through `kc` reduction steps in order, stores it back.
#[inline]
fn kern(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    c: &mut [f32],
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[0f32; NR]; MR];
    for i in 0..mr {
        for j in 0..nr {
            acc[i][j] = c[i * ldc + j];
        }
    }
    for kk in 0..kc {
        let a: &[f32; MR] = ap[kk * MR..kk * MR + MR].try_into().unwrap();
        let b: &[f32; NR] = bp[kk * NR..kk * NR + NR].try_into().unwrap();
        for i in 0..MR {
            let ai = a[i];
            for j in 0..NR {
                // deliberately not f32::mul_add: the scalar reference
                // loops round the product and the sum separately
                acc[i][j] += ai * b[j];
            }
        }
    }
    for i in 0..mr {
        for j in 0..nr {
            c[i * ldc + j] = acc[i][j];
        }
    }
}

/// `C[m x n] += A[m x k] * B[k x n]`, bit-identical to the ordered naive
/// triple loop (see the module docs). `C` is row-major with leading
/// dimension `ldc` and is **accumulated into** — callers start from a
/// zeroed output (or a previous partial sum, extending the reduction
/// chain, e.g. the weight-gradient's ordered fold over batch samples).
/// `pa`/`pb` are packing scratch, typically the calling worker's
/// [`pool::Scratch`] slots.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    rs_a: usize,
    cs_a: usize,
    b: &[f32],
    rs_b: usize,
    cs_b: usize,
    c: &mut [f32],
    ldc: usize,
    pa: &mut Vec<f32>,
    pb: &mut Vec<f32>,
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    debug_assert!(c.len() >= (m - 1) * ldc + n, "gemm: C too small");
    debug_assert!(
        a.len() > (m - 1) * rs_a + (k - 1) * cs_a,
        "gemm: A too small"
    );
    debug_assert!(
        b.len() > (k - 1) * rs_b + (n - 1) * cs_b,
        "gemm: B too small"
    );
    let kc_max = k.min(KC);
    let pbs = pool::grab_dirty(pb, n.min(NC).div_ceil(NR) * NR * kc_max);
    let pas = pool::grab_dirty(pa, m.min(MC).div_ceil(MR) * MR * kc_max);
    for j0 in (0..n).step_by(NC) {
        let nc = NC.min(n - j0);
        // K blocks strictly ascending: each C element's reduction chain
        // continues where the previous block stored it.
        for k0 in (0..k).step_by(KC) {
            let kc = KC.min(k - k0);
            pack_b(b, rs_b, cs_b, k0, kc, j0, nc, pbs);
            for i0 in (0..m).step_by(MC) {
                let mc = MC.min(m - i0);
                pack_a(a, rs_a, cs_a, i0, mc, k0, kc, pas);
                for jp in 0..nc.div_ceil(NR) {
                    let nr = NR.min(nc - jp * NR);
                    let bp = &pbs[jp * kc * NR..(jp + 1) * kc * NR];
                    for ip in 0..mc.div_ceil(MR) {
                        let mr = MR.min(mc - ip * MR);
                        let ap = &pas[ip * kc * MR..(ip + 1) * kc * MR];
                        let coff = (i0 + ip * MR) * ldc + j0 + jp * NR;
                        kern(kc, ap, bp, &mut c[coff..], ldc, mr, nr);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Naive ordered triple loop — the bit-level ground truth.
    fn gemm_ref(
        m: usize,
        n: usize,
        k: usize,
        a: &[f32],
        rs_a: usize,
        cs_a: usize,
        b: &[f32],
        rs_b: usize,
        cs_b: usize,
        c: &mut [f32],
        ldc: usize,
    ) {
        for i in 0..m {
            for j in 0..n {
                let mut acc = c[i * ldc + j];
                for kk in 0..k {
                    acc += a[i * rs_a + kk * cs_a] * b[kk * rs_b + j * cs_b];
                }
                c[i * ldc + j] = acc;
            }
        }
    }

    fn randv(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| {
                // sprinkle exact and negative zeros between gaussians
                match i % 17 {
                    3 => 0.0,
                    11 => -0.0,
                    _ => rng.gauss() as f32,
                }
            })
            .collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn gemm_matches_ordered_reference_bitwise() {
        let mut rng = Rng::new(42);
        // sizes straddling the MR/NR/KC boundaries, incl. degenerate ones
        let cases = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 16, 256),
            (5, 17, 300),
            (13, 40, 9),
            (2, 500 + 30, 61),
            (MR * 2, NR * 2, KC + 3),
        ];
        for &(m, n, k) in &cases {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let mut c = vec![0f32; m * n];
            let mut c_ref = c.clone();
            let (mut pa, mut pb) = (Vec::new(), Vec::new());
            gemm(m, n, k, &a, k, 1, &b, n, 1, &mut c, n, &mut pa, &mut pb);
            gemm_ref(m, n, k, &a, k, 1, &b, n, 1, &mut c_ref, n);
            assert_eq!(bits(&c), bits(&c_ref), "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn gemm_handles_transposed_operand_views() {
        let mut rng = Rng::new(7);
        let (m, n, k) = (6, 19, 33);
        // A stored transposed (k x m), B stored transposed (n x k)
        let at = randv(&mut rng, k * m);
        let bt = randv(&mut rng, n * k);
        let mut c = vec![0f32; m * n];
        let mut c_ref = c.clone();
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        gemm(m, n, k, &at, 1, m, &bt, 1, k, &mut c, n, &mut pa, &mut pb);
        gemm_ref(m, n, k, &at, 1, m, &bt, 1, k, &mut c_ref, n);
        assert_eq!(bits(&c), bits(&c_ref));
    }

    #[test]
    fn gemm_accumulates_into_existing_c() {
        let mut rng = Rng::new(9);
        let (m, n, k) = (5, 9, 12);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut c = randv(&mut rng, m * n);
        let mut c_ref = c.clone();
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        // two chained calls extend one reduction per element
        gemm(m, n, k, &a, k, 1, &b, n, 1, &mut c, n, &mut pa, &mut pb);
        gemm(m, n, k, &a, k, 1, &b, n, 1, &mut c, n, &mut pa, &mut pb);
        gemm_ref(m, n, k, &a, k, 1, &b, n, 1, &mut c_ref, n);
        gemm_ref(m, n, k, &a, k, 1, &b, n, 1, &mut c_ref, n);
        assert_eq!(bits(&c), bits(&c_ref));
    }

    #[test]
    fn empty_dims_are_noops() {
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        let a = vec![1.0f32; 4];
        let b = vec![1.0f32; 4];
        let mut c = vec![7.0f32; 4];
        gemm(0, 2, 2, &a, 2, 1, &b, 2, 1, &mut c, 2, &mut pa, &mut pb);
        gemm(2, 0, 2, &a, 2, 1, &b, 2, 1, &mut c, 2, &mut pa, &mut pb);
        gemm(2, 2, 0, &a, 2, 1, &b, 2, 1, &mut c, 2, &mut pa, &mut pb);
        assert_eq!(c, vec![7.0; 4]);
    }
}
