//! Reconstruction-plan engine: the Algorithm-1 inner loop, compiled.
//!
//! `recon.rs::reconstruct_unit` runs `T` (default 800) iterations per
//! unit, and every quantity except the sampled mini-batch, the rounding
//! variables `v`, the learned activation steps and the (β, λ) schedule is
//! frozen for the whole loop: the unit input cache, the skip cache, the
//! FP targets, the FIM weights, the FP weights/biases and all quantizer
//! bounds. The per-dispatch path re-pays for that freezing every
//! iteration — fresh gather tensors, a `w.clone()` per layer for soft
//! quantization, fresh tapes and gradient buffers, and a full `im2col`
//! of the frozen first-layer input. A [`ReconPlan`] pays once:
//!
//! * **Cached im2col slabs.** The first layer(s) of a unit read the
//!   frozen input cache, so the plan pre-builds their im2col slabs over
//!   the whole K-sample cache — both the forward `(kw × n)` layout and
//!   the transposed `(n × kw)` weight-gradient layout — and each
//!   iteration's GEMMs read the sampled rows straight out of the slab.
//!   Per-sample im2col is a pure per-sample gather, so a slab row is
//!   bitwise identical to a freshly built one. 1×1 stride-1 layers need
//!   no slab at all: the cache row already is its own column matrix.
//! * **Persistent scratch.** Soft-quantized weights, activations,
//!   gradient buffers, the gathered `xb/skb/zb/fb` batches and the
//!   regularizer term buffer are plan-owned and reused every step; the
//!   big slabs come from the [`pool`] shared arena and return to it on
//!   drop, so plan after plan builds warm. A warm `step()` performs no
//!   heap allocation (`tests/plan.rs` pins this on the arena counters).
//! * **Fused dispatch.** One `step(rows, vs, asteps, beta, lam)` call
//!   replaces the ~10·nl-argument `unit_recon` rebinding; the per-layer
//!   soft-quantize and the h(v)-sharing gv/regularizer pass fan out over
//!   out-channels on the pool (ownership-partitioned — each channel's
//!   chain is independent, so thread-count parity is free).
//!
//! **Determinism contract.** Every step is bit-identical to the retained
//! per-dispatch path at any `BRECQ_THREADS`: the slab feeds reproduce
//! `conv2d`/`conv2d_bwd`'s exact GEMM calls on identical operands, every
//! elementwise pass keeps the scalar loop's arithmetic order, and the
//! only cross-element reduction (the f64 rounding-regularizer sum) folds
//! on the calling thread in the dispatch path's layer-then-linear
//! element order. `tests/plan.rs` asserts plan-vs-dispatch equality of
//! losses, gradients and committed weights bitwise at 1/2/8 threads.
//!
//! Scope: plans compile every exported unit shape — single-node units
//! (`layer`/`block` granularity) *and* multi-node `seq(...)` programs
//! (`stage`/`net`/`pack` granularity). A multi-node plan gives each
//! node its own slab/scratch schedule (slabs and direct cache feeds
//! only where the feed is frozen, i.e. node 0), chains the nodes in
//! topo order through persistent inter-node output buffers, and runs
//! the backward pass through per-node gradient buffers in exactly the
//! dispatch path's `run_unit_bwd` node order. `build` still returns
//! `None` for node shapes whose shared-gradient masking cannot be done
//! in place (see the decline rules in `build_native_plan`), and aq-on
//! plans skip the slab feed (the trained activation step re-quantizes
//! the frozen input every iteration) while keeping the persistent
//! scratch and fused dispatch.
//!
//! Plans are also why per-unit checkpoint/resume (`recon.rs`'s
//! `UnitCheckpointer`) needs no state from this module: a plan lives
//! for exactly one unit's iteration loop and is dropped at commit, so
//! a unit boundary — the checkpoint/resume boundary — holds no plan
//! state at all. Resuming rebuilds later units' plans from their
//! restored inputs, bit-identically.

// Kernel-feeding loops index several buffers with shared offset
// arithmetic (same rationale as runtime::native).
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{ensure, Result};

use crate::model::LayerInfo;
use crate::tensor::Tensor;
use crate::util::pool;

use super::gemm;
use super::native::{
    adaround, add_bias, conv2d_bwd_into, conv2d_into, fc_bwd_into,
    fc_fwd_into, gap_fwd, gv_reg_elem, gw_accum, im2col, lsq, lsq_grads,
    relu_inplace, AqParams, BwdGeom, Node, UnitProg,
};

/// Total f32 elements the per-plan im2col slabs may occupy (both layouts
/// summed, ~128 MB). Layers past the budget fall back to per-iteration
/// im2col into warm pool scratch — still zero-alloc, just re-lowered.
const PLAN_SLAB_BUDGET: usize = 1 << 25;

static PLAN_BUILDS: AtomicUsize = AtomicUsize::new(0);
static PLAN_STEPS: AtomicUsize = AtomicUsize::new(0);
static PLAN_FALLBACK_STEPS: AtomicUsize = AtomicUsize::new(0);

/// (plans built, plan steps run, dispatch-fallback iterations) since
/// process start — the bench JSONs report these.
pub fn counters() -> (usize, usize, usize) {
    (
        PLAN_BUILDS.load(Ordering::Relaxed),
        PLAN_STEPS.load(Ordering::Relaxed),
        PLAN_FALLBACK_STEPS.load(Ordering::Relaxed),
    )
}

/// Record one reconstruction iteration that ran on the per-dispatch
/// fallback path instead of a plan.
pub fn note_fallback_step() {
    PLAN_FALLBACK_STEPS.fetch_add(1, Ordering::Relaxed);
}

/// Point-in-time copy of the plan counters. The statics are cumulative
/// process-global atomics, so any absolute read is polluted by earlier
/// work in the same process — take a snapshot before a phase and
/// subtract it after ([`Counters::since`]) to attribute counts to that
/// phase alone. Benches and `tests/plan.rs` read deltas, never totals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Counters {
    pub builds: usize,
    pub steps: usize,
    pub fallback_steps: usize,
}

impl Counters {
    /// Per-field delta `self - earlier` (saturating: a counter can only
    /// grow, but don't turn a misordered pair into a giant number).
    pub fn since(&self, earlier: &Counters) -> Counters {
        Counters {
            builds: self.builds.saturating_sub(earlier.builds),
            steps: self.steps.saturating_sub(earlier.steps),
            fallback_steps: self
                .fallback_steps
                .saturating_sub(earlier.fallback_steps),
        }
    }
}

/// Snapshot the cumulative plan counters.
pub fn snapshot() -> Counters {
    let (builds, steps, fallback_steps) = counters();
    Counters { builds, steps, fallback_steps }
}

/// Everything frozen across a unit's reconstruction loop. Borrowed, not
/// copied: the plan lives inside one `reconstruct_unit` call.
pub struct PlanInputs<'a> {
    /// Quantized-stream unit input cache, (K, ...).
    pub x: &'a Tensor,
    /// Skip-path cache for `uses_skip` units.
    pub skip: Option<&'a Tensor>,
    /// FP reconstruction targets, (K, out...).
    pub z_fp: &'a Tensor,
    /// Eq. 10 weights; `None` means unit weight (plain MSE) — bitwise
    /// identical to an all-ones tensor.
    pub fim: Option<&'a Tensor>,
    /// FP weights / biases, unit binding order.
    pub ws: Vec<&'a Tensor>,
    pub bs: Vec<&'a Tensor>,
    /// Per-channel AdaRound step tensors.
    pub wsteps: Vec<&'a Tensor>,
    /// Weight-grid clip bounds (n, p) per layer.
    pub wbounds: Vec<(f32, f32)>,
    /// Activation-grid bounds (lo, hi) per site.
    pub abounds: Vec<(f32, f32)>,
    /// Activation quantization on?
    pub aq: bool,
    /// Mini-batch size (fixed across all steps).
    pub batch: usize,
}

/// Scalar outputs of one fused iteration — exactly the first three
/// outputs of the `unit_recon` executable (as f32, like its scalar1
/// tensors, so reported losses are bit-identical to the dispatch path).
#[derive(Debug, Clone, Copy)]
pub struct StepOut {
    pub loss: f32,
    pub rec: f32,
    pub round: f32,
}

/// A compiled, stateful reconstruction loop for one unit.
pub trait ReconPlan {
    /// One Algorithm-1 iteration over the sampled cache rows. `vs` and
    /// `asteps` are the current trainables (unit binding order); the
    /// gradients land in [`ReconPlan::gv`] / [`ReconPlan::gsteps`].
    fn step(
        &mut self,
        rows: &[usize],
        vs: &[Tensor],
        asteps: &[Tensor],
        beta: f32,
        lam: f32,
    ) -> Result<StepOut>;

    /// Per-layer AdaRound gradients from the last step.
    fn gv(&self) -> &[Tensor];

    /// Per-site LSQ step gradients from the last step (scalar tensors;
    /// zero when activation quantization is off — the executable's
    /// `gastep` semantics).
    fn gsteps(&self) -> &[Tensor];
}

// ------------------------------------------------------------------
// Native plan
// ------------------------------------------------------------------

/// Where a planned layer reads its input.
#[derive(Clone, Copy, PartialEq)]
enum Input {
    /// The unit input cache (frozen).
    X,
    /// The unit skip cache (frozen).
    Skip,
    /// The precomputed global-average-pool of the input cache (frozen).
    Gap,
    /// Another planned layer's output buffer (unit binding index).
    Layer(usize),
    /// A previous node's residual-combined output buffer (`nouts[m]`).
    /// Only wired when node `m` actually owns one; nodes whose output is
    /// a plain layer wire `Layer(out_layer)` instead.
    Node(usize),
}

/// Where a layer's incoming output-gradient lives during backward.
#[derive(Clone, Copy)]
enum GradSrc {
    /// The unit-output loss gradient buffer.
    GZq,
    /// A consumer layer's input-gradient buffer.
    LayerGx(usize),
    /// A later node's residual-combined input gradient (`gins[m]`).
    Node(usize),
}

/// The unit-binding index of the layer a node's main output flows from
/// (pre any residual add — the residual preserves its shape).
fn out_layer(n: Node) -> usize {
    match n {
        Node::Layer(i) => i,
        Node::Basic { c2, .. } | Node::BasicL2 { c2, .. } => c2,
        Node::Ir { p, .. } | Node::IrL3 { p } => p,
        Node::GapFc { fc } => fc,
    }
}

/// Whether a node's output lives in its own buffer (`nouts[n]`) — true
/// exactly when `node_fwd` materializes a residual add (+ relu) tensor.
fn has_out_buf(n: Node) -> bool {
    matches!(
        n,
        Node::Basic { .. }
            | Node::BasicL2 { .. }
            | Node::IrL3 { .. }
            | Node::Ir { res: true, .. }
    )
}

/// Whole-cache im2col slabs for one frozen-input conv layer.
struct Slab {
    /// Forward layout: per sample `kw_all x n` row-major.
    fwd: Vec<f32>,
    /// Transposed layout: per sample `n x kw_all` (the gw fold operand).
    bwd_t: Vec<f32>,
    /// Elements per sample in each layout (`kw_all * n`).
    per: usize,
}

/// One planned layer: geometry + persistent buffers.
struct PLayer {
    info: LayerInfo,
    input: Input,
    /// Conv geometry at the step batch size (None for fc).
    conv: Option<BwdGeom>,
    /// Frozen 1x1 stride-1 conv reading cache rows directly (aq off).
    direct: bool,
    /// Frozen conv fed from pre-built whole-cache slabs (aq off).
    slab: Option<Slab>,
    wn: f32,
    wp: f32,
    alo: f32,
    ahi: f32,
    /// Soft-quantized weights (rebuilt in place every step).
    what: Tensor,
    /// Output activations (bsz), post bias/relu.
    z: Tensor,
    /// LSQ-quantized input (aq only).
    xq: Option<Tensor>,
    /// Gradient wrt the (quantized) layer input; None when the input is
    /// frozen and no LSQ chain needs it.
    gx: Option<Tensor>,
    /// Weight gradient (re-zeroed by the kernels every step).
    gw: Tensor,
}

pub struct NativeReconPlan<'a> {
    nodes: Vec<Node>,
    layers: Vec<PLayer>,
    // frozen caches + constants (borrowed)
    x: &'a Tensor,
    skip: Option<&'a Tensor>,
    z_fp: &'a Tensor,
    fim: Option<&'a Tensor>,
    ws: Vec<&'a Tensor>,
    bs: Vec<&'a Tensor>,
    wsteps: Vec<&'a Tensor>,
    aq: bool,
    bsz: usize,
    // gathered batches (persistent)
    xb: Option<Tensor>,
    skb: Option<Tensor>,
    zb: Tensor,
    fb: Option<Tensor>,
    /// gap over the whole K cache (GapFc units), gathered into `gapb`.
    gap_cache: Option<Tensor>,
    gapb: Option<Tensor>,
    /// Per-node output after a residual add (+ relu), when the node has
    /// one; later nodes read it as their input (`Input::Node`).
    nouts: Vec<Option<Tensor>>,
    /// Per-node residual-combined input gradient (non-entry Basic /
    /// `Ir{res}` nodes); the earlier node consumes it (`GradSrc::Node`).
    gins: Vec<Option<Tensor>>,
    g_zq: Tensor,
    // per-layer outputs of the fused gv/regularizer pass
    gvs: Vec<Tensor>,
    rbufs: Vec<Vec<f64>>,
    gstep_t: Vec<Tensor>,
}

/// Disjoint (mutable, shared) pair from one layer slice.
fn pair_mut(ls: &mut [PLayer], i: usize, j: usize) -> (&mut PLayer, &PLayer) {
    assert_ne!(i, j, "pair_mut: aliasing layer indices");
    if i < j {
        let (a, b) = ls.split_at_mut(j);
        (&mut a[i], &b[0])
    } else {
        let (a, b) = ls.split_at_mut(i);
        (&mut b[0], &a[j])
    }
}

/// In-place relu backward mask: `g = if out > 0 { g } else { 0 }` — the
/// dispatch path's `relu_mask` without the allocation.
fn relu_mask_inplace(g: &mut Tensor, out: &Tensor) {
    for (gv, ov) in g.data.iter_mut().zip(&out.data) {
        *gv = if *ov > 0.0 { *gv } else { 0.0 };
    }
}

/// Elementwise residual add into a persistent buffer: the dispatch
/// path's `add(a, b)` with `out[i] = a[i] + b[i]`.
fn add_into(a: &Tensor, b: &[f32], out: &mut Tensor) {
    debug_assert_eq!(a.data.len(), out.data.len());
    debug_assert_eq!(b.len(), out.data.len());
    for i in 0..out.data.len() {
        out.data[i] = a.data[i] + b[i];
    }
}

/// LSQ fake-quant of the gathered batch into the persistent xq buffer
/// (the dispatch path's `x.map(|v| lsq(..))`).
fn lsq_fill(x: &Tensor, p: AqParams, xq: &mut Tensor) {
    debug_assert_eq!(x.data.len(), xq.data.len());
    for (o, &v) in xq.data.iter_mut().zip(&x.data) {
        *o = lsq(v, p.step, p.lo, p.hi);
    }
}

/// LSQ backward chain, in the dispatch path's linear element order:
/// transforms `gx` (grad wrt quantized input) into grad wrt raw input in
/// place and returns the accumulated scalar step gradient.
fn lsq_chain(x: &Tensor, p: AqParams, gx: &mut Tensor) -> f32 {
    let mut gstep = 0f32;
    for i in 0..gx.data.len() {
        let (gi, ds) = lsq_grads(x.data[i], p.step, p.lo, p.hi, gx.data[i]);
        gx.data[i] = gi;
        gstep += ds;
    }
    gstep
}

/// Forward column source for a frozen conv layer.
#[derive(Clone, Copy)]
enum ColsSrc<'s> {
    /// Pre-built forward-layout slab, indexed by sampled cache row.
    Slab { slab: &'s [f32], per: usize },
    /// 1x1 stride-1: the cache row already is its own column matrix.
    Cache(&'s Tensor),
}

/// Per-sample im2col+GEMM forward fed straight from the frozen source —
/// exactly `conv2d`'s partitioning and GEMM calls, minus the im2col
/// build. Bit-identical to `conv2d` on the gathered batch.
fn conv_fwd_frozen(
    g: BwdGeom,
    what: &Tensor,
    src: ColsSrc<'_>,
    rows: &[usize],
    z: &mut [f32],
) {
    let (n, kw_g) = (g.n(), g.kw_g());
    z.fill(0.0);
    let work = z.len().saturating_mul(kw_g);
    pool::par_chunks_mut(z, g.cout * n, work, |bi, orow| {
        pool::with_scratch(|s| {
            let cols: &[f32] = match src {
                ColsSrc::Slab { slab, per } => {
                    &slab[rows[bi] * per..][..per]
                }
                ColsSrc::Cache(t) => t.row0(rows[bi]),
            };
            for gi in 0..g.groups {
                gemm::gemm(
                    g.cpg_out,
                    n,
                    kw_g,
                    &what.data[gi * g.cpg_out * kw_g..],
                    kw_g,
                    1,
                    &cols[gi * kw_g * n..],
                    n,
                    1,
                    &mut orow[gi * g.cpg_out * n..],
                    n,
                    &mut s.pack_a,
                    &mut s.pack_b,
                );
            }
        });
    });
}

/// Weight-gradient source for a frozen conv layer's backward fold.
#[derive(Clone, Copy)]
enum GwSrc<'s> {
    /// Pre-built transposed-layout slab rows.
    SlabT { slab: &'s [f32], per: usize },
    /// 1x1 stride-1: cache rows viewed with (1, hw) strides.
    Cache(&'s Tensor),
}

/// Frozen-input weight gradient: out-channel row blocks fold the sampled
/// batch strictly ascending — `conv2d_bwd`'s phase-B partition and
/// `gw_accum` calls on identical operands, with the input-gradient phase
/// (which a frozen unit input never needs) skipped entirely.
fn conv_gw_frozen(
    g: BwdGeom,
    gout: &Tensor,
    src: GwSrc<'_>,
    rows: &[usize],
    gw: &mut [f32],
) {
    let (kw_g, kw_all, hw_in) = (g.kw_g(), g.kw_all(), g.hw_in());
    gw.fill(0.0);
    let work = gout.data.len().saturating_mul(kw_g);
    pool::par_chunks_mut(gw, gemm::MR * kw_g, work, |ci, gwr| {
        pool::with_scratch(|s| {
            let o0 = ci * gemm::MR;
            let mrows = gwr.len() / kw_g;
            let mut r = 0;
            while r < mrows {
                let oc = o0 + r;
                let gi = oc / g.cpg_out;
                let m = ((gi + 1) * g.cpg_out - oc).min(mrows - r);
                for (bi, &row) in rows.iter().enumerate() {
                    let gs = gout.row0(bi);
                    match src {
                        GwSrc::SlabT { slab, per } => gw_accum(
                            gs,
                            &slab[row * per + gi * kw_g..],
                            kw_all,
                            1,
                            g,
                            oc,
                            m,
                            &mut gwr[r * kw_g..],
                            &mut s.pack_a,
                            &mut s.pack_b,
                        ),
                        GwSrc::Cache(t) => gw_accum(
                            gs,
                            &t.row0(row)[gi * g.cpg_in * hw_in..],
                            1,
                            hw_in,
                            g,
                            oc,
                            m,
                            &mut gwr[r * kw_g..],
                            &mut s.pack_a,
                            &mut s.pack_b,
                        ),
                    }
                }
                r += m;
            }
        });
    });
}

/// Build both im2col slab layouts over the whole K-sample cache (samples
/// partitioned across the pool; per-sample im2col is independent, so the
/// slab rows equal freshly built per-batch columns bitwise).
fn build_slab(g: BwdGeom, cache: &Tensor) -> Slab {
    let k = cache.shape[0];
    let per = g.kw_all() * g.n();
    let mut fwd = pool::take_shared(k * per);
    let mut bwd_t = pool::take_shared(k * per);
    let work = (k * per).saturating_mul(4);
    pool::par_chunks2_mut(&mut fwd, per, &mut bwd_t, per, work, |r, f, t| {
        let xs = cache.row0(r);
        im2col(
            xs, g.cin, g.h, g.wd, g.k, g.stride, g.ho, g.wo, g.pad_h,
            g.pad_w,
            g.n(),
            1,
            f,
        );
        im2col(
            xs,
            g.cin,
            g.h,
            g.wd,
            g.k,
            g.stride,
            g.ho,
            g.wo,
            g.pad_h,
            g.pad_w,
            1,
            g.kw_all(),
            t,
        );
    });
    Slab { fwd, bwd_t, per }
}

/// Soft-quantize one layer's weights into its persistent buffer, fanned
/// out per out-channel (each channel owns its contiguous slice and its
/// own step — elementwise, so thread-count parity is free).
fn soft_quant(pl: &mut PLayer, w: &Tensor, steps: &Tensor, v: &Tensor) {
    let inner = w.inner();
    let (wn, wp) = (pl.wn, pl.wp);
    debug_assert_eq!(v.data.len(), w.data.len());
    let work = w.numel().saturating_mul(32);
    pool::par_chunks_mut(&mut pl.what.data, inner, work, |ch, chunk| {
        let s = steps.data[ch];
        let base = ch * inner;
        for (j, o) in chunk.iter_mut().enumerate() {
            *o = adaround(w.data[base + j], s, v.data[base + j], wn, wp);
        }
    });
}

/// One layer forward into its persistent output buffer. `input` is the
/// gathered/produced batch tensor (None when the layer is slab- or
/// cache-fed); `cache` is the frozen K-cache for slab/direct feeds.
fn fwd_layer(
    info: &LayerInfo,
    geom: Option<BwdGeom>,
    slab: Option<&Slab>,
    direct: bool,
    what: &Tensor,
    bias: &Tensor,
    xq: Option<&mut Tensor>,
    z: &mut Tensor,
    input: Option<&Tensor>,
    cache: Option<&Tensor>,
    rows: &[usize],
    aqp: Option<AqParams>,
) {
    let mut conv_in = input;
    let xq_ref;
    if let (Some(p), Some(xq)) = (aqp, xq) {
        lsq_fill(input.expect("aq layers read a gathered batch"), p, xq);
        xq_ref = &*xq;
        conv_in = Some(xq_ref);
    }
    if info.kind == "fc" {
        fc_fwd_into(conv_in.expect("fc input"), what, &mut z.data);
    } else if let Some(s) = slab {
        conv_fwd_frozen(
            geom.expect("conv geom"),
            what,
            ColsSrc::Slab { slab: &s.fwd, per: s.per },
            rows,
            &mut z.data,
        );
    } else if direct {
        conv_fwd_frozen(
            geom.expect("conv geom"),
            what,
            ColsSrc::Cache(cache.expect("direct feed cache")),
            rows,
            &mut z.data,
        );
    } else {
        conv2d_into(
            conv_in.expect("conv input"),
            what,
            info.stride,
            info.groups,
            &mut z.data,
        );
    }
    add_bias(z, bias);
    if info.relu {
        relu_inplace(z);
    }
}

/// One layer backward: weight gradient (always), input gradient (when
/// the plan needs it), LSQ chain (aq). `g` is the grad at the layer
/// output, already masked by this layer's relu. Returns the step grad.
fn bwd_layer(
    info: &LayerInfo,
    geom: Option<BwdGeom>,
    slab: Option<&Slab>,
    direct: bool,
    what: &Tensor,
    raw_in: Option<&Tensor>,
    conv_in: Option<&Tensor>,
    g: &Tensor,
    mut gx: Option<&mut Tensor>,
    gw: &mut Tensor,
    rows: &[usize],
    cache: Option<&Tensor>,
    aqp: Option<AqParams>,
) -> f32 {
    if info.kind == "fc" {
        fc_bwd_into(
            conv_in.expect("fc input"),
            what,
            g,
            gx.as_mut().map(|t| t.data.as_mut_slice()),
            &mut gw.data,
        );
    } else if let Some(s) = slab {
        debug_assert!(gx.is_none(), "slab-fed layers skip gx");
        conv_gw_frozen(
            geom.expect("conv geom"),
            g,
            GwSrc::SlabT { slab: &s.bwd_t, per: s.per },
            rows,
            &mut gw.data,
        );
    } else if direct {
        debug_assert!(gx.is_none(), "cache-fed layers skip gx");
        conv_gw_frozen(
            geom.expect("conv geom"),
            g,
            GwSrc::Cache(cache.expect("direct feed cache")),
            rows,
            &mut gw.data,
        );
    } else {
        conv2d_bwd_into(
            conv_in.expect("conv input"),
            what,
            info.stride,
            info.groups,
            g,
            gx.as_mut().map(|t| t.data.as_mut_slice()),
            &mut gw.data,
        );
    }
    match (aqp, gx) {
        (Some(p), Some(gxt)) => {
            lsq_chain(raw_in.expect("aq raw input"), p, gxt)
        }
        _ => 0.0,
    }
}

/// Batch-shape helper: `shape` with the leading dim replaced by `b`.
fn batched(shape: &[usize], b: usize) -> Vec<usize> {
    let mut s = shape.to_vec();
    s[0] = b;
    s
}

/// Compile a native reconstruction plan for a `UnitProg` of any node
/// count; `None` means the unit keeps the per-dispatch path (node
/// shapes whose shared-gradient masking the plan cannot do in place —
/// none of the exported topologies hit these):
///
/// * Basic/BasicL2 with a relu on `c2`: the node-masked grad is shared
///   between conv2 and the downsample, so the in-place mask needs the
///   first consumer linear.
/// * A non-entry `Ir{res}` node with a relu on `p`: the residual input
///   gradient adds the *unmasked* incoming grad, but `bwd_one` masks
///   the shared buffer in place by `p`'s relu.
/// * BasicL2/IrL3/GapFc inside a multi-node program: those shapes read
///   the unit-level skip/gap caches, which only exist at the entry.
pub(crate) fn build_native_plan<'a>(
    u: &UnitProg,
    inp: PlanInputs<'a>,
) -> Result<Option<Box<dyn ReconPlan + 'a>>> {
    let nn = u.nodes.len();
    ensure!(nn >= 1, "plan: empty unit program");
    for (n, &node) in u.nodes.iter().enumerate() {
        match node {
            Node::Basic { c2, .. } | Node::BasicL2 { c2, .. }
                if u.layers[c2].relu =>
            {
                return Ok(None);
            }
            Node::BasicL2 { .. } | Node::IrL3 { .. } | Node::GapFc { .. }
                if nn > 1 =>
            {
                return Ok(None);
            }
            Node::Ir { p, res: true, .. }
                if n > 0 && u.layers[p].relu =>
            {
                return Ok(None);
            }
            _ => {}
        }
    }

    let nl = u.layers.len();
    ensure!(
        inp.ws.len() == nl
            && inp.bs.len() == nl
            && inp.wsteps.len() == nl
            && inp.wbounds.len() == nl
            && inp.abounds.len() == nl,
        "plan inputs: arity mismatch ({} layers)",
        nl
    );
    let k = inp.x.shape[0];
    let bsz = inp.batch;
    ensure!(bsz >= 1 && bsz <= k, "plan batch {bsz} vs cache {k}");

    // layer input wiring: node 0's entry layers read the frozen unit
    // caches; node n>0's entry layers read the previous node's output
    // (its residual buffer when it owns one, its out layer's z else)
    let mut inputs_of = vec![Input::X; nl];
    let mut entry = Input::X;
    for (n, &node) in u.nodes.iter().enumerate() {
        match node {
            Node::Layer(i) => inputs_of[i] = entry,
            Node::Basic { c1, c2, down } => {
                inputs_of[c1] = entry;
                inputs_of[c2] = Input::Layer(c1);
                if let Some(d) = down {
                    inputs_of[d] = entry;
                }
            }
            Node::BasicL2 { c2, down } => {
                inputs_of[c2] = entry;
                if let Some(d) = down {
                    inputs_of[d] = Input::Skip;
                }
            }
            Node::Ir { e, d, p, .. } => {
                inputs_of[e] = entry;
                inputs_of[d] = Input::Layer(e);
                inputs_of[p] = Input::Layer(d);
            }
            Node::IrL3 { p } => inputs_of[p] = entry,
            Node::GapFc { fc } => inputs_of[fc] = Input::Gap,
        }
        entry = if has_out_buf(node) {
            Input::Node(n)
        } else {
            Input::Layer(out_layer(node))
        };
    }

    // per-layer geometry + shape validation against the frozen caches
    let mut geoms: Vec<Option<BwdGeom>> = Vec::with_capacity(nl);
    for (i, info) in u.layers.iter().enumerate() {
        ensure!(
            inp.ws[i].shape == info.wshape,
            "plan: layer {i} weight shape {:?} != manifest {:?}",
            inp.ws[i].shape,
            info.wshape
        );
        if info.kind == "fc" {
            geoms.push(None);
            continue;
        }
        let g = BwdGeom::of(
            bsz,
            info.cin,
            info.h_in,
            info.w_in,
            inp.ws[i],
            info.stride,
            info.groups,
        );
        let src_shape: Option<&[usize]> = match inputs_of[i] {
            Input::X => Some(&inp.x.shape),
            Input::Skip => inp.skip.map(|s| s.shape.as_slice()),
            _ => None,
        };
        if let Some(sh) = src_shape {
            ensure!(
                sh[1..] == [g.cin, g.h, g.wd],
                "plan: layer {i} input {:?} != cache {:?}",
                [g.cin, g.h, g.wd],
                &sh[1..]
            );
        }
        // producer check: a layer fed by another layer's z, or by a
        // previous node's residual buffer (whose shape is that node's
        // out layer's shape), must agree with the producer's geometry
        let producer = match inputs_of[i] {
            Input::Layer(p) => Some(p),
            Input::Node(m) => Some(out_layer(u.nodes[m])),
            _ => None,
        };
        if let Some(p) = producer {
            if let Some(Some(pg)) = geoms.get(p) {
                ensure!(
                    (pg.cout, pg.ho, pg.wo) == (g.cin, g.h, g.wd),
                    "plan: layer {i} input geometry disagrees with its \
                     producer {p}"
                );
            }
        }
        geoms.push(Some(g));
    }

    // unit output shape at the step batch
    let out_of = |i: usize| -> Vec<usize> {
        match (&u.layers[i].kind[..], geoms[i]) {
            ("fc", _) => vec![bsz, u.layers[i].cout],
            (_, Some(g)) => vec![bsz, g.cout, g.ho, g.wo],
            _ => unreachable!("conv layer without geometry"),
        }
    };
    let out_shape = out_of(out_layer(u.nodes[nn - 1]));
    ensure!(
        inp.z_fp.shape[0] == k && inp.z_fp.shape[1..] == out_shape[1..],
        "plan: z_fp shape {:?} != unit out {:?} at K={k}",
        inp.z_fp.shape,
        out_shape
    );
    if let Some(f) = inp.fim {
        ensure!(
            f.shape == inp.z_fp.shape,
            "plan: fim shape {:?} != z_fp {:?}",
            f.shape,
            inp.z_fp.shape
        );
    }

    // frozen-feed selection: slabs / direct cache reads (aq off only —
    // a trained activation step re-quantizes the input every iteration)
    let mut slab_left = PLAN_SLAB_BUDGET;
    let mut layers: Vec<PLayer> = Vec::with_capacity(nl);
    let mut gvs = Vec::with_capacity(nl);
    let mut rbufs = Vec::with_capacity(nl);
    let mut gstep_t = Vec::with_capacity(nl);
    for (i, info) in u.layers.iter().enumerate() {
        let frozen =
            matches!(inputs_of[i], Input::X | Input::Skip | Input::Gap);
        let is_conv = info.kind != "fc";
        let (direct, slab) = if frozen && is_conv && !inp.aq {
            let g = geoms[i].expect("conv geom");
            if g.direct() {
                (true, None)
            } else {
                let need = 2 * k * g.kw_all() * g.n();
                if need <= slab_left {
                    slab_left -= need;
                    let cache = match inputs_of[i] {
                        Input::X => inp.x,
                        Input::Skip => inp.skip.expect("skip cache"),
                        _ => unreachable!("frozen conv feeds X/Skip"),
                    };
                    (false, Some(build_slab(g, cache)))
                } else {
                    (false, None)
                }
            }
        } else {
            (false, None)
        };
        let in_shape = if is_conv {
            let g = geoms[i].expect("conv geom");
            vec![bsz, g.cin, g.h, g.wd]
        } else {
            vec![bsz, info.cin]
        };
        let want_gx = !frozen || inp.aq;
        layers.push(PLayer {
            info: info.clone(),
            input: inputs_of[i],
            conv: geoms[i],
            direct,
            slab,
            wn: inp.wbounds[i].0,
            wp: inp.wbounds[i].1,
            alo: inp.abounds[i].0,
            ahi: inp.abounds[i].1,
            what: Tensor::zeros(info.wshape.clone()),
            z: Tensor::zeros(out_of(i)),
            xq: inp.aq.then(|| Tensor::zeros(in_shape.clone())),
            gx: want_gx.then(|| Tensor::zeros(in_shape.clone())),
            gw: Tensor::zeros(info.wshape.clone()),
        });
        gvs.push(Tensor::zeros(info.wshape.clone()));
        rbufs.push(vec![0f64; inp.ws[i].numel()]);
        gstep_t.push(Tensor::scalar1(0.0));
    }

    // which gathered batches the steps actually read — a residual add
    // on the *entry* node reads the gathered unit input/skip batch;
    // later nodes' residuals read the previous node's output buffers
    let node0 = u.nodes[0];
    let tensor_fed = |l: &PLayer| l.slab.is_none() && !l.direct;
    let need_xb = layers
        .iter()
        .any(|l| l.input == Input::X && tensor_fed(l))
        || matches!(node0, Node::Basic { down: None, .. })
        || matches!(node0, Node::Ir { res: true, .. });
    let need_skb = layers
        .iter()
        .any(|l| l.input == Input::Skip && tensor_fed(l))
        || matches!(node0, Node::BasicL2 { down: None, .. })
        || matches!(node0, Node::IrL3 { .. });
    if need_skb {
        ensure!(inp.skip.is_some(), "plan: unit needs a skip cache");
    }
    let gap_cache = match node0 {
        Node::GapFc { .. } => Some(gap_fwd(inp.x)),
        _ => None,
    };
    let gapb = gap_cache
        .as_ref()
        .map(|g| Tensor::zeros(batched(&g.shape, bsz)));
    let nouts: Vec<Option<Tensor>> = u
        .nodes
        .iter()
        .map(|&nd| {
            has_out_buf(nd)
                .then(|| Tensor::zeros(out_of(out_layer(nd))))
        })
        .collect();
    // non-entry Basic / residual-Ir nodes combine their entry layer's
    // gx with a shortcut grad into a node input gradient the previous
    // node consumes; its shape is the previous node's output shape
    let gins: Vec<Option<Tensor>> = u
        .nodes
        .iter()
        .enumerate()
        .map(|(n, &nd)| {
            (n > 0
                && matches!(
                    nd,
                    Node::Basic { .. } | Node::Ir { res: true, .. }
                ))
            .then(|| {
                Tensor::zeros(out_of(out_layer(u.nodes[n - 1])))
            })
        })
        .collect();

    PLAN_BUILDS.fetch_add(1, Ordering::Relaxed);
    Ok(Some(Box::new(NativeReconPlan {
        nodes: u.nodes.clone(),
        layers,
        x: inp.x,
        skip: inp.skip,
        z_fp: inp.z_fp,
        fim: inp.fim,
        ws: inp.ws,
        bs: inp.bs,
        wsteps: inp.wsteps,
        aq: inp.aq,
        bsz,
        xb: need_xb.then(|| Tensor::zeros(batched(&inp.x.shape, bsz))),
        skb: need_skb.then(|| {
            Tensor::zeros(batched(&inp.skip.expect("skip").shape, bsz))
        }),
        zb: Tensor::zeros(out_shape.clone()),
        fb: inp.fim.map(|_| Tensor::zeros(out_shape.clone())),
        gap_cache,
        gapb,
        nouts,
        gins,
        g_zq: Tensor::zeros(out_shape),
        gvs,
        rbufs,
        gstep_t,
    })))
}

impl NativeReconPlan<'_> {
    /// Forward one layer into its persistent output buffer.
    fn fwd_one(&mut self, i: usize, rows: &[usize], asteps: &[Tensor]) {
        let aqp = self.aq.then(|| AqParams {
            step: asteps[i].data[0],
            lo: self.layers[i].alo,
            hi: self.layers[i].ahi,
        });
        let input = self.layers[i].input;
        match input {
            Input::Layer(src) => {
                let (pl, sp) = pair_mut(&mut self.layers, i, src);
                fwd_layer(
                    &pl.info,
                    pl.conv,
                    pl.slab.as_ref(),
                    pl.direct,
                    &pl.what,
                    self.bs[i],
                    pl.xq.as_mut(),
                    &mut pl.z,
                    Some(&sp.z),
                    None,
                    rows,
                    aqp,
                );
            }
            src => {
                let cache: Option<&Tensor> = match src {
                    Input::X => Some(self.x),
                    Input::Skip => self.skip,
                    _ => None,
                };
                let batch: Option<&Tensor> = match src {
                    Input::X => self.xb.as_ref(),
                    Input::Skip => self.skb.as_ref(),
                    Input::Node(m) => self.nouts[m].as_ref(),
                    _ => self.gapb.as_ref(),
                };
                let pl = &mut self.layers[i];
                fwd_layer(
                    &pl.info,
                    pl.conv,
                    pl.slab.as_ref(),
                    pl.direct,
                    &pl.what,
                    self.bs[i],
                    pl.xq.as_mut(),
                    &mut pl.z,
                    batch,
                    cache,
                    rows,
                    aqp,
                );
            }
        }
    }

    /// Backward one layer: mask by its own relu, compute gw (and gx /
    /// the LSQ chain when needed), store the step grad.
    fn bwd_one(
        &mut self,
        i: usize,
        src: GradSrc,
        rows: &[usize],
        asteps: &[Tensor],
    ) {
        let aqp = self.aq.then(|| AqParams {
            step: asteps[i].data[0],
            lo: self.layers[i].alo,
            hi: self.layers[i].ahi,
        });
        // take the incoming grad out of its owner so the borrows of this
        // layer, the producer layer and the grad never alias
        let mut g_owned: Option<Tensor> = match src {
            GradSrc::LayerGx(j) => {
                Some(self.layers[j].gx.take().expect("consumer gx"))
            }
            GradSrc::Node(m) => {
                Some(self.gins[m].take().expect("node gin"))
            }
            GradSrc::GZq => None,
        };
        if self.layers[i].info.relu {
            match g_owned.as_mut() {
                Some(g) => relu_mask_inplace(g, &self.layers[i].z),
                None => {
                    relu_mask_inplace(&mut self.g_zq, &self.layers[i].z)
                }
            }
        }
        let gstep = {
            let input = self.layers[i].input;
            let (pl, sp): (&mut PLayer, Option<&PLayer>) = match input {
                Input::Layer(k) => {
                    let (a, b) = pair_mut(&mut self.layers, i, k);
                    (a, Some(b))
                }
                _ => (&mut self.layers[i], None),
            };
            let raw_in: Option<&Tensor> = match input {
                Input::Layer(_) => sp.map(|s| &s.z),
                Input::X => self.xb.as_ref(),
                Input::Skip => self.skb.as_ref(),
                Input::Gap => self.gapb.as_ref(),
                Input::Node(m) => self.nouts[m].as_ref(),
            };
            let cache: Option<&Tensor> = match input {
                Input::X => Some(self.x),
                Input::Skip => self.skip,
                _ => None,
            };
            let conv_in: Option<&Tensor> = if aqp.is_some() {
                Some(pl.xq.as_ref().expect("aq xq"))
            } else {
                raw_in
            };
            let g: &Tensor = g_owned.as_ref().unwrap_or(&self.g_zq);
            bwd_layer(
                &pl.info,
                pl.conv,
                pl.slab.as_ref(),
                pl.direct,
                &pl.what,
                raw_in,
                conv_in,
                g,
                pl.gx.as_mut(),
                &mut pl.gw,
                rows,
                cache,
                aqp,
            )
        };
        self.gstep_t[i].data[0] = if self.aq { gstep } else { 0.0 };
        match src {
            GradSrc::LayerGx(j) => self.layers[j].gx = g_owned,
            GradSrc::Node(m) => self.gins[m] = g_owned,
            GradSrc::GZq => {}
        }
    }

    /// The main-path input batch of node `n` (a residual shortcut reads
    /// it): the gathered unit input for the entry node, the previous
    /// node's output buffer otherwise.
    fn node_in_data(&self, n: usize) -> &[f32] {
        if n == 0 {
            &self.xb.as_ref().expect("residual xb").data
        } else {
            match self.nouts[n - 1].as_ref() {
                Some(t) => &t.data,
                None => {
                    &self.layers[out_layer(self.nodes[n - 1])].z.data
                }
            }
        }
    }

    /// Read access to a gradient buffer by source.
    fn grad_data(&self, src: GradSrc) -> &[f32] {
        match src {
            GradSrc::GZq => &self.g_zq.data,
            GradSrc::LayerGx(j) => {
                &self.layers[j].gx.as_ref().expect("layer gx").data
            }
            GradSrc::Node(m) => {
                &self.gins[m].as_ref().expect("node gin").data
            }
        }
    }

    /// In-place relu mask of the incoming grad buffer by node `n`'s
    /// post-relu output — the dispatch path's `relu_mask(gout, out)`
    /// without the fresh tensor (sound because the buffer is dead once
    /// this node's backward completes; shapes that would still need the
    /// unmasked values are declined at build time).
    fn mask_node_src(&mut self, src: GradSrc, n: usize) {
        let nout = self.nouts[n].take().expect("node out");
        match src {
            GradSrc::GZq => relu_mask_inplace(&mut self.g_zq, &nout),
            GradSrc::LayerGx(j) => relu_mask_inplace(
                self.layers[j].gx.as_mut().expect("layer gx"),
                &nout,
            ),
            GradSrc::Node(m) => relu_mask_inplace(
                self.gins[m].as_mut().expect("node gin"),
                &nout,
            ),
        }
        self.nouts[n] = Some(nout);
    }

    /// Forward one node: its layers in topo order, then the residual
    /// add (+ relu) into `nouts[n]` when the node has one — exactly the
    /// dispatch path's `node_fwd`.
    fn fwd_node(&mut self, n: usize, rows: &[usize], asteps: &[Tensor]) {
        match self.nodes[n] {
            Node::Layer(i) => self.fwd_one(i, rows, asteps),
            Node::Basic { c1, c2, down } => {
                self.fwd_one(c1, rows, asteps);
                self.fwd_one(c2, rows, asteps);
                if let Some(d) = down {
                    self.fwd_one(d, rows, asteps);
                }
                let mut nout =
                    self.nouts[n].take().expect("basic nout");
                {
                    let sc: &[f32] = match down {
                        Some(d) => &self.layers[d].z.data,
                        None => self.node_in_data(n),
                    };
                    add_into(&self.layers[c2].z, sc, &mut nout);
                }
                relu_inplace(&mut nout);
                self.nouts[n] = Some(nout);
            }
            Node::BasicL2 { c2, down } => {
                self.fwd_one(c2, rows, asteps);
                if let Some(d) = down {
                    self.fwd_one(d, rows, asteps);
                }
                let mut nout =
                    self.nouts[n].take().expect("basic_l2 nout");
                {
                    let sc: &[f32] = match down {
                        Some(d) => &self.layers[d].z.data,
                        None => {
                            &self.skb.as_ref().expect("skip batch").data
                        }
                    };
                    add_into(&self.layers[c2].z, sc, &mut nout);
                }
                relu_inplace(&mut nout);
                self.nouts[n] = Some(nout);
            }
            Node::Ir { e, d, p, res } => {
                self.fwd_one(e, rows, asteps);
                self.fwd_one(d, rows, asteps);
                self.fwd_one(p, rows, asteps);
                if res {
                    let mut nout =
                        self.nouts[n].take().expect("ir nout");
                    add_into(
                        &self.layers[p].z,
                        self.node_in_data(n),
                        &mut nout,
                    );
                    self.nouts[n] = Some(nout);
                }
            }
            Node::IrL3 { p } => {
                self.fwd_one(p, rows, asteps);
                let mut nout = self.nouts[n].take().expect("ir_l3 nout");
                add_into(
                    &self.layers[p].z,
                    &self.skb.as_ref().expect("skip batch").data,
                    &mut nout,
                );
                self.nouts[n] = Some(nout);
            }
            Node::GapFc { fc } => self.fwd_one(fc, rows, asteps),
        }
    }

    /// Backward one node in the dispatch path's `node_bwd` order; `src`
    /// is the grad at this node's output. Returns where the grad at the
    /// node's *input* now lives (consumed by the previous node; dead
    /// for the entry node, whose input is frozen).
    fn bwd_node(
        &mut self,
        n: usize,
        src: GradSrc,
        rows: &[usize],
        asteps: &[Tensor],
    ) -> GradSrc {
        match self.nodes[n] {
            Node::Layer(i) => {
                self.bwd_one(i, src, rows, asteps);
                GradSrc::LayerGx(i)
            }
            Node::Basic { c1, c2, down } => {
                self.mask_node_src(src, n);
                self.bwd_one(c2, src, rows, asteps);
                if let Some(d) = down {
                    self.bwd_one(d, src, rows, asteps);
                }
                self.bwd_one(c1, GradSrc::LayerGx(c2), rows, asteps);
                if n > 0 {
                    // node input grad = c1's gx + the shortcut grad
                    // (downsample gx, or the node-masked grad itself)
                    let mut gin =
                        self.gins[n].take().expect("basic gin");
                    {
                        let sc: &[f32] = match down {
                            Some(d) => {
                                &self.layers[d]
                                    .gx
                                    .as_ref()
                                    .expect("down gx")
                                    .data
                            }
                            None => self.grad_data(src),
                        };
                        add_into(
                            self.layers[c1]
                                .gx
                                .as_ref()
                                .expect("c1 gx"),
                            sc,
                            &mut gin,
                        );
                    }
                    self.gins[n] = Some(gin);
                    GradSrc::Node(n)
                } else {
                    GradSrc::LayerGx(c1)
                }
            }
            Node::BasicL2 { c2, down } => {
                self.mask_node_src(src, n);
                self.bwd_one(c2, src, rows, asteps);
                if let Some(d) = down {
                    self.bwd_one(d, src, rows, asteps);
                }
                GradSrc::LayerGx(c2)
            }
            Node::Ir { e, d, p, res } => {
                self.bwd_one(p, src, rows, asteps);
                self.bwd_one(d, GradSrc::LayerGx(p), rows, asteps);
                self.bwd_one(e, GradSrc::LayerGx(d), rows, asteps);
                if res && n > 0 {
                    // residual: node input grad = e's gx + the
                    // *unmasked* incoming grad (p is linear — enforced
                    // by the build-time decline)
                    let mut gin = self.gins[n].take().expect("ir gin");
                    add_into(
                        self.layers[e].gx.as_ref().expect("e gx"),
                        self.grad_data(src),
                        &mut gin,
                    );
                    self.gins[n] = Some(gin);
                    GradSrc::Node(n)
                } else {
                    GradSrc::LayerGx(e)
                }
            }
            Node::IrL3 { p } => {
                self.bwd_one(p, src, rows, asteps);
                GradSrc::LayerGx(p)
            }
            Node::GapFc { fc } => {
                self.bwd_one(fc, src, rows, asteps);
                GradSrc::LayerGx(fc)
            }
        }
    }
}

impl ReconPlan for NativeReconPlan<'_> {
    fn step(
        &mut self,
        rows: &[usize],
        vs: &[Tensor],
        asteps: &[Tensor],
        beta: f32,
        lam: f32,
    ) -> Result<StepOut> {
        let nl = self.layers.len();
        ensure!(rows.len() == self.bsz, "plan step: rows != batch size");
        ensure!(
            vs.len() == nl && asteps.len() == nl,
            "plan step: trainable arity mismatch"
        );
        PLAN_STEPS.fetch_add(1, Ordering::Relaxed);

        // 1. gather the sampled mini-batch into the persistent buffers
        if let Some(xb) = self.xb.as_mut() {
            self.x.gather_rows_into(rows, &mut xb.data);
        }
        if let Some(skb) = self.skb.as_mut() {
            self.skip
                .expect("skip cache")
                .gather_rows_into(rows, &mut skb.data);
        }
        self.z_fp.gather_rows_into(rows, &mut self.zb.data);
        if let Some(fb) = self.fb.as_mut() {
            self.fim
                .expect("fim cache")
                .gather_rows_into(rows, &mut fb.data);
        }
        if let Some(gapb) = self.gapb.as_mut() {
            self.gap_cache
                .as_ref()
                .expect("gap cache")
                .gather_rows_into(rows, &mut gapb.data);
        }

        // 2. soft-quantize every layer's weights (Eq. 16), per channel
        for i in 0..nl {
            debug_assert_eq!(vs[i].data.len(), self.ws[i].data.len());
            soft_quant(
                &mut self.layers[i],
                self.ws[i],
                self.wsteps[i],
                &vs[i],
            );
        }

        // 3. forward through the node program in topo order; each node
        //    reads its predecessor's persistent output buffer
        for n in 0..self.nodes.len() {
            self.fwd_node(n, rows, asteps);
        }

        // 4. FIM-weighted loss (Eq. 10) + gradient at the unit output —
        //    runtime::native::fim_loss{,_grad_zq}'s arithmetic verbatim;
        //    a missing FIM multiplies by an implicit exact 1.0.
        let rec;
        {
            let last = self.nodes.len() - 1;
            let zq: &Tensor = match self.nouts[last].as_ref() {
                Some(t) => t,
                None => {
                    &self.layers[out_layer(self.nodes[last])].z
                }
            };
            let zb = &self.zb;
            debug_assert_eq!(zb.data.len(), zq.data.len());
            let bf = self.bsz as f64;
            let mut acc = 0f64;
            match self.fb.as_ref() {
                Some(fb) => {
                    for i in 0..zb.data.len() {
                        let d = (zb.data[i] - zq.data[i]) as f64;
                        acc += fb.data[i] as f64 * d * d;
                    }
                }
                None => {
                    for i in 0..zb.data.len() {
                        let d = (zb.data[i] - zq.data[i]) as f64;
                        acc += d * d;
                    }
                }
            }
            rec = acc / bf;
            let bs_f = self.bsz as f32;
            let g = &mut self.g_zq;
            match self.fb.as_ref() {
                Some(fb) => {
                    for i in 0..g.data.len() {
                        g.data[i] = -2.0 / bs_f
                            * fb.data[i]
                            * (zb.data[i] - zq.data[i]);
                    }
                }
                None => {
                    for i in 0..g.data.len() {
                        g.data[i] =
                            -2.0 / bs_f * (zb.data[i] - zq.data[i]);
                    }
                }
            }
        }

        // 5. backward through the node program in reverse topo order
        //    (the dispatch path's `run_unit_bwd`): each node consumes
        //    the grad at its output and leaves the grad at its input
        let mut src = GradSrc::GZq;
        for n in (0..self.nodes.len()).rev() {
            src = self.bwd_node(n, src, rows, asteps);
        }

        // 6. fused gv + rounding-regularizer pass: one sigmoid per
        //    element, fanned out per out-channel; the f64 regularizer
        //    terms land in rbuf and fold on this thread in the dispatch
        //    path's layer-then-linear order — bit-identical to the
        //    two-loop form.
        let mut rl = 0f64;
        for i in 0..nl {
            let w = self.ws[i];
            let steps = self.wsteps[i];
            let v = &vs[i];
            let inner = w.inner();
            let (wn, wp) = (self.layers[i].wn, self.layers[i].wp);
            let gw = &self.layers[i].gw;
            let gv = &mut self.gvs[i].data;
            let rbuf = &mut self.rbufs[i];
            let work = w.numel().saturating_mul(64);
            pool::par_chunks2_mut(
                gv,
                inner,
                rbuf,
                inner,
                work,
                |ch, gvc, rc| {
                    let s = steps.data[ch];
                    let base = ch * inner;
                    for j in 0..gvc.len() {
                        let e = base + j;
                        let (term, g) = gv_reg_elem(
                            w.data[e],
                            s,
                            v.data[e],
                            wn,
                            wp,
                            gw.data[e],
                            beta,
                            lam,
                        );
                        rc[j] = term;
                        gvc[j] = g;
                    }
                },
            );
            for &r in self.rbufs[i].iter() {
                rl += r;
            }
        }

        Ok(StepOut {
            loss: (rec + lam as f64 * rl) as f32,
            rec: rec as f32,
            round: rl as f32,
        })
    }

    fn gv(&self) -> &[Tensor] {
        &self.gvs
    }

    fn gsteps(&self) -> &[Tensor] {
        &self.gstep_t
    }
}

impl Drop for NativeReconPlan<'_> {
    fn drop(&mut self) {
        // return the big slabs to the shared arena: the next unit's plan
        // builds warm, keeping whole-calibration runs allocation-flat
        for pl in &mut self.layers {
            if let Some(s) = pl.slab.take() {
                pool::give_shared(s.fwd);
                pool::give_shared(s.bwd_t);
            }
        }
    }
}
