//! Coordinator: environment bootstrap, experiment configuration and report
//! writing — the glue the CLI and the experiment drivers run on.
//!
//! An [`Env`] is the raw substrate (manifest + backend + datasets). The
//! typed front door for running quantization work on it is
//! [`crate::pipeline::Session`], which wraps one `Env` with a shared
//! artifact cache — CLI subcommands and examples construct `Env` only to
//! hand it to a session.
//!
//! Backend selection: `Env::bootstrap` loads the artifact directory when it
//! exists and picks the backend from the manifest's `backend` hint —
//! PJRT-targeted manifests need the `pjrt` cargo feature, `native`
//! manifests run on the pure-Rust interpreter. With no artifacts at all it
//! falls back to [`Env::bootstrap_synthetic`]: a deterministic, generated
//! two-model environment on the native backend, so every CLI command and
//! the whole test suite work on a fresh checkout with no Python/XLA.

pub mod experiments;
pub mod report;

use std::path::PathBuf;

#[cfg(not(feature = "pjrt"))]
use anyhow::Context;
use anyhow::Result;

use crate::calib::{CalibSet, DataSet};
use crate::model::{synthetic, Manifest, ModelInfo};
use crate::runtime::native::NativeBackend;
use crate::runtime::Backend;
use crate::util::rng::Rng;

/// Everything an experiment needs: manifest, backend, datasets.
pub struct Env {
    pub mf: Manifest,
    pub rt: Box<dyn Backend>,
    pub dir: PathBuf,
}

impl Env {
    /// `dir` defaults to ./artifacts (or $BRECQ_ARTIFACTS). An explicitly
    /// requested directory must exist — a typo'd path is a hard error, not
    /// a silent switch to the toy environment. Only the implicit default
    /// falls back to the hermetic synthetic environment.
    pub fn bootstrap(dir: Option<String>) -> Result<Env> {
        let explicit = dir
            .clone()
            .or_else(|| std::env::var("BRECQ_ARTIFACTS").ok());
        let dir = explicit.clone().unwrap_or_else(|| "artifacts".into());
        let path = PathBuf::from(&dir);
        if path.join("manifest.json").exists() {
            Env::from_dir(path)
        } else if explicit.is_some() {
            anyhow::bail!(
                "no manifest.json under requested artifacts dir '{dir}' \
                 (omit --artifacts/$BRECQ_ARTIFACTS to use the generated \
                 synthetic environment; rust/tests/fixtures/manifest.json \
                 is a minimal example of the manifest format)"
            );
        } else {
            eprintln!(
                "[env] no artifacts at {dir}/ — using the generated \
                 synthetic environment (native backend)"
            );
            Env::bootstrap_synthetic()
        }
    }

    /// Hermetic bootstrap: deterministic synthetic models + dataset run by
    /// the native backend. No artifacts, Python or XLA required.
    pub fn bootstrap_synthetic() -> Result<Env> {
        Env::from_dir(synthetic::ensure_default()?)
    }

    /// Load an artifact directory, choosing the backend from the
    /// manifest's `backend` hint and the compiled features.
    pub fn from_dir(dir: PathBuf) -> Result<Env> {
        let mf = Manifest::load(&dir)?;
        let hint = mf
            .json
            .get("backend")
            .and_then(|v| v.as_str())
            .unwrap_or("pjrt");
        let rt: Box<dyn Backend> = if hint == "native" {
            Box::new(NativeBackend::from_manifest(&mf)?)
        } else {
            #[cfg(feature = "pjrt")]
            let b: Box<dyn Backend> = Box::new(
                crate::runtime::pjrt::PjrtRuntime::new(&dir, &mf.json)?,
            );
            #[cfg(not(feature = "pjrt"))]
            let b: Box<dyn Backend> =
                Box::new(NativeBackend::from_manifest(&mf).context(
                    "this manifest targets the PJRT backend and the native \
                     interpreter cannot cover it — rebuild with \
                     --features pjrt",
                )?);
            b
        };
        Ok(Env { mf, rt, dir })
    }

    pub fn model(&self, name: &str) -> &ModelInfo {
        self.mf.model(name)
    }

    /// Non-panicking membership check (the pipeline's typed
    /// `UnknownModel` error is built on this).
    pub fn has_model(&self, name: &str) -> bool {
        self.mf.models.contains_key(name)
    }

    pub fn train_set(&self) -> Result<DataSet> {
        DataSet::load(&self.mf.dataset, "train")
    }

    pub fn test_set(&self) -> Result<DataSet> {
        DataSet::load(&self.mf.dataset, "test")
    }

    /// Train split of the dataset `model` actually consumes (the
    /// detection family carries its own scene rasters; classification
    /// models resolve to the manifest's root dataset).
    pub fn train_set_for(&self, model: &ModelInfo) -> Result<DataSet> {
        DataSet::load(self.mf.dataset_for(model), "train")
    }

    /// Test split of the dataset `model` actually consumes.
    pub fn test_set_for(&self, model: &ModelInfo) -> Result<DataSet> {
        DataSet::load(self.mf.dataset_for(model), "test")
    }

    /// The paper's calibration protocol: `k` images from the train set
    /// (clamped to the train-set size — the synthetic environment is
    /// smaller than the CLI's 1024-image default).
    pub fn calib(&self, train: &DataSet, k: usize, seed: u64)
        -> CalibSet {
        let mut rng = Rng::new(seed ^ 0xca11b);
        train.calib_subset(k.min(train.len()), &mut rng)
    }
}
