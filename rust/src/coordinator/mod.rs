//! Coordinator: environment bootstrap, experiment configuration and report
//! writing — the glue the CLI and the experiment drivers run on.

pub mod experiments;
pub mod report;

use std::path::PathBuf;

use anyhow::Result;

use crate::calib::{CalibSet, DataSet};
use crate::model::{Manifest, ModelInfo};
use crate::runtime::Runtime;
use crate::util::rng::Rng;

/// Everything an experiment needs: manifest, runtime, datasets.
pub struct Env {
    pub mf: Manifest,
    pub rt: Runtime,
    pub dir: PathBuf,
}

impl Env {
    /// `dir` defaults to ./artifacts (or $BRECQ_ARTIFACTS).
    pub fn bootstrap(dir: Option<String>) -> Result<Env> {
        let dir = PathBuf::from(
            dir.or_else(|| std::env::var("BRECQ_ARTIFACTS").ok())
                .unwrap_or_else(|| "artifacts".into()),
        );
        let mf = Manifest::load(&dir)?;
        let rt = Runtime::new(&dir, &mf.json)?;
        Ok(Env { mf, rt, dir })
    }

    pub fn model(&self, name: &str) -> &ModelInfo {
        self.mf.model(name)
    }

    pub fn train_set(&self) -> Result<DataSet> {
        DataSet::load(&self.mf.dataset, "train")
    }

    pub fn test_set(&self) -> Result<DataSet> {
        DataSet::load(&self.mf.dataset, "test")
    }

    /// The paper's calibration protocol: `k` images from the train set.
    pub fn calib(&self, train: &DataSet, k: usize, seed: u64)
        -> CalibSet {
        let mut rng = Rng::new(seed ^ 0xca11b);
        train.calib_subset(k, &mut rng)
    }
}
