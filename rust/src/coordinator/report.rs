//! Markdown table / JSON report writers for the experiment drivers.
//! Each experiment prints its table to stdout (mirroring the paper's rows)
//! and appends a machine-readable record to artifacts/reports/.

use std::fs;
use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }

    /// Persist under artifacts/reports/<id>.md (+ .json).
    pub fn save(&self, dir: &Path, id: &str) -> Result<()> {
        let rep = dir.join("reports");
        fs::create_dir_all(&rep)?;
        fs::write(rep.join(format!("{id}.md")), self.to_markdown())?;
        let json = crate::util::json::obj(vec![
            ("title", crate::util::json::s(&self.title)),
            (
                "headers",
                Json::Arr(
                    self.headers
                        .iter()
                        .map(|h| crate::util::json::s(h))
                        .collect(),
                ),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::Arr(
                                r.iter()
                                    .map(|c| crate::util::json::s(c))
                                    .collect(),
                            )
                        })
                        .collect(),
                ),
            ),
        ]);
        fs::write(rep.join(format!("{id}.json")), json.to_string())?;
        Ok(())
    }
}

pub fn pct(x: f64) -> String {
    format!("{:.2}", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_layout() {
        let mut t = Table::new("T", &["a", "bee"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a | bee |"));
        assert!(md.contains("| 1 | 2   |"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
