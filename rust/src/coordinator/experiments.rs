//! Experiment drivers: one per table/figure of the paper's evaluation.
//!
//! Each driver regenerates the corresponding rows (same methods, same
//! sweep axes) on the synth10 substrate and saves a markdown+JSON report
//! under artifacts/reports/. Absolute numbers differ from the paper (our
//! substrate is a small synthetic task); EXPERIMENTS.md tracks the *shape*:
//! who wins, where the cliffs are, how the curves order.

use anyhow::{Context, Result};

use crate::baselines;
use crate::calib::CalibSet;
use crate::coordinator::report::{pct, Table};
use crate::coordinator::Env;
use crate::distill::{self, DistillConfig};
use crate::eval::{accuracy, map_score, EvalParams};
use crate::hwsim::{size_mb, ArmCpu, HwMeasure, Systolic};
use crate::mp::{GaConfig, GeneticSearch};
use crate::qat::{self, QatConfig};
use crate::recon::{BitConfig, Calibrator, QuantizedModel, ReconConfig};
use crate::sensitivity::Profiler;
use crate::util::stats;

// The method registry lives in the typed pipeline API now; the drivers
// re-export it so table code and downstream callers keep one name.
pub use crate::pipeline::{Hardware, Method};

use crate::pipeline::{Granularity, JobSpec, Session};

/// Shared experiment options (CLI-tunable).
#[derive(Clone)]
pub struct ExpOpts {
    pub iters: usize,
    pub calib_n: usize,
    pub seed: u64,
    pub seeds: usize, // variance study: #seeds for BRECQ rows
    pub verbose: bool,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts { iters: 250, calib_n: 1024, seed: 0, seeds: 1,
                  verbose: false }
    }
}

fn base_cfg(o: &ExpOpts) -> ReconConfig {
    ReconConfig {
        iters: o.iters,
        seed: o.seed,
        verbose: o.verbose,
        ..ReconConfig::default()
    }
}

/// Quantize `model` with one method at the given bit config.
pub fn quantize_with(
    env: &Env,
    model_name: &str,
    method: Method,
    calib: &CalibSet,
    bits: &BitConfig,
    o: &ExpOpts,
) -> Result<QuantizedModel> {
    let model = env.model(model_name);
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let cfg = base_cfg(o);
    match method {
        Method::Fp => anyhow::bail!(
            "quantize_with: 'fp' is not a quantization method"
        ),
        Method::BiasCorr => {
            baselines::bias_correction(&env.rt, &env.mf, model, calib, bits)
        }
        Method::Omse => baselines::omse(&env.rt, &env.mf, model, calib, bits),
        Method::AdaRoundLayer => {
            cal.calibrate(calib, bits, &baselines::adaround_layer_cfg(&cfg))
        }
        Method::AdaQuantLike => {
            cal.calibrate(calib, bits, &baselines::adaquant_like_cfg(&cfg))
        }
        Method::Brecq => {
            cal.calibrate(calib, bits, &baselines::brecq_cfg(&cfg, "block"))
        }
    }
}

fn eval_quantized(
    env: &Env,
    model_name: &str,
    qm: &QuantizedModel,
) -> Result<f64> {
    let test = env.test_set()?;
    accuracy(&env.rt, env.model(model_name), &EvalParams::quantized(qm),
             &test)
}

// ------------------------------------------------------------------
// Table 1: reconstruction-granularity ablation (W2, A=FP)
// ------------------------------------------------------------------

/// Runs through a [`Session`] (not a bare [`Env`]) so repeated
/// regenerations share — and, with a store-backed session, *persist* —
/// every granularity's reconstruction: a warm-store `exp table1` replays
/// bit-identically with zero backend dispatches (`rust/tests/qaas.rs`).
/// The spec path is numerically identical to driving the Calibrator
/// directly: same calib subset, same `brecq_cfg`, same eval.
pub fn table1(s: &Session, o: &ExpOpts) -> Result<Table> {
    let env = s.env();
    let mut t = Table::new(
        "Table 1 — granularity ablation, 2-bit weights (top-1 %)",
        &["Model", "FP", "Layer", "Block", "Stage", "Net", "Pack"],
    );
    for mname in ["resnet_s", "mobilenetv2_s"] {
        if !env.mf.models.contains_key(mname) {
            println!("  table1 {mname}: not in manifest (export with \
`python -m compile.aot --models {mname}`)");
            continue;
        }
        let model = env.model(mname);
        let mut cells = vec![mname.to_string(), pct(model.fp_acc)];
        for gran in ["layer", "block", "stage", "net", "pack"] {
            // models export different granularity subsets (mobilenet has
            // no stage/net partition) — a missing one is a "-" cell, not
            // a failed table
            if !model.grans.contains_key(gran) {
                println!("  table1 {mname} {gran}: not exported, skipping");
                cells.push("-".into());
                continue;
            }
            let spec = JobSpec {
                model: mname.to_string(),
                method: Method::Brecq,
                gran: Granularity::parse(gran)?,
                wbits: 2,
                abits: None,
                first_last_8: true,
                iters: o.iters,
                calib_n: o.calib_n,
                seed: o.seed,
                verbose: o.verbose,
                ..JobSpec::default()
            };
            let out = s.run(&spec)?;
            let acc = out
                .accuracy
                .context("table1 jobs always evaluate")?;
            println!("  table1 {mname} {gran}: {:.2}%", acc * 100.0);
            cells.push(pct(acc));
        }
        t.row(cells);
    }
    Ok(t)
}

// ------------------------------------------------------------------
// Table 2: weight-only PTQ comparison (W4/W3/W2, A=FP)
// ------------------------------------------------------------------

pub fn table2(env: &Env, o: &ExpOpts, models: &[String]) -> Result<Table> {
    let mut t = Table::new(
        "Table 2 — weight-only PTQ (top-1 %), activations FP",
        &["Method", "Bits (W/A)", "resnet_s", "mobilenetv2_s", "regnet_s",
          "mnasnet_s"],
    );
    let train = env.train_set()?;
    let mut fp = vec!["Full Prec.".to_string(), "32/32".to_string()];
    for m in ALL_MODELS {
        fp.push(env.mf.models.get(m).map(|mi| pct(mi.fp_acc))
            .unwrap_or_else(|| "-".into()));
    }
    t.row(fp);

    for wbits in [4usize, 3, 2] {
        for method in [Method::BiasCorr, Method::Omse,
                       Method::AdaRoundLayer, Method::AdaQuantLike,
                       Method::Brecq] {
            let mut cells = vec![
                method.name().to_string(),
                format!("{wbits}/32"),
            ];
            for mname in ALL_MODELS {
                if !models.iter().any(|m| m == mname)
                    || !env.mf.models.contains_key(mname)
                {
                    cells.push("-".into());
                    continue;
                }
                let model = env.model(mname);
                let bits = BitConfig::uniform(model, wbits, None, true);
                // variance study on the BRECQ rows
                let runs = if method == Method::Brecq { o.seeds } else { 1 };
                let mut accs = Vec::new();
                for s in 0..runs {
                    let calib =
                        env.calib(&train, o.calib_n, o.seed + s as u64);
                    let mut os = o.clone();
                    os.seed = o.seed + s as u64;
                    let qm = quantize_with(env, mname, method, &calib,
                                           &bits, &os)?;
                    accs.push(eval_quantized(env, mname, &qm)? * 100.0);
                }
                let cell = if runs > 1 {
                    format!("{:.2}±{:.2}", stats::mean(&accs),
                            stats::std_dev(&accs))
                } else {
                    format!("{:.2}", accs[0])
                };
                println!("  table2 {} W{wbits} {mname}: {cell}",
                         method.name());
                cells.push(cell);
            }
            t.row(cells);
        }

        // Pack-PTQ row: the BRECQ engine at the FIM-grouped pack
        // partition (PAPERS.md) — same quantizer substrate, only the
        // unit grouping changes. Models without an exported pack
        // partition get "-" cells like any other missing granularity.
        let mut cells =
            vec!["BRECQ (pack)*".to_string(), format!("{wbits}/32")];
        for mname in ALL_MODELS {
            if !models.iter().any(|m| m == mname)
                || !env.mf.models.contains_key(mname)
                || !env.model(mname).grans.contains_key("pack")
            {
                cells.push("-".into());
                continue;
            }
            let model = env.model(mname);
            let bits = BitConfig::uniform(model, wbits, None, true);
            let calib = env.calib(&train, o.calib_n, o.seed);
            let cal = Calibrator::new(&env.rt, &env.mf, model);
            let qm = cal.calibrate(
                &calib, &bits,
                &baselines::brecq_cfg(&base_cfg(o), "pack"))?;
            let acc = eval_quantized(env, mname, &qm)?;
            let cell = format!("{:.2}", acc * 100.0);
            println!("  table2 BRECQ (pack) W{wbits} {mname}: {cell}");
            cells.push(cell);
        }
        t.row(cells);
    }
    Ok(t)
}

pub const ALL_MODELS: [&str; 4] =
    ["resnet_s", "mobilenetv2_s", "regnet_s", "mnasnet_s"];

// ------------------------------------------------------------------
// Table 3: fully quantized (W4A4, W2A4)
// ------------------------------------------------------------------

pub fn table3(env: &Env, o: &ExpOpts, models: &[String]) -> Result<Table> {
    let mut t = Table::new(
        "Table 3 — fully quantized PTQ (top-1 %), 4-bit activations",
        &["Method", "Bits (W/A)", "resnet_s", "mobilenetv2_s", "regnet_s",
          "mnasnet_s"],
    );
    let train = env.train_set()?;
    let mut fp = vec!["Full Prec.".to_string(), "32/32".to_string()];
    for m in ALL_MODELS {
        fp.push(env.mf.models.get(m).map(|mi| pct(mi.fp_acc))
            .unwrap_or_else(|| "-".into()));
    }
    t.row(fp);

    for wbits in [4usize, 2] {
        for method in [Method::Omse, Method::AdaQuantLike, Method::Brecq] {
            let mut cells = vec![
                method.name().to_string(),
                format!("{wbits}/4"),
            ];
            for mname in ALL_MODELS {
                if !models.iter().any(|m| m == mname)
                    || !env.mf.models.contains_key(mname)
                {
                    cells.push("-".into());
                    continue;
                }
                let model = env.model(mname);
                let bits = BitConfig::uniform(model, wbits, Some(4), true);
                let calib = env.calib(&train, o.calib_n, o.seed);
                let qm = quantize_with(env, mname, method, &calib, &bits, o)?;
                let acc = eval_quantized(env, mname, &qm)?;
                println!("  table3 {} W{wbits}A4 {mname}: {:.2}%",
                         method.name(), acc * 100.0);
                cells.push(pct(acc));
            }
            t.row(cells);
        }
    }
    Ok(t)
}

// ------------------------------------------------------------------
// Table 4: PTQ vs QAT cost comparison
// ------------------------------------------------------------------

pub fn table4(env: &Env, o: &ExpOpts, qat_steps: usize) -> Result<Table> {
    let mut t = Table::new(
        "Table 4 — BRECQ (PTQ) vs LSQ-QAT: accuracy and production cost",
        &["Model", "Method", "Bits", "Top-1 %", "Size (MB)",
          "#Train data", "Wall-clock (s)"],
    );
    let train = env.train_set()?;
    for mname in ["resnet_s", "mobilenetv2_s"] {
        if !env.mf.models.contains_key(mname) {
            continue;
        }
        let model = env.model(mname);
        let bits4 = BitConfig::uniform(model, 4, Some(4), true);
        let sz = size_mb(model, &bits4.wbits);

        // BRECQ with 1024 real calibration images
        let calib = env.calib(&train, o.calib_n, o.seed);
        let cal = Calibrator::new(&env.rt, &env.mf, model);
        let qm = cal.calibrate(&calib, &bits4,
                               &baselines::brecq_cfg(&base_cfg(o), "block"))?;
        let acc = eval_quantized(env, mname, &qm)?;
        t.row(vec![mname.into(), "BRECQ (ours)".into(), "4/4".into(),
                   pct(acc), format!("{sz:.2}"),
                   format!("{}", o.calib_n),
                   format!("{:.1}", qm.calib_seconds)]);
        println!("  table4 {mname} brecq: {:.2}% in {:.0}s",
                 acc * 100.0, qm.calib_seconds);

        // BRECQ with distilled (zero-shot) data — resnet only (the
        // distill executable is exported for it)
        if model.distill_exe.is_some() {
            let t0 = std::time::Instant::now();
            let dcal = distill::distill(&env.rt, &env.mf, model,
                                        &DistillConfig {
                                            total: o.calib_n,
                                            seed: o.seed,
                                            ..Default::default()
                                        })?;
            let qm = cal.calibrate(&dcal, &bits4,
                                   &baselines::brecq_cfg(&base_cfg(o),
                                                         "block"))?;
            let acc = eval_quantized(env, mname, &qm)?;
            t.row(vec![mname.into(), "BRECQ (distilled data)".into(),
                       "4/4".into(), pct(acc), format!("{sz:.2}"),
                       "0".into(),
                       format!("{:.1}", t0.elapsed().as_secs_f64())]);
            println!("  table4 {mname} brecq-distilled: {:.2}%", acc * 100.0);
        }

        // LSQ QAT on the full training set
        if model.qat_exe.is_some() {
            let r = qat::train(&env.rt, &env.mf, model, &train,
                               &QatConfig {
                                   steps: qat_steps,
                                   seed: o.seed,
                                   verbose: o.verbose,
                                   ..Default::default()
                               })?;
            let acc = eval_quantized(env, mname, &r.model)?;
            t.row(vec![mname.into(), "LSQ QAT".into(), "4/4".into(),
                       pct(acc), format!("{sz:.2}"),
                       format!("{}", train.len()),
                       format!("{:.1}", r.train_seconds)]);
            println!("  table4 {mname} qat({qat_steps} steps): {:.2}% in {:.0}s",
                     acc * 100.0, r.train_seconds);
        }
    }
    Ok(t)
}

// ------------------------------------------------------------------
// Fig 2 / Fig 4: mixed precision under size / latency budgets
// ------------------------------------------------------------------

pub fn mixed_precision(
    env: &Env,
    o: &ExpOpts,
    model_name: &str,
    hw_kind: Hardware,
) -> Result<Table> {
    let model = env.model(model_name);
    let train = env.train_set()?;
    let calib = env.calib(&train, o.calib_n, o.seed);
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights()?;

    // sensitivity LUT (with intra-block off-diagonal terms, 2-bit only)
    let prof = Profiler { rt: &env.rt, mf: &env.mf, model };
    let table = prof.measure(&calib, &ws, &bs, true)?;

    if hw_kind == Hardware::Arm {
        anyhow::ensure!(ArmCpu::supports(model),
            "ARM GEMM model supports normal conv only (paper B.4.3)");
    }
    let measurer = hw_kind.measurer();
    let hw: &dyn HwMeasure = measurer.as_ref();
    let abits = 8usize; // the paper keeps A8 in the MP study

    let mut t = Table::new(
        &format!("Mixed precision — {model_name} under {} budgets",
                 hw.name()),
        &["Config", "H(c) [{unit}]", "Avg W-bits", "Top-1 %",
          "GA predicted loss", "GA seconds"],
    );
    t.headers[1] = format!("H(c) [{}]", hw.unit());

    // unified precision anchor points
    let mut anchors = Vec::new();
    for wb in [8usize, 4, 2] {
        let bits = BitConfig::uniform(model, wb, Some(abits), true);
        let cost = hw.measure(model, &bits.wbits, abits);
        let qm = cal.calibrate(&calib, &bits,
                               &baselines::brecq_cfg(&base_cfg(o), "block"))?;
        let acc = eval_quantized(env, model_name, &qm)?;
        println!("  mp {model_name} unified W{wb}: H={cost:.3} acc={:.2}%",
                 acc * 100.0);
        t.row(vec![format!("unified W{wb}"), format!("{cost:.3}"),
                   format!("{wb}"), pct(acc), "-".into(), "-".into()]);
        anchors.push(cost);
    }

    // mixed precision at budgets interpolating the unified anchors
    let (hi, lo) = (anchors[1], anchors[2]); // W4 .. W2 corridor
    for frac in [0.85f64, 0.6, 0.35] {
        let budget = lo + (hi - lo) * frac;
        let ga = GeneticSearch { model, table: &table, hw, abits, budget };
        let res = ga.run(&GaConfig { seed: o.seed, ..Default::default() })?;
        let bits = BitConfig::mixed(res.wbits.clone(), abits, true);
        let qm = cal.calibrate(&calib, &bits,
                               &baselines::brecq_cfg(&base_cfg(o), "block"))?;
        let acc = eval_quantized(env, model_name, &qm)?;
        let avg: f64 = res.wbits.iter().sum::<usize>() as f64
            / res.wbits.len() as f64;
        println!(
            "  mp {model_name} budget {budget:.3}: H={:.3} avg {avg:.2} \
             bits acc={:.2}% ({} cfgs in {:.2}s)",
            res.hw_cost, acc * 100.0, res.evaluated, res.seconds);
        t.row(vec![format!("GA mixed (δ={budget:.3})"),
                   format!("{:.3}", res.hw_cost),
                   format!("{avg:.2}"), pct(acc),
                   format!("{:.4}", res.predicted_loss),
                   format!("{:.2}", res.seconds)]);
    }
    Ok(t)
}

// ------------------------------------------------------------------
// Table 5: detection backbone PTQ (mAP) on the synthetic det_s family
// ------------------------------------------------------------------

/// The paper's Table 5 evaluates PTQ'd detection backbones by COCO mAP;
/// this runner regenerates its shape on the synthetic `det_s` workload
/// (same quantizer substrate, same W4A8/W2A8 rows, mAP over the seeded
/// scene boxes at IoU {0.5, 0.75}). See EXPERIMENTS.md for the
/// synthetic-vs-COCO fidelity caveats.
pub fn table5(env: &Env, o: &ExpOpts) -> Result<Table> {
    let mut t = Table::new(
        "Table 5 — detection backbone PTQ (mAP @ IoU {0.5, 0.75})",
        &["Method", "Bits (W/A)", "det_s mAP"],
    );
    let mname = "det_s";
    anyhow::ensure!(
        env.mf.models.contains_key(mname),
        "table5 needs the '{mname}' detection model (absent from this \
         manifest)"
    );
    let model = env.model(mname);
    let det = model
        .det
        .as_ref()
        .context("det_s carries no detection geometry in the manifest")?;
    let train = env.train_set_for(model)?;
    let test = env.test_set_for(model)?;
    let calib = env.calib(&train, o.calib_n, o.seed);
    let cal = Calibrator::new(&env.rt, &env.mf, model);

    let (ws, bs) = cal.fp_weights()?;
    let fp = map_score(
        &env.rt,
        model,
        det,
        &EvalParams::fp(model, &ws, &bs),
        &test,
        false,
    )?;
    println!("  table5 det_s fp: mAP {fp:.4}");
    t.row(vec!["Full Prec.".into(), "32/32".into(), format!("{fp:.4}")]);

    for wbits in [4usize, 2] {
        for method in [Method::AdaRoundLayer, Method::Brecq] {
            let bits = BitConfig::uniform(model, wbits, Some(8), true);
            let qm = quantize_with(env, mname, method, &calib, &bits, o)?;
            let map = map_score(
                &env.rt,
                model,
                det,
                &EvalParams::quantized(&qm),
                &test,
                false,
            )?;
            println!(
                "  table5 {} W{wbits}A8: mAP {map:.4}",
                method.name()
            );
            t.row(vec![
                method.name().to_string(),
                format!("{wbits}/8"),
                format!("{map:.4}"),
            ]);
        }
    }
    Ok(t)
}

// ------------------------------------------------------------------
// Table 6 / B.1: first & last layer at 8-bit vs quantized
// ------------------------------------------------------------------

pub fn table6(env: &Env, o: &ExpOpts) -> Result<Table> {
    let mut t = Table::new(
        "Table 6 — impact of keeping first/last layer at 8-bit (A8)",
        &["Model", "First 8b", "Last 8b", "W-bits", "Top-1 %",
          "Size (MB)", "FPGA lat (ms)"],
    );
    let train = env.train_set()?;
    let systolic = Systolic::default();
    for mname in ["resnet_s", "mobilenetv2_s", "regnet_s"] {
        if !env.mf.models.contains_key(mname) {
            continue;
        }
        let model = env.model(mname);
        let calib = env.calib(&train, o.calib_n, o.seed);
        let cal = Calibrator::new(&env.rt, &env.mf, model);
        for wb in [4usize, 2] {
            for (f8, l8) in [(true, true), (false, true), (true, false),
                             (false, false)] {
                let mut bits = BitConfig::uniform(model, wb, Some(8), false);
                if f8 {
                    bits.wbits[model.first_layer()] = 8;
                }
                if l8 {
                    bits.wbits[model.last_layer()] = 8;
                }
                let qm = cal.calibrate(
                    &calib, &bits,
                    &baselines::brecq_cfg(&base_cfg(o), "block"))?;
                let acc = eval_quantized(env, mname, &qm)?;
                let sz = size_mb(model, &bits.wbits);
                let lat = systolic.model_ms(model, &bits.wbits, 8);
                println!("  table6 {mname} W{wb} f8={f8} l8={l8}: {:.2}%",
                         acc * 100.0);
                t.row(vec![mname.into(),
                           if f8 { "yes" } else { "no" }.into(),
                           if l8 { "yes" } else { "no" }.into(),
                           format!("{wb}"), pct(acc), format!("{sz:.3}"),
                           format!("{lat:.2}")]);
            }
        }
    }
    Ok(t)
}

// ------------------------------------------------------------------
// Fig 3 / B.2: calibration-set size and data source
// ------------------------------------------------------------------

pub fn fig3(env: &Env, o: &ExpOpts) -> Result<Table> {
    let mut t = Table::new(
        "Fig 3 — effect of #calibration images and data source (resnet_s)",
        &["Source", "#Images", "W-bits", "Top-1 %"],
    );
    let mname = "resnet_s";
    let model = env.model(mname);
    let train = env.train_set()?;
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    for wb in [4usize, 2] {
        for n in [32usize, 128, 256, 512, 1024] {
            let calib = env.calib(&train, n, o.seed);
            let bits = BitConfig::uniform(model, wb, None, true);
            let qm = cal.calibrate(&calib, &bits,
                                   &baselines::brecq_cfg(&base_cfg(o),
                                                         "block"))?;
            let acc = eval_quantized(env, mname, &qm)?;
            println!("  fig3 real n={n} W{wb}: {:.2}%", acc * 100.0);
            t.row(vec!["real".into(), format!("{n}"), format!("{wb}"),
                       pct(acc)]);
        }
        // distilled data source (needs the model's distill executable —
        // absent e.g. in the synthetic native environment)
        if model.distill_exe.is_none() {
            println!("  fig3 distilled W{wb}: skipped (no distill exe)");
            continue;
        }
        for n in [256usize, 1024] {
            let dcal = distill::distill(&env.rt, &env.mf, model,
                                        &DistillConfig {
                                            total: n,
                                            seed: o.seed,
                                            ..Default::default()
                                        })?;
            let bits = BitConfig::uniform(model, wb, None, true);
            let qm = cal.calibrate(&dcal, &bits,
                                   &baselines::brecq_cfg(&base_cfg(o),
                                                         "block"))?;
            let acc = eval_quantized(env, mname, &qm)?;
            println!("  fig3 distilled n={n} W{wb}: {:.2}%", acc * 100.0);
            t.row(vec!["distilled".into(), format!("{n}"), format!("{wb}"),
                       pct(acc)]);
        }
    }
    Ok(t)
}
