//! Dependency-free scoped worker pool for the native backend's hot paths.
//!
//! Vendored parallelism substrate (crates.io is unavailable offline):
//! `std::thread::scope` workers draining a chunked atomic work queue. The
//! pool size comes from `BRECQ_THREADS` (unset or `0` = auto-detect via
//! `available_parallelism`) and can be overridden at runtime with
//! [`set_threads`] — the CLI's `--threads` flag and the bench/test
//! harnesses use that.
//!
//! # Determinism contract
//!
//! Every helper here guarantees **bit-identical results at any thread
//! count, including 1**. Work is partitioned by *ownership*: each output
//! element is computed entirely by one job, with exactly the same inner
//! arithmetic order as the scalar loop, and job outputs land at fixed
//! indices. No reduction ever races or reassociates floating-point sums
//! across jobs — callers that need a cross-job reduction fold the per-job
//! partials on the calling thread in job-index order. `tests/parallel.rs`
//! enforces this bitwise against scalar references at 1/2/8 threads.
//!
//! # Scheduling
//!
//! Fan-out only happens when (a) the pool has more than one thread,
//! (b) the estimated work clears [`MIN_PAR_WORK`] (scoped thread spawns
//! cost tens of microseconds — tiny kernels stay inline), and (c) the
//! caller is not already inside a pool worker (nested regions run inline
//! on their worker, so a parallel `advance` over calibration batches does
//! not multiply threads with the parallel conv kernels it dispatches).

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Minimum estimated scalar-op count before a region fans out. Below this
/// the scoped-spawn overhead outweighs the parallel win.
pub const MIN_PAR_WORK: usize = 1 << 16;

/// 0 = not yet initialized (read `BRECQ_THREADS` / autodetect on first use).
static THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static IN_POOL: Cell<bool> = Cell::new(false);
}

fn auto_threads() -> usize {
    let env = std::env::var("BRECQ_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok());
    match env {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    }
}

/// Current pool size (threads used by parallel regions).
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let t = auto_threads().max(1);
    THREADS.store(t, Ordering::Relaxed);
    t
}

/// Override the pool size at runtime; `0` re-reads `BRECQ_THREADS` /
/// autodetect. Results are unaffected by construction (see the
/// determinism contract), so this is safe to flip mid-run.
pub fn set_threads(n: usize) {
    let t = if n == 0 { auto_threads().max(1) } else { n };
    THREADS.store(t, Ordering::Relaxed);
}

/// True when the calling thread is a pool worker (nested regions inline).
pub fn in_worker() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Would a region with `work` estimated scalar ops actually fan out?
pub fn active(work: usize) -> bool {
    threads() > 1 && work >= MIN_PAR_WORK && !in_worker()
}

/// Run `job(j)` for every `j in 0..njobs`. Jobs are claimed from an atomic
/// queue in chunks of `grain`; with fan-out disabled (small `work`, one
/// thread, or a nested call) the loop runs inline in index order — the
/// same jobs either way, so results are identical by construction.
pub fn run_jobs(njobs: usize, grain: usize, work: usize, job: &(dyn Fn(usize) + Sync)) {
    let grain = grain.max(1);
    if njobs <= 1 || !active(work) {
        for j in 0..njobs {
            job(j);
        }
        return;
    }
    // Cap spawned threads by both the chunk count and the work size so a
    // barely-above-threshold region does not pay for a full fan-out.
    let nchunks = njobs.div_ceil(grain);
    let by_work = 1 + work / MIN_PAR_WORK;
    let nt = threads().min(nchunks).min(by_work).max(2);
    let next = AtomicUsize::new(0);
    let worker = || {
        IN_POOL.with(|c| c.set(true));
        // Reset on scope exit even if a job panics: a leaked true flag
        // would silently disable fan-out on this thread forever after a
        // caught panic (e.g. libtest's catch_unwind).
        struct FlagGuard;
        impl Drop for FlagGuard {
            fn drop(&mut self) {
                IN_POOL.with(|c| c.set(false));
            }
        }
        let _guard = FlagGuard;
        loop {
            let start = next.fetch_add(grain, Ordering::Relaxed);
            if start >= njobs {
                break;
            }
            let end = (start + grain).min(njobs);
            for j in start..end {
                job(j);
            }
        }
    };
    std::thread::scope(|s| {
        for _ in 1..nt {
            s.spawn(worker);
        }
        worker();
    });
}

/// Raw-pointer wrapper so disjoint chunk writes can cross the scope
/// boundary. Safety rests on the chunk partition below being disjoint.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}

/// Split `data` into consecutive chunks of `chunk` elements (last one
/// short) and run `f(chunk_index, chunk_slice)` over them on the pool.
/// Each element belongs to exactly one chunk, so writes never overlap.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, work: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk = chunk.max(1);
    let len = data.len();
    let nchunks = len.div_ceil(chunk);
    let ptr = SendPtr(data.as_mut_ptr());
    run_jobs(nchunks, 1, work, &|ci| {
        let start = ci * chunk;
        let end = (start + chunk).min(len);
        // SAFETY: chunks [start, end) are pairwise disjoint across chunk
        // indices, every index is claimed by exactly one job, and `data`
        // outlives the scoped workers inside `run_jobs`.
        let slice = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(start), end - start) };
        f(ci, slice);
    });
}

/// Compute `f(i)` for `i in 0..n` on the pool and return the results in
/// index order. `grain` consecutive indices form one queue item.
pub fn par_fill<T, F>(n: usize, grain: usize, work: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let grain = grain.max(1);
    let mut out: Vec<Option<T>> = Vec::new();
    out.resize_with(n, || None);
    par_chunks_mut(&mut out, grain, work, |ci, slots| {
        for (j, slot) in slots.iter_mut().enumerate() {
            *slot = Some(f(ci * grain + j));
        }
    });
    out.into_iter()
        .map(|o| o.expect("par_fill: unfilled slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// The pool size is process-global and libtest runs tests
    /// concurrently — serialize every test that calls `set_threads` so
    /// they cannot stomp each other's configuration mid-assertion.
    static GLOBAL: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        GLOBAL.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn par_fill_preserves_index_order() {
        let _g = lock();
        for nt in [1usize, 2, 8] {
            set_threads(nt);
            for grain in [1usize, 3, 64] {
                let v = par_fill(100, grain, usize::MAX, |i| i * i);
                assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
            }
        }
        set_threads(0);
    }

    #[test]
    fn par_chunks_mut_partitions_disjointly() {
        let _g = lock();
        set_threads(4);
        let mut data = vec![0usize; 103];
        par_chunks_mut(&mut data, 10, usize::MAX, |ci, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = ci * 10 + j;
            }
        });
        assert_eq!(data, (0..103).collect::<Vec<_>>());
        set_threads(0);
    }

    #[test]
    fn nested_regions_run_inline() {
        let _g = lock();
        set_threads(4);
        let outer = par_fill(8, 1, usize::MAX, |i| {
            // nested call must not spawn (and must still be correct)
            let inner = par_fill(5, 1, usize::MAX, move |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        let expect: Vec<usize> =
            (0..8).map(|i| (0..5).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(outer, expect);
        set_threads(0);
    }

    #[test]
    fn small_work_stays_sequential_but_correct() {
        let _g = lock();
        set_threads(8);
        assert!(!active(10));
        let v = par_fill(4, 1, 10, |i| i + 1);
        assert_eq!(v, vec![1, 2, 3, 4]);
        set_threads(0);
    }

    #[test]
    fn set_threads_round_trips() {
        let _g = lock();
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
        assert!(threads() >= 1);
    }
}
