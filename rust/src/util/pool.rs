//! Dependency-free scoped worker pool for the native backend's hot paths.
//!
//! Vendored parallelism substrate (crates.io is unavailable offline):
//! `std::thread::scope` workers draining a chunked atomic work queue. The
//! pool size comes from `BRECQ_THREADS` (unset or `0` = auto-detect via
//! `available_parallelism`) and can be overridden at runtime with
//! [`set_threads`] — the CLI's `--threads` flag and the bench/test
//! harnesses use that.
//!
//! # Determinism contract
//!
//! Every helper here guarantees **bit-identical results at any thread
//! count, including 1**. Work is partitioned by *ownership*: each output
//! element is computed entirely by one job, with exactly the same inner
//! arithmetic order as the scalar loop, and job outputs land at fixed
//! indices. No reduction ever races or reassociates floating-point sums
//! across jobs — callers that need a cross-job reduction fold the per-job
//! partials on the calling thread in job-index order. `tests/parallel.rs`
//! enforces this bitwise against scalar references at 1/2/8 threads.
//!
//! # Scheduling
//!
//! Fan-out only happens when (a) the pool has more than one thread,
//! (b) the estimated work clears [`MIN_PAR_WORK`] (scoped thread spawns
//! cost tens of microseconds — tiny kernels stay inline), and (c) the
//! caller is not already inside a pool worker (nested regions run inline
//! on their worker, so a parallel `advance` over calibration batches does
//! not multiply threads with the parallel conv kernels it dispatches).

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Minimum estimated scalar-op count before a region fans out. Below this
/// the scoped-spawn overhead outweighs the parallel win.
pub const MIN_PAR_WORK: usize = 1 << 16;

/// 0 = not yet initialized (read `BRECQ_THREADS` / autodetect on first use).
static THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static IN_POOL: Cell<bool> = Cell::new(false);
}

fn auto_threads() -> usize {
    let env = std::env::var("BRECQ_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok());
    match env {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    }
}

/// Current pool size (threads used by parallel regions).
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let t = auto_threads().max(1);
    THREADS.store(t, Ordering::Relaxed);
    t
}

/// Override the pool size at runtime; `0` re-reads `BRECQ_THREADS` /
/// autodetect. Results are unaffected by construction (see the
/// determinism contract), so this is safe to flip mid-run.
pub fn set_threads(n: usize) {
    let t = if n == 0 { auto_threads().max(1) } else { n };
    THREADS.store(t, Ordering::Relaxed);
}

/// True when the calling thread is a pool worker (nested regions inline).
pub fn in_worker() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Would a region with `work` estimated scalar ops actually fan out?
pub fn active(work: usize) -> bool {
    threads() > 1 && work >= MIN_PAR_WORK && !in_worker()
}

/// Run `job(j)` for every `j in 0..njobs`. Jobs are claimed from an atomic
/// queue in chunks of `grain`; with fan-out disabled (small `work`, one
/// thread, or a nested call) the loop runs inline in index order — the
/// same jobs either way, so results are identical by construction.
pub fn run_jobs(njobs: usize, grain: usize, work: usize, job: &(dyn Fn(usize) + Sync)) {
    let grain = grain.max(1);
    if njobs <= 1 || !active(work) {
        for j in 0..njobs {
            job(j);
        }
        return;
    }
    // Cap spawned threads by both the chunk count and the work size so a
    // barely-above-threshold region does not pay for a full fan-out.
    let nchunks = njobs.div_ceil(grain);
    let by_work = 1 + work / MIN_PAR_WORK;
    let nt = threads().min(nchunks).min(by_work).max(2);
    let next = AtomicUsize::new(0);
    let worker = || {
        IN_POOL.with(|c| c.set(true));
        // Reset on scope exit even if a job panics: a leaked true flag
        // would silently disable fan-out on this thread forever after a
        // caught panic (e.g. libtest's catch_unwind).
        struct FlagGuard;
        impl Drop for FlagGuard {
            fn drop(&mut self) {
                IN_POOL.with(|c| c.set(false));
            }
        }
        let _guard = FlagGuard;
        loop {
            let start = next.fetch_add(grain, Ordering::Relaxed);
            if start >= njobs {
                break;
            }
            let end = (start + grain).min(njobs);
            for j in start..end {
                job(j);
            }
        }
    };
    std::thread::scope(|s| {
        for _ in 1..nt {
            s.spawn(worker);
        }
        worker();
    });
}

/// Raw-pointer wrapper so disjoint chunk writes can cross the scope
/// boundary. Safety rests on the chunk partition below being disjoint.
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}

/// Split `data` into consecutive chunks of `chunk` elements (last one
/// short) and run `f(chunk_index, chunk_slice)` over them on the pool.
/// Each element belongs to exactly one chunk, so writes never overlap.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, work: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk = chunk.max(1);
    let len = data.len();
    let nchunks = len.div_ceil(chunk);
    let ptr = SendPtr(data.as_mut_ptr());
    run_jobs(nchunks, 1, work, &|ci| {
        let start = ci * chunk;
        let end = (start + chunk).min(len);
        // SAFETY: chunks [start, end) are pairwise disjoint across chunk
        // indices, every index is claimed by exactly one job, and `data`
        // outlives the scoped workers inside `run_jobs`.
        let slice = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(start), end - start) };
        f(ci, slice);
    });
}

/// Like [`par_chunks_mut`] but over two buffers partitioned in lockstep:
/// job `i` receives chunk `i` of `a` (chunks of `ca` elements) and chunk
/// `i` of `b` (chunks of `cb` elements). Both partitions must produce the
/// same number of chunks. The kernels use this to fill an output tensor
/// and a shared scratch slab (e.g. per-sample im2col panels) in one
/// ownership-partitioned region.
pub fn par_chunks2_mut<T, U, F>(
    a: &mut [T],
    ca: usize,
    b: &mut [U],
    cb: usize,
    work: usize,
    f: F,
) where
    T: Send,
    U: Send,
    F: Fn(usize, &mut [T], &mut [U]) + Sync,
{
    let ca = ca.max(1);
    let cb = cb.max(1);
    let (la, lb) = (a.len(), b.len());
    let nchunks = la.div_ceil(ca);
    assert_eq!(
        nchunks,
        lb.div_ceil(cb),
        "par_chunks2_mut: chunk counts differ"
    );
    let pa = SendPtr(a.as_mut_ptr());
    let pb = SendPtr(b.as_mut_ptr());
    run_jobs(nchunks, 1, work, &|ci| {
        let (sa, ea) = (ci * ca, (ci * ca + ca).min(la));
        let (sb, eb) = (ci * cb, (ci * cb + cb).min(lb));
        // SAFETY: as in `par_chunks_mut` — chunk ranges are pairwise
        // disjoint per buffer, each claimed by exactly one job, and both
        // buffers outlive the scoped workers inside `run_jobs`.
        let (sl_a, sl_b) = unsafe {
            (
                std::slice::from_raw_parts_mut(pa.0.add(sa), ea - sa),
                std::slice::from_raw_parts_mut(pb.0.add(sb), eb - sb),
            )
        };
        f(ci, sl_a, sl_b);
    });
}

/// Compute `f(i)` for `i in 0..n` on the pool and return the results in
/// index order. `grain` consecutive indices form one queue item.
pub fn par_fill<T, F>(n: usize, grain: usize, work: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let grain = grain.max(1);
    let mut out: Vec<Option<T>> = Vec::new();
    out.resize_with(n, || None);
    par_chunks_mut(&mut out, grain, work, |ci, slots| {
        for (j, slot) in slots.iter_mut().enumerate() {
            *slot = Some(f(ci * grain + j));
        }
    });
    out.into_iter()
        .map(|o| o.expect("par_fill: unfilled slot"))
        .collect()
}

// ------------------------------------------------------------------
// Per-worker scratch arenas
// ------------------------------------------------------------------

/// Reusable f32 scratch buffers for the GEMM-backed kernels, one set per
/// thread (see [`with_scratch`]). Field names describe the typical role;
/// any kernel may repurpose a slot as long as it holds at most one live
/// [`grab`] borrow per slot at a time (the borrow checker enforces this
/// through the destructured fields).
#[derive(Default)]
pub struct Scratch {
    /// im2col panels (forward cols / backward gradient cols).
    pub im2col: Vec<f32>,
    /// Transposed im2col slab (weight-gradient reduction operand).
    pub cols_t: Vec<f32>,
    /// Packed/flipped weight operand.
    pub wpack: Vec<f32>,
    /// GEMM packed A panels.
    pub pack_a: Vec<f32>,
    /// GEMM packed B panels.
    pub pack_b: Vec<f32>,
}

/// Scratch sets recycled across pool regions. Workers are scoped threads
/// that die at the end of every parallel region, so a plain `thread_local`
/// would re-allocate its buffers on each region; instead each thread
/// checks a `Scratch` out of this arena on first use and its thread-local
/// destructor returns it when the thread exits. Steady state: the arena
/// holds one warm set per historical worker and no `grab` ever allocates.
static RECYCLE: Mutex<Vec<Scratch>> = Mutex::new(Vec::new());

/// Shared (cross-worker) f32 slabs, checked out with [`take_shared`] and
/// returned with [`give_shared`] — used for buffers one region fills and
/// a later region reads (disjoint-chunk writes, shared reads).
static SHARED: Mutex<Vec<Vec<f32>>> = Mutex::new(Vec::new());

/// Scratch requests served without growing a buffer (capacity hit).
static SCRATCH_REUSES: AtomicUsize = AtomicUsize::new(0);
/// Scratch requests that had to allocate or grow a buffer.
static SCRATCH_ALLOCS: AtomicUsize = AtomicUsize::new(0);

/// Caps on the recycle arenas so a pathological thread storm cannot pin
/// unbounded memory; excess sets are simply dropped. The shared cap
/// leaves headroom for the reconstruction plans (`runtime::plan`), which
/// check several whole-cache im2col slabs out per unit and return them
/// on drop so the next unit's plan builds warm.
const RECYCLE_CAP: usize = 64;
const SHARED_CAP: usize = 16;

struct ScratchCell(RefCell<Option<Scratch>>);

impl Drop for ScratchCell {
    fn drop(&mut self) {
        if let Some(s) = self.0.borrow_mut().take() {
            let mut r = RECYCLE.lock().unwrap_or_else(|e| e.into_inner());
            if r.len() < RECYCLE_CAP {
                r.push(s);
            }
        }
    }
}

thread_local! {
    static SCRATCH: ScratchCell = ScratchCell(RefCell::new(None));
}

/// Run `f` with this thread's [`Scratch`] set (checked out of the recycle
/// arena on first use). Do not call re-entrantly from inside `f` — each
/// kernel entry point takes the scratch exactly once per job.
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    SCRATCH.with(|cell| {
        let mut slot = cell.0.borrow_mut();
        if slot.is_none() {
            let recycled = RECYCLE
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .pop()
                .unwrap_or_default();
            *slot = Some(recycled);
        }
        f(slot.as_mut().expect("scratch checked out above"))
    })
}

/// Resize `buf` to exactly `len` zeroed elements and hand it out as a
/// slice, counting whether the request was served from existing capacity
/// (reuse) or had to allocate. Callers that fully overwrite the buffer
/// pay one memset; callers that need a zero background rely on it.
pub fn grab(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if buf.capacity() >= len {
        SCRATCH_REUSES.fetch_add(1, Ordering::Relaxed);
    } else {
        SCRATCH_ALLOCS.fetch_add(1, Ordering::Relaxed);
    }
    buf.clear();
    buf.resize(len, 0.0);
    &mut buf[..]
}

/// Like [`grab`] but without the zeroing pass: contents are stale from
/// the previous use. Only for callers that overwrite every element they
/// read (e.g. the GEMM panel packers, which zero their own pad lanes).
pub fn grab_dirty(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if buf.capacity() >= len {
        SCRATCH_REUSES.fetch_add(1, Ordering::Relaxed);
    } else {
        SCRATCH_ALLOCS.fetch_add(1, Ordering::Relaxed);
    }
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
    &mut buf[..len]
}

/// Check a zeroed `len`-element slab out of the shared arena (the
/// best-fitting warm buffer, or a fresh allocation). Pair with
/// [`give_shared`].
pub fn take_shared(len: usize) -> Vec<f32> {
    let mut pool = SHARED.lock().unwrap_or_else(|e| e.into_inner());
    // prefer the smallest buffer that already fits
    let mut pick: Option<usize> = None;
    for (i, b) in pool.iter().enumerate() {
        if b.capacity() >= len
            && pick.is_none_or(|p| b.capacity() < pool[p].capacity())
        {
            pick = Some(i);
        }
    }
    let mut buf = match pick {
        Some(i) => pool.swap_remove(i),
        None => pool.pop().unwrap_or_default(),
    };
    drop(pool);
    if buf.capacity() >= len {
        SCRATCH_REUSES.fetch_add(1, Ordering::Relaxed);
    } else {
        SCRATCH_ALLOCS.fetch_add(1, Ordering::Relaxed);
    }
    buf.clear();
    buf.resize(len, 0.0);
    buf
}

/// Return a slab taken with [`take_shared`] to the arena.
pub fn give_shared(buf: Vec<f32>) {
    let mut pool = SHARED.lock().unwrap_or_else(|e| e.into_inner());
    if pool.len() < SHARED_CAP {
        pool.push(buf);
    }
}

/// (allocations, capacity-hits) across every scratch request since process
/// start. `tests/parallel.rs` asserts the alloc counter stops moving once
/// the kernels are warm — the zero-steady-state-allocation guarantee.
pub fn scratch_counters() -> (usize, usize) {
    (
        SCRATCH_ALLOCS.load(Ordering::Relaxed),
        SCRATCH_REUSES.load(Ordering::Relaxed),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The pool size is process-global and libtest runs tests
    /// concurrently — serialize every test that calls `set_threads` so
    /// they cannot stomp each other's configuration mid-assertion.
    static GLOBAL: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        GLOBAL.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn par_fill_preserves_index_order() {
        let _g = lock();
        for nt in [1usize, 2, 8] {
            set_threads(nt);
            for grain in [1usize, 3, 64] {
                let v = par_fill(100, grain, usize::MAX, |i| i * i);
                assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
            }
        }
        set_threads(0);
    }

    #[test]
    fn par_chunks_mut_partitions_disjointly() {
        let _g = lock();
        set_threads(4);
        let mut data = vec![0usize; 103];
        par_chunks_mut(&mut data, 10, usize::MAX, |ci, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = ci * 10 + j;
            }
        });
        assert_eq!(data, (0..103).collect::<Vec<_>>());
        set_threads(0);
    }

    #[test]
    fn nested_regions_run_inline() {
        let _g = lock();
        set_threads(4);
        let outer = par_fill(8, 1, usize::MAX, |i| {
            // nested call must not spawn (and must still be correct)
            let inner = par_fill(5, 1, usize::MAX, move |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        let expect: Vec<usize> =
            (0..8).map(|i| (0..5).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(outer, expect);
        set_threads(0);
    }

    #[test]
    fn small_work_stays_sequential_but_correct() {
        let _g = lock();
        set_threads(8);
        assert!(!active(10));
        let v = par_fill(4, 1, 10, |i| i + 1);
        assert_eq!(v, vec![1, 2, 3, 4]);
        set_threads(0);
    }

    #[test]
    fn par_chunks2_partitions_both_buffers_in_lockstep() {
        let _g = lock();
        set_threads(4);
        let mut a = vec![0usize; 10];
        let mut b = vec![0usize; 25];
        par_chunks2_mut(&mut a, 2, &mut b, 5, usize::MAX, |ci, ca, cb| {
            for v in ca.iter_mut() {
                *v = ci + 1;
            }
            for v in cb.iter_mut() {
                *v = 10 * (ci + 1);
            }
        });
        assert_eq!(a, vec![1, 1, 2, 2, 3, 3, 4, 4, 5, 5]);
        assert_eq!(b[0..5], [10; 5]);
        assert_eq!(b[20..25], [50; 5]);
        set_threads(0);
    }

    #[test]
    #[should_panic]
    fn par_chunks2_rejects_mismatched_chunk_counts() {
        let mut a = vec![0usize; 10]; // 5 chunks of 2
        let mut b = vec![0usize; 9]; // 3 chunks of 3
        par_chunks2_mut(&mut a, 2, &mut b, 3, 0, |_, _, _| {});
    }

    /// Note: the strict "warm kernels allocate zero" property is asserted
    /// in `tests/parallel.rs`, where the counters are serialized; here
    /// (concurrent lib tests share the globals) only monotone facts hold.
    #[test]
    fn scratch_grab_reuses_capacity() {
        let (a0, r0) = scratch_counters();
        let mut buf = Vec::new();
        let s = grab(&mut buf, 64);
        s[0] = 1.0;
        // second grab of the same size: capacity hit, zeroed contents
        let s = grab(&mut buf, 64);
        assert_eq!(s[0], 0.0, "grab must re-zero");
        let (a1, r1) = scratch_counters();
        assert!(a1 > a0, "first grab must allocate");
        assert!(r1 > r0, "warm grab must count as a reuse");
    }

    #[test]
    fn shared_slabs_recycle() {
        let buf = take_shared(128);
        assert_eq!(buf.len(), 128);
        give_shared(buf);
        let buf = take_shared(100);
        assert!(buf.capacity() >= 128, "warm slab should be reused");
        assert!(buf.iter().all(|&v| v == 0.0));
        give_shared(buf);
    }

    #[test]
    fn set_threads_round_trips() {
        let _g = lock();
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
        assert!(threads() >= 1);
    }
}
