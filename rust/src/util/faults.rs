//! Deterministic fault injection for chaos testing.
//!
//! A `FaultPlan` names *sites* (string keys compiled into the store and
//! job layers, e.g. `store.publish`, `job.recon`) and attaches a fault
//! kind plus a firing rule to each:
//!
//! ```text
//! BRECQ_FAULTS="store.publish:io@0.1;job.recon:panic@2"
//! ```
//!
//! means "each `store.publish` call fails with a transient IO error
//! with probability 0.1; the 2nd `job.recon` call panics". A parameter
//! containing `.` is a probability; a bare integer `N` fires exactly on
//! the Nth call at that site. Probability draws come from a per-site
//! seeded stream (`fnv64(site) ^ $BRECQ_FAULTS_SEED`), so a plan replays
//! identically across runs and is independent of call order at *other*
//! sites.
//!
//! Probability-mode faults are **bounded-burst**: a site never fires on
//! two consecutive calls. Retry loops with >= 2 attempts therefore
//! always recover an injected transient, which is what makes the chaos
//! soak's compute-exactly-once assertion deterministic rather than
//! flaky.
//!
//! Unarmed (the default — `$BRECQ_FAULTS` unset), `check()` is one
//! relaxed atomic load; no site pays for the instrumentation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once};

use crate::util::rng::Rng;

/// What an armed site does to its caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Transient IO error — retryable (classified like `EINTR`/timeouts).
    Io,
    /// Permanent error — surfaces to the caller without retry.
    Perm,
    /// The call site panics (exercises `catch_unwind` isolation).
    Panic,
}

impl Kind {
    pub fn as_str(self) -> &'static str {
        match self {
            Kind::Io => "io",
            Kind::Perm => "perm",
            Kind::Panic => "panic",
        }
    }

    fn parse(s: &str) -> Option<Kind> {
        match s {
            "io" => Some(Kind::Io),
            "perm" => Some(Kind::Perm),
            "panic" => Some(Kind::Panic),
            _ => None,
        }
    }
}

/// Firing rule for one site.
#[derive(Debug, Clone, Copy, PartialEq)]
enum When {
    /// Fire each call with this probability (seeded per-site stream),
    /// never on two consecutive calls (bounded burst).
    Prob(f64),
    /// Fire exactly on the Nth call at the site (1-based), once.
    Nth(u64),
}

#[derive(Debug, Clone)]
struct Rule {
    site: String,
    kind: Kind,
    when: When,
}

/// A parsed `$BRECQ_FAULTS` plan. Install with [`set_plan`] (tests) or
/// let the first [`check`] pick it up from the environment.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    rules: Vec<Rule>,
    seed: u64,
}

impl FaultPlan {
    /// Parse `site:kind@param` specs separated by `;`.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut rules = Vec::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (site, rest) = part
                .split_once(':')
                .ok_or_else(|| format!("'{part}': expected site:kind@param"))?;
            let (kind, param) = rest
                .split_once('@')
                .ok_or_else(|| format!("'{part}': expected kind@param"))?;
            let kind = Kind::parse(kind)
                .ok_or_else(|| format!("'{part}': unknown kind '{kind}' (io|perm|panic)"))?;
            let when = if param.contains('.') {
                let p: f64 = param
                    .parse()
                    .map_err(|_| format!("'{part}': bad probability '{param}'"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("'{part}': probability {p} outside [0,1]"));
                }
                When::Prob(p)
            } else {
                let n: u64 = param
                    .parse()
                    .map_err(|_| format!("'{part}': bad call index '{param}'"))?;
                if n == 0 {
                    return Err(format!("'{part}': call index is 1-based"));
                }
                When::Nth(n)
            };
            rules.push(Rule { site: site.trim().to_string(), kind, when });
        }
        Ok(FaultPlan { rules, seed })
    }
}

/// Per-site runtime state under an armed plan.
struct SiteState {
    rng: Rng,
    calls: u64,
    fired: u64,
    fired_last: bool,
}

struct PlanState {
    plan: FaultPlan,
    sites: HashMap<String, SiteState>,
}

impl PlanState {
    fn check(&mut self, site: &str) -> Option<Kind> {
        let rule = self.plan.rules.iter().find(|r| r.site == site)?;
        let seed = self.plan.seed;
        let st = self.sites.entry(site.to_string()).or_insert_with(|| SiteState {
            rng: Rng::new(fnv64_local(site.as_bytes()) ^ seed),
            calls: 0,
            fired: 0,
            fired_last: false,
        });
        st.calls += 1;
        let fire = match rule.when {
            When::Nth(n) => st.calls == n,
            // bounded burst: a retry directly after an injected
            // transient always observes a clean attempt
            When::Prob(p) => !st.fired_last && st.rng.f64() < p,
        };
        st.fired_last = fire;
        if fire {
            st.fired += 1;
            Some(rule.kind)
        } else {
            None
        }
    }
}

static ARMED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<PlanState>> = Mutex::new(None);
static ENV_INIT: Once = Once::new();

// Local FNV-1a so util never depends on the pipeline layer.
fn fnv64_local(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn init_from_env() {
    ENV_INIT.call_once(|| {
        let Ok(spec) = std::env::var("BRECQ_FAULTS") else { return };
        if spec.trim().is_empty() {
            return;
        }
        let seed = std::env::var("BRECQ_FAULTS_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        match FaultPlan::parse(&spec, seed) {
            Ok(p) => {
                install(Some(p));
                eprintln!("[faults] armed via $BRECQ_FAULTS: {spec} (seed {seed})");
            }
            Err(e) => eprintln!("[faults] ignoring malformed $BRECQ_FAULTS: {e}"),
        }
    });
}

fn install(plan: Option<FaultPlan>) {
    let mut g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    match plan {
        Some(p) => {
            *g = Some(PlanState { plan: p, sites: HashMap::new() });
            ARMED.store(true, Ordering::Relaxed);
        }
        None => {
            *g = None;
            ARMED.store(false, Ordering::Relaxed);
        }
    }
}

/// Install (or clear) a plan programmatically. Test hook; also disarms
/// the environment pickup so a later `check` can't overwrite it.
pub fn set_plan(plan: Option<FaultPlan>) {
    ENV_INIT.call_once(|| {});
    install(plan);
}

/// Should this call at `site` fail, and how? `None` on the (default)
/// unarmed path after a single relaxed load.
pub fn check(site: &str) -> Option<Kind> {
    init_from_env();
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    g.as_mut()?.check(site)
}

/// Is a fault plan currently armed (env or [`set_plan`])?
pub fn armed() -> bool {
    init_from_env();
    ARMED.load(Ordering::Relaxed)
}

/// `(calls, fired)` counters for `site` under the active plan.
pub fn site_counters(site: &str) -> (u64, u64) {
    let g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    g.as_ref()
        .and_then(|st| st.sites.get(site))
        .map(|s| (s.calls, s.fired))
        .unwrap_or((0, 0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_documented_grammar() {
        let p = FaultPlan::parse("store.publish:io@0.1; job.recon:panic@2", 7).unwrap();
        assert_eq!(p.rules.len(), 2);
        assert_eq!(p.rules[0].site, "store.publish");
        assert_eq!(p.rules[0].kind, Kind::Io);
        assert_eq!(p.rules[0].when, When::Prob(0.1));
        assert_eq!(p.rules[1].kind, Kind::Panic);
        assert_eq!(p.rules[1].when, When::Nth(2));
        assert!(FaultPlan::parse("x", 0).is_err());
        assert!(FaultPlan::parse("a:io", 0).is_err());
        assert!(FaultPlan::parse("a:zap@1", 0).is_err());
        assert!(FaultPlan::parse("a:io@1.5", 0).is_err());
        assert!(FaultPlan::parse("a:io@0", 0).is_err());
    }

    #[test]
    fn nth_mode_fires_exactly_once_and_prob_mode_is_bounded_burst() {
        // direct PlanState checks — no global install, so this test
        // cannot race other tests through the process-wide plan
        let plan = FaultPlan::parse("a:perm@3;b:io@0.5", 11).unwrap();
        let mut st = PlanState { plan, sites: HashMap::new() };
        let hits: Vec<Option<Kind>> = (0..5).map(|_| st.check("a")).collect();
        assert_eq!(hits, vec![None, None, Some(Kind::Perm), None, None]);
        assert_eq!(st.check("unknown.site"), None);
        let mut prev_fired = false;
        let mut total = 0;
        for _ in 0..200 {
            let fired = st.check("b").is_some();
            assert!(!(fired && prev_fired), "prob site fired twice in a row");
            prev_fired = fired;
            total += fired as u32;
        }
        assert!(total > 10, "p=0.5 over 200 calls fired only {total} times");
        let (calls, fired) = {
            let s = st.sites.get("b").unwrap();
            (s.calls, s.fired)
        };
        assert_eq!(calls, 200);
        assert_eq!(fired, total as u64);
    }

    #[test]
    fn prob_streams_replay_identically_for_one_seed() {
        let mk = || {
            let plan = FaultPlan::parse("s:io@0.3", 42).unwrap();
            let mut st = PlanState { plan, sites: HashMap::new() };
            (0..64).map(|_| st.check("s").is_some()).collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }
}
