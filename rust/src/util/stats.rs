//! Small statistics helpers: summary stats for benches and experiment
//! variance reporting (the paper reports mean ± std over seeds).

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// Percentile by linear interpolation, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Bench summary line: n, mean, p50, p95 (all in the caller's unit).
pub fn summary(xs: &[f64]) -> String {
    format!(
        "n={} mean={:.3} p50={:.3} p95={:.3} min={:.3}",
        xs.len(),
        mean(xs),
        percentile(xs, 50.0),
        percentile(xs, 95.0),
        xs.iter().cloned().fold(f64::INFINITY, f64::min)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
