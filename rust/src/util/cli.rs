//! Tiny argv parser substrate (clap is unavailable offline).
//!
//! Grammar: `brecq <subcommand> [positional...] [--key value | --flag]`.
//! Typed getters with defaults keep call sites short; unknown-flag detection
//! catches typos early.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub cmd: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                a.cmd = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        it.next().unwrap().clone()
                    }
                    _ => "true".to_string(), // bare flag
                };
                a.flags.insert(key.to_string(), val);
            } else {
                a.positional.push(tok.clone());
            }
        }
        a
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad usize '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad u64 '{v}'")))
            .unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad f32 '{v}'")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| matches!(v.as_str(), "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// Comma-separated list flag.
    pub fn list(&self, key: &str, default: &str) -> Vec<String> {
        self.str(key, default)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }

    /// Call after all getters: errors on flags nobody consumed (typos).
    pub fn reject_unknown(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .filter(|k| !seen.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown flags: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positionals() {
        let a = Args::parse(&argv(
            "calibrate resnet_s --bits 2 --act-bits 4 --seed 7 --fast",
        ));
        assert_eq!(a.cmd, "calibrate");
        assert_eq!(a.positional, vec!["resnet_s"]);
        assert_eq!(a.usize("bits", 8), 2);
        assert_eq!(a.usize("act-bits", 32), 4);
        assert_eq!(a.u64("seed", 0), 7);
        assert!(a.bool("fast", false));
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv("eval"));
        assert_eq!(a.str("model", "resnet_s"), "resnet_s");
        assert_eq!(a.f32("lam", 0.01), 0.01);
        assert!(!a.bool("aq", false));
    }

    #[test]
    fn unknown_flags_detected() {
        let a = Args::parse(&argv("x --real 1 --typo 2"));
        let _ = a.usize("real", 0);
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn list_flag() {
        let a = Args::parse(&argv("x --models a,b,c"));
        assert_eq!(a.list("models", ""), vec!["a", "b", "c"]);
    }
}
