//! Cooperative cancellation tokens with optional wall-clock deadlines.
//!
//! A `CancelToken` is checked — never enforced — at coarse checkpoints:
//! pipeline stage boundaries and reconstruction unit/iteration
//! boundaries. That keeps cancellation deterministic (a job observes it
//! only between atomic units of work, so partial artifacts are never
//! published) and costs one atomic load per check on an inert token.
//!
//! Tokens form a chain: `batch.child(deadline)` shares the parent's
//! cancel flag (for `ctl cancel <batch-id>`) while adding a per-job
//! deadline whose clock starts when the child is created — i.e. when
//! the job starts *executing*, not when it was queued.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug)]
struct Inner {
    parent: Option<Arc<Inner>>,
    deadline: Option<Instant>,
    deadline_ms: u64,
    cancelled: AtomicBool,
    reason: Mutex<String>,
}

/// Cloneable cancellation handle. `Default`/[`CancelToken::none`] is an
/// inert token that can never cancel (no allocation, near-zero checks).
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Option<Arc<Inner>>);

impl CancelToken {
    /// The inert token: never cancelled, no deadline, no allocation.
    pub fn none() -> CancelToken {
        CancelToken(None)
    }

    /// A live token that [`CancelToken::cancel`] can fire.
    pub fn new() -> CancelToken {
        CancelToken(Some(Arc::new(Inner {
            parent: None,
            deadline: None,
            deadline_ms: 0,
            cancelled: AtomicBool::new(false),
            reason: Mutex::new(String::new()),
        })))
    }

    /// A live token that auto-cancels once `d` has elapsed.
    pub fn with_deadline(d: Duration) -> CancelToken {
        CancelToken::none().child(Some(d))
    }

    /// A child sharing this token's cancellation, optionally adding its
    /// own deadline (clock starts now). An inert parent with no
    /// deadline stays inert.
    pub fn child(&self, deadline: Option<Duration>) -> CancelToken {
        if self.0.is_none() && deadline.is_none() {
            return CancelToken(None);
        }
        CancelToken(Some(Arc::new(Inner {
            parent: self.0.clone(),
            deadline: deadline.map(|d| Instant::now() + d),
            deadline_ms: deadline.map(|d| d.as_millis() as u64).unwrap_or(0),
            cancelled: AtomicBool::new(false),
            reason: Mutex::new(String::new()),
        })))
    }

    /// Cancel this token (and, transitively, every child built from
    /// it). No-op on an inert token.
    pub fn cancel(&self, reason: &str) {
        if let Some(i) = &self.0 {
            *i.reason.lock().unwrap_or_else(|e| e.into_inner()) = reason.to_string();
            i.cancelled.store(true, Ordering::SeqCst);
        }
    }

    /// `Some(reason)` once this token — or any ancestor — is cancelled
    /// or past its deadline; `None` while the work should continue.
    pub fn cancelled(&self) -> Option<String> {
        let mut cur = self.0.as_deref();
        while let Some(i) = cur {
            if i.cancelled.load(Ordering::SeqCst) {
                let r = i.reason.lock().unwrap_or_else(|e| e.into_inner()).clone();
                return Some(if r.is_empty() { "cancelled".to_string() } else { r });
            }
            if let Some(d) = i.deadline {
                if Instant::now() >= d {
                    return Some(format!("deadline of {}ms exceeded", i.deadline_ms));
                }
            }
            cur = i.parent.as_deref();
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_token_never_cancels() {
        let t = CancelToken::none();
        t.cancel("ignored");
        assert_eq!(t.cancelled(), None);
        assert_eq!(CancelToken::default().cancelled(), None);
    }

    #[test]
    fn explicit_cancel_reaches_children_with_the_reason() {
        let parent = CancelToken::new();
        let child = parent.child(None);
        assert_eq!(child.cancelled(), None);
        parent.cancel("cancelled by ctl");
        assert_eq!(child.cancelled().as_deref(), Some("cancelled by ctl"));
        assert_eq!(parent.cancelled().as_deref(), Some("cancelled by ctl"));
    }

    #[test]
    fn deadline_fires_with_a_typed_reason() {
        let t = CancelToken::with_deadline(Duration::from_millis(20));
        assert_eq!(t.cancelled(), None);
        std::thread::sleep(Duration::from_millis(30));
        let why = t.cancelled().expect("deadline must have fired");
        assert!(why.contains("deadline of 20ms exceeded"), "got: {why}");
    }

    #[test]
    fn child_deadline_does_not_cancel_the_parent() {
        let parent = CancelToken::new();
        let child = parent.child(Some(Duration::from_millis(10)));
        std::thread::sleep(Duration::from_millis(20));
        assert!(child.cancelled().is_some());
        assert_eq!(parent.cancelled(), None, "sibling jobs must keep running");
    }
}
