//! Minimal JSON parser/serializer (substrate: serde is unavailable offline).
//!
//! Covers the full JSON grammar we exchange with the Python build path
//! (manifest.json, weight-store indexes, experiment reports) and the
//! pipeline's `JobSpec` batch files (`brecq run jobs.json`): objects,
//! arrays, strings with escapes, numbers, bools, null. Numbers are kept as
//! f64; the manifest never needs more than 2^53 integer precision.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required manifest fields (a malformed
    /// manifest is a build error, not a runtime condition).
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key: {key}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_vec(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }

    pub fn f32_vec(&self) -> Vec<f32> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64().map(|x| x as f32)).collect())
            .unwrap_or_default()
    }

    // ---- writer ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {}", start))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            // (surrogate pairs unsupported; the manifest is
                            // plain ASCII)
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

// convenience constructors used by report writers
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn b(v: bool) -> Json {
    Json::Bool(v)
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true},
                      "e": null, "f": []}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.req("a").usize_vec()[0], 1);
        assert_eq!(v.req("a").as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.req("b").req("c").as_str(), Some("x\ny"));
        assert_eq!(v.req("b").req("d").as_bool(), Some(true));
        assert_eq!(v.req("e"), &Json::Null);
        assert!(v.req("f").as_arr().unwrap().is_empty());
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"k":[{"x":1},{"y":"a\"b"},false,null,1.25]}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn escapes_on_write() {
        let v = obj(vec![("k", s("a\"b\\c\nd"))]);
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back.req("k").as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = arr(vec![num(32.0), num(0.5)]);
        assert_eq!(v.to_string(), "[32,0.5]");
    }
}
