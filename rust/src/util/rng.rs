//! Deterministic PRNG substrate (the `rand` crate is unavailable offline).
//!
//! splitmix64-seeded xoshiro256++ — the standard small-state generator —
//! plus the distributions the coordinator needs: uniform ints/floats,
//! Gaussian (Box-Muller), Fisher-Yates shuffle and sampling without
//! replacement. Every stochastic component of the pipeline (calibration
//! batch sampling, GA init/crossover/mutation, distilled-data init) takes an
//! explicit `Rng` so whole experiments are replayable from one seed.

pub struct Rng {
    s: [u64; 4],
    cached_gauss: Option<f64>,
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let s = [
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
        ];
        Rng { s, cached_gauss: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our sizes).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.cached_gauss.take() {
            return g;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_gauss = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// `k` distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fork a child generator (independent stream for a sub-component).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Bit-exact snapshot of the generator as six words: the xoshiro256++
    /// state followed by a presence flag and the bits of the cached
    /// Box-Muller sample. `from_state(state())` continues the exact
    /// sequence — the checkpoint/resume contract.
    pub fn state(&self) -> [u64; 6] {
        [
            self.s[0],
            self.s[1],
            self.s[2],
            self.s[3],
            self.cached_gauss.is_some() as u64,
            self.cached_gauss.unwrap_or(0.0).to_bits(),
        ]
    }

    /// Rebuild a generator from a `state()` snapshot.
    pub fn from_state(w: [u64; 6]) -> Rng {
        Rng {
            s: [w[0], w[1], w[2], w[3]],
            cached_gauss: (w[4] != 0).then(|| f64::from_bits(w[5])),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(3);
        let mut seen = [0usize; 10];
        for _ in 0..5000 {
            seen[r.below(10)] += 1;
        }
        assert!(seen.iter().all(|&c| c > 300), "{seen:?}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let idx = r.sample_indices(100, 40);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn state_roundtrip_continues_sequence() {
        let mut a = Rng::new(42);
        for _ in 0..37 {
            a.next_u64();
        }
        a.gauss(); // leave a cached Box-Muller sample pending
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.gauss().to_bits(), b.gauss().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let idx_a = a.sample_indices(64, 16);
        let idx_b = b.sample_indices(64, 16);
        assert_eq!(idx_a, idx_b);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
