//! Quantizer substrate: uniform symmetric grids, per-channel MSE step
//! search, AdaRound state (init + hard commit), LSQ activation-step init.
//!
//! Mirrors the math of the Pallas kernels exactly (python/compile/kernels);
//! the Rust side owns everything that happens *outside* the AOT graphs:
//! step initialization, the rounding-variable state between executor calls,
//! and the final hard-rounding commit of Eq. 16.

use crate::tensor::Tensor;

pub const ZETA: f32 = 1.1;
pub const GAMMA: f32 = -0.1;

/// Signed integer grid bounds for b-bit weights: [-2^(b-1), 2^(b-1)-1].
pub fn weight_bounds(bits: usize) -> (f32, f32) {
    let h = 1i64 << (bits - 1);
    (-(h as f32), (h - 1) as f32)
}

/// Activation grid bounds: unsigned [0, 2^b - 1] after ReLU, signed
/// otherwise (linear-bottleneck outputs, standardized images).
pub fn act_bounds(bits: usize, signed: bool) -> (f32, f32) {
    if signed {
        weight_bounds(bits)
    } else {
        (0.0, ((1i64 << bits) - 1) as f32)
    }
}

pub fn rect_sigmoid(v: f32) -> f32 {
    let s = 1.0 / (1.0 + (-v).exp());
    (s * (ZETA - GAMMA) + GAMMA).clamp(0.0, 1.0)
}

/// Inverse of the rectified sigmoid on (0,1): the AdaRound v init such
/// that h(v) equals the fractional part of w/s (soft quant == FP weight).
pub fn rect_sigmoid_inv(h: f32) -> f32 {
    let h = h.clamp(0.01, 0.99);
    let s = (h - GAMMA) / (ZETA - GAMMA);
    (s / (1.0 - s)).ln()
}

/// Nearest-rounding fake-quant of one value.
pub fn round_quant(w: f32, step: f32, n: f32, p: f32) -> f32 {
    step * (w / step).round().clamp(n, p)
}

/// Per-channel MSE-optimal step search (the paper's quantizer init; also
/// the OMSE baseline). For each leading-dim channel, scans `grid` scale
/// fractions of max|w| and keeps the step minimizing ||w - q(w)||^2.
pub fn mse_steps_per_channel(w: &Tensor, bits: usize) -> Vec<f32> {
    let (n, p) = weight_bounds(bits);
    let c = w.c0();
    let inner = w.inner();
    let mut steps = Vec::with_capacity(c);
    for ch in 0..c {
        let row = &w.data[ch * inner..(ch + 1) * inner];
        steps.push(mse_step_slice(row, n, p));
    }
    steps
}

/// Per-tensor MSE-optimal step (activations; also per-tensor weight mode).
pub fn mse_step_tensor(xs: &[f32], qmin: f32, qmax: f32) -> f32 {
    mse_step_slice(xs, qmin, qmax)
}

fn mse_step_slice(row: &[f32], n: f32, p: f32) -> f32 {
    let maxabs = row.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-8);
    // candidate grid: the usual LAPQ/BRECQ-style scan around maxabs/p
    let denom = p.abs().max(n.abs()).max(1.0);
    let base = maxabs / denom;
    let mut best = (f64::INFINITY, base);
    for i in 0..80 {
        let frac = 0.2 + 1.0 * (i as f32) / 79.0; // 0.2 .. 1.2
        let s = (base * frac).max(1e-8);
        let mut err = 0f64;
        for &x in row {
            let d = x - round_quant(x, s, n, p);
            err += (d as f64) * (d as f64);
        }
        if err < best.0 {
            best = (err, s);
        }
    }
    best.1
}

/// Hard nearest-rounding quantization with per-channel steps (baselines,
/// and the sensitivity probe's layer quantizer).
pub fn quantize_nearest(w: &Tensor, steps: &[f32], bits: usize) -> Tensor {
    let (n, p) = weight_bounds(bits);
    let inner = w.inner();
    let mut out = w.clone();
    for ch in 0..w.c0() {
        let s = steps[ch];
        for v in &mut out.data[ch * inner..(ch + 1) * inner] {
            *v = round_quant(*v, s, n, p);
        }
    }
    out
}

/// AdaRound per-layer state: the continuous rounding variables `v`
/// (same shape as w) plus the frozen per-channel steps and clip bounds.
pub struct AdaRoundState {
    pub v: Tensor,
    pub steps: Vec<f32>,
    pub bits: usize,
}

impl AdaRoundState {
    /// v init so that h(v) = frac(w/s): the soft-quantized weight starts
    /// exactly at the FP weight (Nagel et al. 2020 init).
    pub fn init(w: &Tensor, steps: &[f32], bits: usize) -> AdaRoundState {
        let inner = w.inner();
        let mut v = Tensor::zeros(w.shape.clone());
        for ch in 0..w.c0() {
            let s = steps[ch];
            for i in ch * inner..(ch + 1) * inner {
                let r = w.data[i] / s - (w.data[i] / s).floor();
                v.data[i] = rect_sigmoid_inv(r);
            }
        }
        AdaRoundState { v, steps: steps.to_vec(), bits }
    }

    /// Hard commit (Eq. 16 with h binarized at 0.5): the deployed weights.
    pub fn commit(&self, w: &Tensor) -> Tensor {
        let (n, p) = weight_bounds(self.bits);
        let inner = w.inner();
        let mut out = w.clone();
        for ch in 0..w.c0() {
            let s = self.steps[ch];
            for i in ch * inner..(ch + 1) * inner {
                let up = if rect_sigmoid(self.v.data[i]) >= 0.5 { 1.0 } else { 0.0 };
                let g = ((w.data[i] / s).floor() + up).clamp(n, p);
                out.data[i] = s * g;
            }
        }
        out
    }

    /// Fraction of rounding variables not yet saturated (monitoring: the
    /// β-annealed regularizer should drive this to ~0).
    pub fn soft_fraction(&self) -> f64 {
        let n = self.v.data.len().max(1);
        let soft = self
            .v
            .data
            .iter()
            .filter(|&&v| {
                let h = rect_sigmoid(v);
                h > 0.05 && h < 0.95
            })
            .count();
        soft as f64 / n as f64
    }

    /// Per-channel steps as a Tensor for executable input.
    pub fn steps_tensor(&self) -> Tensor {
        Tensor::new(vec![self.steps.len()], self.steps.clone())
    }
}

/// Activation-step init: MSE search over a sample of activation values.
pub fn act_step_init(sample: &[f32], bits: usize, signed: bool) -> f32 {
    let (qmin, qmax) = act_bounds(bits, signed);
    mse_step_tensor(sample, qmin, qmax)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * scale).collect())
    }

    #[test]
    fn bounds() {
        assert_eq!(weight_bounds(2), (-2.0, 1.0));
        assert_eq!(weight_bounds(4), (-8.0, 7.0));
        assert_eq!(weight_bounds(8), (-128.0, 127.0));
        assert_eq!(act_bounds(4, false), (0.0, 15.0));
        assert_eq!(act_bounds(4, true), (-8.0, 7.0));
    }

    #[test]
    fn rect_sigmoid_inverse_roundtrip() {
        for h in [0.05f32, 0.3, 0.5, 0.7, 0.95] {
            let v = rect_sigmoid_inv(h);
            assert!((rect_sigmoid(v) - h).abs() < 1e-5, "h={h}");
        }
    }

    #[test]
    fn mse_step_beats_naive_maxabs() {
        let mut rng = Rng::new(0);
        let w = randn(&mut rng, vec![1, 512], 1.0);
        let (n, p) = weight_bounds(4);
        let s_opt = mse_steps_per_channel(&w, 4)[0];
        let s_naive = w.data.iter().fold(0f32, |m, &x| m.max(x.abs())) / p;
        let err = |s: f32| -> f64 {
            w.data
                .iter()
                .map(|&x| {
                    let d = x - round_quant(x, s, n, p);
                    (d as f64) * (d as f64)
                })
                .sum()
        };
        assert!(err(s_opt) <= err(s_naive) * 1.0001);
    }

    #[test]
    fn adaround_init_is_identity_like() {
        // with h(v)=frac, soft-quantized weight == FP weight (within the
        // clip range)
        let mut rng = Rng::new(1);
        let w = randn(&mut rng, vec![4, 32], 0.5);
        let steps = mse_steps_per_channel(&w, 8);
        let st = AdaRoundState::init(&w, &steps, 8);
        let inner = w.inner();
        for ch in 0..4 {
            let s = steps[ch];
            for i in ch * inner..(ch + 1) * inner {
                let g = (w.data[i] / s).floor();
                if g <= -128.0 || g >= 126.0 {
                    continue; // MSE-optimal steps clip the extreme tail
                }
                let soft = s
                    * (g + rect_sigmoid(st.v.data[i])).clamp(-128.0, 127.0);
                assert!(
                    (soft - w.data[i]).abs() < s * 0.05,
                    "soft {soft} vs {}",
                    w.data[i]
                );
            }
        }
    }

    #[test]
    fn commit_rounds_to_grid() {
        let mut rng = Rng::new(2);
        let w = randn(&mut rng, vec![3, 16], 0.3);
        let steps = mse_steps_per_channel(&w, 2);
        let st = AdaRoundState::init(&w, &steps, 2);
        let q = st.commit(&w);
        let inner = w.inner();
        for ch in 0..3 {
            for i in ch * inner..(ch + 1) * inner {
                let g = q.data[i] / steps[ch];
                assert!((g - g.round()).abs() < 1e-4);
                assert!((-2.0..=1.0).contains(&g.round()));
            }
        }
    }

    #[test]
    fn commit_within_one_step_of_nearest() {
        // AdaRound can differ from nearest rounding by at most one grid step
        let mut rng = Rng::new(3);
        let w = randn(&mut rng, vec![2, 64], 0.4);
        let steps = mse_steps_per_channel(&w, 4);
        let st = AdaRoundState::init(&w, &steps, 4);
        let q = st.commit(&w);
        let nearest = quantize_nearest(&w, &steps, 4);
        let inner = w.inner();
        for ch in 0..2 {
            for i in ch * inner..(ch + 1) * inner {
                assert!(
                    (q.data[i] - nearest.data[i]).abs()
                        <= steps[ch] * 1.0001
                );
            }
        }
    }

    #[test]
    fn quantize_nearest_2bit_has_4_levels() {
        let mut rng = Rng::new(4);
        let w = randn(&mut rng, vec![1, 256], 1.0);
        let steps = mse_steps_per_channel(&w, 2);
        let q = quantize_nearest(&w, &steps, 2);
        let mut levels: Vec<i32> =
            q.data.iter().map(|&x| (x / steps[0]).round() as i32).collect();
        levels.sort_unstable();
        levels.dedup();
        assert!(levels.len() <= 4, "{levels:?}");
    }

    #[test]
    fn act_step_positive() {
        let mut rng = Rng::new(5);
        let xs: Vec<f32> =
            (0..1000).map(|_| (rng.gauss() as f32).abs()).collect();
        let s = act_step_init(&xs, 4, false);
        assert!(s > 0.0 && s < 1.0, "{s}");
    }
}
