//! Mixed-precision search (paper §3.4, Algorithm 2).
//!
//! Genetic algorithm over per-layer weight bit assignments c ∈ {2,4,8}^n:
//! fitness is the sensitivity-LUT-predicted loss (diagonal + intra-block
//! off-diagonal terms), subject to a hardware constraint H(c) ≤ δ evaluated
//! by one of the `hwsim` measurement functions. First and last layers stay
//! pinned at 8-bit (the paper's deployment policy).
//!
//! A ZeroQ-style Pareto-greedy searcher is included as the baseline the
//! paper compares against conceptually (integer-programming/Pareto methods
//! that ignore the off-diagonal terms).
//!
//! Callers reach this through [`crate::pipeline`]: a `JobSpec` with
//! `search: Some(HwBudget { .. })` runs the GA as the `MpSearch` stage
//! (over the session-cached sensitivity LUT), and
//! `Session::mp_search` exposes the stage standalone for the CLI.

use anyhow::Result;

use crate::hwsim::HwMeasure;
use crate::model::ModelInfo;
use crate::sensitivity::SensitivityTable;
use crate::util::pool;
use crate::util::rng::Rng;

pub const BIT_CHOICES: [usize; 3] = [2, 4, 8];

#[derive(Debug, Clone)]
pub struct GaConfig {
    pub population: usize,
    pub iters: usize,
    pub mutate_p: f64,
    pub topk: usize,
    pub seed: u64,
}

impl Default for GaConfig {
    fn default() -> Self {
        // paper B.4.4: population 50, 100 iterations, mutation 0.1
        GaConfig { population: 50, iters: 100, mutate_p: 0.1, topk: 10,
                   seed: 0 }
    }
}

#[derive(Debug, Clone)]
pub struct SearchResult {
    pub wbits: Vec<usize>,
    pub predicted_loss: f64,
    pub hw_cost: f64,
    pub evaluated: usize,
    pub seconds: f64,
}

/// Free (searchable) layer indices: everything but first/last.
fn free_layers(model: &ModelInfo) -> Vec<usize> {
    (0..model.layers.len())
        .filter(|&l| l != model.first_layer() && l != model.last_layer())
        .collect()
}

fn assemble(model: &ModelInfo, genes: &[usize]) -> Vec<usize> {
    let free = free_layers(model);
    let mut w = vec![8usize; model.layers.len()];
    for (g, &l) in genes.iter().zip(&free) {
        w[l] = *g;
    }
    w
}

pub struct GeneticSearch<'a> {
    pub model: &'a ModelInfo,
    pub table: &'a SensitivityTable,
    pub hw: &'a dyn HwMeasure,
    pub abits: usize,
    pub budget: f64,
}

impl<'a> GeneticSearch<'a> {
    fn feasible(&self, genes: &[usize]) -> bool {
        let w = assemble(self.model, genes);
        self.hw.measure(self.model, &w, self.abits) <= self.budget
    }

    fn fitness(&self, genes: &[usize]) -> f64 {
        self.table.predict(&assemble(self.model, genes))
    }

    /// Fitness of every individual, evaluated concurrently on the worker
    /// pool (LUT predictions are independent pure functions; results come
    /// back in population order, so the search stays deterministic). The
    /// work estimate keeps toy populations inline — fan-out only pays off
    /// once population x layer count is large enough.
    fn eval_population(&self, pop: &[Vec<usize>]) -> Vec<f64> {
        let per = self.model.layers.len() * (1 + self.table.offdiag.len());
        let work = pop.len().saturating_mul(per * 64);
        pool::par_fill(pop.len(), 4, work, |i| self.fitness(&pop[i]))
    }

    /// Algorithm 2. Returns the best feasible assignment found.
    pub fn run(&self, cfg: &GaConfig) -> Result<SearchResult> {
        let t0 = std::time::Instant::now();
        let mut rng = Rng::new(cfg.seed);
        let ng = free_layers(self.model).len();
        let mut evaluated = 0usize;

        // init: random population, rejection-sampled to feasibility
        // (paper: Gaussian init rounded to {2,4,8}; uniform is equivalent
        // after rounding at our gene count)
        let mut pop: Vec<Vec<usize>> = Vec::new();
        let mut guard = 0;
        while pop.len() < cfg.population && guard < cfg.population * 200 {
            guard += 1;
            let cand: Vec<usize> =
                (0..ng).map(|_| BIT_CHOICES[rng.below(3)]).collect();
            if self.feasible(&cand) {
                pop.push(cand);
            }
        }
        if pop.is_empty() {
            // budget below the all-2-bit floor
            let floor: Vec<usize> = vec![2; ng];
            anyhow::ensure!(
                self.feasible(&floor),
                "hardware budget {} infeasible even at all-2-bit",
                self.budget
            );
            pop.push(floor);
        }

        let mut topk: Vec<(f64, Vec<usize>)> = Vec::new();
        for _t in 0..cfg.iters {
            // evaluate fitness concurrently, update TopK in order
            let fits = self.eval_population(&pop);
            for (ind, f) in pop.iter().zip(fits) {
                evaluated += 1;
                if !topk.iter().any(|(_, g)| g == ind) {
                    topk.push((f, ind.clone()));
                }
            }
            topk.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            topk.truncate(cfg.topk);

            // crossover half
            let mut crossover = Vec::new();
            let mut guard = 0;
            while crossover.len() < cfg.population / 2
                && guard < cfg.population * 100
            {
                guard += 1;
                let a = &topk[rng.below(topk.len())].1;
                let b = &topk[rng.below(topk.len())].1;
                let child: Vec<usize> = (0..ng)
                    .map(|i| if rng.f64() < 0.5 { a[i] } else { b[i] })
                    .collect();
                if self.feasible(&child) {
                    crossover.push(child);
                }
            }
            // mutation half
            let mut mutate = Vec::new();
            let mut guard = 0;
            while mutate.len() < cfg.population / 2
                && guard < cfg.population * 100
            {
                guard += 1;
                let mut child = topk[rng.below(topk.len())].1.clone();
                for g in child.iter_mut() {
                    if rng.f64() < cfg.mutate_p {
                        *g = BIT_CHOICES[rng.below(3)];
                    }
                }
                if self.feasible(&child) {
                    mutate.push(child);
                }
            }
            pop = crossover;
            pop.append(&mut mutate);
            if pop.is_empty() {
                pop.push(topk[0].1.clone());
            }
        }
        let fits = self.eval_population(&pop);
        for (ind, f) in pop.iter().zip(fits) {
            evaluated += 1;
            if !topk.iter().any(|(_, g)| g == ind) {
                topk.push((f, ind.clone()));
            }
        }
        topk.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let best = &topk[0];
        let wbits = assemble(self.model, &best.1);
        Ok(SearchResult {
            hw_cost: self.hw.measure(self.model, &wbits, self.abits),
            wbits,
            predicted_loss: best.0,
            evaluated,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// ZeroQ-style Pareto-greedy baseline: start all-8, repeatedly drop the
    /// precision of the layer with the best (sensitivity increase)/(bytes
    /// saved) ratio until H(c) ≤ δ. Ignores off-diagonal terms — the
    /// comparison the paper draws.
    pub fn pareto_greedy(&self) -> Result<SearchResult> {
        let t0 = std::time::Instant::now();
        let free = free_layers(self.model);
        let mut wbits = vec![8usize; self.model.layers.len()];
        let mut evaluated = 0usize;
        loop {
            let cost = self.hw.measure(self.model, &wbits, self.abits);
            if cost <= self.budget {
                break;
            }
            // candidate single-step reductions 8->4->2
            let mut best: Option<(f64, usize, usize)> = None;
            for &l in &free {
                let next = match wbits[l] {
                    8 => 4,
                    4 => 2,
                    _ => continue,
                };
                let mut trial = wbits.clone();
                trial[l] = next;
                evaluated += 1;
                let dloss = (self.table.diag[l].get(&next).copied()
                    .unwrap_or(0.0)
                    - self.table.diag[l].get(&wbits[l]).copied()
                        .unwrap_or(0.0))
                .max(1e-9);
                let saved = (cost
                    - self.hw.measure(self.model, &trial, self.abits))
                .max(1e-12);
                let ratio = dloss / saved;
                if best.map_or(true, |(r, _, _)| ratio < r) {
                    best = Some((ratio, l, next));
                }
            }
            match best {
                Some((_, l, next)) => wbits[l] = next,
                None => anyhow::bail!(
                    "pareto: budget {} infeasible at all-2-bit",
                    self.budget
                ),
            }
        }
        Ok(SearchResult {
            hw_cost: self.hw.measure(self.model, &wbits, self.abits),
            predicted_loss: self.table.predict(&wbits),
            wbits,
            evaluated,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// AdaQuant-style integer-programming relaxation: minimize predicted
    /// loss subject to H(c) <= budget, treating layers as independent
    /// (diagonal terms only) and solving by exhaustive per-layer greedy
    /// exchange to a local optimum. Unlike `pareto_greedy` it starts from
    /// the all-2-bit floor and *buys back* precision with the best
    /// loss-reduction-per-cost ratio — the standard knapsack heuristic.
    pub fn integer_programming(&self) -> Result<SearchResult> {
        let t0 = std::time::Instant::now();
        let free = free_layers(self.model);
        let mut wbits = vec![8usize; self.model.layers.len()];
        for &l in &free {
            wbits[l] = 2;
        }
        anyhow::ensure!(
            self.hw.measure(self.model, &wbits, self.abits) <= self.budget,
            "IP: budget {} infeasible at all-2-bit",
            self.budget
        );
        let mut evaluated = 0usize;
        loop {
            let cost = self.hw.measure(self.model, &wbits, self.abits);
            let mut best: Option<(f64, usize, usize)> = None;
            for &l in &free {
                let next = match wbits[l] {
                    2 => 4,
                    4 => 8,
                    _ => continue,
                };
                let mut trial = wbits.clone();
                trial[l] = next;
                evaluated += 1;
                if self.hw.measure(self.model, &trial, self.abits)
                    > self.budget
                {
                    continue;
                }
                let gain = (self.table.diag[l]
                    .get(&wbits[l])
                    .copied()
                    .unwrap_or(0.0)
                    - self.table.diag[l].get(&next).copied().unwrap_or(0.0))
                .max(0.0);
                let dcost = (self
                    .hw
                    .measure(self.model, &trial, self.abits)
                    - cost)
                    .max(1e-12);
                let ratio = gain / dcost;
                if best.map_or(true, |(r, _, _)| ratio > r) {
                    best = Some((ratio, l, next));
                }
            }
            match best {
                Some((r, l, next)) if r > 0.0 => wbits[l] = next,
                Some((_, l, next)) => {
                    // no loss gain left but budget remains: still raise
                    // precision (free accuracy headroom)
                    wbits[l] = next;
                }
                None => break,
            }
        }
        Ok(SearchResult {
            hw_cost: self.hw.measure(self.model, &wbits, self.abits),
            predicted_loss: self.table.predict(&wbits),
            wbits,
            evaluated,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::ModelSize;
    use crate::model::LayerInfo;
    use std::collections::HashMap;

    fn layer(name: &str, nw: usize) -> LayerInfo {
        LayerInfo {
            name: name.into(),
            kind: "conv".into(),
            cin: 1,
            cout: 1,
            k: 1,
            stride: 1,
            groups: 1,
            relu: true,
            site_signed: false,
            h_in: 8,
            w_in: 8,
            macs: 64,
            nparams: nw as u64,
            wshape: vec![1, nw],
        }
    }

    fn model(nlayers: usize) -> ModelInfo {
        ModelInfo {
            name: "toy".into(),
            fp_acc: 1.0,
            weights_prefix: String::new(),
            layers: (0..nlayers)
                .map(|i| layer(&format!("l{i}"), 1000))
                .collect(),
            fwd_exe: String::new(),
            act_obs_exe: String::new(),
            eval_batch: 1,
            grans: Default::default(),
            qat_exe: None,
            qat_batch: 0,
            distill_exe: None,
            distill_batch: 0,
            task: crate::model::Task::Classify,
            dataset: None,
            det: None,
        }
    }

    fn table(nlayers: usize, hot: usize) -> SensitivityTable {
        // layer `hot` is very sensitive; others cheap
        let diag = (0..nlayers)
            .map(|l| {
                let mut m = HashMap::new();
                let scale = if l == hot { 10.0 } else { 0.1 };
                m.insert(2, scale);
                m.insert(4, scale * 0.1);
                m
            })
            .collect();
        SensitivityTable { diag, offdiag: HashMap::new(), base_loss: 1.0 }
    }

    #[test]
    fn ga_respects_budget_and_avoids_hot_layer() {
        let m = model(8);
        let t = table(8, 3);
        let size = ModelSize;
        // budget: roughly half of all-8-bit
        let full = size.measure(&m, &vec![8; 8], 8);
        let ga = GeneticSearch {
            model: &m,
            table: &t,
            hw: &size,
            abits: 8,
            budget: full * 0.55,
        };
        let r = ga.run(&GaConfig { iters: 40, ..Default::default() })
            .unwrap();
        assert!(r.hw_cost <= full * 0.55);
        // the hot layer should keep higher precision than the coldest ones
        let hot_bits = r.wbits[3];
        let cold_bits: Vec<usize> = (1..7).filter(|&l| l != 3)
            .map(|l| r.wbits[l]).collect();
        assert!(
            hot_bits >= *cold_bits.iter().min().unwrap(),
            "hot {hot_bits} cold {cold_bits:?}"
        );
        // pinned first/last
        assert_eq!(r.wbits[0], 8);
        assert_eq!(r.wbits[7], 8);
    }

    #[test]
    fn ga_better_or_equal_pareto_with_offdiag() {
        // off-diagonal term makes layers 1&2 bad together: GA (which sees
        // it) must be no worse than the greedy (which ignores it)
        let m = model(6);
        let mut t = table(6, 100); // no single hot layer
        t.offdiag.insert((1, 2), 5.0);
        let size = ModelSize;
        let full = size.measure(&m, &vec![8; 6], 8);
        // budget must stay above the floor set by pinned-8-bit first/last
        let ga = GeneticSearch {
            model: &m,
            table: &t,
            hw: &size,
            abits: 8,
            budget: full * 0.55,
        };
        let g = ga.run(&GaConfig { iters: 60, seed: 3, ..Default::default() })
            .unwrap();
        let p = ga.pareto_greedy().unwrap();
        assert!(g.predicted_loss <= p.predicted_loss + 1e-9);
        assert!(p.hw_cost <= full * 0.55);
    }

    #[test]
    fn ip_respects_budget_and_buys_back_cold_layers() {
        let m = model(8);
        let t = table(8, 3);
        let size = ModelSize;
        let full = size.measure(&m, &vec![8; 8], 8);
        let ga = GeneticSearch {
            model: &m,
            table: &t,
            hw: &size,
            abits: 8,
            budget: full * 0.6,
        };
        let r = ga.integer_programming().unwrap();
        assert!(r.hw_cost <= full * 0.6);
        // hot layer 3 gets precision priority over the cold free layers
        assert!(r.wbits[3] >= *r.wbits[1..7].iter().min().unwrap());
        assert_eq!(r.wbits[0], 8);
        assert_eq!(r.wbits[7], 8);
    }

    #[test]
    fn infeasible_budget_errors() {
        let m = model(4);
        let t = table(4, 0);
        let size = ModelSize;
        let ga = GeneticSearch {
            model: &m,
            table: &t,
            hw: &size,
            abits: 8,
            budget: 1.0, // bytes: impossible
        };
        assert!(ga.run(&GaConfig::default()).is_err());
        assert!(ga.pareto_greedy().is_err());
        assert!(ga.integer_programming().is_err());
    }
}
