//! PTQ baselines the paper compares against (Tables 2 & 3).
//!
//! * `omse`            — OMSE (Choukroun et al. 2019): per-channel
//!                       MSE-optimal steps, nearest rounding, no data.
//! * `bias_correction` — DFQ-style (Nagel et al. 2019): nearest rounding
//!                       plus empirical per-channel output-mean correction
//!                       measured on calibration data. We correct at
//!                       (layer-granularity) unit outputs — a faithful
//!                       empirical variant of the analytic BN-based rule.
//! * `adaround_layer`  — AdaRound (Nagel et al. 2020): layer-by-layer
//!                       reconstruction, plain MSE objective (H = cI),
//!                       rounding regularizer on. Implemented as the BRECQ
//!                       engine at `gran=layer, use_fim=false`.
//! * `adaquant_like`   — AdaQuant (Hubara et al. 2020) proxy: layer-wise
//!                       MSE reconstruction with *unregularized* continuous
//!                       rounding variables (committed by thresholding).
//!                       Like AdaQuant's unconstrained weight learning, the
//!                       relaxation is benign at 4-bit and collapses at
//!                       2-bit.
//! * `zeroq_nodata`    — ZeroQ (Cai et al. 2020) proxy: no real data at
//!                       all; weights by nearest rounding, activation steps
//!                       calibrated on BN-distilled data (see distill.rs).
//!
//! All baselines share the quantizer substrate (per-channel symmetric,
//! first/last-8-bit policy) so the comparison isolates the *objective*, as
//! in the paper.

use anyhow::Result;

use crate::calib::CalibSet;
use crate::model::{Manifest, ModelInfo};
use crate::quant::{mse_steps_per_channel, quantize_nearest};
use crate::recon::{BitConfig, Calibrator, QuantizedModel, ReconConfig};
use crate::runtime::Backend;
use crate::tensor::Tensor;

/// OMSE: data-free nearest rounding with MSE-optimal per-channel steps.
/// When `bits.aq` is set, activation steps come from calibration stats.
pub fn omse(
    rt: &dyn Backend,
    mf: &Manifest,
    model: &ModelInfo,
    calib: &CalibSet,
    bits: &BitConfig,
) -> Result<QuantizedModel> {
    let t0 = std::time::Instant::now();
    let cal = Calibrator::new(rt, mf, model);
    let (ws, bs) = cal.fp_weights()?;
    let weights: Vec<Tensor> = ws
        .iter()
        .enumerate()
        .map(|(l, w)| {
            let steps = mse_steps_per_channel(w, bits.wbits[l]);
            quantize_nearest(w, &steps, bits.wbits[l])
        })
        .collect();
    let act_steps = if bits.aq {
        cal.init_act_steps(calib, &ws, &bs, bits, 4)?
    } else {
        vec![1.0; ws.len()]
    };
    Ok(QuantizedModel {
        weights,
        biases: bs,
        act_steps,
        bits: bits.clone(),
        reports: vec![],
        calib_seconds: t0.elapsed().as_secs_f64(),
    })
}

/// DFQ-style bias correction: nearest-rounded weights, then walk the
/// layer-granularity units correcting each unit's final-layer bias by the
/// per-channel mean output shift (quantized stream vs FP stream).
pub fn bias_correction(
    rt: &dyn Backend,
    mf: &Manifest,
    model: &ModelInfo,
    calib: &CalibSet,
    bits: &BitConfig,
) -> Result<QuantizedModel> {
    let t0 = std::time::Instant::now();
    let cal = Calibrator::new(rt, mf, model);
    let (ws, bs) = cal.fp_weights()?;
    let qweights: Vec<Tensor> = ws
        .iter()
        .enumerate()
        .map(|(l, w)| {
            let steps = mse_steps_per_channel(w, bits.wbits[l]);
            quantize_nearest(w, &steps, bits.wbits[l])
        })
        .collect();
    let mut biases = bs.clone();
    let act_steps = vec![1.0; ws.len()];
    let nobits = BitConfig::uniform(model, 8, None, false); // acts FP here

    // same validated lookup as calibrate/fim_pass: a model that does
    // not export layer granularity is a typed error, not a panic
    let gran = model.try_gran("layer")?;
    let mut fp_main = calib.images.clone();
    let mut q_main = calib.images.clone();
    let mut fp_skip: Option<Tensor> = None;
    let mut q_skip: Option<Tensor> = None;

    for unit in &gran.units {
        if unit.save_skip {
            fp_skip = Some(fp_main.clone());
            q_skip = Some(q_main.clone());
        }
        let z_fp = cal.advance(
            unit, &fp_main, fp_skip.as_ref(), &ws, &bs, &act_steps, &nobits,
            false,
        )?;
        let z_q = cal.advance(
            unit, &q_main, q_skip.as_ref(), &qweights, &biases, &act_steps,
            &nobits, false,
        )?;
        // per-channel mean shift at the unit output -> correct the bias of
        // the unit's *first owned* layer output channelwise.
        // unit outputs are (K, C, H, W) or (K, C)
        let c = z_fp.shape[1];
        let inner: usize = z_fp.shape[2..].iter().product::<usize>().max(1);
        let k = z_fp.shape[0];
        let mut delta = vec![0f64; c];
        for i in 0..k {
            for ch in 0..c {
                let off = (i * c + ch) * inner;
                for j in 0..inner {
                    delta[ch] +=
                        (z_q.data[off + j] - z_fp.data[off + j]) as f64;
                }
            }
        }
        let scale = 1.0 / (k * inner) as f64;
        // the layer whose cout matches the unit output owns the correction
        if let Some(&lid) = unit
            .layer_ids
            .iter()
            .find(|&&l| model.layers[l].cout == c)
        {
            for ch in 0..c {
                biases[lid].data[ch] -= (delta[ch] * scale) as f32;
            }
        }
        // advance with corrected biases
        let q_next = cal.advance(
            unit, &q_main, q_skip.as_ref(), &qweights, &biases, &act_steps,
            &nobits, false,
        )?;
        fp_main = z_fp;
        q_main = q_next;
        if unit.uses_skip {
            fp_skip = None;
            q_skip = None;
        }
    }

    let act_steps = if bits.aq {
        cal.init_act_steps(calib, &ws, &bs, bits, 4)?
    } else {
        act_steps
    };
    Ok(QuantizedModel {
        weights: qweights,
        biases,
        act_steps,
        bits: bits.clone(),
        reports: vec![],
        calib_seconds: t0.elapsed().as_secs_f64(),
    })
}

/// AdaRound baseline: layer-wise reconstruction, MSE objective.
pub fn adaround_layer_cfg(base: &ReconConfig) -> ReconConfig {
    ReconConfig {
        gran: "layer".into(),
        use_fim: false,
        round_reg: true,
        ..base.clone()
    }
}

/// AdaQuant-like baseline: layer-wise MSE, no rounding regularization.
pub fn adaquant_like_cfg(base: &ReconConfig) -> ReconConfig {
    ReconConfig {
        gran: "layer".into(),
        use_fim: false,
        round_reg: false,
        ..base.clone()
    }
}

/// BRECQ at an arbitrary granularity (Table 1 ablation runs this four ways).
pub fn brecq_cfg(base: &ReconConfig, gran: &str) -> ReconConfig {
    ReconConfig { gran: gran.into(), use_fim: true, round_reg: true,
                  ..base.clone() }
}
