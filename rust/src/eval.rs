//! Model evaluation through the AOT `eval_fwd` executable: top-1 accuracy
//! and cross-entropy loss over arbitrary (weights, act-steps, flag)
//! configurations — FP reference, hard-quantized, or mixed precision.

use anyhow::Result;

use crate::calib::{CalibSet, DataSet};
use crate::model::{Manifest, ModelInfo};
use crate::quant::act_bounds;
use crate::recon::{BitConfig, QuantizedModel};
use crate::runtime::Backend;
use crate::tensor::Tensor;

/// Full eval-forward parameterization.
pub struct EvalParams<'t> {
    pub weights: &'t [Tensor],
    pub biases: &'t [Tensor],
    pub act_steps: Vec<f32>,
    pub bits: BitConfig,
    pub aq: bool,
}

impl<'t> EvalParams<'t> {
    pub fn fp(model: &ModelInfo, ws: &'t [Tensor], bs: &'t [Tensor]) -> Self {
        EvalParams {
            weights: ws,
            biases: bs,
            act_steps: vec![1.0; model.layers.len()],
            bits: BitConfig::uniform(model, 8, None, false),
            aq: false,
        }
    }

    pub fn quantized(qm: &'t QuantizedModel) -> Self {
        EvalParams {
            weights: &qm.weights,
            biases: &qm.biases,
            act_steps: qm.act_steps.clone(),
            bits: qm.bits.clone(),
            aq: qm.bits.aq,
        }
    }
}

/// Logits for `images` (must match the eval batch size of the model).
pub fn forward(
    rt: &dyn Backend,
    model: &ModelInfo,
    p: &EvalParams,
    images: &Tensor,
) -> Result<Tensor> {
    let nl = model.layers.len();
    let flag = Tensor::scalar1(if p.aq { 1.0 } else { 0.0 });
    let mut scalars = Vec::with_capacity(nl);
    for (l, layer) in model.layers.iter().enumerate() {
        let (lo, hi) = act_bounds(p.bits.abits[l], layer.site_signed);
        scalars.push((
            Tensor::scalar1(p.act_steps[l]),
            Tensor::scalar1(lo),
            Tensor::scalar1(hi),
        ));
    }
    let mut args: Vec<&Tensor> = vec![images];
    for l in 0..nl {
        args.push(&p.weights[l]);
        args.push(&p.biases[l]);
    }
    for (st, lo, hi) in &scalars {
        args.push(st);
        args.push(lo);
        args.push(hi);
    }
    args.push(&flag);
    let mut out = rt.run(&model.fwd_exe, &args)?;
    Ok(out.remove(0))
}

/// Top-1 accuracy over a dataset (handles the trailing partial batch by
/// padding with wraparound rows and masking them out of the count).
pub fn accuracy(
    rt: &dyn Backend,
    model: &ModelInfo,
    p: &EvalParams,
    data: &DataSet,
) -> Result<f64> {
    let b = model.eval_batch;
    let n = data.len();
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut start = 0;
    while start < n {
        let take = b.min(n - start);
        let images = if take == b {
            data.batch(start, b)
        } else {
            // pad by wrapping (cyclically); padded rows are ignored below
            let mut parts = vec![data.batch(start, take)];
            let mut have = take;
            while have < b {
                let chunk = (b - have).min(n);
                parts.push(data.batch(0, chunk));
                have += chunk;
            }
            Tensor::stack0(&parts)
        };
        let logits = forward(rt, model, p, &images)?;
        let pred = logits.argmax_rows();
        for i in 0..take {
            if pred[i] == data.labels[start + i] {
                correct += 1;
            }
        }
        seen += take;
        start += take;
    }
    Ok(correct as f64 / seen as f64)
}

/// Mean cross-entropy over a calibration set (sensitivity fitness signal).
pub fn calib_loss(
    rt: &dyn Backend,
    mf: &Manifest,
    model: &ModelInfo,
    p: &EvalParams,
    calib: &CalibSet,
) -> Result<f64> {
    let b = model.eval_batch;
    let n = calib.len();
    let classes = mf.dataset.classes;
    let mut total = 0.0f64;
    let mut seen = 0usize;
    let mut start = 0;
    while start + b <= n {
        let images = calib.batch(start, b);
        let logits = forward(rt, model, p, &images)?;
        for i in 0..b {
            let row = &logits.data[i * classes..(i + 1) * classes];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let lse: f32 =
                row.iter().map(|&x| (x - m).exp()).sum::<f32>().ln() + m;
            total += (lse - row[calib.labels[start + i]]) as f64;
            seen += 1;
        }
        start += b;
    }
    // trailing partial chunk (calib 1024 with eval batch 200): wrap-pad,
    // tiling the set cyclically when it is smaller than the pad
    if start < n {
        let take = n - start;
        let mut parts = vec![calib.batch(start, take)];
        let mut have = take;
        while have < b {
            let chunk = (b - have).min(n);
            parts.push(calib.batch(0, chunk));
            have += chunk;
        }
        let images = Tensor::stack0(&parts);
        let logits = forward(rt, model, p, &images)?;
        for i in 0..take {
            let row = &logits.data[i * classes..(i + 1) * classes];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let lse: f32 =
                row.iter().map(|&x| (x - m).exp()).sum::<f32>().ln() + m;
            total += (lse - row[calib.labels[start + i]]) as f64;
            seen += 1;
        }
    }
    Ok(total / seen as f64)
}
