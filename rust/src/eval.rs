//! Model evaluation through the AOT `eval_fwd` executable: top-1 accuracy
//! and cross-entropy loss over arbitrary (weights, act-steps, flag)
//! configurations — FP reference, hard-quantized, or mixed precision —
//! plus the detection family's mAP path ([`det_map`] / [`map_score`]):
//! IoU-matched average precision over the manifest's seeded box targets,
//! computed serially in f64 after the batched forward so the score is
//! bit-identical at any `BRECQ_THREADS`.

use anyhow::Result;

use crate::calib::{CalibSet, DataSet};
use crate::model::{DetInfo, Manifest, ModelInfo};
use crate::quant::act_bounds;
use crate::recon::{BitConfig, QuantizedModel};
use crate::runtime::Backend;
use crate::tensor::Tensor;

/// Full eval-forward parameterization.
pub struct EvalParams<'t> {
    pub weights: &'t [Tensor],
    pub biases: &'t [Tensor],
    pub act_steps: Vec<f32>,
    pub bits: BitConfig,
    pub aq: bool,
}

impl<'t> EvalParams<'t> {
    pub fn fp(model: &ModelInfo, ws: &'t [Tensor], bs: &'t [Tensor]) -> Self {
        EvalParams {
            weights: ws,
            biases: bs,
            act_steps: vec![1.0; model.layers.len()],
            bits: BitConfig::uniform(model, 8, None, false),
            aq: false,
        }
    }

    pub fn quantized(qm: &'t QuantizedModel) -> Self {
        EvalParams {
            weights: &qm.weights,
            biases: &qm.biases,
            act_steps: qm.act_steps.clone(),
            bits: qm.bits.clone(),
            aq: qm.bits.aq,
        }
    }
}

/// Logits for `images` (must match the eval batch size of the model).
pub fn forward(
    rt: &dyn Backend,
    model: &ModelInfo,
    p: &EvalParams,
    images: &Tensor,
) -> Result<Tensor> {
    let nl = model.layers.len();
    let flag = Tensor::scalar1(if p.aq { 1.0 } else { 0.0 });
    let mut scalars = Vec::with_capacity(nl);
    for (l, layer) in model.layers.iter().enumerate() {
        let (lo, hi) = act_bounds(p.bits.abits[l], layer.site_signed);
        scalars.push((
            Tensor::scalar1(p.act_steps[l]),
            Tensor::scalar1(lo),
            Tensor::scalar1(hi),
        ));
    }
    let mut args: Vec<&Tensor> = vec![images];
    for l in 0..nl {
        args.push(&p.weights[l]);
        args.push(&p.biases[l]);
    }
    for (st, lo, hi) in &scalars {
        args.push(st);
        args.push(lo);
        args.push(hi);
    }
    args.push(&flag);
    let mut out = rt.run(&model.fwd_exe, &args)?;
    Ok(out.remove(0))
}

/// Top-1 accuracy over a dataset (handles the trailing partial batch by
/// padding with wraparound rows and masking them out of the count).
pub fn accuracy(
    rt: &dyn Backend,
    model: &ModelInfo,
    p: &EvalParams,
    data: &DataSet,
) -> Result<f64> {
    let b = model.eval_batch;
    let n = data.len();
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut start = 0;
    while start < n {
        let take = b.min(n - start);
        let images = if take == b {
            data.batch(start, b)
        } else {
            // pad by wrapping (cyclically); padded rows are ignored below
            let mut parts = vec![data.batch(start, take)];
            let mut have = take;
            while have < b {
                let chunk = (b - have).min(n);
                parts.push(data.batch(0, chunk));
                have += chunk;
            }
            Tensor::stack0(&parts)
        };
        let logits = forward(rt, model, p, &images)?;
        let pred = logits.argmax_rows();
        for i in 0..take {
            if pred[i] == data.labels[start + i] {
                correct += 1;
            }
        }
        seen += take;
        start += take;
    }
    Ok(correct as f64 / seen as f64)
}

/// Intersection-over-union of two `[cx, cy, w, h]` boxes.
fn iou(a: [f64; 4], b: [f64; 4]) -> f64 {
    let half = |v: [f64; 4]| {
        (v[0] - v[2] / 2.0, v[0] + v[2] / 2.0, v[1] - v[3] / 2.0, v[1] + v[3] / 2.0)
    };
    let (ax0, ax1, ay0, ay1) = half(a);
    let (bx0, bx1, by0, by1) = half(b);
    let iw = (ax1.min(bx1) - ax0.max(bx0)).max(0.0);
    let ih = (ay1.min(by1) - ay0.max(by0)).max(0.0);
    let inter = iw * ih;
    let union = a[2] * a[3] + b[2] * b[3] - inter;
    if union <= 0.0 {
        0.0
    } else {
        inter / union
    }
}

/// All-points average precision from a ranked TP/FP sequence.
fn ap_from_ranked(hits: &[bool], npos: usize) -> f64 {
    if npos == 0 {
        return 0.0;
    }
    // precision envelope: walk the ranking once, summing precision at
    // each recall step, then take the running-max (right-to-left) form
    let mut precs: Vec<f64> = Vec::with_capacity(hits.len());
    let mut tp = 0usize;
    for (i, &h) in hits.iter().enumerate() {
        if h {
            tp += 1;
        }
        precs.push(tp as f64 / (i + 1) as f64);
    }
    // monotone envelope from the right
    for i in (0..precs.len().saturating_sub(1)).rev() {
        precs[i] = precs[i].max(precs[i + 1]);
    }
    let mut ap = 0.0;
    for (i, &h) in hits.iter().enumerate() {
        if h {
            ap += precs[i];
        }
    }
    ap / npos as f64
}

/// mAP over a logits batch: every anchor of every sample is a prediction
/// (decoded box, objectness score); ground truth is the labeled scene's
/// seeded objects. AP is computed per IoU threshold in {0.5, 0.75} with
/// a global objectness ranking (ties broken by (sample, anchor) so the
/// ordering is total) and greedy per-sample matching, then averaged.
/// Pure, serial, f64 — bit-identical for bit-identical logits.
pub fn det_map(det: &DetInfo, lg: &Tensor, labels: &[usize]) -> f64 {
    det_map_nms(det, lg, labels, false)
}

/// [`det_map`] with optional greedy non-maximum suppression: walking the
/// same total-order ranking, a prediction is dropped when its IoU with
/// any higher-ranked *kept* prediction of the same sample exceeds 0.5.
/// Deterministic (the ranking's (sample, anchor) tie-break is total) and
/// applied before matching, so duplicate boxes stop outranking other
/// objects' true matches. Default off — table5 baselines are NMS-free.
pub fn det_map_nms(
    det: &DetInfo,
    lg: &Tensor,
    labels: &[usize],
    nms: bool,
) -> f64 {
    let d = det.head_dim();
    let na = det.anchors.len();
    let n = labels.len();
    // ranked predictions: (score, sample, anchor, box)
    let mut preds: Vec<(f64, usize, usize, [f64; 4])> =
        Vec::with_capacity(n * na);
    for i in 0..n {
        let row = &lg.data[i * d..(i + 1) * d];
        for a in 0..na {
            preds.push((row[a * 5 + 4] as f64, i, a, det.decode(row, a)));
        }
    }
    preds.sort_by(|x, y| {
        y.0.partial_cmp(&x.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(x.1.cmp(&y.1))
            .then(x.2.cmp(&y.2))
    });
    if nms {
        let mut kept: Vec<Vec<[f64; 4]>> = vec![Vec::new(); n];
        preds.retain(|&(_, i, _, pb)| {
            if kept[i].iter().any(|&kb| iou(pb, kb) > 0.5) {
                return false;
            }
            kept[i].push(pb);
            true
        });
    }
    let npos: usize = labels.iter().map(|&l| det.scenes[l].len()).sum();

    let mut map = 0.0;
    let thresholds = [0.5, 0.75];
    for &thr in &thresholds {
        let mut used: Vec<Vec<bool>> =
            labels.iter().map(|&l| vec![false; det.scenes[l].len()]).collect();
        let mut hits = Vec::with_capacity(preds.len());
        for &(_, i, _, pb) in &preds {
            let gts = &det.scenes[labels[i]];
            let mut best: Option<(f64, usize)> = None;
            for (gi, o) in gts.iter().enumerate() {
                if used[i][gi] {
                    continue;
                }
                let v = iou(pb, o.bbox);
                if v >= thr && best.map_or(true, |(bv, _)| v > bv) {
                    best = Some((v, gi));
                }
            }
            match best {
                Some((_, gi)) => {
                    used[i][gi] = true;
                    hits.push(true);
                }
                None => hits.push(false),
            }
        }
        map += ap_from_ranked(&hits, npos);
    }
    map / thresholds.len() as f64
}

/// mAP over a dataset through the AOT forward (the detection analogue of
/// [`accuracy`]): batches like `accuracy` does, wrap-padding the trailing
/// partial batch, then scores the concatenated logits serially. `nms`
/// enables greedy suppression (see [`det_map_nms`]); off reproduces the
/// table5 baselines exactly.
pub fn map_score(
    rt: &dyn Backend,
    model: &ModelInfo,
    det: &DetInfo,
    p: &EvalParams,
    data: &DataSet,
    nms: bool,
) -> Result<f64> {
    let b = model.eval_batch;
    let n = data.len();
    let d = det.head_dim();
    let mut all = Vec::with_capacity(n * d);
    let mut start = 0;
    while start < n {
        let take = b.min(n - start);
        let images = if take == b {
            data.batch(start, b)
        } else {
            let mut parts = vec![data.batch(start, take)];
            let mut have = take;
            while have < b {
                let chunk = (b - have).min(n);
                parts.push(data.batch(0, chunk));
                have += chunk;
            }
            Tensor::stack0(&parts)
        };
        let logits = forward(rt, model, p, &images)?;
        all.extend_from_slice(&logits.data[..take * d]);
        start += take;
    }
    let lg = Tensor::new(vec![n, d], all);
    Ok(det_map_nms(det, &lg, &data.labels, nms))
}

/// Mean cross-entropy over a calibration set (sensitivity fitness signal).
pub fn calib_loss(
    rt: &dyn Backend,
    mf: &Manifest,
    model: &ModelInfo,
    p: &EvalParams,
    calib: &CalibSet,
) -> Result<f64> {
    let b = model.eval_batch;
    let n = calib.len();
    let classes = mf.dataset_for(model).classes;
    let mut total = 0.0f64;
    let mut seen = 0usize;
    let mut start = 0;
    while start + b <= n {
        let images = calib.batch(start, b);
        let logits = forward(rt, model, p, &images)?;
        for i in 0..b {
            let row = &logits.data[i * classes..(i + 1) * classes];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let lse: f32 =
                row.iter().map(|&x| (x - m).exp()).sum::<f32>().ln() + m;
            total += (lse - row[calib.labels[start + i]]) as f64;
            seen += 1;
        }
        start += b;
    }
    // trailing partial chunk (calib 1024 with eval batch 200): wrap-pad,
    // tiling the set cyclically when it is smaller than the pad
    if start < n {
        let take = n - start;
        let mut parts = vec![calib.batch(start, take)];
        let mut have = take;
        while have < b {
            let chunk = (b - have).min(n);
            parts.push(calib.batch(0, chunk));
            have += chunk;
        }
        let images = Tensor::stack0(&parts);
        let logits = forward(rt, model, p, &images)?;
        for i in 0..take {
            let row = &logits.data[i * classes..(i + 1) * classes];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let lse: f32 =
                row.iter().map(|&x| (x - m).exp()).sum::<f32>().ln() + m;
            total += (lse - row[calib.labels[start + i]]) as f64;
            seen += 1;
        }
    }
    Ok(total / seen as f64)
}
