//! `brecq serve` — a local quantization-as-a-service daemon over a unix
//! socket, plus the thin `brecq submit` client.
//!
//! Protocol: newline-delimited JSON, one document per line, both ways.
//! Client requests:
//!
//! ```text
//!   {"op":"submit", "priority": 0, "jobs": [<JobSpec>, ...]}
//!   {"op":"ping"} | {"op":"stats"} | {"op":"shutdown"}
//! ```
//!
//! Daemon events (streamed while a batch runs; `job` indexes into the
//! submitted array):
//!
//! ```text
//!   {"event":"accepted", "jobs": N}
//!   {"event":"stage", "job": i, "stage": "reconstruct", "done": false}
//!   {"event":"cache", "job": i, "key": "fp/resnet_s",
//!    "outcome": "hit|store-hit|computed|loaded"}
//!   {"event":"result", "job": i, "ok": true, "output": {...}}
//!   {"event":"result", "job": i, "ok": false, "error": "..."}
//!   {"event":"done", "ok": N, "failed": N, "computes": N,
//!    "cache_hits": N, "store_hits": N}
//! ```
//!
//! Scheduling: jobs queue with a per-batch priority and run on a fixed
//! set of daemon workers (each job still fans its kernels out on
//! [`crate::util::pool`], whose regions are per-call and safe to enter
//! from several workers at once). The queue picks the highest-priority
//! job, breaking ties *fair-share*: the connection that has been served
//! the fewest jobs goes first, then FIFO by submission order — so one
//! client dumping 100 jobs cannot starve another's single job at equal
//! priority.
//!
//! Results are deterministic by construction — every job runs through
//! the same [`Session`] cache/store machinery as `brecq run`, so a
//! submitted batch is bit-identical (per [`super::JobOutput::fingerprint`]) to
//! an in-process run of the same specs; `scripts/serve_smoke.sh` gates
//! that in CI. Shutdown (SIGINT/SIGTERM or `{"op":"shutdown"}`) stops
//! accepting connections, drains queued jobs, flushes each batch's
//! `done` event and removes the socket file.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::util::json::{self, Json};

use super::cache::Outcome;
use super::job::{JobEvent, Session};
use super::{Error, JobSpec};

/// How often blocked loops (accept, reads, queue waits) re-check stop.
const POLL: Duration = Duration::from_millis(25);

// ---------------------------------------------------------------------
// Signal handling (daemon entry point only)
// ---------------------------------------------------------------------

mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static STOP: AtomicBool = AtomicBool::new(false);

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn handle(_sig: i32) {
        STOP.store(true, Ordering::SeqCst);
    }

    /// Route SIGINT (2) and SIGTERM (15) to the stop flag.
    #[allow(clippy::fn_to_numeric_cast)]
    pub fn install() {
        unsafe {
            signal(2, handle as usize);
            signal(15, handle as usize);
        }
    }
}

// ---------------------------------------------------------------------
// Daemon internals
// ---------------------------------------------------------------------

/// Per-batch bookkeeping shared by the queue entries of one submit.
struct Batch {
    conn: u64,
    writer: Arc<Mutex<UnixStream>>,
    remaining: AtomicUsize,
    ok: AtomicUsize,
    failed: AtomicUsize,
    computes: AtomicUsize,
    cache_hits: AtomicUsize,
    store_hits: AtomicUsize,
}

struct Queued {
    /// Global submission order (the FIFO tie-break).
    seq: u64,
    priority: i64,
    /// Index into the batch's submitted jobs array.
    job: usize,
    spec: JobSpec,
    batch: Arc<Batch>,
}

struct Shared {
    session: Session,
    queue: Mutex<Vec<Queued>>,
    cv: Condvar,
    /// Jobs served so far per connection (the fair-share signal).
    served: Mutex<HashMap<u64, u64>>,
    stop: AtomicBool,
}

/// Serialize `v` onto one protocol line. Write failures are ignored —
/// a vanished client must not kill its jobs (their artifacts persist).
fn write_line(w: &Mutex<UnixStream>, v: &Json) {
    let mut line = v.to_string();
    line.push('\n');
    let mut s = w.lock().unwrap_or_else(|e| e.into_inner());
    let _ = s.write_all(line.as_bytes());
}

fn event(kind: &str, mut fields: Vec<(&str, Json)>) -> Json {
    fields.insert(0, ("event", json::s(kind)));
    json::obj(fields)
}

impl Shared {
    /// Highest priority first; ties go to the connection served least,
    /// then FIFO. Returns the queue index to take.
    fn pick(&self, q: &[Queued]) -> Option<usize> {
        let served =
            self.served.lock().unwrap_or_else(|e| e.into_inner());
        q.iter()
            .enumerate()
            .max_by_key(|(_, t)| {
                let s = served.get(&t.batch.conn).copied().unwrap_or(0);
                (t.priority, std::cmp::Reverse(s),
                 std::cmp::Reverse(t.seq))
            })
            .map(|(i, _)| i)
    }

    fn worker(&self) {
        loop {
            let task = {
                let mut q = self
                    .queue
                    .lock()
                    .unwrap_or_else(|e| e.into_inner());
                loop {
                    if let Some(i) = self.pick(&q) {
                        break Some(q.remove(i));
                    }
                    if self.stop.load(Ordering::SeqCst) {
                        break None;
                    }
                    q = self
                        .cv
                        .wait_timeout(q, POLL)
                        .unwrap_or_else(|e| e.into_inner())
                        .0;
                }
            };
            let Some(t) = task else { return };
            *self
                .served
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .entry(t.batch.conn)
                .or_insert(0) += 1;
            self.run_one(&t);
            if t.batch.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
                let b = &t.batch;
                write_line(
                    &b.writer,
                    &event("done", vec![
                        ("ok", json::num(
                            b.ok.load(Ordering::SeqCst) as f64)),
                        ("failed", json::num(
                            b.failed.load(Ordering::SeqCst) as f64)),
                        ("computes", json::num(
                            b.computes.load(Ordering::SeqCst) as f64)),
                        ("cache_hits", json::num(
                            b.cache_hits.load(Ordering::SeqCst) as f64)),
                        ("store_hits", json::num(
                            b.store_hits.load(Ordering::SeqCst) as f64)),
                    ]),
                );
            }
        }
    }

    fn run_one(&self, t: &Queued) {
        let b = &t.batch;
        let ji = json::num(t.job as f64);
        let mut emit = |e: JobEvent| match e {
            JobEvent::Stage { stage, done } => write_line(
                &b.writer,
                &event("stage", vec![
                    ("job", ji.clone()),
                    ("stage", json::s(stage)),
                    ("done", json::b(done)),
                ]),
            ),
            JobEvent::Cache { key, outcome } => {
                let ctr = match outcome {
                    Outcome::Hit => &b.cache_hits,
                    Outcome::StoreHit => &b.store_hits,
                    Outcome::Computed => &b.computes,
                    Outcome::Loaded => &b.cache_hits,
                };
                if outcome != Outcome::Loaded {
                    ctr.fetch_add(1, Ordering::SeqCst);
                }
                write_line(
                    &b.writer,
                    &event("cache", vec![
                        ("job", ji.clone()),
                        ("key", json::s(&key)),
                        ("outcome", json::s(outcome.as_str())),
                    ]),
                );
            }
        };
        match self.session.run_traced(&t.spec, &mut emit) {
            Ok(out) => {
                b.ok.fetch_add(1, Ordering::SeqCst);
                write_line(
                    &b.writer,
                    &event("result", vec![
                        ("job", ji.clone()),
                        ("ok", json::b(true)),
                        ("output", out.to_json()),
                    ]),
                );
            }
            Err(e) => {
                b.failed.fetch_add(1, Ordering::SeqCst);
                write_line(
                    &b.writer,
                    &event("result", vec![
                        ("job", ji.clone()),
                        ("ok", json::b(false)),
                        ("error", json::s(&e.to_string())),
                    ]),
                );
            }
        }
    }

    fn handle_request(
        &self,
        line: &str,
        conn: u64,
        writer: &Arc<Mutex<UnixStream>>,
    ) {
        let reply_err = |msg: &str| {
            write_line(
                writer,
                &event("error", vec![("error", json::s(msg))]),
            );
        };
        let v = match Json::parse(line) {
            Ok(v) => v,
            Err(e) => return reply_err(&format!("bad request: {e}")),
        };
        match v.get("op").and_then(Json::as_str) {
            Some("ping") => {
                write_line(writer, &event("pong", vec![]));
            }
            Some("stats") => {
                let (hits, misses) = self.session.cache().stats();
                let mut fields = vec![
                    ("cache_hits", json::num(hits as f64)),
                    ("cache_misses", json::num(misses as f64)),
                    (
                        "computes",
                        json::num(self.session.cache().computes() as f64),
                    ),
                ];
                if let Some(st) = self.session.cache().store() {
                    let s = st.stats();
                    fields.push(
                        ("store_hits", json::num(s.hits as f64)));
                    fields.push(
                        ("store_misses", json::num(s.misses as f64)));
                    fields.push(
                        ("store_corrupt", json::num(s.corrupt as f64)));
                    fields.push((
                        "store_publishes",
                        json::num(s.publishes as f64),
                    ));
                }
                write_line(writer, &event("stats", fields));
            }
            Some("shutdown") => {
                write_line(writer, &event("shutting-down", vec![]));
                self.stop.store(true, Ordering::SeqCst);
                self.cv.notify_all();
            }
            Some("submit") => {
                let priority = v
                    .get("priority")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0) as i64;
                let jobs = match v.get("jobs").and_then(Json::as_arr) {
                    Some(a) => a,
                    None => {
                        return reply_err(
                            "submit needs a 'jobs' array",
                        )
                    }
                };
                let mut specs = Vec::with_capacity(jobs.len());
                for (i, j) in jobs.iter().enumerate() {
                    match JobSpec::from_json(j) {
                        Ok(s) => specs.push(s),
                        Err(e) => {
                            return reply_err(&format!(
                                "job {i}: {e}"
                            ))
                        }
                    }
                }
                write_line(
                    writer,
                    &event("accepted", vec![
                        ("jobs", json::num(specs.len() as f64)),
                    ]),
                );
                if specs.is_empty() {
                    write_line(
                        writer,
                        &event("done", vec![
                            ("ok", json::num(0.0)),
                            ("failed", json::num(0.0)),
                            ("computes", json::num(0.0)),
                            ("cache_hits", json::num(0.0)),
                            ("store_hits", json::num(0.0)),
                        ]),
                    );
                    return;
                }
                let batch = Arc::new(Batch {
                    conn,
                    writer: writer.clone(),
                    remaining: AtomicUsize::new(specs.len()),
                    ok: AtomicUsize::new(0),
                    failed: AtomicUsize::new(0),
                    computes: AtomicUsize::new(0),
                    cache_hits: AtomicUsize::new(0),
                    store_hits: AtomicUsize::new(0),
                });
                let mut q = self
                    .queue
                    .lock()
                    .unwrap_or_else(|e| e.into_inner());
                for (i, spec) in specs.into_iter().enumerate() {
                    // the conn counter doubles as the global seq source:
                    // seq only orders within one lock hold anyway
                    let seq = (conn << 32) | i as u64;
                    q.push(Queued {
                        seq,
                        priority,
                        job: i,
                        spec,
                        batch: batch.clone(),
                    });
                }
                drop(q);
                self.cv.notify_all();
            }
            _ => reply_err("unknown op (submit|ping|stats|shutdown)"),
        }
    }

    /// Read requests off one client connection until it closes or the
    /// daemon stops. Partial lines survive read timeouts (the buffer
    /// accumulates across retries).
    fn handle_conn(&self, stream: UnixStream, conn: u64) {
        let _ = stream.set_read_timeout(Some(POLL));
        let writer = match stream.try_clone() {
            Ok(w) => Arc::new(Mutex::new(w)),
            Err(_) => return,
        };
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return;
            }
            match reader.read_line(&mut line) {
                Ok(0) => return, // client closed
                Ok(_) => {
                    let req = line.trim().to_string();
                    line.clear();
                    if !req.is_empty() {
                        self.handle_request(&req, conn, &writer);
                    }
                }
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock
                        || e.kind() == ErrorKind::TimedOut
                        || e.kind() == ErrorKind::Interrupted => {}
                Err(_) => return,
            }
        }
    }
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

/// Run the daemon on `socket` until SIGINT/SIGTERM or a client
/// `shutdown` op. `workers` concurrent job slots (0 = pool size).
pub fn serve(
    session: Session,
    socket: &Path,
    workers: usize,
) -> Result<(), Error> {
    sig::install();
    serve_until(session, socket, workers, || {
        sig::STOP.load(Ordering::SeqCst)
    })
}

/// Test/embedding variant: same daemon, no signal handlers. Stop it
/// with [`control`]`(sock, "shutdown")` and join the thread.
pub fn spawn(
    session: Session,
    socket: PathBuf,
    workers: usize,
) -> std::thread::JoinHandle<Result<(), Error>> {
    std::thread::spawn(move || {
        serve_until(session, &socket, workers, || false)
    })
}

fn serve_until(
    session: Session,
    socket: &Path,
    workers: usize,
    external_stop: impl Fn() -> bool,
) -> Result<(), Error> {
    let workers = if workers == 0 {
        crate::util::pool::threads()
    } else {
        workers
    };
    // a stale socket file from a dead daemon would make bind fail
    let _ = std::fs::remove_file(socket);
    let listener = UnixListener::bind(socket).map_err(|e| {
        Error::Exec(format!("binding {}: {e}", socket.display()))
    })?;
    listener.set_nonblocking(true).map_err(|e| {
        Error::Exec(format!("nonblocking listener: {e}"))
    })?;
    eprintln!(
        "[serve] listening on {} ({workers} workers)",
        socket.display()
    );
    let shared = Shared {
        session,
        queue: Mutex::new(Vec::new()),
        cv: Condvar::new(),
        served: Mutex::new(HashMap::new()),
        stop: AtomicBool::new(false),
    };
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| shared.worker());
        }
        let mut conn_id = 0u64;
        loop {
            if external_stop() {
                shared.stop.store(true, Ordering::SeqCst);
            }
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    conn_id += 1;
                    let id = conn_id;
                    let sh = &shared;
                    s.spawn(move || sh.handle_conn(stream, id));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(POLL);
                }
                Err(e) => {
                    eprintln!("[serve] accept: {e}");
                    std::thread::sleep(POLL);
                }
            }
        }
        // drain: workers exit once the queue is empty and stop is set;
        // conn threads notice stop on their next read timeout
        shared.cv.notify_all();
    });
    let _ = std::fs::remove_file(socket);
    eprintln!("[serve] shut down cleanly");
    Ok(())
}

// ---------------------------------------------------------------------
// Clients
// ---------------------------------------------------------------------

/// What [`submit`] brings back: per-job results in submission order and
/// the batch-level `done` accounting event.
pub struct SubmitSummary {
    /// One entry per submitted job: the `output` object on success, the
    /// error text on failure.
    pub results: Vec<Result<Json, String>>,
    /// The terminal `done` event (ok/failed/computes/cache_hits/
    /// store_hits counters for this batch).
    pub done: Json,
}

/// Submit `specs` to a daemon on `socket` and stream events until the
/// batch finishes. `on_event` sees every raw protocol event (stage,
/// cache, result, ...) as it arrives.
pub fn submit(
    socket: &Path,
    specs: &[JobSpec],
    priority: i64,
    mut on_event: impl FnMut(&Json),
) -> Result<SubmitSummary, Error> {
    let stream = UnixStream::connect(socket).map_err(|e| {
        Error::Exec(format!(
            "connecting to daemon at {}: {e}",
            socket.display()
        ))
    })?;
    let mut writer = stream.try_clone().map_err(|e| {
        Error::Exec(format!("cloning daemon socket: {e}"))
    })?;
    let req = json::obj(vec![
        ("op", json::s("submit")),
        ("priority", json::num(priority as f64)),
        (
            "jobs",
            Json::Arr(specs.iter().map(JobSpec::to_json).collect()),
        ),
    ]);
    let mut line = req.to_string();
    line.push('\n');
    writer.write_all(line.as_bytes()).map_err(|e| {
        Error::Exec(format!("sending submit request: {e}"))
    })?;

    let mut results: Vec<Option<Result<Json, String>>> =
        (0..specs.len()).map(|_| None).collect();
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line.map_err(|e| {
            Error::Exec(format!("reading daemon event: {e}"))
        })?;
        if line.trim().is_empty() {
            continue;
        }
        let ev = Json::parse(&line).map_err(|e| {
            Error::Exec(format!("bad daemon event: {e}"))
        })?;
        on_event(&ev);
        match ev.get("event").and_then(Json::as_str) {
            Some("error") => {
                return Err(Error::Exec(format!(
                    "daemon rejected the batch: {}",
                    ev.get("error")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown error")
                )));
            }
            Some("result") => {
                let job = ev
                    .get("job")
                    .and_then(Json::as_usize)
                    .filter(|&j| j < results.len())
                    .ok_or_else(|| {
                        Error::Exec(
                            "result event with bad job index".into(),
                        )
                    })?;
                let ok = ev
                    .get("ok")
                    .and_then(Json::as_bool)
                    .unwrap_or(false);
                results[job] = Some(if ok {
                    Ok(ev.get("output").cloned().unwrap_or(Json::Null))
                } else {
                    Err(ev
                        .get("error")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown job error")
                        .to_string())
                });
            }
            Some("done") => {
                let results = results
                    .into_iter()
                    .enumerate()
                    .map(|(i, r)| {
                        r.unwrap_or_else(|| {
                            Err(format!("job {i}: no result received"))
                        })
                    })
                    .collect();
                return Ok(SubmitSummary { done: ev, results });
            }
            _ => {}
        }
    }
    Err(Error::Exec(
        "daemon closed the connection before the batch finished".into(),
    ))
}

/// One-shot control request (`ping` / `stats` / `shutdown`); returns the
/// daemon's reply event.
pub fn control(socket: &Path, op: &str) -> Result<Json, Error> {
    let stream = UnixStream::connect(socket).map_err(|e| {
        Error::Exec(format!(
            "connecting to daemon at {}: {e}",
            socket.display()
        ))
    })?;
    let mut writer = stream.try_clone().map_err(|e| {
        Error::Exec(format!("cloning daemon socket: {e}"))
    })?;
    let mut line = json::obj(vec![("op", json::s(op))]).to_string();
    line.push('\n');
    writer.write_all(line.as_bytes()).map_err(|e| {
        Error::Exec(format!("sending '{op}': {e}"))
    })?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply).map_err(|e| {
        Error::Exec(format!("reading '{op}' reply: {e}"))
    })?;
    Json::parse(reply.trim())
        .map_err(|e| Error::Exec(format!("bad '{op}' reply: {e}")))
}
