//! `brecq serve` — a local quantization-as-a-service daemon over a unix
//! socket, plus the thin `brecq submit` client.
//!
//! Protocol: newline-delimited JSON, one document per line, both ways.
//! Client requests:
//!
//! ```text
//!   {"op":"submit", "priority": 0, "jobs": [<JobSpec>, ...]}
//!   {"op":"cancel", "batch": N}
//!   {"op":"ping"} | {"op":"stats"} | {"op":"shutdown"}
//! ```
//!
//! Daemon events (streamed while a batch runs; `job` indexes into the
//! submitted array, `batch` is the daemon-assigned batch id that
//! `{"op":"cancel"}` takes):
//!
//! ```text
//!   {"event":"accepted", "batch": N, "jobs": N}
//!   {"event":"stage", "job": i, "stage": "reconstruct", "done": false}
//!   {"event":"cache", "job": i, "key": "fp/resnet_s",
//!    "outcome": "hit|store-hit|computed|resumed|loaded"}
//!   {"event":"result", "job": i, "ok": true, "output": {...}}
//!   {"event":"result", "job": i, "ok": false, "error": "..."}
//!   {"event":"cancelling", "batch": N, "queued_dropped": N}
//!   {"event":"done", "batch": N, "ok": N, "failed": N, "computes": N,
//!    "cache_hits": N, "store_hits": N, "units_resumed": N}
//! ```
//!
//! Scheduling: jobs queue with a per-batch priority and run on a fixed
//! set of daemon workers (each job still fans its kernels out on
//! [`crate::util::pool`], whose regions are per-call and safe to enter
//! from several workers at once). The queue picks the highest-priority
//! job, breaking ties *fair-share*: the connection that has been served
//! the fewest jobs goes first, then FIFO by submission order — so one
//! client dumping 100 jobs cannot starve another's single job at equal
//! priority.
//!
//! ## Crash safety
//!
//! Every accepted batch terminates with exactly one `done` event, no
//! matter how its jobs end:
//!
//! * Workers run jobs under `catch_unwind`, so a panicking job (a
//!   backend bug, or an injected `panic` fault from
//!   [`crate::util::faults`]) becomes a per-job
//!   `{"event":"result","ok":false,"error":"panic: ..."}` instead of
//!   killing the daemon, and the batch still completes.
//! * Jobs carry a cooperative [`crate::util::cancel::CancelToken`]:
//!   `{"op":"cancel","batch":N}` (or a spec's `deadline_ms`) stops them
//!   at the next stage/iteration boundary with a typed
//!   `job cancelled: ...` result; queued-but-unstarted siblings are
//!   dropped immediately.
//! * When the session has an artifact store, each in-flight batch is
//!   journalled to `<store>/journal/<pid>-<batch>.json` (written by
//!   tmp-file + rename, updated as jobs finish, removed on `done`). A
//!   daemon restarted over the same store finds journals whose owner
//!   pid is dead, claims them by rename, and re-runs the incomplete
//!   jobs before binding the socket — warm cache hits for anything the
//!   dead daemon had already published, so interrupted work is finished
//!   exactly once.
//! * Reconstruction itself is resumable at unit granularity: each
//!   completed Algorithm-1 unit publishes a checkpoint under the recon
//!   key's pinned `ckpt/` namespace, so a journal-recovered, cancelled,
//!   deadline-expired or killed job that is re-run replays its finished
//!   units bit-identically (`"outcome":"resumed"` cache events;
//!   `units_resumed` on `done` and in `stats`) instead of recomputing
//!   them. Checkpoints are removed once the final recon artifact
//!   publishes.
//!
//! Results are deterministic by construction — every job runs through
//! the same [`Session`] cache/store machinery as `brecq run`, so a
//! submitted batch is bit-identical (per [`super::JobOutput::fingerprint`]) to
//! an in-process run of the same specs; `scripts/serve_smoke.sh` and
//! `scripts/chaos_soak.sh` gate that in CI. Shutdown (SIGINT/SIGTERM or
//! `{"op":"shutdown"}`) stops accepting connections, drains queued
//! jobs, flushes each batch's `done` event and removes the socket file.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::cancel::CancelToken;
use crate::util::json::{self, Json};

use super::cache::Outcome;
use super::job::{JobEvent, Session};
use super::{Error, JobSpec};

/// How often blocked loops (accept, reads, queue waits) re-check stop.
const POLL: Duration = Duration::from_millis(25);

// ---------------------------------------------------------------------
// Signal handling (daemon entry point only)
// ---------------------------------------------------------------------

mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static STOP: AtomicBool = AtomicBool::new(false);

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn handle(_sig: i32) {
        STOP.store(true, Ordering::SeqCst);
    }

    /// Route SIGINT (2) and SIGTERM (15) to the stop flag.
    #[allow(clippy::fn_to_numeric_cast)]
    pub fn install() {
        unsafe {
            signal(2, handle as usize);
            signal(15, handle as usize);
        }
    }
}

/// `kill(pid, 0)` liveness probe: alive if the signal is deliverable
/// (ret 0) or we merely lack permission (EPERM); ESRCH means gone.
fn pid_alive(pid: i32) -> bool {
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    if unsafe { kill(pid, 0) } == 0 {
        return true;
    }
    std::io::Error::last_os_error().raw_os_error() == Some(1) // EPERM
}

// ---------------------------------------------------------------------
// Daemon internals
// ---------------------------------------------------------------------

/// Per-batch bookkeeping shared by the queue entries of one submit.
struct Batch {
    id: u64,
    conn: u64,
    writer: Arc<Mutex<UnixStream>>,
    /// Fires on `{"op":"cancel"}`; each job derives its deadline child
    /// from this, so one token stops the whole batch.
    cancel: CancelToken,
    /// Write-ahead journal file while the batch is in flight (only
    /// when the session has an artifact store).
    journal: Option<PathBuf>,
    specs: Vec<JobSpec>,
    /// Which jobs have reached a terminal result (journal payload).
    done_flags: Mutex<Vec<bool>>,
    remaining: AtomicUsize,
    ok: AtomicUsize,
    failed: AtomicUsize,
    computes: AtomicUsize,
    cache_hits: AtomicUsize,
    store_hits: AtomicUsize,
    /// Reconstruction units replayed from per-unit checkpoints instead
    /// of recomputed — the resume-progress signal for this batch.
    units_resumed: AtomicUsize,
}

struct Queued {
    /// Global submission order (the FIFO tie-break).
    seq: u64,
    priority: i64,
    /// Index into the batch's submitted jobs array.
    job: usize,
    spec: JobSpec,
    batch: Arc<Batch>,
}

struct Shared {
    session: Session,
    queue: Mutex<Vec<Queued>>,
    cv: Condvar,
    /// Jobs served so far per connection (the fair-share signal).
    served: Mutex<HashMap<u64, u64>>,
    /// Live batches by id — the `cancel` op's lookup table.
    batches: Mutex<HashMap<u64, Arc<Batch>>>,
    next_batch: AtomicU64,
    /// `<store>/journal` when the session persists artifacts.
    journal_dir: Option<PathBuf>,
    /// Jobs re-run from dead daemons' journals at startup.
    recovered: AtomicUsize,
    stop: AtomicBool,
}

/// Serialize `v` onto one protocol line. Write failures are ignored —
/// a vanished client must not kill its jobs (their artifacts persist).
fn write_line(w: &Mutex<UnixStream>, v: &Json) {
    let mut line = v.to_string();
    line.push('\n');
    let mut s = w.lock().unwrap_or_else(|e| e.into_inner());
    let _ = s.write_all(line.as_bytes());
}

fn event(kind: &str, mut fields: Vec<(&str, Json)>) -> Json {
    fields.insert(0, ("event", json::s(kind)));
    json::obj(fields)
}

/// Extract a human-readable message from a panic payload.
fn panic_msg(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

impl Batch {
    /// Persist the in-flight journal: tmp write + atomic rename, same
    /// commit discipline as the artifact store. Failures are logged,
    /// not fatal — the journal is a recovery aid, not a correctness
    /// dependency for the running daemon.
    fn write_journal(&self) {
        let Some(path) = &self.journal else { return };
        let done = self
            .done_flags
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|&d| json::b(d))
            .collect();
        let doc = json::obj(vec![
            ("v", json::num(1.0)),
            ("pid", json::num(std::process::id() as f64)),
            ("batch", json::num(self.id as f64)),
            ("done", Json::Arr(done)),
            (
                "jobs",
                Json::Arr(
                    self.specs.iter().map(JobSpec::to_json).collect(),
                ),
            ),
        ]);
        let tmp = path.with_extension("tmp");
        let write = std::fs::write(&tmp, doc.to_string())
            .and_then(|()| std::fs::rename(&tmp, path));
        if let Err(e) = write {
            eprintln!(
                "[serve] journal write {} failed: {e}",
                path.display()
            );
        }
    }

    /// Record job `i`'s terminal result in the journal.
    fn mark_done(&self, i: usize) {
        if self.journal.is_some() {
            self.done_flags
                .lock()
                .unwrap_or_else(|e| e.into_inner())[i] = true;
            self.write_journal();
        }
    }
}

impl Shared {
    /// Highest priority first; ties go to the connection served least,
    /// then FIFO. Returns the queue index to take.
    fn pick(&self, q: &[Queued]) -> Option<usize> {
        let served =
            self.served.lock().unwrap_or_else(|e| e.into_inner());
        q.iter()
            .enumerate()
            .max_by_key(|(_, t)| {
                let s = served.get(&t.batch.conn).copied().unwrap_or(0);
                (t.priority, std::cmp::Reverse(s),
                 std::cmp::Reverse(t.seq))
            })
            .map(|(i, _)| i)
    }

    fn worker(&self) {
        loop {
            let task = {
                let mut q = self
                    .queue
                    .lock()
                    .unwrap_or_else(|e| e.into_inner());
                loop {
                    if let Some(i) = self.pick(&q) {
                        break Some(q.remove(i));
                    }
                    if self.stop.load(Ordering::SeqCst) {
                        break None;
                    }
                    q = self
                        .cv
                        .wait_timeout(q, POLL)
                        .unwrap_or_else(|e| e.into_inner())
                        .0;
                }
            };
            let Some(t) = task else { return };
            *self
                .served
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .entry(t.batch.conn)
                .or_insert(0) += 1;
            // a batch cancelled while this job sat queued never starts
            if let Some(reason) = t.batch.cancel.cancelled() {
                self.report_failure(
                    &t,
                    &format!("job cancelled: {reason}"),
                );
            } else {
                self.run_one(&t);
            }
            self.finish_one(&t.batch);
        }
    }

    /// The single terminal accounting point: every queued job — run,
    /// panicked, cancelled, or dropped — must funnel through here
    /// exactly once so each accepted batch emits exactly one `done`.
    fn finish_one(&self, b: &Arc<Batch>) {
        if b.remaining.fetch_sub(1, Ordering::SeqCst) != 1 {
            return;
        }
        write_line(
            &b.writer,
            &event("done", vec![
                ("batch", json::num(b.id as f64)),
                ("ok", json::num(
                    b.ok.load(Ordering::SeqCst) as f64)),
                ("failed", json::num(
                    b.failed.load(Ordering::SeqCst) as f64)),
                ("computes", json::num(
                    b.computes.load(Ordering::SeqCst) as f64)),
                ("cache_hits", json::num(
                    b.cache_hits.load(Ordering::SeqCst) as f64)),
                ("store_hits", json::num(
                    b.store_hits.load(Ordering::SeqCst) as f64)),
                ("units_resumed", json::num(
                    b.units_resumed.load(Ordering::SeqCst) as f64)),
            ]),
        );
        if let Some(p) = &b.journal {
            let _ = std::fs::remove_file(p);
        }
        self.batches
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(&b.id);
    }

    /// Emit a failed `result` for job `t` and journal it.
    fn report_failure(&self, t: &Queued, msg: &str) {
        let b = &t.batch;
        b.failed.fetch_add(1, Ordering::SeqCst);
        write_line(
            &b.writer,
            &event("result", vec![
                ("job", json::num(t.job as f64)),
                ("ok", json::b(false)),
                ("error", json::s(msg)),
            ]),
        );
        b.mark_done(t.job);
    }

    fn run_one(&self, t: &Queued) {
        let b = &t.batch;
        let ji = json::num(t.job as f64);
        let mut emit = |e: JobEvent| match e {
            JobEvent::Stage { stage, done } => write_line(
                &b.writer,
                &event("stage", vec![
                    ("job", ji.clone()),
                    ("stage", json::s(stage)),
                    ("done", json::b(done)),
                ]),
            ),
            JobEvent::Cache { key, outcome } => {
                let ctr = match outcome {
                    Outcome::Hit => &b.cache_hits,
                    Outcome::StoreHit => &b.store_hits,
                    Outcome::Computed => &b.computes,
                    // one trace event per checkpoint-restored unit; a
                    // resumed unit is neither a cache hit nor a compute
                    Outcome::Resumed => &b.units_resumed,
                    Outcome::Loaded => &b.cache_hits,
                };
                if outcome != Outcome::Loaded {
                    ctr.fetch_add(1, Ordering::SeqCst);
                }
                write_line(
                    &b.writer,
                    &event("cache", vec![
                        ("job", ji.clone()),
                        ("key", json::s(&key)),
                        ("outcome", json::s(outcome.as_str())),
                    ]),
                );
            }
        };
        // catch_unwind so a panicking job is a per-job failure, not a
        // dead daemon: util::pool re-raises worker panics on the
        // calling thread at scope join, so this fence sees them too.
        let r = catch_unwind(AssertUnwindSafe(|| {
            self.session.run_with_cancel(&t.spec, &b.cancel, &mut emit)
        }));
        match r {
            Ok(Ok(out)) => {
                b.ok.fetch_add(1, Ordering::SeqCst);
                write_line(
                    &b.writer,
                    &event("result", vec![
                        ("job", ji.clone()),
                        ("ok", json::b(true)),
                        ("output", out.to_json()),
                    ]),
                );
                b.mark_done(t.job);
            }
            Ok(Err(e)) => self.report_failure(t, &e.to_string()),
            Err(payload) => self.report_failure(
                t,
                &format!("panic: {}", panic_msg(payload)),
            ),
        }
    }

    fn handle_request(
        &self,
        line: &str,
        conn: u64,
        writer: &Arc<Mutex<UnixStream>>,
    ) {
        let reply_err = |msg: &str| {
            write_line(
                writer,
                &event("error", vec![("error", json::s(msg))]),
            );
        };
        let v = match Json::parse(line) {
            Ok(v) => v,
            Err(e) => return reply_err(&format!("bad request: {e}")),
        };
        match v.get("op").and_then(Json::as_str) {
            Some("ping") => {
                write_line(writer, &event("pong", vec![]));
            }
            Some("stats") => {
                let (hits, misses) = self.session.cache().stats();
                let mut fields = vec![
                    ("cache_hits", json::num(hits as f64)),
                    ("cache_misses", json::num(misses as f64)),
                    (
                        "computes",
                        json::num(self.session.cache().computes() as f64),
                    ),
                    (
                        "journal_recovered",
                        json::num(
                            self.recovered.load(Ordering::SeqCst) as f64,
                        ),
                    ),
                    (
                        "units_resumed",
                        json::num(
                            self.session.cache().units_resumed() as f64,
                        ),
                    ),
                    (
                        "ckpt_written",
                        json::num(
                            self.session.cache().ckpt_written() as f64,
                        ),
                    ),
                    (
                        "ckpt_corrupt",
                        json::num(
                            self.session.cache().ckpt_corrupt() as f64,
                        ),
                    ),
                ];
                if let Some(st) = self.session.cache().store() {
                    let s = st.stats();
                    fields.push(
                        ("store_hits", json::num(s.hits as f64)));
                    fields.push(
                        ("store_misses", json::num(s.misses as f64)));
                    fields.push(
                        ("store_corrupt", json::num(s.corrupt as f64)));
                    fields.push((
                        "store_publishes",
                        json::num(s.publishes as f64),
                    ));
                    fields.push(
                        ("store_retried", json::num(s.retried as f64)));
                }
                write_line(writer, &event("stats", fields));
            }
            Some("shutdown") => {
                write_line(writer, &event("shutting-down", vec![]));
                self.stop.store(true, Ordering::SeqCst);
                self.cv.notify_all();
            }
            Some("cancel") => {
                let id = match v.get("batch").and_then(Json::as_f64) {
                    Some(n) if n >= 0.0 => n as u64,
                    _ => {
                        return reply_err(
                            "cancel needs a numeric 'batch' id",
                        )
                    }
                };
                let batch = self
                    .batches
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .get(&id)
                    .cloned();
                let Some(b) = batch else {
                    return reply_err(&format!(
                        "unknown batch {id} (already done?)"
                    ));
                };
                // running jobs observe this at their next checkpoint
                b.cancel.cancel("cancelled by ctl");
                // queued-but-unstarted jobs are dropped right now
                let pulled: Vec<Queued> = {
                    let mut q = self
                        .queue
                        .lock()
                        .unwrap_or_else(|e| e.into_inner());
                    let mut kept = Vec::with_capacity(q.len());
                    let mut pulled = Vec::new();
                    for t in q.drain(..) {
                        if t.batch.id == id {
                            pulled.push(t);
                        } else {
                            kept.push(t);
                        }
                    }
                    *q = kept;
                    pulled
                };
                write_line(
                    writer,
                    &event("cancelling", vec![
                        ("batch", json::num(id as f64)),
                        (
                            "queued_dropped",
                            json::num(pulled.len() as f64),
                        ),
                    ]),
                );
                for t in pulled {
                    self.report_failure(
                        &t,
                        "job cancelled: cancelled by ctl",
                    );
                    self.finish_one(&t.batch);
                }
            }
            Some("submit") => {
                let priority = v
                    .get("priority")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0) as i64;
                let jobs = match v.get("jobs").and_then(Json::as_arr) {
                    Some(a) => a,
                    None => {
                        return reply_err(
                            "submit needs a 'jobs' array",
                        )
                    }
                };
                let mut specs = Vec::with_capacity(jobs.len());
                for (i, j) in jobs.iter().enumerate() {
                    match JobSpec::from_json(j) {
                        Ok(s) => specs.push(s),
                        Err(e) => {
                            return reply_err(&format!(
                                "job {i}: {e}"
                            ))
                        }
                    }
                }
                let id =
                    self.next_batch.fetch_add(1, Ordering::SeqCst);
                write_line(
                    writer,
                    &event("accepted", vec![
                        ("batch", json::num(id as f64)),
                        ("jobs", json::num(specs.len() as f64)),
                    ]),
                );
                if specs.is_empty() {
                    write_line(
                        writer,
                        &event("done", vec![
                            ("batch", json::num(id as f64)),
                            ("ok", json::num(0.0)),
                            ("failed", json::num(0.0)),
                            ("computes", json::num(0.0)),
                            ("cache_hits", json::num(0.0)),
                            ("store_hits", json::num(0.0)),
                            ("units_resumed", json::num(0.0)),
                        ]),
                    );
                    return;
                }
                let n = specs.len();
                let journal = self.journal_dir.as_ref().map(|d| {
                    d.join(format!(
                        "{}-{id}.json",
                        std::process::id()
                    ))
                });
                let batch = Arc::new(Batch {
                    id,
                    conn,
                    writer: writer.clone(),
                    cancel: CancelToken::new(),
                    journal,
                    specs,
                    done_flags: Mutex::new(vec![false; n]),
                    remaining: AtomicUsize::new(n),
                    ok: AtomicUsize::new(0),
                    failed: AtomicUsize::new(0),
                    computes: AtomicUsize::new(0),
                    cache_hits: AtomicUsize::new(0),
                    store_hits: AtomicUsize::new(0),
                    units_resumed: AtomicUsize::new(0),
                });
                // journal before the first job can run: a crash after
                // this point leaves a record to recover from
                batch.write_journal();
                self.batches
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .insert(id, batch.clone());
                let mut q = self
                    .queue
                    .lock()
                    .unwrap_or_else(|e| e.into_inner());
                for (i, spec) in
                    batch.specs.iter().cloned().enumerate()
                {
                    // the conn counter doubles as the global seq source:
                    // seq only orders within one lock hold anyway
                    let seq = (conn << 32) | i as u64;
                    q.push(Queued {
                        seq,
                        priority,
                        job: i,
                        spec,
                        batch: batch.clone(),
                    });
                }
                drop(q);
                self.cv.notify_all();
            }
            _ => reply_err(
                "unknown op (submit|cancel|ping|stats|shutdown)",
            ),
        }
    }

    /// Read requests off one client connection until it closes or the
    /// daemon stops. Partial lines survive read timeouts (the buffer
    /// accumulates across retries). Queued batches outlive their
    /// connection: a client that vanishes mid-batch loses only the
    /// event stream — the jobs, the journal and the terminal `done`
    /// accounting all still happen.
    fn handle_conn(&self, stream: UnixStream, conn: u64) {
        let _ = stream.set_read_timeout(Some(POLL));
        let writer = match stream.try_clone() {
            Ok(w) => Arc::new(Mutex::new(w)),
            Err(_) => return,
        };
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return;
            }
            match reader.read_line(&mut line) {
                Ok(0) => return, // client closed
                Ok(_) => {
                    let req = line.trim().to_string();
                    line.clear();
                    if !req.is_empty() {
                        self.handle_request(&req, conn, &writer);
                    }
                }
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock
                        || e.kind() == ErrorKind::TimedOut
                        || e.kind() == ErrorKind::Interrupted => {}
                Err(_) => return,
            }
        }
    }

    /// Startup recovery: scan `<store>/journal` for batches abandoned
    /// by dead daemons, claim each by rename (two restarting daemons
    /// race safely — rename is atomic, the loser skips), and re-run
    /// the jobs that never reached a terminal result. Anything the
    /// dead daemon already published replays as a warm store hit;
    /// only genuinely unfinished work recomputes.
    fn recover_journals(&self) {
        let Some(dir) = &self.journal_dir else { return };
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(_) => return,
        };
        let mypid = std::process::id();
        for ent in entries.flatten() {
            let path = ent.path();
            if path.extension().and_then(|e| e.to_str())
                != Some("json")
            {
                continue;
            }
            let txt = match std::fs::read_to_string(&path) {
                Ok(t) => t,
                Err(_) => continue,
            };
            let v = match Json::parse(&txt) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!(
                        "[recover] unreadable journal {}: {e}",
                        path.display()
                    );
                    continue;
                }
            };
            let owner = v
                .get("pid")
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as i32;
            if owner > 0
                && owner != mypid as i32
                && pid_alive(owner)
            {
                continue; // a live daemon still owns this batch
            }
            let claimed = path
                .with_extension(format!("recovering.{mypid}"));
            if std::fs::rename(&path, &claimed).is_err() {
                continue; // another daemon claimed it first
            }
            let done: Vec<bool> = v
                .get("done")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .map(|x| x.as_bool().unwrap_or(false))
                        .collect()
                })
                .unwrap_or_default();
            let jobs = match v.get("jobs").and_then(Json::as_arr) {
                Some(a) => a.clone(),
                None => {
                    let _ = std::fs::remove_file(&claimed);
                    continue;
                }
            };
            let todo = jobs
                .iter()
                .enumerate()
                .filter(|(i, _)| !done.get(*i).copied().unwrap_or(false))
                .count();
            eprintln!(
                "[recover] claimed {} (dead pid {owner}): {todo} of {} jobs incomplete",
                path.display(),
                jobs.len()
            );
            for (i, j) in jobs.iter().enumerate() {
                if done.get(i).copied().unwrap_or(false) {
                    continue;
                }
                let spec = match JobSpec::from_json(j) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("[recover] job {i}: bad spec: {e}");
                        continue;
                    }
                };
                // catch_unwind: an armed fault plan must not kill a
                // recovering daemon before it even binds the socket
                let r = catch_unwind(AssertUnwindSafe(|| {
                    let mut emit = |e: JobEvent| {
                        if let JobEvent::Cache { key, outcome } = e {
                            if outcome == Outcome::Computed {
                                eprintln!("[recover] computed {key}");
                            }
                        }
                    };
                    self.session.run_with_cancel(
                        &spec,
                        &CancelToken::none(),
                        &mut emit,
                    )
                }));
                match r {
                    Ok(Ok(_)) => {
                        self.recovered
                            .fetch_add(1, Ordering::SeqCst);
                        eprintln!(
                            "[recover] job {i} ({}) finished",
                            spec.model
                        );
                    }
                    Ok(Err(e)) => {
                        eprintln!("[recover] job {i} failed: {e}")
                    }
                    Err(payload) => eprintln!(
                        "[recover] job {i} panicked: {}",
                        panic_msg(payload)
                    ),
                }
            }
            let _ = std::fs::remove_file(&claimed);
        }
    }
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

/// Run the daemon on `socket` until SIGINT/SIGTERM or a client
/// `shutdown` op. `workers` concurrent job slots (0 = pool size).
pub fn serve(
    session: Session,
    socket: &Path,
    workers: usize,
) -> Result<(), Error> {
    sig::install();
    serve_until(session, socket, workers, || {
        sig::STOP.load(Ordering::SeqCst)
    })
}

/// Test/embedding variant: same daemon, no signal handlers. Stop it
/// with [`control`]`(sock, "shutdown")` and join the thread.
pub fn spawn(
    session: Session,
    socket: PathBuf,
    workers: usize,
) -> std::thread::JoinHandle<Result<(), Error>> {
    std::thread::spawn(move || {
        serve_until(session, &socket, workers, || false)
    })
}

fn serve_until(
    session: Session,
    socket: &Path,
    workers: usize,
    external_stop: impl Fn() -> bool,
) -> Result<(), Error> {
    let workers = if workers == 0 {
        crate::util::pool::threads()
    } else {
        workers
    };
    let journal_dir = session.cache().store().map(|st| {
        let d = st.dir().join("journal");
        let _ = std::fs::create_dir_all(&d);
        d
    });
    let shared = Shared {
        session,
        queue: Mutex::new(Vec::new()),
        cv: Condvar::new(),
        served: Mutex::new(HashMap::new()),
        batches: Mutex::new(HashMap::new()),
        next_batch: AtomicU64::new(1),
        journal_dir,
        recovered: AtomicUsize::new(0),
        stop: AtomicBool::new(false),
    };
    // finish dead daemons' interrupted batches before taking new work
    shared.recover_journals();
    // a stale socket file from a dead daemon would make bind fail
    let _ = std::fs::remove_file(socket);
    let listener = UnixListener::bind(socket).map_err(|e| {
        Error::Exec(format!("binding {}: {e}", socket.display()))
    })?;
    listener.set_nonblocking(true).map_err(|e| {
        Error::Exec(format!("nonblocking listener: {e}"))
    })?;
    eprintln!(
        "[serve] listening on {} ({workers} workers)",
        socket.display()
    );
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| shared.worker());
        }
        let mut conn_id = 0u64;
        loop {
            if external_stop() {
                shared.stop.store(true, Ordering::SeqCst);
            }
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    conn_id += 1;
                    let id = conn_id;
                    let sh = &shared;
                    s.spawn(move || sh.handle_conn(stream, id));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(POLL);
                }
                Err(e) => {
                    eprintln!("[serve] accept: {e}");
                    std::thread::sleep(POLL);
                }
            }
        }
        // drain: workers exit once the queue is empty and stop is set;
        // conn threads notice stop on their next read timeout
        shared.cv.notify_all();
    });
    let _ = std::fs::remove_file(socket);
    eprintln!("[serve] shut down cleanly");
    Ok(())
}

// ---------------------------------------------------------------------
// Clients
// ---------------------------------------------------------------------

/// What [`submit`] brings back: per-job results in submission order and
/// the batch-level `done` accounting event.
pub struct SubmitSummary {
    /// One entry per submitted job: the `output` object on success, the
    /// error text on failure.
    pub results: Vec<Result<Json, String>>,
    /// The terminal `done` event (batch id plus ok/failed/computes/
    /// cache_hits/store_hits counters for this batch).
    pub done: Json,
}

/// Submit `specs` to a daemon on `socket` and stream events until the
/// batch finishes. `on_event` sees every raw protocol event (stage,
/// cache, result, ...) as it arrives — the `accepted` event carries
/// the batch id that `ctl cancel` takes.
///
/// `timeout` bounds the whole wait: `None` waits forever, `Some(d)`
/// returns a typed [`Error::Exec`] once `d` elapses without the batch
/// finishing. A daemon that dies mid-batch is detected as EOF on the
/// socket and reported distinctly from per-job failures — completed
/// artifacts persist in the store either way.
pub fn submit(
    socket: &Path,
    specs: &[JobSpec],
    priority: i64,
    timeout: Option<Duration>,
    mut on_event: impl FnMut(&Json),
) -> Result<SubmitSummary, Error> {
    let stream = UnixStream::connect(socket).map_err(|e| {
        Error::Exec(format!(
            "connecting to daemon at {}: {e}",
            socket.display()
        ))
    })?;
    // short read timeout so the timeout deadline is checked even while
    // the daemon is silent; partial lines accumulate across retries
    stream.set_read_timeout(Some(POLL)).map_err(|e| {
        Error::Exec(format!("setting socket timeout: {e}"))
    })?;
    let mut writer = stream.try_clone().map_err(|e| {
        Error::Exec(format!("cloning daemon socket: {e}"))
    })?;
    let req = json::obj(vec![
        ("op", json::s("submit")),
        ("priority", json::num(priority as f64)),
        (
            "jobs",
            Json::Arr(specs.iter().map(JobSpec::to_json).collect()),
        ),
    ]);
    let mut line = req.to_string();
    line.push('\n');
    writer.write_all(line.as_bytes()).map_err(|e| {
        Error::Exec(format!("sending submit request: {e}"))
    })?;

    let mut results: Vec<Option<Result<Json, String>>> =
        (0..specs.len()).map(|_| None).collect();
    let mut got = 0usize;
    // batch id from the `accepted` event — the cancel handle
    let mut batch_id: Option<u64> = None;
    let t0 = Instant::now();
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    loop {
        if let Some(d) = timeout {
            if t0.elapsed() > d {
                // Best-effort cancel so an abandoned batch stops
                // burning daemon workers. In-flight units have already
                // checkpointed, so a resubmit of the same specs resumes
                // from where the cancel landed rather than from zero.
                let cancelled = batch_id.is_some_and(|id| {
                    control_fields(
                        socket,
                        "cancel",
                        vec![("batch", json::num(id as f64))],
                    )
                    .is_ok()
                });
                return Err(Error::Exec(format!(
                    "timed out after {:.1}s with {got} of {} job \
                     results received — {}",
                    t0.elapsed().as_secs_f64(),
                    specs.len(),
                    if cancelled {
                        "sent 'ctl cancel'; finished units are \
                         checkpointed, resubmit to resume"
                    } else {
                        "the batch may still be running on the \
                         daemon (use 'brecq ctl cancel' to stop it)"
                    }
                )));
            }
        }
        let txt = match reader.read_line(&mut buf) {
            Ok(0) => {
                return Err(Error::Exec(format!(
                    "daemon closed the connection (EOF) after {got} \
                     of {} job results — the daemon likely crashed \
                     or was killed; completed artifacts persist in \
                     the store",
                    specs.len()
                )))
            }
            Ok(_) => {
                let t = buf.trim().to_string();
                buf.clear();
                t
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted =>
            {
                continue
            }
            Err(e) => {
                return Err(Error::Exec(format!(
                    "reading daemon event: {e}"
                )))
            }
        };
        if txt.is_empty() {
            continue;
        }
        let ev = Json::parse(&txt).map_err(|e| {
            Error::Exec(format!("bad daemon event: {e}"))
        })?;
        on_event(&ev);
        match ev.get("event").and_then(Json::as_str) {
            Some("accepted") => {
                batch_id = ev
                    .get("batch")
                    .and_then(Json::as_f64)
                    .map(|n| n as u64);
            }
            Some("error") => {
                return Err(Error::Exec(format!(
                    "daemon rejected the batch: {}",
                    ev.get("error")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown error")
                )));
            }
            Some("result") => {
                let job = ev
                    .get("job")
                    .and_then(Json::as_usize)
                    .filter(|&j| j < results.len())
                    .ok_or_else(|| {
                        Error::Exec(
                            "result event with bad job index".into(),
                        )
                    })?;
                let ok = ev
                    .get("ok")
                    .and_then(Json::as_bool)
                    .unwrap_or(false);
                got += 1;
                results[job] = Some(if ok {
                    Ok(ev.get("output").cloned().unwrap_or(Json::Null))
                } else {
                    Err(ev
                        .get("error")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown job error")
                        .to_string())
                });
            }
            Some("done") => {
                let results = results
                    .into_iter()
                    .enumerate()
                    .map(|(i, r)| {
                        r.unwrap_or_else(|| {
                            Err(format!("job {i}: no result received"))
                        })
                    })
                    .collect();
                return Ok(SubmitSummary { done: ev, results });
            }
            _ => {}
        }
    }
}

/// One-shot control request with extra request fields (the `cancel`
/// op's batch id); returns the daemon's reply event.
pub fn control_fields(
    socket: &Path,
    op: &str,
    extra: Vec<(&str, Json)>,
) -> Result<Json, Error> {
    let stream = UnixStream::connect(socket).map_err(|e| {
        Error::Exec(format!(
            "connecting to daemon at {}: {e}",
            socket.display()
        ))
    })?;
    let mut writer = stream.try_clone().map_err(|e| {
        Error::Exec(format!("cloning daemon socket: {e}"))
    })?;
    let mut fields = vec![("op", json::s(op))];
    fields.extend(extra);
    let mut line = json::obj(fields).to_string();
    line.push('\n');
    writer.write_all(line.as_bytes()).map_err(|e| {
        Error::Exec(format!("sending '{op}': {e}"))
    })?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply).map_err(|e| {
        Error::Exec(format!("reading '{op}' reply: {e}"))
    })?;
    Json::parse(reply.trim())
        .map_err(|e| Error::Exec(format!("bad '{op}' reply: {e}")))
}

/// One-shot control request (`ping` / `stats` / `shutdown`).
pub fn control(socket: &Path, op: &str) -> Result<Json, Error> {
    control_fields(socket, op, Vec::new())
}
