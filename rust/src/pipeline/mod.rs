//! `brecq::pipeline` — the typed, cache-aware PTQ session API.
//!
//! Every consumer of this crate (the CLI subcommands, the examples, batch
//! drivers) describes work as a [`JobSpec`] — typed enums for the method,
//! reconstruction granularity, hardware model and data source, plus the
//! numeric knobs — and executes it through a [`Session`]. A session
//! compiles each job into an explicit DAG of stages,
//!
//! ```text
//!   FpWeights → Calib → Sensitivity? → MpSearch? → Reconstruct → Eval? → HwReport?
//! ```
//!
//! and runs the stages against a content-keyed [`cache::ArtifactCache`],
//! so two jobs sharing a model reuse FP weights, calibration subsets and
//! sensitivity LUTs instead of recomputing them. [`Session::run_many`]
//! executes a batch of jobs concurrently on [`crate::util::pool`] with
//! results **bit-identical** to sequential execution (every cached
//! artifact is a deterministic, seeded function of its key — see
//! `rust/tests/pipeline.rs` for the enforcement).
//!
//! Specs round-trip through [`crate::util::json`] (`JobSpec::to_json` /
//! `JobSpec::from_json`), which is what the `brecq run jobs.json` batch
//! subcommand and `examples/jobs.json` are built on. Errors at this API
//! boundary are the typed [`Error`] — unknown methods, granularities,
//! hardware targets and data sources are distinct variants, not ad-hoc
//! strings.
//!
//! Persistence and serving: [`artifact_store`] is the content-addressed
//! on-disk layer under the cache ([`Session::with_store`]) — distinct
//! from [`crate::store`], which only *reads* the build-time python-ABI
//! tensor files — and [`serve`] is the `brecq serve` job daemon speaking
//! newline-delimited JSON over a unix socket.
//!
//! See DESIGN.md (repo root) for the module inventory and the full DAG
//! discussion.

pub mod artifact_store;
pub mod cache;
pub mod job;
#[cfg(unix)]
pub mod serve;

pub use artifact_store::{Artifact, ArtifactStore, Blob, EvalScore, Loaded,
                         StoreStats};
pub use cache::{ArtifactCache, Outcome, SlotStats};
pub use job::{FpWeights, JobEvent, JobOutput, Session};

use std::fmt;

use crate::hwsim::{size_mb, ArmCpu, HwMeasure, ModelSize, Systolic};
use crate::model::{ModelInfo, Task};
use crate::util::json::{self, Json};

// ---------------------------------------------------------------------
// Typed error at the API boundary
// ---------------------------------------------------------------------

/// Pipeline errors. The `Unknown*` variants replace the stringly-typed
/// `anyhow::bail!` dispatch the CLI used to do; `Spec` covers structural
/// problems in a job description (bad JSON, out-of-range knobs,
/// model/granularity mismatches); `Exec` wraps failures bubbling up from
/// the engine underneath.
#[derive(Debug)]
pub enum Error {
    UnknownModel(String),
    UnknownMethod(String),
    UnknownGranularity(String),
    UnknownHardware(String),
    UnknownDataSource(String),
    /// Structurally invalid job spec (bad JSON shape, bad knob values,
    /// spec/model mismatches).
    Spec(String),
    /// Execution failure from the engine below the API boundary.
    Exec(String),
    /// The job was cancelled cooperatively — `ctl cancel`, a
    /// [`JobSpec::deadline_ms`] expiry, or a parent token firing. The
    /// message is the cancellation reason; no partial artifact was
    /// published.
    Cancelled(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::UnknownModel(m) => {
                write!(f, "unknown model '{m}'")
            }
            Error::UnknownMethod(m) => write!(
                f,
                "unknown method '{m}' (expected \
                 fp|brecq|adaround|adaquant|omse|biascorr)"
            ),
            Error::UnknownGranularity(g) => write!(
                f,
                "unknown granularity '{g}' \
                 (expected layer|block|stage|net|pack)"
            ),
            Error::UnknownHardware(h) => write!(
                f,
                "unknown hardware '{h}' (expected size|fpga|arm)"
            ),
            Error::UnknownDataSource(s) => write!(
                f,
                "unknown data source '{s}' (expected train|distilled)"
            ),
            Error::Spec(m) => write!(f, "invalid job spec: {m}"),
            Error::Exec(m) => write!(f, "pipeline execution: {m}"),
            Error::Cancelled(m) => write!(f, "job cancelled: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Error {
        Error::Exec(e.to_string())
    }
}

// ---------------------------------------------------------------------
// Typed vocabulary: method / granularity / hardware / data source
// ---------------------------------------------------------------------

/// PTQ method registry. `Fp` means "no quantization": the job evaluates
/// (or mixed-precision-searches) the full-precision model and skips the
/// `Reconstruct` stage entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Fp,
    BiasCorr,
    Omse,
    AdaRoundLayer,
    AdaQuantLike,
    Brecq,
}

impl Method {
    pub const ALL: [Method; 6] = [
        Method::Fp,
        Method::BiasCorr,
        Method::Omse,
        Method::AdaRoundLayer,
        Method::AdaQuantLike,
        Method::Brecq,
    ];

    /// Stable machine name (CLI flag / JSON value).
    pub fn as_str(&self) -> &'static str {
        match self {
            Method::Fp => "fp",
            Method::BiasCorr => "biascorr",
            Method::Omse => "omse",
            Method::AdaRoundLayer => "adaround",
            Method::AdaQuantLike => "adaquant",
            Method::Brecq => "brecq",
        }
    }

    /// Pretty name for report tables (matches the paper's rows).
    pub fn name(&self) -> &'static str {
        match self {
            Method::Fp => "Full Prec.",
            Method::BiasCorr => "Bias Correction*",
            Method::Omse => "OMSE",
            Method::AdaRoundLayer => "AdaRound (layer)*",
            Method::AdaQuantLike => "AdaQuant-like*",
            Method::Brecq => "BRECQ (ours)",
        }
    }

    pub fn parse(s: &str) -> Result<Method, Error> {
        Method::ALL
            .iter()
            .copied()
            .find(|m| m.as_str() == s)
            .ok_or_else(|| Error::UnknownMethod(s.to_string()))
    }
}

/// Reconstruction granularity (paper Table 1's ablation axis). Only
/// `Brecq` honors it — the AdaRound/AdaQuant baselines are layer-wise by
/// definition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    Layer,
    Block,
    Stage,
    Net,
    /// Pack-PTQ grouping: adjacent blocks with strong FIM cross-block
    /// coupling are reconstructed jointly (see `sensitivity::group_packs`
    /// and the generator's `pack_partition`). Models export it like any
    /// other granularity; `JobSpec::validate` rejects it for models that
    /// do not.
    Pack,
}

impl Granularity {
    pub const ALL: [Granularity; 5] = [
        Granularity::Layer,
        Granularity::Block,
        Granularity::Stage,
        Granularity::Net,
        Granularity::Pack,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            Granularity::Layer => "layer",
            Granularity::Block => "block",
            Granularity::Stage => "stage",
            Granularity::Net => "net",
            Granularity::Pack => "pack",
        }
    }

    pub fn parse(s: &str) -> Result<Granularity, Error> {
        Granularity::ALL
            .iter()
            .copied()
            .find(|g| g.as_str() == s)
            .ok_or_else(|| Error::UnknownGranularity(s.to_string()))
    }
}

/// Hardware measurement model H(c) for mixed-precision search and the
/// `HwReport` stage (paper Appendix B.4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hardware {
    Size,
    Fpga,
    Arm,
}

impl Hardware {
    pub const ALL: [Hardware; 3] =
        [Hardware::Size, Hardware::Fpga, Hardware::Arm];

    pub fn as_str(&self) -> &'static str {
        match self {
            Hardware::Size => "size",
            Hardware::Fpga => "fpga",
            Hardware::Arm => "arm",
        }
    }

    pub fn parse(s: &str) -> Result<Hardware, Error> {
        Hardware::ALL
            .iter()
            .copied()
            .find(|h| h.as_str() == s)
            .ok_or_else(|| Error::UnknownHardware(s.to_string()))
    }

    /// Instantiate the measurement function (default geometry).
    pub fn measurer(&self) -> Box<dyn HwMeasure> {
        match self {
            Hardware::Size => Box::new(ModelSize),
            Hardware::Fpga => Box::new(Systolic::default()),
            Hardware::Arm => Box::new(ArmCpu::default()),
        }
    }
}

/// Where the calibration images come from: the train split (the paper's
/// default protocol) or ZeroQ-style BN-statistics distillation (zero-shot;
/// needs the model's distill executable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataSource {
    Train,
    Distilled,
}

impl DataSource {
    pub fn as_str(&self) -> &'static str {
        match self {
            DataSource::Train => "train",
            DataSource::Distilled => "distilled",
        }
    }

    pub fn parse(s: &str) -> Result<DataSource, Error> {
        match s {
            "train" => Ok(DataSource::Train),
            "distilled" => Ok(DataSource::Distilled),
            _ => Err(Error::UnknownDataSource(s.to_string())),
        }
    }
}

// ---------------------------------------------------------------------
// Job description
// ---------------------------------------------------------------------

/// Mixed-precision search request: presence turns on the `Sensitivity` and
/// `MpSearch` stages, and the GA's per-layer assignment replaces the
/// uniform `wbits`. `relative: true` interprets `budget` as a fraction of
/// the all-8-bit cost of the model under `hw` — the portable form for
/// committed job files that must work on any environment.
#[derive(Debug, Clone, PartialEq)]
pub struct HwBudget {
    pub hw: Hardware,
    pub budget: f64,
    pub relative: bool,
}

impl HwBudget {
    /// Absolute budget in the measurer's unit.
    pub fn resolve(&self, model: &ModelInfo, hw: &dyn HwMeasure,
                   abits: usize) -> f64 {
        if self.relative {
            let full =
                hw.measure(model, &vec![8; model.layers.len()], abits);
            self.budget * full
        } else {
            self.budget
        }
    }
}

/// One unit of pipeline work: quantize (and/or search, evaluate, report
/// on) one model. Serde-round-trippable via [`crate::util::json`].
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub model: String,
    pub method: Method,
    /// Reconstruction granularity (BRECQ only; baselines fix their own).
    pub gran: Granularity,
    /// Uniform weight bits; superseded by the GA assignment when `search`
    /// is set.
    pub wbits: usize,
    /// Activation bits; `None` keeps activations full-precision.
    pub abits: Option<usize>,
    /// Keep first & last layer at 8-bit (the paper's §4.2 policy).
    pub first_last_8: bool,
    pub iters: usize,
    pub calib_n: usize,
    pub seed: u64,
    pub source: DataSource,
    pub search: Option<HwBudget>,
    /// Evaluate top-1 on the held-out test set after the job.
    pub eval: bool,
    /// Attach a size/latency report for the final bit assignment.
    pub hw_report: bool,
    /// Greedy NMS (IoU 0.5) in the detection eval. Default off so the
    /// table5 baselines are unchanged; no effect on classification.
    pub det_nms: bool,
    /// Wall-clock deadline for the whole job in milliseconds, measured
    /// from when execution *starts* (not queue time). `None`/0 = no
    /// deadline. Expiry surfaces as [`Error::Cancelled`] at the next
    /// stage or reconstruction-iteration boundary. Not part of any
    /// cache key: the artifacts a job computes don't depend on it.
    pub deadline_ms: Option<u64>,
    pub verbose: bool,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            model: "resnet_s".into(),
            method: Method::Brecq,
            gran: Granularity::Block,
            wbits: 4,
            abits: None,
            first_last_8: true,
            iters: 250,
            calib_n: 1024,
            seed: 0,
            source: DataSource::Train,
            search: None,
            eval: true,
            hw_report: false,
            det_nms: false,
            deadline_ms: None,
            verbose: false,
        }
    }
}

/// The stages a job compiles into, in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    FpWeights,
    Calib,
    Sensitivity,
    MpSearch,
    Reconstruct,
    Eval,
    HwReport,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::FpWeights => "fp-weights",
            Stage::Calib => "calib",
            Stage::Sensitivity => "sensitivity",
            Stage::MpSearch => "mp-search",
            Stage::Reconstruct => "reconstruct",
            Stage::Eval => "eval",
            Stage::HwReport => "hw-report",
        }
    }
}

impl JobSpec {
    /// Does this job touch calibration data at all?
    pub fn needs_calib(&self) -> bool {
        self.method != Method::Fp || self.search.is_some()
    }

    /// Compile the spec into its stage DAG (execution order).
    pub fn stages(&self) -> Vec<Stage> {
        let mut s = vec![Stage::FpWeights];
        if self.needs_calib() {
            s.push(Stage::Calib);
        }
        if self.search.is_some() {
            s.push(Stage::Sensitivity);
            s.push(Stage::MpSearch);
        }
        if self.method != Method::Fp {
            s.push(Stage::Reconstruct);
        }
        if self.eval {
            s.push(Stage::Eval);
        }
        if self.hw_report {
            s.push(Stage::HwReport);
        }
        s
    }

    /// "fp-weights -> calib -> reconstruct -> eval" (logging / --verbose).
    pub fn describe_stages(&self) -> String {
        self.stages()
            .iter()
            .map(|s| s.name())
            .collect::<Vec<_>>()
            .join(" -> ")
    }

    /// Structural validation against the target model. Called by
    /// [`Session::run`]; exposed for early checks on batch files.
    pub fn validate(&self, model: &ModelInfo) -> Result<(), Error> {
        if !(1..=8).contains(&self.wbits) {
            return Err(Error::Spec(format!(
                "wbits {} out of range 1..=8",
                self.wbits
            )));
        }
        if let Some(a) = self.abits {
            if !(1..=16).contains(&a) {
                return Err(Error::Spec(format!(
                    "abits {a} out of range 1..=16"
                )));
            }
        }
        let need_gran = match self.method {
            Method::Brecq => Some(self.gran.as_str()),
            Method::AdaRoundLayer
            | Method::AdaQuantLike
            | Method::BiasCorr => Some("layer"),
            Method::Fp | Method::Omse => None,
        };
        if let Some(g) = need_gran {
            if !model.grans.contains_key(g) {
                return Err(Error::Spec(format!(
                    "granularity '{g}' is not exported for model '{}'",
                    model.name
                )));
            }
        }
        if let Some(hb) = &self.search {
            if model.task == Task::Detect {
                return Err(Error::Spec(format!(
                    "mixed-precision search is not supported for the \
                     detection model '{}' (the sensitivity stage's \
                     cross-entropy fitness is undefined for regression \
                     heads)",
                    model.name
                )));
            }
            if !hb.budget.is_finite() || hb.budget <= 0.0 {
                return Err(Error::Spec(
                    "search budget must be a finite value > 0".into(),
                ));
            }
            if hb.hw == Hardware::Arm && !ArmCpu::supports(model) {
                return Err(Error::Spec(format!(
                    "ARM GEMM latency model supports normal convolution \
                     only and '{}' has depthwise/group conv (paper B.4.3)",
                    model.name
                )));
            }
        }
        Ok(())
    }

    // ---- JSON round-trip -------------------------------------------------

    pub fn to_json(&self) -> Json {
        let abits = match self.abits {
            Some(a) => json::num(a as f64),
            None => Json::Null,
        };
        let search = match &self.search {
            Some(hb) => json::obj(vec![
                ("hw", json::s(hb.hw.as_str())),
                ("budget", json::num(hb.budget)),
                ("relative", json::b(hb.relative)),
            ]),
            None => Json::Null,
        };
        json::obj(vec![
            ("model", json::s(&self.model)),
            ("method", json::s(self.method.as_str())),
            ("gran", json::s(self.gran.as_str())),
            ("wbits", json::num(self.wbits as f64)),
            ("abits", abits),
            ("first_last_8", json::b(self.first_last_8)),
            ("iters", json::num(self.iters as f64)),
            ("calib_n", json::num(self.calib_n as f64)),
            ("seed", json::num(self.seed as f64)),
            ("source", json::s(self.source.as_str())),
            ("search", search),
            ("eval", json::b(self.eval)),
            ("hw_report", json::b(self.hw_report)),
            ("det_nms", json::b(self.det_nms)),
            (
                "deadline_ms",
                match self.deadline_ms {
                    Some(ms) => json::num(ms as f64),
                    None => Json::Null,
                },
            ),
            ("verbose", json::b(self.verbose)),
        ])
    }

    /// Parse one job object. Absent keys take [`JobSpec::default`] values
    /// (except the required `model`); unknown keys are rejected so typos
    /// fail loudly instead of silently running the default.
    pub fn from_json(v: &Json) -> Result<JobSpec, Error> {
        let o = v.as_obj().ok_or_else(|| {
            Error::Spec("job must be a JSON object".into())
        })?;
        const KEYS: [&str; 16] = [
            "model", "method", "gran", "wbits", "abits", "first_last_8",
            "iters", "calib_n", "seed", "source", "search", "eval",
            "hw_report", "det_nms", "deadline_ms", "verbose",
        ];
        for k in o.keys() {
            if !KEYS.contains(&k.as_str()) {
                return Err(Error::Spec(format!(
                    "unknown key '{k}' in job object"
                )));
            }
        }
        let d = JobSpec::default();
        let model = j_str(v, "model")?
            .ok_or_else(|| {
                Error::Spec("missing required key 'model'".into())
            })?
            .to_string();
        let method = match j_str(v, "method")? {
            Some(m) => Method::parse(m)?,
            None => d.method,
        };
        let gran = match j_str(v, "gran")? {
            Some(g) => Granularity::parse(g)?,
            None => d.gran,
        };
        let source = match j_str(v, "source")? {
            Some(s) => DataSource::parse(s)?,
            None => d.source,
        };
        // `abits: 0` and `abits: null` both mean full-precision acts (the
        // CLI uses 0 for "off", JSON-minded callers use null)
        let abits = match v.get("abits") {
            None | Some(Json::Null) => d.abits,
            Some(x) => match x.as_usize() {
                Some(0) => None,
                Some(a) => Some(a),
                None => {
                    return Err(Error::Spec(
                        "'abits' must be a number or null".into(),
                    ))
                }
            },
        };
        let search = match v.get("search") {
            None | Some(Json::Null) => None,
            Some(x) => Some(parse_search(x)?),
        };
        // `deadline_ms: 0` and `deadline_ms: null` both mean no deadline
        let deadline_ms = match v.get("deadline_ms") {
            None | Some(Json::Null) => d.deadline_ms,
            Some(x) => match x.as_f64() {
                Some(n) if n == 0.0 => None,
                Some(n) if n > 0.0 => Some(n as u64),
                _ => {
                    return Err(Error::Spec(
                        "'deadline_ms' must be a non-negative number \
                         or null"
                            .into(),
                    ))
                }
            },
        };
        Ok(JobSpec {
            model,
            method,
            gran,
            wbits: j_usize(v, "wbits", d.wbits)?,
            abits,
            first_last_8: j_bool(v, "first_last_8", d.first_last_8)?,
            iters: j_usize(v, "iters", d.iters)?,
            calib_n: j_usize(v, "calib_n", d.calib_n)?,
            seed: j_u64(v, "seed", d.seed)?,
            source,
            search,
            eval: j_bool(v, "eval", d.eval)?,
            hw_report: j_bool(v, "hw_report", d.hw_report)?,
            det_nms: j_bool(v, "det_nms", d.det_nms)?,
            deadline_ms,
            verbose: j_bool(v, "verbose", d.verbose)?,
        })
    }

    /// Parse a batch file: a JSON array of job objects, or an object with
    /// a `jobs` array (room for batch-level settings later).
    pub fn parse_jobs(text: &str) -> Result<Vec<JobSpec>, Error> {
        let v = Json::parse(text).map_err(Error::Spec)?;
        let jobs = match v.get("jobs") {
            Some(j) => j.as_arr(),
            None => v.as_arr(),
        }
        .ok_or_else(|| {
            Error::Spec(
                "expected a JSON array of jobs (or {\"jobs\": [...]})"
                    .into(),
            )
        })?;
        if jobs.is_empty() {
            return Err(Error::Spec("batch file has no jobs".into()));
        }
        jobs.iter().map(JobSpec::from_json).collect()
    }
}

fn parse_search(v: &Json) -> Result<HwBudget, Error> {
    let o = v.as_obj().ok_or_else(|| {
        Error::Spec("'search' must be an object or null".into())
    })?;
    for k in o.keys() {
        if !["hw", "budget", "relative"].contains(&k.as_str()) {
            return Err(Error::Spec(format!(
                "unknown key '{k}' in search object"
            )));
        }
    }
    let hw = Hardware::parse(j_str(v, "hw")?.ok_or_else(|| {
        Error::Spec("search object needs 'hw'".into())
    })?)?;
    let budget = v
        .get("budget")
        .and_then(|x| x.as_f64())
        .ok_or_else(|| {
            Error::Spec("search object needs a numeric 'budget'".into())
        })?;
    Ok(HwBudget { hw, budget, relative: j_bool(v, "relative", false)? })
}

fn j_str<'a>(v: &'a Json, k: &str) -> Result<Option<&'a str>, Error> {
    match v.get(k) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => x.as_str().map(Some).ok_or_else(|| {
            Error::Spec(format!("'{k}' must be a string"))
        }),
    }
}

fn j_usize(v: &Json, k: &str, default: usize) -> Result<usize, Error> {
    match v.get(k) {
        None => Ok(default),
        Some(x) => x.as_usize().ok_or_else(|| {
            Error::Spec(format!("'{k}' must be a number"))
        }),
    }
}

fn j_u64(v: &Json, k: &str, default: u64) -> Result<u64, Error> {
    match v.get(k) {
        None => Ok(default),
        Some(x) => x
            .as_f64()
            .map(|n| n as u64)
            .ok_or_else(|| Error::Spec(format!("'{k}' must be a number"))),
    }
}

fn j_bool(v: &Json, k: &str, default: bool) -> Result<bool, Error> {
    match v.get(k) {
        None => Ok(default),
        Some(x) => x.as_bool().ok_or_else(|| {
            Error::Spec(format!("'{k}' must be a bool"))
        }),
    }
}

// ---------------------------------------------------------------------
// Hardware report (HwReport stage + the `hwsim` subcommand)
// ---------------------------------------------------------------------

/// Deployment cost of one bit assignment across all simulators. `arm_ms`
/// is `None` for models the ARM GEMM kernel cannot serve (depthwise/group
/// conv — why the paper's Fig. 4 only shows ResNets).
#[derive(Debug, Clone, PartialEq)]
pub struct HwReport {
    pub size_mb: f64,
    pub fpga_ms: f64,
    pub arm_ms: Option<f64>,
}

/// Measure one per-layer bit assignment on every hardware model.
pub fn hw_report(model: &ModelInfo, wbits: &[usize], abits: usize)
    -> HwReport {
    HwReport {
        size_mb: size_mb(model, wbits),
        fpga_ms: Systolic::default().model_ms(model, wbits, abits),
        arm_ms: if ArmCpu::supports(model) {
            Some(ArmCpu::default().model_ms(model, wbits, abits))
        } else {
            None
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_round_trips_through_strings() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.as_str()).unwrap(), m);
        }
        for g in Granularity::ALL {
            assert_eq!(Granularity::parse(g.as_str()).unwrap(), g);
        }
        for h in Hardware::ALL {
            assert_eq!(Hardware::parse(h.as_str()).unwrap(), h);
        }
        for s in [DataSource::Train, DataSource::Distilled] {
            assert_eq!(DataSource::parse(s.as_str()).unwrap(), s);
        }
    }

    #[test]
    fn unknown_names_are_typed_errors() {
        assert!(matches!(
            Method::parse("quantum"),
            Err(Error::UnknownMethod(_))
        ));
        assert!(matches!(
            Granularity::parse("half-block"),
            Err(Error::UnknownGranularity(_))
        ));
        assert!(matches!(
            Hardware::parse("tpu"),
            Err(Error::UnknownHardware(_))
        ));
        assert!(matches!(
            DataSource::parse("imagenet"),
            Err(Error::UnknownDataSource(_))
        ));
    }

    #[test]
    fn stage_dag_follows_spec_shape() {
        use Stage::*;
        let d = JobSpec::default();
        assert_eq!(d.stages(), vec![FpWeights, Calib, Reconstruct, Eval]);
        let fp_eval = JobSpec { method: Method::Fp, ..d.clone() };
        assert_eq!(fp_eval.stages(), vec![FpWeights, Eval]);
        let mp_only = JobSpec {
            method: Method::Fp,
            eval: false,
            search: Some(HwBudget {
                hw: Hardware::Size,
                budget: 0.5,
                relative: true,
            }),
            ..d.clone()
        };
        assert_eq!(
            mp_only.stages(),
            vec![FpWeights, Calib, Sensitivity, MpSearch]
        );
        let full = JobSpec {
            search: Some(HwBudget {
                hw: Hardware::Fpga,
                budget: 1.0,
                relative: false,
            }),
            hw_report: true,
            ..d
        };
        assert_eq!(
            full.stages(),
            vec![
                FpWeights, Calib, Sensitivity, MpSearch, Reconstruct,
                Eval, HwReport
            ]
        );
    }

    #[test]
    fn jobspec_json_round_trip_exact() {
        let spec = JobSpec {
            model: "resnet_s".into(),
            method: Method::AdaRoundLayer,
            gran: Granularity::Layer,
            wbits: 3,
            abits: Some(4),
            first_last_8: false,
            iters: 17,
            calib_n: 96,
            seed: 9,
            source: DataSource::Train,
            search: Some(HwBudget {
                hw: Hardware::Fpga,
                budget: 1.25,
                relative: false,
            }),
            eval: false,
            hw_report: true,
            det_nms: true,
            deadline_ms: Some(1500),
            verbose: true,
        };
        let text = spec.to_json().to_string();
        let back =
            JobSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn jobspec_defaults_fill_in() {
        let v = Json::parse(r#"{"model":"m"}"#).unwrap();
        let got = JobSpec::from_json(&v).unwrap();
        assert_eq!(got, JobSpec { model: "m".into(), ..JobSpec::default() });
        // abits: 0 and abits: null both mean FP activations
        let v = Json::parse(r#"{"model":"m","abits":0}"#).unwrap();
        assert_eq!(JobSpec::from_json(&v).unwrap().abits, None);
        // deadline_ms: 0 and null both mean no deadline
        let v = Json::parse(r#"{"model":"m","deadline_ms":0}"#).unwrap();
        assert_eq!(JobSpec::from_json(&v).unwrap().deadline_ms, None);
        let v =
            Json::parse(r#"{"model":"m","deadline_ms":250}"#).unwrap();
        assert_eq!(
            JobSpec::from_json(&v).unwrap().deadline_ms,
            Some(250)
        );
        let v =
            Json::parse(r#"{"model":"m","deadline_ms":-5}"#).unwrap();
        assert!(matches!(JobSpec::from_json(&v), Err(Error::Spec(_))));
    }

    #[test]
    fn jobspec_rejects_unknown_and_missing_keys() {
        let v = Json::parse(r#"{"model":"m","wbitz":4}"#).unwrap();
        assert!(matches!(JobSpec::from_json(&v), Err(Error::Spec(_))));
        let v = Json::parse(r#"{"wbits":4}"#).unwrap();
        assert!(matches!(JobSpec::from_json(&v), Err(Error::Spec(_))));
        let v = Json::parse(r#"{"model":"m","method":"magic"}"#).unwrap();
        assert!(matches!(
            JobSpec::from_json(&v),
            Err(Error::UnknownMethod(_))
        ));
        let v = Json::parse(
            r#"{"model":"m","search":{"hw":"size","budget":1,"frac":true}}"#,
        )
        .unwrap();
        assert!(matches!(JobSpec::from_json(&v), Err(Error::Spec(_))));
    }

    #[test]
    fn parse_jobs_accepts_array_and_wrapper() {
        let a = JobSpec::parse_jobs(r#"[{"model":"m"}]"#).unwrap();
        assert_eq!(a.len(), 1);
        let b = JobSpec::parse_jobs(r#"{"jobs":[{"model":"m"},{"model":"n"}]}"#)
            .unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b[1].model, "n");
        assert!(JobSpec::parse_jobs("[]").is_err());
        assert!(JobSpec::parse_jobs("{nope").is_err());
    }
}
