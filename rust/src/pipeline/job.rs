//! Job execution: [`Session`] compiles a [`JobSpec`] into its stage DAG
//! and runs the stages against the session's artifact cache.
//!
//! A session owns one [`Env`] (manifest + backend + datasets) and one
//! [`ArtifactCache`]. The cached stage accessors (`fp_weights`,
//! `calib_set`, `sensitivity`, ...) are public so CLI views that need a
//! single stage (the `sensitivity` subcommand, for instance) go through
//! exactly the same cache as full jobs.
//!
//! Determinism: every artifact is a seeded, deterministic function of its
//! cache key, and every per-job computation (reconstruction, GA search)
//! seeds its own RNG from the spec — so [`Session::run_many`], which
//! executes jobs concurrently on [`crate::util::pool`], returns results
//! bit-identical to running the same specs sequentially, at any thread
//! count. `rust/tests/pipeline.rs` enforces this bitwise.
//!
//! Persistence: [`Session::with_store`] layers the cache over an on-disk
//! [`ArtifactStore`]. Every backend-touching stage artifact (FP weights,
//! calibration subsets, sensitivity LUTs, reconstructions, GA results,
//! eval scores) then persists under its cache key, and a warm-store
//! session replays a job bit-identically with *zero* backend dispatches
//! — the cheap memory-only values (dataset splits) rebuild from the
//! manifest without touching the backend. `rust/tests/qaas.rs` pins both
//! properties via [`JobOutput::fingerprint`] and dispatch accounting.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::baselines;
use crate::calib::{CalibSet, DataSet};
use crate::coordinator::Env;
use crate::distill::{self, DistillConfig};
use crate::eval::{accuracy, map_score, EvalParams};
use crate::model::ModelInfo;
use crate::mp::{GaConfig, GeneticSearch, SearchResult};
use crate::recon::{BitConfig, Calibrator, CkptHook, QuantizedModel,
                   ReconConfig, UnitCheckpoint, UnitCheckpointer,
                   UnitReport};
use crate::sensitivity::{Profiler, SensitivityTable};
use crate::util::cancel::CancelToken;
use crate::util::faults;
use crate::util::json::{self, Json};
use crate::util::pool;

use super::artifact_store::{fnv64, Artifact, ArtifactStore, EvalScore,
                            Loaded};
use super::cache::{self, ArtifactCache, Outcome};
use super::{hw_report, DataSource, Error, HwBudget, HwReport, JobSpec,
            Method};

/// FP deploy weights + biases in model-layer order (the `FpWeights`
/// stage's artifact).
pub struct FpWeights {
    pub ws: Vec<crate::tensor::Tensor>,
    pub bs: Vec<crate::tensor::Tensor>,
}

/// Typed progress event emitted by [`Session::run_traced`] — what the
/// `serve` daemon streams to its clients while a job runs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobEvent {
    /// A DAG stage started (`done: false`) or finished (`done: true`).
    Stage { stage: &'static str, done: bool },
    /// A cache request this job triggered, and how it was satisfied.
    Cache { key: String, outcome: Outcome },
}

/// Everything a finished job produced. Heavyweight artifacts that later
/// stages or callers may want (the quantized model itself) ride along;
/// cached intermediates stay in the session.
pub struct JobOutput {
    pub spec: JobSpec,
    /// Train-time FP reference accuracy from the manifest.
    pub fp_acc: f64,
    /// Final per-layer weight bits (uniform policy or GA assignment).
    pub wbits: Vec<usize>,
    /// Held-out test-set score (when `spec.eval`): top-1 accuracy for
    /// classification models, mAP for the detection family.
    pub accuracy: Option<f64>,
    /// GA outcome (when `spec.search`).
    pub search: Option<SearchResult>,
    /// Size/latency of the final assignment (when `spec.hw_report`).
    pub hw: Option<HwReport>,
    /// The calibrated model (absent for `Method::Fp`).
    pub quantized: Option<QuantizedModel>,
    /// Whole-job wall-clock, including cache hits.
    pub seconds: f64,
}

impl JobOutput {
    pub fn reports(&self) -> &[UnitReport] {
        self.quantized
            .as_ref()
            .map(|q| q.reports.as_slice())
            .unwrap_or(&[])
    }

    pub fn calib_seconds(&self) -> f64 {
        self.quantized
            .as_ref()
            .map(|q| q.calib_seconds)
            .unwrap_or(0.0)
    }

    /// `W4A8` / `W2AFP` / `Wmixed A8` / `FP` — the bit label for
    /// summaries.
    pub fn bits_label(&self) -> String {
        if self.spec.method == Method::Fp && self.spec.search.is_none() {
            return "FP".into();
        }
        let w = if self.spec.search.is_some() {
            "mixed".to_string()
        } else {
            self.spec.wbits.to_string()
        };
        let a = match self.spec.abits {
            Some(a) => a.to_string(),
            None => "FP".into(),
        };
        format!("W{w}A{a}")
    }

    /// FNV-1a 64 over every result-bearing bit of this output — spec
    /// bits, quantized weights/biases/steps, scores, search and hw
    /// numbers — excluding wall-clock timing. Two runs of the same spec
    /// are bit-identical iff their fingerprints agree, which is how the
    /// serve smoke test and the warm-replay tests compare results across
    /// processes without shipping tensors as text.
    pub fn fingerprint(&self) -> u64 {
        let mut bytes: Vec<u8> = Vec::new();
        let push_u64 = |bytes: &mut Vec<u8>, v: u64| {
            bytes.extend_from_slice(&v.to_le_bytes());
        };
        let push_f64 = |bytes: &mut Vec<u8>, v: f64| {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        };
        push_f64(&mut bytes, self.fp_acc);
        for &w in &self.wbits {
            push_u64(&mut bytes, w as u64);
        }
        push_f64(&mut bytes, self.accuracy.unwrap_or(f64::NEG_INFINITY));
        if let Some(s) = &self.search {
            for &w in &s.wbits {
                push_u64(&mut bytes, w as u64);
            }
            push_f64(&mut bytes, s.predicted_loss);
            push_f64(&mut bytes, s.hw_cost);
        }
        if let Some(h) = &self.hw {
            push_f64(&mut bytes, h.size_mb);
            push_f64(&mut bytes, h.fpga_ms);
            push_f64(&mut bytes, h.arm_ms.unwrap_or(f64::NEG_INFINITY));
        }
        if let Some(q) = &self.quantized {
            for t in q.weights.iter().chain(q.biases.iter()) {
                for &v in &t.data {
                    bytes.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
            for &s in &q.act_steps {
                bytes.extend_from_slice(&s.to_bits().to_le_bytes());
            }
            for &b in q.bits.wbits.iter().chain(q.bits.abits.iter()) {
                push_u64(&mut bytes, b as u64);
            }
            for r in &q.reports {
                push_f64(&mut bytes, r.initial_loss);
                push_f64(&mut bytes, r.final_loss);
                push_f64(&mut bytes, r.soft_fraction_before_commit);
                push_u64(&mut bytes, r.iters as u64);
            }
        }
        fnv64(&bytes)
    }

    /// Result summary as JSON (`brecq run --json`, serve results). All
    /// bit-level comparisons go through the hex `fingerprint` field —
    /// the f64 summary numbers here are for humans and dashboards.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", json::s(&self.spec.model)),
            ("method", json::s(self.spec.method.as_str())),
            ("bits", json::s(&self.bits_label())),
            (
                "wbits",
                Json::Arr(
                    self.wbits.iter().map(|&w| json::num(w as f64))
                        .collect(),
                ),
            ),
            ("fp_acc", json::num(self.fp_acc)),
            ("seconds", json::num(self.seconds)),
            (
                "fingerprint",
                json::s(&format!("{:016x}", self.fingerprint())),
            ),
        ];
        if let Some(a) = self.accuracy {
            fields.push(("accuracy", json::num(a)));
        }
        if let Some(s) = &self.search {
            fields.push(("search_hw_cost", json::num(s.hw_cost)));
            fields.push((
                "search_predicted_loss",
                json::num(s.predicted_loss),
            ));
        }
        if let Some(h) = &self.hw {
            fields.push(("size_mb", json::num(h.size_mb)));
            fields.push(("fpga_ms", json::num(h.fpga_ms)));
            if let Some(ms) = h.arm_ms {
                fields.push(("arm_ms", json::num(ms)));
            }
        }
        json::obj(fields)
    }
}

/// Disarms the thread's cache trace even on an early `?` return.
struct TraceGuard;

impl Drop for TraceGuard {
    fn drop(&mut self) {
        let _ = cache::trace_end();
    }
}

/// A PTQ session: one environment, one artifact cache, any number of
/// jobs. The typed front door for every crate consumer.
pub struct Session {
    env: Env,
    cache: ArtifactCache,
}

impl Session {
    pub fn new(env: Env) -> Session {
        Session { env, cache: ArtifactCache::new() }
    }

    /// A session whose cache persists artifacts to `store`, sharing them
    /// with every other session (past, present, concurrent) on the same
    /// store directory.
    pub fn with_store(env: Env, store: Arc<ArtifactStore>) -> Session {
        Session { env, cache: ArtifactCache::with_store(store) }
    }

    pub fn env(&self) -> &Env {
        &self.env
    }

    pub fn cache(&self) -> &ArtifactCache {
        &self.cache
    }

    /// Typed model lookup (the panicking `Env::model` stays internal).
    pub fn model(&self, name: &str) -> Result<&ModelInfo, Error> {
        if !self.env.has_model(name) {
            return Err(Error::UnknownModel(name.to_string()));
        }
        Ok(self.env.model(name))
    }

    // ---- cached stage accessors -----------------------------------------

    pub fn train_set(&self) -> Result<Arc<DataSet>, Error> {
        self.cache.get_or_try_insert("dataset/train", || {
            self.env.train_set().map_err(Error::from)
        })
    }

    pub fn test_set(&self) -> Result<Arc<DataSet>, Error> {
        self.cache.get_or_try_insert("dataset/test", || {
            self.env.test_set().map_err(Error::from)
        })
    }

    /// Cache key suffix identifying the dataset `model` consumes: empty
    /// for the manifest's root dataset, the override directory for
    /// models carrying their own (the detection family) — so per-model
    /// splits and calibration subsets never collide in the cache.
    fn dataset_id(mi: &ModelInfo) -> String {
        match &mi.dataset {
            Some(d) => format!("{}/", d.dir.display()),
            None => String::new(),
        }
    }

    /// Train split of the dataset `model` consumes (cached per dataset).
    /// Memory-only: splits are cheap, backend-free rebuilds from the
    /// manifest, so persisting them would only bloat the store.
    pub fn train_set_for(&self, model: &str) -> Result<Arc<DataSet>, Error> {
        let mi = self.model(model)?;
        let key = format!("dataset/{}train", Self::dataset_id(mi));
        self.cache.get_or_try_insert(&key, || {
            self.env.train_set_for(mi).map_err(Error::from)
        })
    }

    /// Test split of the dataset `model` consumes (cached per dataset).
    pub fn test_set_for(&self, model: &str) -> Result<Arc<DataSet>, Error> {
        let mi = self.model(model)?;
        let key = format!("dataset/{}test", Self::dataset_id(mi));
        self.cache.get_or_try_insert(&key, || {
            self.env.test_set_for(mi).map_err(Error::from)
        })
    }

    /// `FpWeights` stage: deploy weights in model order, loaded once per
    /// model per session and persisted to the store.
    pub fn fp_weights(&self, model: &str) -> Result<Arc<FpWeights>, Error> {
        let mi = self.model(model)?;
        let key = format!("fp/{model}");
        self.cache.get_or_build(&key, || {
            let cal = Calibrator::new(&self.env.rt, &self.env.mf, mi);
            let (ws, bs) = cal.fp_weights()?;
            Ok(FpWeights { ws, bs })
        })
    }

    /// `Calib` stage: the calibration working set. Train-sourced subsets
    /// are keyed by the dataset the model consumes (jobs on different
    /// models share them iff they share a dataset); distilled sets are
    /// per-model.
    pub fn calib_set(
        &self,
        model: &str,
        source: DataSource,
        n: usize,
        seed: u64,
    ) -> Result<Arc<CalibSet>, Error> {
        match source {
            DataSource::Train => {
                let mi = self.model(model)?;
                let train = self.train_set_for(model)?;
                let key = format!(
                    "calib/{}train/{n}/{seed}",
                    Self::dataset_id(mi)
                );
                self.cache.get_or_build(&key, || {
                    Ok(self.env.calib(&train, n, seed))
                })
            }
            DataSource::Distilled => self.distill(
                model,
                &DistillConfig { total: n, seed, ..Default::default() },
            ),
        }
    }

    /// ZeroQ-style distilled calibration data (cached per config).
    pub fn distill(
        &self,
        model: &str,
        cfg: &DistillConfig,
    ) -> Result<Arc<CalibSet>, Error> {
        let mi = self.model(model)?;
        if mi.distill_exe.is_none() {
            return Err(Error::Spec(format!(
                "model '{model}' has no distill executable in this \
                 environment (required for source=distilled)"
            )));
        }
        let key = format!(
            "distill/{model}/{}/{}/{}/{}",
            cfg.total, cfg.iters, cfg.seed, cfg.lr
        );
        self.cache.get_or_build(&key, || {
            distill::distill(&self.env.rt, &self.env.mf, mi, cfg)
                .map_err(Error::from)
        })
    }

    /// `Sensitivity` stage: the mixed-precision LUT (diagonal + intra-block
    /// off-diagonal terms), computed once per (model, data) and shared by
    /// every search job in the session.
    pub fn sensitivity(
        &self,
        model: &str,
        source: DataSource,
        calib_n: usize,
        seed: u64,
    ) -> Result<Arc<SensitivityTable>, Error> {
        let mi = self.model(model)?;
        let fpw = self.fp_weights(model)?;
        let calib = self.calib_set(model, source, calib_n, seed)?;
        let key = format!(
            "sens/{model}/{}/{calib_n}/{seed}",
            source.as_str()
        );
        self.cache.get_or_build(&key, || {
            let prof =
                Profiler { rt: &self.env.rt, mf: &self.env.mf, model: mi };
            prof.measure(&calib, &fpw.ws, &fpw.bs, true)
                .map_err(Error::from)
        })
    }

    /// `MpSearch` stage as a standalone call (the `mp-search` subcommand):
    /// GA over the cached sensitivity LUT under an absolute budget.
    pub fn mp_search(
        &self,
        model: &str,
        hw: super::Hardware,
        budget: f64,
        calib_n: usize,
        seed: u64,
    ) -> Result<SearchResult, Error> {
        let spec = JobSpec {
            model: model.to_string(),
            method: Method::Fp,
            calib_n,
            seed,
            eval: false,
            search: Some(HwBudget { hw, budget, relative: false }),
            ..JobSpec::default()
        };
        let out = self.run(&spec)?;
        Ok(out
            .search
            .expect("a search job always produces a search result"))
    }

    // ---- persistent cache keys ------------------------------------------

    /// Order-sensitive digest of a bit assignment, folded into recon and
    /// eval keys (binary, not text — assignments can be hundreds of
    /// layers).
    fn bits_digest(bits: &BitConfig) -> u64 {
        let mut bytes = Vec::with_capacity(
            (bits.wbits.len() + bits.abits.len()) * 8 + 1,
        );
        for &b in &bits.wbits {
            bytes.extend_from_slice(&(b as u64).to_le_bytes());
        }
        for &b in &bits.abits {
            bytes.extend_from_slice(&(b as u64).to_le_bytes());
        }
        bytes.push(bits.aq as u8);
        fnv64(&bytes)
    }

    /// Reconstruction cache key. The granularity component is the one the
    /// method *actually uses* (baselines pin their own), so e.g. an
    /// AdaRound job keyed under the spec's default granularity can never
    /// collide with a BRECQ run.
    fn recon_key(&self, spec: &JobSpec, bits: &BitConfig) -> String {
        let gran = match spec.method {
            Method::Brecq => spec.gran.as_str(),
            Method::AdaRoundLayer
            | Method::AdaQuantLike => "layer",
            Method::Omse | Method::BiasCorr => "none",
            Method::Fp => unreachable!("Fp has no Reconstruct stage"),
        };
        format!(
            "recon/{}/{}/{gran}/{}/{}/{}/{}/{:016x}",
            spec.model,
            spec.method.as_str(),
            spec.iters,
            spec.calib_n,
            spec.seed,
            spec.source.as_str(),
            Self::bits_digest(bits)
        )
    }

    // ---- job execution ---------------------------------------------------

    /// Execute one job through its stage DAG.
    pub fn run(&self, spec: &JobSpec) -> Result<JobOutput, Error> {
        self.run_inner(spec, &CancelToken::none(), &mut |_| {})
    }

    /// [`Session::run`] with typed progress events: stage boundaries plus
    /// the cache outcomes each stage triggered on this thread. The serve
    /// daemon forwards these to clients as they happen.
    pub fn run_traced(
        &self,
        spec: &JobSpec,
        emit: &mut dyn FnMut(JobEvent),
    ) -> Result<JobOutput, Error> {
        cache::trace_begin();
        let _guard = TraceGuard;
        self.run_inner(spec, &CancelToken::none(), emit)
    }

    /// [`Session::run_traced`] under a cancellation scope: the job stops
    /// with [`Error::Cancelled`] at the next stage/iteration boundary
    /// once `cancel` fires or the spec's `deadline_ms` budget (measured
    /// from this call) expires.
    pub fn run_with_cancel(
        &self,
        spec: &JobSpec,
        cancel: &CancelToken,
        emit: &mut dyn FnMut(JobEvent),
    ) -> Result<JobOutput, Error> {
        cache::trace_begin();
        let _guard = TraceGuard;
        self.run_inner(spec, cancel, emit)
    }

    fn run_inner(
        &self,
        spec: &JobSpec,
        parent: &CancelToken,
        emit: &mut dyn FnMut(JobEvent),
    ) -> Result<JobOutput, Error> {
        // The deadline clock starts here — job *execution* start, not
        // queue-entry time.
        let cancel =
            parent.child(spec.deadline_ms.map(std::time::Duration::from_millis));
        match self.run_exec(spec, &cancel, emit) {
            // recon surfaces cancellation as an untyped bail routed
            // through Error::Exec; retype it so callers can match
            err @ Err(Error::Cancelled(_)) => err,
            Err(e) => match cancel.cancelled() {
                Some(reason) => Err(Error::Cancelled(reason)),
                None => Err(e),
            },
            ok => ok,
        }
    }

    fn run_exec(
        &self,
        spec: &JobSpec,
        cancel: &CancelToken,
        emit: &mut dyn FnMut(JobEvent),
    ) -> Result<JobOutput, Error> {
        let t0 = std::time::Instant::now();
        let model = self.model(&spec.model)?;
        spec.validate(model)?;
        if spec.verbose {
            eprintln!(
                "[pipeline] {} {}: {}",
                spec.model,
                spec.method.as_str(),
                spec.describe_stages()
            );
        }
        // Emits Stage start/finish around `body`, attributing any cache
        // outcomes recorded on this thread since the previous boundary.
        // Each stage entry is a cancellation checkpoint: an expired
        // deadline or a `ctl cancel` stops the job *between* stages, so
        // no partially-built artifact is ever published.
        macro_rules! stage {
            ($name:expr, $body:expr) => {{
                if let Some(reason) = cancel.cancelled() {
                    return Err(Error::Cancelled(reason));
                }
                emit(JobEvent::Stage { stage: $name, done: false });
                let r = $body;
                for (key, outcome) in cache::trace_drain() {
                    emit(JobEvent::Cache { key, outcome });
                }
                emit(JobEvent::Stage { stage: $name, done: true });
                r
            }};
        }

        // FpWeights
        let fpw = stage!("fp-weights", self.fp_weights(&spec.model))?;
        // Calib
        let calib = if spec.needs_calib() {
            Some(stage!(
                "calib",
                self.calib_set(
                    &spec.model,
                    spec.source,
                    spec.calib_n,
                    spec.seed,
                )
            )?)
        } else {
            None
        };
        // Sensitivity + MpSearch
        let ga_abits = spec.abits.unwrap_or(8);
        let search = match &spec.search {
            Some(hb) => Some(stage!(
                "mp-search",
                self.search_stage(model, spec, hb, ga_abits)
            )?),
            None => None,
        };
        // bit assignment: GA result, the uniform policy, or — for an Fp
        // job without a search — the full-precision reference (reported
        // as all-8, the convention of `EvalParams::fp` and the hw
        // simulators' base cost)
        let bits = match &search {
            Some(res) => BitConfig::mixed(
                res.wbits.clone(),
                ga_abits,
                spec.abits.is_some(),
            ),
            None if spec.method == Method::Fp => {
                BitConfig::uniform(model, 8, None, false)
            }
            None => BitConfig::uniform(
                model,
                spec.wbits,
                spec.abits,
                spec.first_last_8,
            ),
        };
        // Reconstruct
        let quantized = if spec.method == Method::Fp {
            None
        } else {
            let calib = calib
                .as_ref()
                .expect("reconstruction always has a calibration set");
            Some(stage!(
                "reconstruct",
                self.reconstruct(model, spec, calib, &bits, &cancel)
            )?)
        };
        // Eval: top-1 accuracy for classification models, mAP for the
        // detection family — both on the model's own held-out test set
        let acc = if spec.eval {
            let a = stage!(
                "eval",
                self.eval_stage(model, spec, &fpw, &quantized, &bits)
            )?;
            Some(a)
        } else {
            None
        };
        // HwReport
        let hw = if spec.hw_report {
            Some(stage!(
                "hw-report",
                hw_report(model, &bits.wbits, ga_abits)
            ))
        } else {
            None
        };

        Ok(JobOutput {
            spec: spec.clone(),
            fp_acc: model.fp_acc,
            wbits: bits.wbits.clone(),
            accuracy: acc,
            search,
            hw,
            quantized: quantized.map(|q| (*q).clone()),
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Execute a batch of jobs concurrently on the worker pool. Results
    /// come back in spec order and are bit-identical to calling
    /// [`Session::run`] sequentially (see the module docs).
    pub fn run_many(
        &self,
        specs: &[JobSpec],
    ) -> Vec<Result<JobOutput, Error>> {
        pool::par_fill(specs.len(), 1, usize::MAX, |i| self.run(&specs[i]))
    }

    fn search_stage(
        &self,
        model: &ModelInfo,
        spec: &JobSpec,
        hb: &HwBudget,
        abits: usize,
    ) -> Result<SearchResult, Error> {
        // prefetched so the builder below never re-enters the cache
        let table = self.sensitivity(
            &spec.model,
            spec.source,
            spec.calib_n,
            spec.seed,
        )?;
        let key = format!(
            "mp/{}/{}/{}/{}/{}/{:016x}/{}/{abits}",
            spec.model,
            spec.source.as_str(),
            spec.calib_n,
            spec.seed,
            hb.hw.as_str(),
            hb.budget.to_bits(),
            hb.relative as u8,
        );
        let res = self.cache.get_or_build(&key, || {
            let measurer = hb.hw.measurer();
            let budget = hb.resolve(model, measurer.as_ref(), abits);
            let ga = GeneticSearch {
                model,
                table: &table,
                hw: measurer.as_ref(),
                abits,
                budget,
            };
            Ok(ga.run(&GaConfig {
                seed: spec.seed,
                ..GaConfig::default()
            })?)
        })?;
        Ok((*res).clone())
    }

    /// `Reconstruct` stage: method dispatch over the shared engine,
    /// persisted under [`Session::recon_key`]. BRECQ honors the spec's
    /// granularity directly — there is no special-cased non-block path
    /// anymore.
    ///
    /// Store-backed sessions run the calibrate methods under a
    /// [`StoreCheckpointer`]: every committed unit publishes a resumable
    /// checkpoint at `{recon_key}/ckpt/<unit_idx>`, and a rerun of the
    /// same key replays the valid checkpoint prefix instead of
    /// recomputing it — bitwise identical to an uninterrupted run. Once
    /// the final artifact commits the checkpoints are superseded and
    /// removed (they live in the pinned `ckpt/` namespace, outside the
    /// eviction scan, so leaks would otherwise be permanent).
    fn reconstruct(
        &self,
        model: &ModelInfo,
        spec: &JobSpec,
        calib: &CalibSet,
        bits: &BitConfig,
        cancel: &CancelToken,
    ) -> Result<Arc<QuantizedModel>, Error> {
        let key = self.recon_key(spec, bits);
        let ckpt = match (self.cache.store(), spec.method) {
            // Omse/BiasCorr never calibrate — nothing to checkpoint.
            (Some(_), Method::Omse | Method::BiasCorr) => None,
            (Some(st), _) => {
                Some(Arc::new(StoreCheckpointer::new(st.clone(), &key)))
            }
            (None, _) => None,
        };
        let out = self.cache.get_or_build(&key, || {
            if let Some(c) = &ckpt {
                c.ran.store(true, Ordering::Relaxed);
            }
            let cal = Calibrator::new(&self.env.rt, &self.env.mf, model);
            let base = ReconConfig {
                iters: spec.iters,
                seed: spec.seed,
                verbose: spec.verbose,
                cancel: cancel.clone(),
                ckpt: CkptHook(ckpt.clone().map(|c| {
                    c as Arc<dyn UnitCheckpointer>
                })),
                ..ReconConfig::default()
            };
            let qm: Result<QuantizedModel, Error> = (|| {
                Ok(match spec.method {
                    Method::Fp => {
                        unreachable!("Fp skips the Reconstruct stage")
                    }
                    Method::Brecq => cal.calibrate(
                        calib,
                        bits,
                        &baselines::brecq_cfg(&base, spec.gran.as_str()),
                    )?,
                    Method::AdaRoundLayer => cal.calibrate(
                        calib,
                        bits,
                        &baselines::adaround_layer_cfg(&base),
                    )?,
                    Method::AdaQuantLike => cal.calibrate(
                        calib,
                        bits,
                        &baselines::adaquant_like_cfg(&base),
                    )?,
                    Method::Omse => baselines::omse(
                        &self.env.rt,
                        &self.env.mf,
                        model,
                        calib,
                        bits,
                    )?,
                    Method::BiasCorr => baselines::bias_correction(
                        &self.env.rt,
                        &self.env.mf,
                        model,
                        calib,
                        bits,
                    )?,
                })
            })();
            // Tally on success AND failure (a cancelled/deadline-expired
            // job's checkpoint progress must show in stats), and before
            // get_or_build records its own outcome so the per-unit
            // Resumed trace events precede this key's Computed.
            if let Some(c) = &ckpt {
                let (r, w, co) = c.counts();
                self.cache.note_ckpt(&key, r, w, co);
            }
            qm
        })?;
        // Reached only with the final artifact committed (computed and
        // published above, or already present): the checkpoints are now
        // superseded. The `contains` probe also clears stale checkpoints
        // left by a process that crashed between publish and cleanup —
        // this run then memory-/store-hit without ever reading them. An
        // error return skips this, deliberately: those checkpoints are
        // the resume state.
        if let Some(c) = &ckpt {
            if c.ran.load(Ordering::Relaxed) || c.store.contains(&c.key(0))
            {
                for ui in 0..out.reports.len() {
                    c.store.remove(&c.key(ui));
                }
            }
        }
        Ok(out)
    }

    /// `Eval` stage: held-out score, persisted so a warm replay never
    /// re-runs the forward pass. Quantized evals key off the recon key
    /// (whose bits digest pins the exact assignment); FP evals are per
    /// model. The NMS flag is part of the key — it changes the score.
    fn eval_stage(
        &self,
        model: &ModelInfo,
        spec: &JobSpec,
        fpw: &FpWeights,
        quantized: &Option<Arc<QuantizedModel>>,
        bits: &BitConfig,
    ) -> Result<f64, Error> {
        // prefetched so the builder below never re-enters the cache
        let test = self.test_set_for(&spec.model)?;
        let key = match quantized {
            Some(_) => format!(
                "{}/eval/nms{}",
                self.recon_key(spec, bits),
                spec.det_nms as u8
            ),
            None => format!(
                "eval/fp/{}/nms{}",
                spec.model, spec.det_nms as u8
            ),
        };
        let score = self.cache.get_or_build(&key, || {
            let p = match quantized {
                Some(qm) => EvalParams::quantized(qm),
                None => EvalParams::fp(model, &fpw.ws, &fpw.bs),
            };
            let a = match &model.det {
                Some(det) => map_score(
                    &self.env.rt,
                    model,
                    det,
                    &p,
                    &test,
                    spec.det_nms,
                )?,
                None => accuracy(&self.env.rt, model, &p, &test)?,
            };
            Ok(EvalScore(a))
        })?;
        Ok(score.0)
    }
}

/// Store-backed [`UnitCheckpointer`]: publishes one artifact per
/// committed reconstruction unit at `{recon_key}/ckpt/<unit_idx>` (the
/// pinned `ckpt/` store namespace — never evicted by `evict_to_cap`)
/// and replays them on a rerun of the same key. A load that fails
/// verification, carries the wrong kind, or describes a different unit
/// shape is discarded as corrupt — exactly that unit recomputes. A
/// failed save is logged and skipped: the job stays correct, it just
/// loses resume granularity for that unit. `ckpt.load` / `ckpt.save`
/// are fault-injection sites over and above the store's own IO sites,
/// so the chaos suite can target the checkpoint paths specifically.
struct StoreCheckpointer {
    store: Arc<ArtifactStore>,
    base: String,
    resumed: AtomicUsize,
    written: AtomicUsize,
    corrupt: AtomicUsize,
    /// Set at builder entry: distinguishes "computed (cleanup owed)"
    /// from a memory/store hit that never touched checkpoints.
    ran: AtomicBool,
}

impl StoreCheckpointer {
    fn new(store: Arc<ArtifactStore>, recon_key: &str) -> Self {
        StoreCheckpointer {
            store,
            base: recon_key.to_string(),
            resumed: AtomicUsize::new(0),
            written: AtomicUsize::new(0),
            corrupt: AtomicUsize::new(0),
            ran: AtomicBool::new(false),
        }
    }

    fn key(&self, ui: usize) -> String {
        format!("{}/ckpt/{ui}", self.base)
    }

    /// (resumed, written, corrupt) so far.
    fn counts(&self) -> (usize, usize, usize) {
        (
            self.resumed.load(Ordering::Relaxed),
            self.written.load(Ordering::Relaxed),
            self.corrupt.load(Ordering::Relaxed),
        )
    }

    fn discard(&self, key: &str, why: &str) {
        self.store.discard_corrupt(key, why);
        self.corrupt.fetch_add(1, Ordering::Relaxed);
    }
}

impl UnitCheckpointer for StoreCheckpointer {
    fn load(
        &self,
        ui: usize,
        unit: &str,
        layers: usize,
    ) -> Option<UnitCheckpoint> {
        match faults::check("ckpt.load") {
            Some(faults::Kind::Panic) => {
                panic!("injected panic at ckpt.load (unit '{unit}')")
            }
            // An injected read fault is a miss: the unit recomputes.
            Some(_) => return None,
            None => {}
        }
        let key = self.key(ui);
        let blob = match self.store.load_entry(&key) {
            Loaded::Hit(b) => b,
            Loaded::Miss => return None,
            Loaded::Corrupt => {
                self.corrupt.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        if blob.kind() != UnitCheckpoint::KIND {
            self.discard(
                &key,
                &format!(
                    "kind mismatch ('{}' != '{}')",
                    blob.kind(),
                    UnitCheckpoint::KIND
                ),
            );
            return None;
        }
        let ck = match UnitCheckpoint::decode(&blob) {
            Ok(c) => c,
            Err(e) => {
                self.discard(&key, &format!("decode failed: {e}"));
                return None;
            }
        };
        if ck.report.name != unit
            || ck.qweights.len() != layers
            || ck.act_steps.len() != layers
        {
            self.discard(
                &key,
                &format!(
                    "checkpoint is for unit '{}' ({} layers), expected \
                     '{unit}' ({layers})",
                    ck.report.name,
                    ck.qweights.len()
                ),
            );
            return None;
        }
        self.resumed.fetch_add(1, Ordering::Relaxed);
        Some(ck)
    }

    fn save(&self, ui: usize, ckpt: &UnitCheckpoint) {
        match faults::check("ckpt.save") {
            Some(faults::Kind::Panic) => {
                panic!("injected panic at ckpt.save (unit {ui})")
            }
            Some(_) => {
                eprintln!(
                    "[ckpt] injected fault at ckpt.save (unit {ui}) — \
                     checkpoint skipped"
                );
                return;
            }
            None => {}
        }
        let key = self.key(ui);
        // Best-effort by design: a full disk must not fail the job.
        match self.store.publish(&key, &ckpt.encode()) {
            Ok(()) => {
                self.written.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                eprintln!("[ckpt] {e}; unit {ui} will recompute on resume")
            }
        }
    }
}
