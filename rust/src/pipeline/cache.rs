//! Content-keyed artifact cache backing a [`super::Session`].
//!
//! Every stage artifact the pipeline produces — FP deploy weights,
//! calibration subsets, distilled data, sensitivity LUTs, the datasets
//! themselves — is a *deterministic* function of its cache key (all
//! producing computations are seeded), so two jobs that agree on a key can
//! share one artifact with no effect on results. That is what makes
//! [`super::Session::run_many`] bit-identical to sequential execution:
//! whichever job populates a slot first, the value is the same.
//!
//! Concurrency: one mutex guards the key→slot map and a second, per-slot
//! mutex guards each value. A builder runs while *holding its own slot's
//! lock*, so two jobs racing for the same artifact serialize and the
//! second gets a hit instead of recomputing — the compute-once guarantee
//! the cache-hit tests pin down via backend dispatch accounting. Builders
//! never re-enter the cache (dependencies are fetched *before* a slot is
//! claimed), so slot locks are never nested and cannot deadlock.

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::Error;

/// One cache slot: the artifact, type-erased. The slot-level mutex is the
/// compute-once serialization point for that key.
struct Slot {
    value: Mutex<Option<Arc<dyn Any + Send + Sync>>>,
}

/// Key→artifact store shared by every job a session runs.
#[derive(Default)]
pub struct ArtifactCache {
    slots: Mutex<HashMap<String, Arc<Slot>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl ArtifactCache {
    pub fn new() -> ArtifactCache {
        ArtifactCache::default()
    }

    /// Fetch the artifact under `key`, building it with `build` on the
    /// first request. Concurrent requests for the same key block on the
    /// slot and observe the first builder's value. A failed build leaves
    /// the slot empty, so a later request retries.
    pub fn get_or_try_insert<T, F>(&self, key: &str, build: F)
        -> Result<Arc<T>, Error>
    where
        T: Any + Send + Sync,
        F: FnOnce() -> Result<T, Error>,
    {
        let slot = {
            let mut slots =
                self.slots.lock().unwrap_or_else(|e| e.into_inner());
            slots
                .entry(key.to_string())
                .or_insert_with(|| {
                    Arc::new(Slot { value: Mutex::new(None) })
                })
                .clone()
        };
        let mut value =
            slot.value.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(v) = value.as_ref() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return v.clone().downcast::<T>().map_err(|_| {
                Error::Spec(format!(
                    "artifact cache type mismatch for key '{key}'"
                ))
            });
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(build()?);
        *value = Some(built.clone());
        Ok(built)
    }

    /// (hits, misses) since the session started.
    pub fn stats(&self) -> (usize, usize) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Number of populated or in-flight keys.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn computes_once_and_hits_after() {
        let c = ArtifactCache::new();
        let mut calls = 0usize;
        let a: Arc<Vec<u32>> = c
            .get_or_try_insert("k", || {
                calls += 1;
                Ok(vec![1, 2, 3])
            })
            .unwrap();
        let b: Arc<Vec<u32>> = c
            .get_or_try_insert("k", || {
                calls += 1;
                Ok(vec![9, 9, 9])
            })
            .unwrap();
        assert_eq!(calls, 1);
        assert_eq!(*a, vec![1, 2, 3]);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn failed_build_retries() {
        let c = ArtifactCache::new();
        let r: Result<Arc<u32>, Error> =
            c.get_or_try_insert("k", || Err(Error::Spec("boom".into())));
        assert!(r.is_err());
        let v: Arc<u32> = c.get_or_try_insert("k", || Ok(7)).unwrap();
        assert_eq!(*v, 7);
        // both attempts were misses (the failure cached nothing)
        assert_eq!(c.stats(), (0, 2));
    }

    #[test]
    fn type_mismatch_is_a_typed_error() {
        let c = ArtifactCache::new();
        let _: Arc<u32> = c.get_or_try_insert("k", || Ok(1)).unwrap();
        let r: Result<Arc<String>, Error> =
            c.get_or_try_insert("k", || Ok("x".to_string()));
        assert!(matches!(r, Err(Error::Spec(_))));
    }

    #[test]
    fn concurrent_same_key_builds_once() {
        let c = ArtifactCache::new();
        let built = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let v: Arc<usize> = c
                        .get_or_try_insert("shared", || {
                            built.fetch_add(1, Ordering::Relaxed);
                            Ok(42)
                        })
                        .unwrap();
                    assert_eq!(*v, 42);
                });
            }
        });
        assert_eq!(built.load(Ordering::Relaxed), 1);
    }
}
