//! Content-keyed artifact cache backing a [`super::Session`].
//!
//! Every stage artifact the pipeline produces — FP deploy weights,
//! calibration subsets, distilled data, sensitivity LUTs, the datasets
//! themselves — is a *deterministic* function of its cache key (all
//! producing computations are seeded), so two jobs that agree on a key can
//! share one artifact with no effect on results. That is what makes
//! [`super::Session::run_many`] bit-identical to sequential execution:
//! whichever job populates a slot first, the value is the same.
//!
//! Concurrency: one mutex guards the key→slot map and a second, per-slot
//! mutex guards each value. A builder runs while *holding its own slot's
//! lock*, so two jobs racing for the same artifact serialize and the
//! second gets a hit instead of recomputing — the compute-once guarantee
//! the cache-hit tests pin down via backend dispatch accounting. Builders
//! never re-enter the cache (dependencies are fetched *before* a slot is
//! claimed), so slot locks are never nested and cannot deadlock.
//!
//! Persistence: a cache can be layered over an on-disk
//! [`ArtifactStore`](super::artifact_store::ArtifactStore) via
//! [`ArtifactCache::with_store`]. [`ArtifactCache::get_or_build`] then
//! resolves a miss from disk before computing, and publishes what it
//! computes — while holding the store's cross-process entry lock, so of N
//! *processes* racing a cold key exactly one computes. Memory-only values
//! (datasets, distilled batches) keep using
//! [`ArtifactCache::get_or_try_insert`] and are counted as
//! [`Outcome::Loaded`], not computes: a warm-store replay reports zero
//! computes even though it re-reads datasets from the manifest.

use std::any::Any;
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::artifact_store::{Artifact, ArtifactStore};
use super::Error;

/// How a cache request was satisfied. Streamed per key to `serve`
/// clients and aggregated into [`SlotStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Served from this process's memory.
    Hit,
    /// Loaded from the on-disk artifact store (no backend work).
    StoreHit,
    /// Built by running the stage computation.
    Computed,
    /// Built in memory from local inputs (datasets, distilled batches)
    /// — backend-free, so not counted as a compute.
    Loaded,
    /// One reconstruction unit restored from a per-unit checkpoint
    /// instead of recomputed (emitted per unit under the recon key by
    /// [`ArtifactCache::note_ckpt`], alongside the final build outcome).
    Resumed,
}

impl Outcome {
    pub fn as_str(self) -> &'static str {
        match self {
            Outcome::Hit => "hit",
            Outcome::StoreHit => "store-hit",
            Outcome::Computed => "computed",
            Outcome::Loaded => "loaded",
            Outcome::Resumed => "resumed",
        }
    }
}

/// Per-key tally of [`Outcome`]s, surfaced by `brecq run --stats`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SlotStats {
    pub hits: usize,
    pub store_hits: usize,
    pub computes: usize,
    pub loads: usize,
    pub resumed: usize,
}

impl SlotStats {
    fn bump(&mut self, o: Outcome) {
        match o {
            Outcome::Hit => self.hits += 1,
            Outcome::StoreHit => self.store_hits += 1,
            Outcome::Computed => self.computes += 1,
            Outcome::Loaded => self.loads += 1,
            Outcome::Resumed => self.resumed += 1,
        }
    }
}

thread_local! {
    /// Per-thread outcome trace: when armed (by `Session::run_traced`),
    /// every cache request on this thread records (key, outcome) so the
    /// daemon can attribute cache events to the job that triggered them.
    static TRACE: RefCell<Option<Vec<(String, Outcome)>>> =
        const { RefCell::new(None) };
}

/// Arm the calling thread's outcome trace (drops any previous one).
pub(crate) fn trace_begin() {
    TRACE.with(|t| *t.borrow_mut() = Some(Vec::new()));
}

/// Take the outcomes recorded since the last drain, leaving the trace
/// armed. No-op (empty) on an unarmed thread.
pub(crate) fn trace_drain() -> Vec<(String, Outcome)> {
    TRACE.with(|t| {
        t.borrow_mut().as_mut().map(std::mem::take).unwrap_or_default()
    })
}

/// Disarm the calling thread's trace, returning anything undrained.
pub(crate) fn trace_end() -> Vec<(String, Outcome)> {
    TRACE.with(|t| t.borrow_mut().take().unwrap_or_default())
}

fn trace_push(key: &str, o: Outcome) {
    TRACE.with(|t| {
        if let Some(v) = t.borrow_mut().as_mut() {
            v.push((key.to_string(), o));
        }
    });
}

/// One cache slot: the artifact, type-erased. The slot-level mutex is the
/// compute-once serialization point for that key.
struct Slot {
    value: Mutex<Option<Arc<dyn Any + Send + Sync>>>,
}

/// Key→artifact store shared by every job a session runs.
#[derive(Default)]
pub struct ArtifactCache {
    slots: Mutex<HashMap<String, Arc<Slot>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    computes: AtomicUsize,
    store_hits: AtomicUsize,
    units_resumed: AtomicUsize,
    ckpt_written: AtomicUsize,
    ckpt_corrupt: AtomicUsize,
    per_key: Mutex<BTreeMap<String, SlotStats>>,
    store: Option<Arc<ArtifactStore>>,
}

impl ArtifactCache {
    pub fn new() -> ArtifactCache {
        ArtifactCache::default()
    }

    /// A cache persisting its [`Artifact`]-typed slots to `store`.
    pub fn with_store(store: Arc<ArtifactStore>) -> ArtifactCache {
        ArtifactCache { store: Some(store), ..ArtifactCache::default() }
    }

    /// The on-disk layer, if this cache has one.
    pub fn store(&self) -> Option<&Arc<ArtifactStore>> {
        self.store.as_ref()
    }

    fn record(&self, key: &str, o: Outcome) {
        let mut per_key =
            self.per_key.lock().unwrap_or_else(|e| e.into_inner());
        per_key.entry(key.to_string()).or_default().bump(o);
        drop(per_key);
        trace_push(key, o);
    }

    fn claim_slot(&self, key: &str) -> Arc<Slot> {
        let mut slots =
            self.slots.lock().unwrap_or_else(|e| e.into_inner());
        slots
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(Slot { value: Mutex::new(None) }))
            .clone()
    }

    fn typed<T: Any + Send + Sync>(
        key: &str,
        v: Arc<dyn Any + Send + Sync>,
    ) -> Result<Arc<T>, Error> {
        v.downcast::<T>().map_err(|_| {
            Error::Spec(format!(
                "artifact cache type mismatch for key '{key}'"
            ))
        })
    }

    /// Fetch the artifact under `key`, building it with `build` on the
    /// first request. Concurrent requests for the same key block on the
    /// slot and observe the first builder's value. A failed build leaves
    /// the slot empty, so a later request retries. Memory-only: the value
    /// never touches the store, and a build counts as [`Outcome::Loaded`].
    pub fn get_or_try_insert<T, F>(&self, key: &str, build: F)
        -> Result<Arc<T>, Error>
    where
        T: Any + Send + Sync,
        F: FnOnce() -> Result<T, Error>,
    {
        let slot = self.claim_slot(key);
        let mut value =
            slot.value.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(v) = value.as_ref() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.record(key, Outcome::Hit);
            return Self::typed(key, v.clone());
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(build()?);
        *value = Some(built.clone());
        self.record(key, Outcome::Loaded);
        Ok(built)
    }

    /// Like [`Self::get_or_try_insert`], but for persistable artifacts:
    /// a memory miss first tries the on-disk store (under the store's
    /// cross-process entry lock), and a computed value is published back.
    /// Without a store this degrades to the memory path, except the build
    /// counts as a real [`Outcome::Computed`].
    pub fn get_or_build<T, F>(&self, key: &str, build: F)
        -> Result<Arc<T>, Error>
    where
        T: Artifact + Any,
        F: FnOnce() -> Result<T, Error>,
    {
        let slot = self.claim_slot(key);
        let mut value =
            slot.value.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(v) = value.as_ref() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.record(key, Outcome::Hit);
            return Self::typed(key, v.clone());
        }
        self.misses.fetch_add(1, Ordering::Relaxed);

        // Hold the cross-process lock over load→compute→publish so that
        // of N processes racing this cold key, exactly one computes. A
        // lock failure (exotic filesystem) degrades to computing without
        // compute-once across processes — still correct, just slower.
        let guard = match &self.store {
            Some(st) => match st.lock(key) {
                Ok(g) => Some(g),
                Err(e) => {
                    eprintln!("[store] {e}; continuing unlocked");
                    None
                }
            },
            None => None,
        };

        if let Some(st) = &self.store {
            if let Some(blob) = st.load(key) {
                if blob.kind() == T::KIND {
                    match T::decode(&blob) {
                        Ok(v) => {
                            let built = Arc::new(v);
                            *value = Some(built.clone());
                            self.store_hits
                                .fetch_add(1, Ordering::Relaxed);
                            self.record(key, Outcome::StoreHit);
                            drop(guard);
                            return Ok(built);
                        }
                        Err(e) => st.discard_corrupt(
                            key,
                            &format!("decode failed: {e}"),
                        ),
                    }
                } else {
                    st.discard_corrupt(
                        key,
                        &format!(
                            "kind mismatch ('{}' != '{}')",
                            blob.kind(),
                            T::KIND
                        ),
                    );
                }
            }
        }

        let built = Arc::new(build()?);
        if let Some(st) = &self.store {
            // A publish failure (disk full, permissions) must not kill
            // the job — the artifact is in memory and correct.
            if let Err(e) = st.publish(key, &built.encode()) {
                eprintln!("[store] {e}; artifact kept in memory only");
            }
        }
        drop(guard);
        *value = Some(built.clone());
        self.computes.fetch_add(1, Ordering::Relaxed);
        self.record(key, Outcome::Computed);
        Ok(built)
    }

    /// (hits, misses) since the session started.
    pub fn stats(&self) -> (usize, usize) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Stage computations actually run (misses neither in memory nor on
    /// disk, excluding memory-only loads). Zero across a warm-store
    /// replay — the acceptance criterion `serve` asserts in CI.
    pub fn computes(&self) -> usize {
        self.computes.load(Ordering::Relaxed)
    }

    /// Memory misses resolved from the on-disk store.
    pub fn store_hits(&self) -> usize {
        self.store_hits.load(Ordering::Relaxed)
    }

    /// Checkpoint-resume accounting for the recon stage: `resumed`
    /// units restored from per-unit checkpoints instead of recomputed
    /// (recorded per key and traced as one [`Outcome::Resumed`] event
    /// each, so the daemon can attribute them per batch), `written`
    /// checkpoints published, `corrupt` checkpoint entries discarded.
    pub fn note_ckpt(
        &self,
        key: &str,
        resumed: usize,
        written: usize,
        corrupt: usize,
    ) {
        self.units_resumed.fetch_add(resumed, Ordering::Relaxed);
        self.ckpt_written.fetch_add(written, Ordering::Relaxed);
        self.ckpt_corrupt.fetch_add(corrupt, Ordering::Relaxed);
        if resumed > 0 {
            let mut per_key =
                self.per_key.lock().unwrap_or_else(|e| e.into_inner());
            per_key.entry(key.to_string()).or_default().resumed +=
                resumed;
            drop(per_key);
            for _ in 0..resumed {
                trace_push(key, Outcome::Resumed);
            }
        }
    }

    /// Reconstruction units restored from per-unit checkpoints instead
    /// of recomputed.
    pub fn units_resumed(&self) -> usize {
        self.units_resumed.load(Ordering::Relaxed)
    }

    /// Per-unit checkpoints published by the recon stage.
    pub fn ckpt_written(&self) -> usize {
        self.ckpt_written.load(Ordering::Relaxed)
    }

    /// Checkpoint entries that failed verification/decode and were
    /// discarded (each one cost exactly one recomputed unit).
    pub fn ckpt_corrupt(&self) -> usize {
        self.ckpt_corrupt.load(Ordering::Relaxed)
    }

    /// Per-key outcome tallies, sorted by key (`brecq run --stats`).
    pub fn per_key_stats(&self) -> Vec<(String, SlotStats)> {
        self.per_key
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(k, s)| (k.clone(), *s))
            .collect()
    }

    /// Number of populated or in-flight keys.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn computes_once_and_hits_after() {
        let c = ArtifactCache::new();
        let mut calls = 0usize;
        let a: Arc<Vec<u32>> = c
            .get_or_try_insert("k", || {
                calls += 1;
                Ok(vec![1, 2, 3])
            })
            .unwrap();
        let b: Arc<Vec<u32>> = c
            .get_or_try_insert("k", || {
                calls += 1;
                Ok(vec![9, 9, 9])
            })
            .unwrap();
        assert_eq!(calls, 1);
        assert_eq!(*a, vec![1, 2, 3]);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn failed_build_retries() {
        let c = ArtifactCache::new();
        let r: Result<Arc<u32>, Error> =
            c.get_or_try_insert("k", || Err(Error::Spec("boom".into())));
        assert!(r.is_err());
        let v: Arc<u32> = c.get_or_try_insert("k", || Ok(7)).unwrap();
        assert_eq!(*v, 7);
        // both attempts were misses (the failure cached nothing)
        assert_eq!(c.stats(), (0, 2));
    }

    #[test]
    fn type_mismatch_is_a_typed_error() {
        let c = ArtifactCache::new();
        let _: Arc<u32> = c.get_or_try_insert("k", || Ok(1)).unwrap();
        let r: Result<Arc<String>, Error> =
            c.get_or_try_insert("k", || Ok("x".to_string()));
        assert!(matches!(r, Err(Error::Spec(_))));
    }

    #[test]
    fn concurrent_same_key_builds_once() {
        let c = ArtifactCache::new();
        let built = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let v: Arc<usize> = c
                        .get_or_try_insert("shared", || {
                            built.fetch_add(1, Ordering::Relaxed);
                            Ok(42)
                        })
                        .unwrap();
                    assert_eq!(*v, 42);
                });
            }
        });
        assert_eq!(built.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn memory_only_builds_are_loads_not_computes() {
        let c = ArtifactCache::new();
        let _: Arc<u32> = c.get_or_try_insert("k", || Ok(1)).unwrap();
        let _: Arc<u32> = c.get_or_try_insert("k", || Ok(1)).unwrap();
        assert_eq!(c.computes(), 0);
        let per = c.per_key_stats();
        assert_eq!(per.len(), 1);
        assert_eq!(
            per[0].1,
            SlotStats { hits: 1, loads: 1, ..SlotStats::default() }
        );
    }

    #[test]
    fn trace_records_outcomes_per_thread() {
        let c = ArtifactCache::new();
        trace_begin();
        let _: Arc<u32> = c.get_or_try_insert("k", || Ok(1)).unwrap();
        let _: Arc<u32> = c.get_or_try_insert("k", || Ok(1)).unwrap();
        let events = trace_end();
        assert_eq!(
            events,
            vec![
                ("k".to_string(), Outcome::Loaded),
                ("k".to_string(), Outcome::Hit),
            ]
        );
        // a disarmed thread records nothing
        let _: Arc<u32> = c.get_or_try_insert("k2", || Ok(2)).unwrap();
        assert!(trace_end().is_empty());
    }
}
