//! Persistent, content-addressed artifact store — the on-disk layer under
//! [`super::cache::ArtifactCache`].
//!
//! Not to be confused with [`crate::store`], the read-only loader for the
//! *build-time* weight/dataset ABI shared with `python/compile/store.py`.
//! This module persists *computed* pipeline artifacts (FP deploy weights,
//! calibration subsets, sensitivity LUTs, finished reconstructions, eval
//! scores) across processes, so a warm-store job replays bit-identical to
//! a cold run without a single backend dispatch.
//!
//! Layout: one entry per cache key, addressed by a 128-bit FNV-1a hash of
//! the key (the full key is recorded in the index and verified on load,
//! so a hash collision can never serve the wrong artifact):
//!
//! ```text
//!   <store>/<keyhash32hex>.bin    binary payload, little-endian sections
//!   <store>/<keyhash32hex>.json   index: key, kind, sections, checksum
//!   <store>/<keyhash32hex>.lock   cross-process advisory lock (flock)
//!   <store>/ckpt/<keyhash>.*      pinned namespace: keys containing
//!                                 "/ckpt/" (per-unit reconstruction
//!                                 checkpoints) — same entry format, but
//!                                 outside the LRU capacity sweep
//!   <store>/journal/…             write-ahead batch journals (serve.rs),
//!                                 likewise never swept
//! ```
//!
//! Publication is atomic: both files are written to a temp name and
//! `rename(2)`d into place — `.bin` first, `.json` last, so the index is
//! the commit point and a visible index always has its payload. Every
//! f32/f64 value rides in the binary payload, never in JSON text (the
//! [`crate::util::json`] writer does not guarantee round-trip-exact f64
//! formatting); the JSON index carries only structure, names and integer
//! metadata. The payload checksum (FNV-1a 64) is verified on every load:
//! a corrupt or truncated entry is *detected, deleted and recomputed* —
//! never silently served — and counted in [`StoreStats::corrupt`].
//!
//! Compute-once across processes: [`ArtifactStore::lock`] takes an
//! exclusive `flock(2)` on the entry's `.lock` file. The cache holds it
//! over its load→compute→publish window, so of N processes racing a cold
//! key exactly one computes and the rest load the published bits
//! (`rust/tests/qaas.rs` races real processes to pin this).
//!
//! Every IO site (`store.publish`, `store.load`, `store.index`,
//! `store.lock`) classifies errors transient-vs-permanent and retries
//! transients with bounded exponential backoff and deterministic,
//! key-seeded jitter ([`StoreStats::retried`] counts the sleeps). The
//! same four site names are fault-injection points for
//! [`crate::util::faults`] — `$BRECQ_FAULTS="store.publish:io@0.1"`
//! makes a tenth of publishes fail transiently, which the retry loop
//! must absorb bit-identically (pinned by `rust/tests/chaos.rs`).

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::calib::CalibSet;
use crate::mp::SearchResult;
use crate::util::faults;
use crate::util::rng::Rng;
use crate::recon::{BitConfig, QuantizedModel, UnitCheckpoint, UnitReport};
use crate::sensitivity::SensitivityTable;
use crate::tensor::Tensor;
use crate::util::json::{self, Json};

use super::job::FpWeights;
use super::Error;

// ---------------------------------------------------------------------
// Hashing
// ---------------------------------------------------------------------

/// FNV-1a 64 over `bytes` — the payload checksum and the digest helper
/// for composite cache keys (bit vectors, budgets).
pub fn fnv64(bytes: &[u8]) -> u64 {
    fnv64_seeded(0xcbf2_9ce4_8422_2325, bytes)
}

fn fnv64_seeded(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// 128-bit key→path hash as 32 hex chars (two independently seeded FNV-1a
/// 64 streams). Collisions are astronomically unlikely at our key counts,
/// and harmless anyway: the index records the full key and a mismatch is
/// treated as a miss.
fn key_hash(key: &str) -> String {
    let a = fnv64(key.as_bytes());
    let b = fnv64_seeded(0x6c62_272e_07bb_0142, key.as_bytes());
    format!("{a:016x}{b:016x}")
}

// ---------------------------------------------------------------------
// Blob: the codec between typed artifacts and one store entry
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DType {
    F32,
    F64,
    U64,
}

impl DType {
    fn as_str(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::U64 => "u64",
        }
    }

    fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "f64" => Some(DType::F64),
            "u64" => Some(DType::U64),
            _ => None,
        }
    }

    fn width(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 | DType::U64 => 8,
        }
    }
}

#[derive(Debug, Clone)]
struct Section {
    name: String,
    dtype: DType,
    shape: Vec<usize>,
    /// Byte offset into the payload.
    off: usize,
    /// Element count.
    len: usize,
}

/// One store entry in memory: a named, typed set of binary sections plus
/// integer/string JSON metadata. [`Artifact`] implementations encode into
/// and decode out of this; the store handles the bytes on disk.
#[derive(Debug, Clone)]
pub struct Blob {
    kind: String,
    meta: BTreeMap<String, Json>,
    sections: Vec<Section>,
    bytes: Vec<u8>,
}

impl Blob {
    pub fn new(kind: &str) -> Blob {
        Blob {
            kind: kind.to_string(),
            meta: BTreeMap::new(),
            sections: Vec::new(),
            bytes: Vec::new(),
        }
    }

    pub fn kind(&self) -> &str {
        &self.kind
    }

    pub fn payload_len(&self) -> usize {
        self.bytes.len()
    }

    /// Attach a metadata value. Structure only — never put an f32/f64
    /// payload value here (JSON text is not bit-round-trip-exact); use a
    /// binary section.
    pub fn set_meta(&mut self, key: &str, v: Json) {
        self.meta.insert(key.to_string(), v);
    }

    pub fn meta(&self, key: &str) -> Option<&Json> {
        self.meta.get(key)
    }

    fn meta_usize(&self, key: &str) -> Result<usize, Error> {
        self.meta(key).and_then(Json::as_usize).ok_or_else(|| {
            Error::Exec(format!(
                "store blob '{}': missing integer meta '{key}'",
                self.kind
            ))
        })
    }

    fn push(&mut self, name: &str, dtype: DType, shape: Vec<usize>,
            len: usize) {
        self.sections.push(Section {
            name: name.to_string(),
            dtype,
            shape,
            off: self.bytes.len() - len * dtype.width(),
            len,
        });
    }

    pub fn push_f32s(&mut self, name: &str, shape: Vec<usize>,
                     vals: &[f32]) {
        for v in vals {
            self.bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.push(name, DType::F32, shape, vals.len());
    }

    pub fn push_tensor(&mut self, name: &str, t: &Tensor) {
        self.push_f32s(name, t.shape.clone(), &t.data);
    }

    pub fn push_f64s(&mut self, name: &str, vals: &[f64]) {
        for v in vals {
            self.bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.push(name, DType::F64, vec![vals.len()], vals.len());
    }

    pub fn push_u64s(&mut self, name: &str, vals: &[u64]) {
        for v in vals {
            self.bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.push(name, DType::U64, vec![vals.len()], vals.len());
    }

    fn find(&self, name: &str, dtype: DType) -> Result<&Section, Error> {
        self.sections
            .iter()
            .find(|s| s.name == name && s.dtype == dtype)
            .ok_or_else(|| {
                Error::Exec(format!(
                    "store blob '{}': missing {} section '{name}'",
                    self.kind,
                    dtype.as_str()
                ))
            })
    }

    pub fn f32s(&self, name: &str) -> Result<Vec<f32>, Error> {
        let s = self.find(name, DType::F32)?;
        Ok(self.bytes[s.off..s.off + s.len * 4]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn tensor(&self, name: &str) -> Result<Tensor, Error> {
        let shape = self.find(name, DType::F32)?.shape.clone();
        Ok(Tensor::new(shape, self.f32s(name)?))
    }

    pub fn f64s(&self, name: &str) -> Result<Vec<f64>, Error> {
        let s = self.find(name, DType::F64)?;
        Ok(self.bytes[s.off..s.off + s.len * 8]
            .chunks_exact(8)
            .map(|b| {
                f64::from_le_bytes([
                    b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
                ])
            })
            .collect())
    }

    pub fn u64s(&self, name: &str) -> Result<Vec<u64>, Error> {
        let s = self.find(name, DType::U64)?;
        Ok(self.bytes[s.off..s.off + s.len * 8]
            .chunks_exact(8)
            .map(|b| {
                u64::from_le_bytes([
                    b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
                ])
            })
            .collect())
    }

    pub fn usizes(&self, name: &str) -> Result<Vec<usize>, Error> {
        Ok(self.u64s(name)?.into_iter().map(|v| v as usize).collect())
    }

    /// The JSON index document for this blob under `key`.
    fn index_json(&self, key: &str) -> Json {
        let sections: Vec<Json> = self
            .sections
            .iter()
            .map(|s| {
                json::obj(vec![
                    ("name", json::s(&s.name)),
                    ("dtype", json::s(s.dtype.as_str())),
                    (
                        "shape",
                        Json::Arr(
                            s.shape
                                .iter()
                                .map(|&d| json::num(d as f64))
                                .collect(),
                        ),
                    ),
                    ("off", json::num(s.off as f64)),
                    ("len", json::num(s.len as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("v", json::num(1.0)),
            ("key", json::s(key)),
            ("kind", json::s(&self.kind)),
            ("bin_len", json::num(self.bytes.len() as f64)),
            (
                "checksum",
                json::s(&format!("{:016x}", fnv64(&self.bytes))),
            ),
            ("meta", Json::Obj(self.meta.clone())),
            ("sections", Json::Arr(sections)),
        ])
    }

    /// Rebuild a blob from a parsed index + verified payload bytes.
    /// Returns a human-readable reason on any structural problem (the
    /// store treats that as corruption).
    fn from_index(idx: &Json, bytes: Vec<u8>) -> Result<Blob, String> {
        let kind = idx
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("index missing 'kind'")?
            .to_string();
        let meta = idx
            .get("meta")
            .and_then(Json::as_obj)
            .cloned()
            .unwrap_or_default();
        let mut sections = Vec::new();
        for s in idx
            .get("sections")
            .and_then(Json::as_arr)
            .ok_or("index missing 'sections'")?
        {
            let name = s
                .get("name")
                .and_then(Json::as_str)
                .ok_or("section missing 'name'")?
                .to_string();
            let dtype = s
                .get("dtype")
                .and_then(Json::as_str)
                .and_then(DType::parse)
                .ok_or("section has bad 'dtype'")?;
            let shape = s
                .get("shape")
                .map(Json::usize_vec)
                .ok_or("section missing 'shape'")?;
            let off = s
                .get("off")
                .and_then(Json::as_usize)
                .ok_or("section missing 'off'")?;
            let len = s
                .get("len")
                .and_then(Json::as_usize)
                .ok_or("section missing 'len'")?;
            let end = off
                .checked_add(len * dtype.width())
                .ok_or("section range overflows")?;
            if end > bytes.len() {
                return Err(format!(
                    "section '{name}' [{off}..{end}) exceeds payload \
                     ({} bytes)",
                    bytes.len()
                ));
            }
            sections.push(Section { name, dtype, shape, off, len });
        }
        Ok(Blob { kind, meta, sections, bytes })
    }
}

// ---------------------------------------------------------------------
// Artifact: what the cache can persist
// ---------------------------------------------------------------------

/// A stage artifact that can round-trip through the store bit-exactly.
/// `decode(encode(x))` must reproduce every result-bearing bit — the
/// warm-replay tests compare fingerprints across processes.
pub trait Artifact: Send + Sync + Sized + 'static {
    /// Stable entry-kind tag, verified on load so a key can never decode
    /// as the wrong type.
    const KIND: &'static str;

    fn encode(&self) -> Blob;
    fn decode(blob: &Blob) -> Result<Self, Error>;
}

impl Artifact for FpWeights {
    const KIND: &'static str = "fp-weights";

    fn encode(&self) -> Blob {
        let mut b = Blob::new(Self::KIND);
        b.set_meta("layers", json::num(self.ws.len() as f64));
        for (i, t) in self.ws.iter().enumerate() {
            b.push_tensor(&format!("w{i}"), t);
        }
        for (i, t) in self.bs.iter().enumerate() {
            b.push_tensor(&format!("b{i}"), t);
        }
        b
    }

    fn decode(b: &Blob) -> Result<FpWeights, Error> {
        let n = b.meta_usize("layers")?;
        let mut ws = Vec::with_capacity(n);
        let mut bs = Vec::with_capacity(n);
        for i in 0..n {
            ws.push(b.tensor(&format!("w{i}"))?);
            bs.push(b.tensor(&format!("b{i}"))?);
        }
        Ok(FpWeights { ws, bs })
    }
}

impl Artifact for CalibSet {
    const KIND: &'static str = "calib-set";

    fn encode(&self) -> Blob {
        let mut b = Blob::new(Self::KIND);
        b.push_tensor("images", &self.images);
        b.push_u64s(
            "labels",
            &self.labels.iter().map(|&l| l as u64).collect::<Vec<_>>(),
        );
        b
    }

    fn decode(b: &Blob) -> Result<CalibSet, Error> {
        Ok(CalibSet {
            images: b.tensor("images")?,
            labels: b.usizes("labels")?,
        })
    }
}

impl Artifact for SensitivityTable {
    const KIND: &'static str = "sensitivity-lut";

    fn encode(&self) -> Blob {
        let mut b = Blob::new(Self::KIND);
        b.set_meta("layers", json::num(self.diag.len() as f64));
        // HashMap iteration order is nondeterministic: flatten both maps
        // through a sorted key order so encode() is a pure function of
        // the table's contents
        let mut dl = Vec::new();
        let mut db = Vec::new();
        let mut dv = Vec::new();
        for (l, per_layer) in self.diag.iter().enumerate() {
            let mut bits: Vec<usize> = per_layer.keys().copied().collect();
            bits.sort_unstable();
            for bit in bits {
                dl.push(l as u64);
                db.push(bit as u64);
                dv.push(per_layer[&bit]);
            }
        }
        b.push_u64s("diag_layer", &dl);
        b.push_u64s("diag_bit", &db);
        b.push_f64s("diag_val", &dv);
        let mut pairs: Vec<(usize, usize)> =
            self.offdiag.keys().copied().collect();
        pairs.sort_unstable();
        let mut oa = Vec::new();
        let mut ob = Vec::new();
        let mut ov = Vec::new();
        for (x, y) in pairs {
            oa.push(x as u64);
            ob.push(y as u64);
            ov.push(self.offdiag[&(x, y)]);
        }
        b.push_u64s("off_a", &oa);
        b.push_u64s("off_b", &ob);
        b.push_f64s("off_val", &ov);
        b.push_f64s("base_loss", &[self.base_loss]);
        b
    }

    fn decode(b: &Blob) -> Result<SensitivityTable, Error> {
        let layers = b.meta_usize("layers")?;
        let mut diag = vec![std::collections::HashMap::new(); layers];
        let (dl, db, dv) =
            (b.usizes("diag_layer")?, b.usizes("diag_bit")?,
             b.f64s("diag_val")?);
        if dl.len() != db.len() || db.len() != dv.len() {
            return Err(Error::Exec(
                "sensitivity blob: ragged diag sections".into(),
            ));
        }
        for i in 0..dl.len() {
            let l = dl[i];
            if l >= layers {
                return Err(Error::Exec(format!(
                    "sensitivity blob: layer {l} out of range"
                )));
            }
            diag[l].insert(db[i], dv[i]);
        }
        let (oa, ob, ov) =
            (b.usizes("off_a")?, b.usizes("off_b")?, b.f64s("off_val")?);
        if oa.len() != ob.len() || ob.len() != ov.len() {
            return Err(Error::Exec(
                "sensitivity blob: ragged offdiag sections".into(),
            ));
        }
        let mut offdiag = std::collections::HashMap::new();
        for i in 0..oa.len() {
            offdiag.insert((oa[i], ob[i]), ov[i]);
        }
        let base_loss = *b.f64s("base_loss")?.first().ok_or_else(|| {
            Error::Exec("sensitivity blob: empty base_loss".into())
        })?;
        Ok(SensitivityTable { diag, offdiag, base_loss })
    }
}

impl Artifact for QuantizedModel {
    const KIND: &'static str = "quantized-model";

    fn encode(&self) -> Blob {
        let mut b = Blob::new(Self::KIND);
        b.set_meta("layers", json::num(self.weights.len() as f64));
        b.set_meta("aq", json::b(self.bits.aq));
        b.set_meta(
            "report_names",
            Json::Arr(self.reports.iter().map(|r| json::s(&r.name))
                          .collect()),
        );
        for (i, t) in self.weights.iter().enumerate() {
            b.push_tensor(&format!("w{i}"), t);
        }
        for (i, t) in self.biases.iter().enumerate() {
            b.push_tensor(&format!("b{i}"), t);
        }
        b.push_f32s("act_steps", vec![self.act_steps.len()],
                    &self.act_steps);
        b.push_u64s(
            "wbits",
            &self.bits.wbits.iter().map(|&v| v as u64).collect::<Vec<_>>(),
        );
        b.push_u64s(
            "abits",
            &self.bits.abits.iter().map(|&v| v as u64).collect::<Vec<_>>(),
        );
        b.push_u64s(
            "rep_iters",
            &self.reports.iter().map(|r| r.iters as u64).collect::<Vec<_>>(),
        );
        // 4 f64 per report: initial/final loss, soft fraction, seconds
        let mut rep = Vec::with_capacity(self.reports.len() * 4);
        for r in &self.reports {
            rep.extend_from_slice(&[
                r.initial_loss,
                r.final_loss,
                r.soft_fraction_before_commit,
                r.seconds,
            ]);
        }
        b.push_f64s("rep_vals", &rep);
        b.push_f64s("calib_seconds", &[self.calib_seconds]);
        b
    }

    fn decode(b: &Blob) -> Result<QuantizedModel, Error> {
        let n = b.meta_usize("layers")?;
        let mut weights = Vec::with_capacity(n);
        let mut biases = Vec::with_capacity(n);
        for i in 0..n {
            weights.push(b.tensor(&format!("w{i}"))?);
            biases.push(b.tensor(&format!("b{i}"))?);
        }
        let aq = b
            .meta("aq")
            .and_then(Json::as_bool)
            .ok_or_else(|| {
                Error::Exec("quantized blob: missing 'aq' meta".into())
            })?;
        let names: Vec<String> = b
            .meta("report_names")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(|v| v.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default();
        let iters = b.usizes("rep_iters")?;
        let vals = b.f64s("rep_vals")?;
        if names.len() != iters.len() || vals.len() != names.len() * 4 {
            return Err(Error::Exec(
                "quantized blob: ragged report sections".into(),
            ));
        }
        let reports: Vec<UnitReport> = names
            .into_iter()
            .enumerate()
            .map(|(i, name)| UnitReport {
                name,
                initial_loss: vals[i * 4],
                final_loss: vals[i * 4 + 1],
                soft_fraction_before_commit: vals[i * 4 + 2],
                iters: iters[i],
                seconds: vals[i * 4 + 3],
            })
            .collect();
        let calib_seconds =
            *b.f64s("calib_seconds")?.first().ok_or_else(|| {
                Error::Exec("quantized blob: empty calib_seconds".into())
            })?;
        Ok(QuantizedModel {
            weights,
            biases,
            act_steps: b.f32s("act_steps")?,
            bits: BitConfig {
                wbits: b.usizes("wbits")?,
                abits: b.usizes("abits")?,
                aq,
            },
            reports,
            calib_seconds,
        })
    }
}

impl Artifact for UnitCheckpoint {
    const KIND: &'static str = "recon-ckpt";

    fn encode(&self) -> Blob {
        let mut b = Blob::new(Self::KIND);
        b.set_meta("layers", json::num(self.qweights.len() as f64));
        b.set_meta("unit", json::s(&self.report.name));
        b.set_meta("iters", json::num(self.report.iters as f64));
        for (i, t) in self.qweights.iter().enumerate() {
            b.push_tensor(&format!("w{i}"), t);
        }
        b.push_f32s(
            "act_steps",
            vec![self.act_steps.len()],
            &self.act_steps,
        );
        // the report losses feed JobOutput::fingerprint(), so they ride
        // in a binary f64 section like every other payload float
        b.push_f64s(
            "report",
            &[
                self.report.initial_loss,
                self.report.final_loss,
                self.report.soft_fraction_before_commit,
                self.report.seconds,
            ],
        );
        b.push_u64s("rng", &self.rng);
        b
    }

    fn decode(b: &Blob) -> Result<UnitCheckpoint, Error> {
        let n = b.meta_usize("layers")?;
        let name = b
            .meta("unit")
            .and_then(Json::as_str)
            .ok_or_else(|| {
                Error::Exec("recon-ckpt blob: missing 'unit' meta".into())
            })?
            .to_string();
        let iters = b.meta_usize("iters")?;
        let mut qweights = Vec::with_capacity(n);
        for i in 0..n {
            qweights.push(b.tensor(&format!("w{i}"))?);
        }
        let rep = b.f64s("report")?;
        if rep.len() != 4 {
            return Err(Error::Exec(
                "recon-ckpt blob: bad 'report' section".into(),
            ));
        }
        let rng: [u64; 6] =
            b.u64s("rng")?.as_slice().try_into().map_err(|_| {
                Error::Exec("recon-ckpt blob: bad 'rng' section".into())
            })?;
        Ok(UnitCheckpoint {
            qweights,
            act_steps: b.f32s("act_steps")?,
            report: UnitReport {
                name,
                initial_loss: rep[0],
                final_loss: rep[1],
                soft_fraction_before_commit: rep[2],
                iters,
                seconds: rep[3],
            },
            rng,
        })
    }
}

impl Artifact for SearchResult {
    const KIND: &'static str = "mp-search";

    fn encode(&self) -> Blob {
        let mut b = Blob::new(Self::KIND);
        b.set_meta("evaluated", json::num(self.evaluated as f64));
        b.push_u64s(
            "wbits",
            &self.wbits.iter().map(|&v| v as u64).collect::<Vec<_>>(),
        );
        b.push_f64s(
            "vals",
            &[self.predicted_loss, self.hw_cost, self.seconds],
        );
        b
    }

    fn decode(b: &Blob) -> Result<SearchResult, Error> {
        let vals = b.f64s("vals")?;
        if vals.len() != 3 {
            return Err(Error::Exec(
                "mp-search blob: bad 'vals' section".into(),
            ));
        }
        Ok(SearchResult {
            wbits: b.usizes("wbits")?,
            predicted_loss: vals[0],
            hw_cost: vals[1],
            evaluated: b.meta_usize("evaluated")?,
            seconds: vals[2],
        })
    }
}

/// Held-out evaluation score (top-1 accuracy or mAP) as a persistable
/// artifact — the `Eval` stage's cache value.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalScore(pub f64);

impl Artifact for EvalScore {
    const KIND: &'static str = "eval-score";

    fn encode(&self) -> Blob {
        let mut b = Blob::new(Self::KIND);
        b.push_f64s("score", &[self.0]);
        b
    }

    fn decode(b: &Blob) -> Result<EvalScore, Error> {
        Ok(EvalScore(*b.f64s("score")?.first().ok_or_else(|| {
            Error::Exec("eval blob: empty score".into())
        })?))
    }
}

// ---------------------------------------------------------------------
// Cross-process advisory lock
// ---------------------------------------------------------------------

#[cfg(unix)]
mod entry_lock {
    use std::fs::{File, OpenOptions};
    use std::io;
    use std::os::unix::io::AsRawFd;
    use std::path::Path;

    const LOCK_EX: i32 = 2;

    extern "C" {
        fn flock(fd: i32, operation: i32) -> i32;
    }

    /// Held exclusive `flock(2)` on an entry's `.lock` file; released on
    /// drop (closing the descriptor releases the lock).
    #[derive(Debug)]
    pub struct EntryLock {
        _file: File,
    }

    pub fn acquire(path: &Path) -> io::Result<EntryLock> {
        let file = OpenOptions::new()
            .create(true)
            .truncate(false)
            .write(true)
            .open(path)?;
        loop {
            let r = unsafe { flock(file.as_raw_fd(), LOCK_EX) };
            if r == 0 {
                return Ok(EntryLock { _file: file });
            }
            let e = io::Error::last_os_error();
            if e.kind() != io::ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }
}

#[cfg(not(unix))]
mod entry_lock {
    use std::fs::OpenOptions;
    use std::io;
    use std::path::{Path, PathBuf};

    /// Fallback spin lock on `create_new` for platforms without flock;
    /// a lock file older than 60s is considered stale (dead owner).
    #[derive(Debug)]
    pub struct EntryLock {
        path: PathBuf,
    }

    impl Drop for EntryLock {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(&self.path);
        }
    }

    pub fn acquire(path: &Path) -> io::Result<EntryLock> {
        let held = path.with_extension("lock.held");
        loop {
            match OpenOptions::new()
                .create_new(true)
                .write(true)
                .open(&held)
            {
                Ok(_) => return Ok(EntryLock { path: held }),
                Err(e) if e.kind() == io::ErrorKind::AlreadyExists => {
                    if let Ok(meta) = std::fs::metadata(&held) {
                        if let Ok(age) = meta
                            .modified()
                            .and_then(|m| {
                                m.elapsed().map_err(|_| {
                                    io::Error::other("clock skew")
                                })
                            })
                        {
                            if age.as_secs() > 60 {
                                let _ = std::fs::remove_file(&held);
                                continue;
                            }
                        }
                    }
                    std::thread::sleep(
                        std::time::Duration::from_millis(10),
                    );
                }
                Err(e) => return Err(e),
            }
        }
    }
}

pub use entry_lock::EntryLock;

// ---------------------------------------------------------------------
// The store
// ---------------------------------------------------------------------

/// Store counters. `hits`/`misses` are disk-level (the in-memory cache in
/// front has its own); `corrupt` counts entries that failed key, length,
/// checksum or schema verification (each one was deleted and recomputed);
/// `publishes` counts entries written; `evicted` counts entries removed by
/// the capacity sweep; `retried` counts transient-IO backoff sleeps across
/// all sites (zero on a healthy filesystem with no armed fault plan).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub hits: u64,
    pub misses: u64,
    pub corrupt: u64,
    pub publishes: u64,
    pub evicted: u64,
    pub retried: u64,
}

/// Outcome of [`ArtifactStore::load_entry`]: a verified blob, a clean
/// miss (no committed entry), or a corruption that was detected and
/// discarded (the caller will recompute).
#[derive(Debug)]
pub enum Loaded {
    Hit(Blob),
    Miss,
    Corrupt,
}

// ---------------------------------------------------------------------
// Transient-IO retry policy
// ---------------------------------------------------------------------

/// Attempts per IO site (1 initial + 3 retries). Probability-mode
/// injected faults are bounded-burst (never two consecutive fires), so
/// any budget >= 2 recovers them deterministically; real-world EINTR
/// and NFS-style timeouts get the full ladder.
const RETRY_ATTEMPTS: u32 = 4;
/// First backoff sleep; doubles per retry (2, 4, 8 ms before jitter).
const RETRY_BASE_MS: u64 = 2;

/// Errors worth retrying: interruptions and timeouts. Everything else
/// (not-found, permissions, full disk, bad data) is permanent and
/// surfaces immediately.
fn transient(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::Interrupted
            | io::ErrorKind::WouldBlock
            | io::ErrorKind::TimedOut
    )
}

/// Content-addressed on-disk artifact store. Safe to share between any
/// number of threads and processes pointing at the same directory.
#[derive(Debug)]
pub struct ArtifactStore {
    dir: PathBuf,
    cap_bytes: Option<u64>,
    hits: AtomicU64,
    misses: AtomicU64,
    corrupt: AtomicU64,
    publishes: AtomicU64,
    evicted: AtomicU64,
    retried: AtomicU64,
}

impl ArtifactStore {
    /// Open (creating if needed) a store rooted at `dir`, unbounded.
    pub fn open(dir: impl Into<PathBuf>) -> Result<ArtifactStore, Error> {
        Self::open_with_cap(dir, None)
    }

    /// Open with a total-size cap in bytes: after each publish, oldest
    /// entries are evicted until the store fits. Eviction can race a
    /// concurrent reader in another process; the reader detects the
    /// half-deleted entry via the corruption path and recomputes.
    pub fn open_with_cap(
        dir: impl Into<PathBuf>,
        cap_bytes: Option<u64>,
    ) -> Result<ArtifactStore, Error> {
        let dir = dir.into();
        // the pinned checkpoint namespace lives in a subdirectory (see
        // entry_dir), created up front so publishes never race a mkdir
        fs::create_dir_all(dir.join("ckpt")).map_err(|e| {
            Error::Exec(format!(
                "creating artifact store at {}: {e}",
                dir.display()
            ))
        })?;
        Ok(ArtifactStore {
            dir,
            cap_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            corrupt: AtomicU64::new(0),
            publishes: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            retried: AtomicU64::new(0),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn stats(&self) -> StoreStats {
        StoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            corrupt: self.corrupt.load(Ordering::Relaxed),
            publishes: self.publishes.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            retried: self.retried.load(Ordering::Relaxed),
        }
    }

    /// Run `op` with the store's transient-IO retry policy: up to
    /// [`RETRY_ATTEMPTS`] attempts, exponential backoff with jitter
    /// drawn from a deterministic `(key, site)`-seeded stream (so two
    /// processes retrying the same entry desynchronize, and a failing
    /// run replays identically). `site` is also a fault-injection
    /// point: an armed plan can replace any attempt with an injected
    /// transient/permanent error or a panic before `op` runs.
    fn with_retry<T>(
        &self,
        site: &str,
        key: &str,
        mut op: impl FnMut() -> io::Result<T>,
    ) -> io::Result<T> {
        let mut jitter =
            Rng::new(fnv64(key.as_bytes()) ^ fnv64(site.as_bytes()));
        let mut delay = RETRY_BASE_MS;
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            let r = match faults::check(site) {
                Some(faults::Kind::Io) => Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    format!("injected transient IO fault at {site}"),
                )),
                Some(faults::Kind::Perm) => {
                    return Err(io::Error::other(format!(
                        "injected permanent fault at {site}"
                    )))
                }
                Some(faults::Kind::Panic) => {
                    panic!("injected panic at {site} (key '{key}')")
                }
                None => op(),
            };
            match r {
                Ok(v) => return Ok(v),
                Err(e) if transient(&e) && attempt < RETRY_ATTEMPTS => {
                    self.retried.fetch_add(1, Ordering::Relaxed);
                    let ms = delay + jitter.next_u64() % delay.max(1);
                    std::thread::sleep(
                        std::time::Duration::from_millis(ms),
                    );
                    delay *= 2;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Number of committed entries (published indexes) on disk.
    pub fn len(&self) -> usize {
        self.index_paths().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Keys in the checkpoint namespace — any key containing "/ckpt/"
    /// (per-unit reconstruction checkpoints, `{recon_key}/ckpt/<i>`) —
    /// are *pinned*: they live under `<store>/ckpt/`, outside the
    /// top-level scan that [`Self::len`] counts and
    /// [`Self::evict_to_cap`] sweeps. A cap squeeze can therefore never
    /// evict another daemon's in-flight partial progress (the same
    /// isolation the `journal/` subdirectory gives batch journals).
    fn pinned(key: &str) -> bool {
        key.contains("/ckpt/")
    }

    fn entry_dir(&self, key: &str) -> PathBuf {
        if Self::pinned(key) {
            self.dir.join("ckpt")
        } else {
            self.dir.clone()
        }
    }

    fn entry_paths(&self, key: &str) -> (PathBuf, PathBuf) {
        let h = key_hash(key);
        let d = self.entry_dir(key);
        (d.join(format!("{h}.json")), d.join(format!("{h}.bin")))
    }

    /// Exclusive cross-process lock for `key`'s entry. Hold it over the
    /// whole load→compute→publish window for compute-once semantics.
    pub fn lock(&self, key: &str) -> Result<EntryLock, Error> {
        let path =
            self.entry_dir(key).join(format!("{}.lock", key_hash(key)));
        self.with_retry("store.lock", key, || entry_lock::acquire(&path))
            .map_err(|e| {
                Error::Exec(format!(
                    "locking store entry for '{key}': {e}"
                ))
            })
    }

    /// Load the committed entry for `key`, verifying key, kind integrity,
    /// payload length and checksum. Any verification failure deletes the
    /// entry, bumps `corrupt` and reports a miss — a corrupt artifact is
    /// never served. A hit also touches the index mtime, which is the
    /// recency signal [`Self::evict_to_cap`] sorts by: eviction under a
    /// size cap is least-recently-*used*, not oldest-published.
    pub fn load(&self, key: &str) -> Option<Blob> {
        match self.load_entry(key) {
            Loaded::Hit(b) => Some(b),
            _ => None,
        }
    }

    /// Like [`Self::load`], but distinguishes a clean miss from a
    /// detected-and-discarded corruption — checkpoint resume surfaces
    /// that distinction as its `ckpt_corrupt` tally.
    pub fn load_entry(&self, key: &str) -> Loaded {
        let (jp, bp) = self.entry_paths(key);
        let text =
            match self.with_retry("store.index", key, || {
                fs::read_to_string(&jp)
            }) {
                Ok(t) => t,
                Err(_) => {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    return Loaded::Miss;
                }
            };
        match self.verify_and_decode(key, &text, &bp) {
            Ok(blob) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                if self.cap_bytes.is_some() {
                    Self::touch(&jp);
                }
                Loaded::Hit(blob)
            }
            Err(why) => {
                self.discard_corrupt(key, &why);
                self.misses.fetch_add(1, Ordering::Relaxed);
                Loaded::Corrupt
            }
        }
    }

    /// Whether a committed entry exists for `key` — one `stat`, no
    /// verification, no counter traffic. Cheap existence probe for
    /// checkpoint cleanup on warm hits.
    pub fn contains(&self, key: &str) -> bool {
        let (jp, _) = self.entry_paths(key);
        jp.exists()
    }

    /// Best-effort unpublish of `key`: index first (readers stop seeing
    /// the entry), then payload. Used to clear per-unit checkpoints once
    /// the final reconstruction artifact commits; missing files are fine.
    pub fn remove(&self, key: &str) {
        let (jp, bp) = self.entry_paths(key);
        let _ = fs::remove_file(jp);
        let _ = fs::remove_file(bp);
    }

    /// Best-effort mtime bump on hit (capped stores only) — keeps hot
    /// entries at the back of the LRU eviction order.
    fn touch(jp: &Path) {
        let now = std::time::SystemTime::now();
        if let Ok(f) = fs::File::options().write(true).open(jp) {
            let _ = f.set_times(
                fs::FileTimes::new().set_accessed(now).set_modified(now),
            );
        }
    }

    /// Count a corrupt entry and delete its files (also used by the cache
    /// when a verified payload fails typed decode — schema drift).
    pub(crate) fn discard_corrupt(&self, key: &str, why: &str) {
        self.corrupt.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "[store] corrupt entry for '{key}' ({why}) — deleted, will \
             recompute"
        );
        let (jp, bp) = self.entry_paths(key);
        let _ = fs::remove_file(jp);
        let _ = fs::remove_file(bp);
    }

    fn verify_and_decode(
        &self,
        key: &str,
        index_text: &str,
        bin_path: &Path,
    ) -> Result<Blob, String> {
        let idx = Json::parse(index_text)
            .map_err(|e| format!("bad index JSON: {e}"))?;
        if idx.get("v").and_then(Json::as_usize) != Some(1) {
            return Err("unknown index version".into());
        }
        let stored_key = idx
            .get("key")
            .and_then(Json::as_str)
            .ok_or("index missing 'key'")?;
        if stored_key != key {
            return Err(format!(
                "key mismatch (entry holds '{stored_key}')"
            ));
        }
        let bin_len = idx
            .get("bin_len")
            .and_then(Json::as_usize)
            .ok_or("index missing 'bin_len'")?;
        let want_sum = idx
            .get("checksum")
            .and_then(Json::as_str)
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or("index missing 'checksum'")?;
        let bytes = self
            .with_retry("store.load", key, || fs::read(bin_path))
            .map_err(|e| format!("payload unreadable: {e}"))?;
        if bytes.len() != bin_len {
            return Err(format!(
                "payload truncated ({} of {bin_len} bytes)",
                bytes.len()
            ));
        }
        let got_sum = fnv64(&bytes);
        if got_sum != want_sum {
            return Err(format!(
                "checksum mismatch ({got_sum:016x} != {want_sum:016x})"
            ));
        }
        Blob::from_index(&idx, bytes)
    }

    /// Atomically publish `blob` under `key`: payload first, index last
    /// (the rename of the index is the commit point). Safe against
    /// readers in other processes at every intermediate state, and safe
    /// to retry whole: the temp names are pid-suffixed and every step
    /// is an overwrite, so a transiently-failed attempt replays clean.
    pub fn publish(&self, key: &str, blob: &Blob) -> Result<(), Error> {
        let (jp, bp) = self.entry_paths(key);
        let pid = std::process::id();
        let ctx = |what: &str| {
            move |e: io::Error| {
                io::Error::new(e.kind(), format!("{what}: {e}"))
            }
        };
        self.with_retry("store.publish", key, || {
            let bin_tmp = bp.with_extension(format!("bin.tmp.{pid}"));
            fs::write(&bin_tmp, &blob.bytes)
                .map_err(ctx("write payload"))?;
            fs::rename(&bin_tmp, &bp).map_err(ctx("commit payload"))?;
            let json_tmp = jp.with_extension(format!("json.tmp.{pid}"));
            fs::write(&json_tmp, blob.index_json(key).to_string())
                .map_err(ctx("write index"))?;
            fs::rename(&json_tmp, &jp).map_err(ctx("commit index"))?;
            Ok(())
        })
        .map_err(|e| {
            Error::Exec(format!("store publish '{key}': {e}"))
        })?;
        self.publishes.fetch_add(1, Ordering::Relaxed);
        if self.cap_bytes.is_some() {
            self.evict_to_cap(&jp);
        }
        Ok(())
    }

    fn index_paths(&self) -> Vec<PathBuf> {
        let Ok(rd) = fs::read_dir(&self.dir) else { return Vec::new() };
        let mut v: Vec<PathBuf> = rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.extension().map(|x| x == "json").unwrap_or(false)
            })
            .collect();
        v.sort();
        v
    }

    /// Evict least-recently-used entries until the store fits
    /// `cap_bytes`, never touching the just-published `keep`. Recency
    /// is the index mtime (path as the deterministic tie-break), which
    /// [`Self::load`] bumps on every hit — so a hot entry outlives
    /// colder but younger ones. The sweep walks only top-level indexes
    /// ([`Self::index_paths`]): the `ckpt/` and `journal/`
    /// subdirectories — in-flight partial progress and write-ahead
    /// batch journals — are pinned out of it by construction (see
    /// [`Self::pinned`]).
    fn evict_to_cap(&self, keep: &Path) {
        let Some(cap) = self.cap_bytes else { return };
        let mut entries: Vec<(std::time::SystemTime, PathBuf, u64)> =
            Vec::new();
        let mut total = 0u64;
        for jp in self.index_paths() {
            let bp = jp.with_extension("bin");
            let jm = fs::metadata(&jp).ok();
            let sz = jm.as_ref().map(|m| m.len()).unwrap_or(0)
                + fs::metadata(&bp).map(|m| m.len()).unwrap_or(0);
            let mtime = jm
                .and_then(|m| m.modified().ok())
                .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            total += sz;
            entries.push((mtime, jp, sz));
        }
        entries.sort();
        for (_, jp, sz) in entries {
            if total <= cap {
                break;
            }
            if jp == keep {
                continue;
            }
            // index first (unpublish), then payload
            let _ = fs::remove_file(&jp);
            let _ = fs::remove_file(jp.with_extension("bin"));
            total = total.saturating_sub(sz);
            self.evicted.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "brecq-store-unit-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn blob_sections_round_trip_exact_bits() {
        let mut b = Blob::new("test");
        let t = Tensor::new(
            vec![2, 3],
            vec![1.0, -0.0, f32::MIN_POSITIVE, 3.5e-42, 1e30, -7.25],
        );
        b.push_tensor("t", &t);
        b.push_f64s("d", &[0.1, -1e-300, 2f64.powi(-1074)]);
        b.push_u64s("u", &[0, u64::MAX, 42]);
        b.set_meta("n", json::num(3.0));

        let store = ArtifactStore::open(tmp_dir("blob")).unwrap();
        store.publish("k", &b).unwrap();
        let back = store.load("k").expect("published entry loads");
        assert_eq!(back.kind(), "test");
        let bt = back.tensor("t").unwrap();
        assert_eq!(bt.shape, t.shape);
        let bits = |v: &[f32]| -> Vec<u32> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&bt.data), bits(&t.data));
        let d = back.f64s("d").unwrap();
        assert_eq!(d[1].to_bits(), (-1e-300f64).to_bits());
        assert_eq!(d[2].to_bits(), 2f64.powi(-1074).to_bits());
        assert_eq!(back.u64s("u").unwrap(), vec![0, u64::MAX, 42]);
        assert_eq!(back.meta("n").and_then(Json::as_usize), Some(3));
        assert_eq!(
            store.stats(),
            StoreStats { hits: 1, publishes: 1, ..StoreStats::default() }
        );
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn missing_entry_is_a_miss_not_corruption() {
        let store = ArtifactStore::open(tmp_dir("miss")).unwrap();
        assert!(store.load("nope").is_none());
        let s = store.stats();
        assert_eq!((s.misses, s.corrupt), (1, 0));
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn flipped_payload_byte_is_detected_and_discarded() {
        let store = ArtifactStore::open(tmp_dir("corrupt")).unwrap();
        let mut b = Blob::new("test");
        b.push_f64s("x", &[1.0, 2.0, 3.0]);
        store.publish("k", &b).unwrap();
        let (_, bp) = store.entry_paths("k");
        let mut bytes = fs::read(&bp).unwrap();
        bytes[3] ^= 0x40;
        fs::write(&bp, &bytes).unwrap();
        assert!(store.load("k").is_none(), "corrupt entry served");
        let s = store.stats();
        assert_eq!(s.corrupt, 1);
        // the entry was deleted: the next load is a clean miss
        assert!(store.load("k").is_none());
        assert_eq!(store.stats().corrupt, 1);
        assert_eq!(store.len(), 0);
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn truncated_index_is_detected() {
        let store = ArtifactStore::open(tmp_dir("truncidx")).unwrap();
        let mut b = Blob::new("test");
        b.push_u64s("x", &[7]);
        store.publish("k", &b).unwrap();
        let (jp, _) = store.entry_paths("k");
        let text = fs::read_to_string(&jp).unwrap();
        fs::write(&jp, &text[..text.len() / 2]).unwrap();
        assert!(store.load("k").is_none());
        assert_eq!(store.stats().corrupt, 1);
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn capacity_cap_evicts_oldest_entries() {
        let store =
            ArtifactStore::open_with_cap(tmp_dir("evict"), Some(4096))
                .unwrap();
        for i in 0..8 {
            let mut b = Blob::new("test");
            b.push_f64s("x", &vec![i as f64; 128]); // ~1KiB payload
            store.publish(&format!("k{i}"), &b).unwrap();
        }
        assert!(store.stats().evicted > 0, "cap never evicted");
        assert!(store.len() < 8);
        // the most recent entry survives
        assert!(store.load("k7").is_some());
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn lru_touch_on_hit_keeps_hot_entries_through_a_cap_squeeze() {
        // cap sized so three ~2.4KiB entries fit and a fourth forces
        // exactly one eviction
        let store =
            ArtifactStore::open_with_cap(tmp_dir("lru"), Some(8000))
                .unwrap();
        let blob = |i: usize| {
            let mut b = Blob::new("test");
            b.push_f64s("x", &vec![i as f64; 256]);
            b
        };
        for i in 0..3 {
            store.publish(&format!("k{i}"), &blob(i)).unwrap();
            // mtime separation (filesystem timestamp granularity)
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        assert_eq!(store.stats().evicted, 0, "cap squeezed too early");
        // k0 is the oldest-published entry — a hit makes it the hottest
        assert!(store.load("k0").is_some());
        std::thread::sleep(std::time::Duration::from_millis(20));
        // k3 pushes over cap: LRU must evict k1, not the hot k0
        store.publish("k3", &blob(3)).unwrap();
        assert!(store.stats().evicted > 0, "cap never evicted");
        assert!(
            store.load("k0").is_some(),
            "hot entry evicted — eviction is not LRU"
        );
        assert!(store.load("k3").is_some());
        assert!(store.load("k1").is_none(), "LRU entry survived");
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn ckpt_namespace_survives_a_cap_squeeze() {
        let store =
            ArtifactStore::open_with_cap(tmp_dir("pinned"), Some(4096))
                .unwrap();
        let ck = "recon/m/brecq/1/32/0/train/abc/ckpt/0";
        let mut cb = Blob::new("recon-ckpt");
        cb.push_f64s("x", &vec![9.0; 256]); // ~2KiB, over half the cap
        store.publish(ck, &cb).unwrap();
        // pinned entries are outside len() (top-level indexes only)
        assert_eq!(store.len(), 0);
        for i in 0..8 {
            let mut b = Blob::new("test");
            b.push_f64s("x", &vec![i as f64; 128]);
            store.publish(&format!("k{i}"), &b).unwrap();
        }
        assert!(store.stats().evicted > 0, "cap never evicted");
        assert!(
            store.load(ck).is_some(),
            "cap squeeze evicted a pinned /ckpt/ entry"
        );
        // remove() unpublishes it: clean miss, not corruption
        store.remove(ck);
        assert!(store.load(ck).is_none());
        assert_eq!(store.stats().corrupt, 0);
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn load_entry_classifies_miss_vs_corrupt() {
        let store = ArtifactStore::open(tmp_dir("classify")).unwrap();
        assert!(matches!(store.load_entry("nope"), Loaded::Miss));
        let mut b = Blob::new("test");
        b.push_f64s("x", &[1.0, 2.0]);
        store.publish("k", &b).unwrap();
        assert!(matches!(store.load_entry("k"), Loaded::Hit(_)));
        let (_, bp) = store.entry_paths("k");
        let mut bytes = fs::read(&bp).unwrap();
        bytes[0] ^= 0xff;
        fs::write(&bp, &bytes).unwrap();
        assert!(matches!(store.load_entry("k"), Loaded::Corrupt));
        // discarded: the next probe is a clean miss
        assert!(matches!(store.load_entry("k"), Loaded::Miss));
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn recon_ckpt_blob_round_trips_bitwise() {
        let ck = UnitCheckpoint {
            qweights: vec![
                Tensor::new(vec![2, 2], vec![1.5, -0.0, 3.5e-42, 2.0]),
                Tensor::new(vec![1, 3], vec![-7.25, 0.125, 1e30]),
            ],
            act_steps: vec![0.01, f32::MIN_POSITIVE],
            report: UnitReport {
                name: "block2".into(),
                initial_loss: 0.1,
                final_loss: 1e-300,
                soft_fraction_before_commit: 0.25,
                iters: 80,
                seconds: 1.25,
            },
            rng: [1, u64::MAX, 3, 4, 1, 0x3ff0_0000_0000_0001],
        };
        let store = ArtifactStore::open(tmp_dir("ckptrt")).unwrap();
        store.publish("r/ckpt/3", &ck.encode()).unwrap();
        let blob = store.load("r/ckpt/3").unwrap();
        assert_eq!(blob.kind(), UnitCheckpoint::KIND);
        let back = UnitCheckpoint::decode(&blob).unwrap();
        for (a, b) in ck.qweights.iter().zip(&back.qweights) {
            assert_eq!(a.shape, b.shape);
            let bits =
                |t: &Tensor| -> Vec<u32> {
                    t.data.iter().map(|x| x.to_bits()).collect()
                };
            assert_eq!(bits(a), bits(b));
        }
        assert_eq!(
            ck.act_steps.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            back.act_steps.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(back.report.name, "block2");
        assert_eq!(
            back.report.final_loss.to_bits(),
            ck.report.final_loss.to_bits()
        );
        assert_eq!(back.report.iters, 80);
        assert_eq!(back.rng, ck.rng);
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn key_hash_is_stable_and_distinct() {
        assert_eq!(key_hash("a"), key_hash("a"));
        assert_ne!(key_hash("a"), key_hash("b"));
        assert_eq!(key_hash("a").len(), 32);
    }
}
