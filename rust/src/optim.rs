//! Adam optimizer (host side). The AOT executables return gradients; the
//! coordinator owns all optimizer state — AdaRound rounding variables,
//! activation step sizes, QAT parameters and distilled-data pixels all
//! update through this one implementation.

use crate::tensor::Tensor;

pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: i32,
}

impl Adam {
    pub fn new(lr: f32, sizes: &[usize]) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            v: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            t: 0,
        }
    }

    pub fn for_params(lr: f32, params: &[&Tensor]) -> Adam {
        Adam::new(lr, &params.iter().map(|p| p.numel()).collect::<Vec<_>>())
    }

    /// One step: params[i] -= lr * mhat/(sqrt(vhat)+eps). Call with the
    /// same param ordering every time.
    pub fn step(&mut self, params: &mut [&mut Tensor], grads: &[&Tensor]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t);
        let bc2 = 1.0 - self.beta2.powi(self.t);
        for (i, p) in params.iter_mut().enumerate() {
            let g = &grads[i].data;
            assert_eq!(p.numel(), g.len());
            let (m, v) = (&mut self.m[i], &mut self.v[i]);
            for j in 0..g.len() {
                m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * g[j];
                v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * g[j] * g[j];
                let mh = m[j] / bc1;
                let vh = v[j] / bc2;
                p.data[j] -= self.lr * mh / (vh.sqrt() + self.eps);
            }
        }
    }
}

/// BRECQ's β annealing for the rounding regularizer: warmup with λ=0,
/// then β decays start→end (Nagel et al. 2020 schedule family).
pub struct BetaSchedule {
    pub total: usize,
    pub warmup: f32,
    pub start: f32,
    pub end: f32,
}

impl BetaSchedule {
    pub fn brecq_default(total: usize) -> BetaSchedule {
        BetaSchedule { total, warmup: 0.2, start: 20.0, end: 2.0 }
    }

    /// Returns (beta, reg_active) at iteration t.
    pub fn at(&self, t: usize) -> (f32, bool) {
        let warm = (self.total as f32 * self.warmup) as usize;
        if t < warm {
            return (self.start, false);
        }
        let rel = (t - warm) as f32 / (self.total - warm).max(1) as f32;
        (self.end + (self.start - self.end) * (1.0 - rel), true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_quadratic() {
        // f(x) = sum (x - 3)^2 — Adam should converge to 3
        let mut x = Tensor::zeros(vec![4]);
        let mut opt = Adam::new(0.1, &[4]);
        for _ in 0..500 {
            let g = Tensor::new(
                vec![4],
                x.data.iter().map(|&v| 2.0 * (v - 3.0)).collect(),
            );
            opt.step(&mut [&mut x], &[&g]);
        }
        for &v in &x.data {
            assert!((v - 3.0).abs() < 1e-2, "{v}");
        }
    }

    #[test]
    fn adam_multi_param_groups() {
        let mut a = Tensor::zeros(vec![2]);
        let mut b = Tensor::full(vec![3], 5.0);
        let mut opt = Adam::new(0.05, &[2, 3]);
        for _ in 0..800 {
            let ga = Tensor::new(
                vec![2],
                a.data.iter().map(|&v| 2.0 * (v + 1.0)).collect(),
            );
            let gb = Tensor::new(
                vec![3],
                b.data.iter().map(|&v| 2.0 * (v - 2.0)).collect(),
            );
            opt.step(&mut [&mut a, &mut b], &[&ga, &gb]);
        }
        assert!(a.data.iter().all(|&v| (v + 1.0).abs() < 1e-2));
        assert!(b.data.iter().all(|&v| (v - 2.0).abs() < 1e-2));
    }

    #[test]
    fn beta_schedule_shape() {
        let s = BetaSchedule::brecq_default(1000);
        let (b0, on0) = s.at(0);
        assert_eq!(b0, 20.0);
        assert!(!on0); // warmup: regularizer off
        let (_, on1) = s.at(300);
        assert!(on1);
        let (bmid, _) = s.at(600);
        assert!(bmid < 20.0 && bmid > 2.0);
        let (bend, _) = s.at(999);
        assert!((bend - 2.0).abs() < 0.1);
    }
}
