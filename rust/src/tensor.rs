//! Dense row-major f32 tensor: the host-side value type the coordinator
//! moves between the weight store, the calibration caches and the PJRT
//! runtime. Deliberately minimal — all heavy math lives in the AOT
//! executables; the tensor only needs shape bookkeeping, elementwise
//! helpers for the quantizer/optimizer, and (de)serialization.

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} != data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar1(v: f32) -> Tensor {
        Tensor { shape: vec![1], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Leading-dim (out-channel) count; 1 for scalars.
    pub fn c0(&self) -> usize {
        self.shape.first().copied().unwrap_or(1)
    }

    /// Elements per leading-dim slice.
    pub fn inner(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.numel() / self.shape[0]
        }
    }

    pub fn reshaped(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Rows of a (B, C) logits tensor -> argmax per row.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        self.data
            .chunks(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }

    /// Borrow row `i` of the leading dimension (no copy) — the native
    /// kernels' per-sample view.
    pub fn row0(&self, i: usize) -> &[f32] {
        let inner = self.inner();
        &self.data[i * inner..(i + 1) * inner]
    }

    /// Slice of the leading dimension: rows [start, start+len).
    pub fn slice0(&self, start: usize, len: usize) -> Tensor {
        let inner = self.inner();
        let mut shape = self.shape.clone();
        shape[0] = len;
        Tensor::new(
            shape,
            self.data[start * inner..(start + len) * inner].to_vec(),
        )
    }

    /// Gather leading-dim rows into a caller-provided buffer (no
    /// allocation): `out[bi] = self[rows[bi]]`. Each gathered row is a
    /// plain copy, so a gather is bitwise identical to slicing the same
    /// rows out one by one — the reconstruction plan's batch assembly
    /// relies on that.
    pub fn gather_rows_into(&self, rows: &[usize], out: &mut [f32]) {
        let inner = self.inner();
        assert_eq!(
            out.len(),
            rows.len() * inner,
            "gather_rows_into: dst len {} != {} rows x {}",
            out.len(),
            rows.len(),
            inner
        );
        for (bi, &r) in rows.iter().enumerate() {
            out[bi * inner..(bi + 1) * inner]
                .copy_from_slice(&self.data[r * inner..(r + 1) * inner]);
        }
    }

    /// Concatenate along a new leading batch axis built from equal chunks.
    pub fn stack0(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let mut shape = parts[0].shape.clone();
        shape[0] = parts.iter().map(|p| p.shape[0]).sum();
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            assert_eq!(p.shape[1..], parts[0].shape[1..]);
            data.extend_from_slice(&p.data);
        }
        Tensor::new(shape, data)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.c0(), 2);
        assert_eq!(t.inner(), 3);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn argmax() {
        let t = Tensor::new(vec![2, 3], vec![0., 2., 1., 5., 4., 3.]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn slice_and_stack_roundtrip() {
        let t = Tensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect());
        let a = t.slice0(0, 2);
        let b = t.slice0(2, 2);
        assert_eq!(Tensor::stack0(&[a, b]), t);
    }

    #[test]
    fn row0_borrows_leading_rows() {
        let t = Tensor::new(vec![3, 2], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.row0(0), &[0.0, 1.0]);
        assert_eq!(t.row0(2), &[4.0, 5.0]);
    }

    #[test]
    fn gather_rows_into_copies_rows() {
        let t = Tensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect());
        let mut out = vec![0f32; 6];
        t.gather_rows_into(&[3, 0, 3], &mut out);
        assert_eq!(out, vec![6., 7., 0., 1., 6., 7.]);
    }

    #[test]
    fn scalar_and_full() {
        assert_eq!(Tensor::scalar1(3.0).data, vec![3.0]);
        assert_eq!(Tensor::full(vec![2, 2], 1.5).data, vec![1.5; 4]);
    }
}
