//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build substrate has no crates.io access, so this path dependency
//! provides the exact subset the workspace uses: `Error`, `Result`,
//! `Context::{context, with_context}` on both `Result` and `Option`, and
//! the `anyhow!` / `bail!` / `ensure!` macros. Errors are flattened to a
//! single message string ("outer context: inner cause"), which matches how
//! the CLI reports them (`{e:#}`).

use std::fmt;

/// A flattened error: message plus accumulated context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line (anyhow's `Error::context`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like anyhow: any std error converts. (Sound because `Error` itself does
// not implement `std::error::Error`, so this cannot overlap `From<T> for T`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn context_chains_and_displays() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
        let e2 = e.context("bootstrap");
        assert!(e2.to_string().starts_with("bootstrap: reading config: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }
}
