//! Pipeline API tests: JobSpec JSON round-trips, typed errors at the API
//! boundary, artifact-cache sharing (the sensitivity LUT is computed once
//! across jobs — pinned via backend dispatch accounting), and
//! batch-vs-sequential **bit-identity** of `Session::run_many` at
//! `BRECQ_THREADS` 1 and 4.
//!
//! Everything runs on the hermetic synthetic environment (native backend,
//! no artifacts).

use std::sync::Mutex;

use brecq::coordinator::experiments::{table5, ExpOpts};
use brecq::coordinator::Env;
use brecq::pipeline::{DataSource, Error, Granularity, Hardware, HwBudget,
                      JobOutput, JobSpec, Method, Session};
use brecq::util::json::Json;
use brecq::util::pool;

/// `pool::set_threads` is process-global and libtest runs tests
/// concurrently: serialize the tests that pin a thread count.
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn lock_pool() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn session() -> Session {
    Session::new(Env::bootstrap_synthetic().expect("synthetic environment"))
}

#[test]
fn jobspec_round_trips_through_util_json_text() {
    // full spec: every non-default field exercised through actual text
    let spec = JobSpec {
        model: "mobilenetv2_s".into(),
        method: Method::AdaQuantLike,
        gran: Granularity::Layer,
        wbits: 3,
        abits: Some(4),
        first_last_8: false,
        iters: 17,
        calib_n: 96,
        seed: 9,
        source: DataSource::Train,
        search: Some(HwBudget {
            hw: Hardware::Fpga,
            budget: 1.25,
            relative: true,
        }),
        eval: false,
        hw_report: true,
        det_nms: true,
        verbose: true,
    };
    let text = spec.to_json().to_string();
    let back = JobSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, spec);

    // a whole batch file round-trips
    let batch =
        format!("[{text},{}]", JobSpec::default().to_json().to_string());
    let specs = JobSpec::parse_jobs(&batch).unwrap();
    assert_eq!(specs.len(), 2);
    assert_eq!(specs[0], spec);
    assert_eq!(specs[1], JobSpec::default());
}

#[test]
fn unknown_model_and_bad_specs_are_typed_errors() {
    let s = session();
    let r = s.run(&JobSpec { model: "nope".into(), ..JobSpec::default() });
    assert!(matches!(r, Err(Error::UnknownModel(_))));

    // zero-budget search is rejected before any work happens
    let r = s.run(&JobSpec {
        search: Some(HwBudget {
            hw: Hardware::Size,
            budget: 0.0,
            relative: false,
        }),
        ..JobSpec::default()
    });
    assert!(matches!(r, Err(Error::Spec(_))));

    // ARM latency model rejects the depthwise-conv model (typed, not a
    // panic deep in the simulator)
    let r = s.run(&JobSpec {
        model: "mobilenetv2_s".into(),
        method: Method::Fp,
        eval: false,
        search: Some(HwBudget {
            hw: Hardware::Arm,
            budget: 0.9,
            relative: true,
        }),
        ..JobSpec::default()
    });
    assert!(matches!(r, Err(Error::Spec(_))));

    // out-of-range bits
    let r = s.run(&JobSpec { wbits: 0, ..JobSpec::default() });
    assert!(matches!(r, Err(Error::Spec(_))));
}

/// Dispatch count of the model-eval executables (the sensitivity probes'
/// workhorse) since the session's backend was created.
fn eval_fwd_calls(s: &Session) -> u64 {
    s.env()
        .rt
        .hotspots(usize::MAX)
        .iter()
        .filter(|(name, _, _)| name.ends_with("eval_fwd"))
        .map(|(_, calls, _)| *calls)
        .sum()
}

#[test]
fn sensitivity_lut_computed_once_across_jobs() {
    let _g = lock_pool();
    pool::set_threads(1);
    let s = session();
    let model = s.model("resnet_s").unwrap();
    let nl = model.layers.len();

    // budgets above the pinned-8-bit all-2 floor, measured not guessed
    let meas = Hardware::Size.measurer();
    let full = meas.measure(model, &vec![8; nl], 8);
    let mut floor_bits = vec![2usize; nl];
    floor_bits[0] = 8;
    floor_bits[nl - 1] = 8;
    let floor = meas.measure(model, &floor_bits, 8) / full;
    let frac = |t: f64| floor + (1.0 - floor) * t;

    let mp_job = |t: f64| JobSpec {
        model: "resnet_s".into(),
        method: Method::Fp,
        eval: false,
        calib_n: 32,
        seed: 2,
        search: Some(HwBudget {
            hw: Hardware::Size,
            budget: frac(t),
            relative: true,
        }),
        ..JobSpec::default()
    };

    let loose = s.run(&mp_job(0.6)).unwrap();
    let calls_after_first = eval_fwd_calls(&s);
    assert!(
        calls_after_first > 0,
        "sensitivity probes must dispatch eval_fwd"
    );

    // second job: different budget, same (model, data) key — the LUT and
    // every upstream artifact must come from the cache, so not a single
    // additional eval_fwd dispatch is allowed
    let tight = s.run(&mp_job(0.25)).unwrap();
    let calls_after_second = eval_fwd_calls(&s);
    assert_eq!(
        calls_after_first, calls_after_second,
        "second search job recomputed the sensitivity LUT"
    );
    let (hits, misses) = s.cache().stats();
    assert!(hits >= 3, "expected cache hits (got {hits}/{misses})");

    // both jobs are real searches over the shared LUT
    let l = loose.search.unwrap();
    let t = tight.search.unwrap();
    assert!(l.hw_cost <= frac(0.6) * full + 1e-9);
    assert!(t.hw_cost <= frac(0.25) * full + 1e-9);
    // a looser budget can only help the predicted loss
    assert!(l.predicted_loss <= t.predicted_loss + 1e-12);
    pool::set_threads(0);
}

#[test]
fn fp_job_matches_manifest_reference() {
    let s = session();
    let out = s
        .run(&JobSpec { method: Method::Fp, ..JobSpec::default() })
        .unwrap();
    let acc = out.accuracy.expect("eval stage ran");
    assert!(
        (acc - out.fp_acc).abs() < 1e-9,
        "FP eval {acc} vs manifest {}",
        out.fp_acc
    );
    assert!(out.quantized.is_none());
    assert!(out.wbits.iter().all(|&b| b == 8));
}

#[test]
fn brecq_honors_non_block_granularity() {
    // the CLI's old `--gran != block` special case is gone: the pipeline
    // routes any granularity through the same engine path
    let s = session();
    let out = s
        .run(&JobSpec {
            gran: Granularity::Layer,
            wbits: 4,
            abits: Some(8),
            iters: 8,
            calib_n: 32,
            ..JobSpec::default()
        })
        .unwrap();
    let model = s.model("resnet_s").unwrap();
    assert_eq!(
        out.reports().len(),
        model.gran("layer").units.len(),
        "layer granularity must reconstruct layer units"
    );
}

/// Everything result-bearing a job produced, as exact bits.
fn fingerprint(outs: &[JobOutput]) -> Vec<(
    Option<u64>,
    Vec<usize>,
    Option<Vec<u32>>,
    Option<Vec<u32>>,
    Option<(Vec<usize>, u64)>,
)> {
    outs.iter()
        .map(|o| {
            (
                o.accuracy.map(|a| a.to_bits()),
                o.wbits.clone(),
                o.quantized.as_ref().map(|q| {
                    q.weights
                        .iter()
                        .flat_map(|t| t.data.iter().map(|v| v.to_bits()))
                        .collect()
                }),
                o.quantized.as_ref().map(|q| {
                    q.act_steps.iter().map(|v| v.to_bits()).collect()
                }),
                o.search.as_ref().map(|r| {
                    (r.wbits.clone(), r.predicted_loss.to_bits())
                }),
            )
        })
        .collect()
}

/// The detection family rides the same JobSpec surface: an FP job's
/// `accuracy` field carries mAP and must reproduce the generator's
/// manifest reference (the runtime forward replays the generator
/// forward bit-exactly; test_n is a whole number of eval batches).
#[test]
fn det_fp_job_matches_manifest_reference() {
    let s = session();
    let out = s
        .run(&JobSpec {
            model: "det_s".into(),
            method: Method::Fp,
            ..JobSpec::default()
        })
        .unwrap();
    let map = out.accuracy.expect("eval stage ran");
    assert!(
        (map - out.fp_acc).abs() < 1e-9,
        "FP mAP {map} vs manifest {}",
        out.fp_acc
    );
    assert!(
        (0.0..=1.0).contains(&map),
        "mAP out of range: {map}"
    );
    assert!(out.quantized.is_none());
}

/// mAP evaluation (batched forward + serial f64 scoring) and the whole
/// quantized detection job must be bit-identical at 1/2/8 threads.
#[test]
fn det_quantized_job_is_thread_invariant() {
    let _g = lock_pool();
    let spec = JobSpec {
        model: "det_s".into(),
        wbits: 4,
        abits: Some(8),
        iters: 8,
        calib_n: 32,
        seed: 0,
        ..JobSpec::default()
    };
    let mut prints = Vec::new();
    for nt in [1usize, 2, 8] {
        pool::set_threads(nt);
        let s = session();
        let out = s.run(&spec).unwrap();
        assert!(
            out.accuracy.is_some(),
            "quantized det job must evaluate mAP"
        );
        prints.push(fingerprint(std::slice::from_ref(&out)));
    }
    pool::set_threads(0);
    assert_eq!(prints[0], prints[1], "det job differs at 1 vs 2 threads");
    assert_eq!(prints[1], prints[2], "det job differs at 2 vs 8 threads");
}

/// Mixed-precision search is undefined for the regression head: the
/// pipeline rejects it with a typed spec error before any work runs.
#[test]
fn det_search_is_a_typed_spec_error() {
    let s = session();
    let r = s.run(&JobSpec {
        model: "det_s".into(),
        method: Method::Fp,
        eval: false,
        search: Some(HwBudget {
            hw: Hardware::Size,
            budget: 0.8,
            relative: true,
        }),
        ..JobSpec::default()
    });
    assert!(matches!(r, Err(Error::Spec(_))));
}

/// The Table 5 runner renders byte-identical markdown across runs and
/// thread counts — the determinism fingerprint kick-tires.sh relies on.
#[test]
fn table5_runner_is_deterministic_and_thread_invariant() {
    let _g = lock_pool();
    let o = ExpOpts {
        iters: 6,
        calib_n: 32,
        seed: 0,
        seeds: 1,
        verbose: false,
    };
    let mut renders = Vec::new();
    for nt in [1usize, 2] {
        pool::set_threads(nt);
        let env = Env::bootstrap_synthetic().unwrap();
        renders.push(table5(&env, &o).unwrap().to_markdown());
    }
    pool::set_threads(0);
    assert_eq!(renders[0], renders[1], "table5 depends on thread count");
    // FP row plus {W4, W2} x {adaround-layer, brecq} quantized rows
    let lines = renders[0].lines().count();
    assert!(lines >= 7, "table5 too short:\n{}", renders[0]);
}

#[test]
fn run_many_bit_identical_to_sequential_at_1_and_4_threads() {
    let _g = lock_pool();
    let specs = vec![
        JobSpec {
            model: "resnet_s".into(),
            wbits: 4,
            abits: Some(8),
            iters: 12,
            calib_n: 32,
            seed: 0,
            ..JobSpec::default()
        },
        JobSpec {
            model: "resnet_s".into(),
            method: Method::Omse,
            wbits: 4,
            abits: None,
            calib_n: 32,
            seed: 0,
            ..JobSpec::default()
        },
        JobSpec {
            model: "mobilenetv2_s".into(),
            wbits: 4,
            abits: Some(8),
            iters: 8,
            calib_n: 32,
            seed: 1,
            ..JobSpec::default()
        },
    ];

    let mut per_thread_prints = Vec::new();
    for nt in [1usize, 4] {
        pool::set_threads(nt);
        // sequential: fresh session, jobs one by one
        let s1 = session();
        let seq: Vec<JobOutput> =
            specs.iter().map(|sp| s1.run(sp).unwrap()).collect();
        // batched: fresh session, all jobs through the pool
        let s2 = session();
        let many: Vec<JobOutput> = s2
            .run_many(&specs)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        assert_eq!(
            fingerprint(&seq),
            fingerprint(&many),
            "run_many differs from sequential at {nt} threads"
        );
        // batching shares artifacts: fewer misses than 3 independent
        // loads of (train set, test set, fp weights, calib)
        let (hits, _misses) = s2.cache().stats();
        assert!(hits > 0, "batch run must hit the shared cache");
        per_thread_prints.push(fingerprint(&seq));
    }
    pool::set_threads(0);
    assert_eq!(
        per_thread_prints[0], per_thread_prints[1],
        "results depend on the thread count"
    );
}
