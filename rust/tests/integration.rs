//! Integration tests over the real artifacts (manifest + HLO + weights).
//! Each test skips (prints a notice) when `make artifacts` hasn't run, so
//! `cargo test` stays green on a fresh checkout.

use brecq::coordinator::Env;
use brecq::eval::{accuracy, calib_loss, forward, EvalParams};
use brecq::quant::{mse_steps_per_channel, quantize_nearest};
use brecq::recon::{BitConfig, Calibrator, ReconConfig};
use brecq::tensor::Tensor;

fn env() -> Option<Env> {
    let dir = std::env::var("BRECQ_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("integration test skipped: no artifacts at {dir}/");
        return None;
    }
    Some(Env::bootstrap(Some(dir)).expect("bootstrap"))
}

#[test]
fn manifest_and_weights_consistent() {
    let Some(env) = env() else { return };
    for (name, model) in &env.mf.models {
        let store = env.mf.load_weights(model).expect("weights");
        for l in &model.layers {
            let w = store.get(&format!("{}.w", l.name));
            assert_eq!(w.shape, l.wshape, "{name}/{}", l.name);
            let b = store.get(&format!("{}.b", l.name));
            assert_eq!(b.shape, vec![l.cout]);
        }
        // every referenced executable must exist with a parseable signature
        for g in model.grans.values() {
            assert!(env.rt.signature(&g.fim_exe).is_some());
            for u in &g.units {
                assert!(env.rt.signature(&u.fwd_exe).is_some(), "{}", u.name);
                assert!(env.rt.signature(&u.recon_exe).is_some());
            }
        }
        assert!(env.rt.signature(&model.fwd_exe).is_some());
        assert!(env.rt.signature(&model.act_obs_exe).is_some());
    }
}

#[test]
fn fp_eval_matches_training_reference() {
    let Some(env) = env() else { return };
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let test = env.test_set().unwrap();
    let acc = accuracy(&env.rt, model, &EvalParams::fp(model, &ws, &bs),
                       &test)
        .unwrap();
    // the AOT eval path must reproduce the Python-side deploy accuracy
    assert!((acc - model.fp_acc).abs() < 0.002,
            "AOT eval {acc} vs trained {}", model.fp_acc);
}

#[test]
fn unit_stream_stitches_to_full_forward() {
    // advancing the unit stream with FP weights must produce the same
    // logits as the monolithic eval executable — the stream semantics
    // (save_skip / uses_skip) are load-bearing for the whole engine.
    let Some(env) = env() else { return };
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 32, 7);

    for gran in ["layer", "block", "stage", "net"] {
        let mut main = calib.images.clone();
        let mut skip: Option<Tensor> = None;
        let bits = BitConfig::uniform(model, 8, None, false);
        for unit in &model.gran(gran).units {
            if unit.save_skip {
                skip = Some(main.clone());
            }
            main = cal
                .advance(unit, &main, skip.as_ref(), &ws, &bs,
                         &vec![1.0; ws.len()], &bits, false)
                .unwrap();
            if unit.uses_skip {
                skip = None;
            }
        }
        // compare against eval_fwd logits (pad batch up to eval batch)
        let b = model.eval_batch;
        let mut parts = vec![calib.images.clone()];
        while parts.iter().map(|t| t.shape[0]).sum::<usize>() < b {
            parts.push(calib.images.clone());
        }
        let padded = Tensor::stack0(&parts).slice0(0, b);
        let logits = forward(&env.rt, model,
                             &EvalParams::fp(model, &ws, &bs), &padded)
            .unwrap();
        for i in 0..32 * 10 {
            assert!((main.data[i] - logits.data[i]).abs() < 2e-3,
                    "gran={gran} logit {i}: {} vs {}", main.data[i],
                    logits.data[i]);
        }
    }
}

#[test]
fn w8_nearest_rounding_preserves_accuracy() {
    let Some(env) = env() else { return };
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let q: Vec<Tensor> = ws
        .iter()
        .map(|w| {
            let steps = mse_steps_per_channel(w, 8);
            quantize_nearest(w, &steps, 8)
        })
        .collect();
    let test = env.test_set().unwrap();
    let p = EvalParams {
        weights: &q,
        biases: &bs,
        act_steps: vec![1.0; ws.len()],
        bits: BitConfig::uniform(model, 8, None, false),
        aq: false,
    };
    let acc = accuracy(&env.rt, model, &p, &test).unwrap();
    assert!(acc > model.fp_acc - 0.01,
            "8-bit nearest rounding dropped accuracy: {acc}");
}

#[test]
fn brecq_w4_beats_nearest_rounding_w2_cliff() {
    // tiny-budget sanity: W4 BRECQ stays near FP; W2 nearest collapses
    let Some(env) = env() else { return };
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 64, 3);
    let test = env.test_set().unwrap();

    let bits4 = BitConfig::uniform(model, 4, None, true);
    let cfg = ReconConfig { iters: 40, ..ReconConfig::default() };
    let qm = cal.calibrate(&calib, &bits4, &cfg).unwrap();
    let acc4 = accuracy(&env.rt, model, &EvalParams::quantized(&qm), &test)
        .unwrap();
    assert!(acc4 > model.fp_acc - 0.05, "W4 BRECQ too low: {acc4}");

    let (ws, bs) = cal.fp_weights().unwrap();
    let q2: Vec<Tensor> = ws
        .iter()
        .map(|w| {
            let steps = mse_steps_per_channel(w, 2);
            quantize_nearest(w, &steps, 2)
        })
        .collect();
    let p2 = EvalParams {
        weights: &q2,
        biases: &bs,
        act_steps: vec![1.0; ws.len()],
        bits: BitConfig::uniform(model, 2, None, false),
        aq: false,
    };
    let acc2 = accuracy(&env.rt, model, &p2, &test).unwrap();
    assert!(acc4 > acc2 + 0.2,
            "expected W2-nearest cliff below W4-BRECQ: {acc4} vs {acc2}");
}

#[test]
fn calib_loss_orders_with_accuracy() {
    let Some(env) = env() else { return };
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 256, 1);
    let p_fp = EvalParams::fp(model, &ws, &bs);
    let loss_fp = calib_loss(&env.rt, &env.mf, model, &p_fp, &calib)
        .unwrap();
    let q2: Vec<Tensor> = ws
        .iter()
        .map(|w| {
            let steps = mse_steps_per_channel(w, 2);
            quantize_nearest(w, &steps, 2)
        })
        .collect();
    let p_q = EvalParams {
        weights: &q2,
        biases: &bs,
        act_steps: vec![1.0; ws.len()],
        bits: BitConfig::uniform(model, 2, None, false),
        aq: false,
    };
    let loss_q = calib_loss(&env.rt, &env.mf, model, &p_q, &calib).unwrap();
    assert!(loss_q > loss_fp + 0.1,
            "2-bit loss {loss_q} should exceed FP loss {loss_fp}");
}
