//! Hermetic end-to-end integration tests.
//!
//! These run against the deterministic synthetic environment executed by
//! the pure-Rust native backend — no artifacts, Python or XLA — and they
//! never skip. They exercise the full BRECQ pipeline: manifest/weights
//! consistency, FP evaluation, unit-stream semantics at every granularity,
//! Algorithm 1 (block reconstruction with FIM weighting and AdaRound/LSQ
//! optimization) at W4A8 and W2A8, the baselines, and Algorithm 2 (the GA
//! mixed-precision search over the sensitivity LUT).
//!
//! The artifact-backed PJRT path still exists behind the `pjrt` cargo
//! feature + `BRECQ_ARTIFACTS` (see the module at the bottom).

use brecq::coordinator::Env;
use brecq::eval::{accuracy, calib_loss, forward, EvalParams};
use brecq::hwsim::{HwMeasure, ModelSize};
use brecq::mp::{GaConfig, GeneticSearch};
use brecq::quant::{mse_steps_per_channel, quantize_nearest};
use brecq::recon::{BitConfig, Calibrator, ReconConfig};
use brecq::sensitivity::Profiler;
use brecq::tensor::Tensor;

fn env() -> Env {
    Env::bootstrap_synthetic().expect("synthetic environment")
}

#[test]
fn manifest_and_weights_consistent() {
    let env = env();
    assert!(
        env.mf.models.contains_key("resnet_s")
            && env.mf.models.contains_key("mobilenetv2_s"),
        "synthetic manifest must name both models"
    );
    for (name, model) in &env.mf.models {
        let store = env.mf.load_weights(model).expect("weights");
        for l in &model.layers {
            let w = store.get(&format!("{}.w", l.name));
            assert_eq!(w.shape, l.wshape, "{name}/{}", l.name);
            let b = store.get(&format!("{}.b", l.name));
            assert_eq!(b.shape, vec![l.cout]);
        }
        // every referenced executable must exist with a parseable signature
        for g in model.grans.values() {
            assert!(env.rt.signature(&g.fim_exe).is_some());
            for u in &g.units {
                assert!(env.rt.signature(&u.fwd_exe).is_some(), "{}", u.name);
                assert!(env.rt.signature(&u.recon_exe).is_some());
            }
        }
        assert!(env.rt.signature(&model.fwd_exe).is_some());
        assert!(env.rt.signature(&model.act_obs_exe).is_some());
    }
    assert_eq!(env.rt.kind(), "native");
}

#[test]
fn fp_eval_matches_generated_reference() {
    let env = env();
    let test = env.test_set().unwrap();
    for name in ["resnet_s", "mobilenetv2_s"] {
        let model = env.model(name);
        let cal = Calibrator::new(&env.rt, &env.mf, model);
        let (ws, bs) = cal.fp_weights().unwrap();
        let acc =
            accuracy(&env.rt, model, &EvalParams::fp(model, &ws, &bs), &test)
                .unwrap();
        // the generator measures fp_acc with the same kernels; the task
        // acceptance loop requires a perfectly separable task
        assert!(
            (acc - model.fp_acc).abs() < 1e-9,
            "{name}: eval {acc} vs manifest {}",
            model.fp_acc
        );
        assert!(acc > 0.99, "{name}: synthetic task must be separable");
    }
}

#[test]
fn unit_stream_stitches_to_full_forward() {
    // advancing the unit stream with FP weights must produce the same
    // logits as the monolithic eval executable at EVERY granularity — the
    // stream semantics (save_skip / uses_skip) are load-bearing for the
    // whole engine.
    let env = env();
    let train = env.train_set().unwrap();
    for name in ["resnet_s", "mobilenetv2_s"] {
        let model = env.model(name);
        let cal = Calibrator::new(&env.rt, &env.mf, model);
        let (ws, bs) = cal.fp_weights().unwrap();
        let calib = env.calib(&train, model.eval_batch, 7);
        let bits = BitConfig::uniform(model, 8, None, false);
        let logits = forward(
            &env.rt,
            model,
            &EvalParams::fp(model, &ws, &bs),
            &calib.images,
        )
        .unwrap();
        let mut grans: Vec<&String> = model.grans.keys().collect();
        grans.sort();
        assert!(!grans.is_empty());
        let unit_steps = vec![1.0f32; ws.len()];
        for gran in grans {
            let gran = gran.as_str();
            let mut main = calib.images.clone();
            let mut skip: Option<Tensor> = None;
            for unit in &model.gran(gran).units {
                if unit.save_skip {
                    skip = Some(main.clone());
                }
                main = cal
                    .advance(unit, &main, skip.as_ref(), &ws, &bs,
                             &unit_steps, &bits, false)
                    .unwrap();
                if unit.uses_skip {
                    skip = None;
                }
            }
            assert_eq!(main.shape, logits.shape);
            for i in 0..main.data.len() {
                assert!(
                    (main.data[i] - logits.data[i]).abs() < 1e-3,
                    "{name} gran={gran} logit {i}: {} vs {}",
                    main.data[i],
                    logits.data[i]
                );
            }
        }
    }
}

/// The headline acceptance test: full Algorithm 1 on the native backend at
/// W4A8 and W2A8. Reconstruction loss must decrease on every unit that
/// actually quantizes below 8 bits, and the committed model must clear a
/// seeded accuracy floor on the held-out set.
#[test]
fn brecq_e2e_calibration_w4a8_and_w2a8() {
    let env = env();
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let train = env.train_set().unwrap();
    let test = env.test_set().unwrap();
    // K == batch == calib_batch: full-batch optimization, deterministic
    // loss curve, and the unit-executable ABI (declared at calib_batch)
    // holds exactly
    let calib = env.calib(&train, 32, 3);
    let units = &model.gran("block").units;

    for (wbits, floor) in [(4usize, 0.85f64), (2, 0.6)] {
        let bits = BitConfig::uniform(model, wbits, Some(8), true);
        let cfg = ReconConfig {
            iters: 48,
            batch: 32,
            seed: 0,
            ..ReconConfig::default()
        };
        let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();

        assert_eq!(qm.reports.len(), units.len());
        for (unit, r) in units.iter().zip(&qm.reports) {
            let low_bit =
                unit.layer_ids.iter().any(|&l| bits.wbits[l] < 8);
            if low_bit {
                assert!(
                    r.final_loss < r.initial_loss,
                    "W{wbits} unit {}: loss did not decrease \
                     ({:.4e} -> {:.4e})",
                    r.name,
                    r.initial_loss,
                    r.final_loss
                );
            } else {
                // 8-bit units sit at the noise floor; they must not blow up
                assert!(
                    r.final_loss <= r.initial_loss * 1.5 + 1e-6,
                    "W{wbits} unit {}: 8-bit unit regressed \
                     ({:.4e} -> {:.4e})",
                    r.name,
                    r.initial_loss,
                    r.final_loss
                );
            }
        }

        let acc =
            accuracy(&env.rt, model, &EvalParams::quantized(&qm), &test)
                .unwrap();
        assert!(
            acc >= floor,
            "W{wbits}A8 top-1 {acc:.3} below the seeded floor {floor}"
        );
    }
}

#[test]
fn mbv2_block_recon_smoke() {
    // inverted-residual path (depthwise conv, linear bottleneck, identity
    // residual) through the same engine at W4A8
    let env = env();
    let model = env.model("mobilenetv2_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let train = env.train_set().unwrap();
    let test = env.test_set().unwrap();
    let calib = env.calib(&train, 32, 5);
    let bits = BitConfig::uniform(model, 4, Some(8), true);
    let cfg = ReconConfig {
        iters: 32,
        batch: 32,
        seed: 0,
        ..ReconConfig::default()
    };
    let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();
    for r in &qm.reports {
        assert!(
            r.final_loss <= r.initial_loss * 1.5 + 1e-6,
            "unit {}: {:.4e} -> {:.4e}",
            r.name,
            r.initial_loss,
            r.final_loss
        );
    }
    let acc = accuracy(&env.rt, model, &EvalParams::quantized(&qm), &test)
        .unwrap();
    assert!(acc >= 0.8, "mbv2 W4A8 top-1 {acc:.3}");
}

#[test]
fn baselines_run_hermetically() {
    let env = env();
    let model = env.model("resnet_s");
    let train = env.train_set().unwrap();
    let test = env.test_set().unwrap();
    let calib = env.calib(&train, 64, 1);
    let bits = BitConfig::uniform(model, 4, None, true);

    let qm = brecq::baselines::omse(&env.rt, &env.mf, model, &calib, &bits)
        .unwrap();
    let acc_omse =
        accuracy(&env.rt, model, &EvalParams::quantized(&qm), &test).unwrap();
    assert!(acc_omse >= 0.75, "OMSE W4 top-1 {acc_omse:.3}");

    // bias correction walks the layer-granularity unit stream
    let qm = brecq::baselines::bias_correction(
        &env.rt, &env.mf, model, &calib, &bits,
    )
    .unwrap();
    let acc_bc =
        accuracy(&env.rt, model, &EvalParams::quantized(&qm), &test).unwrap();
    assert!(acc_bc >= 0.75, "bias-corr W4 top-1 {acc_bc:.3}");
}

#[test]
fn calib_loss_orders_with_accuracy() {
    let env = env();
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 64, 1);
    let p_fp = EvalParams::fp(model, &ws, &bs);
    let loss_fp =
        calib_loss(&env.rt, &env.mf, model, &p_fp, &calib).unwrap();
    let q2: Vec<Tensor> = ws
        .iter()
        .map(|w| {
            let steps = mse_steps_per_channel(w, 2);
            quantize_nearest(w, &steps, 2)
        })
        .collect();
    let p_q = EvalParams {
        weights: &q2,
        biases: &bs,
        act_steps: vec![1.0; ws.len()],
        bits: BitConfig::uniform(model, 2, None, false),
        aq: false,
    };
    let loss_q = calib_loss(&env.rt, &env.mf, model, &p_q, &calib).unwrap();
    // measured across accepted synthetic tasks: FP CE ~1e-4..0.07, all-2-bit
    // CE ~0.1..0.7 — assert a conservative separation
    assert!(
        loss_q > loss_fp + 0.02,
        "all-2-bit loss {loss_q} should exceed FP loss {loss_fp}"
    );
}

/// Algorithm 2 end-to-end: sensitivity LUT (diagonal + intra-block pair
/// terms) -> GA search under a model-size budget -> calibrate the winning
/// mixed-precision assignment and evaluate it.
#[test]
fn ga_mixed_precision_search_e2e() {
    let env = env();
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let train = env.train_set().unwrap();
    let test = env.test_set().unwrap();
    let calib = env.calib(&train, 32, 2);
    let (ws, bs) = cal.fp_weights().unwrap();

    let prof = Profiler { rt: &env.rt, mf: &env.mf, model };
    let table = prof.measure(&calib, &ws, &bs, true).unwrap();
    assert!(table.base_loss.is_finite());

    let nl = model.layers.len();
    let size = ModelSize;
    let pinned = |b: usize| -> Vec<usize> {
        let mut w = vec![b; nl];
        w[0] = 8;
        w[nl - 1] = 8;
        w
    };
    let c4 = size.measure(model, &pinned(4), 8);
    let c2 = size.measure(model, &pinned(2), 8);
    let budget = (c4 + c2) / 2.0;

    let ga = GeneticSearch { model, table: &table, hw: &size, abits: 8,
                             budget };
    let res = ga
        .run(&GaConfig { iters: 30, seed: 0, ..GaConfig::default() })
        .unwrap();
    assert!(res.hw_cost <= budget, "{} > {budget}", res.hw_cost);
    assert_eq!(res.wbits[0], 8);
    assert_eq!(res.wbits[nl - 1], 8);
    assert!(res.wbits.iter().all(|b| [2, 4, 8].contains(b)));

    let bits = BitConfig::mixed(res.wbits.clone(), 8, true);
    let cfg = ReconConfig {
        iters: 32,
        batch: 32,
        seed: 0,
        ..ReconConfig::default()
    };
    let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();
    let acc = accuracy(&env.rt, model, &EvalParams::quantized(&qm), &test)
        .unwrap();
    assert!(acc >= 0.6, "GA mixed config top-1 {acc:.3}");
}

#[test]
fn dispatch_accounting_populates() {
    let env = env();
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let test = env.test_set().unwrap();
    accuracy(&env.rt, model, &EvalParams::fp(model, &ws, &bs), &test)
        .unwrap();
    let hot = env.rt.hotspots(4);
    assert!(!hot.is_empty());
    assert!(hot[0].1 >= 1);
}

// ------------------------------------------------------------------
// Artifact-backed path (PJRT): opt-in via the `pjrt` feature and
// BRECQ_ARTIFACTS pointing at a `make artifacts` output directory.
// ------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use super::*;

    fn artifact_env() -> Option<Env> {
        let dir = std::env::var("BRECQ_ARTIFACTS").ok()?;
        if !std::path::Path::new(&dir).join("manifest.json").exists() {
            eprintln!("pjrt artifact test skipped: no artifacts at {dir}/");
            return None;
        }
        Some(Env::bootstrap(Some(dir)).expect("bootstrap"))
    }

    #[test]
    fn artifacts_fp_eval_matches_training_reference() {
        let Some(env) = artifact_env() else { return };
        let model = env.model("resnet_s");
        let cal = Calibrator::new(&env.rt, &env.mf, model);
        let (ws, bs) = cal.fp_weights().unwrap();
        let test = env.test_set().unwrap();
        let acc =
            accuracy(&env.rt, model, &EvalParams::fp(model, &ws, &bs), &test)
                .unwrap();
        assert!((acc - model.fp_acc).abs() < 0.002,
                "AOT eval {acc} vs trained {}", model.fp_acc);
    }

    #[test]
    fn artifacts_w8_nearest_rounding_preserves_accuracy() {
        let Some(env) = artifact_env() else { return };
        let model = env.model("resnet_s");
        let cal = Calibrator::new(&env.rt, &env.mf, model);
        let (ws, bs) = cal.fp_weights().unwrap();
        let q: Vec<Tensor> = ws
            .iter()
            .map(|w| {
                let steps = mse_steps_per_channel(w, 8);
                quantize_nearest(w, &steps, 8)
            })
            .collect();
        let test = env.test_set().unwrap();
        let p = EvalParams {
            weights: &q,
            biases: &bs,
            act_steps: vec![1.0; ws.len()],
            bits: BitConfig::uniform(model, 8, None, false),
            aq: false,
        };
        let acc = accuracy(&env.rt, model, &p, &test).unwrap();
        assert!(acc > model.fp_acc - 0.01,
                "8-bit nearest rounding dropped accuracy: {acc}");
    }

    #[test]
    fn artifacts_brecq_w4_beats_nearest_w2_cliff() {
        let Some(env) = artifact_env() else { return };
        let model = env.model("resnet_s");
        let cal = Calibrator::new(&env.rt, &env.mf, model);
        let train = env.train_set().unwrap();
        let calib = env.calib(&train, 64, 3);
        let test = env.test_set().unwrap();

        let bits4 = BitConfig::uniform(model, 4, None, true);
        let cfg = ReconConfig { iters: 40, ..ReconConfig::default() };
        let qm = cal.calibrate(&calib, &bits4, &cfg).unwrap();
        let acc4 =
            accuracy(&env.rt, model, &EvalParams::quantized(&qm), &test)
                .unwrap();
        assert!(acc4 > model.fp_acc - 0.05, "W4 BRECQ too low: {acc4}");

        let (ws, bs) = cal.fp_weights().unwrap();
        let q2: Vec<Tensor> = ws
            .iter()
            .map(|w| {
                let steps = mse_steps_per_channel(w, 2);
                quantize_nearest(w, &steps, 2)
            })
            .collect();
        let p2 = EvalParams {
            weights: &q2,
            biases: &bs,
            act_steps: vec![1.0; ws.len()],
            bits: BitConfig::uniform(model, 2, None, false),
            aq: false,
        };
        let acc2 = accuracy(&env.rt, model, &p2, &test).unwrap();
        assert!(acc4 > acc2 + 0.2,
                "expected W2-nearest cliff below W4-BRECQ: {acc4} vs {acc2}");
    }
}
